"""repro.obs — unified telemetry across the serving tower and sweep engines.

Layers (see ROADMAP "Conventions"):

* device-resident metrics — :class:`MetricsBuf` pytrees threaded through
  the jitted hot paths and folded per chunk (no host syncs);
* time-resolved timelines — :class:`TimelineBuf` ring/windowed pytrees of
  per-round / per-window series (arrival rate, backlog, pick, served) and
  delay-histogram deltas; windowed percentiles are recoverable host-side;
* SLO / convergence monitoring — :class:`SLOSpec` burn rates and
  pick-settling over timeline snapshots, with structured NDJSON events
  (:class:`EventLog`) mirrored into the span trace as instant marks;
* host span tracing — :func:`span` / :func:`traced` around compile /
  launch / upload / finalize boundaries, exported as Chrome trace JSON via
  :func:`write_trace` and aggregate tables via :func:`aggregate`;
* shared compile accounting — :class:`CompileStats` behind every engine's
  ``stats`` object, queryable in one shot via :func:`compile_snapshot`;
* launch profiling — :func:`profile_launch` cost-model + wallclock records
  registered into the same compile registry;
* dashboards — :func:`ascii_dashboard` / :func:`html_report` over the
  timeline snapshots, SLO reports, and profiler tables;
* per-request flight recorder — :class:`FlightLog` over the exact engine's
  static ``flight=True`` records (simulated-time Chrome traces, NDJSON
  streams, p99 exemplar mining) and :class:`FlightRing` for the serving
  loop's per-round phase breakdown.

Everything is gated on ``REPRO_OBS=1`` (or :func:`set_enabled`); disabled,
the layer costs one branch per site and changes no compiled graph.
"""
from repro.obs.state import enabled, set_enabled
from repro.obs.compile import CompileStats, compile_snapshot, register_stats
from repro.obs.metrics import (
    PICK_BINS,
    MetricsBuf,
    sweep_point_metrics,
    to_prometheus,
    valid_mask,
)
from repro.obs.timeline import (
    DELAY_BINS,
    TIMELINE_SLOTS,
    TimelineBuf,
    delay_bucket,
    hist_percentile,
    rolling_percentile,
    sweep_timeline,
    timeline_window,
)
from repro.obs.slo import (
    EventLog,
    SLOSpec,
    burn_rate,
    convergence,
    slo_report,
)
from repro.obs.profile import (
    format_profile,
    profile_launch,
    profile_snapshot,
    reset_profiles,
)
from repro.obs.dashboard import ascii_dashboard, html_report, sparkline
from repro.obs.flight import (
    FLIGHT_SCHEMA,
    FlightLog,
    FlightRing,
    exemplar_panel,
    oracle_task_rows,
)
from repro.obs.trace import (
    Tracer,
    aggregate,
    get_tracer,
    instant,
    reset_trace,
    span,
    traced,
    write_trace,
    write_trace_doc,
)
from repro.obs.meta import SCHEMA_VERSION, git_rev, run_meta

__all__ = [
    "enabled",
    "set_enabled",
    "CompileStats",
    "compile_snapshot",
    "register_stats",
    "MetricsBuf",
    "PICK_BINS",
    "sweep_point_metrics",
    "valid_mask",
    "to_prometheus",
    "TimelineBuf",
    "TIMELINE_SLOTS",
    "DELAY_BINS",
    "delay_bucket",
    "hist_percentile",
    "rolling_percentile",
    "sweep_timeline",
    "timeline_window",
    "SLOSpec",
    "EventLog",
    "burn_rate",
    "convergence",
    "slo_report",
    "profile_launch",
    "profile_snapshot",
    "format_profile",
    "reset_profiles",
    "ascii_dashboard",
    "html_report",
    "sparkline",
    "FLIGHT_SCHEMA",
    "FlightLog",
    "FlightRing",
    "exemplar_panel",
    "oracle_task_rows",
    "Tracer",
    "span",
    "traced",
    "instant",
    "get_tracer",
    "write_trace",
    "write_trace_doc",
    "aggregate",
    "reset_trace",
    "SCHEMA_VERSION",
    "git_rev",
    "run_meta",
]
