"""Pallas gf2mm kernel vs pure-jnp/numpy oracles (interpret mode).

Sweeps shapes, block sizes and dtypes per the kernel-test contract.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis_compat import given, settings, st

from repro.coding import gf256, rs
from repro.kernels.gf2mm import gf2mm, ops, ref


@pytest.mark.parametrize(
    "M,K,N",
    [
        (8, 8, 16),        # tiny, heavy padding
        (48, 48, 256),     # (12,6) code bit-matrix shape
        (128, 128, 128),   # exactly one tile
        (130, 200, 513),   # ragged on all dims
        (256, 2048, 1024), # k = 256 strips (max field), wide payload
    ],
)
@pytest.mark.parametrize("in_dtype", [np.uint8, np.int8, np.float32])
def test_gf2mm_matches_ref_shapes_dtypes(M, K, N, in_dtype):
    rng = np.random.default_rng(M * 7 + K * 3 + N)
    a = rng.integers(0, 2, size=(M, K)).astype(in_dtype)
    b = rng.integers(0, 2, size=(K, N)).astype(in_dtype)
    got = gf2mm.gf2_matmul(jnp.asarray(a), jnp.asarray(b), interpret=True)
    want = ref.gf2_matmul_ref(a, b)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("bm,bn,bk", [(128, 128, 128), (128, 256, 256), (256, 512, 128)])
def test_gf2mm_block_shape_sweep(bm, bn, bk):
    rng = np.random.default_rng(bm + bn + bk)
    M, K, N = 96, 320, 640
    a = rng.integers(0, 2, size=(M, K), dtype=np.uint8)
    b = rng.integers(0, 2, size=(K, N), dtype=np.uint8)
    got = gf2mm.gf2_matmul(
        jnp.asarray(a), jnp.asarray(b), block_m=bm, block_n=bn, block_k=bk, interpret=True
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref.gf2_matmul_ref(a, b)))


@given(
    st.integers(1, 8).flatmap(lambda k: st.tuples(st.just(k), st.integers(k, 2 * k + 4))),
    st.integers(1, 96),
    st.integers(0, 2**31 - 1),
)
@settings(max_examples=15, deadline=None)
def test_rs_encode_kernel_vs_numpy_oracle(kn, B, seed):
    k, n = kn
    rng = np.random.default_rng(seed)
    data = rng.integers(0, 256, size=(k, B), dtype=np.uint8)
    got = np.asarray(ops.rs_encode(jnp.asarray(data), n=n, k=k, interpret=True))
    want = rs.encode(data, n, k)
    np.testing.assert_array_equal(got, want)


def test_rs_decode_kernel_roundtrip():
    rng = np.random.default_rng(42)
    n, k, B = 12, 6, 200
    data = rng.integers(0, 256, size=(k, B), dtype=np.uint8)
    coded = np.asarray(ops.rs_encode(jnp.asarray(data), n=n, k=k, interpret=True))
    present = (1, 3, 6, 8, 10, 11)
    got = np.asarray(
        ops.rs_decode(jnp.asarray(coded[list(present)]), n=n, k=k, present=present, interpret=True)
    )
    np.testing.assert_array_equal(got, data)


def test_gf256_matmul_ref_matches_numpy():
    rng = np.random.default_rng(3)
    g = rng.integers(0, 256, size=(5, 7), dtype=np.uint8)
    d = rng.integers(0, 256, size=(7, 33), dtype=np.uint8)
    got = np.asarray(ref.gf256_matmul_ref(g, d))
    want = gf256.matmul(g, d)
    np.testing.assert_array_equal(got, want)


def test_encode_decode_blob_helpers():
    rng = np.random.default_rng(9)
    payload = rng.integers(0, 256, size=10_001, dtype=np.uint8)
    strips = ops.encode_blob(payload, n=10, k=4)
    assert strips.shape[0] == 10
    present = (0, 5, 7, 9)
    got = ops.decode_blob(strips[list(present)], present, n=10, k=4, payload_len=payload.size)
    np.testing.assert_array_equal(got, payload)
