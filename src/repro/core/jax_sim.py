"""JAX lax.scan simulator: the paper's own M/M/1-style approximation, fast.

The event simulator (:mod:`repro.core.simulator`) is the oracle. This module
implements the *approximate* system the paper analyses in §IV-A — a single
queue with service rate L/U(n,k) — as one ``lax.scan`` over arrivals, fully
jitted. Per arrival i:

  * controller update (TOFEC thresholds, EWMA) → (n_i, k_i),
  * Lindley recursion on the virtual waiting time with service time
    s_i = U(n_i, k_i)/L   (M/G/1 fluid over L threads),
  * service delay sampled exactly as Δ(B) + (1/μ)(Σ_{j<k} E_j/(n−j)) —
    the k-th order statistic of n i.i.d. exponentials.

Used for the wide λ-sweeps in the benchmarks (cross-validated against the
event sim) and as the jit-friendly TOFEC integration point.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.controller import TofecTables, tofec_threshold_step
from repro.core.delay_model import DelayParams, RequestClass


@dataclasses.dataclass(frozen=True)
class JaxSimParams:
    delta_bar: float
    delta_tilde: float
    psi_bar: float
    psi_tilde: float
    J: float
    L: int
    alpha: float
    n_max: int

    @classmethod
    def from_class(cls, c: RequestClass, L: int, alpha: float = 0.99) -> "JaxSimParams":
        p = c.params
        return cls(p.delta_bar, p.delta_tilde, p.psi_bar, p.psi_tilde, c.file_mb, L, alpha, c.n_max)


def _usage(p: JaxSimParams, k, r):
    return p.delta_bar * k * r + p.delta_tilde * p.J * r + p.psi_bar * k + p.psi_tilde * p.J


def backlog_proxy(p, queueing):
    """Queue-length proxy series from the scan's queueing-delay output.

    The scan observes backlog as ``w · L / ū(1,1)`` and reports ``d_q = w``,
    so the controller's exact per-arrival backlog is recoverable post-hoc
    with the same float32 ops — this is what the timeline layer records
    without touching the scan carry."""
    return queueing * p.L / _usage(p, 1.0, 1.0)


def _service_delay(p, k, n, exps, n_max: int):
    """Δ(B) + (1/μ(B)) Σ_{j<k} E_j/(n−j); exps: (n_max,) Exp(1) draws."""
    B = p.J / k
    j = jnp.arange(n_max, dtype=jnp.float32)
    mask = j < k
    denom = jnp.maximum(n - j, 1.0)
    tail = jnp.sum(jnp.where(mask, exps / denom, 0.0))
    return (p.delta_bar + p.delta_tilde * B) + (p.psi_bar + p.psi_tilde * B) * tail


def tofec_scan_core(
    p,
    h_k: jax.Array,
    h_n: jax.Array,
    r_max,
    interarrivals: jax.Array,
    exp_draws: jax.Array,
    *,
    n_max: int,
) -> dict[str, jax.Array]:
    """Traceable single-config scan body shared by the jitted entry point and
    the fleet sweep.

    ``p`` is any object exposing the :class:`JaxSimParams` float fields
    (``delta_bar``/``delta_tilde``/``psi_bar``/``psi_tilde``/``J``/``L``/
    ``alpha``); those fields, the threshold tables and ``r_max`` may all be
    tracers — :mod:`repro.fleet.sweep` vmaps this function over a stacked
    (λ × policy × seed) axis. Only ``n_max`` (the ``exp_draws`` width) must
    be static.
    """

    # Mean usage at the basic code — scale factor for the q-length proxy.
    ubar_hint = _usage(p, 1.0, 1.0)

    def step(carry, inp):
        w, q_ewma = carry  # w: virtual waiting work (seconds of queue wait)
        dt, exps = inp
        w = jnp.maximum(w - dt, 0.0)
        # Queue length proxy upon arrival: waiting work / mean service time
        # (Little's law over the L fluid lanes).
        q_ewma, n_i, k_i = tofec_threshold_step(
            q_ewma, w * p.L / ubar_hint, h_k, h_n, r_max, p.alpha
        )
        nf, kf = n_i.astype(jnp.float32), k_i.astype(jnp.float32)
        r = nf / kf
        s = _usage(p, kf, r) / p.L
        d_q = w
        d_s = _service_delay(p, kf, nf, exps, n_max)
        w = w + s
        return (w, q_ewma), (d_q + d_s, d_q, d_s, n_i, k_i)

    # q̄ starts at the -1.0 cold-start sentinel (tofec_threshold_step):
    # the first observed backlog seeds the EWMA instead of decaying from 0.
    init = (jnp.float32(0.0), jnp.float32(-1.0))
    (_, _), (tot, dq, ds, ns, ks) = jax.lax.scan(step, init, (interarrivals, exp_draws))
    return {"total": tot, "queueing": dq, "service": ds, "n": ns, "k": ks}


@functools.partial(jax.jit, static_argnames=("p",))
def simulate_tofec_scan(
    p: JaxSimParams,
    tables: TofecTables,
    interarrivals: jax.Array,
    exp_draws: jax.Array,
) -> dict[str, jax.Array]:
    """Scan over arrivals. interarrivals: (T,), exp_draws: (T, n_max).

    Returns per-request total delay, queueing delay, service delay, n, k.
    """
    return tofec_scan_core(
        p, tables.h_k, tables.h_n, tables.r_max, interarrivals, exp_draws,
        n_max=p.n_max,
    )


def simulate_tofec_reference(
    p: JaxSimParams,
    tables: TofecTables,
    interarrivals: np.ndarray,
    exp_draws: np.ndarray,
) -> dict[str, np.ndarray]:
    """Pure-Python/numpy mirror of :func:`simulate_tofec_scan`, step for step.

    The regression oracle for the fused serving/scan path: same Lindley
    recursion, same threshold controller, float32 throughout to match the
    scan's device arithmetic. Kept dependency-free of jax execution so a
    silent change in the jitted step (fusion reordering, table handling,
    controller semantics) shows up as a divergence in
    ``tests/test_scan_regression.py``.
    """
    h_k = np.asarray(tables.h_k, np.float32)
    h_n = np.asarray(tables.h_n, np.float32)
    inter = np.asarray(interarrivals, np.float32)
    exps = np.asarray(exp_draws, np.float32)
    one = np.float32(1.0)
    alpha = np.float32(p.alpha)
    L = np.float32(p.L)
    ubar = np.float32(_usage(p, np.float32(1.0), np.float32(1.0)))
    j = np.arange(p.n_max, dtype=np.float32)
    w = np.float32(0.0)
    q_ewma = np.float32(-1.0)  # cold-start sentinel, mirrors the scan carry
    tot, dq_l, ds_l, ns, ks = [], [], [], [], []
    for dt, e in zip(inter, exps):
        w = np.maximum(w - dt, np.float32(0.0))
        q = w * L / ubar
        q_ewma = q if q_ewma < 0.0 else alpha * q + (one - alpha) * q_ewma
        k = 1 + int(np.sum(h_k[1:] > q_ewma))
        n = 1 + int(np.sum(h_n[1:] > q_ewma))
        n = max(min(int(np.float32(tables.r_max) * np.float32(k)), n), k)
        nf, kf = np.float32(n), np.float32(k)
        r = nf / kf
        s = np.float32(_usage(p, kf, r)) / L
        B = np.float32(p.J) / kf
        denom = np.maximum(nf - j, np.float32(1.0))
        tail = np.sum(np.where(j < kf, e / denom, np.float32(0.0)), dtype=np.float32)
        d_s = (np.float32(p.delta_bar) + np.float32(p.delta_tilde) * B) + (
            np.float32(p.psi_bar) + np.float32(p.psi_tilde) * B
        ) * tail
        tot.append(w + d_s)
        dq_l.append(w)
        ds_l.append(d_s)
        ns.append(n)
        ks.append(k)
        w = w + s
    return {
        "total": np.asarray(tot, np.float32),
        "queueing": np.asarray(dq_l, np.float32),
        "service": np.asarray(ds_l, np.float32),
        "n": np.asarray(ns, np.int32),
        "k": np.asarray(ks, np.int32),
    }


def run_tofec_scan(
    c: RequestClass,
    tables: TofecTables,
    lam: float,
    count: int,
    *,
    L: int = 16,
    alpha: float = 0.99,
    seed: int = 0,
) -> dict[str, np.ndarray]:
    """Host wrapper: Poisson arrivals + Exp(1) draws, returns numpy arrays."""
    rng = np.random.default_rng(seed)
    p = JaxSimParams.from_class(c, L, alpha)
    inter = jnp.asarray(rng.exponential(1.0 / lam, size=count), jnp.float32)
    exps = jnp.asarray(rng.exponential(1.0, size=(count, c.n_max)), jnp.float32)
    out = simulate_tofec_scan(p, tables, inter, exps)
    return {k: np.asarray(v) for k, v in out.items()}
