"""Fused jitted serving step: one launch = TOFEC admission update + batched
codec work. Correctness vs the host oracle/policy, bounded retracing across
heterogeneous codes and batch sizes, and the engine's batched fetch path."""

import numpy as np
import pytest

import jax

from repro.coding import rs
from repro.coding.codec import Codec, pow2_bucket
from repro.coding.layout import SharedKeyLayout
import jax.numpy as jnp

from repro.core import (
    PAPER_READ_3MB,
    PAPER_WRITE_3MB,
    FeedbackPolicy,
    FixedKAdaptivePolicy,
    MPCPolicy,
    MPCTables,
    RequestClass,
    StaticPolicy,
    TOFECPolicy,
    mpc_step_jax,
)
from repro.models import get
from repro.serve import (
    ClosedLoopServer,
    FusedServingStep,
    ServePolicy,
    ServingEngine,
)
from repro.storage import MemoryStore, Proxy

CLS = RequestClass("read3mb", 3.0, PAPER_READ_3MB, k_max=6, r_max=2.0, n_max=12)
L = 16
JIT_BACKENDS = ["jnp", "pallas"]


def _erased(rng, data, n, k):
    batch = data.shape[0]
    coded = np.stack([rs.encode(data[i], n, k) for i in range(batch)])
    present = np.stack([rng.permutation(n)[:k] for _ in range(batch)])
    rows = np.stack([coded[i][present[i]] for i in range(batch)])
    return coded, present, rows


@pytest.mark.parametrize("backend", JIT_BACKENDS)
def test_fused_decode_matches_oracle_and_policy(backend):
    step = FusedServingStep.for_class(CLS, L, codec=Codec(backend))
    policy = TOFECPolicy.for_classes([CLS], L)
    rng = np.random.default_rng(0)
    n, k = 12, 6
    for q, batch, B in [(0, 3, 100), (4, 5, 57), (30, 2, 128)]:
        data = rng.integers(0, 256, size=(batch, k, B), dtype=np.uint8)
        _, present, rows = _erased(rng, data, n, k)
        got, next_code = step.decode_batch(rows, present, n=n, k=k, q=q)
        np.testing.assert_array_equal(got, data)
        # The in-jit controller tracks the host policy's EWMA + thresholds.
        assert next_code == policy.select(q=q, idle=0)


@pytest.mark.parametrize("backend", JIT_BACKENDS)
def test_fused_encode_matches_oracle(backend):
    step = FusedServingStep.for_class(CLS, L, codec=Codec(backend))
    rng = np.random.default_rng(1)
    for n, k, batch, B in [(12, 6, 4, 64), (5, 3, 2, 200), (3, 3, 2, 40)]:
        data = rng.integers(0, 256, size=(batch, k, B), dtype=np.uint8)
        coded, next_code = step.encode_batch(data, n=n, k=k, q=1.0)
        want = np.stack([rs.encode(data[i], n, k) for i in range(batch)])
        np.testing.assert_array_equal(coded, want)
        assert next_code[0] >= next_code[1] >= 1


def test_fused_step_requires_jitted_backend():
    with pytest.raises(ValueError, match="host-only"):
        FusedServingStep.for_class(CLS, L, codec=Codec("numpy"))


def test_fused_step_retrace_bounded_across_codes_and_batches():
    """A heterogeneous stream of (n, k) codes, erasure patterns and batch
    sizes compiles at most once per shape bucket: codes + patterns travel as
    runtime matrices, never as trace constants."""
    step = FusedServingStep.for_class(CLS, L, codec=Codec("jnp"))
    rng = np.random.default_rng(2)
    stream = [
        (n, k, batch, Bw)
        for k in (2, 4)
        for n in (k, k + 1, 2 * k)
        for batch in (1, 3, 8)
        for Bw in (33, 120)
    ]
    buckets = set()
    calls = 0
    for n, k, batch, Bw in stream * 2:  # second pass must be compile-free
        data = rng.integers(0, 256, size=(batch, k, Bw), dtype=np.uint8)
        _, present, rows = _erased(rng, data, n, k)
        got, _ = step.decode_batch(rows, present, n=n, k=k, q=float(batch))
        np.testing.assert_array_equal(got, data)
        calls += 1
        buckets.add(("dec", k, pow2_bucket(k), pow2_bucket(Bw, Codec.B_FLOOR),
                     pow2_bucket(batch)))
        if n > k:
            coded, _ = step.encode_batch(data, n=n, k=k, q=float(batch))
            calls += 1
            buckets.add(("enc", k, pow2_bucket(n - k), pow2_bucket(Bw, Codec.B_FLOOR),
                         pow2_bucket(batch)))
    assert step.traces <= len(buckets), (
        f"{step.traces} fused compilations for {len(buckets)} shape buckets"
    )
    assert calls > 2 * len(buckets)  # sanity: far fewer compiles than calls


def test_fused_ewma_state_threads_across_calls():
    """q_ewma persists on device between rounds: repeated heavy-q rounds walk
    the controller from max chunking down to (1, 1), like the host policy."""
    step = FusedServingStep.for_class(CLS, L, codec=Codec("jnp"))
    policy = TOFECPolicy.for_classes([CLS], L)
    rng = np.random.default_rng(3)
    n, k = 12, 6
    data = rng.integers(0, 256, size=(2, k, 64), dtype=np.uint8)
    _, present, rows = _erased(rng, data, n, k)
    codes_fused, codes_host = [], []
    for q in [0, 0, 40, 40, 40, 0, 0, 0]:
        _, nxt = step.decode_batch(rows, present, n=n, k=k, q=q)
        codes_fused.append(nxt)
        codes_host.append(policy.select(q=q, idle=0))
    assert codes_fused == codes_host
    assert codes_fused[1] == (12, 6) and codes_fused[4] == (1, 1)
    step.reset()
    _, nxt = step.decode_batch(rows, present, n=n, k=k, q=0)
    assert nxt == (12, 6)


def test_engine_fused_fetch_matches_unfused_end_to_end():
    arch = get("qwen1.5-0.5b", smoke=True)
    params = arch.init(jax.random.key(1))
    eng = ServingEngine(arch, params, max_seq=64)

    prompt_len = 16
    layout = SharedKeyLayout(K=4, r=2, strip_bytes=prompt_len)
    store = MemoryStore()
    rng = np.random.default_rng(4)
    keys, truth = [], []
    for i in range(4):
        toks = rng.integers(0, arch.cfg.vocab, size=(prompt_len,)).astype(np.int32)
        key = f"prompt/{i}"
        ServingEngine.store_prompt(store, key, layout, toks)
        keys.append(key)
        truth.append(toks)

    cls = RequestClass("prompt", prompt_len * 4 / 2**20, PAPER_READ_3MB,
                       k_max=4, r_max=2.0, n_max=8)
    fused = FusedServingStep.for_class(cls, L=8, codec=Codec("jnp"))
    proxy = Proxy(store, StaticPolicy(4, 2), L=8)
    try:
        res = eng.serve(proxy, layout, keys, prompt_len=prompt_len, steps=4)
        fres = eng.serve(proxy, layout, keys, prompt_len=prompt_len, steps=4,
                         fused=fused)
        assert res.next_code is None and fres.next_code is not None
        np.testing.assert_array_equal(fres.tokens, res.tokens)
        direct = eng.generate(np.stack(truth), steps=4)
        np.testing.assert_array_equal(fres.tokens, direct)
        assert all(c == (4, 2) for c in fres.codes)
    finally:
        proxy.close()


def test_fused_step_error_names_env_var(monkeypatch):
    """The numpy-backend error fires at CONSTRUCTION (not trace time) and
    tells the user exactly which knob to turn."""
    monkeypatch.setenv("REPRO_CODEC_BACKEND", "numpy")
    with pytest.raises(ValueError, match="REPRO_CODEC_BACKEND=jnp") as ei:
        FusedServingStep.for_class(CLS, L, codec=Codec("numpy"))
    assert "'numpy'" in str(ei.value)  # names the current setting


MPC_GRID = [
    # (cls, L, lam, seed) — ≥4 pinned points spanning pool sizes, classes,
    # and light/heavy arrival rates (cold→warm rate estimator transitions).
    (RequestClass("r3", 3.0, PAPER_READ_3MB, k_max=6, r_max=2.0, n_max=12), 16, 2.0, 0),
    (RequestClass("r3", 3.0, PAPER_READ_3MB, k_max=6, r_max=2.0, n_max=12), 16, 30.0, 1),
    (RequestClass("w3", 3.0, PAPER_WRITE_3MB, k_max=4, r_max=3.0, n_max=12), 8, 5.0, 2),
    (RequestClass("r1", 1.0, PAPER_READ_3MB, k_max=3, r_max=2.0, n_max=6), 4, 60.0, 3),
]


@pytest.mark.parametrize("cls_,pool,lam,seed", MPC_GRID)
def test_mpc_host_device_parity_draw_for_draw(cls_, pool, lam, seed):
    """On-device MPC (mpc_step_jax) matches the host MPCPolicy decision
    sequence draw-for-draw: same EWMA carries, same k-major first-minimum
    argmin tie-breaking (see the mpc_step_jax docstring for the contract).

    Host timestamps are float64 sums of float32 interarrivals, so the host's
    ``now - last`` reproduces the exact float32 dt the device sees.
    """
    pol = MPCPolicy(cls_, pool)
    tables = MPCTables.from_policy(pol)
    rng = np.random.default_rng(seed)
    dts = rng.exponential(1.0 / lam, 120).astype(np.float32)
    qs = rng.integers(0, 50, 120)
    carry = (jnp.float32(-1.0), jnp.float32(0.0), jnp.float32(0.0))
    now = 0.0
    for i, (dt, q) in enumerate(zip(dts, qs)):
        if i > 0:
            now += float(dt)
        host = pol.select(q=int(q), idle=0, now=now)
        carry, n, k = mpc_step_jax(
            carry, jnp.float32(q), jnp.float32(dt if i > 0 else -1.0), tables
        )
        assert (int(n), int(k)) == host, f"diverged at arrival {i}"
    # the carries themselves stayed bit-identical, not just the decisions
    assert float(carry[0]) == float(pol.q_ewma)
    assert float(carry[1]) == float(pol.mean_ia)


def test_serve_policy_swap_shares_one_trace():
    """MPC, TOFEC, static and fixed-k run through the SAME fused launch:
    policies are runtime data (ServeTables), so swapping them mid-stream
    never recompiles — and each lane still matches its host policy."""
    policies = {
        "tofec": (ServePolicy.tofec(), TOFECPolicy.for_classes([CLS], L)),
        "static": (ServePolicy.static(8, 4), StaticPolicy(8, 4)),
        "fixedk": (ServePolicy.fixedk(4), FixedKAdaptivePolicy(CLS, L, 4)),
        "mpc": (ServePolicy.mpc(), MPCPolicy(CLS, L)),
    }
    step = FusedServingStep.for_policy(policies["tofec"][0], CLS, L,
                                       codec=Codec("jnp"))
    rng = np.random.default_rng(5)
    n, k = 12, 6
    data = rng.integers(0, 256, size=(2, k, 64), dtype=np.uint8)
    _, present, rows = _erased(rng, data, n, k)
    for name, (spec, host_pol) in policies.items():
        step.set_policy(spec.tables(CLS, L))
        step.reset()
        host_pol.reset()
        now = 0.0
        for i, q in enumerate([0, 7, 25, 3]):
            now += 0.05
            got, nxt = step.decode_batch(rows, present, n=n, k=k, q=q,
                                         dt=(0.05 if i > 0 else -1.0))
            np.testing.assert_array_equal(got, data)
            want = host_pol.select(q=q, idle=0, now=now)
            # fixed-k host may propose n beyond this layout; the fused step
            # reports the controller's raw pick, same as the host policy.
            assert nxt == want, (name, i)
    assert step.traces == 1, f"policy swap retraced: {step.traces} compiles"


def test_closed_loop_round_is_one_launch_and_feeds_writes():
    """Tentpole acceptance: ONE jitted step per round covers admission →
    batched decode → bytes→tokens → prefill (trace count bounded per shape
    bucket), generated tokens match the unfused engine, and the controller's
    pick lands in the proxy's write policy each round."""
    arch = get("qwen1.5-0.5b", smoke=True)
    params = arch.init(jax.random.key(2))
    eng = ServingEngine(arch, params, max_seq=64)

    prompt_len = 16
    layout = SharedKeyLayout(K=4, r=2, strip_bytes=prompt_len)
    store = MemoryStore()
    rng = np.random.default_rng(6)
    keys, truth = [], []
    for i in range(4):
        toks = rng.integers(0, arch.cfg.vocab, size=(prompt_len,)).astype(np.int32)
        ServingEngine.store_prompt(store, f"p/{i}", layout, toks)
        keys.append(f"p/{i}")
        truth.append(toks)

    write_pol = FeedbackPolicy(layout.N, layout.K)
    proxy = Proxy(store, StaticPolicy(8, 4), L=8, write_policy=write_pol)
    step = FusedServingStep.for_policy(ServePolicy.tofec(), CLS, L,
                                       codec=Codec("jnp"))
    server = ClosedLoopServer(eng, proxy, layout, step, prompt_len=prompt_len)
    try:
        results = [server.serve_round(keys, steps=3) for _ in range(4)]
        # one shape bucket (fixed batch/layout) → exactly one fused compile
        assert server.traces == 1, f"{server.traces} compiles for 4 rounds"
        for res in results:
            assert res.ok == [True] * 4
            assert res.next_code == write_pol.code  # loop is closed
        # same tokens as prefill+decode on the ground-truth prompts
        direct = eng.generate(np.stack(truth), steps=3)
        np.testing.assert_array_equal(results[-1].tokens, direct)
        # and the fed-back code governs the next queued write end-to-end
        payload = rng.integers(0, 256, layout.file_bytes, dtype=np.uint8).tobytes()
        server.put("w/0", payload)
        proxy.flush_writes()
        wres = [r for r in proxy.results if r.op == "write"]
        assert wres and (wres[-1].n, wres[-1].k) == write_pol.code
        back = proxy.read("w/0", layout, payload_len=len(payload))
        assert back.ok and back.data == payload
    finally:
        proxy.close()
