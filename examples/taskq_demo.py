"""Taskq demo: the EXACT task-level frontier overlaid on the fluid one.

Runs the same (λ × policy) grid twice — through the exact task-level engine
(:mod:`repro.taskq`: k-of-n order statistics, cancellation, trace-pool
delays, true backlog/idle observables) and through the fluid fleet scan
(:mod:`repro.fleet`: the §IV-A M/G/1 approximation) — and overlays both
mean-delay-vs-λ frontiers as ASCII. Greedy rides the exact grid only: it
needs the idle-thread count the fluid model does not have. Writes the
``BENCH_taskq.json`` artifact next to the fleet's.

Run:  PYTHONPATH=src python examples/taskq_demo.py [--fast]
"""

import argparse
import os
import time

import jax
import numpy as np

from repro.core import PAPER_READ_3MB, RequestClass, queueing
from repro.core.traces import TraceStore
from repro.fleet import FleetSweep, PolicySpec, frontier, frontier_points, grid_cases
from repro.taskq import TaskqSweep, write_taskq_artifact

CLS = RequestClass("read3mb", 3.0, PAPER_READ_3MB, k_max=6, r_max=2.0, n_max=12)
L = 16


def ascii_overlay(exact_by, fluid_by, width: int = 64, height: int = 18) -> str:
    """λ on x, mean delay on y (log scale). Exact curves draw UPPERCASE
    glyphs, fluid ones lowercase — same letter, same policy."""
    pts_all = [p for by in (exact_by, fluid_by) for pts in by.values() for p in pts]
    y_min = min(p.mean for p in pts_all)
    y_max = max(p.mean for p in pts_all)
    x_min = min(p.lam for p in pts_all)
    x_max = max(p.lam for p in pts_all)
    span = np.log(y_max / y_min) + 1e-9
    grid = [[" "] * width for _ in range(height)]
    legend = {}

    def put(by, upper):
        # Greedy draws last: it hugs the same cells as TOFEC at light load.
        for name, pts in sorted(by.items(), key=lambda kv: (kv[0] == "greedy", kv[0])):
            g = name[0].upper() if upper else name[0].lower()
            if name == "static(1,1)":
                g = "B" if upper else "b"  # basic code: avoid the 's' clash
            if name == "static(12,6)":
                g = "H" if upper else "h"  # high-chunk latency-optimal code
            legend[("exact " if upper else "fluid ") + name] = g
            for p in pts:
                x = int((p.lam - x_min) / (x_max - x_min + 1e-9) * (width - 1))
                y = int(np.log(p.mean / y_min) / span * (height - 1))
                grid[height - 1 - y][x] = g

    put(fluid_by, upper=False)
    put(exact_by, upper=True)  # exact over fluid where they collide
    lines = [f"mean delay, log scale ({y_min:.3f}s .. {y_max:.3f}s)"]
    lines += ["|" + "".join(row) for row in grid]
    lines.append("+" + "-" * width + f"> lambda {x_min:.0f}..{x_max:.0f} req/s")
    lines.append("legend: " + "  ".join(f"{g}={n}" for n, g in sorted(legend.items())))
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="smaller grid/horizon")
    args = ap.parse_args()

    cap = queueing.capacity(PAPER_READ_3MB, CLS.file_mb, 1, 1.0, L)
    n_rates = 5 if args.fast else 10
    count = 1000 if args.fast else 3000
    samples = 2048 if args.fast else 8192
    rates = np.linspace(0.10 * cap, 0.85 * cap, n_rates)

    store = TraceStore.generate(
        PAPER_READ_3MB, [CLS.file_mb / k for k in range(1, CLS.k_max + 1)],
        threads=CLS.n_max, samples=samples, correlation=0.14, seed=0,
    )
    dp = store.device_pools(n_max=CLS.n_max)

    fluid_pols = [PolicySpec.tofec(), PolicySpec.static(1, 1), PolicySpec.static(12, 6)]
    exact_pols = fluid_pols + [PolicySpec.greedy()]  # greedy: exact engine only

    t0 = time.monotonic()
    sweep = TaskqSweep(chunk=32)
    exact = sweep.run(grid_cases(rates, exact_pols, [0], CLS, L), count, dp)
    jax.block_until_ready(exact.out)
    dt_exact = time.monotonic() - t0

    t0 = time.monotonic()
    fluid = FleetSweep(chunk=32).run(grid_cases(rates, fluid_pols, [0], CLS, L), count)
    jax.block_until_ready(fluid.out)
    dt_fluid = time.monotonic() - t0

    exact_by = frontier(frontier_points(exact))
    fluid_by = frontier(frontier_points(fluid))
    print(ascii_overlay(exact_by, fluid_by))
    print(f"\nexact taskq sweep: {len(exact.cases)} points in {dt_exact:.2f}s "
          f"({exact.launches} launches, {exact.compiles} compiles); "
          f"fluid fleet sweep: {len(fluid.cases)} points in {dt_fluid:.2f}s")
    tofec_gap = [
        abs(e.mean - f.mean) / f.mean
        for e, f in zip(exact_by["tofec"], fluid_by["tofec"])
    ]
    print(f"exact-vs-fluid tofec mean-delay gap: median "
          f"{100 * float(np.median(tofec_gap)):.1f}% across the λ grid")
    g = exact_by["greedy"]
    print(f"greedy (exact engine only): mean delay {g[0].mean:.3f}s at "
          f"λ={g[0].lam:.0f} → {g[-1].mean:.3f}s at λ={g[-1].lam:.0f}")

    # Flight zoom: replay the slowest grid cell with the per-request
    # recorder on (aggregate engines stream, flight replays one case).
    worst = int(np.argmax(exact.to_numpy()["total"].mean(axis=1)))
    flight_log = sweep.replay_flight(exact, dp, worst)

    out = os.path.join(os.path.dirname(__file__), "..", "benchmarks", "results",
                       "BENCH_taskq.json")
    art = write_taskq_artifact(os.path.abspath(out), exact, flight=flight_log)
    print(f"wrote {os.path.abspath(out)} "
          f"(headline: {art['headline'].get('delay_gain_vs_basic', float('nan')):.2f}x "
          f"light-load delay gain vs basic)")
    fb = art["flight"]
    print(f"flight replay [{fb['label']}]: {fb['records']} task records, "
          f"{fb['exemplars']} exemplars")


if __name__ == "__main__":
    main()
