from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state
from repro.train.train_step import abstract_state, batch_logical_axes, make_train_step, param_specs
from repro.train.trainer import Trainer, TrainerConfig
