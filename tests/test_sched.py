"""repro.sched: the joint shared-pool multi-class scheduler.

Pins the four claims of the subsystem:

* **Degenerate equivalence** — a single-class ``TenantMix`` through
  ``multiclass_scan_core`` reproduces ``tofec_scan_core`` draw for draw
  (same RNG plumbing, identical (n, k) choices, delays to float32 ulp)
  under every discipline.
* **Oracle cross-validation** — the joint scan tracks the discrete-event
  shared-pool simulator at ≥4 grid points mixing disciplines and class
  sizes (the §IV-A fluid-approximation error band; priority points carry a
  wider band — near saturation the fluid model smooths the event system's
  head-of-line granularity).
* **Bounded compiles** — a ≥32-point grid mixing FIFO/priority/WFQ
  disciplines and class counts compiles ONCE per shape bucket (disciplines
  are runtime data), observable via ``SchedSweep.stats``.
* **Cross-class interference** — under strict priority at high aggregate λ
  the low-priority class's p99 strictly exceeds its Poisson-split (fleet
  ``tenant_cases``) prediction while the high-priority class stays near its
  solo value — the phenomenon the fluid split cannot express.
"""

import warnings

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (
    PAPER_READ_3MB,
    PAPER_WRITE_3MB,
    RequestClass,
    TofecTables,
    TOFECPolicy,
    build_class_plan,
)
from repro.core.jax_sim import JaxSimParams, simulate_tofec_scan
from repro.core.simulator import simulate_shared_pool
from repro.core.traces import TraceSampler
from repro.fleet import (
    FleetSweep,
    PoissonWorkload,
    PolicySpec,
    TenantMix,
    frontier_points,
    tenant_cases,
)
from repro.sched import (
    DisciplineSpec,
    SchedCase,
    SchedSweep,
    interference_summary,
    jain_index,
    multiclass_points,
    sched_cases,
    write_multiclass_artifact,
)

R3 = RequestClass("read3mb", 3.0, PAPER_READ_3MB, k_max=6, r_max=2.0, n_max=12)
R1 = RequestClass("read1mb", 1.0, PAPER_READ_3MB, k_max=4, r_max=2.0, n_max=8)
W1 = RequestClass("write1mb", 1.0, PAPER_WRITE_3MB, k_max=3, r_max=2.0, n_max=6)
L = 16


def _mix2(lam: float, w0: float = 0.6) -> TenantMix:
    return TenantMix(lam, (R3, R1), (w0, 1.0 - w0))


# ---------------------------------------------------------------------------
# Degenerate equivalence: C = 1 must be tofec_scan_core, draw for draw
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "disc",
    [DisciplineSpec.fifo(), DisciplineSpec.priority(0), DisciplineSpec.wfq(1.0)],
)
def test_single_class_mix_reproduces_tofec_scan(disc):
    """Every discipline degenerates to the single-class scan on the same
    draws: the FIFO drain is bit-exact max(w−dt, 0) for C = 1, priorities
    and weights have nothing to arbitrate."""
    lam, seed, count = 18.0, 5, 1200
    mix = TenantMix(lam=lam, classes=(R3,), weights=(1.0,))
    res = SchedSweep(chunk=4).run(
        [SchedCase(mix=mix, discipline=disc, seed=seed, L=L)], count
    )

    # Same RNG plumbing as a fleet grid point: one default_rng(seed) stream,
    # interarrivals then exponentials; a single-class mix draws no class ids.
    rng = np.random.default_rng(seed)
    inter, exps = PoissonWorkload(lam).device_arrays(rng, count, R3.n_max)
    ref = simulate_tofec_scan(
        JaxSimParams.from_class(R3, L),
        TofecTables.from_plan(build_class_plan(R3, L)),
        jnp.asarray(inter), jnp.asarray(exps),
    )
    out = res.to_numpy()
    np.testing.assert_array_equal(out["n"][0], np.asarray(ref["n"]))
    np.testing.assert_array_equal(out["k"][0], np.asarray(ref["k"]))
    np.testing.assert_array_equal(out["service"][0], np.asarray(ref["service"]))
    # total/queueing may differ by one float32 ulp (drain-select FMA fusion).
    for name in ("total", "queueing"):
        np.testing.assert_allclose(
            out[name][0], np.asarray(ref[name]), rtol=0, atol=1e-6
        )


def test_single_class_mix_device_arrays_draw_for_draw():
    """TenantMix.multiclass_device_arrays consumes the RNG stream exactly
    like Workload.device_arrays when C = 1 (ids are free)."""
    rng_a, rng_b = np.random.default_rng(9), np.random.default_rng(9)
    inter_a, exps_a = PoissonWorkload(12.0).device_arrays(rng_a, 500, R3.n_max)
    mix = TenantMix(12.0, (R3,), (1.0,))
    inter_b, exps_b, ids = mix.multiclass_device_arrays(rng_b, 500, R3.n_max)
    np.testing.assert_array_equal(inter_a, inter_b)
    np.testing.assert_array_equal(exps_a, exps_b)
    assert ids.dtype == np.int32 and not ids.any()


# ---------------------------------------------------------------------------
# Cross-validation against the event-sim shared-pool oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "mix,disc,tol",
    [
        (_mix2(20.0), DisciplineSpec.fifo(), 0.20),
        (_mix2(28.0), DisciplineSpec.priority(0, 1), 0.30),
        (TenantMix(30.0, (R3, R1), (0.5, 0.5)), DisciplineSpec.wfq(2.0, 1.0), 0.35),
        (TenantMix(35.0, (R3, R1, W1), (0.4, 0.3, 0.3)), DisciplineSpec.fifo(), 0.25),
        (TenantMix(55.0, (R3, R1), (0.5, 0.5)), DisciplineSpec.priority(1, 0), 0.40),
    ],
)
def test_joint_scan_cross_validates_against_shared_pool_oracle(mix, disc, tol):
    """≥4 joint grid points (mixed disciplines, mixed class sizes): the
    scan's aggregate mean delay lands in the event oracle's band, and both
    simulators agree on the per-class delay ordering."""
    count = 3000
    res = SchedSweep().run([SchedCase(mix=mix, discipline=disc, seed=3, L=L)], count)
    pt = multiclass_points(res)[0]

    rng = np.random.default_rng(7)
    arr = np.cumsum(mix.interarrivals(rng, count).astype(np.float64))
    ids = mix.cls_ids(rng, count)
    pols = [TOFECPolicy([build_class_plan(c, L)]) for c in mix.classes]
    samp = [TraceSampler(c.params, c.file_mb) for c in mix.classes]
    kw = {}
    if disc.kind == "priority":
        kw["prio"] = disc.prio
    if disc.kind == "wfq":
        kw["weights"] = disc.weights
    ev = simulate_shared_pool(
        pols, arr, ids, samp, L=L, discipline=disc.kind, seed=8, **kw
    )
    ev_mean = float(ev.totals().mean())
    assert abs(pt.agg_mean - ev_mean) / ev_mean < tol, (pt.agg_mean, ev_mean)

    ev_cls = [
        np.mean([s.total for s in ev.stats if s.cls_id == c])
        for c in range(len(mix.classes))
    ]
    scan_cls = [c["mean"] for c in pt.classes]
    # Per-class means stay in the oracle's band (loose: priority amplifies
    # the starved class's approximation error), and when the oracle clearly
    # separates the classes the scan agrees on who suffers most/least.
    for e, s in zip(ev_cls, scan_cls):
        assert abs(s - e) / e < 0.5, (scan_cls, ev_cls)
    if max(ev_cls) > 1.5 * min(ev_cls):
        assert int(np.argmax(scan_cls)) == int(np.argmax(ev_cls))
        assert int(np.argmin(scan_cls)) == int(np.argmin(ev_cls))


def test_shared_pool_oracle_validates_inputs():
    pols = [TOFECPolicy([build_class_plan(R3, L)])]
    arr, ids = np.arange(4.0), np.zeros(4, np.int64)
    samp = [TraceSampler(R3.params, R3.file_mb)]
    with pytest.raises(ValueError):
        simulate_shared_pool(pols, arr, ids, samp, discipline="lifo")
    with pytest.raises(ValueError):
        simulate_shared_pool(pols, arr, ids, samp, discipline="priority", prio=(1,))
    with pytest.raises(ValueError):
        simulate_shared_pool(pols, arr, ids, samp, discipline="wfq", weights=(0.0,))


# ---------------------------------------------------------------------------
# Shape buckets / compile counts
# ---------------------------------------------------------------------------


def test_sched_compile_count_bounded_on_heterogeneous_discipline_grid():
    """A ≥32-point grid mixing all three disciplines, class counts (2 and 3)
    and rates runs in ONE compilation — disciplines and class mixes are
    runtime data in a shared (chunk, T, C, n_max, tables) bucket."""
    sweep = SchedSweep(chunk=16, t_floor=512)
    disciplines = [
        DisciplineSpec.fifo(),
        DisciplineSpec.priority(0, 1),
        DisciplineSpec.priority(1, 0),
        DisciplineSpec.wfq(3.0, 1.0),
    ]
    mixes = [_mix2(lam) for lam in (10.0, 20.0, 30.0, 40.0)]
    cases = sched_cases(mixes, disciplines, [0, 1], L=L)
    # A 3-class mix in the same run pads every case to C = 3 (shared bucket).
    cases += sched_cases(
        [TenantMix(25.0, (R3, R1, W1), (0.4, 0.3, 0.3))],
        [DisciplineSpec.fifo(), DisciplineSpec.priority(2, 0, 1),
         DisciplineSpec.wfq(1.0, 1.0, 2.0)],
        [0], L=L,
    )
    assert len(cases) == 35

    res = sweep.run(cases, count=500)
    assert res.compiles == 1, res.compiles
    assert res.launches == 3  # ceil(35 / 16) memory-bounded chunks

    # Same bucket: count 400 pads to the same 512 T-bucket, and keeping a
    # 3-class case in the subset keeps the run's class padding at C = 3.
    res2 = sweep.run(cases[:10] + cases[32:], count=400)
    assert res2.compiles == 0
    # New time bucket compiles once more.
    res3 = sweep.run(cases[16:], count=600)
    assert res3.compiles == 1
    assert sweep.stats.traces == 2 and sweep.stats.cases == 35 + 13 + 19


def test_sched_chunk_padding_keeps_results_exact():
    """Tail-chunk repetition padding never leaks into joint results."""
    cases = sched_cases(
        [_mix2(12.0), _mix2(35.0), _mix2(55.0)],
        [DisciplineSpec.fifo(), DisciplineSpec.priority(0, 1)],
        [0], L=L,
    )
    a = SchedSweep(chunk=4).run(cases, count=600).to_numpy()  # 6 = 4 + 2(pad)
    b = SchedSweep(chunk=8).run(cases, count=600).to_numpy()  # one launch
    for name in ("total", "queueing", "service", "n", "k", "cls_ids"):
        np.testing.assert_array_equal(a[name], b[name])


# ---------------------------------------------------------------------------
# Cross-class interference: what the Poisson split cannot see
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def interference_setup():
    """High aggregate load, two identical-parameter classes, 50/50 split;
    the fleet's Poisson-split prediction vs the joint shared-pool scan."""
    lo = RequestClass("read3mb-lo", 3.0, PAPER_READ_3MB, k_max=6, r_max=2.0, n_max=12)
    mix = TenantMix(60.0, (R3, lo), (0.5, 0.5))
    count = 4000
    joint = SchedSweep().run(
        [
            SchedCase(mix=mix, discipline=DisciplineSpec.priority(0, 1), seed=3, L=L),
            SchedCase(mix=mix, discipline=DisciplineSpec.fifo(), seed=3, L=L),
            SchedCase(mix=mix, discipline=DisciplineSpec.wfq(1.0, 1.0), seed=3, L=L),
        ],
        count,
    )
    split = FleetSweep().run(
        tenant_cases(mix, [PolicySpec.tofec()], [3], L, quiet=True), count
    )
    split_p99 = {p.cls_name: p.p99 for p in frontier_points(split)}
    return multiclass_points(joint), split_p99, joint


def test_priority_starves_low_class_beyond_split_prediction(interference_setup):
    """THE acceptance claim: under strict priority at high λ the low-priority
    p99 strictly exceeds the fluid split's prediction (which gives every
    class its own private pool) while the high-priority class's p99 stays
    near its solo value."""
    points, split_p99, _ = interference_setup
    prio = next(p for p in points if p.discipline.startswith("priority"))
    hi, lo = prio.cls("read3mb"), prio.cls("read3mb-lo")
    # Low priority: the split prediction misses the interference entirely.
    assert lo["p99"] > 2.0 * split_p99["read3mb-lo"], (lo["p99"], split_p99)
    # High priority: unaffected by the low class — near its solo prediction.
    assert hi["p99"] < 1.3 * split_p99["read3mb"], (hi["p99"], split_p99)
    # And the adaptation interferes too: the starved class backs off to
    # cheap codes while the protected class keeps chunking aggressively.
    assert lo["mean_k"] < hi["mean_k"]


def test_fifo_and_wfq_share_pain_fairly(interference_setup):
    """FIFO and equal-weight WFQ spread the shared-pool congestion evenly:
    both classes exceed their split prediction and Jain stays ≈ 1, while
    priority collapses the fairness index."""
    points, split_p99, _ = interference_setup
    for name in ("fifo", "wfq(1:1)"):
        pt = next(p for p in points if p.discipline == name)
        assert pt.jain_delay > 0.95, (name, pt.jain_delay)
        for c in pt.classes:
            assert c["p99"] > split_p99[c["name"]], (name, c)
    prio = next(p for p in points if p.discipline.startswith("priority"))
    assert prio.jain_delay < 0.8, prio.jain_delay


def test_interference_summary_and_artifact(interference_setup, tmp_path):
    points, split_p99, joint = interference_setup
    summary = interference_summary(points, split_p99)
    assert summary["priority(0,1)"]["p99_vs_split"]["read3mb-lo"] > 2.0
    assert summary["priority(0,1)"]["p99_spread"] > summary["fifo"]["p99_spread"]

    import json

    path = tmp_path / "BENCH_multiclass.json"
    art = write_multiclass_artifact(str(path), joint, points=points)
    on_disk = json.loads(path.read_text())
    assert on_disk["schema"] == "repro.sched/BENCH_multiclass/v1"
    assert on_disk["grid_size"] == 3 and len(on_disk["points"]) == 3
    assert art["compiles"] == joint.compiles
    for p in on_disk["points"]:
        assert {c["name"] for c in p["classes"]} == {"read3mb", "read3mb-lo"}


# ---------------------------------------------------------------------------
# Frontier reductions
# ---------------------------------------------------------------------------


def test_multiclass_points_percentiles_and_counts():
    mix = TenantMix(25.0, (R3, R1), (0.7, 0.3))
    res = SchedSweep().run(
        sched_cases([mix], [DisciplineSpec.fifo()], [0, 1], L=L), 2000
    )
    for pt in multiclass_points(res):
        counts = [c["count"] for c in pt.classes]
        assert sum(counts) == pytest.approx(2000 * 0.95, rel=0.01)
        assert counts[0] > counts[1]  # 70/30 split
        for c in pt.classes:
            assert c["p50"] <= c["p90"] <= c["p95"] <= c["p99"]
            assert 1.0 <= c["mean_k"] <= c["mean_n"]
        assert 0.0 < pt.jain_delay <= 1.0


def test_jain_index_bounds():
    assert jain_index([1.0, 1.0, 1.0]) == pytest.approx(1.0)
    assert jain_index([1.0, 0.0, 0.0]) == pytest.approx(1.0 / 3.0)
    assert jain_index([]) == 1.0


# ---------------------------------------------------------------------------
# Satellite: the fluid split is now the documented approximation path
# ---------------------------------------------------------------------------


def test_tenant_cases_warns_and_quiet_flag():
    mix = _mix2(20.0)
    with pytest.warns(UserWarning, match="repro.sched"):
        tenant_cases(mix, [PolicySpec.tofec()], [0], L)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        cases = tenant_cases(mix, [PolicySpec.tofec()], [0], L, quiet=True)
    assert len(cases) == 2
