"""Discrete-event simulator of the proxy queueing system (Fig.2).

Faithful to §II-A semantics:
  * FIFO request queue; FIFO task queue; L threads.
  * The head-of-line request is admitted only when at least one thread is
    idle AND the task queue is empty; its n tasks are then injected.
  * Tasks start on idle threads in FIFO order; per-batch task delays are
    pre-sampled jointly (preserving Shared-Key cross-thread correlation;
    "the i-th thread downloads the i-th coded chunk", §III-B).
  * When k tasks of a request have completed, the request departs and its
    remaining tasks are preemptively cancelled: queued ones are removed,
    in-service ones release their thread immediately (§II-A, footnote 1).
  * Work conserving: freed threads immediately pull queued tasks, and
    admission re-runs whenever a thread frees or the task queue drains.

Delay bookkeeping matches §II-C: D_q = T_1 − T_A (first task start minus
arrival), D_s = X_(k) − T_1, total = D_q + D_s.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
from collections import deque

import numpy as np

from repro.core.controller import Policy


@dataclasses.dataclass
class RequestStats:
    arrival: float
    cls_id: int
    n: int
    k: int
    t_first_start: float = np.nan
    t_done: float = np.nan
    completed_tasks: int = 0

    @property
    def d_q(self) -> float:
        return self.t_first_start - self.arrival

    @property
    def d_s(self) -> float:
        return self.t_done - self.t_first_start

    @property
    def total(self) -> float:
        return self.t_done - self.arrival


@dataclasses.dataclass
class SimResult:
    stats: list[RequestStats]
    horizon: float

    def totals(self) -> np.ndarray:
        return np.array([s.total for s in self.stats])

    def service(self) -> np.ndarray:
        return np.array([s.d_s for s in self.stats])

    def queueing(self) -> np.ndarray:
        return np.array([s.d_q for s in self.stats])

    def ks(self) -> np.ndarray:
        return np.array([s.k for s in self.stats])

    def ns(self) -> np.ndarray:
        return np.array([s.n for s in self.stats])

    def throughput(self) -> float:
        return len(self.stats) / self.horizon if self.horizon > 0 else 0.0

    def k_composition(self, k_max: int) -> np.ndarray:
        """Fraction of requests served at each k = 1..k_max (Fig.8)."""
        ks = self.ks()
        return np.array([(ks == k).mean() for k in range(1, k_max + 1)])

    def summary(self) -> dict:
        t = self.totals()
        if len(t) == 0:
            return {"count": 0}
        return {
            "count": len(t),
            "mean": float(t.mean()),
            "median": float(np.median(t)),
            "p90": float(np.percentile(t, 90)),
            "p99": float(np.percentile(t, 99)),
            "std": float(t.std()),
            "mean_k": float(self.ks().mean()),
            "mean_n": float(self.ns().mean()),
            "throughput": float(self.throughput()),
        }


class _Task:
    __slots__ = ("req", "delay", "cancelled", "started", "done")

    def __init__(self, req, delay: float):
        self.req = req
        self.delay = delay
        self.cancelled = False
        self.started = False
        self.done = False


class _Request:
    __slots__ = ("stats", "tasks")

    def __init__(self, stats: RequestStats):
        self.stats = stats
        self.tasks: list[_Task] = []


def simulate(
    policy: Policy,
    arrivals: np.ndarray,
    sampler,
    *,
    L: int = 16,
    cls_ids: np.ndarray | None = None,
    samplers: list | None = None,
    seed: int = 0,
    warmup_frac: float = 0.05,
) -> SimResult:
    """Run the event simulation over the given arrival times.

    ``sampler``: object with .sample(rng, k, n) → (n,) task delays (used for
    cls 0); ``samplers`` optionally overrides per class.
    """
    rng = np.random.default_rng(seed)
    arrivals = np.asarray(arrivals, dtype=np.float64)
    if cls_ids is None:
        cls_ids = np.zeros(len(arrivals), dtype=np.int64)
    samplers = samplers or [sampler]
    policy.reset()

    seq = itertools.count()
    events: list = []  # (time, seq, kind, payload)
    for t, c in zip(arrivals, cls_ids):
        heapq.heappush(events, (float(t), next(seq), 0, int(c)))  # 0 = arrival

    request_queue: deque[_Request] = deque()
    task_queue: deque[_Task] = deque()
    idle = L
    now = 0.0
    done_stats: list[RequestStats] = []

    def start_tasks():
        nonlocal idle
        while idle > 0 and task_queue:
            task = task_queue.popleft()
            if task.cancelled:
                continue
            idle -= 1
            task.started = True
            req = task.req
            if np.isnan(req.stats.t_first_start):
                req.stats.t_first_start = now
            heapq.heappush(events, (now + task.delay, next(seq), 1, task))

    def admit():
        while request_queue and idle > 0 and not task_queue:
            req = request_queue.popleft()
            st = req.stats
            s = samplers[st.cls_id] if st.cls_id < len(samplers) else samplers[0]
            delays = np.asarray(s.sample(rng, st.k, st.n), dtype=np.float64)
            req.tasks = [_Task(req, float(d)) for d in delays]
            task_queue.extend(req.tasks)
            start_tasks()

    while events:
        now, _, kind, payload = heapq.heappop(events)
        if kind == 0:  # arrival
            cls_id = payload
            n, k = policy.select(q=len(request_queue), idle=idle, cls_id=cls_id, now=now)
            st = RequestStats(arrival=now, cls_id=cls_id, n=int(n), k=int(k))
            request_queue.append(_Request(st))
            admit()
        else:  # task completion
            task: _Task = payload
            if task.cancelled or task.done:
                continue
            task.done = True
            idle += 1
            req = task.req
            req.stats.completed_tasks += 1
            if req.stats.completed_tasks == req.stats.k:
                req.stats.t_done = now
                done_stats.append(req.stats)
                # Preemptive cancellation of the n − k leftovers.
                for t2 in req.tasks:
                    if not t2.done and not t2.cancelled:
                        t2.cancelled = True
                        if t2.started:
                            idle += 1  # preempt in-service task
            start_tasks()
            admit()

    horizon = float(arrivals[-1] - arrivals[0]) if len(arrivals) > 1 else 0.0
    done_stats.sort(key=lambda s: s.arrival)
    n_warm = int(len(done_stats) * warmup_frac)
    return SimResult(stats=done_stats[n_warm:], horizon=horizon)


def poisson_arrivals(rng: np.random.Generator, lam: float, count: int) -> np.ndarray:
    return np.cumsum(rng.exponential(1.0 / lam, size=count))


def piecewise_poisson_arrivals(
    rng: np.random.Generator, rates: list[tuple[float, float]]
) -> np.ndarray:
    """Arrivals for consecutive (duration_s, rate) segments (Fig.10 setup).

    .. deprecated:: use :class:`repro.fleet.workloads.PiecewiseWorkload`
       directly — this is now a thin wrapper kept for source compatibility
       (draw-for-draw identical RNG consumption). The fleet workload family
       also yields device-ready interarrival arrays from the same spec.
    """
    from repro.fleet.workloads import PiecewiseWorkload

    return PiecewiseWorkload(tuple(rates)).arrival_times(rng)
