"""End-to-end behaviour tests for the paper's system (the TOFEC claims that
matter, exercised through the full stack — controller → simulator, and
storage → proxy → erasure decode → model serving)."""

import numpy as np

from repro.coding.layout import SharedKeyLayout
from repro.core import (
    PAPER_READ_3MB,
    RequestClass,
    StaticPolicy,
    TOFECPolicy,
)
from repro.core import queueing
from repro.core.controller import MPCPolicy
from repro.core.simulator import poisson_arrivals, simulate
from repro.core.traces import TraceSampler
from repro.storage import FaultyStore, MemoryStore, Proxy, store_coded_object

CLS = RequestClass("read3mb", 3.0, PAPER_READ_3MB, k_max=6, r_max=2.0, n_max=12)
L = 16
SAMPLER = TraceSampler(PAPER_READ_3MB, 3.0, correlation=0.14)


def _run(policy, lam, count=5000, seed=11):
    rng = np.random.default_rng(seed)
    return simulate(policy, poisson_arrivals(rng, lam, count), SAMPLER, L=L, seed=seed)


def test_paper_headline_light_load_gain():
    """TOFEC ≥ 1.7× lower mean delay than basic at light load (paper ~2.5×)."""
    cap = queueing.capacity(PAPER_READ_3MB, 3.0, 1, 1.0, L)
    tofec = _run(TOFECPolicy.for_classes([CLS], L), 0.15 * cap)
    basic = _run(StaticPolicy(1, 1), 0.15 * cap)
    assert basic.totals().mean() / tofec.totals().mean() > 1.7


def test_paper_headline_capacity_retention():
    """TOFEC sustains ≥ 2.3× the arrival rate that the delay-optimal static
    (6,3) code can (paper: >3×) — queues stay bounded where (6,3) diverges."""
    cap = queueing.capacity(PAPER_READ_3MB, 3.0, 1, 1.0, L)
    lam = 0.9 * cap  # ≈ 2.3× the capacity of the (6,3) code
    tofec = _run(TOFECPolicy.for_classes([CLS], L), lam, count=8000)
    static63 = _run(StaticPolicy(6, 3), lam, count=8000)
    assert tofec.totals().mean() < 0.6  # bounded
    assert static63.totals().mean() > 5 * tofec.totals().mean()  # divergent
    cap63 = queueing.capacity(PAPER_READ_3MB, 3.0, 3, 2.0, L)
    assert cap / cap63 > 2.3


def test_beyond_paper_mpc_dominates_threshold_controller():
    cap = queueing.capacity(PAPER_READ_3MB, 3.0, 1, 1.0, L)
    for frac in (0.4, 0.75):
        tofec = _run(TOFECPolicy.for_classes([CLS], L), frac * cap)
        mpc = _run(MPCPolicy(CLS, L), frac * cap)
        assert mpc.totals().mean() < tofec.totals().mean() * 1.02, frac


def test_full_stack_read_after_node_losses():
    """Fig.3 layout + proxy + RS decode survive failures of chunk reads."""
    layout = SharedKeyLayout(K=6, r=2, strip_bytes=512)
    rng = np.random.default_rng(0)
    payload = rng.integers(0, 256, size=layout.file_bytes, dtype=np.uint8).tobytes()
    inner = MemoryStore()
    store_coded_object(inner, "blob", layout, payload)
    store = FaultyStore(inner, p_fail=0.45, seed=1)
    proxy = Proxy(store, StaticPolicy(6, 3), L=8)
    try:
        ok = 0
        for _ in range(12):
            res = proxy.read("blob", layout, payload_len=len(payload))
            if res.ok:
                assert res.data == payload
                ok += 1
        assert ok >= 6  # (6,3) tolerates 3 failures/request at 45% fail rate
    finally:
        proxy.close()
