"""Shared-Key strip layout (paper §II-B, §III, Fig.3).

One (N = r*K, K) MDS codeword over b-byte *strips* is stored as a single
coded object of N*b bytes. For every divisor m of K it simultaneously acts
as an (n = N/m, k = K/m) MDS code over B = m*b-byte *chunks*: chunk i is the
contiguous strip range [i*m, (i+1)*m), fetched with one ranged read. Any k
chunks cover k*m = K strips, which reconstruct the file.

This is what makes variable chunk sizing storage-efficient: one stored
object (cost r × file size) supports every chunking level, vs. Unique-Key's
extra r × file size *per chunk size* (§III-A.1).

Encode/decode route through the unified batched codec engine
(:mod:`repro.coding.codec`); the backend follows ``REPRO_CODEC_BACKEND``
(numpy oracle by default, ``jnp`` / ``pallas`` for bulk batched paths) and
can be overridden per call. :func:`encode_files` amortizes one kernel
launch over a whole batch of same-class files — the proxy's write-queue
drain uses it — and :func:`reconstruct_batch` is its read-side mirror: one
batched decode with per-item ``present`` masks reconstructs a whole
admission round of completed reads, across heterogeneous chunk levels and
erasure patterns.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.coding import codec as codec_mod


def divisors(x: int) -> list[int]:
    return [d for d in range(1, x + 1) if x % d == 0]


@dataclasses.dataclass(frozen=True)
class SharedKeyLayout:
    """Layout parameters for one file class.

    K: code dimension at strip granularity (max chunking level k_max).
    r: integer redundancy ratio (N = r*K).
    strip_bytes: b. File payload is K*b bytes (padded if shorter).
    """

    K: int
    r: int
    strip_bytes: int

    def __post_init__(self):
        if self.K < 1 or self.r < 1 or self.strip_bytes < 1:
            raise ValueError("K, r, strip_bytes must be positive")
        if self.N > 256:
            raise ValueError("N = r*K must be <= 256 for GF(256) RS")

    @property
    def N(self) -> int:
        return self.r * self.K

    @property
    def file_bytes(self) -> int:
        return self.K * self.strip_bytes

    @property
    def object_bytes(self) -> int:
        return self.N * self.strip_bytes

    def supported_k(self) -> list[int]:
        """Chunk-level code dimensions k available from this one object."""
        return sorted(self.K // m for m in divisors(self.K))

    def code_for_k(self, k: int) -> tuple[int, int, int]:
        """(n_max, k, m) for a chunk-level dimension k; n_max = N/m."""
        if self.K % k != 0:
            raise ValueError(f"k={k} must divide K={self.K}")
        m = self.K // k
        if self.N % m != 0:
            raise ValueError(f"m={m} must divide N={self.N}")
        return self.N // m, k, m

    def chunk_bytes(self, k: int) -> int:
        """B = J / k for chunk-level dimension k."""
        _, _, m = self.code_for_k(k)
        return m * self.strip_bytes

    def chunk_range(self, k: int, chunk_idx: int) -> tuple[int, int]:
        """(offset, length) byte range of chunk ``chunk_idx`` at level k.

        This is the argument to the storage partial-read API
        (S3 getObject with setRange / Azure DownloadRangeToStream).
        """
        n_max, _, m = self.code_for_k(k)
        if not 0 <= chunk_idx < n_max:
            raise ValueError(f"chunk_idx {chunk_idx} out of range for n_max={n_max}")
        off = chunk_idx * m * self.strip_bytes
        return off, m * self.strip_bytes

    # -- encode / decode ----------------------------------------------------

    def _strip_data(self, payload: bytes) -> np.ndarray:
        if len(payload) > self.file_bytes:
            raise ValueError(f"payload {len(payload)}B exceeds {self.file_bytes}B")
        buf = np.zeros(self.file_bytes, dtype=np.uint8)
        buf[: len(payload)] = np.frombuffer(payload, dtype=np.uint8)
        return buf.reshape(self.K, self.strip_bytes)

    def _n_strips(self, n: int | None, k: int | None) -> int:
        """Strip count for an adapted chunk-level code (n, k); N if n is None.

        The shared-key property makes the first n·m strips of the FULL (N, K)
        codeword exactly an (n, k) chunk-level codeword, so an adapted write
        is a strip-prefix — existing readers keep decoding at any level whose
        chunks fall inside the written prefix.
        """
        if n is None:
            return self.N
        if k is None:
            raise ValueError("adapted encode needs both n and k")
        n_max, _, m = self.code_for_k(k)
        if not k <= n <= n_max:
            raise ValueError(f"invalid chunk code ({n},{k}) for {self}")
        return n * m

    def encode_file(
        self,
        payload: bytes,
        codec: "codec_mod.Codec | None" = None,
        *,
        n: int | None = None,
        k: int | None = None,
    ) -> bytes:
        """Pad payload to K*b, strip-encode, return the N*b coded object.

        With an adapted chunk-level code (n, k) — the closed-loop write path
        — returns the n·m·b-byte strip prefix instead (see :meth:`_n_strips`).
        """
        codec = codec or codec_mod.get_codec()
        n_strips = self._n_strips(n, k)
        coded = codec.encode(self._strip_data(payload), self.N, self.K, n_out=n_strips)
        return np.asarray(coded).tobytes()

    def encode_files(
        self,
        payloads: Sequence[bytes],
        codec: "codec_mod.Codec | None" = None,
        *,
        n: int | None = None,
        k: int | None = None,
    ) -> list[bytes]:
        """Batch-encode many files of this class in one codec call.

        This is the proxy's admission-round amortization: one (batch, K, b)
        → (batch, N, b) kernel launch instead of per-object launches. The
        optional (n, k) is the adapted chunk-level code for queued writes
        (same prefix semantics as :meth:`encode_file`).
        """
        if not payloads:
            return []
        codec = codec or codec_mod.get_codec()
        n_strips = self._n_strips(n, k)
        data = np.stack([self._strip_data(p) for p in payloads])
        coded = np.asarray(codec.encode(data, self.N, self.K, n_out=n_strips))
        return [coded[i].tobytes() for i in range(len(payloads))]

    def gather_rows(self, k: int, chunks: dict[int, bytes]) -> tuple[np.ndarray, list[int]]:
        """(K, b) surviving strip rows + their strip ids from any >= k
        chunk-level fetches at level k.

        ``chunks`` maps chunk index (at level k) -> chunk bytes. Exactly the
        first k (by index order) are used; extras are ignored (they are the
        redundant tasks the proxy cancels late). Every chunk level yields the
        same (K, b) row block (k chunks cover k·m = K strips), which is what
        lets reads served at *different* levels share one batched decode.
        """
        _, _, m = self.code_for_k(k)
        if len(chunks) < k:
            raise ValueError(f"need >= {k} chunks, got {len(chunks)}")
        use = sorted(chunks)[:k]
        strip_ids: list[int] = []
        rows = np.empty((k * m, self.strip_bytes), dtype=np.uint8)
        for slot, ci in enumerate(use):
            blob = np.frombuffer(chunks[ci], dtype=np.uint8)
            if blob.size != m * self.strip_bytes:
                raise ValueError(f"chunk {ci}: got {blob.size}B, want {m * self.strip_bytes}B")
            rows[slot * m : (slot + 1) * m] = blob.reshape(m, self.strip_bytes)
            strip_ids.extend(range(ci * m, (ci + 1) * m))
        return rows, strip_ids

    def gather_rows_batch(
        self, items: Sequence[tuple[int, dict[int, bytes]]]
    ) -> tuple[np.ndarray, np.ndarray]:
        """Stack :meth:`gather_rows` over (k_level, chunks) pairs into the
        (batch, K, b) rows + (batch, K) present arrays one batched decode
        consumes — shared by :meth:`reconstruct_batch` and the fused serving
        step's raw-chunk assembly."""
        rows = np.empty((len(items), self.K, self.strip_bytes), dtype=np.uint8)
        present = np.empty((len(items), self.K), dtype=np.int64)
        for i, (k, chunks) in enumerate(items):
            rows[i], ids = self.gather_rows(k, chunks)
            present[i] = ids
        return rows, present

    def reconstruct(self, k: int, chunks: dict[int, bytes], payload_len: int | None = None,
                    codec: "codec_mod.Codec | None" = None) -> bytes:
        """Rebuild the file from any >= k chunk-level fetches at level k."""
        return self.reconstruct_batch([(k, chunks, payload_len)], codec=codec)[0]

    def reconstruct_batch(
        self,
        items: Sequence[tuple[int, dict[int, bytes], int | None]],
        codec: "codec_mod.Codec | None" = None,
    ) -> list[bytes]:
        """Rebuild many files of this class in ONE batched decode.

        ``items`` is a sequence of (k_level, chunks, payload_len) triples.
        All reads of one layout share the strip-level (N, K) code no matter
        which chunk level k served them, so the whole admission round — with
        heterogeneous chunk levels *and* heterogeneous erasure patterns —
        collapses into a single ``codec.decode`` call with per-item
        ``present`` masks (the proxy's read-side amortization, the mirror of
        :meth:`encode_files` on the write side).
        """
        if not items:
            return []
        rows, present = self.gather_rows_batch([(k, c) for k, c, _ in items])
        codec = codec or codec_mod.get_codec()
        data = np.asarray(codec.decode(rows, present, self.N, self.K))
        out: list[bytes] = []
        for i, (_, _, payload_len) in enumerate(items):
            blob = data[i].reshape(-1).tobytes()
            out.append(blob if payload_len is None else blob[:payload_len])
        return out


def layout_for_file(file_bytes: int, k_max: int, r_max: int) -> SharedKeyLayout:
    """Choose strip size so K = k_max strips cover the file (paper §V-A uses
    k_max = 6, r_max = 2 for 3MB files -> 0.5MB strips, (12, 6) strip code)."""
    strip = -(-file_bytes // k_max)  # ceil
    return SharedKeyLayout(K=k_max, r=r_max, strip_bytes=strip)
