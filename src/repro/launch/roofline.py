"""Roofline-term extraction from AOT-compiled artifacts.

Three terms per (arch × shape × mesh), in seconds (v5e constants):

    compute    = HLO_FLOPs / (chips × 197e12 FLOP/s bf16)
    memory     = HLO_bytes / (chips × 819e9 B/s HBM)
    collective = collective_bytes / (chips × 50e9 B/s ICI per link)

HLO_FLOPs / bytes come from ``compiled.cost_analysis()``. Collective bytes
are NOT in cost_analysis: we parse the optimized HLO text and sum operand
sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops.
"""

from __future__ import annotations

import dataclasses
import math
import re

PEAK_FLOPS = 197e12  # bf16 per chip (v5e)
HBM_BW = 819e9  # B/s per chip
ICI_BW = 50e9  # B/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4,
    "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of all array shapes in an HLO result-type string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*"  # result variable
    r"((?:\([^)]*\)|[\w\[\]\{\},:. ])+?)\s*"  # result type (may be a tuple)
    r"([a-z][a-z0-9\-]*)\("  # op name
)


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum result-shape bytes of every collective op in optimized HLO.

    Per-device semantics: in SPMD-partitioned HLO, op shapes are per-shard,
    so the sum approximates bytes moved through each device's links. Async
    pairs are counted once (the -start carries the buffers; -done skipped).

    Collectives are bucketed by where they live: ``region_*`` computations
    (while-loop bodies / control-flow regions — executed once per scanned
    layer/chunk, so they must be scaled by trip count) vs everything else
    (entry-level: FSDP epilogues, gradient all-reduce — executed once).
    """
    out: dict[str, int] = {c: 0 for c in _COLLECTIVES}
    out["count"] = 0
    out["in_loop"] = 0
    out["in_entry"] = 0
    current = "ENTRY"
    for line in hlo_text.splitlines():
        ls = line.strip()
        if ls.startswith("ENTRY"):
            current = "ENTRY"
            continue
        if ls.startswith("%") and ls.endswith("{") and "=" not in ls.split("(")[0]:
            current = ls.split(" ")[0]
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        base = op.removesuffix("-start")
        if op.endswith("-done"):
            continue
        if base in _COLLECTIVES:
            b = _shape_bytes(shape_str)
            if op.endswith("-start"):
                # start ops carry (input, output) tuples — halve.
                b //= 2
            out[base] += b
            out["count"] += 1
            if current.startswith("%region"):
                out["in_loop"] += b
            else:
                out["in_entry"] += b
    return out


@dataclasses.dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    coll_bytes: float
    chips: int
    coll_breakdown: dict

    @property
    def t_compute(self) -> float:
        return self.flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        # coll_bytes is per-shard already (SPMD HLO); one link assumed.
        return self.coll_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes,
            "chips": self.chips,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "coll_breakdown": {k: v for k, v in self.coll_breakdown.items() if v},
        }


def analyze(compiled, chips: int) -> Roofline:
    """Extract roofline terms from a jax compiled artifact."""
    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # older jax returns [dict]
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    hbm = float(ca.get("bytes accessed", 0.0))
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = ""
    coll = collective_bytes(hlo)
    cbytes = float(coll.get("in_loop", 0) + coll.get("in_entry", 0))
    return Roofline(
        flops=flops, hbm_bytes=hbm, coll_bytes=cbytes, chips=chips,
        coll_breakdown=coll,
    )


def model_flops(cfg, shape, kind: str) -> float:
    """MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D; decode: D = batch·1."""
    n_active = cfg.active_param_count()
    if kind == "train":
        tokens = shape.batch * shape.seq
        return 6.0 * n_active * tokens
    if kind == "prefill":
        tokens = shape.batch * shape.seq
        return 2.0 * n_active * tokens
    tokens = shape.batch * 1
    return 2.0 * n_active * tokens
