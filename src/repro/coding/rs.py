"""Systematic Cauchy Reed-Solomon MDS codes over GF(2^8).

An (n, k) code maps k data strips (rows of bytes) to n coded strips; the
first k coded strips equal the data (systematic), the remaining n - k are
parity rows produced by a Cauchy matrix, which guarantees the MDS property:
any k of the n strips reconstruct the data.

The paper (§II-B) uses one high-dimension (N = r*K, K) "strip" code that is
simultaneously an (N/m, K/m) code for chunk size B = m*b; that batching is
implemented in :mod:`repro.coding.layout` on top of this module.

Host-side encode/decode here is table-based numpy (the oracle). Bulk encode
on TPU goes through :mod:`repro.kernels.gf2mm` (bit-matrix MXU formulation).
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np

from repro.coding import gf256


@functools.cache
def cauchy_parity_matrix(n: int, k: int) -> np.ndarray:
    """(n - k, k) Cauchy matrix over GF(256).

    X_i = i (rows / parities), Y_j = (n - k) + j (cols / data); all distinct,
    entries 1 / (X_i + Y_j). Requires n <= 256 (field size bound for MDS).
    """
    if not (0 < k <= n):
        raise ValueError(f"need 0 < k <= n, got ({n=}, {k=})")
    if n > 256:
        raise ValueError("Cauchy RS over GF(256) supports n <= 256")
    rows = np.arange(n - k, dtype=np.uint8)[:, None]
    cols = (np.arange(k, dtype=np.uint8) + np.uint8(n - k))[None, :]
    return gf256.inv(gf256.add(rows, cols)) if n > k else np.zeros((0, k), np.uint8)


@functools.cache
def generator_matrix(n: int, k: int) -> np.ndarray:
    """(n, k) systematic generator: [I_k ; Cauchy]."""
    eye = np.eye(k, dtype=np.uint8)
    par = cauchy_parity_matrix(n, k)
    return np.concatenate([eye, par], axis=0)


def encode(data: np.ndarray, n: int, k: int) -> np.ndarray:
    """Encode (k, B) data strips -> (n, B) coded strips (systematic)."""
    data = np.asarray(data, dtype=np.uint8)
    if data.ndim != 2 or data.shape[0] != k:
        raise ValueError(f"data must be (k={k}, B), got {data.shape}")
    par = cauchy_parity_matrix(n, k)
    parity = gf256.matmul(par, data) if n > k else np.zeros((0, data.shape[1]), np.uint8)
    return np.concatenate([data, parity], axis=0)


@functools.cache
def decode_matrix(n: int, k: int, present: tuple[int, ...]) -> np.ndarray:
    """(k, k) matrix D s.t. D @ coded[present] == data, for any k present rows."""
    if len(present) != k:
        raise ValueError(f"need exactly k={k} present indices, got {len(present)}")
    if len(set(present)) != k or max(present) >= n or min(present) < 0:
        raise ValueError(f"invalid present set {present} for (n={n}, k={k})")
    gen = generator_matrix(n, k)
    sub = gen[list(present)]  # (k, k)
    return gf256.mat_inv(sub)


def decode(coded_rows: np.ndarray, present: tuple[int, ...], n: int, k: int) -> np.ndarray:
    """Reconstruct (k, B) data from any k coded strips.

    ``coded_rows`` is (k, B): the surviving strips, in the order given by
    ``present`` (sorted or not — order must match).
    """
    coded_rows = np.asarray(coded_rows, dtype=np.uint8)
    dec = decode_matrix(n, k, tuple(int(i) for i in present))
    return gf256.matmul(dec, coded_rows)


@dataclasses.dataclass(frozen=True)
class MDSCode:
    """Convenience bundle for an (n, k) systematic Cauchy RS code."""

    n: int
    k: int

    def __post_init__(self):
        generator_matrix(self.n, self.k)  # validates and caches

    @property
    def r(self) -> float:
        """Redundancy ratio n / k (paper's r)."""
        return self.n / self.k

    def encode(self, data: np.ndarray) -> np.ndarray:
        return encode(data, self.n, self.k)

    def decode(self, coded_rows: np.ndarray, present) -> np.ndarray:
        return decode(coded_rows, tuple(int(i) for i in present), self.n, self.k)

    def generator(self) -> np.ndarray:
        return generator_matrix(self.n, self.k)

    def parity(self) -> np.ndarray:
        return cauchy_parity_matrix(self.n, self.k)
