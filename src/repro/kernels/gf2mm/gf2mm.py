"""Pallas TPU kernels: GF(2) matrix multiply (bit-matrix Reed-Solomon encode).

TPU adaptation of the paper's MDS encode/decode hot loop (DESIGN.md §3):
GF(256) arithmetic is lifted to GF(2) by expanding each field constant into
its 8x8 binary multiplication matrix. Encoding k data strips of B bytes with
an (n, k) generator then becomes

    C2[8(n-k), B] = ( G2[8(n-k), 8k] @ D2[8k, B] ) mod 2

where G2 is the expanded parity matrix and D2 the LSB-first bit-planes of
the data. A 0/1 matmul with int accumulation is exactly MXU-shaped; the
mod-2 runs in the epilogue on the VPU.

Two kernels are provided:

* :func:`gf2_matmul` — the classic three-level tiled 0/1 matmul
  (grid = (M/bm, N/bn, K/bk), fp32 VMEM scratch accumulator, bf16 MXU
  operands); callers pack/unpack bit-planes themselves.
* :func:`gf2_rs_matmul_bytes` — the batched, fused codec path: raw uint8
  byte strips in, raw uint8 byte strips out. The bitplane unpack of the
  data tile, the GF(2) matmul against a per-item bit-matrix, and the
  bitplane repack of the result all happen inside one kernel invocation
  (grid = (batch, M/bm, B/bn)), so a batch of codewords is one launch and
  ``bytes_to_bitplanes`` stops being a separate pass over HBM.

Compat: the pinned JAX names the TPU compiler-params dataclass
``TPUCompilerParams``; newer releases renamed it ``CompilerParams``.
:func:`tpu_compiler_params` resolves whichever exists.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def tpu_compiler_params(**kwargs):
    """Build TPU compiler params across the CompilerParams rename.

    JAX < 0.5 exposes ``pltpu.TPUCompilerParams``; newer versions renamed it
    to ``pltpu.CompilerParams``. Returns None when neither exists (e.g. a
    CPU-only build stripped of the TPU backend) so callers can omit the
    argument entirely.
    """
    cls = getattr(pltpu, "CompilerParams", None) or getattr(pltpu, "TPUCompilerParams", None)
    return cls(**kwargs) if cls is not None else None


def _pallas_call_kwargs(**kwargs):
    """Drop compiler_params when the compat shim found no class."""
    if kwargs.get("compiler_params") is None:
        kwargs.pop("compiler_params", None)
    return kwargs


def _gf2mm_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k_tiles: int):
    """One (bm, bn) output tile; accumulates over the K grid dimension."""

    @pl.when(pl.program_id(2) == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.bfloat16)
    b = b_ref[...].astype(jnp.bfloat16)
    acc_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k_tiles - 1)
    def _epilogue():
        # mod-2 of an exact small-integer float: cast and mask the LSB.
        o_ref[...] = (acc_ref[...].astype(jnp.int32) & 1).astype(o_ref.dtype)


def gf2_matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 512,
    block_k: int = 128,
    out_dtype=jnp.uint8,
    interpret: bool = False,
) -> jax.Array:
    """(A @ B) mod 2 for 0/1 matrices. A: (M, K), B: (K, N) -> (M, N).

    Inputs may be any integer/float dtype holding 0/1 values. Dimensions are
    padded to tile multiples internally (zero rows/cols contribute nothing).
    """
    if a.ndim != 2 or b.ndim != 2 or a.shape[1] != b.shape[0]:
        raise ValueError(f"bad shapes {a.shape} @ {b.shape}")
    M, K = a.shape
    _, N = b.shape
    bm, bn, bk = block_m, block_n, block_k

    Mp, Kp, Np = (-(-M // bm) * bm, -(-K // bk) * bk, -(-N // bn) * bn)
    a_p = jnp.zeros((Mp, Kp), jnp.bfloat16).at[:M, :K].set(a.astype(jnp.bfloat16))
    b_p = jnp.zeros((Kp, Np), jnp.bfloat16).at[:K, :N].set(b.astype(jnp.bfloat16))

    n_k_tiles = Kp // bk
    grid = (Mp // bm, Np // bn, n_k_tiles)

    out = pl.pallas_call(
        functools.partial(_gf2mm_kernel, n_k_tiles=n_k_tiles),
        **_pallas_call_kwargs(
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, bk), lambda i, j, t: (i, t)),
                pl.BlockSpec((bk, bn), lambda i, j, t: (t, j)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j, t: (i, j)),
            out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
            compiler_params=tpu_compiler_params(
                dimension_semantics=("parallel", "parallel", "arbitrary"),
            ),
            interpret=interpret,
        ),
    )(a_p, b_p)
    return out[:M, :N]


def _rs_bytes_kernel(a_ref, d_ref, o_ref, *, k: int):
    """Fused tile: unpack byte strips → GF(2) matmul → repack bytes.

    a_ref: (1, bm, 8k) 0/1 bit-matrix rows for this batch item.
    d_ref: (1, k, bn) raw data bytes (the whole contraction dim at once —
           k ≤ 256 so 8k ≤ 2048 columns fit comfortably in VMEM).
    o_ref: (1, bm // 8, bn) raw output bytes.
    """
    a = a_ref[0].astype(jnp.bfloat16)  # (bm, 8k)
    d = d_ref[0]  # (k, bn) uint8
    bm = a.shape[0]
    bn = d.shape[1]

    # Unpack LSB-first bitplanes in-register: row 8i+b of planes is bit b of
    # data row i, matching gf256.bytes_to_bitplanes.
    shifts = jax.lax.broadcasted_iota(jnp.int32, (k, 8, bn), dimension=1)
    planes = (d[:, None, :].astype(jnp.int32) >> shifts) & 1
    planes = planes.reshape(8 * k, bn).astype(jnp.bfloat16)

    # 0/1 matmul, exact in bf16 operands / fp32 accumulation (sums ≤ 2048).
    acc = jnp.dot(a, planes, preferred_element_type=jnp.float32)
    bits = acc.astype(jnp.int32) & 1  # (bm, bn) mod-2 epilogue

    # Repack: output byte row i collects plane rows 8i..8i+7.
    oshift = jax.lax.broadcasted_iota(jnp.int32, (bm // 8, 8, bn), dimension=1)
    packed = jnp.sum(bits.reshape(bm // 8, 8, bn) << oshift, axis=1)
    o_ref[0] = packed.astype(o_ref.dtype)


def gf2_rs_matmul_bytes(
    bitmats: jax.Array,
    data: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Batched fused RS matmul on raw bytes.

    bitmats: (batch, 8m, 8k) 0/1 — per-item GF(2)-expanded coding matrices
             (parity rows for encode, inverted generator rows for decode).
    data:    (batch, k, B) uint8 — raw byte strips.
    Returns  (batch, m, B) uint8: the GF(256) product rows, bytes in / bytes
    out, pack/unpack fused into the kernel (no separate bitplane pass).

    batch, m and B should be pre-bucketed by the caller (repro.coding.codec)
    so heterogeneous (n, k) streams reuse a small set of compilations.
    """
    if bitmats.ndim != 3 or data.ndim != 3:
        raise ValueError(f"bad ranks {bitmats.shape} / {data.shape}")
    batch, M, K8 = bitmats.shape
    _, k, B = data.shape
    if K8 != 8 * k or M % 8 or data.shape[0] != batch:
        raise ValueError(f"inconsistent shapes {bitmats.shape} / {data.shape}")

    bm = min(block_m, M)
    bn = min(block_n, B)
    Mp = -(-M // bm) * bm
    Bp = -(-B // bn) * bn
    if Mp != M:
        bitmats = jnp.concatenate(
            [bitmats, jnp.zeros((batch, Mp - M, K8), bitmats.dtype)], axis=1
        )
    if Bp != B:
        data = jnp.concatenate([data, jnp.zeros((batch, k, Bp - B), data.dtype)], axis=2)

    grid = (batch, Mp // bm, Bp // bn)
    out = pl.pallas_call(
        functools.partial(_rs_bytes_kernel, k=k),
        **_pallas_call_kwargs(
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, bm, K8), lambda b, i, j: (b, i, 0)),
                pl.BlockSpec((1, k, bn), lambda b, i, j: (b, 0, j)),
            ],
            out_specs=pl.BlockSpec((1, bm // 8, bn), lambda b, i, j: (b, i, j)),
            out_shape=jax.ShapeDtypeStruct((batch, Mp // 8, Bp), jnp.uint8),
            compiler_params=tpu_compiler_params(
                dimension_semantics=("parallel", "parallel", "parallel"),
            ),
            interpret=interpret,
        ),
    )(bitmats, data)
    return out[:, : M // 8, :B]
