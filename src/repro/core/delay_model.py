"""Task-delay model of the paper (§III-C, Eq.1) and parameter fitting (§V-A).

    D_t(B) ~ Δ(B) + Exp(mean = 1/μ(B)),   Δ(B) = Δ̄ + Δ̃·B,   1/μ(B) = Ψ̄ + Ψ̃·B

Units: seconds and MB throughout.

The default constants are calibrated (DESIGN.md §2) so that the paper's
headline numbers come out of the simulator for the (read, 3 MB) class with
L = 16 threads: basic (1,1) mean ≈ 205 ms, simple replication (2,1) ≈ 151 ms,
best code at light load ≈ 80-90 ms, capacity of the delay-optimal high-chunk
codes ≈ 30-40 % of basic — matching Fig.1/Fig.7 within the fidelity that a
synthetic trace permits.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DelayParams:
    """{Δ̄, Δ̃, Ψ̄, Ψ̃} for one request type (read or write)."""

    delta_bar: float  # Δ̄  [s]      fixed per-task overhead floor
    delta_tilde: float  # Δ̃  [s/MB]  floor growth per MB
    psi_bar: float  # Ψ̄  [s]      exponential-tail mean at B=0
    psi_tilde: float  # Ψ̃  [s/MB]  tail-mean growth per MB

    def delta(self, B: float) -> float:
        """Deterministic lower bound Δ(B) of task delay (observation 3)."""
        return self.delta_bar + self.delta_tilde * B

    def tail_mean(self, B: float) -> float:
        """Mean (= std) 1/μ(B) of the exponential tail (observation 4)."""
        return self.psi_bar + self.psi_tilde * B

    def task_mean(self, B: float) -> float:
        return self.delta(B) + self.tail_mean(B)

    def task_std(self, B: float) -> float:
        return self.tail_mean(B)

    def sample(self, rng: np.random.Generator, B: float, size=None) -> np.ndarray:
        """Draw task delays for chunk size B."""
        return self.delta(B) + rng.exponential(self.tail_mean(B), size=size)


# Calibrated to land the paper's Fig.1/Fig.7 numbers for (read, 3MB), L=16.
PAPER_READ_3MB = DelayParams(
    delta_bar=0.050, delta_tilde=0.018, psi_bar=0.015, psi_tilde=0.030
)
# Writes on S3 are slower per byte (paper measured both; constants scaled).
PAPER_WRITE_3MB = DelayParams(
    delta_bar=0.060, delta_tilde=0.024, psi_bar=0.020, psi_tilde=0.040
)


def fit_delay_params(
    chunk_sizes_mb: np.ndarray,
    delays_s: list[np.ndarray],
    *,
    drop_worst_frac: float = 0.10,
) -> DelayParams:
    """Fit {Δ̄, Δ̃, Ψ̄, Ψ̃} from per-chunk-size task-delay samples (§V-A).

    Paper procedure: filter out the worst ``drop_worst_frac`` of task delays
    per setting, then least-squares lines through (B, mean) and (B, std).
    Δ is recovered from mean − std (shifted exponential: mean = Δ + 1/μ,
    std = 1/μ).
    """
    chunk_sizes_mb = np.asarray(chunk_sizes_mb, dtype=np.float64)
    means, stds = [], []
    for d in delays_s:
        d = np.sort(np.asarray(d, dtype=np.float64))
        keep = d[: max(1, int(round(len(d) * (1.0 - drop_worst_frac))))]
        means.append(keep.mean())
        stds.append(keep.std())
    means = np.asarray(means)
    stds = np.asarray(stds)

    def lsq_line(x, y):
        A = np.stack([x, np.ones_like(x)], axis=1)
        slope, intercept = np.linalg.lstsq(A, y, rcond=None)[0]
        return float(slope), float(intercept)

    psi_tilde, psi_bar = lsq_line(chunk_sizes_mb, stds)
    mean_slope, mean_intercept = lsq_line(chunk_sizes_mb, means)
    # mean = Δ̄ + Ψ̄ + (Δ̃ + Ψ̃)·B  →  subtract the tail line.
    delta_tilde = mean_slope - psi_tilde
    delta_bar = mean_intercept - psi_bar
    return DelayParams(
        delta_bar=max(delta_bar, 0.0),
        delta_tilde=max(delta_tilde, 0.0),
        psi_bar=max(psi_bar, 1e-6),
        psi_tilde=max(psi_tilde, 0.0),
    )


@dataclasses.dataclass(frozen=True)
class RequestClass:
    """(type, size) request class (§IV): file size + its delay parameters."""

    name: str
    file_mb: float
    params: DelayParams
    k_max: int = 6
    r_max: float = 2.0
    n_max: int = 12

    def chunk_mb(self, k: float) -> float:
        return self.file_mb / k
