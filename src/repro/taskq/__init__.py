"""repro.taskq — on-device trace-driven task-level queue engine.

The fleet (:mod:`repro.fleet`) and scheduler (:mod:`repro.sched`) sweeps
run the paper's *fluid* §IV-A approximation — fast, but per-request delay
is modeled, not simulated. This package runs the **exact** §II-A task-level
system on device: per-request delay is the k-th order statistic of n
correlated chunk-task delays racing over a shared L-thread pool with
preemptive cancellation of stragglers, exactly as the discrete-event oracle
computes it — and matching that oracle draw for draw when both consume the
same pre-sampled trace pools.

* :mod:`repro.taskq.engine` — ``taskq_scan_core``: the exact per-request
  recurrence (FIFO assignment with own-completion feedback, k-of-n
  completion, cancellation replay) as one ``lax.scan`` over arrivals.
* :mod:`repro.taskq.policies` — policies as runtime data: threshold tables
  (TOFEC / static / fixed-k, shared with the fleet) plus the traceable
  §V-A ``greedy_select``, which needs the idle-thread count only the exact
  engine observes.
* :mod:`repro.taskq.sweep` — ``TaskqSweep``: (λ × policy × seed) grids
  vmapped with the fleet's bucketed jit cache and chunked launches, trace
  pools broadcast grid-wide; ``BENCH_taskq.json`` artifact writer;
  ``replay_flight`` re-runs one grid point with the per-request flight
  recorder on (``flight=True``) and returns the
  :class:`repro.obs.flight.FlightLog` — aggregate engines stream, flight
  replays one case.

Use ``taskq`` when per-request exactness matters (tail percentiles under
cancellation, Greedy/idle-aware policies, trace replay); use ``fleet``/
``sched`` for cheap fluid scans over very large grids.
"""

from repro.taskq.engine import taskq_scan, taskq_scan_core
from repro.taskq.policies import (
    POL_GREEDY,
    POL_TABLE,
    EncodedPolicy,
    encode_policy,
    greedy_select,
)
from repro.taskq.sweep import (
    TaskqResult,
    TaskqSweep,
    taskq_streams,
    write_taskq_artifact,
)

__all__ = [
    "taskq_scan",
    "taskq_scan_core",
    "POL_TABLE",
    "POL_GREEDY",
    "EncodedPolicy",
    "encode_policy",
    "greedy_select",
    "TaskqSweep",
    "TaskqResult",
    "taskq_streams",
    "write_taskq_artifact",
]
