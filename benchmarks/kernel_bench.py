"""Kernel micro-benchmarks: GF(2) bit-matrix RS encode (Pallas, interpret)
vs the table-based GF(256) jnp oracle, plus the unified codec engine's
batched-throughput sweep (backend × batch × (n, k)).

On CPU the Pallas kernel runs in interpret mode, so wall-clock here measures
the *reference environment*, not TPU perf — the TPU story is the §Roofline
arithmetic-intensity argument (bit-matrix matmul is MXU-shaped; table
lookups are not). We report both wall time and derived arithmetic intensity.

The codec sweep is the measurement behind the TOFEC amortization claim
(coding overhead Ψ caps throughput under load, FAST CLOUD §IV): one batched
``Codec.encode`` over b queued objects vs b per-object calls. Rows report
MB/s for each and the batched/looped speedup.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BenchTimer
from repro.coding import rs
from repro.coding.codec import Codec
from repro.kernels.gf2mm import gf2mm, ops, ref


def bench_gf2mm(n: int = 12, k: int = 6, B: int = 16384) -> list[str]:
    rng = np.random.default_rng(0)
    data = rng.integers(0, 256, size=(k, B), dtype=np.uint8)
    jdata = jnp.asarray(data)

    # jit the wrapper so both timed paths measure pure device dispatch
    enc = jax.jit(lambda d: ops.rs_encode(d, n=n, k=k, interpret=True))
    enc(jdata).block_until_ready()
    with BenchTimer("kernel_rs_encode_pallas", calls=3) as t1:
        for _ in range(3):
            enc(jdata).block_until_ready()

    par = jnp.asarray(rs.cauchy_parity_matrix(n, k))
    ref_fn = jax.jit(lambda d: ref.gf256_matmul_ref(par, d))
    ref_fn(jdata).block_until_ready()
    with BenchTimer("kernel_rs_encode_tableref", calls=3) as t2:
        for _ in range(3):
            ref_fn(jdata).block_until_ready()

    # Derived: GF(2) matmul arithmetic intensity on TPU for this shape.
    M, K = 8 * (n - k), 8 * k
    flops = 2 * M * K * B  # MXU MACs on bit-planes
    bytes_ = (M * K + K * B + M * B)  # bf16→1B-ish planes; order of magnitude
    return [
        t1.row(f"payload={k * B / 2 ** 20:.1f}MB"),
        t2.row(f"bitmm_arith_intensity={flops / bytes_:.1f}flop/B"),
    ]


def bench_codec_sweep(B: int = 4096) -> list[str]:
    """Backend × batch × (n, k): batched encode vs the per-object loop.

    The acceptance bar for the unified engine: batched throughput ≥ the
    per-object loop at batch ≥ 8 on the jnp or pallas-interpret backend
    (per-launch/trace overhead amortized across the admission round).
    """
    rng = np.random.default_rng(7)
    rows: list[str] = []
    for backend in ("numpy", "jnp", "pallas"):
        codec = Codec(backend)
        for n, k in ((8, 4), (12, 6)):
            for batch in (1, 8, 32):
                data = rng.integers(0, 256, size=(batch, k, B), dtype=np.uint8)
                # warm both paths (jit compile outside the timed region)
                codec.encode(data, n, k)
                codec.encode(data[0], n, k)
                mb = batch * k * B / 2**20

                t0 = time.monotonic()
                codec.encode(data, n, k)
                dt_batched = time.monotonic() - t0

                t0 = time.monotonic()
                for i in range(batch):
                    codec.encode(data[i], n, k)
                dt_looped = time.monotonic() - t0

                speedup = dt_looped / max(dt_batched, 1e-9)
                timer = BenchTimer(f"codec_encode_{backend}_n{n}k{k}_b{batch}", calls=1)
                timer.elapsed = dt_batched
                rows.append(
                    timer.row(
                        f"batched={mb / dt_batched:.1f}MB/s"
                        f"|looped={mb / dt_looped:.1f}MB/s"
                        f"|speedup={speedup:.2f}x"
                    )
                )
    return rows


def bench_ckpt_encode(leaf_mb: int = 1) -> list[str]:
    rng = np.random.default_rng(1)
    payload = rng.integers(0, 256, size=leaf_mb * 2**20, dtype=np.uint8)
    with BenchTimer("ckpt_encode_blob", calls=1) as t:
        strips = ops.encode_blob(payload, n=8, k=4)
    present = (1, 3, 5, 7)
    with BenchTimer("ckpt_decode_blob", calls=1) as t2:
        out = ops.decode_blob(strips[list(present)], present, n=8, k=4,
                              payload_len=payload.size)
    assert np.array_equal(out, payload)
    mbps = leaf_mb / t.elapsed
    return [t.row(f"encode_{leaf_mb}MB@{mbps:.1f}MB/s"), t2.row("decode_ok")]


ALL_KERNEL = [bench_gf2mm, bench_codec_sweep, bench_ckpt_encode]
