"""Sharded, streaming fleet sweep: a Fig.7 frontier on 8 virtual devices.

The same (λ × policy × seed) grid as ``fleet_sweep_demo``, but the sweep
runs the scale-out path from :mod:`repro.fleet.shard`: the grid axis is
partitioned across an 8-device host mesh with ``shard_map`` (forced below
via ``--xla_force_host_platform_device_count`` — on real multi-chip
hardware, drop the flag and the mesh picks up the physical devices), and
each chunk folds into running frontier statistics on device instead of
materializing the (G, T) delay block. The frontier that comes out is a
bit-exact equal of the single-device materialized one — asserted here.

Run:  PYTHONPATH=src python examples/shard_sweep_demo.py [--fast]
"""

import argparse
import json
import os
import time

# Must be set before jax initializes its backend; harmless if the caller
# already exported their own XLA_FLAGS.
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import PAPER_READ_3MB, RequestClass  # noqa: E402
from repro.core import queueing  # noqa: E402
from repro.fleet import (  # noqa: E402
    FleetSweep,
    PolicySpec,
    frontier,
    frontier_points,
    grid_cases,
)

from fleet_sweep_demo import ascii_frontier  # noqa: E402

CLS = RequestClass("read3mb", 3.0, PAPER_READ_3MB, k_max=6, r_max=2.0, n_max=12)
L = 16


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="smaller grid/horizon")
    args = ap.parse_args()

    n_dev = len(jax.devices())
    print(f"devices: {n_dev} ({jax.devices()[0].platform}), "
          f"host cores: {os.cpu_count()}")

    cap = queueing.capacity(PAPER_READ_3MB, CLS.file_mb, 1, 1.0, L)
    n_rates = 8 if args.fast else 24
    count = 1024 if args.fast else 2048
    rates = np.linspace(0.08 * cap, 0.92 * cap, n_rates)
    policies = [
        PolicySpec.tofec(),
        PolicySpec.static(1, 1),   # throughput-optimal basic
        PolicySpec.static(12, 6),  # latency-optimal high-chunk code
        PolicySpec.fixedk(6),
    ]
    cases = grid_cases(rates, policies, range(4), CLS, L)
    print(f"grid: {len(cases)} points, {count} arrivals each")

    # Sharded + streamed: grid axis split across the mesh, per-chunk fold.
    sweep = FleetSweep(chunk=64, mesh=n_dev)
    sweep.run(cases[:64], count, stream=True)  # warm the shape bucket
    t0 = time.monotonic()
    res = sweep.run(cases, count, stream=True)
    dt = time.monotonic() - t0
    print(f"sharded+streamed sweep: {dt:.2f}s on {n_dev} devices "
          f"({res.launches} launches, {res.compiles} compiles); "
          f"no (G, T) block materialized: out={res.out}")

    pts = frontier_points(res)

    # The whole point of the exact streaming fold: same numbers, bitwise.
    ref = FleetSweep(chunk=64).run(cases, count)
    ref_pts = frontier_points(ref)
    assert json.dumps([p.to_dict() for p in pts]) == \
        json.dumps([p.to_dict() for p in ref_pts])
    print("bit-exact vs single-device materialized sweep: OK\n")

    print("=== Fig.7 frontier, sharded+streamed ===")
    print(ascii_frontier(frontier(pts)))


if __name__ == "__main__":
    main()
