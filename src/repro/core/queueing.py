"""Queueing approximations of the paper (§IV-A, Eq.2-5).

All functions take the class delay parameters {Δ̄, Δ̃, Ψ̄, Ψ̃}, file size J
[MB], code (k, r) with n = k·r, and the thread count L.
"""

from __future__ import annotations

import math

import numpy as np

from repro.core.delay_model import DelayParams


def service_delay_exact(p: DelayParams, J: float, k: float, n: float) -> float:
    """Eq.2 first line: Δ(J/k) + (1/μ)(Σ_{j=0}^{k-1} 1/(n-j)), integer k, n."""
    B = J / k
    hsum = sum(1.0 / (n - j) for j in range(int(round(k))))
    return p.delta(B) + p.tail_mean(B) * hsum


def service_delay(p: DelayParams, J: float, k: float, r: float) -> float:
    """Eq.2 (log approximation, continuous k, r):

    D_s = Δ̄ + Δ̃J/k + (Ψ̄ + Ψ̃J/k)·ln(r / (r-1)).
    """
    B = J / k
    if r <= 1.0:
        # r = 1 means no redundancy: k-of-k join; ln(r/(r-1)) → ∞ in the
        # approximation. Use the exact harmonic form with n = k.
        return service_delay_exact(p, J, k, max(k, 1.0))
    return p.delta(B) + p.tail_mean(B) * math.log(r / (r - 1.0))


def usage(p: DelayParams, J: float, k: float, r: float) -> float:
    """Eq.3 expected system usage (thread-seconds per request):

    U = Δ̄·k·r + Δ̃·J·r + Ψ̄·k + Ψ̃·J.
    """
    return p.delta_bar * k * r + p.delta_tilde * J * r + p.psi_bar * k + p.psi_tilde * J


def queueing_delay(lam: float, U_bar: float, L: int) -> float:
    """Eq.4 M/M/1 approximation with service rate L/Ū:

    D_q = λŪ² / (L(L − λŪ)).  Infinite if λŪ ≥ L.
    """
    lam_bar = lam * U_bar
    if lam_bar >= L:
        return math.inf
    return lam_bar * U_bar / (L * (L - lam_bar))


def queue_length(lam: float, U_bar: float, L: int) -> float:
    """Eq.5: Q = λ̄² / (L(L − λ̄)) with λ̄ = λŪ."""
    lam_bar = lam * U_bar
    if lam_bar >= L:
        return math.inf
    return lam_bar**2 / (L * (L - lam_bar))


def lambda_bar_from_queue(Q: float, L: int) -> float:
    """Invert Eq.5: λ̄ = L(√(Q² + 4Q) − Q)/2 (paper, below Corollary 1)."""
    if math.isinf(Q):
        return float(L)
    return L * (math.sqrt(Q * Q + 4.0 * Q) - Q) / 2.0


def capacity(p: DelayParams, J: float, k: float, r: float, L: int) -> float:
    """Max sustainable arrival rate λ for a single class: L / U(k, r)."""
    return L / usage(p, J, k, r)


def total_delay(p: DelayParams, J: float, k: float, r: float, L: int, lam: float) -> float:
    """D_q + D_s for a single-class static (n=rk, k) strategy at rate λ."""
    U = usage(p, J, k, r)
    return queueing_delay(lam, U, L) + service_delay(p, J, k, r)
