"""Production mesh builders.

Single-pod: (16, 16) → ("data", "model") — 256 chips (one v5e pod).
Multi-pod:  (2, 16, 16) → ("pod", "data", "model") — 512 chips.

Functions, not module constants: importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before any jax import)."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist, as a 1-D 'data' mesh (tests/examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))
