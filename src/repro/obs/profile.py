"""Per-compiled-function launch profiling: cost model vs measured wallclock.

:func:`profile_launch` AOT-compiles one jitted callable at one arg shape,
reads XLA's ``cost_analysis`` (FLOPs, bytes accessed), measures post-warmup
wallclock (best of ``iters`` blocked calls), and derives the roofline view:
achieved GFLOP/s and GB/s, arithmetic intensity, the compute-vs-memory
bound side, and the fraction of the configured peak achieved.  Peaks
default to the v5e constants of :mod:`repro.launch.roofline` — override
per call for other hosts; on CPU the fractions are indicative only, the
measured wallclock and the FLOPs/bytes are the portable numbers.

Each profile registers a labeled :class:`repro.obs.compile.CompileStats`
(held strongly here, so the weak registry keeps it), which makes profiled
functions first-class citizens of :func:`repro.obs.compile_snapshot` —
one query answers both "what compiled" and "how fast did it run".
:func:`profile_snapshot` returns the measured records merged with those
counts, and :func:`format_profile` renders the terminal table the demo and
the dashboard embed.
"""
from __future__ import annotations

import time

from repro.obs import trace as _trace
from repro.obs.compile import CompileStats

#: Strong refs so the weak compile registry keeps profiled labels alive.
_PROFILES: dict[str, dict] = {}
_STATS: dict[str, CompileStats] = {}


def _cost_dict(ca) -> dict:
    """Normalize ``compiled.cost_analysis()`` across jax versions
    (dict | [dict] | None)."""
    if isinstance(ca, list):
        ca = ca[0] if ca else None
    return dict(ca) if ca else {}


def profile_launch(label: str, fn, *args, warmup: int = 1, iters: int = 3,
                   peak_flops: float | None = None,
                   peak_bw: float | None = None, **kwargs) -> dict:
    """Profile one jitted callable at one argument shape; returns the record.

    ``fn`` must be a ``jax.jit`` product (anything with ``.lower``).  The
    compile happens here (AOT), then ``warmup`` discarded calls, then the
    best of ``iters`` blocked calls is the wallclock."""
    import jax

    from repro.launch.roofline import HBM_BW, PEAK_FLOPS

    peak_flops = PEAK_FLOPS if peak_flops is None else float(peak_flops)
    peak_bw = HBM_BW if peak_bw is None else float(peak_bw)

    with _trace.get_tracer().span("obs.profile_compile", label=label):
        compiled = fn.lower(*args, **kwargs).compile()
    ca = _cost_dict(compiled.cost_analysis())
    flops = float(ca.get("flops", 0.0))
    nbytes = float(ca.get("bytes accessed", 0.0))

    for _ in range(warmup):
        jax.block_until_ready(compiled(*args, **kwargs))
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(compiled(*args, **kwargs))
        best = min(best, time.perf_counter() - t0)

    t_compute = flops / peak_flops
    t_memory = nbytes / peak_bw
    rec = {
        "label": label,
        "flops": flops,
        "bytes": nbytes,
        "wall_s": best,
        "gflops": flops / best / 1e9 if best > 0 else 0.0,
        "gbps": nbytes / best / 1e9 if best > 0 else 0.0,
        "intensity": flops / nbytes if nbytes else 0.0,
        "bound": "compute" if t_compute >= t_memory else "memory",
        # Efficiency vs the binding roofline term at the configured peaks.
        "frac_peak": (max(t_compute, t_memory) / best) if best > 0 else 0.0,
    }
    _PROFILES[label] = rec
    stats = _STATS.get(label)
    if stats is None:
        stats = _STATS[label] = CompileStats(label=f"profile.{label}")
    stats.traces += 1
    stats.launches += warmup + iters
    return rec


def profile_snapshot() -> dict:
    """label -> measured record + the registry's compile counts."""
    out = {}
    for label, rec in _PROFILES.items():
        stats = _STATS.get(label)
        out[label] = dict(rec)
        if stats is not None:
            out[label]["traces"] = stats.traces
            out[label]["launches"] = stats.launches
    return out


def reset_profiles() -> None:
    _PROFILES.clear()
    _STATS.clear()


def _fmt_qty(v: float) -> str:
    for unit, div in (("G", 1e9), ("M", 1e6), ("K", 1e3)):
        if v >= div:
            return f"{v / div:.2f}{unit}"
    return f"{v:.0f}"


def format_profile(snap: dict | None = None) -> str:
    """ASCII roofline/efficiency table over :func:`profile_snapshot`."""
    snap = profile_snapshot() if snap is None else snap
    rows = [("fn", "flops", "bytes", "wall_ms", "gflop/s", "gb/s",
             "bound", "peak%", "launches")]
    for label, r in sorted(snap.items()):
        rows.append((
            label, _fmt_qty(r["flops"]), _fmt_qty(r["bytes"]),
            f"{r['wall_s'] * 1e3:.3f}", f"{r['gflops']:.2f}",
            f"{r['gbps']:.2f}", r["bound"], f"{r['frac_peak'] * 100:.2f}",
            str(r.get("launches", "")),
        ))
    widths = [max(len(row[i]) for row in rows) for i in range(len(rows[0]))]
    return "\n".join(
        "  ".join(c.ljust(w) for c, w in zip(row, widths)).rstrip()
        for row in rows
    )
