"""Zamba2-style hybrid: a scanned Mamba2 backbone with one SHARED
attention+MLP block (single weight copy) applied every ``attn_every``
backbone layers.

The shared block's weights are closure constants of the layer scan; each
application site keeps its own KV cache (weights are shared, activations are
not). The shared attention uses a sliding window (`local_window`) so the
512k-context decode cell runs with O(window) memory — a documented
adaptation (real Zamba2 uses full attention; the window is what makes
long_500k admissible, see DESIGN.md §4)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import layers as ly
from repro.models import ssm
from repro.models.config import ModelConfig
from repro.models.lm import _remat_policy, chunked_ce_loss
from repro.models.sharding import constrain


def _attn_flags(cfg: ModelConfig) -> tuple[jnp.ndarray, jnp.ndarray, int]:
    """(apply_attn flag per layer, attn slot index per layer, n_sites)."""
    flags, slots = [], []
    site = 0
    for i in range(cfg.n_layers):
        hit = cfg.attn_every > 0 and (i + 1) % cfg.attn_every == 0
        flags.append(hit)
        slots.append(site if hit else 0)
        if hit:
            site += 1
    return (
        jnp.asarray(flags, jnp.int32),
        jnp.asarray(slots, jnp.int32),
        site,
    )


def init(rng, cfg: ModelConfig):
    k_emb, k_layers, k_attn, k_mlp = jax.random.split(rng, 4)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)

    def init_backbone_layer(k):
        return {
            "ln": ly.init_rmsnorm(cfg.d_model, ly.dt(cfg)),
            "mamba": ssm.init_mamba2(k, cfg),
        }

    return {
        "embedding": ly.init_embedding(k_emb, cfg),
        "layers": jax.vmap(init_backbone_layer)(layer_keys),
        "shared": {
            "ln1": ly.init_rmsnorm(cfg.d_model, ly.dt(cfg)),
            "attn": ly.init_attention(k_attn, cfg),
            "ln2": ly.init_rmsnorm(cfg.d_model, ly.dt(cfg)),
            "mlp": ly.init_mlp(k_mlp, cfg),
        },
        "ln_f": ly.init_rmsnorm(cfg.d_model, ly.dt(cfg)),
    }


def logical_axes(cfg: ModelConfig):
    norm = {"scale": (None,)}
    backbone = {
        "ln": {"scale": (None, None)},
        "mamba": jax.tree.map(
            lambda axes: (None, *axes), ssm.mamba2_logical_axes(cfg),
            is_leaf=lambda x: isinstance(x, tuple),
        ),
    }
    return {
        "embedding": ly.embedding_logical_axes(cfg),
        "layers": backbone,
        "shared": {
            "ln1": norm,
            "attn": ly.attention_logical_axes(cfg),
            "ln2": norm,
            "mlp": ly.mlp_logical_axes(cfg),
        },
        "ln_f": norm,
    }


def _shared_block(shared, cfg: ModelConfig, x):
    h = ly.rmsnorm(shared["ln1"], x)
    x = x + ly.attention(shared["attn"], cfg, h, causal=True, window=cfg.local_window)
    h = ly.rmsnorm(shared["ln2"], x)
    x = x + ly.mlp(shared["mlp"], cfg, h)
    return x


def backbone(params, cfg: ModelConfig, x):
    flags, _, _ = _attn_flags(cfg)
    shared = params["shared"]

    def block(p, x, flag):
        h = ly.rmsnorm(p["ln"], x)
        out, _ = ssm.mamba2_block(p["mamba"], cfg, h)
        x = x + out
        x = constrain(x, "batch", None, None)
        x = jax.lax.cond(flag > 0, lambda z: _shared_block(shared, cfg, z), lambda z: z, x)
        return constrain(x, "batch", None, None)

    block = jax.checkpoint(block, policy=_remat_policy(cfg))

    def body(x, inp):
        p, f = inp
        return block(p, x, f), None

    x, _ = jax.lax.scan(body, x, (params["layers"], flags), unroll=cfg.scan_unroll)
    return ly.rmsnorm(params["ln_f"], x)


def train_loss(params, cfg: ModelConfig, batch) -> jax.Array:
    x = ly.embed(params["embedding"], cfg, batch["tokens"])
    x = backbone(params, cfg, x)
    return chunked_ce_loss(params, cfg, x, batch["labels"])


# -- serving ------------------------------------------------------------------


def _stacked_mamba_state(cfg: ModelConfig, B: int):
    st = ssm.mamba2_state_init(cfg, B)
    return jax.tree.map(lambda s: jnp.stack([s] * cfg.n_layers), st)


def init_cache(cfg: ModelConfig, B: int, max_seq: int):
    _, _, n_sites = _attn_flags(cfg)
    Smax = min(max_seq, cfg.local_window)
    Hkv, hd = cfg.n_kv_heads, cfg.hd
    return {
        "mamba": _stacked_mamba_state(cfg, B),
        "k": jnp.zeros((n_sites, B, Smax, Hkv, hd), ly.dt(cfg)),
        "v": jnp.zeros((n_sites, B, Smax, Hkv, hd), ly.dt(cfg)),
        "slot_pos": jnp.full((n_sites, Smax), -(2**30), jnp.int32),
        "pos": jnp.int32(0),
    }


def prefill(params, cfg: ModelConfig, batch, max_seq: int | None = None):
    """Python-loop prefill (keeps per-site cache extraction simple)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    max_seq = max_seq or S
    Smax = min(max_seq, cfg.local_window)
    x = ly.embed(params["embedding"], cfg, tokens)
    shared = params["shared"]
    mamba_states, cks, cvs, sps = [], [], [], []
    positions = jnp.arange(S)[None, :].astype(jnp.int32)
    for i in range(cfg.n_layers):
        p = jax.tree.map(lambda a: a[i], params["layers"])
        h = ly.rmsnorm(p["ln"], x)
        out, st = ssm.mamba2_block(p["mamba"], cfg, h)
        mamba_states.append(st)
        x = x + out
        if cfg.attn_every > 0 and (i + 1) % cfg.attn_every == 0:
            h = ly.rmsnorm(shared["ln1"], x)
            q, k, v = ly._project_qkv(shared["attn"], cfg, h, positions)
            attn = ly.chunked_attention(
                cfg, q, k, v, causal=True, window=cfg.local_window, softcap=None
            )
            x = x + attn.reshape(B, S, -1) @ shared["attn"]["wo"]
            ck, cv, sp = ly.fill_cache_from_prefill(k, v, Smax)
            cks.append(ck), cvs.append(cv), sps.append(sp)
            h = ly.rmsnorm(shared["ln2"], x)
            x = x + ly.mlp(shared["mlp"], cfg, h)
    x = ly.rmsnorm(params["ln_f"], x)
    last = ly.logits(params["embedding"], cfg, x[:, -1:])
    cache = {
        "mamba": jax.tree.map(lambda *s: jnp.stack(s), *mamba_states),
        "k": jnp.stack(cks),
        "v": jnp.stack(cvs),
        "slot_pos": jnp.stack(sps),
        "pos": jnp.int32(S),
    }
    return last, cache


def decode_step(params, cfg: ModelConfig, token, cache):
    x = ly.embed(params["embedding"], cfg, token)
    flags, slots, n_sites = _attn_flags(cfg)
    shared = params["shared"]
    pos = cache["pos"]

    def body(carry, inp):
        x, kc, vc, spc = carry
        p, st, flag, slot = inp
        h = ly.rmsnorm(p["ln"], x)
        out, st2 = ssm.mamba2_decode_step(p["mamba"], cfg, h, st)
        x = x + out

        def with_attn(args):
            x, kc, vc, spc = args
            h = ly.rmsnorm(shared["ln1"], x)
            out, ck, cv, sp = ly.decode_attention(
                shared["attn"], cfg, h, kc[slot], vc[slot], spc[slot], pos,
                window=cfg.local_window,
            )
            x = x + out
            h = ly.rmsnorm(shared["ln2"], x)
            x = x + ly.mlp(shared["mlp"], cfg, h)
            kc = jax.lax.dynamic_update_index_in_dim(kc, ck, slot, 0)
            vc = jax.lax.dynamic_update_index_in_dim(vc, cv, slot, 0)
            spc = jax.lax.dynamic_update_index_in_dim(spc, sp, slot, 0)
            return x, kc, vc, spc

        x, kc, vc, spc = jax.lax.cond(
            flag > 0, with_attn, lambda a: a, (x, kc, vc, spc)
        )
        return (x, kc, vc, spc), st2

    (x, kc, vc, spc), mamba_new = jax.lax.scan(
        body,
        (x, cache["k"], cache["v"], cache["slot_pos"]),
        (params["layers"], cache["mamba"], flags, slots),
        unroll=cfg.scan_unroll,
    )
    x = ly.rmsnorm(params["ln_f"], x)
    lg = ly.logits(params["embedding"], cfg, x)
    new_cache = {
        "mamba": mamba_new, "k": kc, "v": vc, "slot_pos": spc, "pos": pos + 1,
    }
    return lg, new_cache


def cache_logical_axes(cfg: ModelConfig, B: int):
    if B == 1:
        kv = (None, None, "kv_seq", None, None)
    elif cfg.decode_cache_seq_shard:
        kv = (None, "batch", "kv_seq", None, None)
    else:
        kv = (None, "batch", None, "kv_heads", None)
    return {
        "mamba": (
            (None, "batch", None, "ff"),          # conv buffer (L, B, K-1, dconv)
            (None, "batch", "heads", None, None),  # S state (L, B, H, N, P)
            (None, "batch", "heads", None),        # n state (L, B, H, N)
        ),
        "k": kv, "v": kv, "slot_pos": (None, None), "pos": (),
    }
