"""One benchmark per paper figure (Fig.1, 4, 5, 6, 7, 8, 9, 10).

Each ``fig*`` function writes a CSV artifact under benchmarks/results/ and
returns `name,us_per_call,derived` summary lines for benchmarks.run.

The λ-sweeps behind Fig.1/7/8 run on the vmapped fleet simulator
(:mod:`repro.fleet`): one grid = a handful of jitted launches instead of a
serial host loop, with discrete-event spot-checks retained at a few grid
points (the event sim stays the oracle; the fleet scan is the paper's own
§IV-A approximation, cross-validated in ``tests/test_fleet.py``). Greedy —
not table-expressible — rides the exact task-level engine
(:mod:`repro.taskq`) in Fig.7/9; MPC, whose cost-model state stays
host-side, remains on the event sim.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import (
    CAPACITY_BASIC,
    CLS,
    L,
    RESULTS_DIR,
    SAMPLER,
    BenchTimer,
    all_static_codes,
    fleet_sweep,
    fresh_greedy,
    fresh_tofec,
    rate_grid,
    run_policy,
    taskq_sweep,
    write_csv,
)
from repro.core import PAPER_READ_3MB, RequestClass, StaticPolicy, fit_delay_params
from repro.core import queueing
from repro.core.simulator import piecewise_poisson_arrivals, simulate
from repro.core.traces import TraceSampler, TraceStore
from repro.fleet import (
    PolicySpec,
    frontier,
    frontier_points,
    grid_cases,
    write_fleet_artifact,
)


def fig1_static_tradeoff(count: int = 3000) -> list[str]:
    """Fig.1: total delay vs arrival rate for every static MDS code —
    one vmapped fleet launch over the full (code × λ) grid."""
    rows = []
    rates = rate_grid(8, 0.1, 0.95)
    codes = all_static_codes()
    policies = [PolicySpec.static(n, k) for n, k in codes]
    with BenchTimer("fig1_static_tradeoff", calls=len(rates) * len(codes)) as t:
        res = fleet_sweep().run(grid_cases(rates, policies, [1], CLS, L), count)
        pts = {(p.policy, round(p.lam, 6)): p for p in frontier_points(res)}
        for (n, k) in codes:
            for lam in rates:
                p = pts[(f"static({n},{k})", round(float(lam), 6))]
                tput = min(lam, p.capacity_est)
                rows.append([n, k, f"{lam:.2f}", f"{p.mean:.4f}", f"{p.p50:.4f}",
                             f"{tput:.2f}"])
    write_csv("fig1_static_tradeoff.csv", ["n", "k", "lambda", "mean_s", "median_s", "tput"], rows)
    # Event-sim spot-check: the fleet scan tracks the oracle at a light and a
    # mid-load point of the basic code.
    errs = []
    for lam in (rates[0], rates[3]):
        ev = run_policy(StaticPolicy(1, 1), lam, min(count, 2000)).summary()
        fl = pts[("static(1,1)", round(float(lam), 6))]
        errs.append(abs(fl.mean - ev["mean"]) / ev["mean"])
    # Derived check: capacity loss of (6,3) vs (1,1) ≈ 30-40% (paper: ~30%).
    cap_63 = queueing.capacity(PAPER_READ_3MB, CLS.file_mb, 3, 2.0, L)
    return [t.row(f"cap63/cap11={cap_63 / CAPACITY_BASIC:.2f}"
                  f"|event_spotcheck_relerr={max(errs):.3f}")]


def fig4_task_ccdf() -> list[str]:
    """Fig.4: per-thread task-delay CCDF, Unique vs Shared Key (1MB chunks)."""
    rows = []
    with BenchTimer("fig4_task_ccdf") as t:
        for mode, corr in [("unique", 0.0), ("shared", 0.14)]:
            store = TraceStore.generate(
                PAPER_READ_3MB, [1.0], threads=6, samples=30_000,
                correlation=corr, seed=11,
            )
            delays = store.flat_delays(1.0)
            qs = np.quantile(delays, 1 - np.logspace(0, -4, 30))
            for q, v in zip(np.logspace(0, -4, 30), qs):
                rows.append([mode, f"{v:.4f}", f"{q:.6f}"])
            rho = store.cross_correlation(1.0)
            rows.append([f"{mode}_xcorr", f"{rho:.4f}", ""])
    write_csv("fig4_task_ccdf.csv", ["mode", "delay_s", "ccdf"], rows)
    return [t.row("unique_xcorr<0.05,shared~0.14")]


def fig5_service_ccdf(count: int = 20_000) -> list[str]:
    """Fig.5: service-delay CCDF for (n, 3) codes, n = 3..6, batch start."""
    rows = []
    rng = np.random.default_rng(5)
    p99_by_n = {}
    with BenchTimer("fig5_service_ccdf") as t:
        for n in range(3, 7):
            batch = SAMPLER.sample_batch(rng, k=3, n=n, size=count)
            d_s = np.sort(batch, axis=1)[:, 2]  # 3rd order statistic
            p99_by_n[n] = float(np.percentile(d_s, 99))
            for q in np.logspace(0, -4, 30):
                rows.append([n, f"{np.quantile(d_s, 1 - q):.4f}", f"{q:.6f}"])
    write_csv("fig5_service_ccdf.csv", ["n", "delay_s", "ccdf"], rows)
    # Paper: +1/+2/+3 chunks cut p99 by ~50/65/80%.
    red = 1 - p99_by_n[6] / p99_by_n[3]
    return [t.row(f"p99cut_n6_vs_n3={red:.2f}(paper~0.8)")]


def fig6_linear_fit() -> list[str]:
    """Fig.6: mean/std of task delay vs chunk size + least-squares lines,
    closing the loop: re-fitting traces recovers the generating params."""
    sizes = [0.5, 0.75, 1.0, 1.5, 2.0, 3.0]
    rows = []
    with BenchTimer("fig6_linear_fit") as t:
        store = TraceStore.generate(PAPER_READ_3MB, sizes, samples=30_000, seed=6)
        delays = [store.flat_delays(B) for B in sizes]
        for B, d in zip(sizes, delays):
            rows.append([f"{B:.2f}", f"{d.mean():.4f}", f"{d.std():.4f}"])
        fit = fit_delay_params(np.array(sizes), delays, drop_worst_frac=0.10)
    write_csv("fig6_linear_fit.csv", ["chunk_mb", "mean_s", "std_s"], rows)
    err = abs(fit.delta_tilde - PAPER_READ_3MB.delta_tilde) / PAPER_READ_3MB.delta_tilde
    return [t.row(f"refit_delta_tilde_relerr={err:.3f}")]


def fig7_adaptive_tradeoff(count: int = 3500) -> list[str]:
    """Fig.7: mean/median/p90/p99 vs λ — TOFEC, FixedK(6), basic, replication
    and every static code in ONE fleet launch (best_static is the per-rate
    min over the static part of the grid); Greedy rides the exact task
    engine (one vmapped taskq launch over the λ grid — it observes idle
    threads, which only the task-level simulation has); MPC, whose
    cost-model state stays host-side, remains on the event sim. Emits the
    BENCH_fleet.json frontier artifact."""
    import os

    from repro.core.controller import MPCPolicy

    rates = rate_grid(8, 0.1, 0.92)
    statics = all_static_codes()
    fleet_names = {
        "tofec": "tofec", "fixedk6": "fixedk(k=6)",
        "basic": "static(1,1)", "repl21": "static(2,1)",
    }
    policies = [PolicySpec.tofec(), PolicySpec.fixedk(6)] + [
        PolicySpec.static(n, k) for n, k in statics
    ]
    rows = []
    lines = []
    with BenchTimer("fig7_adaptive_tradeoff", calls=len(rates)) as t:
        res = fleet_sweep().run(grid_cases(rates, policies, [1], CLS, L), count)
        pts = frontier_points(res)
        art = write_fleet_artifact(
            os.path.join(RESULTS_DIR, "BENCH_fleet.json"), res, points=pts,
            extra={"figure": "fig7", "rates": [float(x) for x in rates]},
        )
        by = frontier(pts)
        tq, pools = taskq_sweep()
        greedy_by = frontier(frontier_points(tq.run(
            grid_cases(rates, [PolicySpec.greedy()], [1], CLS, L), count, pools
        )))["greedy"]
        for i, lam in enumerate(rates):
            for name, fleet_name in fleet_names.items():
                p = by[fleet_name][i]
                rows.append([name, f"{lam:.2f}", f"{p.mean:.4f}", f"{p.p50:.4f}",
                             f"{p.p90:.4f}", f"{p.p99:.4f}", f"{p.mean_k:.2f}"])
            stat_pts = [by[f"static({n},{k})"][i] for n, k in statics]
            rows.append(["best_static", f"{lam:.2f}",
                         f"{min(p.mean for p in stat_pts):.4f}",
                         f"{min(p.p50 for p in stat_pts):.4f}",
                         f"{min(p.p90 for p in stat_pts):.4f}",
                         f"{min(p.p99 for p in stat_pts):.4f}", ""])
            # Greedy: exact task engine (vmapped over the whole λ grid).
            g = greedy_by[i]
            rows.append(["greedy", f"{lam:.2f}", f"{g.mean:.4f}", f"{g.p50:.4f}",
                         f"{g.p90:.4f}", f"{g.p99:.4f}", f"{g.mean_k:.2f}"])
            # MPC: event-sim only (host-side cost-model state).
            s = run_policy(MPCPolicy(CLS, L), lam, count).summary()
            rows.append(["mpc", f"{lam:.2f}", f"{s['mean']:.4f}", f"{s['median']:.4f}",
                         f"{s['p90']:.4f}", f"{s['p99']:.4f}", f"{s['mean_k']:.2f}"])
    write_csv(
        "fig7_adaptive_tradeoff.csv",
        ["policy", "lambda", "mean_s", "median_s", "p90_s", "p99_s", "mean_k"], rows,
    )
    # Headline claims at light load, from the fleet frontier — with an
    # event-sim spot-check of the TOFEC point retained.
    gain = art["headline"].get("delay_gain_vs_basic", float("nan"))
    cap_gain = art["headline"].get("capacity_gain_vs_latency_optimal", float("nan"))
    ev = run_policy(fresh_tofec(), rates[0], count).summary()
    spot = abs(by["tofec"][0].mean - ev["mean"]) / ev["mean"]
    lines.append(t.row(
        f"light_load_mean_gain_vs_basic={gain:.2f}x(paper~2.5x)"
        f"|capacity_gain_vs_latency_optimal={cap_gain:.2f}x(paper~3x)"
        f"|event_spotcheck_relerr={spot:.3f}"
    ))
    return lines


def fig8_composition(count: int = 3500) -> list[str]:
    """Fig.8: fraction of requests served at each k — TOFEC from one fleet
    λ-sweep (k composition read off the stacked device outputs), Greedy from
    the event sim."""
    rates = rate_grid(6, 0.15, 0.9)
    rows = []
    with BenchTimer("fig8_composition", calls=len(rates)) as t:
        res = fleet_sweep().run(grid_cases(rates, [PolicySpec.tofec()], [1], CLS, L), count)
        ks_all = np.asarray(res.out["k"])
        warm = int(count * 0.05)
        mono_ok = True
        prev_mean_k = np.inf
        for i, lam in enumerate(rates):
            ks = ks_all[i, warm:]
            comp = [(ks == k).mean() for k in range(1, CLS.k_max + 1)]
            rows.append(["tofec", f"{lam:.2f}"] + [f"{c:.3f}" for c in comp])
            mk = ks.mean()
            mono_ok &= mk <= prev_mean_k + 0.35
            prev_mean_k = mk
            ev = run_policy(fresh_greedy(), lam, count)
            comp_g = ev.k_composition(CLS.k_max)
            rows.append(["greedy", f"{lam:.2f}"] + [f"{c:.3f}" for c in comp_g])
    write_csv("fig8_composition.csv",
              ["policy", "lambda"] + [f"k{k}" for k in range(1, CLS.k_max + 1)], rows)
    return [t.row(f"tofec_k_monotone_decreasing={mono_ok}")]


def fig9_std(count: int = 3500) -> list[str]:
    """Fig.9: delay standard deviation — TOFEC vs Greedy (QoS claim), both
    policies in ONE exact task-engine launch (Greedy's idle-thread state and
    the per-request order-statistic spread are task-level quantities the
    fluid scan cannot produce); an event-sim spot-check of the Greedy std is
    retained at the lightest rate."""
    rates = rate_grid(6, 0.15, 0.9)
    rows = []
    ratios = []
    with BenchTimer("fig9_std", calls=len(rates)) as t:
        tq, pools = taskq_sweep()
        res = tq.run(
            grid_cases(rates, [PolicySpec.tofec(), PolicySpec.greedy()], [1], CLS, L),
            count, pools,
        )
        by = frontier(frontier_points(res))
        for i, lam in enumerate(rates):
            s_t, s_g = by["tofec"][i].std, by["greedy"][i].std
            rows.append([f"{lam:.2f}", f"{s_t:.4f}", f"{s_g:.4f}"])
            ratios.append(s_g / s_t)
        ev = run_policy(fresh_greedy(), rates[0], count).totals().std()
        spot = abs(by["greedy"][0].std - ev) / ev
    write_csv("fig9_std.csv", ["lambda", "tofec_std_s", "greedy_std_s"], rows)
    return [t.row(f"greedy/tofec_std_mid={np.median(ratios):.2f}x(paper:2-3x)"
                  f"|event_spotcheck_relerr={spot:.3f}")]


def fig10_transient() -> list[str]:
    """Fig.10: 600s run at 10 → 70 → 10 req/s; per-request total delay and
    backlog recovery for TOFEC / Greedy / static(3,2)."""
    rows = []
    with BenchTimer("fig10_transient", calls=3) as t:
        recover = {}
        for name, pol in [
            ("tofec", fresh_tofec()),
            ("greedy", fresh_greedy()),
            ("static32", StaticPolicy(3, 2)),
        ]:
            rng = np.random.default_rng(10)
            arr = piecewise_poisson_arrivals(
                rng, [(200.0, 10.0), (200.0, 70.0), (200.0, 10.0)]
            )
            res = simulate(pol, arr, SAMPLER, L=L, seed=23, warmup_frac=0.0)
            for st in res.stats[:: max(1, len(res.stats) // 600)]:
                rows.append([name, f"{st.arrival:.1f}", f"{st.total:.4f}"])
            # recovery = first time after t=400 when the delay stays down
            # (rolling median of the next 20 requests < 2× light-load mean).
            late = [(st.arrival, st.total) for st in res.stats if st.arrival > 400.0]
            light_mean = np.mean([st.total for st in res.stats if st.arrival < 180.0])
            rec = 600.0
            for i in range(len(late) - 20):
                window = np.median([d for _, d in late[i : i + 20]])
                if window < 2 * light_mean:
                    rec = late[i][0]
                    break
            recover[name] = rec - 400.0
    write_csv("fig10_transient.csv", ["policy", "arrival_s", "total_delay_s"], rows)
    return [t.row(
        f"recovery_s tofec={recover['tofec']:.0f} greedy={recover['greedy']:.0f} "
        f"static32={recover['static32']:.0f}(paper:>100s)"
    )]


def fig_multiclass_disciplines(count: int = 3000) -> list[str]:
    """§IV-style figure: per-class delay vs aggregate λ when two tenant
    classes share ONE L-thread pool, under FIFO / strict-priority / WFQ
    admission — the joint :mod:`repro.sched` sweep — with the fleet's
    Poisson-split prediction (``tenant_cases``, the documented
    approximation) as the no-interference baseline column.

    The derived headline is the interference gap the fluid split cannot
    express: at the highest λ, the low-priority class's joint p99 over its
    split prediction (≫1) vs the high-priority class's (≈1).
    """
    import os

    from repro.fleet import TenantMix, tenant_cases
    from repro.sched import (
        DisciplineSpec,
        SchedSweep,
        interference_summary,
        multiclass_points,
        sched_cases,
        write_multiclass_artifact,
    )

    lo_cls = RequestClass("read1mb", 1.0, PAPER_READ_3MB, k_max=4, r_max=2.0, n_max=8)
    rates = rate_grid(6, 0.25, 0.85)
    disciplines = [
        DisciplineSpec.fifo(),
        DisciplineSpec.priority(0, 1),
        DisciplineSpec.wfq(1.0, 1.0),
    ]
    mixes = [TenantMix(float(lam), (CLS, lo_cls), (0.5, 0.5)) for lam in rates]
    rows = []
    with BenchTimer("fig_multiclass_disciplines", calls=len(rates)) as t:
        res = SchedSweep(chunk=32).run(sched_cases(mixes, disciplines, [1], L=L), count)
        pts = multiclass_points(res)
        # Poisson-split baseline: same mixes, split into per-class fluid
        # queues (quiet=True — the split is the deliberate contrast here).
        split_cases = [
            c for mix in mixes
            for c in tenant_cases(mix, [PolicySpec.tofec()], [1], L, quiet=True)
        ]
        split_res = fleet_sweep().run(split_cases, count)
        # Split cases carry the per-class rate w·λ (w = 0.5); key the
        # baseline by the aggregate λ it came from.
        split = {}
        for c, p in zip(split_cases, frontier_points(split_res)):
            split[(round(c.lam / 0.5, 6), c.cls.name)] = p
        for pt in pts:
            for cl in pt.classes:
                sp = split[(round(pt.lam, 6), cl["name"])]
                rows.append([
                    pt.discipline, f"{pt.lam:.2f}", cl["name"],
                    f"{cl['mean']:.4f}", f"{cl['p50']:.4f}", f"{cl['p99']:.4f}",
                    f"{sp.mean:.4f}", f"{sp.p99:.4f}",
                    f"{cl['mean_k']:.2f}", f"{pt.jain_delay:.4f}",
                ])
        split_p99 = {
            cl["name"]: split[(round(max(r.lam for r in pts), 6), cl["name"])].p99
            for cl in pts[-1].classes
        }
        head = interference_summary(pts, split_p99)
        write_multiclass_artifact(
            os.path.join(RESULTS_DIR, "BENCH_multiclass.json"), res, points=pts,
            extra={"figure": "fig_multiclass", "split_p99": split_p99},
        )
    write_csv(
        "fig_multiclass_disciplines.csv",
        ["discipline", "lambda", "class", "mean_s", "median_s", "p99_s",
         "split_mean_s", "split_p99_s", "mean_k", "jain_delay"],
        rows,
    )
    pr = head["priority(0,1)"]["p99_vs_split"]
    return [t.row(
        f"prio_p99_vs_split lo={pr['read1mb']:.1f}x hi={pr['read3mb']:.2f}x"
        f"|jain fifo={head['fifo']['jain_delay']:.3f}"
        f" prio={head['priority(0,1)']['jain_delay']:.3f}"
        f"|compiles={res.compiles}"
    )]


ALL_FIGS = [
    fig1_static_tradeoff,
    fig4_task_ccdf,
    fig5_service_ccdf,
    fig6_linear_fit,
    fig7_adaptive_tradeoff,
    fig8_composition,
    fig9_std,
    fig10_transient,
    fig_multiclass_disciplines,
]
