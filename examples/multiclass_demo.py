"""Shared-pool multi-class demo: what the Poisson split cannot see.

Two tenant classes (3 MB reads + 1 MB reads) share ONE pool of L = 16
threads. The joint scheduler sweep (:mod:`repro.sched`) evaluates the same
mix under the three admission disciplines — FIFO, strict priority (class 0
first), equal-weight WFQ — across an aggregate-λ grid, in a handful of
vmapped launches. The fleet's Poisson-split path (``tenant_cases``, the
documented approximation) rides alongside as the no-interference baseline.

The punchline is the §IV shared-resource story: under strict priority at
high load the low-priority class's p99 blows past its split prediction
while the high-priority class sits on its solo curve; FIFO and WFQ spread
the congestion evenly (Jain ≈ 1).

Run:  PYTHONPATH=src python examples/multiclass_demo.py [--fast]
"""

import argparse
import os
import time

import jax
import numpy as np

from repro.core import PAPER_READ_3MB, RequestClass
from repro.core import queueing
from repro.fleet import FleetSweep, PolicySpec, TenantMix, frontier_points, tenant_cases
from repro.sched import (
    DisciplineSpec,
    SchedSweep,
    by_discipline,
    interference_summary,
    multiclass_points,
    sched_cases,
    write_multiclass_artifact,
)

HI = RequestClass("read3mb", 3.0, PAPER_READ_3MB, k_max=6, r_max=2.0, n_max=12)
LO = RequestClass("read1mb", 1.0, PAPER_READ_3MB, k_max=4, r_max=2.0, n_max=8)
L = 16


def ascii_perclass(by, cls_name: str, split_means: dict[float, float],
                   width: int = 64, height: int = 12) -> str:
    """One class's mean delay vs aggregate λ, one glyph per discipline,
    with the Poisson-split prediction (``s``) as the baseline curve."""
    glyphs = {"fifo": "f", "priority(0,1)": "p", "wfq(1:1)": "w", "split": "s"}
    pts_all = [(pt.lam, c["mean"]) for pts in by.values() for pt in pts
               for c in pt.classes if c["name"] == cls_name]
    pts_all += list(split_means.items())
    y_min = min(m for _, m in pts_all)
    y_max = max(m for _, m in pts_all)
    x_min = min(x for x, _ in pts_all)
    x_max = max(x for x, _ in pts_all)
    span = np.log(y_max / y_min) + 1e-9
    grid = [[" "] * width for _ in range(height)]

    def plot(name, series):
        g = glyphs.get(name, name[0])
        for lam, m in series:
            x = int((lam - x_min) / (x_max - x_min + 1e-9) * (width - 1))
            y = int(np.log(m / y_min) / span * (height - 1))
            grid[height - 1 - y][x] = g

    plot("split", sorted(split_means.items()))
    for name, pts in sorted(by.items()):
        plot(name, [(pt.lam, pt.cls(cls_name)["mean"]) for pt in pts])
    lines = [f"{cls_name}: mean delay, log scale ({y_min:.3f}s .. {y_max:.3f}s)"]
    lines += ["|" + "".join(row) for row in grid]
    lines.append("+" + "-" * width + f"> aggregate lambda {x_min:.0f}..{x_max:.0f} req/s")
    lines.append("legend: " + "  ".join(f"{g}={n}" for n, g in sorted(glyphs.items())))
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="smaller grid/horizon")
    args = ap.parse_args()

    cap = queueing.capacity(PAPER_READ_3MB, HI.file_mb, 1, 1.0, L)
    n_rates = 4 if args.fast else 8
    count = 1500 if args.fast else 4000
    rates = np.linspace(0.25 * cap, 0.85 * cap, n_rates)
    disciplines = [
        DisciplineSpec.fifo(),
        DisciplineSpec.priority(0, 1),  # 3 MB reads outrank 1 MB reads
        DisciplineSpec.wfq(1.0, 1.0),
    ]
    mixes = [TenantMix(float(lam), (HI, LO), (0.5, 0.5)) for lam in rates]
    cases = sched_cases(mixes, disciplines, [0], L=L)
    print(f"joint grid: {len(cases)} points ({n_rates} rates x "
          f"{len(disciplines)} disciplines), {count} merged arrivals each")

    sweep = SchedSweep(chunk=32)
    t0 = time.monotonic()
    res = sweep.run(cases, count)
    jax.block_until_ready(res.out)  # async dispatch: sync before stopping
    dt = time.monotonic() - t0
    print(f"swept {len(cases)} x {count} arrivals in {dt:.2f}s "
          f"({res.launches} launches, {res.compiles} compiles)\n")
    pts = multiclass_points(res)
    by = by_discipline(pts)

    # The no-interference baseline: Poisson split through the fleet
    # (quiet=True — the fluid split is exactly what we want to contrast).
    split_cases = [
        c for mix in mixes
        for c in tenant_cases(mix, [PolicySpec.tofec()], [0], L, quiet=True)
    ]
    split_pts = frontier_points(FleetSweep(chunk=32).run(split_cases, count))
    split_p99 = {p.cls_name: p.p99 for p in split_pts
                 if p.lam == max(q.lam for q in split_pts if q.cls_name == p.cls_name)}
    # Split cases carry the per-class rate w·λ (w = 0.5): key by aggregate λ.
    split_means = {p.lam / 0.5: p.mean for p in split_pts if p.cls_name == "read1mb"}

    print("=== per-class frontier: the low-priority tenant (read1mb) ===")
    print(ascii_perclass(by, "read1mb", split_means))
    print()

    head = interference_summary(pts, split_p99)
    print("=== interference at the highest λ (joint p99 / split p99) ===")
    for name, entry in head.items():
        ratios = "  ".join(f"{k}={v:.2f}x" for k, v in entry["p99_vs_split"].items())
        print(f"{name:15s} jain={entry['jain_delay']:.3f} "
              f"spread={entry['p99_spread']:.2f}x  {ratios}")

    path = os.path.join(os.path.dirname(__file__), "..", "benchmarks", "results",
                        "BENCH_multiclass.json")
    write_multiclass_artifact(
        os.path.normpath(path), res, points=pts,
        extra={"source": "multiclass_demo", "split_p99": split_p99},
    )
    print(f"\nartifact: {os.path.normpath(path)}")


if __name__ == "__main__":
    main()
