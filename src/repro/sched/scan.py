"""Joint multi-class shared-pool scan: ONE L-thread pool, C request classes.

:func:`repro.core.jax_sim.tofec_scan_core` models a single class against the
pool; the fleet's ``tenant_cases`` path Poisson-splits a :class:`repro.fleet.
workloads.TenantMix` into independent copies of that fluid queue, so every
class believes it has all L threads to itself and cross-class interference —
the phenomenon §IV's multi-class analysis is about — never appears.

This module is the joint simulation: a single ``lax.scan`` over the merged
arrival stream, carrying a **per-class backlog vector** ``w`` (seconds of
pool work) and a per-class EWMA vector. The pool is work conserving — total
backlog drains at rate 1 between arrivals regardless of discipline — but
*which class's* work drains first, and how much queued work an arrival must
wait behind, is set by the admission discipline:

* ``DISC_FIFO`` — arrival order. Backlog drains across classes in proportion
  to their share (the fluid limit of well-mixed FIFO), and an arrival waits
  behind the *total* backlog.
* ``DISC_PRIORITY`` — strict priority by per-class rank (lower rank drains
  first); an arrival waits behind the backlog of its own and higher-priority
  classes only.
* ``DISC_WFQ`` — weighted fair (the GPS fluid limit of deficit round-robin):
  drain splits by weight among backlogged classes with unused share
  redistributed; an arrival of class c waits for its own backlog served at
  class c's guaranteed share of the pool.

All three are computed as plain arithmetic and chosen with ``jnp.where`` on
a per-grid-point discipline id, so a heterogeneous discipline grid rides one
compiled, ``vmap``-able function — the same policies-as-data trick the fleet
plays with threshold tables. Each class keeps its own TOFEC state (backlog
EWMA → (n, k) via its own threshold tables); usage accounting and queueing
delay come from the shared pool.

Degenerate guarantee (pinned in ``tests/test_sched.py``): with C = 1 every
discipline reduces to ``tofec_scan_core`` draw for draw — the FIFO drain
``w − min(dt, W)·(w/W)`` is bit-exact ``max(w − dt, 0)`` for a single class.

Cross-validated against the discrete-event oracle
:func:`repro.core.simulator.simulate_shared_pool`.
"""

from __future__ import annotations

import types

import jax
import jax.numpy as jnp

from repro.core.controller import tofec_threshold_step
from repro.core.jax_sim import _service_delay, _usage

#: Discipline ids (per-grid-point runtime data, never a static arg).
DISC_FIFO = 0
DISC_PRIORITY = 1
DISC_WFQ = 2

DISC_NAMES = {DISC_FIFO: "fifo", DISC_PRIORITY: "priority", DISC_WFQ: "wfq"}

_EPS = 1e-20  # guards 0/0 on empty backlogs; far above float32 denormals


def multiclass_scan_core(
    p,
    h_k: jax.Array,
    h_n: jax.Array,
    disc: jax.Array,
    prio: jax.Array,
    wfq_w: jax.Array,
    interarrivals: jax.Array,
    cls_ids: jax.Array,
    exp_draws: jax.Array,
    *,
    n_max: int,
) -> dict[str, jax.Array]:
    """Traceable joint scan body shared by the jitted entry and the sweep.

    ``p`` exposes per-class vectors ``delta_bar``/``delta_tilde``/``psi_bar``/
    ``psi_tilde``/``J``/``alpha``/``r_max`` of shape (C,) plus the scalar pool
    size ``L``; ``h_k`` (C, k_max+1) and ``h_n`` (C, n_max+1) are the
    per-class threshold tables (trailing zeros inert, like the fleet).
    ``disc`` is the scalar discipline id, ``prio`` (C,) the priority ranks
    (lower drains first; must be distinct), ``wfq_w`` (C,) positive weights.
    ``cls_ids`` (T,) int32 names the arriving class per step. Everything but
    ``n_max`` (the ``exp_draws`` width) may be a tracer, so the sched sweep
    can ``vmap`` a mixed-discipline grid through one compilation.
    """
    C = h_k.shape[0]
    eps = jnp.float32(_EPS)
    # Per-class mean usage at the basic code — q-length proxy scale factors.
    ubar = _usage(p, jnp.float32(1.0), jnp.float32(1.0))

    def step(carry, inp):
        # w: (C,) per-class waiting work [s of pool time]; t_tot/work track
        # cumulative time and per-class service work for online utilization.
        w, q_ewma, t_tot, work = carry
        dt, cid, exps = inp
        t_tot = t_tot + dt

        # ---- shared-pool drain over dt (work conserving in total) --------
        W = jnp.sum(w)
        drain = jnp.minimum(dt, W)
        # FIFO fluid: drained work splits across classes by backlog share.
        # For C = 1 this is bit-exact max(w - dt, 0): w/W == 1.0 exactly.
        share = w / jnp.maximum(W, eps)
        w_fifo = w - drain * share
        # Strict priority: class c only drains once all lower-rank backlog
        # ahead of it is gone.
        ahead = jnp.sum(jnp.where(prio[None, :] < prio[:, None], w[None, :], 0.0), axis=1)
        w_prio = w - jnp.clip(dt - ahead, 0.0, w)
        # Weighted fair (GPS fluid): split by weight among backlogged
        # classes, redistributing unused share. C rounds make the interval
        # allocation exact — each round empties a class or exhausts dt.
        w_wfq, rem = w, drain
        for _ in range(C):
            active = (w_wfq > 0.0).astype(jnp.float32)
            denom = jnp.sum(wfq_w * active)
            alloc = rem * wfq_w * active / jnp.maximum(denom, eps)
            d = jnp.minimum(alloc, w_wfq)
            w_wfq = w_wfq - d
            rem = rem - jnp.sum(d)
        w = jnp.where(
            disc == DISC_FIFO, w_fifo, jnp.where(disc == DISC_PRIORITY, w_prio, w_wfq)
        )

        # ---- queueing delay the class-cid arrival will experience --------
        onehot = jnp.arange(C) == cid
        dq_fifo = jnp.sum(w)
        # Priority: snapshot backlog at own-or-higher rank, amplified by
        # 1/(1 − σ_hi) for the strictly-higher-priority work that will keep
        # overtaking during the wait (the M/G/1 priority delay-cycle factor;
        # σ from the online utilization estimate, floor-clipped so a
        # saturated high class starves rather than diverges).
        rho = work / jnp.maximum(t_tot, eps)
        rho_hi = jnp.sum(jnp.where(prio < prio[cid], rho, 0.0))
        dq_prio = jnp.sum(jnp.where(prio <= prio[cid], w, 0.0)) / jnp.clip(
            1.0 - rho_hi, 0.05, 1.0
        )
        # Own backlog served at the class's share of the pool (share over
        # classes that are backlogged now — plus itself — not over all C).
        phi_act = jnp.where((w > 0.0) | onehot, wfq_w, 0.0)
        dq_wfq = w[cid] * jnp.sum(phi_act) / jnp.maximum(wfq_w[cid], eps)
        d_q = jnp.where(
            disc == DISC_FIFO, dq_fifo, jnp.where(disc == DISC_PRIORITY, dq_prio, dq_wfq)
        )

        # ---- per-class TOFEC adaptation (own EWMA, own tables) -----------
        pc = types.SimpleNamespace(
            delta_bar=p.delta_bar[cid], delta_tilde=p.delta_tilde[cid],
            psi_bar=p.psi_bar[cid], psi_tilde=p.psi_tilde[cid], J=p.J[cid],
        )
        q_new, n_i, k_i = tofec_threshold_step(
            q_ewma[cid], d_q * p.L / ubar[cid], h_k[cid], h_n[cid],
            p.r_max[cid], p.alpha[cid],
        )
        q_ewma = q_ewma.at[cid].set(q_new)

        nf, kf = n_i.astype(jnp.float32), k_i.astype(jnp.float32)
        s = _usage(pc, kf, nf / kf) / p.L
        d_s = _service_delay(pc, kf, nf, exps, n_max)
        w = w.at[cid].add(s)
        work = work.at[cid].add(s)
        return (w, q_ewma, t_tot, work), (d_q + d_s, d_q, d_s, n_i, k_i)

    # Per-class q̄ starts at the -1.0 cold-start sentinel (tofec_threshold_step).
    init = (
        jnp.zeros(C, jnp.float32), jnp.full(C, -1.0, jnp.float32),
        jnp.float32(0.0), jnp.zeros(C, jnp.float32),
    )
    _, (tot, dq, ds, ns, ks) = jax.lax.scan(
        step, init, (interarrivals, cls_ids, exp_draws)
    )
    return {"total": tot, "queueing": dq, "service": ds, "n": ns, "k": ks}
