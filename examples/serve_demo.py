"""Serving demo: batched decode with erasure-coded prompt storage.

Prompts live in the emulated store as Shared-Key coded objects; the proxy
fetches them with adaptive (n, k) ranged reads under an S3-like latency
model, tolerating injected read failures; the LM then prefills + decodes.

The fetch runs twice: once on the unfused path (the proxy batch-decodes
completions per admission round on the host codec) and once through the
fused serving step — one jitted launch running the TOFEC admission update
AND the batched MDS decode for the whole round. The fused step's codec
backend follows ``REPRO_CODEC_BACKEND`` when that names a jitted backend
(jnp / pallas) and falls back to jnp otherwise.

``--closed-loop`` runs the full serving tower instead: a ClosedLoopServer
whose single jitted step covers admission update → batched decode →
bytes→tokens → LM prefill, with the controller's (n, k) pick fed back into
the proxy's write policy so queued writes re-encode under the adapted code.

Run:  PYTHONPATH=src python examples/serve_demo.py [--closed-loop] [--fast]
"""

import argparse
import dataclasses

import jax
import numpy as np

from repro.coding.codec import get_codec
from repro.coding.layout import SharedKeyLayout
from repro.configs.qwen1_5_0_5b import CONFIG as QWEN
from repro.core import (
    PAPER_READ_3MB,
    FeedbackPolicy,
    RequestClass,
    TOFECPolicy,
)
from repro.models.registry import Arch, _FAMILY_MODULES
from repro.serve import (
    ClosedLoopServer,
    FusedServingStep,
    ServePolicy,
    ServingEngine,
)
from repro.storage import FaultyStore, LatencyStore, MemoryStore, Proxy
from repro.storage.proxy import store_coded_object

CFG = dataclasses.replace(
    QWEN, name="serve-demo", n_layers=4, d_model=256, n_heads=8, n_kv_heads=8,
    d_ff=512, vocab=4096,
)


def _setup(fast: bool, p_fail: float = 0.15):
    arch = Arch(cfg=CFG, module=_FAMILY_MODULES["dense"])
    params = arch.init(jax.random.key(0))
    eng = ServingEngine(arch, params, max_seq=96)

    prompt_len = 32
    layout = SharedKeyLayout(K=4, r=2, strip_bytes=prompt_len)
    inner = MemoryStore()
    store = FaultyStore(
        LatencyStore(inner, PAPER_READ_3MB, time_scale=1e-3, seed=2),
        p_fail=p_fail, seed=3,
    )
    rng = np.random.default_rng(1)
    keys = []
    for i in range(4 if fast else 6):
        toks = rng.integers(0, CFG.vocab, size=(prompt_len,)).astype(np.int32)
        store_coded_object(inner, f"prompt/{i}", layout, toks.tobytes())
        keys.append(f"prompt/{i}")

    cls = RequestClass("prompt", prompt_len * 4 / 2**20, PAPER_READ_3MB,
                       k_max=4, r_max=2.0, n_max=8)
    codec = get_codec()
    if not codec.backend.jitted:  # numpy default is host-only; fuse on jnp
        codec = get_codec("jnp")
    return eng, layout, inner, store, keys, cls, codec, prompt_len, rng


def run_fused_fetch(fast: bool):
    eng, layout, _, store, keys, cls, codec, prompt_len, _ = _setup(fast)
    steps = 4 if fast else 8
    fused = FusedServingStep.for_class(cls, L=8, codec=codec)
    proxy = Proxy(store, TOFECPolicy.for_classes([cls], L=8), L=8)
    try:
        res = eng.serve(proxy, layout, keys, prompt_len=prompt_len, steps=steps)
        print("generated token grid (batch × steps):")
        print(res.tokens)
        print("\nper-prompt storage fetch: code (n,k), delay")
        for key, code, d in zip(keys, res.codes, res.storage_total_s):
            print(f"  {key}: ({code[0]},{code[1]})  {d * 1e3:.1f} ms wall")
        print(f"\n15% injected read-failure rate absorbed by erasure coding; "
              f"{sum(r.failures for r in proxy.results)} task failures total")

        fres = eng.serve(proxy, layout, keys, prompt_len=prompt_len, steps=steps,
                         fused=fused)
        match = np.array_equal(fres.tokens, res.tokens)
        print(f"\nfused serving step ({codec.name} backend): one jitted launch "
              f"ran the TOFEC admission update + batched decode of all "
              f"{len(keys)} prompts")
        print(f"  tokens match unfused path: {match}")
        print(f"  controller pick for the next round: (n,k)={fres.next_code}, "
              f"compiled traces so far: {fused.traces}")
    finally:
        proxy.close()


def run_closed_loop(fast: bool):
    # Writes must land durably for the round-trip, so no injected failures
    # on this path (reads would shrug them off; the demo writes too).
    eng, layout, inner, _, keys, cls, codec, prompt_len, rng = _setup(
        fast, p_fail=0.0)
    store = LatencyStore(inner, PAPER_READ_3MB, time_scale=1e-3, seed=2)
    steps = 4 if fast else 8
    rounds = 2 if fast else 4
    write_pol = FeedbackPolicy(layout.N, layout.K)
    proxy = Proxy(store, TOFECPolicy.for_classes([cls], L=8), L=8,
                  write_policy=write_pol)
    step = FusedServingStep.for_policy(ServePolicy.tofec(), cls, 8, codec=codec)
    srv = ClosedLoopServer(eng, proxy, layout, step, prompt_len=prompt_len)
    try:
        print(f"closed-loop serving tower ({codec.name} backend): one jitted "
              f"step per round = admission update → batched decode → "
              f"bytes→tokens → LM prefill")
        for rnd in range(rounds):
            res = srv.serve_round(keys, steps=steps)
            print(f"\nround {rnd}: served {len(res.served_keys)}/{len(keys)} "
                  f"prompts, controller pick (n,k)={res.next_code} "
                  f"(pushed to write policy: {write_pol.code})")
            # queue a write: it encodes under the fed-back code at the next
            # admission round — the write path follows the controller.
            payload = rng.integers(0, 256, layout.file_bytes,
                                   dtype=np.uint8).tobytes()
            srv.put(f"out/{rnd}", payload)
        proxy.flush_writes()
        wres = [r for r in proxy.results if r.op == "write"]
        print(f"\n{len(wres)} writes flushed; codes used: "
              f"{sorted({(r.n, r.k) for r in wres})}")
        back = proxy.read(f"out/{rounds - 1}", layout,
                          payload_len=layout.file_bytes)
        print(f"read-back of last write under adapted code: ok={back.ok}")
        print(f"compiled closed-loop traces: {srv.traces} "
              f"(bounded per shape bucket)")
    finally:
        proxy.close()


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--closed-loop", action="store_true",
                    help="run the closed-loop serving tower (fused admission "
                         "+ decode + prefill, write policy fed back)")
    ap.add_argument("--fast", action="store_true",
                    help="smaller batch/steps for CI smoke runs")
    args = ap.parse_args()
    if args.closed_loop:
        run_closed_loop(args.fast)
    else:
        run_fused_fetch(args.fast)


if __name__ == "__main__":
    main()
