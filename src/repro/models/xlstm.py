"""xLSTM LM: alternating mLSTM (parallel/chunked) and sLSTM (sequential)
blocks, pre-norm residual, no separate FFN (d_ff=0 in the xlstm-350m config —
the blocks carry their own up/down projections)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as ly
from repro.models import ssm
from repro.models.config import ModelConfig
from repro.models.lm import chunked_ce_loss
from repro.models.sharding import constrain


def _is_slstm(cfg: ModelConfig, i: int) -> bool:
    return cfg.slstm_every > 0 and (i + 1) % cfg.slstm_every == 0


def init(rng, cfg: ModelConfig):
    k_emb, k_layers = jax.random.split(rng)
    keys = jax.random.split(k_layers, cfg.n_layers)
    blocks = []
    for i, k in enumerate(keys):
        cell = ssm.init_slstm(k, cfg) if _is_slstm(cfg, i) else ssm.init_mlstm(k, cfg)
        blocks.append({"ln": ly.init_rmsnorm(cfg.d_model, ly.dt(cfg)), "cell": cell})
    return {
        "embedding": ly.init_embedding(k_emb, cfg),
        "blocks": blocks,
        "ln_f": ly.init_rmsnorm(cfg.d_model, ly.dt(cfg)),
    }


def logical_axes(cfg: ModelConfig):
    norm = {"scale": (None,)}
    blocks = []
    for i in range(cfg.n_layers):
        cell = ssm.slstm_logical_axes(cfg) if _is_slstm(cfg, i) else ssm.mlstm_logical_axes(cfg)
        blocks.append({"ln": norm, "cell": cell})
    return {
        "embedding": ly.embedding_logical_axes(cfg),
        "blocks": blocks,
        "ln_f": norm,
    }


def _apply_block(cfg, i, blk, x, state=None):
    h = ly.rmsnorm(blk["ln"], x)
    if _is_slstm(cfg, i):
        out, new_state = ssm.slstm_block(blk["cell"], cfg, h, state)
    else:
        out, new_state = ssm.mlstm_block(blk["cell"], cfg, h, state)
    return x + out, new_state


def backbone(params, cfg: ModelConfig, x):
    for i, blk in enumerate(params["blocks"]):
        x, _ = _apply_block(cfg, i, blk, x)
        x = constrain(x, "batch", None, None)
    return ly.rmsnorm(params["ln_f"], x)


def train_loss(params, cfg: ModelConfig, batch) -> jax.Array:
    x = ly.embed(params["embedding"], cfg, batch["tokens"])
    x = backbone(params, cfg, x)
    return chunked_ce_loss(params, cfg, x, batch["labels"])


# -- serving ------------------------------------------------------------------


def init_cache(cfg: ModelConfig, B: int, max_seq: int):
    states = []
    for i in range(cfg.n_layers):
        if _is_slstm(cfg, i):
            states.append(ssm.slstm_state_init(cfg, B))
        else:
            states.append(ssm.mlstm_state_init(cfg, B))
    return {"states": states, "pos": jnp.int32(0)}


def prefill(params, cfg: ModelConfig, batch, max_seq: int | None = None):
    x = ly.embed(params["embedding"], cfg, batch["tokens"])
    states = []
    for i, blk in enumerate(params["blocks"]):
        x, st = _apply_block(cfg, i, blk, x)
        states.append(st)
    x = ly.rmsnorm(params["ln_f"], x)
    last = ly.logits(params["embedding"], cfg, x[:, -1:])
    return last, {"states": states, "pos": jnp.int32(batch["tokens"].shape[1])}


def decode_step(params, cfg: ModelConfig, token, cache):
    x = ly.embed(params["embedding"], cfg, token)
    new_states = []
    for i, (blk, st) in enumerate(zip(params["blocks"], cache["states"])):
        h = ly.rmsnorm(blk["ln"], x)
        if _is_slstm(cfg, i):
            out, st2 = ssm.slstm_decode_step(blk["cell"], cfg, h, st)
        else:
            out, st2 = ssm.mlstm_decode_step(blk["cell"], cfg, h, st)
        x = x + out
        new_states.append(st2)
    x = ly.rmsnorm(params["ln_f"], x)
    lg = ly.logits(params["embedding"], cfg, x)
    return lg, {"states": new_states, "pos": cache["pos"] + 1}


def cache_logical_axes(cfg: ModelConfig, B: int):
    states = []
    for i in range(cfg.n_layers):
        if _is_slstm(cfg, i):
            states.append((("batch", None),) * 4)  # h, c, n, m: (B, d)
        else:
            states.append((("batch", "heads", None, None), ("batch", "heads", None)))
    return {"states": states, "pos": ()}
