"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, asserting output shapes and no NaNs; plus a prefill→decode consistency
check per family."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import arch_names, get
from repro.models.config import ShapeSpec
from repro.models.registry import make_batch

SMOKE_SHAPE = ShapeSpec("smoke_train", "train", seq=32, batch=2)


@pytest.mark.parametrize("name", arch_names())
def test_smoke_train_step(name):
    arch = get(name, smoke=True)
    params = arch.init(jax.random.key(0))
    batch = make_batch(arch.cfg, SMOKE_SHAPE)

    loss, grads = jax.jit(jax.value_and_grad(lambda p: arch.train_loss(p, batch)))(params)
    assert np.isfinite(float(loss)), f"{name}: loss not finite"
    # Loss at init should be near ln(vocab) for random labels.
    assert 0.2 * np.log(arch.cfg.vocab) < float(loss) < 3.0 * np.log(arch.cfg.vocab)
    leaf_norms = [float(jnp.linalg.norm(g.astype(jnp.float32))) for g in jax.tree.leaves(grads)]
    assert all(np.isfinite(leaf_norms)), f"{name}: non-finite grads"
    assert any(n > 0 for n in leaf_norms), f"{name}: all-zero grads"


@pytest.mark.parametrize("name", arch_names())
def test_smoke_prefill_decode(name):
    arch = get(name, smoke=True)
    params = arch.init(jax.random.key(1))
    B, S = 2, 16
    shape = ShapeSpec("smoke_prefill", "prefill", seq=S, batch=B)
    batch = make_batch(arch.cfg, shape)

    last, cache = jax.jit(lambda p, b: arch.prefill(p, b, max_seq=S + 8))(params, batch)
    assert last.shape == (B, 1, arch.cfg.vocab)
    assert np.all(np.isfinite(np.asarray(last)))

    token = jnp.argmax(last, axis=-1).astype(jnp.int32)
    logits2, cache2 = jax.jit(arch.decode_step)(params, token, cache)
    assert logits2.shape == (B, 1, arch.cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits2)))
    # One more step to exercise cache advancement.
    token3 = jnp.argmax(logits2, axis=-1).astype(jnp.int32)
    logits3, _ = jax.jit(arch.decode_step)(params, token3, cache2)
    assert np.all(np.isfinite(np.asarray(logits3)))


@pytest.mark.parametrize("name", ["mistral-nemo-12b", "mixtral-8x7b", "zamba2-2.7b", "xlstm-350m"])
def test_decode_matches_prefill_continuation(name):
    """Decoding token t+1 after prefill[0:t] must match prefill[0:t+1]'s
    last logits (teacher-forcing consistency)."""
    arch = get(name, smoke=True)
    params = arch.init(jax.random.key(2))
    rng = np.random.default_rng(3)
    B, S = 2, 12
    toks = rng.integers(0, arch.cfg.vocab, size=(B, S + 1))
    batch_s = {"tokens": jnp.asarray(toks[:, :S], jnp.int32)}
    batch_s1 = {"tokens": jnp.asarray(toks, jnp.int32)}
    if arch.cfg.family == "vlm":
        patches = jnp.asarray(rng.normal(size=(B, arch.cfg.vision_patches, arch.cfg.d_model)), jnp.float32)
        batch_s["patches"] = patches
        batch_s1["patches"] = patches

    _, cache = arch.prefill(params, batch_s, max_seq=S + 4)
    step_logits, _ = arch.decode_step(params, jnp.asarray(toks[:, S : S + 1], jnp.int32), cache)
    full_logits, _ = arch.prefill(params, batch_s1, max_seq=S + 4)
    np.testing.assert_allclose(
        np.asarray(step_logits), np.asarray(full_logits), rtol=0.08, atol=0.08
    )


def test_param_count_estimates():
    """Analytic N vs actual init leaf count — within 10% for the big dense
    archs (validates MODEL_FLOPS = 6·N·D inputs)."""
    for name in ["yi-6b", "mistral-nemo-12b", "mixtral-8x7b"]:
        arch = get(name, smoke=False)
        est = arch.cfg.param_count_dense()
        want = {"yi-6b": 6e9, "mistral-nemo-12b": 12e9, "mixtral-8x7b": 46e9}[name]
        assert 0.7 * want < est < 1.4 * want, (name, est)
