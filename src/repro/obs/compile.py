"""Shared compile/retrace accounting.

Every jitted engine used to carry its own counter dataclass
(``FleetSweep.stats`` / ``SchedSweep`` / ``TaskqSweep``'s ``SweepStats``,
the codec's ``CodecStats``, and the bare ``traces`` ints on
``FusedServingStep`` / ``ClosedLoopServer``).  :class:`CompileStats` is the
one implementation: the old names stay importable as thin aliases and the
attribute APIs (``.traces``, ``.launches``, ``.cases``, ``.by_mesh``,
``.calls``, ``.items``, ``.reset()``) are unchanged, so existing tests and
compile-count pins keep passing.

Instances constructed with a ``label`` self-register in a process-wide weak
registry; :func:`compile_snapshot` aggregates it into one dict so "where
did every retrace go" is a single call across engines.
"""
from __future__ import annotations

import dataclasses
import weakref


@dataclasses.dataclass(eq=False)
class CompileStats:
    """Uniform trace/launch/case accounting (asserted in tests).

    ``by_mesh`` splits the trace count by the mesh shape the compilation
    was built for — ``()`` for the single-device path, ``(D,)`` for a
    D-device grid mesh — so the mesh-keyed bucket rule is pinnable.
    ``calls``/``items`` serve the codec's per-launch batching claim.
    """

    label: str = ""
    traces: int = 0  # distinct compilations (incremented at trace time)
    launches: int = 0
    cases: int = 0
    calls: int = 0
    items: int = 0
    by_mesh: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        if self.label:
            register_stats(self)

    def reset(self) -> None:
        self.traces = self.launches = self.cases = self.calls = self.items = 0
        self.by_mesh.clear()

    def snapshot(self) -> dict:
        return {
            "traces": self.traces,
            "launches": self.launches,
            "cases": self.cases,
            "calls": self.calls,
            "items": self.items,
            "by_mesh": {str(k): v for k, v in self.by_mesh.items()},
        }


_REGISTRY: "weakref.WeakSet[CompileStats]" = weakref.WeakSet()


def register_stats(stats: CompileStats) -> CompileStats:
    _REGISTRY.add(stats)
    return stats


def compile_snapshot() -> dict:
    """Aggregate every labeled live CompileStats, summed per label."""
    out: dict = {}
    for s in sorted(_REGISTRY, key=lambda s: s.label):
        agg = out.setdefault(s.label, {"traces": 0, "launches": 0, "cases": 0, "calls": 0, "items": 0, "by_mesh": {}})
        snap = s.snapshot()
        for k in ("traces", "launches", "cases", "calls", "items"):
            agg[k] += snap[k]
        for mk, mv in snap["by_mesh"].items():
            agg["by_mesh"][mk] = agg["by_mesh"].get(mk, 0) + mv
    return out
