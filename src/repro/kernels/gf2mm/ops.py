"""Jit'd user-facing ops over the gf2mm Pallas kernel.

Thin compatibility wrappers around the unified batched codec engine
(:mod:`repro.coding.codec`) pinned to the ``pallas`` backend: this module
used to be one of three divergent encode call-paths (alongside the numpy
oracle in ``rs.py`` and the layout's own path); it now just routes
single-codeword calls through the shared engine, inheriting its shape-
bucketed jit caching and the fused bitplane pack/unpack kernel.

``REPRO_PALLAS_INTERPRET=1`` (default in CPU containers) runs the kernel in
interpret mode; flip to 0 on real TPUs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.coding.codec import default_pallas_interpret

INTERPRET = default_pallas_interpret()


def _codec(interpret: bool):
    from repro.coding.codec import get_codec

    return get_codec("pallas", interpret=interpret)


def rs_encode(data: jax.Array, *, n: int, k: int, interpret: bool = INTERPRET) -> jax.Array:
    """Systematic RS encode on TPU: (k, B) uint8 -> (n, B) uint8.

    Data rows pass through; parity rows come from the GF(2) bit-matrix
    matmul kernel (batched engine, batch of one).
    """
    if data.shape[0] != k:
        raise ValueError(f"data rows {data.shape[0]} != k {k}")
    return jnp.asarray(_codec(interpret).encode(data, n, k))


def rs_decode(
    rows: jax.Array, *, n: int, k: int, present: tuple[int, ...], interpret: bool = INTERPRET
) -> jax.Array:
    """Reconstruct (k, B) data from k surviving strips via the same kernel.

    ``present`` selects the decode matrix; decode is just encode with the
    inverted generator submatrix (a traced input to the bucketed kernel).
    """
    if rows.shape[0] != k:
        raise ValueError(f"rows {rows.shape[0]} != k {k}")
    present = tuple(int(i) for i in present)
    return jnp.asarray(_codec(interpret).decode(rows, present, n, k))


def encode_blob(payload: np.ndarray, *, n: int, k: int) -> np.ndarray:
    """Host convenience: 1-D uint8 payload -> (n, ceil(len/k)) coded strips."""
    return _codec(INTERPRET).encode_blob(np.asarray(payload, np.uint8), n=n, k=k)


def decode_blob(
    strips: np.ndarray, present: tuple[int, ...], *, n: int, k: int, payload_len: int
) -> np.ndarray:
    """Host convenience: any k strips (k, strip) + ids -> payload bytes."""
    return _codec(INTERPRET).decode_blob(
        strips, tuple(int(i) for i in present), n=n, k=k, payload_len=payload_len
    )
