"""Exact task-level FEC queue engine as one ``lax.scan`` over arrivals.

:mod:`repro.core.jax_sim` runs the paper's §IV-A *fluid* approximation — a
single Lindley recursion with service rate L/U(n, k). This module runs the
**exact** §II-A system instead, on device: L threads, FIFO request backlog,
k-of-n completion, preemptive cancellation of the n−k stragglers, task
delays read from pre-sampled trace pools. It matches the discrete-event
oracle (:func:`repro.core.simulator.simulate`) draw for draw when both
consume the same :class:`repro.core.traces.DevicePools` — the parity pin of
``tests/test_taskq.py``.

Why one admission per scan step is exact
----------------------------------------
With a single FIFO class, requests are admitted in arrival order, and a
request's service depends only on (a) the thread busy-until multiset left
by its predecessors and (b) its own task delays — never on later arrivals.
So the whole event simulation collapses to a per-request recurrence over an
L-vector ``b`` of thread busy-until times:

1. **Assign** (pass 1): tasks take threads in FIFO order at successive
   thread-free events. ``fori_loop`` over the task lanes: task m starts at
   ``S_m = max(t, min(f))`` and tentatively completes at ``C_m = S_m + X_m``
   (updating ``f``) — this handles the intra-request feedback where a
   request's later tasks start on threads freed by its *own* earlier
   completions.
2. **Complete**: the request departs at the k-th order statistic
   ``D = sort(C)[k−1]``. Tasks with ``C ≤ D`` are the k winners (task
   delays are strictly positive, so every winner's start precedes D);
   tasks with ``S ≥ D`` never start (cancelled in queue); the rest are
   cancelled *in service* at D.
3. **Cancel** (pass 2): replay the assignment against the real outcome —
   started tasks hold their thread until ``min(C, D)``, never-started tasks
   leave it untouched. Never-started tasks form a suffix of the FIFO task
   order and only ever claim threads freeing at or after D, so the pass-1
   and pass-2 thread-free multisets agree below D and the replay is exact.

Queue-length observable: the carry holds a rolling ring of the last
``q_cap`` admission times (the FIFO backlog); the backlog length at an
arrival is the count of prior admissions still in the future — exact while
the instantaneous backlog is shorter than ``q_cap`` (at which point the
observation saturates; threshold policies have long since pinned the basic
code). The idle-thread count ``#{b ≤ t}`` is exact always: a thread with
no residual work is idle precisely because admission is work-conserving.

Everything but the shapes may be a tracer, so :class:`repro.taskq.sweep.
TaskqSweep` vmaps heterogeneous (λ × policy × seed) grids — threshold AND
greedy points mixed — through one compilation per shape bucket.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro import obs
from repro.core.controller import tofec_threshold_step
from repro.taskq.policies import POL_GREEDY, greedy_select

_INF = jnp.float32(jnp.inf)


def taskq_scan_core(
    cfg,
    interarrivals: jax.Array,
    pool_idx: jax.Array,
    pools: jax.Array,
    pool_sizes: jax.Array,
    *,
    L: int,
    q_cap: int = 128,
    collect: bool = False,
    valid: jax.Array | None = None,
    window: int | None = None,
    flight: bool = False,
) -> dict[str, jax.Array]:
    """Traceable single-point engine body shared by the jitted entry point
    and :class:`repro.taskq.sweep.TaskqSweep`.

    ``cfg`` maps per-point runtime scalars/tables: ``J`` (file MB),
    ``alpha``, ``r_max``, ``pol`` (int32 policy id), ``gk_max`` (int32
    greedy chunk cap), ``h_k``/``h_n`` threshold tables. ``interarrivals``
    (T,) float32 gaps; ``pool_idx`` (T,) int32 pre-sampled row draws;
    ``pools`` (S, P, W) float32 per-chunk-size delay pools and
    ``pool_sizes`` (S,) float32 their chunk sizes — grid-shared broadcast
    arrays from :meth:`repro.core.traces.TraceStore.device_pools`. Only
    ``L`` (the thread-state width) and ``q_cap`` (the backlog ring width)
    are static; the task-lane count is the pool width W, so codes with
    n > L are exact too (their excess tasks queue for threads freed by
    their own siblings' completions — the pass-1 feedback).

    Returns per-request (T,) arrays: ``total``/``queueing``/``service``
    delays (queueing = first task start − arrival, matching §II-C's D_q)
    and the chosen ``n``/``k``.

    ``collect`` (static) additionally emits per-step exact observables —
    cancellation counts split queue/service, the idle-thread count and the
    backlog length — and reduces them on device into an ``"obs"``
    :class:`repro.obs.MetricsBuf` entry (idle histogram, cancellation
    counters, backlog high-water mark). ``valid`` is an optional (T,) mask
    of real arrivals so bucket-padded launches don't count padding.
    ``window`` (static, collect only) additionally emits a ``"timeline"``
    :class:`repro.obs.TimelineBuf` of per-window series — here the backlog
    series is the scan's *exact* per-arrival queue length. The primary
    outputs' graph is untouched either way.

    ``flight`` (static, independent of ``collect``) additionally emits the
    per-request task records the aggregate reductions discard: a
    ``"flight"`` dict of ``arrival``/``depart`` (T,) and per-lane
    ``start``/``tent``/``thread`` (T, W) arrays. Starts and tentative
    completions come from the pass-1 assignment (exact for every task that
    really starts — the pass-1/pass-2 free multisets agree below D); the
    assigned-thread id is captured in the pass-2 settle loop, whose thread
    state IS the real one, so per-thread task intervals never overlap (a
    lane that never starts records thread −1). Host-side reconstruction —
    cancel kinds, spans, Chrome traces — lives in
    :class:`repro.obs.flight.FlightLog`. Off, the graph is bit-identical.
    """
    W = pools.shape[2]
    n_cap = W
    lane = jnp.arange(n_cap)
    J = jnp.asarray(cfg["J"], jnp.float32)
    alpha = jnp.asarray(cfg["alpha"], jnp.float32)
    r_max = jnp.asarray(cfg["r_max"], jnp.float32)
    pol = jnp.asarray(cfg["pol"], jnp.int32)
    gk_max = jnp.asarray(cfg["gk_max"], jnp.int32)
    h_k = jnp.asarray(cfg["h_k"], jnp.float32)
    h_n = jnp.asarray(cfg["h_n"], jnp.float32)

    def step(carry, inp):
        t, b, ring, pos, q_ewma = carry
        dt, idx = inp
        t = t + dt

        # ---- exact arrival-instant observables ---------------------------
        idle = jnp.sum(b <= t).astype(jnp.int32)
        q = jnp.sum(ring > t).astype(jnp.float32)

        # ---- policy: threshold tables and greedy, selected by id ---------
        q_new, n_t, k_t = tofec_threshold_step(q_ewma, q, h_k, h_n, r_max, alpha)
        n_g, k_g = greedy_select(q, idle, gk_max, r_max)
        is_greedy = pol == POL_GREEDY
        n = jnp.where(is_greedy, n_g, n_t)
        k = jnp.where(is_greedy, k_g, k_t)
        k = jnp.minimum(k, jnp.int32(n_cap))
        n = jnp.clip(n, k, jnp.int32(n_cap))
        q_ewma = q_new  # EWMA tracked uniformly (inert for greedy points)

        # ---- task delays from the shared trace pools ---------------------
        s_idx = jnp.argmin(jnp.abs(pool_sizes - J / k.astype(jnp.float32)))
        row = pools[s_idx, idx]  # one jointly-sampled thread batch (W,)
        X = jnp.where(lane < n, row, _INF)

        # ---- pass 1: FIFO assignment with own-completion feedback --------
        def assign(m, st):
            f, S, C = st
            j = jnp.argmin(f)
            s_m = jnp.maximum(t, f[j])
            c_m = s_m + X[m]
            live = m < n
            S = S.at[m].set(jnp.where(live, s_m, _INF))
            C = C.at[m].set(jnp.where(live, c_m, _INF))
            f = jnp.where(live, f.at[j].set(c_m), f)
            return f, S, C

        _, S, C = jax.lax.fori_loop(
            0, n_cap, assign, (b, jnp.full(n_cap, _INF), jnp.full(n_cap, _INF))
        )

        # ---- k-of-n completion -------------------------------------------
        D = jnp.sort(C)[k - 1]

        # ---- pass 2: replay with cancellation → new thread state ---------
        if flight:
            # Same replay, additionally recording WHICH thread each started
            # task held — pass-2 identities are the real occupancy (pass-1
            # ids can differ on ties even though the free multisets agree).
            def settle_rec(m, st):
                f, tid = st
                j = jnp.argmin(f)
                started = (m < n) & (jnp.maximum(t, f[j]) < D)
                f = jnp.where(started, f.at[j].set(jnp.minimum(C[m], D)), f)
                tid = tid.at[m].set(
                    jnp.where(started, j.astype(jnp.int32), jnp.int32(-1)))
                return f, tid

            b, tid = jax.lax.fori_loop(
                0, n_cap, settle_rec, (b, jnp.full(n_cap, -1, jnp.int32)))
        else:
            def settle(m, f):
                j = jnp.argmin(f)
                started = (m < n) & (jnp.maximum(t, f[j]) < D)
                return jnp.where(started, f.at[j].set(jnp.minimum(C[m], D)), f)

            b = jax.lax.fori_loop(0, n_cap, settle, b)

        # ---- bookkeeping -------------------------------------------------
        a = S[0]  # admission = first task start (§II-C's T_1)
        ring = ring.at[pos].set(a)
        pos = (pos + 1) % q_cap
        d_q = a - t
        d_s = D - a
        ys = (d_q + d_s, d_q, d_s, n, k)
        if collect:
            # Started tasks have S < D (and X > 0 ⇒ S ≥ D implies C > D),
            # so the cancelled n−k split exactly into queue vs in-service.
            live = lane < n
            cancel_q = jnp.sum(live & (S >= D)).astype(jnp.int32)
            cancel_s = jnp.sum(live & (S < D) & (C > D)).astype(jnp.int32)
            ys = ys + (idle, q, cancel_q, cancel_s)
        if flight:
            ys = ys + (t, D, S, C, tid)
        return (t, b, ring, pos, q_ewma), ys

    init = (
        jnp.float32(0.0),
        jnp.zeros(L, jnp.float32),
        jnp.full(q_cap, -_INF),
        jnp.int32(0),
        jnp.float32(-1.0),  # q̄ cold-start sentinel (tofec_threshold_step)
    )
    _, ys = jax.lax.scan(step, init, (interarrivals, pool_idx))
    tot, dq, ds, ns, ks = ys[:5]
    out = {"total": tot, "queueing": dq, "service": ds, "n": ns, "k": ks}
    if flight:
        fl_t, fl_d, fl_s, fl_c, fl_tid = ys[-5:]
        out["flight"] = {"arrival": fl_t, "depart": fl_d, "start": fl_s,
                         "tent": fl_c, "thread": fl_tid}
        ys = ys[:-5]
    if collect:
        idle_t, q_t, cq_t, cs_t = ys[5:]
        if valid is None:
            valid = jnp.ones(tot.shape[-1], bool)
        w = valid.astype(jnp.int32)
        # Cancellations *issued*: tasks with C > D. Ties C == D complete
        # with the request (nothing to cancel), so this can undershoot the
        # n−k budget by the tie count — it is the exact cancel-RPC tally.
        buf = obs.MetricsBuf.zeros(
            counters=("taskq_cancelled", "taskq_cancel_queue",
                      "taskq_cancel_service"),
            hists={"taskq_idle": L + 1},
            highs=("taskq_q_hi",),
        )
        buf = buf.count("taskq_cancelled", ((cq_t + cs_t) * w).sum())
        buf = buf.count("taskq_cancel_queue", (cq_t * w).sum())
        buf = buf.count("taskq_cancel_service", (cs_t * w).sum())
        buf = buf.observe("taskq_idle", idle_t, weight=w)
        buf = buf.high("taskq_q_hi", jnp.where(valid, q_t, 0.0))
        out["obs"] = buf
        if window:
            out["timeline"] = obs.sweep_timeline(
                out, interarrivals, window=window, valid=valid, backlog=q_t)
    return out


@functools.partial(
    jax.jit, static_argnames=("L", "q_cap", "collect", "window", "flight")
)
def _taskq_scan_jit(
    cfg, interarrivals, pool_idx, pools, pool_sizes, *, L, q_cap, collect,
    window, flight,
):
    return taskq_scan_core(
        cfg, interarrivals, pool_idx, pools, pool_sizes,
        L=L, q_cap=q_cap, collect=collect, window=window, flight=flight,
    )


def taskq_scan(
    cfg,
    interarrivals: jax.Array,
    pool_idx: jax.Array,
    pools: jax.Array,
    pool_sizes: jax.Array,
    *,
    L: int,
    q_cap: int = 128,
    collect: bool | None = None,
    window: int | None = None,
    flight: bool = False,
) -> dict[str, jax.Array]:
    """Jitted single-grid-point entry point (the serial-scan baseline of
    ``benchmarks.kernel_bench.bench_taskq_engine``). ``collect`` defaults
    to the ``REPRO_OBS`` gate; it, ``window`` and ``flight`` are static jit
    args, so a constant setting keeps compile counts at their pinned
    values."""
    if collect is None:
        collect = obs.enabled()
    if window is not None:
        window = int(window)
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
    return _taskq_scan_jit(
        cfg, interarrivals, pool_idx, pools, pool_sizes,
        L=L, q_cap=q_cap, collect=bool(collect),
        window=window, flight=bool(flight),
    )
