"""Erasure-coded distributed checkpointing (TOFEC-integrated).

Every checkpoint leaf (one array of the params/opt-state pytree) is:
  1. serialized (raw bytes + dtype/shape manifest entry, crc32 checksum),
  2. RS-encoded into n strips of size ⌈bytes/k⌉ through the MXU bit-matrix
     kernel path (:mod:`repro.kernels.gf2mm`),
  3. written as n independent objects ``{prefix}/step{s}/{leaf}/strip{i}``.

Restore fetches any k surviving strips per leaf and decodes — node/object
loss up to n−k per leaf is invisible. The chunking level k is chosen
per-write by the TOFEC controller from the writer backlog: an idle writer
uses high k (many small parallel strips → low write latency), a backlogged
writer drops to k=1 (one big strip + parity → max throughput), which is
exactly the paper's throughput-delay trade-off transplanted to checkpoints.

``AsyncCheckpointer`` overlaps encode+write with training steps.
"""

from __future__ import annotations

import dataclasses
import json
import queue as _queue
import threading
import zlib

import jax
import numpy as np

from repro.core.controller import Policy, StaticPolicy
from repro.kernels.gf2mm import ops as rsops
from repro.storage.backend import ObjectStore, StorageError


@dataclasses.dataclass
class CodingPlan:
    n: int
    k: int


def _leaf_paths(tree) -> list[tuple[str, np.ndarray]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(str(p.key) if hasattr(p, "key") else str(p.idx) for p in path)
        out.append((name, np.asarray(leaf)))
    return out


def save_checkpoint(
    store: ObjectStore,
    prefix: str,
    step: int,
    tree,
    *,
    policy: Policy | None = None,
    n_max: int = 8,
    k_max: int = 4,
    pending_hint: int = 0,
) -> dict:
    """Write one erasure-coded checkpoint; returns the manifest."""
    policy = policy or StaticPolicy(n_max, k_max)
    leaves = _leaf_paths(tree)
    manifest = {"step": step, "leaves": {}, "format": 1}
    for i, (name, arr) in enumerate(leaves):
        # Backlog signal = externally pending checkpoint snapshots (the
        # async writer's queue depth) — the TOFEC queue-length analogue.
        # An idle writer chunks finely (low latency); a backlogged one
        # degrades toward k=1 (max throughput), Corollary 1 verbatim.
        q = pending_hint
        n, k = policy.select(q=q, idle=max(0, n_max - 1), cls_id=0)
        n = min(n, n_max)
        k = min(k, k_max, max(1, n))
        payload = arr.tobytes()
        strips = rsops.encode_blob(np.frombuffer(payload, np.uint8), n=n, k=k)
        for si in range(n):
            store.put(f"{prefix}/step{step}/{name}/strip{si}", strips[si].tobytes())
        manifest["leaves"][name] = {
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "n": int(n),
            "k": int(k),
            "bytes": len(payload),
            "strip_bytes": int(strips.shape[1]),
            "crc": zlib.crc32(payload) & 0xFFFFFFFF,
        }
    store.put(f"{prefix}/step{step}/MANIFEST", json.dumps(manifest).encode())
    store.put(f"{prefix}/LATEST", str(step).encode())
    return manifest


def latest_step(store: ObjectStore, prefix: str) -> int | None:
    try:
        return int(store.get(f"{prefix}/LATEST").decode())
    except StorageError:
        return None


def restore_checkpoint(store: ObjectStore, prefix: str, step: int, tree_like) -> object:
    """Rebuild a pytree matching ``tree_like`` from any-k-of-n strips."""
    manifest = json.loads(store.get(f"{prefix}/step{step}/MANIFEST").decode())
    leaves = _leaf_paths(tree_like)
    out_leaves = []
    for name, like in leaves:
        meta = manifest["leaves"][name]
        n, k, nbytes = meta["n"], meta["k"], meta["bytes"]
        got: dict[int, bytes] = {}
        for si in range(n):
            if len(got) >= k:
                break
            try:
                got[si] = store.get(f"{prefix}/step{step}/{name}/strip{si}")
            except StorageError:
                continue
        if len(got) < k:
            raise StorageError(
                f"{name}: only {len(got)}/{k} strips survive — unrecoverable"
            )
        present = tuple(sorted(got))[:k]
        strips = np.stack(
            [np.frombuffer(got[si], np.uint8) for si in present]
        )
        payload = rsops.decode_blob(strips, present, n=n, k=k, payload_len=nbytes)
        if (zlib.crc32(payload.tobytes()) & 0xFFFFFFFF) != meta["crc"]:
            raise StorageError(f"{name}: checksum mismatch after decode")
        arr = np.frombuffer(payload.tobytes(), dtype=meta["dtype"]).reshape(meta["shape"])
        out_leaves.append(arr)
    treedef = jax.tree_util.tree_structure(tree_like)
    return jax.tree_util.tree_unflatten(treedef, out_leaves)


class AsyncCheckpointer:
    """Background checkpoint writer: snapshot on submit, write off-thread.

    ``submit`` copies device arrays to host (blocking only on transfer),
    then a worker thread encodes + writes. ``wait()`` drains the queue.
    """

    def __init__(self, store: ObjectStore, prefix: str, *, policy: Policy | None = None):
        self.store = store
        self.prefix = prefix
        self.policy = policy
        self._q: _queue.Queue = _queue.Queue()
        self._err: Exception | None = None
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    def submit(self, step: int, tree) -> None:
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        self._q.put((step, host_tree))

    def _loop(self):
        while True:
            item = self._q.get()
            try:
                if item is None:
                    return
                step, tree = item
                save_checkpoint(
                    self.store, self.prefix, step, tree,
                    policy=self.policy, pending_hint=self._q.qsize(),
                )
            except Exception as e:  # pragma: no cover
                self._err = e
            finally:
                self._q.task_done()

    def wait(self):
        """Block until all submitted checkpoints are durable."""
        self._q.join()
        if self._err:
            raise self._err

    def close(self):
        self._q.put(None)
        self._thread.join()
        if self._err:
            raise self._err
