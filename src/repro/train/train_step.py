"""Jittable train/eval steps with full sharding specs.

``make_train_step(arch)`` returns (fn, in_shardings, out_shardings) builders
usable both for the real trainer and the AOT dry-run (lower + compile on
ShapeDtypeStructs). Gradients all-reduce in bf16 (compression) and the AdamW
math runs in fp32 against fp32 moments (ZeRO-sharded alongside params).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.registry import Arch
from repro.models.sharding import spec_for
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state


def batch_logical_axes(cfg):
    axes = {"tokens": ("batch", None), "labels": ("batch", None)}
    if cfg.family == "encdec":
        axes["frames"] = ("batch", None, None)
    if cfg.family == "vlm":
        axes["patches"] = ("batch", None, None)
    return axes


def param_specs(arch: Arch, params_shapes):
    """PartitionSpec tree for params (requires an active axis_rules ctx)."""
    logical = arch.logical_axes()
    return jax.tree.map(
        lambda sds, lg: spec_for(tuple(sds.shape), tuple(lg)),
        params_shapes,
        logical,
        is_leaf=lambda x: isinstance(x, tuple) and (len(x) == 0 or isinstance(x[0], (str, type(None)))),
    )


def opt_state_specs(p_specs):
    return {"m": p_specs, "v": p_specs, "step": P()}


def make_train_step(arch: Arch, opt_cfg: AdamWConfig | None = None):
    opt_cfg = opt_cfg or AdamWConfig()
    accum = max(1, arch.cfg.grad_accum)

    def train_step(params, opt_state, batch):
        if accum == 1:
            def loss_fn(p):
                return arch.train_loss(p, batch)

            loss, grads = jax.value_and_grad(loss_fn)(params)
        else:
            # §Perf microbatching: scan over `accum` microbatches, keeping
            # only one microbatch's activations live at a time. Gradients
            # accumulate in the param dtype (bf16 — documented compression).
            def split(x):
                B = x.shape[0]
                return x.reshape(accum, B // accum, *x.shape[1:])

            micro = {k: split(v) for k, v in batch.items()}

            def body(carry, mb):
                g_acc, loss_acc = carry
                loss, grads = jax.value_and_grad(lambda p: arch.train_loss(p, mb))(params)
                g_acc = jax.tree.map(lambda a, g: a + g.astype(a.dtype), g_acc, grads)
                return (g_acc, loss_acc + loss), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype), params)
            (grads, loss_sum), _ = jax.lax.scan(body, (g0, jnp.float32(0.0)), micro)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = loss_sum / accum
        grads = jax.tree.map(lambda g, p: g.astype(p.dtype), grads, params)
        new_params, new_opt, metrics = adamw_update(params, grads, opt_state, opt_cfg)
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    return train_step


def make_eval_step(arch: Arch):
    def eval_step(params, batch):
        return arch.train_loss(params, batch)

    return eval_step


def abstract_state(arch: Arch, rng=None):
    """ShapeDtypeStruct trees for (params, opt_state) without allocation."""
    rng = rng if rng is not None else jax.random.key(0)
    params_shapes = jax.eval_shape(lambda: arch.init(rng))
    opt_shapes = jax.eval_shape(lambda: init_opt_state(params_shapes))
    return params_shapes, opt_shapes
