"""Shared neural building blocks (pure JAX, functional params-as-pytrees).

Conventions:
  * params are nested dicts of jnp arrays; init fns take an `rng` and shapes.
  * activations flow as (batch, seq, d_model) in cfg.dtype; layernorm/softmax
    accumulate in fp32.
  * attention is GQA with chunked online-softmax (flash-style, pure JAX) for
    train/prefill, plain cached attention for decode.
"""

from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.sharding import constrain


def dt(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def init_dense(rng, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(rng, (d_in, d_out), jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_rmsnorm(d: int, dtype):
    return {"scale": jnp.zeros((d,), dtype)}


def rmsnorm(params, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    normed = xf * jax.lax.rsqrt(var + eps)
    # gemma-style (1 + scale); scale initialized to zeros.
    return (normed * (1.0 + params["scale"].astype(jnp.float32))).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32) * (math.log(theta) / half))
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, half)
    cos = jnp.cos(ang)[..., :, None, :]  # (..., seq, 1, half)
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def init_attention(rng, cfg: ModelConfig):
    d, hd = cfg.d_model, cfg.hd
    ks = jax.random.split(rng, 4)
    p = {
        "wq": init_dense(ks[0], d, cfg.n_heads * hd, dt(cfg)),
        "wk": init_dense(ks[1], d, cfg.n_kv_heads * hd, dt(cfg)),
        "wv": init_dense(ks[2], d, cfg.n_kv_heads * hd, dt(cfg)),
        "wo": init_dense(ks[3], cfg.n_heads * hd, d, dt(cfg)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), dt(cfg))
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), dt(cfg))
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), dt(cfg))
    return p


def attention_logical_axes(cfg: ModelConfig):
    p = {
        "wq": ("embed", "heads"),
        "wk": ("embed", "kv_heads"),
        "wv": ("embed", "kv_heads"),
        "wo": ("heads", "embed"),
    }
    if cfg.qkv_bias:
        p.update({"bq": ("heads",), "bk": ("kv_heads",), "bv": ("kv_heads",)})
    return p


def use_weight(cfg: ModelConfig, w, *axes):
    """§Perf weight_gather: constrain a stored (FSDP-sharded) weight to its
    compute layout (embed axis gathered) right before the contraction."""
    if not cfg.weight_gather:
        return w
    return constrain(w, *axes)


def _softcap(x, cap):
    return cap * jnp.tanh(x / cap) if cap else x


def _project_qkv(params, cfg: ModelConfig, x, positions):
    B, S, _ = x.shape
    hd = cfg.hd
    q = x @ use_weight(cfg, params["wq"], None, "heads")
    k = x @ use_weight(cfg, params["wk"], None, "kv_heads")
    v = x @ use_weight(cfg, params["wv"], None, "kv_heads")
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = q.reshape(B, S, cfg.n_heads, hd)
    k = k.reshape(B, S, cfg.n_kv_heads, hd)
    v = v.reshape(B, S, cfg.n_kv_heads, hd)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q = constrain(q, "batch", None, "heads", None)
    k = constrain(k, "batch", None, "kv_heads", None)
    v = constrain(v, "batch", None, "kv_heads", None)
    return q, k, v


def _block_attn(q, k, v, qpos, kpos, scale, softcap, causal, window):
    """One (q-chunk × kv-chunk) block. q: (B,qc,Hkv,G,hd), k/v: (B,kc,Hkv,hd).

    Returns (scores_exp (B,Hkv,G,qc,kc) numerator terms, row max, row sum)
    in the online-softmax decomposition.
    """
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q, k, preferred_element_type=jnp.float32)
    s = _softcap(s * scale, softcap)
    dqk = qpos[:, None] - kpos[None, :]  # (qc, kc)
    mask = (kpos >= 0)[None, :]  # padded kv positions carry kpos < 0
    if causal:
        mask = mask & (dqk >= 0)
    if window is not None:
        mask = mask & (dqk < window)
    s = jnp.where(mask[None, None, None], s, -1e30)
    m = jnp.max(s, axis=-1)  # (B,Hkv,G,qc)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)
    return o, m, l


def chunked_attention(
    cfg: ModelConfig,
    q: jax.Array,  # (B, Sq, H, hd)
    k: jax.Array,  # (B, Skv, Hkv, hd)
    v: jax.Array,
    *,
    causal: bool,
    window: int | None,
    softcap: float | None,
    q_offset: int = 0,
) -> jax.Array:
    """Flash-style online-softmax attention, scanned over q and kv chunks.

    Memory per step is O(q_chunk × kv_chunk). With ``window`` set, only the
    banded kv range [q_hi − window − qc, q_hi) is sliced per q-chunk, making
    SWA linear in sequence length.
    """
    B, Sq, H, hd = q.shape
    Sq_real = Sq
    Skv = k.shape[1]
    Hkv = k.shape[2]
    G = H // Hkv
    scale = 1.0 / math.sqrt(hd)
    qc = min(cfg.attn_q_chunk, Sq)
    kc = min(cfg.attn_kv_chunk, Skv)
    if Sq % qc != 0:  # pad queries; outputs trimmed at the end
        pad = qc * -(-Sq // qc) - Sq
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Sq += pad
    kpos_all = jnp.arange(Skv)
    if Skv % kc != 0:  # pad keys; kpos < 0 masks them out in _block_attn
        pad = kc * -(-Skv // kc) - Skv
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kpos_all = jnp.concatenate([kpos_all, jnp.full((pad,), -(2**30))])
        Skv += pad
    nq = Sq // qc
    q = q.reshape(B, nq, qc, Hkv, G, hd)
    nk = Skv // kc
    band = window is not None and window + qc < Skv
    if band:
        # Banded SWA: slice [hi − (window + qc) … hi) of kv per q-chunk.
        span_k = -(-(window + qc) // kc) * kc
    else:
        span_k = Skv

    def per_q_chunk(carry, qi):
        qblk = jax.lax.dynamic_index_in_dim(q, qi, axis=1, keepdims=False)
        qpos = q_offset + qi * qc + jnp.arange(qc)

        if band:
            hi = q_offset + (qi + 1) * qc
            start = jnp.clip(hi - span_k, 0, Skv - span_k)
            kblk_all = jax.lax.dynamic_slice_in_dim(k, start, span_k, axis=1)
            vblk_all = jax.lax.dynamic_slice_in_dim(v, start, span_k, axis=1)
            kpos_band = start + jnp.arange(span_k)
        else:
            kblk_all, vblk_all, kpos_band = k, v, kpos_all

        nkb = span_k // kc

        def per_kv_chunk(acc, ki):
            o_acc, m_acc, l_acc = acc
            kblk = jax.lax.dynamic_slice_in_dim(kblk_all, ki * kc, kc, axis=1)
            vblk = jax.lax.dynamic_slice_in_dim(vblk_all, ki * kc, kc, axis=1)
            kpos = jax.lax.dynamic_slice_in_dim(kpos_band, ki * kc, kc, axis=0)
            o, m, l = _block_attn(qblk, kblk, vblk, qpos, kpos, scale, softcap, causal, window)
            m_new = jnp.maximum(m_acc, m)
            c_old = jnp.exp(m_acc - m_new)
            c_new = jnp.exp(m - m_new)
            l_acc = l_acc * c_old + l * c_new
            o_acc = (
                o_acc * c_old.transpose(0, 3, 1, 2)[..., None]
                + o * c_new.transpose(0, 3, 1, 2)[..., None]
            )
            return (o_acc, m_new, l_acc), None

        o0 = jnp.zeros((B, qc, Hkv, G, hd), jnp.float32)
        m0 = jnp.full((B, Hkv, G, qc), -1e30, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, qc), jnp.float32)
        (o, m, l), _ = jax.lax.scan(
            per_kv_chunk, (o0, m0, l0), jnp.arange(nkb), unroll=cfg.scan_unroll
        )
        out = o / jnp.maximum(l.transpose(0, 3, 1, 2)[..., None], 1e-30)
        return carry, out.astype(v.dtype)

    _, outs = jax.lax.scan(per_q_chunk, None, jnp.arange(nq), unroll=cfg.scan_unroll)
    # outs: (nq, B, qc, Hkv, G, hd) → (B, Sq, H, hd), trimmed of q padding
    out = jnp.moveaxis(outs, 0, 1).reshape(B, Sq, Hkv, G, hd).reshape(B, Sq, H, hd)
    return out[:, :Sq_real]


def attention(
    params,
    cfg: ModelConfig,
    x: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    positions: jax.Array | None = None,
    kv_override: tuple[jax.Array, jax.Array] | None = None,
) -> jax.Array:
    """Full attention sublayer for train/prefill. x: (B, S, d)."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :].astype(jnp.int32)
    q, k, v = _project_qkv(params, cfg, x, positions)
    if kv_override is not None:  # cross-attention (whisper decoder)
        k, v = kv_override
    out = chunked_attention(
        cfg, q, k, v, causal=causal, window=window, softcap=cfg.attn_softcap
    )
    out = out.reshape(B, S, cfg.n_heads * cfg.hd)
    return out @ use_weight(cfg, params["wo"], "heads", None)


def decode_attention(
    params,
    cfg: ModelConfig,
    x: jax.Array,  # (B, 1, d)
    cache_k: jax.Array,  # (B, Smax, Hkv, hd) — ring buffer when Smax < ctx
    cache_v: jax.Array,
    slot_pos: jax.Array,  # (Smax,) int32 absolute position per slot (−big = empty)
    pos: jax.Array,  # scalar int32: position of the new token
    *,
    window: int | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One decode step with a (possibly ring-buffer) KV cache.

    Returns (out, new_k, new_v, new_slot_pos). The new token is written at
    slot ``pos % Smax``; masking uses per-slot absolute positions, so a
    sliding-window cache of size `window` supports unbounded contexts
    (long_500k runs with O(window) memory).
    """
    B = x.shape[0]
    hd = cfg.hd
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(params, cfg, x, positions)
    Smax = cache_k.shape[1]
    slot = jnp.mod(pos, Smax)
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k_new, slot, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v_new, slot, axis=1)
    slot_pos = jax.lax.dynamic_update_slice_in_dim(
        slot_pos, jnp.full((1,), pos, slot_pos.dtype), slot, axis=0
    )
    if B == 1:  # long-context: cache sharded along sequence, not heads
        cache_k = constrain(cache_k, None, "kv_seq", None, None)
        cache_v = constrain(cache_v, None, "kv_seq", None, None)
    elif cfg.decode_cache_seq_shard:
        cache_k = constrain(cache_k, "batch", "kv_seq", None, None)
        cache_v = constrain(cache_v, "batch", "kv_seq", None, None)
    else:
        cache_k = constrain(cache_k, "batch", None, "kv_heads", None)
        cache_v = constrain(cache_v, "batch", None, "kv_heads", None)
    Hkv, G = cfg.n_kv_heads, cfg.q_per_kv
    qh = q.reshape(B, 1, Hkv, G, hd)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qh, cache_k, preferred_element_type=jnp.float32)
    s = _softcap(s / math.sqrt(hd), cfg.attn_softcap)
    mask = slot_pos <= pos
    mask &= slot_pos >= 0
    if window is not None:
        mask &= slot_pos > pos - window
    s = jnp.where(mask[None, None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(cache_v.dtype), cache_v)
    out = o.reshape(B, 1, cfg.n_heads * hd) @ use_weight(cfg, params["wo"], "heads", None)
    return out, cache_k, cache_v, slot_pos


def fill_cache_from_prefill(
    k: jax.Array, v: jax.Array, Smax: int
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Arrange the last Smax of (B, S, Hkv, hd) prefill K/V into ring slots."""
    B, S, Hkv, hd = k.shape
    take = min(S, Smax)
    positions = jnp.arange(S - take, S)
    slots = jnp.mod(positions, Smax)
    ck = jnp.zeros((B, Smax, Hkv, hd), k.dtype).at[:, slots].set(k[:, S - take :])
    cv = jnp.zeros((B, Smax, Hkv, hd), v.dtype).at[:, slots].set(v[:, S - take :])
    sp = jnp.full((Smax,), -(2**30), jnp.int32).at[slots].set(positions.astype(jnp.int32))
    return ck, cv, sp


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def init_mlp(rng, cfg: ModelConfig, d_ff: int | None = None):
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(rng, 3)
    p = {
        "wi": init_dense(ks[0], cfg.d_model, d_ff, dt(cfg)),
        "wo": init_dense(ks[1], d_ff, cfg.d_model, dt(cfg)),
    }
    if cfg.glu:
        p["wg"] = init_dense(ks[2], cfg.d_model, d_ff, dt(cfg))
    return p


def mlp_logical_axes(cfg: ModelConfig):
    p = {"wi": ("embed", "ff"), "wo": ("ff", "embed")}
    if cfg.glu:
        p["wg"] = ("embed", "ff")
    return p


def _act(cfg: ModelConfig, x):
    if cfg.mlp_act == "gelu":
        return jax.nn.gelu(x)
    return jax.nn.silu(x)


def mlp(params, cfg: ModelConfig, x):
    h = x @ use_weight(cfg, params["wi"], None, "ff")
    if cfg.glu:
        h = _act(cfg, x @ use_weight(cfg, params["wg"], None, "ff")) * h
    else:
        h = _act(cfg, h)
    h = constrain(h, "batch", None, "ff")
    return h @ use_weight(cfg, params["wo"], "ff", None)


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def init_embedding(rng, cfg: ModelConfig):
    ks = jax.random.split(rng, 2)
    return {
        "embed": (jax.random.normal(ks[0], (cfg.vocab, cfg.d_model), jnp.float32)).astype(dt(cfg)),
        "head": init_dense(ks[1], cfg.d_model, cfg.vocab, dt(cfg)),
    }


def embedding_logical_axes(cfg: ModelConfig):
    return {"embed": ("vocab", "embed"), "head": ("embed", "vocab")}


def embed(params, cfg: ModelConfig, tokens):
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt(cfg))
    return x * math.sqrt(cfg.d_model)


def logits(params, cfg: ModelConfig, x):
    out = x @ use_weight(cfg, params["head"], None, "vocab")
    out = _softcap(out.astype(jnp.float32), cfg.logit_softcap)
    return constrain(out, "batch", None, "vocab")


def cross_entropy(logit, labels):
    """Mean next-token CE. logit: (B,S,V) fp32, labels: (B,S) int32."""
    lse = jax.scipy.special.logsumexp(logit, axis=-1)
    gold = jnp.take_along_axis(logit, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)
