"""Emulated key-value object stores with the APIs the paper requires.

The paper's two access modes (§III):
  * Unique Key  — basic ``put`` / ``get`` (every store has these),
  * Shared Key  — "partial read" (:meth:`get_range`, S3 getObject+setRange)
                  and "partial write" (:meth:`upload_part` +
                  :meth:`complete_multipart`, S3 multipart upload).

Implementations: in-memory, file-backed, plus wrappers injecting latency
(from the §III-C delay model) and faults (lost objects / failed reads) used
by the erasure-coded checkpoint tests.
"""

from __future__ import annotations

import os
import threading
import time

import numpy as np

from repro.core.delay_model import DelayParams


class StorageError(KeyError):
    pass


class ObjectStore:
    """Abstract key-value store with ranged and multipart access."""

    def put(self, key: str, data: bytes) -> None:
        raise NotImplementedError

    def get(self, key: str) -> bytes:
        raise NotImplementedError

    def get_range(self, key: str, offset: int, length: int) -> bytes:
        blob = self.get(key)
        if offset < 0 or offset + length > len(blob):
            raise StorageError(f"range [{offset}, {offset + length}) outside {key}")
        return blob[offset : offset + length]

    def upload_part(self, key: str, part_id: int, data: bytes) -> None:
        raise NotImplementedError

    def complete_multipart(self, key: str, part_ids: list[int]) -> None:
        raise NotImplementedError

    def delete(self, key: str) -> None:
        raise NotImplementedError

    def exists(self, key: str) -> bool:
        raise NotImplementedError

    def keys(self) -> list[str]:
        raise NotImplementedError


class MemoryStore(ObjectStore):
    def __init__(self):
        self._objects: dict[str, bytes] = {}
        self._parts: dict[str, dict[int, bytes]] = {}
        self._lock = threading.Lock()

    def put(self, key, data):
        with self._lock:
            self._objects[key] = bytes(data)

    def get(self, key):
        with self._lock:
            try:
                return self._objects[key]
            except KeyError:
                raise StorageError(key) from None

    def upload_part(self, key, part_id, data):
        with self._lock:
            self._parts.setdefault(key, {})[part_id] = bytes(data)

    def complete_multipart(self, key, part_ids):
        with self._lock:
            parts = self._parts.pop(key, {})
            missing = [p for p in part_ids if p not in parts]
            if missing:
                raise StorageError(f"{key}: missing parts {missing}")
            self._objects[key] = b"".join(parts[p] for p in part_ids)

    def delete(self, key):
        with self._lock:
            self._objects.pop(key, None)
            self._parts.pop(key, None)

    def exists(self, key):
        with self._lock:
            return key in self._objects

    def keys(self):
        with self._lock:
            return sorted(self._objects)


class FileStore(ObjectStore):
    """Objects as files under a root dir; ranged reads via seek (no full
    object load — the point of partial-read APIs)."""

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self._lock = threading.Lock()

    def _path(self, key: str) -> str:
        safe = key.replace("/", "_")
        return os.path.join(self.root, safe)

    def put(self, key, data):
        tmp = self._path(key) + ".tmp"
        with open(tmp, "wb") as f:
            f.write(data)
        os.replace(tmp, self._path(key))

    def get(self, key):
        try:
            with open(self._path(key), "rb") as f:
                return f.read()
        except FileNotFoundError:
            raise StorageError(key) from None

    def get_range(self, key, offset, length):
        try:
            with open(self._path(key), "rb") as f:
                f.seek(offset)
                out = f.read(length)
        except FileNotFoundError:
            raise StorageError(key) from None
        if len(out) != length:
            raise StorageError(f"short read on {key}")
        return out

    def upload_part(self, key, part_id, data):
        self.put(f"{key}.part{part_id}", data)

    def complete_multipart(self, key, part_ids):
        chunks = []
        for p in part_ids:
            chunks.append(self.get(f"{key}.part{p}"))
        self.put(key, b"".join(chunks))
        for p in part_ids:
            self.delete(f"{key}.part{p}")

    def delete(self, key):
        try:
            os.remove(self._path(key))
        except FileNotFoundError:
            pass

    def exists(self, key):
        return os.path.exists(self._path(key))

    def keys(self):
        return sorted(os.listdir(self.root))


class LatencyStore(ObjectStore):
    """Injects §III-C task delays: sleep(Δ(B) + Exp(1/μ(B))) · time_scale.

    ``time_scale`` compresses emulated seconds to wall seconds so tests run
    fast while preserving relative timing (default 1 ms wall per emulated s).
    """

    def __init__(
        self,
        inner: ObjectStore,
        read_params: DelayParams,
        write_params: DelayParams | None = None,
        *,
        time_scale: float = 1e-3,
        seed: int = 0,
    ):
        self.inner = inner
        self.read_params = read_params
        self.write_params = write_params or read_params
        self.time_scale = time_scale
        self._rng = np.random.default_rng(seed)
        self._lock = threading.Lock()
        self.emulated_busy_s = 0.0  # accumulated emulated task time

    def _delay(self, params: DelayParams, nbytes: int) -> float:
        mb = nbytes / 2**20
        with self._lock:
            d = float(params.sample(self._rng, mb))
            self.emulated_busy_s += d
        return d

    def _sleep(self, d: float):
        if self.time_scale > 0:
            time.sleep(d * self.time_scale)

    def put(self, key, data):
        self._sleep(self._delay(self.write_params, len(data)))
        self.inner.put(key, data)

    def get(self, key):
        out = self.inner.get(key)
        self._sleep(self._delay(self.read_params, len(out)))
        return out

    def get_range(self, key, offset, length):
        out = self.inner.get_range(key, offset, length)
        self._sleep(self._delay(self.read_params, length))
        return out

    def upload_part(self, key, part_id, data):
        self._sleep(self._delay(self.write_params, len(data)))
        self.inner.upload_part(key, part_id, data)

    def complete_multipart(self, key, part_ids):
        self.inner.complete_multipart(key, part_ids)

    def delete(self, key):
        self.inner.delete(key)

    def exists(self, key):
        return self.inner.exists(key)

    def keys(self):
        return self.inner.keys()


class FaultyStore(ObjectStore):
    """Drops reads with probability p_fail and can lose objects outright —
    the failure model the erasure-coded checkpoint layer must survive."""

    def __init__(self, inner: ObjectStore, *, p_fail: float = 0.0, seed: int = 0):
        self.inner = inner
        self.p_fail = p_fail
        self._rng = np.random.default_rng(seed)
        self._lost: set[str] = set()
        self._lock = threading.Lock()

    def lose_object(self, key: str) -> None:
        with self._lock:
            self._lost.add(key)

    def _maybe_fail(self, key: str):
        with self._lock:
            if key in self._lost:
                raise StorageError(f"{key}: object lost")
            if self.p_fail > 0 and self._rng.random() < self.p_fail:
                raise StorageError(f"{key}: transient read failure")

    def put(self, key, data):
        self.inner.put(key, data)
        with self._lock:
            self._lost.discard(key)

    def get(self, key):
        self._maybe_fail(key)
        return self.inner.get(key)

    def get_range(self, key, offset, length):
        self._maybe_fail(key)
        return self.inner.get_range(key, offset, length)

    def upload_part(self, key, part_id, data):
        self.inner.upload_part(key, part_id, data)

    def complete_multipart(self, key, part_ids):
        self.inner.complete_multipart(key, part_ids)

    def delete(self, key):
        self.inner.delete(key)

    def exists(self, key):
        with self._lock:
            if key in self._lost:
                return False
        return self.inner.exists(key)

    def keys(self):
        with self._lock:
            lost = set(self._lost)
        return [k for k in self.inner.keys() if k not in lost]
