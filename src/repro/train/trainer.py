"""Fault-tolerant training loop: checkpoint/restart, async erasure-coded
checkpoints, deterministic data, straggler-aware I/O.

Runs on whatever mesh is active (1-device CPU for the examples/tests; the
production meshes in the dry-run). Restart-from-failure is exercised in
tests by killing and re-building the trainer mid-run: state comes back from
any k-of-n checkpoint strips and the data pipeline resumes at the recorded
step with bit-identical batches.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.ckpt.checkpoint import (
    AsyncCheckpointer,
    latest_step,
    restore_checkpoint,
)
from repro.core.controller import Policy
from repro.data.pipeline import SyntheticTokens
from repro.models.config import ShapeSpec
from repro.models.registry import Arch
from repro.storage.backend import ObjectStore
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_step import make_train_step


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    log_every: int = 10
    seed: int = 0
    opt: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)


class Trainer:
    def __init__(
        self,
        arch: Arch,
        shape: ShapeSpec,
        store: ObjectStore,
        *,
        cfg: TrainerConfig | None = None,
        ckpt_prefix: str = "ckpt",
        ckpt_policy: Policy | None = None,
    ):
        self.arch = arch
        self.shape = shape
        self.store = store
        self.cfg = cfg or TrainerConfig()
        self.ckpt_prefix = ckpt_prefix
        self.data = SyntheticTokens(arch.cfg, shape, seed=self.cfg.seed)
        self.step_fn = jax.jit(make_train_step(arch, self.cfg.opt))
        self.ckpt = AsyncCheckpointer(store, ckpt_prefix, policy=ckpt_policy)
        self.metrics_log: list[dict] = []

        resume = latest_step(store, ckpt_prefix)
        if resume is not None:
            params_like = jax.eval_shape(lambda: arch.init(jax.random.key(self.cfg.seed)))
            params_like = jax.tree.map(lambda s: np.zeros(s.shape, s.dtype), params_like)
            opt_like = jax.tree.map(
                lambda a: np.zeros(a.shape, np.float32), params_like
            )
            state_like = {
                "params": params_like,
                "opt": {"m": opt_like, "v": opt_like, "step": np.int32(0)},
            }
            state = restore_checkpoint(store, ckpt_prefix, resume, state_like)
            self.params = jax.tree.map(jax.numpy.asarray, state["params"])
            self.opt_state = jax.tree.map(jax.numpy.asarray, state["opt"])
            self.start_step = resume
        else:
            self.params = arch.init(jax.random.key(self.cfg.seed))
            self.opt_state = init_opt_state(self.params)
            self.start_step = 0

    def run(self, steps: int | None = None) -> list[dict]:
        steps = steps if steps is not None else self.cfg.total_steps
        t0 = time.monotonic()
        end = min(self.start_step + steps, self.cfg.total_steps)
        for step in range(self.start_step, end):
            batch = {k: jax.numpy.asarray(v) for k, v in self.data.batch_at(step).items()}
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch
            )
            if (step + 1) % self.cfg.log_every == 0 or step == end - 1:
                rec = {
                    "step": step + 1,
                    "loss": float(metrics["loss"]),
                    "grad_norm": float(metrics["grad_norm"]),
                    "wall_s": time.monotonic() - t0,
                }
                self.metrics_log.append(rec)
            if (step + 1) % self.cfg.ckpt_every == 0 or step == end - 1:
                self.ckpt.submit(step + 1, {"params": self.params, "opt": self.opt_state})
        self.ckpt.wait()
        self.start_step = end
        return self.metrics_log
