"""Unit + property tests for GF(256), Cauchy RS, and the shared-key layout."""

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.coding import gf256, layout, rs


# ---------------------------------------------------------------------------
# GF(256)
# ---------------------------------------------------------------------------


def test_gf_tables_bijective():
    exp = gf256.exp_table()
    assert sorted(set(int(v) for v in exp[:255])) == list(range(1, 256))


@given(st.integers(1, 255), st.integers(1, 255))
def test_gf_mul_log_consistency(a, b):
    exp, log = gf256.exp_table(), gf256.log_table()
    got = int(gf256.mul(np.uint8(a), np.uint8(b)))
    want = int(exp[(int(log[a]) + int(log[b])) % 255])
    assert got == want


@given(st.integers(0, 255), st.integers(0, 255), st.integers(0, 255))
def test_gf_mul_distributes_over_xor(a, b, c):
    left = int(gf256.mul(np.uint8(a), np.uint8(b ^ c)))
    right = int(gf256.mul(np.uint8(a), np.uint8(b))) ^ int(gf256.mul(np.uint8(a), np.uint8(c)))
    assert left == right


@given(st.integers(1, 255))
def test_gf_inverse(a):
    assert int(gf256.mul(np.uint8(a), gf256.inv(np.uint8(a)))) == 1


def test_gf_mat_inv_roundtrip():
    rng = np.random.default_rng(0)
    for n in (1, 2, 3, 5, 8):
        # Cauchy matrices are always invertible.
        m = rs.cauchy_parity_matrix(2 * n, n)[:n, :n]
        minv = gf256.mat_inv(m)
        assert np.array_equal(gf256.matmul(m, minv), np.eye(n, dtype=np.uint8))


def test_bitmatrix_matches_field_mul():
    rng = np.random.default_rng(1)
    for c in [0, 1, 2, 3, 7, 29, 128, 255]:
        M = gf256.bitmatrix(c)
        for v in rng.integers(0, 256, size=16):
            bits = gf256.bytes_to_bitplanes(np.array([[v]], dtype=np.uint8))[:, 0]
            out_bits = (M @ bits) % 2
            got = gf256.bitplanes_to_bytes(out_bits.astype(np.uint8)[:, None])[0, 0]
            assert int(got) == int(gf256.mul(np.uint8(c), np.uint8(v)))


@given(st.integers(1, 6), st.integers(1, 64))
@settings(max_examples=20, deadline=None)
def test_bitplane_roundtrip(k, B):
    rng = np.random.default_rng(k * 1000 + B)
    data = rng.integers(0, 256, size=(k, B), dtype=np.uint8)
    planes = gf256.bytes_to_bitplanes(data)
    assert planes.shape == (8 * k, B)
    assert np.array_equal(gf256.bitplanes_to_bytes(planes), data)


def test_expand_bitmatrix_equals_gf_matmul():
    rng = np.random.default_rng(2)
    n, k, B = 6, 3, 32
    G = rs.generator_matrix(n, k)
    D = rng.integers(0, 256, size=(k, B), dtype=np.uint8)
    want = gf256.matmul(G, D)
    G2 = gf256.expand_bitmatrix(G)
    D2 = gf256.bytes_to_bitplanes(D)
    got = gf256.bitplanes_to_bytes(((G2.astype(np.int64) @ D2.astype(np.int64)) % 2).astype(np.uint8))
    assert np.array_equal(got, want)


# ---------------------------------------------------------------------------
# Reed-Solomon
# ---------------------------------------------------------------------------


@given(
    st.integers(1, 12).flatmap(
        lambda k: st.tuples(st.just(k), st.integers(k, min(24, 2 * k + 6)))
    ),
    st.integers(1, 80),
    st.integers(0, 2**31 - 1),
)
@settings(max_examples=40, deadline=None)
def test_rs_any_k_of_n_decodes(kn, B, seed):
    k, n = kn
    rng = np.random.default_rng(seed)
    data = rng.integers(0, 256, size=(k, B), dtype=np.uint8)
    coded = rs.encode(data, n, k)
    assert np.array_equal(coded[:k], data)  # systematic
    present = tuple(sorted(rng.choice(n, size=k, replace=False).tolist()))
    got = rs.decode(coded[list(present)], present, n, k)
    assert np.array_equal(got, data)


def test_rs_rejects_bad_args():
    with pytest.raises(ValueError):
        rs.encode(np.zeros((3, 4), np.uint8), n=2, k=3)
    with pytest.raises(ValueError):
        rs.decode_matrix(6, 3, (0, 1))
    with pytest.raises(ValueError):
        rs.decode_matrix(6, 3, (0, 1, 7))


def test_mds_code_wrapper():
    code = rs.MDSCode(n=6, k=3)
    assert code.r == 2.0
    data = np.arange(3 * 10, dtype=np.uint8).reshape(3, 10)
    coded = code.encode(data)
    assert np.array_equal(code.decode(coded[[1, 3, 5]], (1, 3, 5)), data)


# ---------------------------------------------------------------------------
# Shared-key layout (Fig.3 semantics)
# ---------------------------------------------------------------------------


def test_fig3_example():
    """3MB file, 0.5MB strips, (12, 6) strip code; usable as (2,1), (4,2), (6,3), (12,6)."""
    lay = layout.SharedKeyLayout(K=6, r=2, strip_bytes=512)  # scaled-down strip
    assert lay.N == 12
    assert lay.supported_k() == [1, 2, 3, 6]
    n_max, k, m = lay.code_for_k(1)
    assert (n_max, m) == (2, 6)
    n_max, k, m = lay.code_for_k(3)
    assert (n_max, m) == (6, 2)
    # (2,1): chunk 0 covers strips 0-5 (bytes [0, 6*512)), chunk 1 strips 6-11.
    assert lay.chunk_range(1, 0) == (0, 6 * 512)
    assert lay.chunk_range(1, 1) == (6 * 512, 6 * 512)


@given(
    st.sampled_from([1, 2, 3, 4, 6]),
    st.integers(1, 3),
    st.integers(0, 2**31 - 1),
)
@settings(max_examples=30, deadline=None)
def test_layout_roundtrip_any_k_chunks(k, r, seed):
    rng = np.random.default_rng(seed)
    lay = layout.SharedKeyLayout(K=12, r=r, strip_bytes=64)
    payload = rng.integers(0, 256, size=lay.file_bytes - 17, dtype=np.uint8).tobytes()
    obj = lay.encode_file(payload)
    assert len(obj) == lay.object_bytes
    n_max, _, m = lay.code_for_k(k)
    picks = rng.choice(n_max, size=k, replace=False)
    chunks = {}
    for ci in picks:
        off, ln = lay.chunk_range(k, int(ci))
        chunks[int(ci)] = obj[off : off + ln]
    got = lay.reconstruct(k, chunks, payload_len=len(payload))
    assert got == payload


def test_layout_for_file_paper_params():
    lay = layout.layout_for_file(file_bytes=3 * 2**20, k_max=6, r_max=2)
    assert lay.K == 6 and lay.N == 12
    assert lay.strip_bytes == 2**19  # 0.5 MB strips as in Fig.3
