"""Decoder-only LM covering the dense / moe / vlm families.

Layer stack runs as ``lax.scan`` over stacked per-layer params with
``jax.checkpoint`` (remat) on the block body — compact HLO for the 40-cell
dry-run and bounded activation memory. Per-layer attention patterns
(gemma2 local/global alternation) ride along the scan as flag arrays.

Loss never materializes full (B, S, V) logits: the LM head + cross-entropy
run in sequence chunks (critical for vocab=256k at 1M tokens).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import layers as ly
from repro.models import moe as moe_mod
from repro.models.config import ModelConfig
from repro.models.sharding import constrain

AUX_LOSS_WEIGHT = 0.01
LOSS_CHUNK = 512


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _layer_windows(cfg: ModelConfig) -> list[int]:
    """Per-layer attention window (0 = full causal)."""
    out = []
    for i in range(cfg.n_layers):
        if cfg.sliding_window is not None:
            out.append(cfg.sliding_window)
        elif cfg.local_global_period and i % cfg.local_global_period == 0:
            out.append(cfg.local_window)
        else:
            out.append(0)
    return out


def init_block(rng, cfg: ModelConfig):
    ks = jax.random.split(rng, 4)
    p = {
        "ln1": ly.init_rmsnorm(cfg.d_model, ly.dt(cfg)),
        "attn": ly.init_attention(ks[0], cfg),
        "ln2": ly.init_rmsnorm(cfg.d_model, ly.dt(cfg)),
    }
    if cfg.n_experts:
        p["moe"] = moe_mod.init_moe(ks[1], cfg)
    else:
        p["mlp"] = ly.init_mlp(ks[2], cfg)
    return p


def block_logical_axes(cfg: ModelConfig):
    p = {
        "ln1": {"scale": (None,)},
        "attn": ly.attention_logical_axes(cfg),
        "ln2": {"scale": (None,)},
    }
    if cfg.n_experts:
        p["moe"] = moe_mod.moe_logical_axes(cfg)
    else:
        p["mlp"] = ly.mlp_logical_axes(cfg)
    return p


def init(rng, cfg: ModelConfig):
    k_emb, k_layers, k_vis = jax.random.split(rng, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    stacked = jax.vmap(lambda k: init_block(k, cfg))(layer_keys)
    params = {
        "embedding": ly.init_embedding(k_emb, cfg),
        "layers": stacked,
        "ln_f": ly.init_rmsnorm(cfg.d_model, ly.dt(cfg)),
    }
    if cfg.family == "vlm":
        params["vision_proj"] = ly.init_dense(k_vis, cfg.d_model, cfg.d_model, ly.dt(cfg))
    return params


def logical_axes(cfg: ModelConfig):
    """Pytree of logical-axis tuples matching init(); stacked layers get a
    leading None (layer axis unsharded)."""
    blk = block_logical_axes(cfg)
    stacked = jax.tree.map(lambda axes: (None, *axes), blk,
                           is_leaf=lambda x: isinstance(x, tuple))
    p = {
        "embedding": ly.embedding_logical_axes(cfg),
        "layers": stacked,
        "ln_f": {"scale": (None,)},
    }
    if cfg.family == "vlm":
        p["vision_proj"] = ("embed", None)
    return p


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _remat_policy(cfg: ModelConfig):
    if cfg.remat_policy == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    if cfg.remat_policy == "full":
        return jax.checkpoint_policies.everything_saveable
    return jax.checkpoint_policies.nothing_saveable


def _block_apply(cfg: ModelConfig, p, x, window):
    """One transformer block. window: traced int32 scalar (0 = full)."""
    h = ly.rmsnorm(p["ln1"], x)
    # Window is a per-layer static-pattern flag; lax.cond keeps one compiled
    # body per branch (local vs global) inside the scan.
    if cfg.sliding_window is not None:
        attn_out = ly.attention(p["attn"], cfg, h, causal=True, window=cfg.sliding_window)
    elif cfg.local_global_period:
        attn_out = jax.lax.cond(
            window > 0,
            lambda hh: ly.attention(p["attn"], cfg, hh, causal=True, window=cfg.local_window),
            lambda hh: ly.attention(p["attn"], cfg, hh, causal=True, window=None),
            h,
        )
    else:
        attn_out = ly.attention(p["attn"], cfg, h, causal=True, window=None)
    x = x + attn_out
    x = constrain(x, "batch", "seq_sp", None)
    h = ly.rmsnorm(p["ln2"], x)
    if cfg.n_experts:
        mlp_out, aux = moe_mod.moe_mlp(p["moe"], cfg, h)
    else:
        mlp_out, aux = ly.mlp(p["mlp"], cfg, h), jnp.float32(0.0)
    x = x + mlp_out
    x = constrain(x, "batch", "seq_sp", None)
    return x, aux


def backbone(params, cfg: ModelConfig, x):
    """(B, S, d) → (B, S, d) through the scanned layer stack."""
    windows = jnp.asarray(_layer_windows(cfg), jnp.int32)
    block = functools.partial(_block_apply, cfg)
    block = jax.checkpoint(block, policy=_remat_policy(cfg))

    def body(carry, inp):
        x, aux_sum = carry
        p, w = inp
        x, aux = block(p, x, w)
        return (x, aux_sum + aux), None

    (x, aux_sum), _ = jax.lax.scan(
        body, (x, jnp.float32(0.0)), (params["layers"], windows), unroll=cfg.scan_unroll
    )
    return ly.rmsnorm(params["ln_f"], x), aux_sum


def _inputs_to_embeddings(params, cfg: ModelConfig, batch):
    """tokens (+ patch embeddings for vlm) → (B, S_total, d)."""
    x = ly.embed(params["embedding"], cfg, batch["tokens"])
    if cfg.family == "vlm":
        vis = batch["patches"].astype(ly.dt(cfg)) @ params["vision_proj"]
        x = jnp.concatenate([vis, x], axis=1)
    return constrain(x, "batch", "seq_sp", None)


def chunked_ce_loss(params, cfg: ModelConfig, x, labels):
    """Scan the LM head + CE over sequence chunks; returns mean CE."""
    B, S, d = x.shape
    c = min(LOSS_CHUNK, S)
    assert S % c == 0
    nc = S // c
    xs = x.reshape(B, nc, c, d).swapaxes(0, 1)
    lbl = labels.reshape(B, nc, c).swapaxes(0, 1)

    def body(tot, inp):
        xc, lc = inp
        lg = ly.logits(params["embedding"], cfg, xc)  # (B, c, V) fp32
        lse = jax.scipy.special.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, lc[..., None], axis=-1)[..., 0]
        return tot + jnp.sum(lse - gold), None

    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    tot, _ = jax.lax.scan(body, jnp.float32(0.0), (xs, lbl), unroll=cfg.scan_unroll)
    return tot / (B * S)


def train_loss(params, cfg: ModelConfig, batch) -> jax.Array:
    """CE against pre-aligned next-token labels (+ MoE aux). Loss covers
    token positions only (vlm: the patch prefix is excluded)."""
    x = _inputs_to_embeddings(params, cfg, batch)
    x, aux = backbone(params, cfg, x)
    S_text = batch["tokens"].shape[1]
    x_text = x[:, -S_text:]
    loss = chunked_ce_loss(params, cfg, x_text, batch["labels"])
    return loss + AUX_LOSS_WEIGHT * aux


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------


def cache_len(cfg: ModelConfig, max_seq: int) -> int:
    if cfg.sliding_window is not None:
        return min(max_seq, cfg.sliding_window)
    return max_seq


def init_cache(cfg: ModelConfig, B: int, max_seq: int):
    Smax = cache_len(cfg, max_seq)
    L, Hkv, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((L, B, Smax, Hkv, hd), ly.dt(cfg)),
        "v": jnp.zeros((L, B, Smax, Hkv, hd), ly.dt(cfg)),
        "slot_pos": jnp.full((L, Smax), -(2**30), jnp.int32),
        "pos": jnp.int32(0),
    }


def decode_step(params, cfg: ModelConfig, token, cache):
    """token: (B, 1) int32 → (logits (B, 1, V) fp32, new cache)."""
    x = ly.embed(params["embedding"], cfg, token)
    windows = jnp.asarray(_layer_windows(cfg), jnp.int32)
    pos = cache["pos"]

    def body(x, inp):
        p, ck, cv, sp, w = inp
        h = ly.rmsnorm(p["ln1"], x)
        window = cfg.sliding_window
        if cfg.local_global_period:
            # decode: window flag folded into slot_pos masking via w.
            window = None
        out, ck, cv, sp = ly.decode_attention(
            p["attn"], cfg, h, ck, cv, sp, pos, window=window
        )
        if cfg.local_global_period:
            # local layers additionally mask to the window.
            out_local, ck2, cv2, sp2 = ly.decode_attention(
                p["attn"], cfg, h, ck, cv, sp, pos, window=cfg.local_window
            )
            is_local = w > 0
            out = jnp.where(is_local, out_local, out)
        x = x + out
        h = ly.rmsnorm(p["ln2"], x)
        if cfg.n_experts:
            mlp_out, _ = moe_mod.moe_mlp(p["moe"], cfg, h, dropless=True)
        else:
            mlp_out = ly.mlp(p["mlp"], cfg, h)
        return x + mlp_out, (ck, cv, sp)

    x, (ck, cv, sp) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"], cache["slot_pos"], windows),
        unroll=cfg.scan_unroll,
    )
    x = ly.rmsnorm(params["ln_f"], x)
    lg = ly.logits(params["embedding"], cfg, x)
    new_cache = {"k": ck, "v": cv, "slot_pos": sp, "pos": pos + 1}
    return lg, new_cache


def prefill(params, cfg: ModelConfig, batch, max_seq: int | None = None):
    """Run the full prompt, return (last-token logits, primed cache)."""
    x = _inputs_to_embeddings(params, cfg, batch)
    B, S, _ = x.shape
    max_seq = max_seq or S
    Smax = cache_len(cfg, max_seq)
    windows = jnp.asarray(_layer_windows(cfg), jnp.int32)

    def body(carry, inp):
        x, = carry
        p, w = inp
        h = ly.rmsnorm(p["ln1"], x)
        positions = jnp.arange(S)[None, :].astype(jnp.int32)
        q, k, v = ly._project_qkv(p["attn"], cfg, h, positions)
        if cfg.sliding_window is not None:
            window = cfg.sliding_window
        elif cfg.local_global_period:
            window = None  # global path; local layers masked below via cond
        else:
            window = None
        attn = ly.chunked_attention(
            cfg, q, k, v, causal=True, window=window, softcap=cfg.attn_softcap
        )
        if cfg.local_global_period:
            attn_local = ly.chunked_attention(
                cfg, q, k, v, causal=True, window=cfg.local_window, softcap=cfg.attn_softcap
            )
            attn = jnp.where(w > 0, attn_local, attn)
        out = attn.reshape(B, S, cfg.n_heads * cfg.hd) @ p["attn"]["wo"]
        x = x + out
        x = constrain(x, "batch", "seq_sp", None)
        h = ly.rmsnorm(p["ln2"], x)
        if cfg.n_experts:
            # Inference: dropless routing, so decode (S=1, can never drop)
            # reproduces prefill continuations token-exactly.
            mlp_out, _ = moe_mod.moe_mlp(p["moe"], cfg, h, dropless=True)
        else:
            mlp_out = ly.mlp(p["mlp"], cfg, h)
        x = x + mlp_out
        x = constrain(x, "batch", "seq_sp", None)
        ck, cv, sp = ly.fill_cache_from_prefill(k, v, Smax)
        return (x,), (ck, cv, sp)

    body = jax.checkpoint(body, policy=_remat_policy(cfg))
    (x,), (ck, cv, sp) = jax.lax.scan(
        body, (x,), (params["layers"], windows), unroll=cfg.scan_unroll
    )
    x = ly.rmsnorm(params["ln_f"], x)
    last = ly.logits(params["embedding"], cfg, x[:, -1:])
    cache = {"k": ck, "v": cv, "slot_pos": sp, "pos": jnp.int32(S)}
    return last, cache


def prefill_tokens(params, cfg: ModelConfig, tokens, max_seq: int | None = None):
    """Tokens-only prefill contract for the fused serving tower.

    ``tokens`` is a plain (B, S) int32 array — traceable, so the serving
    step can jit it together with the storage decode (no host batch-dict
    construction between decode and prefill). Non-token modalities get zero
    extras: the vlm family sees an all-zero patch grid (the serving tower
    has no image side yet).
    """
    batch = {"tokens": tokens}
    if cfg.family == "vlm":
        batch["patches"] = jnp.zeros(
            (tokens.shape[0], cfg.vision_patches, cfg.d_model), jnp.float32
        )
    return prefill(params, cfg, batch, max_seq)


def cache_logical_axes(cfg: ModelConfig, B: int):
    """Logical axes matching init_cache's structure. B==1 (long-context)
    shards the cache sequence over 'model'; otherwise batch+kv-heads."""
    if B == 1:  # long-context: shard the cache sequence, not heads
        kv = (None, None, "kv_seq", None, None)
    elif cfg.decode_cache_seq_shard:
        # §Perf: batch × sequence sharding = full 256-way cache split
        # (kv_heads rarely divide the model axis; the sequence always does).
        kv = (None, "batch", "kv_seq", None, None)
    else:
        kv = (None, "batch", None, "kv_heads", None)
    return {"k": kv, "v": kv, "slot_pos": (None, None), "pos": ()}
