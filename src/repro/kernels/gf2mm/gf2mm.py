"""Pallas TPU kernel: GF(2) matrix multiply (bit-matrix Reed-Solomon encode).

TPU adaptation of the paper's MDS encode/decode hot loop (DESIGN.md §3):
GF(256) arithmetic is lifted to GF(2) by expanding each field constant into
its 8x8 binary multiplication matrix. Encoding k data strips of B bytes with
an (n, k) generator then becomes

    C2[8(n-k), B] = ( G2[8(n-k), 8k] @ D2[8k, B] ) mod 2

where G2 is the expanded parity matrix and D2 the LSB-first bit-planes of
the data. A 0/1 matmul with int accumulation is exactly MXU-shaped; the
mod-2 runs in the epilogue on the VPU.

The kernel is a classic three-level tiled matmul:
  grid = (M / bm, N / bn, K / bk), K innermost ("arbitrary" semantics),
  fp32 VMEM scratch accumulator, bf16 MXU operands (0/1 values are exact in
  bf16; sums <= K <= 8*256 = 2048 are exact in fp32).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gf2mm_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k_tiles: int):
    """One (bm, bn) output tile; accumulates over the K grid dimension."""

    @pl.when(pl.program_id(2) == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.bfloat16)
    b = b_ref[...].astype(jnp.bfloat16)
    acc_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k_tiles - 1)
    def _epilogue():
        # mod-2 of an exact small-integer float: cast and mask the LSB.
        o_ref[...] = (acc_ref[...].astype(jnp.int32) & 1).astype(o_ref.dtype)


def gf2_matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 512,
    block_k: int = 128,
    out_dtype=jnp.uint8,
    interpret: bool = False,
) -> jax.Array:
    """(A @ B) mod 2 for 0/1 matrices. A: (M, K), B: (K, N) -> (M, N).

    Inputs may be any integer/float dtype holding 0/1 values. Dimensions are
    padded to tile multiples internally (zero rows/cols contribute nothing).
    """
    if a.ndim != 2 or b.ndim != 2 or a.shape[1] != b.shape[0]:
        raise ValueError(f"bad shapes {a.shape} @ {b.shape}")
    M, K = a.shape
    _, N = b.shape
    bm, bn, bk = block_m, block_n, block_k

    Mp, Kp, Np = (-(-M // bm) * bm, -(-K // bk) * bk, -(-N // bn) * bn)
    a_p = jnp.zeros((Mp, Kp), jnp.bfloat16).at[:M, :K].set(a.astype(jnp.bfloat16))
    b_p = jnp.zeros((Kp, Np), jnp.bfloat16).at[:K, :N].set(b.astype(jnp.bfloat16))

    n_k_tiles = Kp // bk
    grid = (Mp // bm, Np // bn, n_k_tiles)

    out = pl.pallas_call(
        functools.partial(_gf2mm_kernel, n_k_tiles=n_k_tiles),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, t: (i, t)),
            pl.BlockSpec((bk, bn), lambda i, j, t: (t, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, t: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a_p, b_p)
    return out[:M, :N]
