"""ShapeDtypeStruct stand-ins for every (arch × shape) dry-run cell.

``input_specs(arch, shape)`` returns (fn, args_specs, in_specs_tree,
out_shardings) ready for ``jax.jit(fn, ...).lower(*args_specs)`` — no device
allocation anywhere (weights, optimizer state and caches are all abstract).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.config import SHAPES, ModelConfig, ShapeSpec
from repro.models.registry import Arch, get
from repro.models.sharding import axis_rules, pure_dp_rules, spec_for
from repro.train.optimizer import init_opt_state
from repro.train.train_step import batch_logical_axes, make_train_step


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def batch_specs(cfg: ModelConfig, shape: ShapeSpec, kind: str) -> dict:
    B, S = shape.batch, shape.seq
    out = {"tokens": _sds((B, S), jnp.int32)}
    if kind == "train":
        out["labels"] = _sds((B, S), jnp.int32)
    if cfg.family == "encdec":
        out["frames"] = _sds((B, cfg.encoder_seq, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        out["patches"] = _sds((B, cfg.vision_patches, cfg.d_model), jnp.float32)
    return out


def _abstract_params(arch: Arch):
    return jax.eval_shape(lambda: arch.init(jax.random.key(0)))


def _specs_tree(mesh, shapes_tree, logical_tree):
    def one(sds, lg):
        return NamedSharding(mesh, spec_for(tuple(sds.shape), tuple(lg)))

    return jax.tree.map(
        one, shapes_tree, logical_tree,
        is_leaf=lambda x: isinstance(x, tuple)
        and (len(x) == 0 or isinstance(x[0], (str, type(None)))),
    )


def _batch_shardings(mesh, cfg, batch_tree):
    logical = batch_logical_axes(cfg)

    def one(name, sds):
        lg = logical.get(name, ("batch",) + (None,) * (len(sds.shape) - 1))
        return NamedSharding(mesh, spec_for(tuple(sds.shape), tuple(lg)))

    return {k: one(k, v) for k, v in batch_tree.items()}


def _cache_shardings(mesh, arch: Arch, B: int, cache_shapes):
    """Per-model cache logical axes (see each module's cache_logical_axes)."""
    logical = arch.module.cache_logical_axes(arch.cfg, B)

    def one(sds, lg):
        return NamedSharding(mesh, spec_for(tuple(sds.shape), tuple(lg)))

    return jax.tree.map(one, cache_shapes, logical)


def dryrun_target(arch_name: str, shape_name: str, mesh, cfg_override: ModelConfig | None = None):
    """Build (jitted_fn, arg_specs) for one cell under the given mesh.

    kinds: train → train_step (fwd+bwd+adamw); prefill → prefill;
    decode → serve_step (decode_step with abstract cache).

    mesh=None builds an unsharded target (used by the FLOPs pass, which
    lowers with unrolled scans and never compiles)."""
    arch = get(arch_name)
    if cfg_override is not None:
        arch = Arch(cfg=cfg_override, module=arch.module)
    cfg = arch.cfg
    shape = SHAPES[shape_name]
    rules = None
    if mesh is not None and cfg.sharding_profile == "pure_dp":
        rules = pure_dp_rules(mesh)

    with axis_rules(mesh, rules):
        params_shapes = _abstract_params(arch)
        sharded = mesh is not None
        p_specs = _specs_tree(mesh, params_shapes, arch.logical_axes()) if sharded else None

        if shape.kind == "train":
            opt_shapes = jax.eval_shape(lambda: init_opt_state(params_shapes))
            batch = batch_specs(cfg, shape, "train")
            fn = make_train_step(arch)
            if sharded:
                o_specs = {"m": p_specs, "v": p_specs, "step": NamedSharding(mesh, P())}
                b_specs = _batch_shardings(mesh, cfg, batch)
                jfn = jax.jit(
                    fn,
                    in_shardings=(p_specs, o_specs, b_specs),
                    out_shardings=(p_specs, o_specs, None),
                    donate_argnums=(0, 1),
                )
            else:
                jfn = jax.jit(fn)
            return jfn, (params_shapes, opt_shapes, batch)

        if shape.kind == "prefill":
            batch = batch_specs(cfg, shape, "prefill")

            def prefill_fn(params, batch):
                return arch.module.prefill(params, cfg, batch, max_seq=shape.seq)

            if sharded:
                b_specs = _batch_shardings(mesh, cfg, batch)
                jfn = jax.jit(prefill_fn, in_shardings=(p_specs, b_specs))
            else:
                jfn = jax.jit(prefill_fn)
            return jfn, (params_shapes, batch)

        # decode: one new token against a seq-length cache.
        B = shape.batch
        cache_shapes = jax.eval_shape(lambda: arch.init_cache(B, shape.seq))
        token = _sds((B, 1), jnp.int32)

        def serve_step(params, token, cache):
            return arch.decode_step(params, token, cache)

        if sharded:
            c_specs = _cache_shardings(mesh, arch, B, cache_shapes)
            t_spec = NamedSharding(mesh, spec_for((B, 1), ("batch", None)))
            jfn = jax.jit(
                serve_step,
                in_shardings=(p_specs, t_spec, c_specs),
                out_shardings=(None, c_specs),
                donate_argnums=(2,),
            )
        else:
            jfn = jax.jit(serve_step)
        return jfn, (params_shapes, token, cache_shapes)


def flops_pass_cfg(cfg: ModelConfig, shape: ShapeSpec) -> ModelConfig:
    """Config for the FLOPs lowering: scans unrolled; full-attention chunks
    enlarged (rectangular-chunk FLOPs are chunk-size invariant, so this only
    shrinks the unrolled HLO); windowed/banded attention keeps its real chunk
    sizes (band FLOPs DO depend on them)."""
    import dataclasses as _dc

    kw = dict(scan_unroll=True)
    if not (cfg.sliding_window or cfg.local_global_period):
        kw["attn_q_chunk"] = min(shape.seq, 4096)
        kw["attn_kv_chunk"] = min(shape.seq, 4096)
    return _dc.replace(cfg, **kw)


def slstm_flops_correction(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """xLSTM sLSTM layers run a sequential per-token scan that is NOT
    unrolled in the FLOPs pass (4096-step bodies would explode the HLO).
    The scan body is counted once by cost_analysis; add the missing
    (S−1) iterations of the recurrent matmul h@R: 2·B·d·4d flops each,
    ×4 for train (fwd + full-remat recompute + ~2× bwd)."""
    if cfg.family != "ssm" or cfg.slstm_every <= 0:
        return 0.0
    n_slstm = sum(
        1 for i in range(cfg.n_layers) if (i + 1) % cfg.slstm_every == 0
    )
    if shape.kind == "decode":
        return 0.0  # decode is a single step; nothing missing
    per_step = 2.0 * shape.batch * cfg.d_model * 4 * cfg.d_model
    mult = 4.0 if shape.kind == "train" else 1.0
    return n_slstm * (shape.seq - 1) * per_step * mult
