"""repro.fleet.shard: streaming frontier reductions and shard_map scale-out.

Single device: a streamed run (``run(..., stream=...)``) must be a
bit-exact equal of the materialized reduce for all three engines — the
fold runs the SAME jitted reduction kernels per chunk that the
materialized path runs on the full block, and per-row reductions are
leading-batch invariant. Multi-device (host-platform virtual devices via
``XLA_FLAGS=--xla_force_host_platform_device_count=N``): mesh-sharded
sweeps are bit-exact equals of unsharded ones on mixed-policy fleet,
mixed-discipline sched, and threshold+greedy taskq grids, with compile
counts pinned per mesh shape through ``stats.by_mesh``. Also pins the
``masked_percentiles`` empty-mask/single-survivor contract (NaN, not
clamped garbage) and its propagation through the frontier consumers.
"""

import json
import math

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import PAPER_READ_3MB, PAPER_WRITE_3MB, RequestClass
from repro.core.traces import TraceStore
from repro.fleet import (
    FleetSweep,
    PolicySpec,
    StreamSpec,
    TenantMix,
    convergence_stats,
    frontier_points,
    grid_cases,
    resolve_grid_mesh,
    write_fleet_artifact,
)
from repro.fleet.shard import resolve_stream
from repro.fleet.stats import masked_percentiles
from repro.sched import (
    DisciplineSpec,
    SchedSweep,
    multiclass_points,
    sched_cases,
    write_multiclass_artifact,
)
from repro.taskq import TaskqSweep, write_taskq_artifact

R3 = RequestClass("read3mb", 3.0, PAPER_READ_3MB, k_max=6, r_max=2.0, n_max=12)
R1 = RequestClass("read1mb", 1.0, PAPER_READ_3MB, k_max=4, r_max=2.0, n_max=8)
W1 = RequestClass("write1mb", 1.0, PAPER_WRITE_3MB, k_max=3, r_max=2.0, n_max=6)
L = 16

N_DEV = len(jax.devices())
needs2 = pytest.mark.skipif(N_DEV < 2, reason="needs >=2 devices "
                            "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")
needs4 = pytest.mark.skipif(N_DEV < 4, reason="needs >=4 devices")


def fleet_grid(n_lam: int = 4) -> list:
    """Mixed-policy fleet grid: TOFEC adaptive + static + fixed-k points."""
    lams = np.linspace(5.0, 60.0, n_lam)
    pols = [PolicySpec.tofec(), PolicySpec.static(6, 3), PolicySpec.fixedk(4)]
    return grid_cases(lams, pols, [0], R3, L)


def sched_grid() -> list:
    """Mixed-discipline joint grid over a 2-class tenant mix."""
    mixes = [TenantMix(lam, (R3, R1), (0.6, 0.4)) for lam in (15.0, 35.0)]
    discs = [DisciplineSpec.fifo(), DisciplineSpec.priority(0, 1),
             DisciplineSpec.wfq(2.0, 1.0)]
    return sched_cases(mixes, discs, [0], L=L)


def taskq_grid() -> list:
    """Threshold (tofec/static) + greedy exact-engine grid."""
    lams = np.linspace(10.0, 50.0, 3)
    pols = [PolicySpec.tofec(), PolicySpec.greedy()]
    return grid_cases(lams, pols, [0], R3, L)


@pytest.fixture(scope="module")
def pools():
    sizes = tuple(R3.file_mb / k for k in range(1, R3.k_max + 1))
    store = TraceStore.generate(PAPER_READ_3MB, sizes, threads=R3.n_max,
                                samples=1024, correlation=0.12, seed=3)
    return store.device_pools(n_max=R3.n_max)


def assert_points_equal(a, b):
    """Bit-exact frontier/multiclass point equality, NaN-aware (json keeps
    float repr and serializes NaN identically on both sides)."""
    assert len(a) == len(b)
    for pa, pb in zip(a, b):
        assert json.dumps(pa.to_dict()) == json.dumps(pb.to_dict())


# ---------------------------------------------------------------------------
# masked_percentiles edge cases (empty mask, single survivor)
# ---------------------------------------------------------------------------


def test_masked_percentiles_empty_mask_is_nan():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(3, 50)).astype(np.float32))
    qs = jnp.asarray([50.0, 90.0, 99.0])
    mask = jnp.ones_like(x, dtype=bool).at[1].set(False)
    pct = np.asarray(masked_percentiles(x, qs, mask))
    assert np.all(np.isnan(pct[1]))  # empty row: no statistic, not a clamp
    assert np.all(np.isfinite(pct[[0, 2]]))
    # Fully-masked rows agree with plain percentiles (lower interpolation).
    ref = np.percentile(np.asarray(x[0]), [50.0, 90.0, 99.0], method="lower")
    np.testing.assert_array_equal(pct[0], ref.astype(np.float32))


def test_masked_percentiles_single_survivor():
    """One surviving sample IS every percentile of that row."""
    x = jnp.asarray(np.arange(20, dtype=np.float32).reshape(1, 20) * 3.0)
    mask = jnp.zeros_like(x, dtype=bool).at[0, 7].set(True)
    pct = np.asarray(masked_percentiles(x, jnp.asarray([0.0, 50.0, 100.0]), mask))
    np.testing.assert_array_equal(pct[0], np.full(3, 21.0, np.float32))


def test_multiclass_points_propagate_empty_class_as_nan():
    """A class with weight 0 never arrives: its stats are NaN rows (count 0),
    and Jain/aggregate stats come from the populated classes only."""
    mix = TenantMix(20.0, (R3, R1), (1.0, 0.0))
    res = SchedSweep(chunk=4).run(sched_cases([mix], [DisciplineSpec.fifo()], [0]),
                                  400)
    (pt,) = multiclass_points(res)
    empty = pt.cls("read1mb")
    assert empty["count"] == 0
    assert all(math.isnan(empty[f]) for f in
               ("mean", "p50", "p90", "p95", "p99", "mean_queueing",
                "mean_k", "mean_n"))
    assert math.isfinite(pt.cls("read3mb")["mean"]) and pt.jain_delay == 1.0
    # ...and the artifact writer serializes the NaN rows without crashing.
    streamed = SchedSweep(chunk=4).run(
        sched_cases([mix], [DisciplineSpec.fifo()], [0]), 400, stream=True)
    assert_points_equal([pt], multiclass_points(streamed))


# ---------------------------------------------------------------------------
# Streaming: bit-exact vs the materialized reduce (single device)
# ---------------------------------------------------------------------------


def test_fleet_streamed_bit_exact(tmp_path):
    cases = fleet_grid()
    mat = FleetSweep(chunk=8).run(cases, 700)
    st = FleetSweep(chunk=8).run(cases, 700, stream=True)
    assert st.out == {} and st.streamed is not None  # no (G, T) block kept
    assert_points_equal(frontier_points(mat), frontier_points(st))
    assert convergence_stats(mat) == convergence_stats(st)
    a = write_fleet_artifact(str(tmp_path / "a.json"), mat)
    b = write_fleet_artifact(str(tmp_path / "b.json"), st)
    assert a["points"] == b["points"] and a["convergence"] == b["convergence"]


def test_sched_streamed_bit_exact(tmp_path):
    cases = sched_grid()
    mat = SchedSweep(chunk=4).run(cases, 500)
    st = SchedSweep(chunk=4).run(cases, 500, stream=True)
    assert st.out == {}
    assert_points_equal(multiclass_points(mat), multiclass_points(st))
    a = write_multiclass_artifact(str(tmp_path / "a.json"), mat)
    b = write_multiclass_artifact(str(tmp_path / "b.json"), st)
    assert a["points"] == b["points"]


def test_taskq_streamed_bit_exact(pools, tmp_path):
    cases = taskq_grid()
    mat = TaskqSweep(chunk=4).run(cases, 500, pools)
    st = TaskqSweep(chunk=4).run(cases, 500, pools, stream=True)
    assert st.out == {}
    assert_points_equal(frontier_points(mat), frontier_points(st))
    a = write_taskq_artifact(str(tmp_path / "a.json"), mat)
    b = write_taskq_artifact(str(tmp_path / "b.json"), st)
    assert a["points"] == b["points"]


def test_stream_spec_fixes_warmup_at_launch():
    """The fold bakes the warmup cut in at launch; asking the frontier for a
    different cut afterwards must be a loud error, not a silent reuse."""
    res = FleetSweep(chunk=8).run(fleet_grid(2), 600, stream=StreamSpec(0.05))
    frontier_points(res, 0.05)  # matching cut: fine
    with pytest.raises(ValueError, match="warmup"):
        frontier_points(res, 0.20)
    assert resolve_stream(True) == StreamSpec()
    assert resolve_stream(None) is None and resolve_stream(False) is None


# ---------------------------------------------------------------------------
# Mesh-sharded equivalence (host virtual devices)
# ---------------------------------------------------------------------------


def test_resolve_grid_mesh_validates():
    mesh = resolve_grid_mesh(1)
    assert mesh.axis_names == ("grid",) and mesh.size == 1
    with pytest.raises(ValueError):
        resolve_grid_mesh(N_DEV + 1)
    with pytest.raises(ValueError):
        resolve_grid_mesh(0)


@needs2
@pytest.mark.parametrize("d", [2, pytest.param(4, marks=needs4)])
def test_fleet_mesh_bit_exact(d):
    """Sharded (d-device) sweep == unsharded, raw outputs bitwise; compile
    counts pinned per mesh shape via ``stats.by_mesh``."""
    cases = fleet_grid()
    ref = FleetSweep(chunk=8).run(cases, 700)
    sweep = FleetSweep(chunk=8, mesh=d)
    res = sweep.run(cases, 700)
    for name in ("total", "queueing", "service", "n", "k"):
        np.testing.assert_array_equal(np.asarray(res.out[name]),
                                      np.asarray(ref.out[name]))
    assert sweep.stats.by_mesh == {(d,): 1}
    # Same bucket, different grid size: no new trace on this mesh shape.
    sweep.run(fleet_grid(2), 700)
    assert sweep.stats.by_mesh == {(d,): 1}
    # Sharded AND streamed: still bit-exact vs unsharded materialized.
    st = sweep.run(cases, 700, stream=True)
    assert_points_equal(frontier_points(ref), frontier_points(st))
    assert convergence_stats(ref) == convergence_stats(st)


@needs2
def test_sched_mesh_bit_exact():
    cases = sched_grid()
    ref = SchedSweep(chunk=4).run(cases, 500)
    sweep = SchedSweep(chunk=4, mesh=2)
    res = sweep.run(cases, 500)
    for name in ("total", "queueing", "service", "n", "k", "cls_ids"):
        np.testing.assert_array_equal(np.asarray(res.out[name]),
                                      np.asarray(ref.out[name]))
    assert sweep.stats.by_mesh == {(2,): 1}
    st = sweep.run(cases, 500, stream=True)
    assert_points_equal(multiclass_points(ref), multiclass_points(st))


@needs2
def test_taskq_mesh_bit_exact(pools):
    """Exact engine on a mesh: grid shards, the one trace-pool copy
    broadcasts to every device (in_axes=None -> replicated spec)."""
    cases = taskq_grid()
    ref = TaskqSweep(chunk=8).run(cases, 500, pools)
    sweep = TaskqSweep(chunk=8, mesh=2)
    res = sweep.run(cases, 500, pools)
    for name in ("total", "queueing", "service", "n", "k"):
        np.testing.assert_array_equal(np.asarray(res.out[name]),
                                      np.asarray(ref.out[name]))
    assert sweep.stats.by_mesh == {(2,): 1}
    st = sweep.run(cases, 500, pools, stream=True)
    assert_points_equal(frontier_points(ref), frontier_points(st))


@needs4
def test_chunk_rounds_up_to_mesh_multiple():
    """chunk=6 on a 4-device mesh pads to 8 so every shard gets equal rows;
    results for the real rows are untouched by the padding."""
    cases = fleet_grid()[:5]
    sweep = FleetSweep(chunk=6, mesh=4)
    key = sweep.bucket_key(len(cases), 700, R3.n_max, R3.k_max + 1, R3.n_max + 1)
    assert key[0] % 4 == 0
    res = sweep.run(cases, 700)
    assert res.launches == 1
    ref = FleetSweep(chunk=8).run(cases, 700)
    np.testing.assert_array_equal(np.asarray(res.out["total"]),
                                  np.asarray(ref.out["total"]))


@needs2
def test_fleet_mesh_timeline_bit_exact():
    """Timelines fold per case (cut -> concat), so a collected mesh-sharded
    run carries the identical per-window timeline to the single-device
    path — the timeline twin of ``test_fleet_mesh_bit_exact``."""
    from repro import obs

    cases = fleet_grid()
    try:
        obs.set_enabled(True)
        ref = FleetSweep(chunk=8).run(cases, 700)
        res = FleetSweep(chunk=8, mesh=2).run(cases, 700)
    finally:
        obs.set_enabled(None)
    a, b = ref.timeline.snapshot(), res.timeline.snapshot()
    assert a["window"] == b["window"]
    assert set(a["series"]) == set(b["series"])
    for name in a["series"]:
        np.testing.assert_array_equal(a["series"][name], b["series"][name])
    np.testing.assert_array_equal(a["hists"]["delay"], b["hists"]["delay"])
