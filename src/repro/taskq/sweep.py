"""Vmapped task-level sweep: exact (λ × policy × seed) grids per launch.

Mirrors :class:`repro.fleet.sweep.FleetSweep` — the same
:class:`~repro.fleet.sweep.ChunkedVmapSweep` pow2-bucketed jit cache,
chunked memory-bounded launches and compile observability — but each grid
point runs the exact task-level engine (:func:`repro.taskq.engine.
taskq_scan_core`) instead of the fluid scan, and the per-chunk-size delay
pools ride every launch as **grid-shared broadcast arrays** (``in_axes
None``): one device copy of the trace store serves the whole grid.

Cases are plain :class:`repro.fleet.sweep.SweepCase` grids (reuse
``grid_cases``), so a fleet grid re-runs on the exact engine unchanged —
plus ``PolicySpec.greedy()`` points, which only this sweep accepts.
Reductions reuse :func:`repro.fleet.frontier.frontier_points` unchanged
(the result carries the same stacked outputs and per-case params), and
:func:`write_taskq_artifact` emits the ``BENCH_taskq.json`` twin of the
fleet artifact.
"""

from __future__ import annotations

import dataclasses
import json
import os

import numpy as np

from repro import obs
from repro.coding.codec import pow2_bucket
from repro.core.traces import DevicePools
from repro.fleet.sweep import ChunkedVmapSweep, SweepCase, SweepResult, frontier_fold
from repro.taskq.policies import encode_policy


def taskq_streams(case: SweepCase, count: int, n_rows: int):
    """One grid point's host-side draws: (interarrivals, pool row indices).

    The draw order — workload gaps first, then row indices, from ONE
    ``default_rng(case.seed)`` stream — is the contract both the sweep and
    the oracle cross-validation tests rely on to feed identical randomness
    to both engines.
    """
    rng = np.random.default_rng(case.seed)
    inter = case.resolved_workload().interarrivals(rng, count)
    idx = rng.integers(n_rows, size=count).astype(np.int32)
    return inter, idx


@dataclasses.dataclass
class TaskqResult(SweepResult):
    """Stacked per-request outputs for every exact grid point — the same
    layout as the fluid sweep's :class:`repro.fleet.sweep.SweepResult`
    (which it reuses wholesale), so the fleet's frontier reductions consume
    it unchanged; here the delays are exact task-level simulations."""


class TaskqSweep(ChunkedVmapSweep):
    """Chunked, shape-bucketed vmapped sweep over exact task-level points.

    ``q_cap`` bounds the backlog-length observable (see
    :mod:`repro.taskq.engine`); all cases of one run must share ``L`` (the
    thread-state width is structural). Compilations are keyed on (chunk,
    pow2(T), L, q_cap, table lengths, pool shape) and observable via
    ``stats`` — pinned in ``tests/test_taskq.py``.
    """

    def __init__(self, *, chunk: int = 64, t_floor: int | None = None,
                 q_cap: int = 128, mesh=None):
        super().__init__(chunk=chunk, t_floor=t_floor, mesh=mesh)
        if q_cap < 1:
            raise ValueError("q_cap must be >= 1")
        self.q_cap = q_cap

    # -- compilation cache --------------------------------------------------

    def bucket_key(self, n_cases: int, count: int, L: int, hk_len: int,
                   hn_len: int, pool_shape: tuple):
        """The compilation-cache key a run with these shapes lands in.

        The trailing timeline window derives from the pow2 time bucket
        (:func:`repro.obs.timeline_window`), so listing it never splits a
        bucket."""
        t_b = pow2_bucket(count, self.t_floor)
        return (
            self._chunk_bucket(n_cases),
            t_b,
            L,
            self.q_cap,
            hk_len,
            hn_len,
            tuple(pool_shape),
            self.mesh_shape,
            obs.timeline_window(t_b),
        )

    def _build(self, key: tuple, collect: bool = False):
        L, q_cap = key[2], key[3]
        window = key[-1]

        def one(cfg, inter, idx, pools, sizes):
            from repro import obs
            from repro.taskq.engine import taskq_scan_core

            valid = obs.valid_mask(cfg, inter.shape[-1]) if collect else None
            out = taskq_scan_core(cfg, inter, idx, pools, sizes, L=L,
                                  q_cap=q_cap, collect=collect, valid=valid,
                                  window=window if collect else None)
            if collect:
                # The scan-internal buf (cancellations, idle, backlog) rides
                # with the generic per-case picks; disjoint names union-merge.
                out["obs"] = out["obs"].merge(
                    obs.sweep_point_metrics(out, "taskq", valid=valid))
            return out

        # Pools and sizes broadcast: every grid row reads the one device copy.
        return self._vmapped(one, in_axes=(0, 0, 0, None, None))

    # -- the sweep ----------------------------------------------------------

    def _stack_cfg(self, cases: list[SweepCase], hk_len: int, hn_len: int):
        G = len(cases)
        cfg = {
            name: np.empty(G, np.float32)
            for name in ("delta_bar", "delta_tilde", "psi_bar", "psi_tilde",
                         "J", "L", "alpha", "r_max")
        }
        cfg["pol"] = np.empty(G, np.int32)
        cfg["gk_max"] = np.empty(G, np.int32)
        cfg["h_k"] = np.zeros((G, hk_len), np.float32)
        cfg["h_n"] = np.zeros((G, hn_len), np.float32)
        for i, case in enumerate(cases):
            plan = (
                self._plan_for(case.cls, case.L, case.policy.eq7_factor)
                if case.policy.kind == "tofec" else None
            )
            enc = encode_policy(case.policy, case.cls, case.L, hk_len, hn_len, plan)
            pr = case.cls.params
            # delta/psi params ride along for the frontier's usage reduction
            # (the engine itself reads delays from the trace pools).
            cfg["delta_bar"][i] = pr.delta_bar
            cfg["delta_tilde"][i] = pr.delta_tilde
            cfg["psi_bar"][i] = pr.psi_bar
            cfg["psi_tilde"][i] = pr.psi_tilde
            cfg["J"][i] = case.cls.file_mb
            cfg["L"][i] = case.L
            cfg["alpha"][i] = enc.alpha
            cfg["r_max"][i] = enc.r_max
            cfg["pol"][i] = enc.pol
            cfg["gk_max"][i] = enc.gk_max
            cfg["h_k"][i] = enc.h_k
            cfg["h_n"][i] = enc.h_n
        return cfg

    def run(self, cases: list[SweepCase], count: int,
            pools: DevicePools, *, stream=None) -> TaskqResult:
        """Evaluate every grid point exactly over ``count`` arrivals.

        Host side: per-case RNG streams (:func:`taskq_streams`) generate the
        workload gaps and pool-row draws. Device side: ceil(G / chunk)
        vmapped launches sharing one device copy of ``pools`` — on a mesh,
        the pools replicate to every device while the grid axis shards.

        ``stream`` (True or a :class:`repro.fleet.shard.StreamSpec`) folds
        each chunk into the fleet frontier statistics on device instead of
        stacking the exact (G, count) block — see :mod:`repro.fleet.shard`.
        """
        if not cases:
            raise ValueError("empty case grid")
        from repro.fleet.shard import StreamedStats, resolve_stream

        spec = resolve_stream(stream)
        Ls = {c.L for c in cases}
        if len(Ls) != 1:
            raise ValueError(f"all cases of one run must share L, got {sorted(Ls)}")
        L = Ls.pop()
        n_need = max(c.cls.n_max for c in cases)
        if pools.pools.shape[2] < n_need:
            raise ValueError(
                f"pool width {pools.pools.shape[2]} cannot serve "
                f"n_max={n_need}; re-export with "
                f"TraceStore.device_pools(n_max={n_need})"
            )
        traces0, launches0 = self.stats.traces, self.stats.launches
        hk_len = max(c.cls.k_max for c in cases) + 1
        hn_len = n_need + 1
        key = self.bucket_key(len(cases), count, L, hk_len, hn_len,
                              pools.pools.shape)
        chunk, T_b = key[0], key[1]

        cfg = self._stack_cfg(cases, hk_len, hn_len)
        G = len(cases)
        collect = obs.enabled()
        if collect:
            cfg["obs_count"] = np.full(G, count, np.int32)

        def chunk_streams(rows):
            inter = np.zeros((len(rows), T_b), np.float32)
            idx = np.zeros((len(rows), T_b), np.int32)
            for j, i in enumerate(rows):
                if j and i == rows[0]:  # tail pad: repeat the chunk's row 0
                    inter[j], idx[j] = inter[0], idx[0]
                    continue
                it, ix = taskq_streams(cases[i], count, pools.n_rows)
                inter[j, :count] = it
                idx[j, :count] = ix
            return inter, idx

        fn = self._fn_for(key, collect)
        fold = (
            frontier_fold(int(count * spec.warmup_frac), hn_len)
            if spec else None
        )
        stacked = self._launch_chunks(
            fn, cfg, chunk_streams, G, chunk, count,
            broadcast=(pools.pools, pools.sizes_mb), fold=fold,
        )
        return TaskqResult(
            cases=list(cases),
            out={} if spec else stacked,
            cfg=cfg,
            count=count,
            compiles=self.stats.traces - traces0,
            launches=self.stats.launches - launches0,
            streamed=(
                StreamedStats(spec.warmup_frac, count, stacked) if spec else None
            ),
            metrics=self._last_metrics,
            timeline=self._last_timeline,
            mesh_shape=self.mesh_shape,
        )

    def replay_flight(self, result: TaskqResult, pools: DevicePools,
                      case_index: int, *, label: str | None = None):
        """Re-run ONE grid point of ``result`` with the flight recorder on.

        The "aggregate engines stream, flight replays one case" rule: grid
        runs keep their streamed/stacked reductions, and an anomalous cell
        is zoomed into after the fact — this regenerates the case's exact
        host streams from its seed (:func:`taskq_streams`), replays it
        through :func:`repro.taskq.engine.taskq_scan` with ``flight=True``
        (its own jit cache entry; the sweep's compiled buckets are
        untouched) and returns the :class:`repro.obs.flight.FlightLog`.
        The replay consumes the stored ``result.cfg`` row, so its
        per-request delays equal the sweep cell's — pinned in
        ``tests/test_flight.py``.
        """
        from repro.obs.flight import FlightLog
        from repro.taskq.engine import taskq_scan

        G = len(result.cases)
        if not 0 <= case_index < G:
            raise ValueError(f"case_index {case_index} outside grid of {G}")
        case = result.cases[case_index]
        cfg_row = {name: np.asarray(v[case_index])
                   for name, v in result.cfg.items() if name != "obs_count"}
        inter, idx = taskq_streams(case, result.count, pools.n_rows)
        out = taskq_scan(
            cfg_row, np.asarray(inter, np.float32),
            np.asarray(idx, np.int32), pools.pools, pools.sizes_mb,
            L=case.L, q_cap=self.q_cap, collect=False, flight=True,
        )
        return FlightLog(
            out, label=label or f"taskq[{case_index}]:{case.policy.name}")


def write_taskq_artifact(
    path: str,
    result: TaskqResult,
    *,
    warmup_frac: float = 0.05,
    extra: dict | None = None,
    flight=None,
    flight_top_k: int = 3,
) -> dict:
    """Reduce an exact sweep and write the ``BENCH_taskq.json`` artifact.

    Reuses the fleet's frontier reductions (per-point delay stats, per-policy
    capacities, convergence, headline ratios) on the exact per-request
    delays — the trace-driven twin of ``BENCH_fleet.json``.

    ``flight``: optional :class:`repro.obs.flight.FlightLog` from a
    :meth:`TaskqSweep.replay_flight` zoom of one cell — adds a ``"flight"``
    block with the structural counts the perf gate pins (records emitted,
    exemplars found) plus the replayed case's label.
    """
    from repro.fleet.frontier import (
        capacity_estimates,
        convergence_stats,
        frontier_points,
        headline_ratios,
    )

    points = frontier_points(result, warmup_frac)
    artifact = {
        "schema": "repro.taskq/BENCH_taskq/v1",
        "meta": obs.run_meta(mesh_shape=getattr(result, "mesh_shape", ())),
        "grid_size": len(result.cases),
        "count": result.count,
        "compiles": result.compiles,
        "launches": result.launches,
        "points": [p.to_dict() for p in points],
        "capacity_req_s": capacity_estimates(points),
        "convergence": convergence_stats(result, warmup_frac),
        "headline": headline_ratios(points),
    }
    if flight is not None:
        exemplars = flight.exemplars(flight_top_k)
        artifact["flight"] = {
            "label": flight.label,
            "requests": len(flight),
            "records": len(flight.records()),
            "exemplars": len(exemplars),
            "exemplar_reqs": [ex["req"] for ex in exemplars],
        }
    if extra:
        artifact.update(extra)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(artifact, f, indent=1)
    return artifact
