"""The front-end proxy of Fig.2, executing real I/O against an ObjectStore.

A :class:`Proxy` owns L connection threads, a FIFO request queue, and a FIFO
task queue, and serves high-level read/write requests with (n, k) MDS codes
chosen per request by a :class:`repro.core.controller.Policy` — the
real-I/O twin of :mod:`repro.core.simulator` (which is the statistics
oracle).

Reads use the Shared-Key layout: the coded object (N·b bytes) lives under
one key; each task is a ranged read of one chunk; the request completes when
k chunks arrive and the remaining tasks are cancelled (best-effort: queued
tasks are dropped; in-flight ones are abandoned — their results discarded —
matching a proxy that closes the connection).

Writes encode k chunks into n, upload each as a part, and complete when any
k parts are durable (the paper's write model); the remaining uploads continue
as background tasks (footnote 1), and once every issued task has resolved the
proxy assembles the durable parts into the readable coded object and records
which strips exist in its write registry — subsequent reads of that key only
target chunks whose strips were actually written. The write path has its own
policy hook (``write_policy``, e.g. :class:`repro.core.controller.FeedbackPolicy`
fed by the fused serving controller), closing the §III control loop: each
admission round encodes queued writes under the currently-adapted (n, k) via
:meth:`SharedKeyLayout.encode_files`'s chunk-level code.

Coding on BOTH directions of the hot path goes through the unified batched
codec engine, amortized per admission round (the coding-overhead Ψ cap of
FAST CLOUD §IV):

* writes — each round drains every queued write and encodes all same-layout
  payloads with ONE batched :meth:`SharedKeyLayout.encode_files` call;
* reads — completed reads accumulate (workers only collect chunks and hand
  the finished request to the admit loop) and each round reconstructs the
  whole accumulation with ONE batched :meth:`SharedKeyLayout.reconstruct_batch`
  call, per-item ``present`` masks carrying each request's own erasure
  pattern and chunk level through a single ``codec.decode``.

The admission *rule* (inject the next request's tasks only when the task
queue is drained and a thread idles) is unchanged — batching moves coding
off the per-request critical path, not the paper's queueing model. Callers
that want the raw chunks instead (e.g. the fused serving step in
:mod:`repro.serve.engine`, which decodes *inside* its jitted step) pass
``raw=True``; those requests skip proxy-side decode and return their
surviving chunks + indices in :attr:`RequestResult.chunks`.
"""

from __future__ import annotations

import dataclasses
import logging
import queue as _queue
import threading
import time
from collections import deque

import numpy as np

from repro import obs
from repro.coding import codec as codec_mod
from repro.coding.layout import SharedKeyLayout
from repro.core.controller import Policy
from repro.storage.backend import ObjectStore, StorageError


_log = logging.getLogger(__name__)

#: admit-loop wakeup marker: a completed read is waiting for batched decode.
_WAKE = object()


@dataclasses.dataclass
class RequestResult:
    key: str
    op: str
    n: int
    k: int
    ok: bool
    data: bytes | None
    t_arrival: float
    t_first_start: float
    t_done: float
    failures: int = 0
    #: raw reads only: surviving chunk index -> chunk bytes (data stays None)
    chunks: dict[int, bytes] | None = None

    @property
    def total_s(self) -> float:
        return self.t_done - self.t_arrival

    @property
    def queueing_s(self) -> float:
        return self.t_first_start - self.t_arrival

    @property
    def service_s(self) -> float:
        return self.t_done - self.t_first_start


class _Request:
    def __init__(self, op, key, layout, payload, payload_len, n, k, cls_id, raw=False):
        self.op = op
        self.key = key
        self.layout: SharedKeyLayout = layout
        self.payload = payload
        self.payload_len = payload_len
        self.n = n
        self.k = k
        self.cls_id = cls_id
        self.raw = raw
        self.t_arrival = time.monotonic()
        self.t_first_start = None
        self.done = threading.Event()
        self.lock = threading.Lock()
        self.completed: dict[int, bytes] = {}
        self.failures = 0
        self.cancelled = False
        self.result: RequestResult | None = None
        self.coded: bytes | None = None  # write path: batch-encoded object
        self.n_issued = n  # tasks actually injected (registry may shrink it)
        self.settled = threading.Event()  # write path: all issued tasks resolved


class Proxy:
    """L-threaded proxy with TOFEC admission control."""

    def __init__(self, store: ObjectStore, policy: Policy, *, L: int = 16,
                 codec: codec_mod.Codec | None = None,
                 write_policy: Policy | None = None):
        self.store = store
        self.policy = policy
        #: optional separate policy for the write path (closed-loop feedback);
        #: None = writes share the read policy.
        self.write_policy = write_policy
        self.L = L
        self.codec = codec or codec_mod.get_codec()
        #: key -> set of strip ids known durable (adapted writes store a strip
        #: prefix; reads only target chunks whose strips are all present).
        self._written: dict[str, set[int]] = {}
        self._write_reqs: list[_Request] = []
        self._task_q: _queue.Queue = _queue.Queue()
        self._request_q: _queue.Queue = _queue.Queue()
        # Completed (non-raw) reads awaiting the admission round's ONE
        # batched reconstruct; fed by workers, drained by the admit loop.
        self._decode_q: _queue.Queue = _queue.Queue()
        self._idle = L
        # Requests the admit loop has drained but not yet injected: still
        # queued from the policy's point of view (TOFEC's q signal).
        self._admit_backlog = 0
        self._state_lock = threading.Lock()
        self._shutdown = False
        self.results: list[RequestResult] = []
        self._threads = [
            threading.Thread(target=self._worker, daemon=True, name=f"proxy-{i}")
            for i in range(L)
        ]
        self._admitter = threading.Thread(target=self._admit_loop, daemon=True)
        for t in self._threads:
            t.start()
        self._admitter.start()

    # -- public API ---------------------------------------------------------

    def read(self, key: str, layout: SharedKeyLayout, payload_len: int | None = None,
             cls_id: int = 0, timeout: float = 60.0, *, raw: bool = False) -> RequestResult:
        return self.wait(self.read_async(key, layout, payload_len, cls_id, raw=raw), timeout)

    def read_async(self, key: str, layout: SharedKeyLayout, payload_len: int | None = None,
                   cls_id: int = 0, *, raw: bool = False) -> _Request:
        """Submit a read without blocking; pair with :meth:`wait`.

        ``raw=True`` skips proxy-side decode: the result carries the
        surviving chunks + indices (for callers that decode in their own
        batched/fused step).
        """
        return self._submit("read", key, layout, None, payload_len, cls_id, raw=raw)

    @staticmethod
    def wait(req: _Request, timeout: float = 60.0) -> RequestResult:
        req.done.wait(timeout)
        if req.result is None:
            raise TimeoutError(f"{req.op} {req.key} timed out")
        return req.result

    def read_many(self, keys: list[str], layout: SharedKeyLayout,
                  payload_len: int | None = None, *, cls_id: int = 0,
                  raw: bool = False, timeout: float = 60.0) -> list[RequestResult]:
        """Batched fetch: submit every key up front, then collect.

        Submitting the whole round before waiting lets the policy see the
        true backlog (TOFEC's q signal) and lets the admit loop reconstruct
        the completions in batched decode calls instead of one per request.
        """
        with obs.span("proxy.read_many", keys=len(keys), raw=raw):
            reqs = [self.read_async(k, layout, payload_len, cls_id, raw=raw)
                    for k in keys]
            return [self.wait(r, timeout) for r in reqs]

    def write(self, key: str, layout: SharedKeyLayout, payload: bytes,
              cls_id: int = 0, timeout: float = 60.0) -> RequestResult:
        req = self.write_async(key, layout, payload, cls_id)
        req.done.wait(timeout)
        if req.result is None:
            raise TimeoutError(f"write {key} timed out")
        return req.result

    def write_async(self, key: str, layout: SharedKeyLayout, payload: bytes,
                    cls_id: int = 0) -> _Request:
        """Submit a write without blocking; pair with :meth:`wait`.

        The request completes (``done``) at k durable parts; the remaining
        uploads run in background and ``settled`` fires once the assembled
        object is readable (:meth:`flush_writes` waits for all of them).
        """
        return self._submit("write", key, layout, payload, len(payload), cls_id)

    def flush_writes(self, timeout: float = 60.0) -> None:
        """Drain the write path's background tasks (footnote 1).

        Blocks until every submitted write's issued uploads have resolved and
        the assembled coded object + its registry entry are visible to reads.
        """
        with self._state_lock:
            reqs, self._write_reqs = self._write_reqs, []
        deadline = time.monotonic() + timeout
        with obs.span("proxy.flush_writes", writes=len(reqs)):
            for r in reqs:
                if not r.settled.wait(max(deadline - time.monotonic(), 0.0)):
                    with self._state_lock:
                        self._write_reqs.extend(
                            rr for rr in reqs if not rr.settled.is_set())
                    raise TimeoutError(f"write {r.key} did not settle")

    def close(self):
        self._shutdown = True
        self._request_q.put(None)
        for _ in self._threads:
            self._task_q.put(None)

    # -- internals ----------------------------------------------------------

    def _submit(self, op, key, layout, payload, payload_len, cls_id, raw=False) -> _Request:
        with self._state_lock:
            q_len = self._request_q.qsize() + self._admit_backlog
            idle = self._idle
        pol = self.write_policy if (op == "write" and self.write_policy is not None) \
            else self.policy
        n, k = pol.select(q=q_len, idle=idle, cls_id=cls_id, now=time.monotonic())
        # Clamp to what the layout supports: k | K, n ≤ N/m.
        k = max(kk for kk in layout.supported_k() if kk <= k)
        n_max, _, _ = layout.code_for_k(k)
        n = max(k, min(n, n_max))
        req = _Request(op, key, layout, payload, payload_len, n, k, cls_id, raw=raw)
        if op == "write":
            with self._state_lock:
                self._write_reqs.append(req)
        self._request_q.put(req)
        return req

    def _admit_loop(self):
        pending: deque[_Request] = deque()
        while not self._shutdown:
            if not pending:
                req = self._request_q.get()
                if req is None:
                    break
                if req is _WAKE:  # a read completed while we were idle
                    self._flush_completed_reads()
                    continue
                pending.append(req)
            # Drain everything else that already arrived, then batch-encode
            # all queued writes (and batch-decode all completed reads) in one
            # codec call per layout class.
            while True:
                try:
                    req = self._request_q.get_nowait()
                except _queue.Empty:
                    break
                if req is None:
                    self._flush_completed_reads()
                    return
                if req is _WAKE:
                    continue
                pending.append(req)
            with self._state_lock:
                self._admit_backlog = len(pending)
            self._flush_completed_reads()
            self._encode_pending_writes(pending)
            req = pending.popleft()
            with self._state_lock:
                self._admit_backlog = len(pending)
            # Paper's admission rule: wait until the task queue is drained
            # and a thread is idle before injecting the next batch.
            while not self._shutdown:
                with self._state_lock:
                    ready = self._idle > 0 and self._task_q.empty()
                if ready:
                    break
                self._flush_completed_reads()  # decode while tasks drain
                time.sleep(1e-4)
            self._inject(req)
        self._flush_completed_reads()

    def _flush_completed_reads(self) -> None:
        """One batched reconstruct per layout group of completed reads.

        This is the read-side twin of :meth:`_encode_pending_writes`: all
        reads that finished since the last round — any mix of chunk levels
        and erasure patterns — decode in a single ``codec.decode`` per
        layout via per-item ``present`` masks.
        """
        reqs: list[_Request] = []
        while True:
            try:
                reqs.append(self._decode_q.get_nowait())
            except _queue.Empty:
                break
        if not reqs:
            return
        groups: dict[SharedKeyLayout, list[_Request]] = {}
        for r in reqs:
            groups.setdefault(r.layout, []).append(r)
        for lay, group in groups.items():
            try:
                datas = lay.reconstruct_batch(
                    [(r.k, r.completed, r.payload_len) for r in group], codec=self.codec
                )
            except Exception as batch_err:
                # Torn batch (e.g. one malformed chunk): fall back to
                # per-request decode so one bad item can't wedge the rest.
                _log.warning("batched reconstruct failed (%s); retrying "
                             "per-request", batch_err)
                for r in group:
                    try:
                        data = lay.reconstruct(r.k, r.completed, r.payload_len,
                                               codec=self.codec)
                        self._finish(r, True, data=data)
                    except Exception:
                        _log.exception("reconstruct failed for read %r "
                                       "(k=%d, chunks=%s)", r.key, r.k,
                                       sorted(r.completed))
                        self._finish(r, False)
                continue
            for r, data in zip(group, datas):
                self._finish(r, True, data=data)

    def _encode_pending_writes(self, pending: "deque[_Request]") -> None:
        """One batched encode per (layout, n, k) group of queued writes.

        Grouping by the adapted chunk-level code means each admission round's
        writes encode under whatever (n, k) the (possibly feedback-driven)
        write policy picked at submission — the closed-loop write path.
        """
        todo = [r for r in pending if r.op == "write" and r.coded is None]
        groups: dict[tuple[SharedKeyLayout, int, int], list[_Request]] = {}
        for r in todo:
            groups.setdefault((r.layout, r.n, r.k), []).append(r)
        for (lay, n, k), reqs in groups.items():
            with obs.span("proxy.encode_writes", n=n, k=k, writes=len(reqs)):
                coded = lay.encode_files([r.payload for r in reqs],
                                         codec=self.codec, n=n, k=k)
            for r, c in zip(reqs, coded):
                r.coded = c

    def _inject(self, req: _Request):
        if req.op == "read":
            n_max, _, m = req.layout.code_for_k(req.k)
            with self._state_lock:
                avail = self._written.get(req.key)
            if avail is None:
                cand = list(range(n_max))  # pre-coded object: all chunks exist
            else:
                # Proxy-written key: only chunks whose strips are all durable.
                cand = [ci for ci in range(n_max)
                        if all(s in avail for s in range(ci * m, (ci + 1) * m))]
            # Prefer spread of chunk indices across the object (diversity).
            order = np.random.default_rng(hash(req.key) & 0xFFFF).permutation(len(cand))
            issue = [cand[i] for i in order[: req.n]]
            req.n_issued = len(issue)
            if req.n_issued < req.k:
                with req.lock:
                    req.cancelled = True
                    self._finish(req, False)
                return
            for ci in issue:
                self._task_q.put((req, int(ci), None))
        else:
            coded = req.coded
            if coded is None:  # direct _inject callers outside the admit loop
                coded = req.layout.encode_file(req.payload, codec=self.codec,
                                               n=req.n, k=req.k)
            req.n_issued = req.n
            for ci in range(req.n):
                off, ln = req.layout.chunk_range(req.k, ci)
                self._task_q.put((req, int(ci), coded[off : off + ln]))

    def _worker(self):
        while True:
            item = self._task_q.get()
            if item is None:
                return
            req, ci, blob = item
            if req.cancelled:
                continue
            with self._state_lock:
                self._idle -= 1
            if req.t_first_start is None:
                req.t_first_start = time.monotonic()
            try:
                if req.op == "read":
                    off, ln = req.layout.chunk_range(req.k, ci)
                    data = self.store.get_range(req.key, off, ln)
                else:
                    self.store.upload_part(req.key, ci, blob)
                    data = blob
                ok = True
            except StorageError:
                ok = False
            finally:
                with self._state_lock:
                    self._idle += 1
            self._on_task_done(req, ci, data if ok else None, ok)

    def _on_task_done(self, req: _Request, ci: int, data, ok: bool):
        assemble = False
        with req.lock:
            if req.op == "read":
                if req.cancelled:
                    return
                if ok:
                    req.completed[ci] = data
                else:
                    req.failures += 1
                if len(req.completed) >= req.k:
                    req.cancelled = True  # preemptive cancellation of the rest
                    if not req.raw:
                        # Hand off to the admit loop: the round's completions
                        # reconstruct together in one batched decode.
                        self._decode_q.put(req)
                        self._request_q.put(_WAKE)
                        if self._shutdown:
                            # The admit loop may already have done its final
                            # flush; decode inline so the waiter isn't stranded.
                            self._flush_completed_reads()
                    else:
                        self._finish(req, True)
                elif req.failures > req.n_issued - req.k:
                    req.cancelled = True
                    self._finish(req, False)
                return
            # write: never cancelled — uploads past the k-th durable part run
            # as background tasks (footnote 1).
            if ok:
                req.completed[ci] = data
            else:
                req.failures += 1
            if req.result is None:
                if len(req.completed) >= req.k:
                    self._finish(req, True)
                elif req.failures > req.n_issued - req.k:
                    self._finish(req, False)
            if len(req.completed) + req.failures >= req.n_issued:
                assemble = True
        if assemble:
            self._finalize_write(req)

    def _finalize_write(self, req: _Request) -> None:
        """All issued uploads resolved: assemble the durable parts into the
        readable coded object and record its strips in the write registry.

        Failed chunks leave zero-filled holes; the registry keeps reads off
        them. Runs on the worker that resolved the last task (background —
        off the request's completion path).
        """
        with obs.span("proxy.finalize_write", key=req.key, n=req.n, k=req.k):
            self._finalize_write_inner(req)

    def _finalize_write_inner(self, req: _Request) -> None:
        try:
            _, _, m = req.layout.code_for_k(req.k)
            b = req.layout.strip_bytes
            if req.completed:
                obj = bytearray(req.n_issued * m * b)
                strips: set[int] = set()
                for ci, blob in req.completed.items():
                    off, ln = req.layout.chunk_range(req.k, ci)
                    obj[off:off + ln] = blob
                    strips.update(range(ci * m, (ci + 1) * m))
                try:
                    self.store.put(req.key, bytes(obj))
                    with self._state_lock:
                        self._written[req.key] = strips
                except StorageError:
                    _log.warning("write finalize failed for %r", req.key)
        finally:
            req.settled.set()

    def _finish(self, req: _Request, ok: bool, data: bytes | None = None):
        chunks = None
        if req.op == "read" and req.raw:
            # Raw reads surface whatever chunks arrived even on failure: a
            # partially-failed batch item carries its own per-item error mask
            # (ok=False) + partial data instead of wedging the whole batch.
            chunks = dict(req.completed)
        elif ok and req.op == "read" and data is None:
            # direct callers bypassing the admit loop
            data = req.layout.reconstruct(req.k, req.completed, req.payload_len,
                                          codec=self.codec)
        # writes: k parts durable → request complete; the remaining uploads
        # keep running in background (footnote 1) and _finalize_write
        # assembles the readable object once they all resolve.
        req.result = RequestResult(
            key=req.key,
            op=req.op,
            n=req.n,
            k=req.k,
            ok=ok,
            data=data,
            t_arrival=req.t_arrival,
            t_first_start=req.t_first_start or time.monotonic(),
            t_done=time.monotonic(),
            failures=req.failures,
            chunks=chunks,
        )
        self.results.append(req.result)
        req.done.set()


def store_coded_object(store: ObjectStore, key: str, layout: SharedKeyLayout, payload: bytes):
    """Pre-code and store a file for later proxy reads (paper: files are
    pre-coded with the (n_max, k) code and stored on the cloud)."""
    store.put(key, layout.encode_file(payload))
