"""Uniform run metadata stamped into every BENCH_*.json artifact.

The perf-regression gate (benchmarks/gate.py) keys its tolerances off these
fields — a wallclock number recorded on a 4-core CI runner is not comparable
to one from a 32-core dev box, but a compile count is.
"""
from __future__ import annotations

import os
import subprocess

# Version of the *meta block* shared by all artifacts (each artifact keeps
# its own "schema" path string for payload layout).
SCHEMA_VERSION = 2


def git_rev() -> str | None:
    """Short rev of the repo containing this file; None outside a checkout."""
    try:
        proc = subprocess.run(
            ["git", "rev-parse", "--short=12", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True,
            text=True,
            timeout=10,
        )
    except (OSError, subprocess.SubprocessError):
        return None
    rev = proc.stdout.strip()
    return rev if proc.returncode == 0 and rev else None


def run_meta(mesh_shape=None) -> dict:
    import jax

    return {
        "schema_version": SCHEMA_VERSION,
        "git_rev": git_rev(),
        "host_cores": os.cpu_count() or 1,
        "host_devices": jax.device_count(),
        "mesh_shape": list(mesh_shape) if mesh_shape else [],
    }
