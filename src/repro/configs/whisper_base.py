"""whisper-base [audio]: enc-dec, conv frontend STUBBED (precomputed frames).

6L (enc) + 6L (dec), d_model=512, 8H MHA (kv=8), d_ff=2048, vocab=51865.
[arXiv:2212.04356]
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="encdec",
    n_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab=51865,
    mlp_act="gelu",
    glu=False,
    qkv_bias=True,
    encoder_layers=6,
    encoder_seq=1500,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, encoder_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=256, encoder_seq=16,
    )
