"""Perf-regression gate over the ``BENCH_*.json`` artifacts.

Every bench leg writes a ``benchmarks/results/BENCH_*.json`` artifact; this
module normalizes each into a flat list of named metrics and compares them
against the committed baselines in ``benchmarks/baselines/``, failing CI on
regressions. Three metric kinds with different contracts:

* ``count`` — structural integers (grid sizes, compile/launch counts). The
  artifact-level twin of the test-suite compile pins: any drift fails.
* ``stat`` — deterministic simulation outputs (headline ratios, capacity
  estimates, interference spreads). Seeded RNG makes these host-independent,
  so they gate at a tight relative tolerance (default 10%) in BOTH
  directions — an unexplained improvement is as suspicious as a regression.
* ``wallclock`` — req/s, ms, speedups. Shared CI cores make these noisy, so
  they only *warn* past their (generous) tolerance unless
  ``--strict-wallclock``; the direction is inferred from the unit (higher
  req/s and x good, lower ms good).

A results file with no committed baseline passes with a note (so new bench
legs land before their baseline), as do metrics present on only one side of
an ``--update``d schema change — but a metric the baseline has and the new
run lost is a coverage regression and fails.

Usage::

    python benchmarks/gate.py --check benchmarks/results/
    python benchmarks/gate.py --update benchmarks/results/   # refresh baselines
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys

BASELINES_DIR = os.path.join(os.path.dirname(__file__), "baselines")

TOL_STAT = 0.10
TOL_WALLCLOCK = 0.50

# Units where larger is better; anything else (ms, s, MB) regresses upward.
_HIGHER_BETTER_UNITS = {"req/s", "x", "ratio"}


def _metric(metrics: dict, name: str, value, kind: str, unit: str = "") -> None:
    if value is None:
        return
    try:
        value = float(value)
    except (TypeError, ValueError):
        return
    metrics[name] = {"value": value, "kind": kind, "unit": unit}


def _frontier_metrics(art: dict, metrics: dict) -> None:
    """Shared normalizer for the fleet/taskq frontier artifact layout."""
    for name in ("grid_size", "count", "compiles", "launches"):
        _metric(metrics, name, art.get(name), "count")
    for pol, cap in (art.get("capacity_req_s") or {}).items():
        _metric(metrics, f"capacity_req_s/{pol}", cap, "stat", "req/s")
    head = art.get("headline") or {}
    for name in ("delay_gain_vs_basic", "capacity_gain_vs_latency_optimal",
                 "tofec_light_mean", "basic_light_mean"):
        _metric(metrics, f"headline/{name}", head.get(name), "stat")
    # Flight-recorder zoom (taskq only): structural counts of the replayed
    # cell's per-request records — drift means the recorder lost coverage.
    flight = art.get("flight") or {}
    for name in ("requests", "records", "exemplars"):
        _metric(metrics, f"flight/{name}", flight.get(name), "count")


def _multiclass_metrics(art: dict, metrics: dict) -> None:
    for name in ("grid_size", "count", "compiles", "launches"):
        _metric(metrics, name, art.get(name), "count")
    for disc, entry in (art.get("interference") or {}).items():
        _metric(metrics, f"interference/{disc}/jain_delay",
                entry.get("jain_delay"), "stat")
        _metric(metrics, f"interference/{disc}/p99_spread",
                entry.get("p99_spread"), "stat")


def _serve_metrics(art: dict, metrics: dict) -> None:
    for name in ("rounds", "steps", "prompt_len"):
        _metric(metrics, name, art.get(name), "count")
    for rec in art.get("results") or []:
        b = rec.get("batch")
        _metric(metrics, f"batch{b}/fused_req_per_s",
                rec.get("fused_req_per_s"), "wallclock", "req/s")
        _metric(metrics, f"batch{b}/speedup", rec.get("speedup"),
                "wallclock", "x")
    # Timeline/SLO block from the collected post-timing pass: the pick
    # series ignores wallclock (threshold lane on a constant backlog
    # signal), so the convergence round is structurally deterministic;
    # dwell is a simulation statistic.
    slo = art.get("slo") or {}
    _metric(metrics, "slo/settle_round", slo.get("settle_round"), "count")
    _metric(metrics, "slo/dwell_final", slo.get("dwell_final"), "stat")


def _shard_metrics(art: dict, metrics: dict) -> None:
    for name in ("grid", "count", "big_grid", "big_count"):
        _metric(metrics, name, art.get(name), "count")
    _metric(metrics, "baseline_materialized_ms",
            art.get("baseline_materialized_ms"), "wallclock", "ms")
    _metric(metrics, "big_grid_ms", art.get("big_grid_ms"), "wallclock", "ms")
    for row in art.get("scaling") or []:
        _metric(metrics, f"d{row.get('devices')}/ms", row.get("ms"),
                "wallclock", "ms")


_NORMALIZERS = {
    "repro.fleet/BENCH_fleet": _frontier_metrics,
    "repro.taskq/BENCH_taskq": _frontier_metrics,
    "repro.sched/BENCH_multiclass": _multiclass_metrics,
    "repro.serve/BENCH_serve": _serve_metrics,
    "repro.fleet/BENCH_shard": _shard_metrics,
}


def normalize(artifact: dict) -> dict:
    """Artifact dict → ``{name: {value, kind, unit}}`` flat metric map.

    Unknown schemas normalize to the empty map (pass-through) so a new
    artifact can land before the gate learns to read it.
    """
    schema = str(artifact.get("schema", ""))
    fn = _NORMALIZERS.get(schema.rsplit("/", 1)[0])
    metrics: dict = {}
    if fn is not None:
        fn(artifact, metrics)
    return metrics


def _regresses(name: str, base: dict, new: dict,
               tol_stat: float, tol_wc: float):
    """Compare one metric; returns (level, message) or None.

    ``level`` is ``"fail"`` or ``"warn"``.
    """
    bv, nv = base["value"], new["value"]
    kind = base.get("kind", new.get("kind", "stat"))
    if kind == "count":
        if nv != bv:
            return "fail", f"{name}: count {bv:g} -> {nv:g}"
        return None
    denom = abs(bv) if bv else 1.0
    rel = (nv - bv) / denom
    if kind == "stat":
        if abs(rel) > tol_stat:
            return "fail", (f"{name}: {bv:.4g} -> {nv:.4g} "
                            f"({rel:+.1%}, tol ±{tol_stat:.0%})")
        return None
    # wallclock: regression direction from the unit
    worse = -rel if base.get("unit") in _HIGHER_BETTER_UNITS else rel
    if worse > tol_wc:
        return "warn", (f"{name}: {bv:.4g} -> {nv:.4g} {base.get('unit', '')} "
                        f"({worse:+.1%} worse, tol {tol_wc:.0%})")
    return None


def check_file(result_path: str, baseline_path: str, *,
               tol_stat: float = TOL_STAT, tol_wc: float = TOL_WALLCLOCK):
    """Gate one artifact; returns (fails, warns, notes) message lists."""
    fails: list = []
    warns: list = []
    notes: list = []
    with open(result_path) as f:
        new = normalize(json.load(f))
    if not os.path.exists(baseline_path):
        notes.append(f"no baseline for {os.path.basename(result_path)} (pass)")
        return fails, warns, notes
    with open(baseline_path) as f:
        base = json.load(f).get("metrics", {})
    for name, bm in sorted(base.items()):
        nm = new.get(name)
        if nm is None:
            if bm.get("kind") == "wallclock":
                warns.append(f"{name}: wallclock metric missing from new run")
            else:
                fails.append(f"{name}: metric missing from new run")
            continue
        hit = _regresses(name, bm, nm, tol_stat, tol_wc)
        if hit is not None:
            (fails if hit[0] == "fail" else warns).append(hit[1])
    for name in sorted(set(new) - set(base)):
        notes.append(f"{name}: new metric, no baseline (pass)")
    return fails, warns, notes


def _result_files(results_dir: str) -> list:
    return sorted(glob.glob(os.path.join(results_dir, "BENCH_*.json")))


def update(results_dir: str, baselines_dir: str) -> list:
    """Rewrite the committed baselines from a results directory."""
    os.makedirs(baselines_dir, exist_ok=True)
    written = []
    for path in _result_files(results_dir):
        with open(path) as f:
            art = json.load(f)
        metrics = normalize(art)
        if not metrics:
            continue
        out = {
            "schema": art.get("schema"),
            "meta": art.get("meta"),
            "metrics": metrics,
        }
        dst = os.path.join(baselines_dir, os.path.basename(path))
        with open(dst, "w") as f:
            json.dump(out, f, indent=1, sort_keys=True)
        written.append(dst)
    return written


def check(results_dir: str, baselines_dir: str, *,
          tol_stat: float = TOL_STAT, tol_wc: float = TOL_WALLCLOCK,
          strict_wallclock: bool = False) -> int:
    """Gate every artifact in ``results_dir``; returns the exit code."""
    paths = _result_files(results_dir)
    if not paths:
        print(f"gate: no BENCH_*.json under {results_dir} (nothing to check)")
        return 0
    n_fail = 0
    for path in paths:
        name = os.path.basename(path)
        fails, warns, notes = check_file(
            path, os.path.join(baselines_dir, name),
            tol_stat=tol_stat, tol_wc=tol_wc,
        )
        if strict_wallclock:
            fails, warns = fails + warns, []
        status = "FAIL" if fails else "ok"
        print(f"gate: {name}: {status} "
              f"({len(fails)} fail, {len(warns)} warn, {len(notes)} note)")
        for msg in fails:
            print(f"  FAIL {msg}")
        for msg in warns:
            print(f"  warn {msg}")
        for msg in notes:
            print(f"  note {msg}")
        n_fail += len(fails)
    if n_fail:
        print(f"gate: {n_fail} regression(s); refresh intended changes with "
              f"`python benchmarks/gate.py --update <results-dir>`")
        return 1
    print("gate: all artifacts within tolerance")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    mode = ap.add_mutually_exclusive_group(required=True)
    mode.add_argument("--check", metavar="DIR",
                      help="gate a results directory against the baselines")
    mode.add_argument("--update", metavar="DIR",
                      help="rewrite the baselines from a results directory")
    ap.add_argument("--baselines", default=BASELINES_DIR,
                    help="baseline directory (default: benchmarks/baselines)")
    ap.add_argument("--tol-stat", type=float, default=TOL_STAT)
    ap.add_argument("--tol-wallclock", type=float, default=TOL_WALLCLOCK)
    ap.add_argument("--strict-wallclock", action="store_true",
                    help="promote wallclock warnings to failures")
    args = ap.parse_args(argv)
    if args.update:
        for dst in update(args.update, args.baselines):
            print(f"gate: wrote {dst}")
        return 0
    return check(args.check, args.baselines, tol_stat=args.tol_stat,
                 tol_wc=args.tol_wallclock,
                 strict_wallclock=args.strict_wallclock)


if __name__ == "__main__":
    sys.exit(main())
