"""Serving engine: storage-fed prompts → prefill → batched decode."""

import numpy as np

from repro.coding.layout import SharedKeyLayout
from repro.core import StaticPolicy
from repro.models import get
from repro.serve import ServingEngine
from repro.storage import MemoryStore, Proxy

import jax


def test_generate_shapes_and_determinism():
    arch = get("qwen1.5-0.5b", smoke=True)
    params = arch.init(jax.random.key(0))
    eng = ServingEngine(arch, params, max_seq=64)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, arch.cfg.vocab, size=(3, 8)).astype(np.int32)
    out1 = eng.generate(prompts, steps=5)
    out2 = eng.generate(prompts, steps=5)
    assert out1.shape == (3, 5)
    np.testing.assert_array_equal(out1, out2)


def test_serve_via_erasure_coded_prompt_storage():
    arch = get("qwen1.5-0.5b", smoke=True)
    params = arch.init(jax.random.key(1))
    eng = ServingEngine(arch, params, max_seq=64)

    prompt_len = 16
    layout = SharedKeyLayout(K=4, r=2, strip_bytes=prompt_len)  # 4·16B strips
    store = MemoryStore()
    rng = np.random.default_rng(2)
    keys = []
    truth = []
    for i in range(3):
        toks = rng.integers(0, arch.cfg.vocab, size=(prompt_len,)).astype(np.int32)
        key = f"prompt/{i}"
        ServingEngine.store_prompt(store, key, layout, toks)
        keys.append(key)
        truth.append(toks)

    proxy = Proxy(store, StaticPolicy(4, 2), L=8)
    try:
        res = eng.serve(proxy, layout, keys, prompt_len=prompt_len, steps=4)
        assert res.tokens.shape == (3, 4)
        assert all(c == (4, 2) for c in res.codes)
        # Cross-check: direct generation from the ground-truth prompts.
        direct = eng.generate(np.stack(truth), steps=4)
        np.testing.assert_array_equal(res.tokens, direct)
    finally:
        proxy.close()
