"""Mixture-of-Experts MLP: token-choice top-k routing with per-row capacity.

GShard-style static-shape dispatch adapted to TPU/GSPMD:
  * tokens are grouped by batch row (the data-sharded axis), so the
    dispatch scatter and combine gather stay shard-local under pjit;
  * per-row expert capacity C = ceil(cf · S · top_k / E); overflow tokens
    drop to the residual path (standard capacity-based dropping);
  * expert FFNs run as one batched einsum over (E, C) slots with d_ff
    sharded over the "model" axis (TP-within-expert — E=8 does not divide
    the 16-way model axis, see DESIGN.md §5).

Returns (output, aux_load_balance_loss).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import _act, dt, init_dense, use_weight
from repro.models.sharding import constrain


def init_moe(rng, cfg: ModelConfig):
    ks = jax.random.split(rng, 4)
    E, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    scale = 1.0 / math.sqrt(d)

    def expert_stack(key, d_in, d_out):
        return (jax.random.normal(key, (E, d_in, d_out), jnp.float32) * scale).astype(dt(cfg))

    p = {
        "router": init_dense(ks[0], d, E, jnp.float32),
        "wi": expert_stack(ks[1], d, f),
        "wo": expert_stack(ks[2], f, d),
    }
    if cfg.glu:
        p["wg"] = expert_stack(ks[3], d, f)
    return p


def moe_logical_axes(cfg: ModelConfig):
    p = {
        "router": ("embed", None),
        "wi": ("experts", "embed", "ff"),
        "wo": ("experts", "ff", "embed"),
    }
    if cfg.glu:
        p["wg"] = ("experts", "embed", "ff")
    return p


def moe_mlp(
    params, cfg: ModelConfig, x: jax.Array, *, dropless: bool = False
) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) → (B, S, d), aux loss. Dispatch is per batch row.

    ``dropless=True`` sizes per-row capacity at its tight upper bound C = S
    (a token contributes each expert at most once), so no token is ever
    dropped. Inference paths (prefill / decode) use this: capacity dropping
    is a *training-time* load-balancing economy, and at S=1 a decode step
    can never drop — so prefill must not drop either, or teacher-forcing
    decode-vs-prefill parity breaks on exactly the overflowed tokens.

    Cost note: dropless dispatch buffers are (B, E, S, d) — roughly
    E/(K·capacity_factor) × the capacity-bounded path — so long-context
    prefill pays dense worst-case slots for a sparse dispatch. A
    sort/segment-based dropless dispatch removes that overhead (ROADMAP
    open item); at decode (S=1) the two paths cost the same.
    """
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    if dropless:
        C = S
    else:
        C = max(1, int(math.ceil(cfg.capacity_factor * S * K / E)))

    gates = (x.astype(jnp.float32) @ params["router"])  # (B, S, E)
    probs = jax.nn.softmax(gates, axis=-1)
    topw, topi = jax.lax.top_k(probs, K)  # (B, S, K)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    # Aux load-balancing loss (GShard §2.2): E · Σ_e f_e · p̄_e.
    me = jnp.mean(probs, axis=(0, 1))  # (E,)
    assign = jax.nn.one_hot(topi[..., 0], E, dtype=jnp.float32)
    fe = jnp.mean(assign, axis=(0, 1))
    aux = E * jnp.sum(fe * me)

    # Position of each (token, choice) within its expert, per batch row.
    flat_i = topi.reshape(B, S * K)  # (B, T') with T' = S·K
    onehot = jax.nn.one_hot(flat_i, E, dtype=jnp.int32)  # (B, T', E)
    pos = jnp.cumsum(onehot, axis=1) - 1  # (B, T', E)
    pos_in_e = jnp.take_along_axis(pos, flat_i[..., None], axis=2)[..., 0]  # (B, T')
    keep = pos_in_e < C
    slot = jnp.where(keep, pos_in_e, C)  # overflow slot C is discarded

    # Dispatch: scatter tokens into (B, E, C+1, d) slots (row-local).
    xt = jnp.repeat(x, K, axis=1)  # (B, T', d) token repeated per choice
    b_idx = jnp.arange(B)[:, None] * jnp.ones_like(flat_i)
    buf = jnp.zeros((B, E, C + 1, d), x.dtype)
    buf = buf.at[b_idx, flat_i, slot].add(xt)
    buf = buf[:, :, :C]  # (B, E, C, d)
    buf = constrain(buf, "batch", "experts", None, None)

    # Expert FFN over slots; d_ff TP-sharded over "model". Contractions
    # accumulate in fp32 (MXU-native); operands stay in cfg.dtype.
    wi = use_weight(cfg, params["wi"], None, None, "ff")
    h = jnp.einsum("becd,edf->becf", buf, wi, preferred_element_type=jnp.float32)
    if cfg.glu:
        wg = use_weight(cfg, params["wg"], None, None, "ff")
        g = jnp.einsum("becd,edf->becf", buf, wg, preferred_element_type=jnp.float32)
        h = _act(cfg, g) * h
    else:
        h = _act(cfg, h)
    h = constrain(h, "batch", "experts", None, "ff").astype(x.dtype)
    wo = use_weight(cfg, params["wo"], None, "ff", None)
    y = jnp.einsum("becf,efd->becd", h, wo, preferred_element_type=jnp.float32)

    # Combine in fp32: gather each choice's slot, weight, sum over K.
    y = jnp.concatenate([y, jnp.zeros((B, E, 1, d), y.dtype)], axis=2)
    yt = y[b_idx, flat_i, slot]  # (B, T', d) fp32
    yt = yt * (topw.reshape(B, S * K)[..., None] * keep[..., None])
    out = yt.reshape(B, S, K, d).sum(axis=2).astype(x.dtype)
    return constrain(out, "batch", None, None), aux
