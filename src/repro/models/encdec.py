"""Encoder-decoder model (whisper-base backbone).

The audio conv frontend is a STUB per the task spec: ``input_specs`` feeds
precomputed frame embeddings (B, encoder_seq, d_model). Encoder blocks are
bidirectional; decoder blocks are causal self-attn + cross-attn + MLP.
Layer counts are small (6+6) so layers run as a Python loop over per-layer
params (no scan needed)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as ly
from repro.models.config import ModelConfig
from repro.models.lm import chunked_ce_loss
from repro.models.sharding import constrain


def _init_enc_block(rng, cfg: ModelConfig):
    ks = jax.random.split(rng, 2)
    return {
        "ln1": ly.init_rmsnorm(cfg.d_model, ly.dt(cfg)),
        "attn": ly.init_attention(ks[0], cfg),
        "ln2": ly.init_rmsnorm(cfg.d_model, ly.dt(cfg)),
        "mlp": ly.init_mlp(ks[1], cfg),
    }


def _init_dec_block(rng, cfg: ModelConfig):
    ks = jax.random.split(rng, 3)
    return {
        "ln1": ly.init_rmsnorm(cfg.d_model, ly.dt(cfg)),
        "self_attn": ly.init_attention(ks[0], cfg),
        "ln_x": ly.init_rmsnorm(cfg.d_model, ly.dt(cfg)),
        "cross_attn": ly.init_attention(ks[1], cfg),
        "ln2": ly.init_rmsnorm(cfg.d_model, ly.dt(cfg)),
        "mlp": ly.init_mlp(ks[2], cfg),
    }


def init(rng, cfg: ModelConfig):
    k_emb, k_enc, k_dec = jax.random.split(rng, 3)
    enc_keys = jax.random.split(k_enc, cfg.encoder_layers)
    dec_keys = jax.random.split(k_dec, cfg.n_layers)
    return {
        "embedding": ly.init_embedding(k_emb, cfg),
        "encoder": [_init_enc_block(k, cfg) for k in enc_keys],
        "decoder": [_init_dec_block(k, cfg) for k in dec_keys],
        "ln_enc": ly.init_rmsnorm(cfg.d_model, ly.dt(cfg)),
        "ln_f": ly.init_rmsnorm(cfg.d_model, ly.dt(cfg)),
    }


def logical_axes(cfg: ModelConfig):
    attn = ly.attention_logical_axes(cfg)
    mlp = ly.mlp_logical_axes(cfg)
    norm = {"scale": (None,)}
    enc = {"ln1": norm, "attn": attn, "ln2": norm, "mlp": mlp}
    dec = {
        "ln1": norm, "self_attn": attn, "ln_x": norm,
        "cross_attn": attn, "ln2": norm, "mlp": mlp,
    }
    return {
        "embedding": ly.embedding_logical_axes(cfg),
        "encoder": [enc for _ in range(cfg.encoder_layers)],
        "decoder": [dec for _ in range(cfg.n_layers)],
        "ln_enc": norm,
        "ln_f": norm,
    }


def encode(params, cfg: ModelConfig, frames):
    """frames: (B, T, d) stub embeddings → encoder memory (B, T, d)."""
    x = frames.astype(ly.dt(cfg))
    x = constrain(x, "batch", None, None)
    for blk in params["encoder"]:
        h = ly.rmsnorm(blk["ln1"], x)
        x = x + ly.attention(blk["attn"], cfg, h, causal=False)
        h = ly.rmsnorm(blk["ln2"], x)
        x = x + ly.mlp(blk["mlp"], cfg, h)
    return ly.rmsnorm(params["ln_enc"], x)


def _decoder_stack(params, cfg: ModelConfig, x, memory):
    B, S, _ = x.shape
    mem_pos = jnp.arange(memory.shape[1])[None, :].astype(jnp.int32)
    for blk in params["decoder"]:
        h = ly.rmsnorm(blk["ln1"], x)
        x = x + ly.attention(blk["self_attn"], cfg, h, causal=True)
        h = ly.rmsnorm(blk["ln_x"], x)
        mk, mv = _cross_kv(blk["cross_attn"], cfg, memory, mem_pos)
        x = x + ly.attention(blk["cross_attn"], cfg, h, causal=False, kv_override=(mk, mv))
        h = ly.rmsnorm(blk["ln2"], x)
        x = x + ly.mlp(blk["mlp"], cfg, h)
        x = constrain(x, "batch", "seq_sp", None)
    return ly.rmsnorm(params["ln_f"], x)


def _cross_kv(attn_params, cfg: ModelConfig, memory, mem_pos):
    B, T, _ = memory.shape
    hd = cfg.hd
    k = (memory @ attn_params["wk"])
    v = (memory @ attn_params["wv"])
    if cfg.qkv_bias:
        k = k + attn_params["bk"]
        v = v + attn_params["bv"]
    k = ly.rope(k.reshape(B, T, cfg.n_kv_heads, hd), mem_pos, cfg.rope_theta)
    v = v.reshape(B, T, cfg.n_kv_heads, hd)
    return k, v


def train_loss(params, cfg: ModelConfig, batch) -> jax.Array:
    memory = encode(params, cfg, batch["frames"])
    x = ly.embed(params["embedding"], cfg, batch["tokens"])
    x = _decoder_stack(params, cfg, x, memory)
    return chunked_ce_loss(params, cfg, x, batch["labels"])


# -- serving ----------------------------------------------------------------


def init_cache(cfg: ModelConfig, B: int, max_seq: int):
    L, Hkv, hd, T = cfg.n_layers, cfg.n_kv_heads, cfg.hd, cfg.encoder_seq
    return {
        "k": jnp.zeros((L, B, max_seq, Hkv, hd), ly.dt(cfg)),
        "v": jnp.zeros((L, B, max_seq, Hkv, hd), ly.dt(cfg)),
        "slot_pos": jnp.full((L, max_seq), -(2**30), jnp.int32),
        "cross_k": jnp.zeros((L, B, T, Hkv, hd), ly.dt(cfg)),
        "cross_v": jnp.zeros((L, B, T, Hkv, hd), ly.dt(cfg)),
        "pos": jnp.int32(0),
    }


def prefill(params, cfg: ModelConfig, batch, max_seq: int | None = None):
    """Encode frames, run prompt tokens, prime self- and cross-caches."""
    memory = encode(params, cfg, batch["frames"])
    tokens = batch["tokens"]
    B, S = tokens.shape
    max_seq = max_seq or S
    x = ly.embed(params["embedding"], cfg, tokens)
    mem_pos = jnp.arange(memory.shape[1])[None, :].astype(jnp.int32)
    positions = jnp.arange(S)[None, :].astype(jnp.int32)
    cks, cvs, sps, xks, xvs = [], [], [], [], []
    for blk in params["decoder"]:
        h = ly.rmsnorm(blk["ln1"], x)
        q, k, v = ly._project_qkv(blk["self_attn"], cfg, h, positions)
        attn = ly.chunked_attention(cfg, q, k, v, causal=True, window=None, softcap=None)
        x = x + attn.reshape(B, S, -1) @ blk["self_attn"]["wo"]
        ck, cv, sp = ly.fill_cache_from_prefill(k, v, max_seq)
        cks.append(ck), cvs.append(cv), sps.append(sp)
        h = ly.rmsnorm(blk["ln_x"], x)
        mk, mv = _cross_kv(blk["cross_attn"], cfg, memory, mem_pos)
        xks.append(mk), xvs.append(mv)
        x = x + ly.attention(blk["cross_attn"], cfg, h, causal=False, kv_override=(mk, mv))
        h = ly.rmsnorm(blk["ln2"], x)
        x = x + ly.mlp(blk["mlp"], cfg, h)
    x = ly.rmsnorm(params["ln_f"], x)
    last = ly.logits(params["embedding"], cfg, x[:, -1:])
    cache = {
        "k": jnp.stack(cks), "v": jnp.stack(cvs), "slot_pos": jnp.stack(sps),
        "cross_k": jnp.stack(xks), "cross_v": jnp.stack(xvs), "pos": jnp.int32(S),
    }
    return last, cache


def decode_step(params, cfg: ModelConfig, token, cache):
    x = ly.embed(params["embedding"], cfg, token)
    pos = cache["pos"]
    ck_out, cv_out, sp_out = [], [], []
    for li, blk in enumerate(params["decoder"]):
        h = ly.rmsnorm(blk["ln1"], x)
        out, ck, cv, sp = ly.decode_attention(
            blk["self_attn"], cfg, h, cache["k"][li], cache["v"][li],
            cache["slot_pos"][li], pos,
        )
        ck_out.append(ck), cv_out.append(cv), sp_out.append(sp)
        x = x + out
        h = ly.rmsnorm(blk["ln_x"], x)
        B = x.shape[0]
        hd = cfg.hd
        q = (h @ blk["cross_attn"]["wq"])
        if cfg.qkv_bias:
            q = q + blk["cross_attn"]["bq"]
        q = q.reshape(B, 1, cfg.n_heads, hd)
        q = ly.rope(q, jnp.zeros((B, 1), jnp.int32), cfg.rope_theta)
        mk, mv = cache["cross_k"][li], cache["cross_v"][li]
        G = cfg.q_per_kv
        qh = q.reshape(B, 1, cfg.n_kv_heads, G, hd)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qh, mk, preferred_element_type=jnp.float32)
        p = jax.nn.softmax(s / (hd ** 0.5), axis=-1)
        o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(mv.dtype), mv)
        x = x + o.reshape(B, 1, -1) @ blk["cross_attn"]["wo"]
        h = ly.rmsnorm(blk["ln2"], x)
        x = x + ly.mlp(blk["mlp"], cfg, h)
    x = ly.rmsnorm(params["ln_f"], x)
    lg = ly.logits(params["embedding"], cfg, x)
    new_cache = dict(cache)
    new_cache.update(
        k=jnp.stack(ck_out), v=jnp.stack(cv_out), slot_pos=jnp.stack(sp_out), pos=pos + 1
    )
    return lg, new_cache


def cache_logical_axes(cfg: ModelConfig, B: int):
    if B == 1:
        kv = (None, None, "kv_seq", None, None)
    elif cfg.decode_cache_seq_shard:
        kv = (None, "batch", "kv_seq", None, None)
    else:
        kv = (None, "batch", None, "kv_heads", None)
    xkv = (None, "batch", None, "kv_heads", None)
    return {
        "k": kv, "v": kv, "slot_pos": (None, None),
        "cross_k": xkv, "cross_v": xkv, "pos": (),
    }
