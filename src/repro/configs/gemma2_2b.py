"""gemma2-2b [dense]: local+global alternating attention, logit softcaps.

26L, d_model=2304, 8H (GQA kv=4), head_dim=256, d_ff=9216, vocab=256000,
local window 4096, attn softcap 50, final logit softcap 30. [arXiv:2408.00118]
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab=256000,
    mlp_act="gelu",
    glu=True,
    local_global_period=2,
    local_window=4096,
    attn_softcap=50.0,
    logit_softcap=30.0,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512, local_window=8,
    )
