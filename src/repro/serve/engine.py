"""Batched serving engine with TOFEC-admitted prompt storage.

Flow per request: the prompt blob is fetched from the object store through
the TOFEC proxy (erasure-coded ranged reads, adaptive (n, k) from the proxy
backlog), tokenized prompts are batched, prefilled, and decoded with the
arch's cached ``decode_step``. The storage path is the paper's system; the
LM path is the substrate it feeds.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.coding.layout import SharedKeyLayout
from repro.models.registry import Arch
from repro.storage.proxy import Proxy, store_coded_object


@dataclasses.dataclass
class ServeResult:
    tokens: np.ndarray  # (B, steps) generated ids
    storage_total_s: list[float]  # per-request proxy read delays
    codes: list[tuple[int, int]]  # (n, k) used per prompt fetch


class ServingEngine:
    def __init__(self, arch: Arch, params, *, max_seq: int = 128):
        self.arch = arch
        self.params = params
        self.max_seq = max_seq
        self._prefill = jax.jit(
            lambda p, b: arch.prefill(p, b, max_seq=self.max_seq)
        )
        self._decode = jax.jit(arch.decode_step)

    # -- storage integration -------------------------------------------------

    @staticmethod
    def store_prompt(store, key: str, layout: SharedKeyLayout, tokens: np.ndarray):
        store_coded_object(store, key, layout, tokens.astype(np.int32).tobytes())

    def fetch_prompts(
        self, proxy: Proxy, layout: SharedKeyLayout, keys: list[str], prompt_len: int
    ) -> tuple[np.ndarray, list[float], list[tuple[int, int]]]:
        toks, delays, codes = [], [], []
        for key in keys:
            res = proxy.read(key, layout, payload_len=prompt_len * 4)
            if not res.ok:
                raise RuntimeError(f"prompt fetch failed for {key}")
            toks.append(np.frombuffer(res.data, np.int32))
            delays.append(res.total_s)
            codes.append((res.n, res.k))
        return np.stack(toks), delays, codes

    # -- generation -----------------------------------------------------------

    def generate(self, prompts: np.ndarray, steps: int, *, greedy: bool = True) -> np.ndarray:
        """prompts: (B, S) int32 → (B, steps) generated ids."""
        batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
        if self.arch.cfg.family == "vlm":
            B = prompts.shape[0]
            batch["patches"] = jnp.zeros(
                (B, self.arch.cfg.vision_patches, self.arch.cfg.d_model), jnp.float32
            )
        if self.arch.cfg.family == "encdec":
            B = prompts.shape[0]
            batch["frames"] = jnp.zeros(
                (B, self.arch.cfg.encoder_seq, self.arch.cfg.d_model), jnp.float32
            )
        logits, cache = self._prefill(self.params, batch)
        out = []
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        for _ in range(steps):
            out.append(np.asarray(tok)[:, 0])
            logits, cache = self._decode(self.params, tok, cache)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return np.stack(out, axis=1)

    def serve(
        self,
        proxy: Proxy,
        layout: SharedKeyLayout,
        keys: list[str],
        *,
        prompt_len: int,
        steps: int,
    ) -> ServeResult:
        prompts, delays, codes = self.fetch_prompts(proxy, layout, keys, prompt_len)
        gen = self.generate(prompts, steps)
        return ServeResult(tokens=gen, storage_total_s=delays, codes=codes)
