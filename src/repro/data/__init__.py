from repro.data.pipeline import CodedShardReader, SyntheticTokens
