"""qwen1.5-0.5b [dense]: QKV bias, MHA-as-GQA (kv=16).

24L, d_model=1024, 16H (kv=16), d_ff=2816, vocab=151936. [hf:Qwen/Qwen1.5-0.5B]
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=2816,
    vocab=151936,
    qkv_bias=True,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=512,
    )
