"""Property tests: the chunked linear recurrence vs its sequential oracle.

The invariant behind every parallel-form recurrent block (mLSTM, Mamba2 SSD):
for ANY chunk size, outputs and final states must equal the step-by-step
recurrence. Hypothesis sweeps shapes, chunk sizes, gates, and dtypes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.models.ssm import chunk_linear_recurrence, linear_recurrence_step


def _oracle(q, k, v, log_a, gate_i, normalize):
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    state = jnp.zeros((B, H, dk, dv), jnp.float32)
    n_state = jnp.zeros((B, H, dk), jnp.float32)
    ys = []
    for t in range(S):
        y, state, n_state = linear_recurrence_step(
            q[:, t], k[:, t], v[:, t], log_a[:, t], gate_i[:, t],
            state, n_state, normalize=normalize,
        )
        ys.append(y)
    return jnp.stack(ys, axis=1), state, n_state


@given(
    st.integers(1, 3),   # B
    st.integers(1, 13),  # S
    st.integers(1, 2),   # H
    st.integers(1, 5),   # dk
    st.integers(1, 4),   # dv
    st.sampled_from([1, 2, 3, 4, 8]),  # chunk
    st.booleans(),       # normalize
    st.integers(0, 2**31 - 1),
)
@settings(max_examples=25, deadline=None)
def test_chunked_matches_sequential(B, S, H, dk, dv, chunk, normalize, seed):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, S, H, dk)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, dk)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, dv)), jnp.float32)
    log_a = jnp.asarray(-np.abs(rng.normal(size=(B, S, H))), jnp.float32)
    gate_i = jnp.asarray(rng.uniform(0, 1, size=(B, S, H)), jnp.float32)

    y, (Sf, nf) = chunk_linear_recurrence(
        q, k, v, log_a, gate_i, chunk=chunk, normalize=normalize
    )
    y_ref, S_ref, n_ref = _oracle(q, k, v, log_a, gate_i, normalize)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(Sf), np.asarray(S_ref), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(nf), np.asarray(n_ref), rtol=2e-4, atol=2e-4)


def test_unroll_flag_is_equivalent():
    rng = np.random.default_rng(0)
    B, S, H, dk, dv = 2, 12, 2, 4, 4
    args = [
        jnp.asarray(rng.normal(size=(B, S, H, dk)), jnp.float32),
        jnp.asarray(rng.normal(size=(B, S, H, dk)), jnp.float32),
        jnp.asarray(rng.normal(size=(B, S, H, dv)), jnp.float32),
        jnp.asarray(-np.abs(rng.normal(size=(B, S, H))), jnp.float32),
        jnp.asarray(rng.uniform(0, 1, size=(B, S, H)), jnp.float32),
    ]
    y1, _ = chunk_linear_recurrence(*args, chunk=4, unroll=False)
    y2, _ = chunk_linear_recurrence(*args, chunk=4, unroll=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-6)
