"""On-device per-class reductions for joint shared-pool sweeps.

Consumes a :class:`repro.sched.sweep.SchedResult` and produces the §IV-style
multi-class quantities the fluid split cannot: per-class delay percentiles
under cross-class interference, per-class chosen-code mixes, the Jain
fairness index of per-class mean delay, and the ``BENCH_multiclass.json``
artifact. Class membership is a runtime mask (``cls_ids``), so one jitted
reduction covers the whole (G, T) block: per-class percentiles route through
the shared :func:`repro.fleet.stats.masked_percentiles` helper (class-masked
sort + gather at the class's own count — lower-interpolation percentiles,
exact for the class sample).
"""

from __future__ import annotations

import dataclasses
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.fleet.stats import masked_percentiles


def jain_index(xs) -> float:
    """Jain's fairness index (Σx)²/(m·Σx²) ∈ (0, 1]; 1 = perfectly equal."""
    xs = np.asarray([x for x in xs], dtype=np.float64)
    if xs.size == 0:
        return 1.0
    denom = xs.size * np.sum(xs * xs)
    return float(np.sum(xs) ** 2 / denom) if denom > 0 else 1.0


@functools.partial(jax.jit, static_argnames=("C", "w"))
def _reduce_multiclass(out, *, C: int, w: int):
    """One jitted per-class reduction over the whole (G, T) result block."""
    tot = out["total"][:, w:]
    dq = out["queueing"][:, w:]
    nf = out["n"][:, w:].astype(jnp.float32)
    kf = out["k"][:, w:].astype(jnp.float32)
    ids = out["cls_ids"][:, w:]
    qs = jnp.asarray([50.0, 90.0, 95.0, 99.0])

    def one_class(c):
        mask = ids == c
        cnt = jnp.sum(mask, axis=1)
        safe = jnp.maximum(cnt, 1).astype(jnp.float32)

        def masked_mean(x):
            # A class with no post-warmup arrivals has no statistics: NaN,
            # matching masked_percentiles — not a silent 0.0.
            return jnp.where(cnt > 0, jnp.sum(jnp.where(mask, x, 0.0), axis=1) / safe,
                             jnp.nan)

        pct = masked_percentiles(tot, qs, mask)  # (G, 4)
        return {
            "count": cnt,
            "mean": masked_mean(tot),
            "p50": pct[:, 0], "p90": pct[:, 1], "p95": pct[:, 2], "p99": pct[:, 3],
            "mean_queueing": masked_mean(dq),
            "mean_k": masked_mean(kf),
            "mean_n": masked_mean(nf),
        }

    per = [one_class(c) for c in range(C)]
    red = {name: jnp.stack([p[name] for p in per], axis=1) for name in per[0]}  # (G, C)
    red["agg_mean"] = jnp.mean(tot, axis=1)
    # Lower interpolation, like the per-class percentiles: a pure sort +
    # gather stays bitwise identical under any mesh sharding of the grid
    # axis, where linear interpolation picks up layout-dependent rounding.
    red["agg_p99"] = jnp.percentile(tot, 99.0, axis=1, method="lower")
    return red


@dataclasses.dataclass
class MulticlassPoint:
    """Reduced statistics for one joint grid point: aggregate + per class."""

    discipline: str
    lam: float  # aggregate arrival rate of the mix
    seed: int
    mix_name: str
    L: int
    agg_mean: float
    agg_p99: float
    jain_delay: float  # Jain index of per-class mean delays
    classes: list[dict]  # per-class: name, lam, weight, mean, p50..p99, ...

    def cls(self, name: str) -> dict:
        return next(c for c in self.classes if c["name"] == name)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def multiclass_points(result, warmup_frac: float = 0.05) -> list[MulticlassPoint]:
    """Per-grid-point aggregate + per-class statistics, reduced on device.

    Streamed results (``SchedSweep.run(..., stream=...)``) reuse the
    statistics the per-chunk fold already accumulated — same values, no
    materialized (G, T) block."""
    streamed = getattr(result, "streamed", None)
    if streamed is not None:
        red = streamed.require(warmup_frac)
    else:
        C = max(len(case.mix.classes) for case in result.cases)
        red = _reduce_multiclass(result.out, C=C, w=int(result.count * warmup_frac))
        red = {k: np.asarray(v) for k, v in red.items()}
    points = []
    for i, case in enumerate(result.cases):
        classes = []
        for c, (cls, wt) in enumerate(zip(case.mix.classes, case.mix.weights)):
            classes.append({
                "name": cls.name,
                "lam": case.mix.lam * wt,
                "weight": wt,
                "count": int(red["count"][i, c]),
                "mean": float(red["mean"][i, c]),
                "p50": float(red["p50"][i, c]),
                "p90": float(red["p90"][i, c]),
                "p95": float(red["p95"][i, c]),
                "p99": float(red["p99"][i, c]),
                "mean_queueing": float(red["mean_queueing"][i, c]),
                "mean_k": float(red["mean_k"][i, c]),
                "mean_n": float(red["mean_n"][i, c]),
            })
        points.append(MulticlassPoint(
            discipline=case.discipline.name,
            lam=case.mix.lam,
            seed=case.seed,
            mix_name="+".join(c.name for c in case.mix.classes),
            L=case.L,
            agg_mean=float(red["agg_mean"][i]),
            agg_p99=float(red["agg_p99"][i]),
            jain_delay=jain_index([c["mean"] for c in classes if c["count"] > 0]),
            classes=classes,
        ))
    return points


def by_discipline(points: list[MulticlassPoint]) -> dict[str, list[MulticlassPoint]]:
    """Group by discipline, λ-sorted: per-class delay-vs-rate curves."""
    by: dict[str, list[MulticlassPoint]] = {}
    for pt in points:
        by.setdefault(pt.discipline, []).append(pt)
    for pts in by.values():
        pts.sort(key=lambda p: (p.lam, p.seed))
    return by


def interference_summary(
    joint: list[MulticlassPoint], split_p99: dict[str, float] | None = None
) -> dict:
    """Cross-class interference headline at the highest common λ.

    For each discipline at max λ: the spread of per-class p99 (max/min) and
    the Jain index. When ``split_p99`` (class name → the Poisson-split
    fleet's p99 prediction) is given, also reports per-class joint/split p99
    ratios — the quantity the fluid split gets wrong (≈1 for the
    high-priority class, ≫1 for the starved one).
    """
    out: dict = {}
    for name, pts in by_discipline(joint).items():
        p = pts[-1]
        p99s = [c["p99"] for c in p.classes if c["count"] > 0]
        entry = {
            "lam": p.lam,
            "jain_delay": p.jain_delay,
            "p99_spread": max(p99s) / max(min(p99s), 1e-12),
        }
        if split_p99:
            entry["p99_vs_split"] = {
                c["name"]: c["p99"] / split_p99[c["name"]]
                for c in p.classes
                if c["name"] in split_p99 and c["count"] > 0
            }
        out[name] = entry
    return out


def write_multiclass_artifact(
    path: str,
    result,
    *,
    warmup_frac: float = 0.05,
    extra: dict | None = None,
    points: list[MulticlassPoint] | None = None,
) -> dict:
    """Reduce a joint sweep and write the ``BENCH_multiclass.json`` artifact."""
    if points is None:
        points = multiclass_points(result, warmup_frac)
    artifact = {
        "schema": "repro.sched/BENCH_multiclass/v1",
        "meta": obs.run_meta(mesh_shape=getattr(result, "mesh_shape", ())),
        "grid_size": len(result.cases),
        "count": result.count,
        "compiles": result.compiles,
        "launches": result.launches,
        "points": [p.to_dict() for p in points],
        "interference": interference_summary(points),
    }
    if extra:
        artifact.update(extra)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(artifact, f, indent=1)
    return artifact
