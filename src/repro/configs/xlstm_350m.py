"""xlstm-350m [ssm]: sLSTM + mLSTM blocks, no separate FFN (d_ff=0).

24L, d_model=1024, 4H, vocab=50304. sLSTM every 4th block (xLSTM[7:1]-style
mix), mLSTM elsewhere. [arXiv:2405.04517]
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    ssm_expand=2,
    slstm_every=4,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=64, n_heads=2, n_kv_heads=2, vocab=256,
        slstm_every=3, ssm_chunk=8,
    )
