"""repro.fleet: workload generators, the vmapped sweep's fidelity to the
single jitted scan AND the discrete-event oracle, the bounded-compile
claim for heterogeneous grids, and the BENCH_fleet.json frontier artifact
(TOFEC-vs-static delay/capacity ordering of Fig.7/8)."""

import json

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (
    PAPER_READ_3MB,
    PAPER_WRITE_3MB,
    FixedKAdaptivePolicy,
    RequestClass,
    StaticPolicy,
    TofecTables,
    TOFECPolicy,
    build_class_plan,
    tofec_threshold_step,
)
from repro.core.jax_sim import JaxSimParams, simulate_tofec_scan
from repro.core.simulator import piecewise_poisson_arrivals, poisson_arrivals, simulate
from repro.core.traces import TraceSampler
from repro.fleet import (
    DiurnalWorkload,
    FlashCrowdWorkload,
    FleetSweep,
    MMPPWorkload,
    PiecewiseWorkload,
    PoissonWorkload,
    PolicySpec,
    TenantMix,
    capacity_estimates,
    convergence_stats,
    fixedk_tables,
    frontier_points,
    grid_cases,
    static_tables,
    tenant_cases,
    write_fleet_artifact,
)

CLS = RequestClass("read3mb", 3.0, PAPER_READ_3MB, k_max=6, r_max=2.0, n_max=12)
L = 16
PLAN = build_class_plan(CLS, L)
SAMPLER = TraceSampler(PAPER_READ_3MB, CLS.file_mb)


# ---------------------------------------------------------------------------
# Workload generators
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "wl",
    [
        PoissonWorkload(20.0),
        MMPPWorkload(rates=(8.0, 40.0), dwell=(6.0, 2.0)),
        DiurnalWorkload(base=20.0, amplitude=0.6, period=60.0),
        PiecewiseWorkload(((30.0, 10.0), (30.0, 40.0))),
    ],
)
def test_workload_mean_rate_and_device_arrays(wl):
    rng = np.random.default_rng(0)
    count = 4000
    inter, exps = wl.device_arrays(rng, count, CLS.n_max)
    assert inter.shape == (count,) and inter.dtype == np.float32
    assert exps.shape == (count, CLS.n_max) and exps.dtype == np.float32
    assert np.all(inter >= 0.0)
    # Empirical rate within 15% of the spec's mean rate.
    emp = count / inter.sum()
    assert 0.85 * wl.mean_rate() < emp < 1.15 * wl.mean_rate(), (emp, wl)
    # Event-sim form: increasing absolute times at a consistent rate.
    times = wl.arrival_times(np.random.default_rng(1), 120.0)
    assert np.all(np.diff(times) > 0.0) and times[-1] < 120.0
    emp_t = len(times) / 120.0
    assert 0.7 * wl.mean_rate() < emp_t < 1.3 * wl.mean_rate()


def test_mmpp_is_bursty():
    """Burstiness shows up as interarrival CoV > 1 (Poisson has CoV = 1)."""
    rng = np.random.default_rng(2)
    inter = MMPPWorkload(rates=(4.0, 80.0), dwell=(8.0, 2.0)).interarrivals(rng, 20_000)
    cov = inter.std() / inter.mean()
    assert cov > 1.25, cov


def test_flash_crowd_rate_step():
    """The step is transient (rate reverts to base after t_off), so the
    flash crowd is pinned by its profile, not a single long-run mean."""
    wl = FlashCrowdWorkload(base=10.0, peak=80.0, t_on=50.0, t_off=100.0)
    times = wl.arrival_times(np.random.default_rng(3), 150.0)
    burst = np.sum((times >= 50.0) & (times < 100.0)) / 50.0
    calm = (np.sum(times < 50.0) + np.sum(times >= 100.0)) / 100.0
    assert burst > 4.0 * calm
    inter, exps = wl.device_arrays(np.random.default_rng(4), 2000, CLS.n_max)
    assert inter.shape == (2000,) and exps.shape == (2000, CLS.n_max)
    assert np.all(inter >= 0.0)


def test_piecewise_wrapper_is_draw_for_draw_compatible():
    """simulator.piecewise_poisson_arrivals is now a thin wrapper: identical
    output for the identical RNG stream (Fig.10 stays reproducible)."""
    rates = [(200.0, 10.0), (200.0, 70.0), (200.0, 10.0)]
    a = piecewise_poisson_arrivals(np.random.default_rng(10), rates)
    b = PiecewiseWorkload(tuple(rates)).arrival_times(np.random.default_rng(10))
    np.testing.assert_allclose(a, b)
    assert a[-1] < 600.0 and np.sum((a > 200) & (a < 400)) > 10_000


def test_tenant_mix_split_and_cls_ids():
    small = RequestClass("read1mb", 1.0, PAPER_READ_3MB, k_max=4, r_max=2.0, n_max=8)
    mix = TenantMix(lam=30.0, classes=(CLS, small), weights=(0.75, 0.25))
    rng = np.random.default_rng(4)
    ids = mix.cls_ids(rng, 8000)
    assert 0.70 < (ids == 0).mean() < 0.80
    split = mix.split()
    assert [c.name for c, _ in split] == ["read3mb", "read1mb"]
    assert np.isclose(sum(w.lam for _, w in split), 30.0)
    # Per-class sub-points ride one heterogeneous sweep (padded tables).
    # quiet=True: the fluid split is deliberate here (repro.sched owns the
    # joint shared-pool path and tenant_cases warns about the approximation).
    res = FleetSweep(chunk=8).run(
        tenant_cases(mix, [PolicySpec.tofec()], [0], L, quiet=True), count=600
    )
    ks = np.asarray(res.out["k"])
    assert int(ks[0].max()) <= CLS.k_max and int(ks[1].max()) <= small.k_max


# ---------------------------------------------------------------------------
# Policy-as-tables encodings
# ---------------------------------------------------------------------------


def test_static_tables_pin_the_code():
    for n, k in [(1, 1), (2, 1), (6, 3), (12, 6), (5, 4)]:
        h_k, h_n, r_max = static_tables(n, k, CLS.k_max, CLS.n_max)
        for q in [0.0, 0.3, 7.0, 1e4]:
            _, n_j, k_j = tofec_threshold_step(
                jnp.float32(q), jnp.float32(q), jnp.asarray(h_k), jnp.asarray(h_n),
                r_max, 0.99,
            )
            assert (int(n_j), int(k_j)) == (n, k), (n, k, q)


def test_fixedk_tables_match_host_policy():
    k = 6
    h_k, h_n, r_max = fixedk_tables(CLS, L, k)
    pol = FixedKAdaptivePolicy(CLS, L, k=k)
    q_ewma = 0.0
    for q in [0.0, 0.5, 1.0, 2.0, 4.0, 9.0, 30.0, 2.0, 0.0]:
        n_host, k_host = pol.select(q=q, idle=0)
        q_ewma, n_j, k_j = tofec_threshold_step(
            jnp.float32(q_ewma), jnp.float32(q), jnp.asarray(h_k), jnp.asarray(h_n),
            r_max, pol.alpha,
        )
        assert (int(n_j), int(k_j)) == (n_host, k_host), q


# ---------------------------------------------------------------------------
# Sweep fidelity
# ---------------------------------------------------------------------------


def test_sweep_row_matches_single_jitted_scan():
    """A fleet grid row must reproduce simulate_tofec_scan on the same
    draws — the vmapped/chunked/padded path adds no semantic drift."""
    lam, seed, count = 18.0, 5, 1200
    cases = grid_cases([lam], [PolicySpec.tofec()], [seed], CLS, L)
    res = FleetSweep(chunk=4).run(cases, count)

    rng = np.random.default_rng(seed)
    inter, exps = PoissonWorkload(lam).device_arrays(rng, count, CLS.n_max)
    ref = simulate_tofec_scan(
        JaxSimParams.from_class(CLS, L), TofecTables.from_plan(PLAN),
        jnp.asarray(inter), jnp.asarray(exps),
    )
    out = res.to_numpy()
    assert (out["n"][0] == np.asarray(ref["n"])).mean() >= 0.999
    assert (out["k"][0] == np.asarray(ref["k"])).mean() >= 0.999
    np.testing.assert_allclose(out["total"][0], np.asarray(ref["total"]),
                               rtol=1e-4, atol=1e-6)


@pytest.mark.parametrize(
    "lam,policy,host_policy,tol",
    [
        (5.0, PolicySpec.tofec(), None, 0.30),
        (5.0, PolicySpec.static(1, 1), StaticPolicy(1, 1), 0.15),
        (25.0, PolicySpec.static(6, 3), StaticPolicy(6, 3), 0.15),
        (50.0, PolicySpec.tofec(), None, 0.30),
    ],
)
def test_sweep_cross_validates_against_event_oracle(lam, policy, host_policy, tol):
    """≥3 (λ, policy) grid points: fleet mean total delay within tolerance
    of the discrete-event simulator (the §IV-A approximation error band)."""
    count = 3000
    res = FleetSweep().run(grid_cases([lam], [policy], [3], CLS, L), count)
    fleet_mean = frontier_points(res)[0].mean

    rng = np.random.default_rng(7)
    arr = poisson_arrivals(rng, lam, count)
    host = host_policy if host_policy is not None else TOFECPolicy([PLAN])
    event = simulate(host, arr, SAMPLER, L=L, seed=8)
    event_mean = float(event.totals().mean())
    assert abs(fleet_mean - event_mean) / event_mean < tol, (fleet_mean, event_mean)


# ---------------------------------------------------------------------------
# Shape buckets / compile counts
# ---------------------------------------------------------------------------


def test_sweep_compile_count_bounded_on_heterogeneous_grid():
    """A ≥64-point heterogeneous (λ × policy × seed) grid runs in ONE
    compilation; re-runs and same-bucket grids stay compile-free; only a
    bucket change (different T bucket) compiles again."""
    sweep = FleetSweep(chunk=16, t_floor=512)
    lams = np.linspace(4.0, 64.0, 8)
    policies = [PolicySpec.tofec(), PolicySpec.static(1, 1),
                PolicySpec.static(12, 6), PolicySpec.fixedk(6)]
    cases = grid_cases(lams, policies, [0, 1], CLS, L)
    assert len(cases) == 64

    res = sweep.run(cases, count=500)
    assert res.compiles == 1, res.compiles
    assert res.launches == 4  # 64 points / chunk 16: memory-bounded batching

    # Same bucket (count 500 vs 400 both pad to 512; different grid subset).
    res2 = sweep.run(cases[:40], count=400)
    assert res2.compiles == 0
    # New time bucket compiles once more.
    res3 = sweep.run(cases[:8], count=600)
    assert res3.compiles == 1
    assert sweep.stats.traces == 2 and sweep.stats.cases == 64 + 40 + 8


def test_sweep_chunk_padding_keeps_results_exact():
    """The repeated-row padding of the tail chunk never leaks into results:
    the same grid swept with different chunkings is identical."""
    cases = grid_cases([6.0, 30.0, 55.0], [PolicySpec.tofec()], [0, 1], CLS, L)
    a = FleetSweep(chunk=4).run(cases, count=700).to_numpy()   # 6 = 4 + 2(pad)
    b = FleetSweep(chunk=8).run(cases, count=700).to_numpy()   # one launch
    for name in ("total", "queueing", "service", "n", "k"):
        np.testing.assert_array_equal(a[name], b[name])


# ---------------------------------------------------------------------------
# Frontier reductions + artifact
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def frontier_sweep():
    lams = np.linspace(5.0, 65.0, 6)
    policies = [PolicySpec.tofec(), PolicySpec.static(1, 1), PolicySpec.static(2, 1),
                PolicySpec.static(6, 3), PolicySpec.static(12, 6)]
    return FleetSweep().run(grid_cases(lams, policies, [1], CLS, L), count=2500)


def test_frontier_artifact_reproduces_paper_ordering(frontier_sweep, tmp_path):
    """One ≥64-point-capable launch family → BENCH_fleet.json with the
    TOFEC-vs-static delay AND capacity ordering of Fig.7/8."""
    path = tmp_path / "BENCH_fleet.json"
    art = write_fleet_artifact(str(path), frontier_sweep)
    on_disk = json.loads(path.read_text())
    assert on_disk["schema"] == "repro.fleet/BENCH_fleet/v1"
    assert on_disk["grid_size"] == 30 and len(on_disk["points"]) == 30

    h = art["headline"]
    # Delay ordering: TOFEC beats the throughput-optimal basic code at
    # light load by a wide margin (paper: ~2.5x).
    assert h["delay_gain_vs_basic"] > 1.5
    # Capacity ordering: TOFEC's supportable rate beats the latency-optimal
    # static code by a wide margin (paper: ~3x).
    assert h["capacity_gain_vs_latency_optimal"] > 1.5
    caps = art["capacity_req_s"]
    assert caps["tofec"] > caps["static(12,6)"]
    assert caps["static(1,1)"] > caps["static(6,3)"] > caps["static(12,6)"]


def test_frontier_percentiles_and_k_adaptation(frontier_sweep):
    pts = frontier_points(frontier_sweep)
    for p in pts:
        assert p.p50 <= p.p90 <= p.p95 <= p.p99
        assert 1.0 <= p.mean_k <= CLS.k_max and p.mean_k <= p.mean_n
    tofec = sorted((p for p in pts if p.policy == "tofec"), key=lambda p: p.lam)
    # Corollary 1: chunking backs off as load grows (Fig.8's story).
    assert tofec[0].mean_k > tofec[-1].mean_k + 1.0


def test_convergence_stats_static_settles_instantly(frontier_sweep):
    stats = convergence_stats(frontier_sweep)
    assert len(stats) == len(frontier_sweep.cases)
    for s in stats:
        if s["policy"].startswith("static("):
            assert s["settle_frac"] == 0.0 and s["modal_frac"] == 1.0
        assert 0.0 <= s["settle_frac"] <= 1.0


def test_capacity_estimates_match_queueing_theory(frontier_sweep):
    """Static-code capacity estimates from the sweep equal L/U from the
    queueing module (the codes' known saturation rates)."""
    from repro.core import queueing

    caps = capacity_estimates(frontier_points(frontier_sweep))
    for (n, k) in [(1, 1), (2, 1), (6, 3)]:
        want = queueing.capacity(PAPER_READ_3MB, CLS.file_mb, k, n / k, L)
        assert abs(caps[f"static({n},{k})"] - want) / want < 1e-3


def test_multi_class_grid_pads_tables_and_exps():
    """Classes with different (k_max, n_max, J) and write-side params share
    one bucketed launch; each row respects its own class's code bounds."""
    wr = RequestClass("write1mb", 1.0, PAPER_WRITE_3MB, k_max=3, r_max=2.0, n_max=6)
    cases = grid_cases([8.0], [PolicySpec.tofec()], [0], CLS, L) + \
        grid_cases([8.0], [PolicySpec.tofec()], [0], wr, L)
    res = FleetSweep(chunk=2).run(cases, count=800)
    assert res.compiles == 1
    out = res.to_numpy()
    assert out["k"][0].max() <= CLS.k_max and out["n"][0].max() <= CLS.n_max
    assert out["k"][1].max() <= wr.k_max and out["n"][1].max() <= wr.n_max
