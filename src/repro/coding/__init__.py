from repro.coding import gf256, layout, rs
from repro.coding import codec as codec_module
from repro.coding.codec import Codec, get_codec
from repro.coding.layout import SharedKeyLayout, layout_for_file
from repro.coding.rs import MDSCode

__all__ = [
    "gf256",
    "rs",
    "layout",
    "codec_module",
    "Codec",
    "get_codec",
    "MDSCode",
    "SharedKeyLayout",
    "layout_for_file",
]
