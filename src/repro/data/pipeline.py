"""Deterministic data pipeline with storage-backed, erasure-coded shards.

Two tiers:
  * :class:`SyntheticTokens` — seeded synthetic next-token batches (dry-run,
    smoke tests, the quickstart example). Deterministic per (seed, step,
    data_shard), so restarts resume bit-identically.
  * :class:`CodedShardReader` — token shards stored in the object store as
    Shared-Key coded objects and fetched through the TOFEC proxy: redundant
    ranged reads mitigate storage stragglers/failures (the paper's mechanism
    applied to the input pipeline), with a background prefetch thread.
"""

from __future__ import annotations

import queue as _queue
import threading

import numpy as np

from repro.coding.layout import SharedKeyLayout
from repro.models.config import ModelConfig, ShapeSpec
from repro.storage.proxy import Proxy, store_coded_object


class SyntheticTokens:
    """Deterministic synthetic LM batches: tokens + aligned next-token labels.

    The underlying stream is a per-shard counter-seeded PRNG: batch ``step``
    for shard ``(shard_id, n_shards)`` never depends on wall clock or
    iteration history — checkpoint/restart and elastic re-sharding resume
    exactly.
    """

    def __init__(self, cfg: ModelConfig, shape: ShapeSpec, *, seed: int = 0,
                 shard_id: int = 0, n_shards: int = 1):
        if shape.batch % n_shards != 0:
            raise ValueError(f"batch {shape.batch} not divisible by {n_shards} shards")
        self.cfg = cfg
        self.shape = shape
        self.seed = seed
        self.shard_id = shard_id
        self.n_shards = n_shards
        self.local_batch = shape.batch // n_shards

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.shard_id])
        )
        B, S = self.local_batch, self.shape.seq
        stream = rng.integers(0, self.cfg.vocab, size=(B, S + 1), dtype=np.int64)
        out = {
            "tokens": stream[:, :S].astype(np.int32),
            "labels": stream[:, 1:].astype(np.int32),
        }
        if self.cfg.family == "encdec":
            out["frames"] = rng.standard_normal(
                (B, self.cfg.encoder_seq, self.cfg.d_model), dtype=np.float32
            )
        if self.cfg.family == "vlm":
            out["patches"] = rng.standard_normal(
                (B, self.cfg.vision_patches, self.cfg.d_model), dtype=np.float32
            )
        return out

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class CodedShardReader:
    """Reads tokenized shards from the object store via the TOFEC proxy.

    Shards are Shared-Key coded objects (one per shard id). A background
    thread prefetches ``prefetch`` shards ahead; a failed or slow chunk is
    absorbed by the (n, k) code rather than stalling the trainer.
    """

    def __init__(self, proxy: Proxy, layout: SharedKeyLayout, shard_keys: list[str],
                 *, tokens_per_shard: int, prefetch: int = 2):
        self.proxy = proxy
        self.layout = layout
        self.shard_keys = shard_keys
        self.tokens_per_shard = tokens_per_shard
        self._q: _queue.Queue = _queue.Queue(maxsize=prefetch)
        self._stop = False
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    @staticmethod
    def write_shards(store, layout: SharedKeyLayout, shards: list[np.ndarray], prefix: str):
        keys = []
        for i, arr in enumerate(shards):
            key = f"{prefix}/shard{i:05d}"
            store_coded_object(store, key, layout, arr.astype(np.int32).tobytes())
            keys.append(key)
        return keys

    def _loop(self):
        idx = 0
        while not self._stop:
            key = self.shard_keys[idx % len(self.shard_keys)]
            res = self.proxy.read(key, self.layout, payload_len=self.tokens_per_shard * 4)
            if res.ok:
                arr = np.frombuffer(res.data, np.int32)
                try:
                    self._q.put((key, arr), timeout=1.0)
                    idx += 1
                except _queue.Full:
                    continue
            # on failure: retry the same shard (redundancy usually absorbs it)

    def next_shard(self, timeout: float = 30.0) -> tuple[str, np.ndarray]:
        return self._q.get(timeout=timeout)

    def close(self):
        self._stop = True
