"""TOFEC core: the paper's contribution (delay model, Theorem-1 optimizer,
threshold-based adaptive controller, queueing simulators)."""

from repro.core.controller import (
    FeedbackPolicy,
    FixedKAdaptivePolicy,
    GreedyPolicy,
    MPCPolicy,
    MPCTables,
    Policy,
    StaticPolicy,
    TofecTables,
    TOFECPolicy,
    mpc_step_jax,
    mpc_tables,
    tofec_step_jax,
    tofec_threshold_step,
)
from repro.core.delay_model import (
    PAPER_READ_3MB,
    PAPER_WRITE_3MB,
    DelayParams,
    RequestClass,
    fit_delay_params,
)
from repro.core.static_optimizer import (
    ClassPlan,
    build_class_plan,
    optimal_static_code,
    q_for_k,
    solve_r_for_k,
)

__all__ = [
    "DelayParams",
    "RequestClass",
    "fit_delay_params",
    "PAPER_READ_3MB",
    "PAPER_WRITE_3MB",
    "Policy",
    "StaticPolicy",
    "TOFECPolicy",
    "GreedyPolicy",
    "FixedKAdaptivePolicy",
    "FeedbackPolicy",
    "MPCPolicy",
    "MPCTables",
    "mpc_step_jax",
    "mpc_tables",
    "TofecTables",
    "tofec_step_jax",
    "tofec_threshold_step",
    "ClassPlan",
    "build_class_plan",
    "optimal_static_code",
    "solve_r_for_k",
    "q_for_k",
]
