"""Jit'd user-facing ops over the gf2mm Pallas kernel.

``rs_encode`` / ``rs_decode`` are the bulk encode/decode entry points used
by the erasure-coded checkpoint writer (repro.ckpt): the GF(256) generator /
decode matrices are expanded to GF(2) bit matrices on the host (tiny, trace
time), the payload bit-planes are produced with vectorized shifts, and the
heavy lifting is one MXU matmul.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.coding import gf256, rs
from repro.kernels.gf2mm import ref
from repro.kernels.gf2mm.gf2mm import gf2_matmul

# interpret=True everywhere in this container (CPU); on real TPU this flag
# flips to False via REPRO_PALLAS_INTERPRET=0.
import os

INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


@functools.partial(jax.jit, static_argnames=("n", "k", "interpret"))
def rs_encode(data: jax.Array, *, n: int, k: int, interpret: bool = INTERPRET) -> jax.Array:
    """Systematic RS encode on TPU: (k, B) uint8 -> (n, B) uint8.

    Data rows pass through; parity rows come from the GF(2) bit-matrix
    matmul kernel.
    """
    if data.shape[0] != k:
        raise ValueError(f"data rows {data.shape[0]} != k {k}")
    if n == k:
        return data
    parity_g = rs.cauchy_parity_matrix(n, k)  # (n-k, k) GF(256), host const
    g2 = jnp.asarray(gf256.expand_bitmatrix(parity_g), jnp.uint8)  # (8(n-k), 8k)
    d2 = ref.bytes_to_bitplanes_ref(data)  # (8k, B)
    p2 = gf2_matmul(g2, d2, interpret=interpret)  # (8(n-k), B) 0/1
    parity = ref.bitplanes_to_bytes_ref(p2)  # (n-k, B)
    return jnp.concatenate([data.astype(jnp.uint8), parity], axis=0)


@functools.partial(jax.jit, static_argnames=("n", "k", "present", "interpret"))
def rs_decode(
    rows: jax.Array, *, n: int, k: int, present: tuple[int, ...], interpret: bool = INTERPRET
) -> jax.Array:
    """Reconstruct (k, B) data from k surviving strips via the same kernel.

    ``present`` (static) selects the decode matrix; decode is just encode
    with the inverted generator submatrix.
    """
    if rows.shape[0] != k:
        raise ValueError(f"rows {rows.shape[0]} != k {k}")
    dec = rs.decode_matrix(n, k, present)  # (k, k) GF(256), host const
    d2 = jnp.asarray(gf256.expand_bitmatrix(dec), jnp.uint8)  # (8k, 8k)
    r2 = ref.bytes_to_bitplanes_ref(rows)  # (8k, B)
    out_planes = gf2_matmul(d2, r2, interpret=interpret)
    return ref.bitplanes_to_bytes_ref(out_planes)


def encode_blob(payload: np.ndarray, *, n: int, k: int) -> np.ndarray:
    """Host convenience: 1-D uint8 payload -> (n, ceil(len/k)) coded strips."""
    payload = np.asarray(payload, np.uint8).reshape(-1)
    strip = -(-payload.size // k)
    buf = np.zeros(k * strip, np.uint8)
    buf[: payload.size] = payload
    return np.asarray(rs_encode(jnp.asarray(buf.reshape(k, strip)), n=n, k=k))


def decode_blob(
    strips: np.ndarray, present: tuple[int, ...], *, n: int, k: int, payload_len: int
) -> np.ndarray:
    """Host convenience: any k strips (k, strip) + ids -> payload bytes."""
    out = np.asarray(
        rs_decode(jnp.asarray(strips, jnp.uint8), n=n, k=k, present=tuple(int(i) for i in present))
    )
    return out.reshape(-1)[:payload_len]
