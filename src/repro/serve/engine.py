"""Batched serving engine with TOFEC-admitted prompt storage.

Flow per request: the prompt blob is fetched from the object store through
the TOFEC proxy (erasure-coded ranged reads, adaptive (n, k) from the proxy
backlog), tokenized prompts are batched, prefilled, and decoded with the
arch's cached ``decode_step``. The storage path is the paper's system; the
LM path is the substrate it feeds.

Three fetch paths:

* **unfused** — :meth:`ServingEngine.fetch_prompts` submits the whole round
  through :meth:`Proxy.read_many`; the proxy batch-decodes completions per
  admission round on the host codec.
* **fused** — pass a :class:`FusedServingStep`: the proxy returns raw chunks
  (``raw=True``) and ONE jitted launch then runs the admission update *and*
  the batched MDS decode for the whole round. The controller is runtime data
  (:class:`ServeTables`): TOFEC, static, fixed-k (threshold form, same
  encodings as the :mod:`repro.fleet` sweeps) and MPC (traceable cost-model
  argmin, :func:`repro.core.controller.mpc_step_jax`) all run through the
  same trace — swapping the policy swaps arrays, never recompiles.
* **closed loop** — :class:`ClosedLoopServer` extends the fused launch with
  the LM prefill: one jitted step covers admission update → batched decode →
  bytes→tokens → prefill, and the controller's (n, k) pick is pushed into
  the proxy's write policy (:class:`repro.core.controller.FeedbackPolicy`)
  so the next admission round's queued writes encode under the adapted code.
  This is the paper's §III loop closed end to end.

Compilation is shape-bucketed exactly like :mod:`repro.coding.codec`
(powers of two on batch / parity rows / strip width), and the per-item
decode matrices travel as *runtime* arrays built host-side from the cached
Cauchy tables — so a heterogeneous stream of codes, erasure patterns and
batch sizes reuses one trace per shape bucket (asserted in
``tests/test_fused_serve.py``).
"""

from __future__ import annotations

import dataclasses
import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.coding import codec as codec_mod
from repro.coding import rs
from repro.coding.layout import SharedKeyLayout
from repro.core.controller import (
    FeedbackPolicy,
    MPCTables,
    TofecTables,
    mpc_step_jax,
    mpc_tables,
    tofec_threshold_step,
)
from repro.core.delay_model import RequestClass
from repro.core.static_optimizer import build_class_plan
from repro.models.registry import Arch
from repro.storage.proxy import Proxy, store_coded_object
from repro import obs


#: ServeTables.pol ids: threshold-table controllers (tofec / static / fixedk)
#: vs the MPC cost-model argmin.
POL_THRESH = 0
POL_MPC = 1


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ServeTables:
    """The serving controller as pure runtime data (one request class).

    Every field is a device array, so the four policies (TOFEC / static /
    fixed-k in threshold form + MPC) share ONE trace per shape bucket:
    ``pol`` selects the lane inside the step and swapping policies swaps
    array contents, never recompiles. Threshold encodings follow the
    :mod:`repro.fleet` sweep convention (BIG sentinel, inert trailing
    zeros); the MPC lane rides in :class:`repro.core.controller.MPCTables`.
    """

    pol: jax.Array  # () int32: POL_THRESH | POL_MPC
    h_k: jax.Array  # (k_max + 1,) float32 thresholds (zeros on the MPC lane)
    h_n: jax.Array  # (n_max + 1,) float32
    r_max: jax.Array  # () float32
    alpha: jax.Array  # () float32 backlog-EWMA memory (threshold lane)
    mpc: MPCTables

    @classmethod
    def from_tofec(cls, tables: TofecTables, *, alpha: float = 0.99) -> "ServeTables":
        return cls(
            pol=jnp.int32(POL_THRESH),
            h_k=jnp.asarray(tables.h_k, jnp.float32),
            h_n=jnp.asarray(tables.h_n, jnp.float32),
            r_max=jnp.float32(tables.r_max),
            alpha=jnp.float32(alpha),
            mpc=MPCTables.trivial(),
        )


def serve_policy_step(
    carry: tuple[jax.Array, jax.Array, jax.Array],
    q: jax.Array,
    dt: jax.Array,
    tables: ServeTables,
) -> tuple[tuple[jax.Array, jax.Array, jax.Array], jax.Array, jax.Array]:
    """One admission update with the policy as runtime data.

    Carry = (q_ewma, mean_ia, has_rate) float32 scalars, initialized to
    (-1.0, 0.0, 0.0): ``q_ewma < 0`` is the cold-start sentinel (the first
    observation seeds the EWMA) and the rate pair only advances on
    ``dt ≥ 0`` (see :func:`repro.core.controller.mpc_step_jax`). Both lanes
    are evaluated and ``tables.pol`` selects — the price of one small argmin
    buys policy swaps with zero recompiles.
    """
    q_ewma, mean_ia, has_rate = carry
    q = jnp.float32(q)
    dt = jnp.float32(dt)
    q_thr, n_thr, k_thr = tofec_threshold_step(
        q_ewma, q, tables.h_k, tables.h_n, tables.r_max, tables.alpha
    )
    (q_mpc, mean_ia, has_rate), n_mpc, k_mpc = mpc_step_jax(
        (q_ewma, mean_ia, has_rate), q, dt, tables.mpc
    )
    is_mpc = tables.pol == POL_MPC
    carry = (jnp.where(is_mpc, q_mpc, q_thr), mean_ia, has_rate)
    n = jnp.where(is_mpc, n_mpc, n_thr)
    k = jnp.where(is_mpc, k_mpc, k_thr)
    return carry, n, k


@dataclasses.dataclass(frozen=True)
class ServePolicy:
    """Declarative serving controller: tofec | static | fixedk | mpc.

    :meth:`tables` resolves it to :class:`ServeTables` for one request
    class; all four kinds produce identically-shaped tables for the same
    class, so a live policy swap (``FusedServingStep.set_policy``) reuses
    the existing trace.
    """

    kind: str
    n: int = 0
    k: int = 0
    alpha: float = 0.99
    eq7_factor: float = 2.0
    alpha_rate: float = 0.05
    util_cap: float = 0.9
    q_guard: float = 4.0
    alpha_q: float = 0.1

    @classmethod
    def tofec(cls, alpha: float = 0.99, eq7_factor: float = 2.0) -> "ServePolicy":
        return cls("tofec", alpha=alpha, eq7_factor=eq7_factor)

    @classmethod
    def static(cls, n: int, k: int) -> "ServePolicy":
        return cls("static", n=n, k=k)

    @classmethod
    def fixedk(cls, k: int, eq7_factor: float = 2.0) -> "ServePolicy":
        return cls("fixedk", k=k, eq7_factor=eq7_factor)

    @classmethod
    def mpc(cls, *, alpha_rate: float = 0.05, util_cap: float = 0.9,
            q_guard: float = 4.0, alpha_q: float = 0.1) -> "ServePolicy":
        return cls("mpc", alpha_rate=alpha_rate, util_cap=util_cap,
                   q_guard=q_guard, alpha_q=alpha_q)

    def tables(self, request_class: RequestClass, L: int) -> ServeTables:
        # The MPC lane is always populated (shape-stable swaps); threshold
        # kinds just never select it.
        mpc_t = mpc_tables(
            request_class, L, alpha_rate=self.alpha_rate, util_cap=self.util_cap,
            q_guard=self.q_guard, alpha_q=self.alpha_q,
        )
        if self.kind == "mpc":
            h_k = np.zeros(request_class.k_max + 1, np.float32)
            h_n = np.zeros(request_class.n_max + 1, np.float32)
            r_max = request_class.r_max
            pol = POL_MPC
        else:
            from repro.fleet.sweep import PolicySpec, policy_tables

            spec = PolicySpec(self.kind, n=self.n, k=self.k, alpha=self.alpha,
                              eq7_factor=self.eq7_factor)
            h_k, h_n, r_max = policy_tables(spec, request_class, L)
            pol = POL_THRESH
        return ServeTables(
            pol=jnp.int32(pol),
            h_k=jnp.asarray(h_k, jnp.float32),
            h_n=jnp.asarray(h_n, jnp.float32),
            r_max=jnp.float32(r_max),
            alpha=jnp.float32(self.alpha),
            mpc=mpc_t,
        )


class FusedServingStep:
    """One jitted launch per serving round: admission update + batched MDS
    codec work (encode or decode), fused.

    State: the controller carry (q̄ backlog EWMA + the MPC rate pair) lives
    on device and is threaded through successive calls, so the step is the
    serving-path twin of one :func:`repro.core.jax_sim.simulate_tofec_scan`
    iteration. Each call returns the payloads *and* the (n, k) the
    controller picks for the next round.

    Matrices are runtime inputs: decode matrices come from
    :meth:`Codec.decode_mats` (host-cached per erasure pattern), parity
    matrices from the cached Cauchy generator, both padded to the shape
    bucket and run through ``backend.prep_mats``; the controller itself is
    runtime data too (:class:`ServeTables`) — so changing the code, the
    erasure pattern or the *policy* never retraces; only a new shape bucket
    compiles.
    """

    def __init__(self, tables: TofecTables | ServeTables, *,
                 codec: codec_mod.Codec | None = None, alpha: float = 0.99):
        self.codec = codec or codec_mod.get_codec()
        if not self.codec.backend.jitted:
            env = os.environ.get("REPRO_CODEC_BACKEND")
            raise ValueError(
                f"codec backend {self.codec.name!r} is host-only: the fused "
                "serving step runs admission + codec (+ prefill) in one "
                "jitted launch and needs the jnp or pallas backend. Fix: set "
                "REPRO_CODEC_BACKEND=jnp (or REPRO_CODEC_BACKEND=pallas) in "
                "the environment, or pass codec=get_codec('jnp') explicitly "
                f"(REPRO_CODEC_BACKEND is currently {env!r})."
            )
        if isinstance(tables, TofecTables):
            tables = ServeTables.from_tofec(tables, alpha=alpha)
        self.tables = tables
        self.alpha = alpha
        # Outer-jit compilations (bounded by shape buckets); shared
        # CompileStats so retrace accounting is uniform across engines —
        # ``.traces`` stays the public pin via the property below.
        self.stats = obs.CompileStats(label="serve.FusedServingStep")
        self._fns: dict[tuple, object] = {}
        self._lock = threading.Lock()
        self.reset()

    @property
    def traces(self) -> int:
        return self.stats.traces

    @traces.setter
    def traces(self, value: int) -> None:
        self.stats.traces = value

    @classmethod
    def for_class(cls, request_class, L: int, *, codec: codec_mod.Codec | None = None,
                  alpha: float = 0.99, eq7_factor: float = 2.0) -> "FusedServingStep":
        plan = build_class_plan(request_class, L, eq7_factor=eq7_factor)
        return cls(TofecTables.from_plan(plan), codec=codec, alpha=alpha)

    @classmethod
    def for_policy(cls, policy: ServePolicy, request_class, L: int, *,
                   codec: codec_mod.Codec | None = None) -> "FusedServingStep":
        return cls(policy.tables(request_class, L), codec=codec, alpha=policy.alpha)

    def reset(self) -> None:
        # (q_ewma, mean_ia, has_rate); -1.0 = cold-start sentinel.
        self.carry = (jnp.float32(-1.0), jnp.float32(0.0), jnp.float32(0.0))

    @property
    def q_ewma(self) -> jax.Array:
        return self.carry[0]

    def set_policy(self, tables: ServeTables) -> None:
        """Swap the controller live. Same table shapes → zero recompiles."""
        self.tables = tables

    # -- compilation cache ---------------------------------------------------

    def _fn(self, key: tuple):
        with self._lock:
            fn = self._fns.get(key)
        if fn is not None:
            return fn
        backend = self.codec.backend
        kind = key[0]

        if kind == "adm":  # admission update only (n == k: no parity work)

            def fused(tables, carry, q, dt):
                self.traces += 1  # runs at trace time only
                return serve_policy_step(carry, q, dt, tables)

        elif kind == "dec":

            def fused(tables, carry, mats, rows, q, dt):
                self.traces += 1  # runs at trace time only
                carry, n_nxt, k_nxt = serve_policy_step(carry, q, dt, tables)
                return carry, n_nxt, k_nxt, backend.matmul_traced(mats, rows)

        else:

            def fused(tables, carry, mats, data, q, dt):
                self.traces += 1  # runs at trace time only
                carry, n_nxt, k_nxt = serve_policy_step(carry, q, dt, tables)
                parity = backend.matmul_traced(mats, data)
                return carry, n_nxt, k_nxt, jnp.concatenate([data, parity], axis=1)

        fn = jax.jit(fused)
        with self._lock:
            fn = self._fns.setdefault(key, fn)
        return fn

    # -- fused entry points ----------------------------------------------------

    def decode_batch(self, rows, present, *, n: int, k: int, q: float,
                     dt: float = -1.0) -> tuple[np.ndarray, tuple[int, int]]:
        """Admission update + batched reconstruct in ONE jitted launch.

        rows: (batch, k, B) surviving strips; present: (batch, k) strip ids
        (or a shared (k,) pattern); q: the round's backlog signal; dt: the
        interarrival seconds feeding the MPC rate estimator (< 0 = unknown;
        threshold policies ignore it). Returns ((batch, k, B) decoded data,
        (n, k) for the next round).
        """
        rows = np.asarray(rows, np.uint8)
        single = rows.ndim == 2
        if single:
            rows = rows[None]
        batch, _, B = rows.shape
        present = np.asarray(present, np.int64)
        if present.ndim == 1:
            present = np.broadcast_to(present, (batch, k))
        mats = self.codec.decode_mats(present, n, k)
        mats_p, rows_p, key = self.codec.pad_to_bucket("dec", mats, rows, n, k)
        fn = self._fn(key)
        with obs.span("serve.decode_batch", bucket=str(key), batch=batch):
            self.carry, n_nxt, k_nxt, out = fn(
                self.tables, self.carry,
                jnp.asarray(self.codec.backend.prep_mats(mats_p)), jnp.asarray(rows_p),
                jnp.float32(q), jnp.float32(dt),
            )
        self.stats.launches += 1
        data = np.asarray(out)[:batch, :k, :B]
        return (data[0] if single else data), (int(n_nxt), int(k_nxt))

    def encode_batch(self, data, *, n: int, k: int, q: float,
                     dt: float = -1.0) -> tuple[np.ndarray, tuple[int, int]]:
        """Admission update + batched systematic encode in ONE launch.

        data: (batch, k, B) → ((batch, n, B) coded strips, next (n, k)).
        """
        data = np.asarray(data, np.uint8)
        single = data.ndim == 2
        if single:
            data = data[None]
        batch, _, B = data.shape
        if n == k:  # no parity: admission update only, data passes through
            fn = self._fn(("adm",))
            self.carry, n_nxt, k_nxt = fn(self.tables, self.carry,
                                          jnp.float32(q), jnp.float32(dt))
            self.stats.launches += 1
            return (data[0] if single else data), (int(n_nxt), int(k_nxt))
        m = n - k
        par = rs.cauchy_parity_matrix(n, k)
        mats = np.broadcast_to(par, (batch, m, k))
        mats_p, data_p, key = self.codec.pad_to_bucket("enc", mats, data, n, k)
        fn = self._fn(key)
        with obs.span("serve.encode_batch", bucket=str(key), batch=batch):
            self.carry, n_nxt, k_nxt, out = fn(
                self.tables, self.carry,
                jnp.asarray(self.codec.backend.prep_mats(mats_p)), jnp.asarray(data_p),
                jnp.float32(q), jnp.float32(dt),
            )
        self.stats.launches += 1
        coded = np.asarray(out)[:batch, :n, :B]
        return (coded[0] if single else coded), (int(n_nxt), int(k_nxt))


def tokens_from_strips(data: jax.Array, k: int, strip_bytes: int,
                       prompt_len: int) -> jax.Array:
    """Traceable bytes→tokens: (batch, ≥k, ≥strip_bytes) decoded uint8 strips
    → (batch, prompt_len) int32, little-endian 4-byte words.

    The slice order matters: padding must come OFF before the flatten
    (slicing after would interleave pad bytes into the token stream).
    """
    flat = data[:, :k, :strip_bytes].reshape(data.shape[0], k * strip_bytes)
    by = flat[:, : prompt_len * 4].reshape(-1, prompt_len, 4).astype(jnp.int32)
    return by[..., 0] | (by[..., 1] << 8) | (by[..., 2] << 16) | (by[..., 3] << 24)


@dataclasses.dataclass
class ServeResult:
    tokens: np.ndarray  # (B, steps) generated ids
    storage_total_s: list[float]  # per-request proxy read delays
    codes: list[tuple[int, int]]  # (n, k) used per prompt fetch
    next_code: tuple[int, int] | None = None  # fused path: controller's pick


class ServingEngine:
    def __init__(self, arch: Arch, params, *, max_seq: int = 128):
        self.arch = arch
        self.params = params
        self.max_seq = max_seq
        self._prefill = jax.jit(
            lambda p, b: arch.prefill(p, b, max_seq=self.max_seq)
        )
        self._decode = jax.jit(arch.decode_step)

    # -- storage integration -------------------------------------------------

    @staticmethod
    def store_prompt(store, key: str, layout: SharedKeyLayout, tokens: np.ndarray):
        store_coded_object(store, key, layout, tokens.astype(np.int32).tobytes())

    def fetch_prompts(
        self, proxy: Proxy, layout: SharedKeyLayout, keys: list[str], prompt_len: int,
        *, fused: FusedServingStep | None = None, retries: int = 3,
    ) -> tuple[np.ndarray, list[float], list[tuple[int, int]], tuple[int, int] | None]:
        """Batched prompt fetch: the whole round is submitted up front (the
        proxy's policy sees it as backlog) and reconstructed batched — by the
        proxy's admission round (unfused) or by ``fused``'s single jitted
        admission+decode launch (raw chunks in, payloads out).

        Reads that exhaust their n − k failure budget (the backlog-adapted
        code can be as lean as (1, 1)) are resubmitted up to ``retries``
        times; the retry round is smaller, so the policy re-picks with more
        redundancy. Reported delays accumulate across attempts (what the
        client actually waited); codes report the attempt that served."""
        payload_len = prompt_len * 4
        raw = fused is not None
        results = proxy.read_many(keys, layout, payload_len, raw=raw)
        failed_s = [0.0] * len(keys)
        for _ in range(retries):
            bad_idx = [i for i, r in enumerate(results) if not r.ok]
            if not bad_idx:
                break
            for i in bad_idx:
                failed_s[i] += results[i].total_s
            redo = proxy.read_many([keys[i] for i in bad_idx], layout, payload_len,
                                   raw=raw)
            for i, r in zip(bad_idx, redo):
                results[i] = r
        bad = [k for k, r in zip(keys, results) if not r.ok]
        if bad:
            raise RuntimeError(f"prompt fetch failed for {', '.join(bad)}")
        delays = [r.total_s + extra for r, extra in zip(results, failed_s)]
        codes = [(r.n, r.k) for r in results]
        if fused is None:
            toks = [np.frombuffer(r.data, np.int32) for r in results]
            return np.stack(toks), delays, codes, None
        rows, present = layout.gather_rows_batch([(r.k, r.chunks) for r in results])
        data, next_code = fused.decode_batch(
            rows, present, n=layout.N, k=layout.K, q=len(keys)
        )
        toks = [
            np.frombuffer(data[i].reshape(-1)[:payload_len].tobytes(), np.int32)
            for i in range(len(results))
        ]
        return np.stack(toks), delays, codes, next_code

    # -- generation -----------------------------------------------------------

    def generate(self, prompts: np.ndarray, steps: int, *, greedy: bool = True) -> np.ndarray:
        """prompts: (B, S) int32 → (B, steps) generated ids."""
        batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
        if self.arch.cfg.family == "vlm":
            B = prompts.shape[0]
            batch["patches"] = jnp.zeros(
                (B, self.arch.cfg.vision_patches, self.arch.cfg.d_model), jnp.float32
            )
        if self.arch.cfg.family == "encdec":
            B = prompts.shape[0]
            batch["frames"] = jnp.zeros(
                (B, self.arch.cfg.encoder_seq, self.arch.cfg.d_model), jnp.float32
            )
        logits, cache = self._prefill(self.params, batch)
        out = []
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        for _ in range(steps):
            out.append(np.asarray(tok)[:, 0])
            logits, cache = self._decode(self.params, tok, cache)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return np.stack(out, axis=1)

    def serve(
        self,
        proxy: Proxy,
        layout: SharedKeyLayout,
        keys: list[str],
        *,
        prompt_len: int,
        steps: int,
        fused: FusedServingStep | None = None,
    ) -> ServeResult:
        prompts, delays, codes, next_code = self.fetch_prompts(
            proxy, layout, keys, prompt_len, fused=fused
        )
        gen = self.generate(prompts, steps)
        return ServeResult(tokens=gen, storage_total_s=delays, codes=codes,
                           next_code=next_code)


@dataclasses.dataclass
class ClosedLoopResult:
    tokens: np.ndarray  # (G, steps) generated ids, one row per SERVED key
    ok: list[bool]  # per input key: did its read survive (per-item mask)
    served_keys: list[str]  # keys in tokens' row order (the ok subset)
    codes: list[tuple[int, int]]  # read (n, k) per served key
    next_code: tuple[int, int]  # controller's pick, pushed to the write policy
    storage_total_s: list[float]  # proxy read delays per served key


class ClosedLoopServer:
    """The paper's proxy as a CLOSED loop, one jitted step per round.

    Each :meth:`serve_round`:

    1. fetches the round's prompts through the proxy (``raw=True`` — chunks
       only, per-item error masks; a partially-failed item drops out of the
       round instead of wedging it),
    2. runs ONE jitted launch: admission update (policy as runtime data,
       :func:`serve_policy_step`) → batched MDS decode → bytes→tokens →
       LM prefill — no per-round host round-trip between those stages,
    3. finishes generation with the engine's cached ``decode_step``,
    4. pushes the controller's (n, k) into the proxy's write policy
       (:class:`repro.core.controller.FeedbackPolicy`), so writes queued for
       the next admission round encode under the adapted code. (The pick is
       read back after generation — which forces the launch anyway — so the
       round never stalls on a mid-round device sync.)

    Trace count is bounded per shape bucket: the cache key is the codec's
    decode bucket extended with (prompt_len, strip_bytes) — the prefill's
    static shape inputs. Batch varies within pow2 buckets; prefill/decode
    run at the padded batch and outputs are sliced on host at the end.
    """

    def __init__(self, engine: ServingEngine, proxy: Proxy, layout: SharedKeyLayout,
                 step: FusedServingStep, *, prompt_len: int,
                 write_policy: FeedbackPolicy | None = None):
        if prompt_len * 4 > layout.file_bytes:
            raise ValueError(
                f"prompt_len {prompt_len} needs {prompt_len * 4} bytes but the "
                f"layout holds {layout.file_bytes}"
            )
        self.engine = engine
        self.proxy = proxy
        self.layout = layout
        self.step = step
        self.prompt_len = prompt_len
        if write_policy is None and isinstance(proxy.write_policy, FeedbackPolicy):
            write_policy = proxy.write_policy
        self.write_policy = write_policy
        self.stats = obs.CompileStats(label="serve.ClosedLoopServer")
        self._fns: dict[tuple, object] = {}
        self._lock = threading.Lock()
        self._last_now: float | None = None
        self._mbuf = None  # device MetricsBuf, created on first collected round
        self._tlbuf = None  # device TimelineBuf ring, same lifecycle as _mbuf
        self._flight = None  # host FlightRing, same lifecycle as _mbuf

    @property
    def traces(self) -> int:
        return self.stats.traces

    @traces.setter
    def traces(self, value: int) -> None:
        self.stats.traces = value

    @property
    def metrics(self):
        """The device-resident :class:`repro.obs.MetricsBuf` accumulated
        across collected rounds (None until a round runs with REPRO_OBS=1).
        Call ``.snapshot()`` on it for plain dicts — the only host sync."""
        return self._mbuf

    @property
    def timeline(self):
        """The device-resident :class:`repro.obs.TimelineBuf` ring of
        per-round samples — arrival rate ``lam``, ``backlog`` signal, the
        controller's ``pick_n``/``pick_k``, ``served`` count, and the
        round's ``delay`` histogram delta (windowed percentiles recoverable
        host-side).  None until a round runs with REPRO_OBS=1; the last
        :data:`_TL_CAP` rounds are retained.  Call ``.snapshot()`` for
        oldest-first numpy series — the only host sync."""
        return self._tlbuf

    @property
    def flight(self):
        """The host-side :class:`repro.obs.flight.FlightRing` of per-round
        phase breakdowns (admit → decode → generate on the compacted
        simulated round clock) — where each round spent its budget.  None
        until a round runs with REPRO_OBS=1; the last :data:`_TL_CAP`
        rounds are retained, matching the timeline ring."""
        return self._flight

    def put(self, key: str, payload: bytes, cls_id: int = 0):
        """Queue a write through the proxy (encodes under the fed-back code
        at the next admission round). Returns the async request handle."""
        return self.proxy.write_async(key, self.layout, payload, cls_id)

    def _fn(self, key: tuple):
        with self._lock:
            fn = self._fns.get(key)
        if fn is not None:
            return fn
        backend = self.step.codec.backend
        arch = self.engine.arch
        max_seq = self.engine.max_seq
        K, b, plen = self.layout.K, self.layout.strip_bytes, self.prompt_len
        vocab = arch.cfg.vocab
        collect = key[-1]  # metrics flag is part of the cache key

        def core(tables, carry, mats, rows, q, dt, params):
            self.traces += 1  # runs at trace time only
            carry, n_nxt, k_nxt = serve_policy_step(carry, q, dt, tables)
            data = backend.matmul_traced(mats, rows)
            toks = tokens_from_strips(data, K, b, plen)
            # Bucket-padding rows decode to zeros; clip keeps any stray bytes
            # inside the embedding table instead of relying on gather clamping.
            toks = jnp.clip(toks, 0, vocab - 1)
            logits, cache = arch.prefill_tokens(params, toks, max_seq=max_seq)
            return carry, n_nxt, k_nxt, toks, logits, cache

        if collect:

            def fused(tables, carry, mats, rows, q, dt, params,
                      mbuf, requested, served, errs, tlbuf, delays):
                carry, n_nxt, k_nxt, toks, logits, cache = core(
                    tables, carry, mats, rows, q, dt, params)
                # Pure additions on the side bufs: the primary outputs'
                # graph is identical to the collect=False trace.
                mbuf = (mbuf.count("serve_rounds", 1)
                            .count("serve_requested", requested)
                            .count("serve_served", served)
                            .count("serve_decode_errors", errs)
                            .observe("serve_q", q)
                            .observe("serve_pick_n", n_nxt)
                            .observe("serve_pick_k", k_nxt)
                            .observe("serve_batch", served)
                            .high("serve_q_hi", q))
                # One timeline ring slot per round.  ``delays`` is padded to
                # the bucket batch (its length is already in the cache key);
                # the lane mask drops the padding from the histogram delta.
                lam = jnp.where(
                    dt > 0,
                    served.astype(jnp.float32) / jnp.maximum(dt, 1e-9),
                    0.0,
                )
                lane = jnp.arange(delays.shape[0])
                wvec = (lane < served).astype(jnp.int32)
                tlbuf = tlbuf.append(
                    {"lam": lam, "backlog": q, "pick_n": n_nxt,
                     "pick_k": k_nxt, "served": served},
                    {"delay": (obs.delay_bucket(delays), wvec)},
                )
                return carry, n_nxt, k_nxt, toks, logits, cache, mbuf, tlbuf

        else:
            fused = core

        fn = jax.jit(fused)
        with self._lock:
            fn = self._fns.setdefault(key, fn)
        return fn

    #: fixed bucket counts for the round histograms (values clip into the
    #: last bucket); one shared buf shape per server, so adding a round
    #: never changes the pytree structure (-> no retrace).
    _Q_BINS = 64

    #: Timeline ring capacity: the last _TL_CAP rounds stay resident;
    #: older slots are overwritten in ring order (snapshot restores
    #: oldest-first).  Capacity is static pytree structure, so it never
    #: varies the trace.
    _TL_CAP = 256

    def _zero_mbuf(self):
        return obs.MetricsBuf.zeros(
            counters=("serve_rounds", "serve_requested", "serve_served",
                      "serve_decode_errors"),
            hists={"serve_q": self._Q_BINS, "serve_batch": self._Q_BINS,
                   "serve_pick_n": obs.PICK_BINS,
                   "serve_pick_k": obs.PICK_BINS},
            highs=("serve_q_hi",),
        )

    def _zero_tlbuf(self):
        return obs.TimelineBuf.zeros(
            self._TL_CAP,
            series=("lam", "backlog", "pick_n", "pick_k", "served"),
            hists={"delay": obs.DELAY_BINS},
        )

    def serve_round(self, keys: list[str], *, steps: int,
                    q: float | None = None) -> ClosedLoopResult:
        """One closed-loop serving round over ``keys``; see class docstring."""
        with obs.span("serve.round", keys=len(keys), steps=steps):
            return self._serve_round(keys, steps=steps, q=q)

    def _serve_round(self, keys: list[str], *, steps: int,
                     q: float | None = None) -> ClosedLoopResult:
        payload_len = self.prompt_len * 4
        collect = obs.enabled()
        t_round0 = time.monotonic()
        with obs.span("serve.fetch", keys=len(keys)):
            results = self.proxy.read_many(keys, self.layout, payload_len,
                                           raw=True)
        t_fetch = time.monotonic()
        ok = [r.ok for r in results]
        good = [r for r in results if r.ok]
        if not good:
            raise RuntimeError(
                f"all {len(keys)} prompt fetches failed this round"
            )
        rows, present = self.layout.gather_rows_batch(
            [(r.k, r.chunks) for r in good]
        )
        now = time.monotonic()
        dt = -1.0 if self._last_now is None else max(now - self._last_now, 1e-9)
        self._last_now = now
        q_sig = float(len(keys)) if q is None else float(q)
        codec = self.step.codec
        n, k = self.layout.N, self.layout.K
        mats = codec.decode_mats(np.asarray(present, np.int64), n, k)
        mats_p, rows_p, bkey = codec.pad_to_bucket("dec", mats, rows, n, k)
        key = ("pfd", *bkey, self.prompt_len, self.layout.strip_bytes, collect)
        fn = self._fn(key)
        args = (
            self.step.tables, self.step.carry,
            jnp.asarray(codec.backend.prep_mats(mats_p)), jnp.asarray(rows_p),
            jnp.float32(q_sig), jnp.float32(dt), self.engine.params,
        )
        with obs.span("serve.launch", bucket=str(key), batch=len(good)):
            if collect:
                if self._mbuf is None:
                    self._mbuf = self._zero_mbuf()
                if self._tlbuf is None:
                    self._tlbuf = self._zero_tlbuf()
                # Host-known round tallies ride as runtime scalars; the
                # error count is the per-item mask's failed-fetch tally.
                # Per-item proxy delays pad to the bucket batch (rows_p's
                # leading axis, already in the cache key).
                delays = np.zeros(rows_p.shape[0], np.float32)
                delays[: len(good)] = [r.total_s for r in good]
                (carry, n_nxt, k_nxt, _toks, logits, cache,
                 self._mbuf, self._tlbuf) = fn(
                    *args, self._mbuf, jnp.int32(len(keys)),
                    jnp.int32(len(good)), jnp.int32(len(keys) - len(good)),
                    self._tlbuf, jnp.asarray(delays),
                )
            else:
                carry, n_nxt, k_nxt, _toks, logits, cache = fn(*args)
        t_launch = time.monotonic()
        self.stats.launches += 1
        self.step.carry = carry
        # Generation continues at the padded batch (same trace each round);
        # rows are sliced back to the served subset on host at the end.
        gen = []
        with obs.span("serve.generate", steps=steps):
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            for _ in range(steps):
                gen.append(np.asarray(tok)[:, 0])
                logits, cache = self.engine._decode(self.engine.params, tok, cache)
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        tokens = np.stack(gen, axis=1)[: len(good)]
        # Pull the controller's pick to host only now: generation already
        # forced the launch, so this sync is free (reading it before the
        # decode loop would stall the round on the fused launch).
        next_code = (int(n_nxt), int(k_nxt))
        if collect:
            # One flight-ring record per collected round: where the round's
            # budget went.  "decode" covers the whole fused admission +
            # decode + prefill launch (one dispatch — the engine cannot
            # split it host-side); "generate" includes the sync that forces
            # it, which is exactly the wait the client sees.
            from repro.obs.flight import FlightRing

            if self._flight is None:
                self._flight = FlightRing(self._TL_CAP, label="serve")
            self._flight.record(
                [("admit", t_fetch - t_round0),
                 ("decode", t_launch - t_fetch),
                 ("generate", time.monotonic() - t_launch)],
                requested=len(keys), served=len(good), code=next_code,
            )
        if self.write_policy is not None:
            self.write_policy.push(*next_code)  # close the write loop
        return ClosedLoopResult(
            tokens=tokens,
            ok=ok,
            served_keys=[r.key for r in good],
            codes=[(r.n, r.k) for r in good],
            next_code=next_code,
            storage_total_s=[r.total_s for r in good],
        )
