"""Synthetic S3-like delay traces (stand-in for the paper's measured traces).

No network access in this container, so the trace-driven evaluation draws
from the paper's own fitted model family (§III-C): shifted exponential with
Δ(B), 1/μ(B) linear in chunk size. Two placement modes:

  * ``unique_key``  — i.i.d. task delays (measured cross-corr < 0.05),
  * ``shared_key``  — correlated tails via a Gaussian copula targeting the
                      measured cross-correlation coefficient (0.11–0.17).

A :class:`TraceStore` pre-generates per-chunk-size delay pools — the moral
equivalent of the paper's 24h measurement runs — from which the simulator
resamples, and from which :func:`repro.core.delay_model.fit_delay_params`
re-estimates {Δ̄, Δ̃, Ψ̄, Ψ̃} exactly the way §V-A does.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

try:  # dev-only dependency (requirements-dev.txt); the erf fallback below
    from scipy import stats as _scipy_stats  # keeps minimal containers working
except ImportError:  # pragma: no cover - exercised on minimal containers
    _scipy_stats = None

from repro.core.delay_model import DelayParams

_SQRT2 = math.sqrt(2.0)
_vec_erf = np.vectorize(math.erf, otypes=[np.float64])


def _norm_cdf(z: np.ndarray) -> np.ndarray:
    """Standard-normal CDF; scipy when available, math.erf otherwise.

    Φ(z) = (1 + erf(z/√2))/2 — exact, just slower elementwise on the
    fallback path, which only runs where scipy isn't installed.
    """
    if _scipy_stats is not None:
        return _scipy_stats.norm.cdf(z)
    return 0.5 * (1.0 + _vec_erf(np.asarray(z) / _SQRT2))


def _corr_exponentials(
    rng: np.random.Generator, mean: float, n: int, rho: float, size: int
) -> np.ndarray:
    """(size, n) exponentials, pairwise Gaussian-copula correlation ~rho."""
    if rho <= 0.0 or n == 1:
        return rng.exponential(mean, size=(size, n))
    cov = np.full((n, n), rho)
    np.fill_diagonal(cov, 1.0)
    z = rng.multivariate_normal(np.zeros(n), cov, size=size, method="cholesky")
    u = _norm_cdf(z)
    u = np.clip(u, 1e-12, 1.0 - 1e-12)
    return -mean * np.log1p(-u)


@dataclasses.dataclass
class TraceSampler:
    """Draws per-task delays for a request served with an (n, k) code."""

    params: DelayParams
    file_mb: float
    correlation: float = 0.0  # 0 → Unique Key; ~0.14 → Shared Key

    def sample(self, rng: np.random.Generator, k: int, n: int) -> np.ndarray:
        B = self.file_mb / k
        tails = _corr_exponentials(rng, self.params.tail_mean(B), n, self.correlation, 1)[0]
        return self.params.delta(B) + tails

    def sample_batch(self, rng: np.random.Generator, k: int, n: int, size: int) -> np.ndarray:
        B = self.file_mb / k
        tails = _corr_exponentials(rng, self.params.tail_mean(B), n, self.correlation, size)
        return self.params.delta(B) + tails


@dataclasses.dataclass
class TraceStore:
    """Pre-generated delay pools per chunk size (the 'collected traces')."""

    chunk_sizes_mb: np.ndarray
    pools: list[np.ndarray]  # pools[i]: (samples, threads) delays for size i

    @classmethod
    def generate(
        cls,
        params: DelayParams,
        chunk_sizes_mb,
        *,
        threads: int = 12,
        samples: int = 20_000,
        correlation: float = 0.0,
        seed: int = 0,
    ) -> "TraceStore":
        rng = np.random.default_rng(seed)
        sizes = np.asarray(chunk_sizes_mb, dtype=np.float64)
        pools = []
        for B in sizes:
            tails = _corr_exponentials(rng, params.tail_mean(B), threads, correlation, samples)
            pools.append(params.delta(B) + tails)
        return cls(chunk_sizes_mb=sizes, pools=pools)

    def pool_for(self, B: float) -> np.ndarray:
        i = int(np.argmin(np.abs(self.chunk_sizes_mb - B)))
        return self.pools[i]

    def thread_delays(self, B: float) -> list[np.ndarray]:
        """Per-thread delay series at chunk size B (for CCDF / corr plots)."""
        pool = self.pool_for(B)
        return [pool[:, t] for t in range(pool.shape[1])]

    def flat_delays(self, B: float) -> np.ndarray:
        return self.pool_for(B).reshape(-1)

    def cross_correlation(self, B: float) -> float:
        """Mean pairwise cross-correlation coefficient between threads."""
        pool = self.pool_for(B)
        c = np.corrcoef(pool.T)
        n = c.shape[0]
        off = c[~np.eye(n, dtype=bool)]
        return float(off.mean())

    def device_pools(self, n_max: int, size: int | None = None) -> "DevicePools":
        """Export the per-chunk-size pools as one stacked device-ready block.

        Returns a :class:`DevicePools` holding ``sizes_mb`` (S,) float32 and
        ``pools`` (S, size, n_max) float32 — the shared pre-sampled delay
        supply consumed by BOTH the on-device task engine
        (:mod:`repro.taskq`) and the host event oracle (via
        :meth:`DevicePools.host_sampler`). Rows are whole jointly-sampled
        thread batches, so the shared-key copula correlation of the trace
        survives the export; reading row ``i`` of pool ``s`` yields identical
        values on both sides, which is what makes the engine-vs-oracle
        parity pin of ``tests/test_taskq.py`` possible.
        """
        widths = [p.shape[1] for p in self.pools]
        if min(widths) < n_max:
            raise ValueError(
                f"store pools have {min(widths)} threads; need >= n_max={n_max}"
            )
        rows = min(p.shape[0] for p in self.pools)
        size = rows if size is None else size
        if size > rows:
            raise ValueError(f"requested {size} rows; pools hold only {rows}")
        stacked = np.stack([p[:size, :n_max] for p in self.pools])
        return DevicePools(
            sizes_mb=self.chunk_sizes_mb.astype(np.float32),
            pools=stacked.astype(np.float32),
        )


@dataclasses.dataclass
class DevicePools:
    """Stacked per-chunk-size delay pools shared by device and host samplers.

    ``pools[s, i, j]`` is the delay of thread j in jointly-sampled batch i at
    chunk size ``sizes_mb[s]``. The pool index for a request served at code
    dimension k is ``argmin |sizes_mb − J/k|`` computed in float32 — the
    device engine and :class:`PoolSampler` use the byte-identical rule so
    they always land in the same pool.
    """

    sizes_mb: np.ndarray  # (S,) float32
    pools: np.ndarray     # (S, P, W) float32

    @property
    def n_rows(self) -> int:
        return self.pools.shape[1]

    def pool_index(self, file_mb: float, k: int) -> int:
        B = np.float32(file_mb) / np.float32(k)
        return int(np.argmin(np.abs(self.sizes_mb - B)))

    def host_sampler(self, file_mb: float, indices: np.ndarray) -> "PoolSampler":
        """Oracle-side sampler reading the same rows the device engine reads
        (``indices[i]`` is request i's pre-sampled row draw)."""
        return PoolSampler(self, file_mb, np.asarray(indices, dtype=np.int64))


@dataclasses.dataclass
class PoolSampler:
    """Trace sampler replaying :class:`DevicePools` rows by request index.

    Exposes the :func:`repro.core.simulator.simulate` sampler interface plus
    the ``sample_indexed`` oracle hook: when present, the event simulator
    passes each request's arrival index so host draws line up with the
    device engine's ``pools[s, indices[i]]`` gather draw for draw, even when
    admission order and arrival order are allowed to diverge (multi-class
    disciplines). ``sample`` falls back to call-order indexing, which equals
    arrival order for the single-class FIFO oracle.
    """

    device: DevicePools
    file_mb: float
    indices: np.ndarray
    _ptr: int = 0

    def sample_indexed(self, index: int, k: int, n: int) -> np.ndarray:
        if n > self.device.pools.shape[2]:
            raise ValueError(f"n={n} exceeds pool width {self.device.pools.shape[2]}")
        s = self.device.pool_index(self.file_mb, k)
        return self.device.pools[s, self.indices[index], :n].astype(np.float64)

    def sample(self, rng: np.random.Generator, k: int, n: int) -> np.ndarray:
        i = self._ptr
        self._ptr += 1
        return self.sample_indexed(i, k, n)


@dataclasses.dataclass
class StoreSampler:
    """Trace-driven sampler: resamples rows of a TraceStore pool.

    Sampling a row (all threads at one 'time') preserves the cross-thread
    correlation structure of the trace, like replaying measured batches.
    """

    store: TraceStore
    file_mb: float

    def sample(self, rng: np.random.Generator, k: int, n: int) -> np.ndarray:
        B = self.file_mb / k
        pool = self.store.pool_for(B)
        row = pool[rng.integers(pool.shape[0])]
        if n <= row.shape[0]:
            return row[:n].copy()
        extra = pool[rng.integers(pool.shape[0])][: n - row.shape[0]]
        return np.concatenate([row, extra])
