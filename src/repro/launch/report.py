"""Regenerate the EXPERIMENTS.md roofline tables from dry-run artifacts.

Usage:  PYTHONPATH=src python -m repro.launch.report [--dir benchmarks/results/dryrun]
Prints markdown; EXPERIMENTS.md §Roofline embeds the output.
"""

from __future__ import annotations

import argparse
import json
import os

from repro.launch.roofline import HBM_BW, ICI_BW, PEAK_FLOPS

DEFAULT_DIR = os.path.join(os.path.dirname(__file__), "../../../benchmarks/results/dryrun")

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCH_ORDER = [
    "whisper-base", "xlstm-350m", "gemma2-2b", "mistral-nemo-12b", "yi-6b",
    "qwen1.5-0.5b", "pixtral-12b", "grok-1-314b", "mixtral-8x7b", "zamba2-2.7b",
]


def load(dirpath: str) -> list[dict]:
    recs = []
    for name in sorted(os.listdir(dirpath)):
        if name.endswith(".json"):
            try:
                with open(os.path.join(dirpath, name)) as f:
                    recs.append(json.load(f))
            except json.JSONDecodeError:
                continue  # sweep mid-write
    return recs


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}µs"


def fmt_b(x) -> str:
    if x is None:
        return "—"
    for unit, div in [("GB", 2**30), ("MB", 2**20)]:
        if x >= div:
            return f"{x / div:.1f}{unit}"
    return f"{x}B"


def roofline_table(recs: list[dict], mesh: str = "16x16") -> str:
    lines = [
        "| arch | shape | t_compute | t_memory | t_collective | dominant | "
        "useful/HLO flops | peak mem/dev | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            rec = next(
                (r for r in recs if r["arch"] == arch and r["shape"] == shape
                 and r["mesh"] == mesh), None)
            if rec is None:
                continue
            if rec["status"] == "skipped":
                lines.append(f"| {arch} | {shape} | — | — | — | SKIP | — | — | — |")
                continue
            if rec["status"] == "error":
                lines.append(f"| {arch} | {shape} | — | — | — | ERROR | — | — | — |")
                continue
            r = rec["roofline"]
            tc, tm, tl = r["t_compute_s"], r["t_memory_s"], r["t_collective_s"]
            bound = max(tc, tm, tl)
            frac = tc / bound if bound > 0 else 0.0
            ratio = rec.get("useful_flops_ratio")
            peak = rec.get("memory", {}).get("temp_size_b")
            arg = rec.get("memory", {}).get("argument_size_b")
            tot = (peak or 0) + (arg or 0)
            lines.append(
                f"| {arch} | {shape} | {fmt_s(tc)} | {fmt_s(tm)} | {fmt_s(tl)} "
                f"| {r['dominant']} | {ratio:.2f} | {fmt_b(tot)} | {frac:.2f} |"
                if ratio is not None else
                f"| {arch} | {shape} | {fmt_s(tc)} | {fmt_s(tm)} | {fmt_s(tl)} "
                f"| {r['dominant']} | — | {fmt_b(tot)} | {frac:.2f} |"
            )
    return "\n".join(lines)


def summary_stats(recs: list[dict]) -> str:
    recs = [r for r in recs if not r.get("optimized")]
    ok = [r for r in recs if r["status"] == "ok"]
    skip = [r for r in recs if r["status"] == "skipped"]
    err = [r for r in recs if r["status"] == "error"]
    by_dom = {}
    for r in ok:
        by_dom.setdefault(r["roofline"]["dominant"], []).append(r)
    lines = [
        f"cells: {len(ok)} ok, {len(skip)} skipped (documented), {len(err)} errors",
        f"dominant-term histogram: " + ", ".join(f"{k}={len(v)}" for k, v in sorted(by_dom.items())),
        f"constants: {PEAK_FLOPS/1e12:.0f} TFLOP/s bf16, {HBM_BW/1e9:.0f} GB/s HBM, "
        f"{ICI_BW/1e9:.0f} GB/s ICI per link (v5e)",
    ]
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=DEFAULT_DIR)
    args = ap.parse_args()
    recs = load(args.dir)
    print("## Dry-run / roofline summary\n")
    print(summary_stats(recs))
    print("\n### Single-pod (16×16 = 256 chips) roofline, per cell\n")
    print(roofline_table(recs, "16x16"))
    opt = [r for r in recs if r.get("optimized") and r["status"] == "ok"]
    if opt:
        print("\n### §Perf-optimized cells (--opt: weight_gather, cache re-shard, microbatching)\n")
        for r in opt:
            ro = r["roofline"]
            m = r.get("memory", {})
            tot = (m.get("temp_size_b") or 0) + (m.get("argument_size_b") or 0)
            print(f"* {r['arch']} × {r['shape']} × {r['mesh']}: "
                  f"t_comp={fmt_s(ro['t_compute_s'])} t_mem={fmt_s(ro['t_memory_s'])} "
                  f"t_coll={fmt_s(ro['t_collective_s'])} mem/dev={fmt_b(tot)}")
    print("\n### Multi-pod (2×16×16 = 512 chips) — compile/shard proof\n")
    recs_m = [r for r in recs if r["mesh"] == "2x16x16" and not r.get("optimized")]
    ok = sum(1 for r in recs_m if r["status"] == "ok")
    sk = sum(1 for r in recs_m if r["status"] == "skipped")
    er = [r for r in recs_m if r["status"] == "error"]
    print(f"{ok} cells compile on the multi-pod mesh, {sk} documented skips, "
          f"{len(er)} errors{': ' + ', '.join(r['arch'] + '×' + r['shape'] for r in er) if er else ''}.")


if __name__ == "__main__":
    main()
