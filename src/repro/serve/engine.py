"""Batched serving engine with TOFEC-admitted prompt storage.

Flow per request: the prompt blob is fetched from the object store through
the TOFEC proxy (erasure-coded ranged reads, adaptive (n, k) from the proxy
backlog), tokenized prompts are batched, prefilled, and decoded with the
arch's cached ``decode_step``. The storage path is the paper's system; the
LM path is the substrate it feeds.

Two fetch paths:

* **unfused** — :meth:`ServingEngine.fetch_prompts` submits the whole round
  through :meth:`Proxy.read_many`; the proxy batch-decodes completions per
  admission round on the host codec.
* **fused** — pass a :class:`FusedServingStep`: the proxy returns raw chunks
  (``raw=True``) and ONE jitted launch then runs the TOFEC admission update
  (:func:`repro.core.controller.tofec_step_jax`) *and* the batched MDS
  decode for the whole round. Admission control and erasure coding share a
  single compiled step — the serving-path half of the paper's proxy, on the
  jnp / pallas codec backends (``REPRO_CODEC_BACKEND`` selects which; the
  numpy backend is host-only and cannot fuse).

Compilation is shape-bucketed exactly like :mod:`repro.coding.codec`
(powers of two on batch / parity rows / strip width), and the per-item
decode matrices travel as *runtime* arrays built host-side from the cached
Cauchy tables — so a heterogeneous stream of codes, erasure patterns and
batch sizes reuses one trace per shape bucket (asserted in
``tests/test_fused_serve.py``).
"""

from __future__ import annotations

import dataclasses
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.coding import codec as codec_mod
from repro.coding import rs
from repro.coding.layout import SharedKeyLayout
from repro.core.controller import TofecTables, tofec_step_jax
from repro.core.static_optimizer import build_class_plan
from repro.models.registry import Arch
from repro.storage.proxy import Proxy, store_coded_object


class FusedServingStep:
    """One jitted launch per serving round: TOFEC admission update + batched
    MDS codec work (encode or decode), fused.

    State: ``q_ewma`` (the controller's backlog EWMA) lives on device and is
    threaded through successive calls, so the step is the serving-path twin
    of one :func:`repro.core.jax_sim.simulate_tofec_scan` iteration. Each
    call returns the payloads *and* the (n, k) the controller picks for the
    next round.

    Matrices are runtime inputs: decode matrices come from
    :meth:`Codec.decode_mats` (host-cached per erasure pattern), parity
    matrices from the cached Cauchy generator, both padded to the shape
    bucket and run through ``backend.prep_mats`` — so changing the code or
    the erasure pattern never retraces; only a new shape bucket compiles.
    """

    def __init__(self, tables: TofecTables, *, codec: codec_mod.Codec | None = None,
                 alpha: float = 0.99):
        self.codec = codec or codec_mod.get_codec()
        if not self.codec.backend.jitted:
            raise ValueError(
                f"codec backend {self.codec.name!r} is host-only; the fused "
                "serving step needs the jnp or pallas backend (select via "
                "REPRO_CODEC_BACKEND or get_codec('jnp'))"
            )
        self.tables = tables
        self.alpha = alpha
        self.traces = 0  # outer-jit compilations (bounded by shape buckets)
        self._fns: dict[tuple, object] = {}
        self._lock = threading.Lock()
        self.q_ewma = jnp.float32(0.0)

    @classmethod
    def for_class(cls, request_class, L: int, *, codec: codec_mod.Codec | None = None,
                  alpha: float = 0.99, eq7_factor: float = 2.0) -> "FusedServingStep":
        plan = build_class_plan(request_class, L, eq7_factor=eq7_factor)
        return cls(TofecTables.from_plan(plan), codec=codec, alpha=alpha)

    def reset(self) -> None:
        self.q_ewma = jnp.float32(0.0)

    # -- compilation cache ---------------------------------------------------

    def _fn(self, key: tuple):
        with self._lock:
            fn = self._fns.get(key)
        if fn is not None:
            return fn
        backend = self.codec.backend
        tables, alpha = self.tables, self.alpha
        kind = key[0]

        if kind == "adm":  # admission update only (n == k: no parity work)

            def fused(q_ewma, q):
                self.traces += 1  # runs at trace time only
                return tofec_step_jax(q_ewma, q, tables, alpha)

        elif kind == "dec":

            def fused(mats, rows, q_ewma, q):
                self.traces += 1  # runs at trace time only
                q_new, n_nxt, k_nxt = tofec_step_jax(q_ewma, q, tables, alpha)
                return q_new, n_nxt, k_nxt, backend.matmul_traced(mats, rows)

        else:

            def fused(mats, data, q_ewma, q):
                self.traces += 1  # runs at trace time only
                q_new, n_nxt, k_nxt = tofec_step_jax(q_ewma, q, tables, alpha)
                parity = backend.matmul_traced(mats, data)
                return q_new, n_nxt, k_nxt, jnp.concatenate([data, parity], axis=1)

        fn = jax.jit(fused)
        with self._lock:
            fn = self._fns.setdefault(key, fn)
        return fn

    # -- fused entry points ----------------------------------------------------

    def decode_batch(self, rows, present, *, n: int, k: int, q: float
                     ) -> tuple[np.ndarray, tuple[int, int]]:
        """Admission update + batched reconstruct in ONE jitted launch.

        rows: (batch, k, B) surviving strips; present: (batch, k) strip ids
        (or a shared (k,) pattern); q: the round's backlog signal. Returns
        ((batch, k, B) decoded data, (n, k) for the next round).
        """
        rows = np.asarray(rows, np.uint8)
        single = rows.ndim == 2
        if single:
            rows = rows[None]
        batch, _, B = rows.shape
        present = np.asarray(present, np.int64)
        if present.ndim == 1:
            present = np.broadcast_to(present, (batch, k))
        mats = self.codec.decode_mats(present, n, k)
        mats_p, rows_p, key = self.codec.pad_to_bucket("dec", mats, rows, n, k)
        fn = self._fn(key)
        self.q_ewma, n_nxt, k_nxt, out = fn(
            jnp.asarray(self.codec.backend.prep_mats(mats_p)), jnp.asarray(rows_p),
            self.q_ewma, jnp.float32(q),
        )
        data = np.asarray(out)[:batch, :k, :B]
        return (data[0] if single else data), (int(n_nxt), int(k_nxt))

    def encode_batch(self, data, *, n: int, k: int, q: float
                     ) -> tuple[np.ndarray, tuple[int, int]]:
        """Admission update + batched systematic encode in ONE launch.

        data: (batch, k, B) → ((batch, n, B) coded strips, next (n, k)).
        """
        data = np.asarray(data, np.uint8)
        single = data.ndim == 2
        if single:
            data = data[None]
        batch, _, B = data.shape
        if n == k:  # no parity: admission update only, data passes through
            fn = self._fn(("adm",))
            self.q_ewma, n_nxt, k_nxt = fn(self.q_ewma, jnp.float32(q))
            return (data[0] if single else data), (int(n_nxt), int(k_nxt))
        m = n - k
        par = rs.cauchy_parity_matrix(n, k)
        mats = np.broadcast_to(par, (batch, m, k))
        mats_p, data_p, key = self.codec.pad_to_bucket("enc", mats, data, n, k)
        fn = self._fn(key)
        self.q_ewma, n_nxt, k_nxt, out = fn(
            jnp.asarray(self.codec.backend.prep_mats(mats_p)), jnp.asarray(data_p),
            self.q_ewma, jnp.float32(q),
        )
        coded = np.asarray(out)[:batch, :n, :B]
        return (coded[0] if single else coded), (int(n_nxt), int(k_nxt))


@dataclasses.dataclass
class ServeResult:
    tokens: np.ndarray  # (B, steps) generated ids
    storage_total_s: list[float]  # per-request proxy read delays
    codes: list[tuple[int, int]]  # (n, k) used per prompt fetch
    next_code: tuple[int, int] | None = None  # fused path: controller's pick


class ServingEngine:
    def __init__(self, arch: Arch, params, *, max_seq: int = 128):
        self.arch = arch
        self.params = params
        self.max_seq = max_seq
        self._prefill = jax.jit(
            lambda p, b: arch.prefill(p, b, max_seq=self.max_seq)
        )
        self._decode = jax.jit(arch.decode_step)

    # -- storage integration -------------------------------------------------

    @staticmethod
    def store_prompt(store, key: str, layout: SharedKeyLayout, tokens: np.ndarray):
        store_coded_object(store, key, layout, tokens.astype(np.int32).tobytes())

    def fetch_prompts(
        self, proxy: Proxy, layout: SharedKeyLayout, keys: list[str], prompt_len: int,
        *, fused: FusedServingStep | None = None, retries: int = 3,
    ) -> tuple[np.ndarray, list[float], list[tuple[int, int]], tuple[int, int] | None]:
        """Batched prompt fetch: the whole round is submitted up front (the
        proxy's policy sees it as backlog) and reconstructed batched — by the
        proxy's admission round (unfused) or by ``fused``'s single jitted
        admission+decode launch (raw chunks in, payloads out).

        Reads that exhaust their n − k failure budget (the backlog-adapted
        code can be as lean as (1, 1)) are resubmitted up to ``retries``
        times; the retry round is smaller, so the policy re-picks with more
        redundancy. Reported delays accumulate across attempts (what the
        client actually waited); codes report the attempt that served."""
        payload_len = prompt_len * 4
        raw = fused is not None
        results = proxy.read_many(keys, layout, payload_len, raw=raw)
        failed_s = [0.0] * len(keys)
        for _ in range(retries):
            bad_idx = [i for i, r in enumerate(results) if not r.ok]
            if not bad_idx:
                break
            for i in bad_idx:
                failed_s[i] += results[i].total_s
            redo = proxy.read_many([keys[i] for i in bad_idx], layout, payload_len,
                                   raw=raw)
            for i, r in zip(bad_idx, redo):
                results[i] = r
        bad = [k for k, r in zip(keys, results) if not r.ok]
        if bad:
            raise RuntimeError(f"prompt fetch failed for {', '.join(bad)}")
        delays = [r.total_s + extra for r, extra in zip(results, failed_s)]
        codes = [(r.n, r.k) for r in results]
        if fused is None:
            toks = [np.frombuffer(r.data, np.int32) for r in results]
            return np.stack(toks), delays, codes, None
        rows, present = layout.gather_rows_batch([(r.k, r.chunks) for r in results])
        data, next_code = fused.decode_batch(
            rows, present, n=layout.N, k=layout.K, q=len(keys)
        )
        toks = [
            np.frombuffer(data[i].reshape(-1)[:payload_len].tobytes(), np.int32)
            for i in range(len(results))
        ]
        return np.stack(toks), delays, codes, next_code

    # -- generation -----------------------------------------------------------

    def generate(self, prompts: np.ndarray, steps: int, *, greedy: bool = True) -> np.ndarray:
        """prompts: (B, S) int32 → (B, steps) generated ids."""
        batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
        if self.arch.cfg.family == "vlm":
            B = prompts.shape[0]
            batch["patches"] = jnp.zeros(
                (B, self.arch.cfg.vision_patches, self.arch.cfg.d_model), jnp.float32
            )
        if self.arch.cfg.family == "encdec":
            B = prompts.shape[0]
            batch["frames"] = jnp.zeros(
                (B, self.arch.cfg.encoder_seq, self.arch.cfg.d_model), jnp.float32
            )
        logits, cache = self._prefill(self.params, batch)
        out = []
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        for _ in range(steps):
            out.append(np.asarray(tok)[:, 0])
            logits, cache = self._decode(self.params, tok, cache)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return np.stack(out, axis=1)

    def serve(
        self,
        proxy: Proxy,
        layout: SharedKeyLayout,
        keys: list[str],
        *,
        prompt_len: int,
        steps: int,
        fused: FusedServingStep | None = None,
    ) -> ServeResult:
        prompts, delays, codes, next_code = self.fetch_prompts(
            proxy, layout, keys, prompt_len, fused=fused
        )
        gen = self.generate(prompts, steps)
        return ServeResult(tokens=gen, storage_total_s=delays, codes=codes,
                           next_code=next_code)
