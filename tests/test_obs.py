"""repro.obs: collection invariance (bit-identical primary outputs and
pinned compile counts with telemetry on), device-folded histogram
correctness against host recounts, exact taskq cancellation accounting,
span-tree nesting + Chrome-trace JSON validity, the Prometheus formatter,
the shared CompileStats registry, and the perf-gate comparison rules."""

import json

import numpy as np
import pytest

import jax.numpy as jnp

from repro import obs
from repro.core import PAPER_READ_3MB, RequestClass
from repro.core.traces import TraceStore
from repro.fleet import FleetSweep, PolicySpec, grid_cases
from repro.taskq import TaskqSweep

CLS = RequestClass("read3mb", 3.0, PAPER_READ_3MB, k_max=6, r_max=2.0, n_max=12)
L = 16
SIZES = tuple(CLS.file_mb / k for k in range(1, CLS.k_max + 1))


@pytest.fixture
def obs_on():
    obs.set_enabled(True)
    obs.reset_trace()
    yield
    obs.set_enabled(None)
    obs.reset_trace()


@pytest.fixture
def obs_off():
    obs.set_enabled(False)
    yield
    obs.set_enabled(None)


def _pools(seed=3, samples=512):
    store = TraceStore.generate(
        PAPER_READ_3MB, SIZES, threads=CLS.n_max, samples=samples,
        correlation=0.0, seed=seed,
    )
    return store.device_pools(n_max=CLS.n_max)


def _grid(n_seeds=2):
    return grid_cases(
        [10.0, 25.0], [PolicySpec.tofec(), PolicySpec.static(12, 6)],
        list(range(n_seeds)), CLS, L,
    )


# ---------------------------------------------------------------------------
# MetricsBuf: host-visible semantics of the device folds
# ---------------------------------------------------------------------------


def test_metricsbuf_count_observe_high_snapshot():
    buf = obs.MetricsBuf.zeros(counters=("c",), hists={"h": 4}, highs=("hi",))
    buf = buf.count("c", 3).count("c")
    buf = buf.observe("h", jnp.array([0, 1, 1, 9]))  # 9 clips to last bucket
    buf = buf.observe("h", jnp.array([2, 2]), weight=jnp.array([1, 0]))
    buf = buf.high("hi", jnp.array([1.5, 7.25, 0.0])).high("hi", 2.0)
    snap = buf.snapshot()
    assert snap["counters"]["c"] == 4
    assert snap["hists"]["h"] == [1, 2, 1, 1]
    assert snap["highs"]["hi"] == 7.25


def test_metricsbuf_reduce_rows_drops_tail_padding():
    buf = obs.MetricsBuf(
        counters={"c": jnp.array([1, 2, 99], jnp.int32)},
        hists={"h": jnp.array([[1, 0], [0, 1], [5, 5]], jnp.int32)},
        highs={"hi": jnp.array([1.0, 3.0, 9.0], jnp.float32)},
    )
    snap = buf.reduce_rows(2).snapshot()
    assert snap["counters"]["c"] == 3
    assert snap["hists"]["h"] == [1, 1]
    assert snap["highs"]["hi"] == 3.0


def test_metricsbuf_merge_unions_disjoint_and_adds_shared():
    a = obs.MetricsBuf.zeros(counters=("x",), highs=("hi",)).count("x", 2)
    b = obs.MetricsBuf.zeros(counters=("x", "y"), highs=("hi",))
    b = b.count("x", 5).count("y", 1).high("hi", 4.0)
    snap = a.merge(b).snapshot()
    assert snap["counters"] == {"x": 7, "y": 1}
    assert snap["highs"]["hi"] == 4.0


def test_prometheus_exposition_shape():
    buf = obs.MetricsBuf.zeros(counters=("reqs",), hists={"q": 3}, highs=("q_hi",))
    buf = buf.count("reqs", 2).observe("q", jnp.array([0, 2, 2])).high("q_hi", 2.0)
    text = buf.to_prometheus(prefix="t")
    assert "# TYPE t_reqs_total counter" in text
    assert "t_reqs_total 2" in text
    # cumulative buckets, +Inf tail, count line
    assert 't_q_bucket{le="0"} 1' in text
    assert 't_q_bucket{le="+Inf"} 3' in text
    assert "t_q_count 3" in text
    assert "t_q_hi 2.0" in text


# ---------------------------------------------------------------------------
# Sweep collection: invariance, padding masks, host recounts
# ---------------------------------------------------------------------------


def test_fleet_collection_invariant_and_histograms_match_host_recount():
    cases, count = _grid(), 300  # pads to a larger pow2 time bucket
    try:
        obs.set_enabled(False)
        base = FleetSweep(chunk=4).run(cases, count)
        obs.set_enabled(True)
        res = FleetSweep(chunk=4).run(cases, count)
    finally:
        obs.set_enabled(None)
    # Primary outputs are bit-identical with collection on.
    for name in base.out:
        np.testing.assert_array_equal(
            np.asarray(base.out[name]), np.asarray(res.out[name]))
    # Collection costs no extra compiles (the collect flag is in the key).
    assert res.compiles == base.compiles
    assert base.metrics is None and res.metrics is not None
    snap = res.metrics.snapshot()
    G = len(cases)
    # Padded steps masked out: exact request/task tallies.
    assert snap["counters"]["fleet_requests"] == G * count
    ks = np.asarray(res.out["k"])[:, :count].astype(int)
    ns = np.asarray(res.out["n"])[:, :count].astype(int)
    assert snap["counters"]["fleet_tasks"] == int(ns.sum())
    np.testing.assert_array_equal(
        snap["hists"]["fleet_pick_k"],
        np.bincount(ks.ravel(), minlength=obs.PICK_BINS))
    np.testing.assert_array_equal(
        snap["hists"]["fleet_pick_n"],
        np.bincount(ns.ravel(), minlength=obs.PICK_BINS))
    assert snap["highs"]["fleet_delay_hi"] == pytest.approx(
        float(np.asarray(res.out["total"])[:, :count].max()), rel=1e-6)


def test_taskq_collection_invariant_with_exact_cancellations(obs_on):
    cases, count = _grid(n_seeds=1), 200
    dp = _pools()
    obs.set_enabled(False)
    base = TaskqSweep(chunk=4).run(cases, count, dp)
    obs.set_enabled(True)
    res = TaskqSweep(chunk=4).run(cases, count, dp)
    for name in base.out:
        np.testing.assert_array_equal(
            np.asarray(base.out[name]), np.asarray(res.out[name]))
    assert res.compiles == base.compiles == 1
    snap = res.metrics.snapshot()
    G = len(cases)
    assert snap["counters"]["taskq_requests"] == G * count
    ns = np.asarray(res.out["n"])[:, :count].astype(int)
    ks = np.asarray(res.out["k"])[:, :count].astype(int)
    c = snap["counters"]
    # Cancel RPCs split exactly into queued vs in-service; ties C == D
    # complete with the request, so the total can undershoot Σ(n−k).
    assert c["taskq_cancelled"] == c["taskq_cancel_queue"] + c["taskq_cancel_service"]
    assert 0 < c["taskq_cancelled"] <= int((ns - ks).sum())
    # Idle-thread histogram counts every real arrival once.
    assert sum(snap["hists"]["taskq_idle"]) == G * count
    assert len(snap["hists"]["taskq_idle"]) == L + 1
    assert snap["highs"]["taskq_q_hi"] >= 0.0


def test_taskq_scan_entry_point_collect_arg(obs_off):
    from repro.taskq.engine import taskq_scan
    from repro.taskq.policies import encode_policy

    case = _grid(n_seeds=1)[0]
    dp = _pools()
    enc = encode_policy(PolicySpec.static(12, 6), CLS, L, CLS.k_max + 1,
                        CLS.n_max + 1, None)
    cfg = {"J": CLS.file_mb, "alpha": enc.alpha, "r_max": enc.r_max,
           "pol": enc.pol, "gk_max": enc.gk_max, "h_k": enc.h_k,
           "h_n": enc.h_n}
    from repro.taskq import taskq_streams
    inter, idx = taskq_streams(case, 64, dp.n_rows)
    off = taskq_scan(cfg, inter, idx, dp.pools, dp.sizes_mb, L=L)
    on = taskq_scan(cfg, inter, idx, dp.pools, dp.sizes_mb, L=L, collect=True)
    assert "obs" not in off and "obs" in on
    for name in off:
        np.testing.assert_array_equal(np.asarray(off[name]), np.asarray(on[name]))


# ---------------------------------------------------------------------------
# Closed-loop serving: device metrics ride the fused step
# ---------------------------------------------------------------------------


def _serve_tokens(rounds=2, steps=2):
    import jax

    from repro.coding.codec import Codec
    from repro.coding.layout import SharedKeyLayout
    from repro.core import FeedbackPolicy, StaticPolicy
    from repro.models import get
    from repro.serve import ClosedLoopServer, FusedServingStep, ServePolicy, ServingEngine
    from repro.storage import MemoryStore, Proxy

    arch = get("qwen1.5-0.5b", smoke=True)
    params = arch.init(jax.random.key(2))
    eng = ServingEngine(arch, params, max_seq=64)
    prompt_len = 16
    layout = SharedKeyLayout(K=4, r=2, strip_bytes=prompt_len)
    store = MemoryStore()
    rng = np.random.default_rng(6)
    keys = []
    for i in range(3):
        toks = rng.integers(0, arch.cfg.vocab, size=(prompt_len,)).astype(np.int32)
        ServingEngine.store_prompt(store, f"p/{i}", layout, toks)
        keys.append(f"p/{i}")
    proxy = Proxy(store, StaticPolicy(8, 4), L=8,
                  write_policy=FeedbackPolicy(layout.N, layout.K))
    step = FusedServingStep.for_policy(ServePolicy.tofec(), CLS, L,
                                       codec=Codec("jnp"))
    server = ClosedLoopServer(eng, proxy, layout, step, prompt_len=prompt_len)
    try:
        results = [server.serve_round(keys, steps=steps) for _ in range(rounds)]
        return [np.asarray(r.tokens) for r in results], server
    finally:
        proxy.close()


def test_closed_loop_metrics_invariant_and_exact(tmp_path):
    obs.set_enabled(False)
    try:
        toks_off, server_off = _serve_tokens()
    finally:
        obs.set_enabled(None)
    obs.set_enabled(True)
    obs.reset_trace()
    try:
        toks_on, server_on = _serve_tokens()
        # Generated tokens bit-identical with collection on; still one trace.
        for a, b in zip(toks_off, toks_on):
            np.testing.assert_array_equal(a, b)
        assert server_on.traces == server_off.traces == 1
        assert server_off.metrics is None
        snap = server_on.metrics.snapshot()
        c = snap["counters"]
        assert c["serve_rounds"] == 2
        assert c["serve_requested"] == 2 * 3
        assert c["serve_served"] == 2 * 3
        assert c["serve_decode_errors"] == 0
        assert sum(snap["hists"]["serve_batch"]) == 2
        assert sum(snap["hists"]["serve_pick_n"]) == 2
        assert snap["highs"]["serve_q_hi"] >= 0.0
        # The round's host spans export as a loadable Chrome trace.
        names = {ev["name"] for ev in obs.get_tracer().events()}
        assert {"serve.round", "serve.fetch", "serve.launch"} <= names
        path = obs.write_trace(str(tmp_path / "serve_trace.json"))
        doc = json.load(open(path))
        assert any(ev["name"] == "serve.round" for ev in doc["traceEvents"])
        # Prometheus exposition of the same snapshot is well-formed.
        assert "repro_serve_rounds_total 2" in obs.to_prometheus(snap)
    finally:
        obs.set_enabled(None)
        obs.reset_trace()


# ---------------------------------------------------------------------------
# Span tracing
# ---------------------------------------------------------------------------


def test_span_nesting_and_chrome_trace_json(obs_on, tmp_path):
    tr = obs.get_tracer()
    with obs.span("outer", mesh=[1]):
        with obs.span("inner", bucket="(4, 64)"):
            pass
        with obs.span("inner"):
            pass
    by_name: dict = {}
    for ev in tr.events():  # spans record at exit: inner events come first
        by_name.setdefault(ev["name"], []).append(ev)
    (outer,), inners = by_name["outer"], by_name["inner"]
    assert outer["args"]["depth"] == 0
    assert outer["args"]["parent"] is None
    assert all(ev["args"]["depth"] == 1 for ev in inners)
    assert all(ev["args"]["parent"] == "outer" for ev in inners)
    assert inners[0]["args"]["bucket"] == "(4, 64)"
    # Chrome trace_event document: loads back, complete events, µs fields.
    path = obs.write_trace(str(tmp_path / "trace.json"))
    doc = json.load(open(path))
    assert doc["displayTimeUnit"] == "ms"
    assert len(doc["traceEvents"]) == 3
    for ev in doc["traceEvents"]:
        assert ev["ph"] == "X" and ev["dur"] >= 0 and "pid" in ev and "tid" in ev
    agg = obs.aggregate()
    assert agg["inner"]["count"] == 2
    assert agg["outer"]["total_us"] >= agg["outer"]["max_us"]
    assert "outer" in tr.format_table()


def test_spans_disabled_record_nothing():
    obs.set_enabled(False)
    obs.reset_trace()
    try:
        with obs.span("never"):
            pass
        assert obs.get_tracer().events() == []
    finally:
        obs.set_enabled(None)


def test_traced_decorator(obs_on):
    calls = []

    @obs.traced("deco.fn", tag=1)
    def fn(x):
        calls.append(x)
        return x + 1

    assert fn(1) == 2 and calls == [1]
    ev = [e for e in obs.get_tracer().events() if e["name"] == "deco.fn"]
    assert len(ev) == 1 and ev[0]["args"]["tag"] == 1


def test_sweep_run_emits_spans(obs_on):
    FleetSweep(chunk=4).run(_grid(n_seeds=1), 64)
    names = {ev["name"] for ev in obs.get_tracer().events()}
    assert {"sweep.chunk", "sweep.launch", "sweep.trace"} <= names


# ---------------------------------------------------------------------------
# Shared compile accounting + run metadata
# ---------------------------------------------------------------------------


def test_compile_stats_registry_and_aliases():
    s = obs.CompileStats(label="test.engine")
    s.traces += 2
    s.launches += 5
    snap = obs.compile_snapshot()
    assert snap["test.engine"]["traces"] == 2
    assert snap["test.engine"]["launches"] == 5
    # Back-compat aliases still resolve to the shared class.
    from repro.coding.codec import CodecStats
    from repro.fleet.sweep import SweepStats
    assert SweepStats is obs.CompileStats and CodecStats is obs.CompileStats


def test_run_meta_fields():
    meta = obs.run_meta(mesh_shape=(2, 4))
    assert meta["schema_version"] == obs.SCHEMA_VERSION
    assert meta["host_cores"] >= 1 and meta["host_devices"] >= 1
    assert meta["mesh_shape"] == [2, 4]
    rev = meta["git_rev"]
    assert rev is None or (isinstance(rev, str) and len(rev) >= 7)


# ---------------------------------------------------------------------------
# Perf gate: comparison rules
# ---------------------------------------------------------------------------


def test_gate_rules(tmp_path):
    from benchmarks import gate

    art = {
        "schema": "repro.fleet/BENCH_fleet/v1",
        "grid_size": 8, "count": 256, "compiles": 1, "launches": 2,
        "capacity_req_s": {"tofec": 30.0},
        "headline": {"delay_gain_vs_basic": 2.5},
    }
    res_dir, base_dir = tmp_path / "res", tmp_path / "base"
    res_dir.mkdir()
    (res_dir / "BENCH_fleet.json").write_text(json.dumps(art))
    # No baseline: passes with a note.
    assert gate.check(str(res_dir), str(base_dir)) == 0
    gate.update(str(res_dir), str(base_dir))
    assert gate.check(str(res_dir), str(base_dir)) == 0
    # Count drift fails exactly; stat drift fails past the tolerance.
    bad = dict(art, compiles=2,
               headline={"delay_gain_vs_basic": 2.5 * 1.2})
    (res_dir / "BENCH_fleet.json").write_text(json.dumps(bad))
    assert gate.check(str(res_dir), str(base_dir)) == 1
    fails, warns, notes = gate.check_file(
        str(res_dir / "BENCH_fleet.json"),
        str(base_dir / "BENCH_fleet.json"))
    assert len(fails) == 2 and not warns
    # Within-tolerance stat drift passes.
    ok = dict(art, headline={"delay_gain_vs_basic": 2.5 * 1.05})
    (res_dir / "BENCH_fleet.json").write_text(json.dumps(ok))
    assert gate.check(str(res_dir), str(base_dir)) == 0
