"""Dry-run machinery validation at test scale (8 host devices, subprocess).

Covers: the XLA while-loop-counted-once fact the FLOPs pass corrects for,
the collective-bytes HLO parser, and a miniature end-to-end dry-run cell
(sharded lower + compile + roofline) on a 2×4 mesh with a smoke config.
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_py(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=540, env=env, cwd=ROOT,
    )
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


def test_scan_flops_counted_once_and_unroll_corrects():
    out = _run_py("""
        import jax, jax.numpy as jnp
        # same normalization as repro.launch.dryrun.cost_dict (that module
        # must not be imported here: it forces 512 host devices on import)
        def cost_dict(ca): return (ca[0] if ca else {}) if isinstance(ca, (list, tuple)) else ca
        def body(c, _): return c @ c, None
        def f(unroll):
            def g(x):
                y, _ = jax.lax.scan(body, x, None, length=7, unroll=unroll)
                return y
            return g
        x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
        rolled = cost_dict(jax.jit(f(False)).lower(x).compile().cost_analysis())["flops"]
        unrolled = cost_dict(jax.jit(f(True)).lower(x).cost_analysis())["flops"]
        print(f"RATIO {unrolled / rolled}")
    """)
    ratio = float(out.split("RATIO ")[1])
    assert ratio == pytest.approx(7.0, rel=0.05)


def test_collective_parser_on_real_partitioned_hlo():
    out = _run_py("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.roofline import collective_bytes
        mesh = jax.make_mesh((8,), ("d",))
        with mesh:
            def g(a, b):
                return jnp.sum(a @ b)
            gs = jax.jit(g,
                in_shardings=(NamedSharding(mesh, P(None, "d")), NamedSharding(mesh, P("d", None))),
                out_shardings=NamedSharding(mesh, P()))
            a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
            comp = gs.lower(a, a).compile()
        cb = collective_bytes(comp.as_text())
        print("COLL", cb["all-reduce"], cb["count"])
    """)
    _, ar_bytes, count = out.strip().rsplit(" ", 2)[-3:], None, None
    parts = out.strip().split()
    ar_bytes, count = int(parts[-2]), int(parts[-1])
    assert count >= 1
    # contraction-sharded matmul all-reduces the (256, 256) f32 result.
    assert ar_bytes >= 256 * 256 * 4


def test_mini_dryrun_cell_sharded_compile_and_roofline():
    out = _run_py("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.models.registry import get
        from repro.models.sharding import axis_rules, spec_for
        from repro.launch.roofline import analyze
        from repro.launch.specs import _specs_tree, _batch_shardings, batch_specs
        from repro.train.train_step import make_train_step
        from repro.train.optimizer import init_opt_state
        from repro.models.config import ShapeSpec

        arch = get("qwen1.5-0.5b", smoke=True)
        shape = ShapeSpec("mini", "train", seq=64, batch=8)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        with mesh:
            with axis_rules(mesh):
                params = jax.eval_shape(lambda: arch.init(jax.random.key(0)))
                p_specs = _specs_tree(mesh, params, arch.logical_axes())
                opt = jax.eval_shape(lambda: init_opt_state(params))
                o_specs = {"m": p_specs, "v": p_specs, "step": NamedSharding(mesh, P())}
                batch = batch_specs(arch.cfg, shape, "train")
                b_specs = _batch_shardings(mesh, arch.cfg, batch)
                fn = make_train_step(arch)
                jfn = jax.jit(fn, in_shardings=(p_specs, o_specs, b_specs),
                              out_shardings=(p_specs, o_specs, None))
                compiled = jfn.lower(params, opt, batch).compile()
                roof = analyze(compiled, 8)
        mem = compiled.memory_analysis()
        print("RESULT", roof.flops > 0, roof.hbm_bytes > 0,
              mem.temp_size_in_bytes >= 0, roof.dominant)
    """)
    assert "RESULT True True True" in out


def test_dryrun_results_schema():
    """Any artifacts already produced by the sweep have the right schema."""
    d = os.path.join(ROOT, "benchmarks", "results", "dryrun")
    if not os.path.isdir(d) or not os.listdir(d):
        pytest.skip("no dry-run artifacts yet")
    for name in sorted(os.listdir(d))[:10]:
        try:
            with open(os.path.join(d, name)) as f:
                rec = json.load(f)
        except json.JSONDecodeError:
            continue  # sweep may be mid-write
        assert rec["status"] in ("ok", "skipped", "error"), name
        if rec["status"] == "ok":
            r = rec["roofline"]
            assert r["flops"] > 0 and r["chips"] in (256, 512)
            assert rec["useful_flops_ratio"] is None or rec["useful_flops_ratio"] < 1.5
