"""Telemetry demo: every repro.obs layer over one serving loop, one exact
task-level sweep and two profiled kernels.

Turns collection on (:func:`repro.obs.set_enabled` — the programmatic twin
of ``REPRO_OBS=1``), runs a small closed-loop serve and a TaskqSweep grid,
then exports everything the layer produces:

* the device-folded metrics snapshots (round/request counters, picked-(n,k)
  and idle-thread histograms, queue high-water marks) plus their Prometheus
  text exposition;
* the per-round / per-window **timelines** (arrival rate, backlog, picks,
  delay-histogram deltas) and the :func:`repro.obs.slo_report` judged over
  them — burn rate, breach events, controller pick-settling;
* the launch **profiler** table — XLA cost_analysis FLOPs/bytes vs measured
  wallclock, roofline bound per compiled kernel;
* the ASCII **dashboard** (sparkline timelines + SLO tiles + p99 exemplar
  anatomy) on stdout and its self-contained HTML twin, plus the structured
  NDJSON event log;
* the per-request **flight recorder**: the sweep's slowest cell replayed
  with ``flight=True`` (aggregate engines stream, flight replays one case)
  → ``flight_trace.json`` (simulated-clock Perfetto trace, one track per
  pool thread) + ``flight_records.ndjson`` (``repro.obs/flight/v1``), and
  the serving loop's per-round phase ring;
* the shared compile-accounting snapshot across every engine touched;
* the host span table (compile/launch/fetch/finalize boundaries) and the
  Chrome ``trace_event`` JSON — load it in ``chrome://tracing`` / Perfetto.

Run:  PYTHONPATH=src python examples/obs_demo.py [--fast] [--out DIR]
"""

import argparse
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.coding.codec import Codec
from repro.coding.layout import SharedKeyLayout
from repro.configs.qwen1_5_0_5b import CONFIG as QWEN
from repro.core import PAPER_READ_3MB, FeedbackPolicy, RequestClass, StaticPolicy
from repro.core.controller import TofecTables
from repro.core.jax_sim import JaxSimParams, simulate_tofec_scan
from repro.core.static_optimizer import build_class_plan
from repro.core.traces import TraceStore
from repro.fleet import PolicySpec, grid_cases
from repro.models.registry import Arch, _FAMILY_MODULES
from repro.serve import ClosedLoopServer, FusedServingStep, ServePolicy, ServingEngine
from repro.storage import MemoryStore, Proxy
from repro.taskq import TaskqSweep

CLS = RequestClass("read3mb", 3.0, PAPER_READ_3MB, k_max=6, r_max=2.0, n_max=12)
L = 16
CFG = dataclasses.replace(
    QWEN, name="obs-demo", n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
    d_ff=256, vocab=2048,
)


def serve_rounds(rounds: int, steps: int) -> tuple:
    arch = Arch(cfg=CFG, module=_FAMILY_MODULES["dense"])
    eng = ServingEngine(arch, arch.init(jax.random.key(0)), max_seq=64)
    prompt_len = 16
    layout = SharedKeyLayout(K=4, r=2, strip_bytes=prompt_len)
    store = MemoryStore()
    rng = np.random.default_rng(0)
    keys = []
    for i in range(4):
        toks = rng.integers(0, CFG.vocab, size=(prompt_len,)).astype(np.int32)
        ServingEngine.store_prompt(store, f"p/{i}", layout, toks)
        keys.append(f"p/{i}")
    proxy = Proxy(store, StaticPolicy(8, 4), L=8,
                  write_policy=FeedbackPolicy(layout.N, layout.K))
    step = FusedServingStep.for_policy(ServePolicy.tofec(), CLS, L,
                                       codec=Codec("jnp"))
    server = ClosedLoopServer(eng, proxy, layout, step, prompt_len=prompt_len)
    try:
        for _ in range(rounds):
            server.serve_round(keys, steps=steps)
        return (server.metrics.snapshot(), server.timeline.snapshot(),
                server.flight.records())
    finally:
        proxy.close()


def taskq_grid(count: int) -> tuple:
    sizes = tuple(CLS.file_mb / k for k in range(1, CLS.k_max + 1))
    store = TraceStore.generate(PAPER_READ_3MB, sizes, threads=CLS.n_max,
                                samples=1024, correlation=0.0, seed=3)
    cases = grid_cases([10.0, 25.0],
                       [PolicySpec.tofec(), PolicySpec.static(12, 6)],
                       [0], CLS, L)
    dp = store.device_pools(n_max=CLS.n_max)
    sweep = TaskqSweep(chunk=4)
    res = sweep.run(cases, count, dp)
    # Flight zoom: replay the grid's slowest cell with the recorder on.
    worst = int(np.argmax(res.to_numpy()["total"].mean(axis=1)))
    log = sweep.replay_flight(res, dp, worst)
    return res.metrics.snapshot(), res.timeline.snapshot(), log


def profile_kernels(count: int) -> None:
    """Roofline-profile the fluid scan and the codec's decode GEMM shape."""
    p = JaxSimParams.from_class(CLS, L)
    tables = TofecTables.from_plan(build_class_plan(CLS, L))
    rng = np.random.default_rng(0)
    inter = jnp.asarray(rng.exponential(1.0 / 25.0, size=count), jnp.float32)
    exps = jnp.asarray(rng.exponential(1.0, size=(count, CLS.n_max)), jnp.float32)
    # Close over the static params: AOT-compiled callables take only the
    # array arguments, so profile a fully-array-signature wrapper.
    scan = jax.jit(lambda i, e: simulate_tofec_scan(p, tables, i, e))
    obs.profile_launch("tofec_scan", scan, inter, exps)

    # The MDS decode inner product at a serving-sized shape: (k × n) decode
    # matrix against n coded strips of 4 KB.
    G = jnp.asarray(rng.standard_normal((CLS.k_max, CLS.n_max)), jnp.float32)
    shards = jnp.asarray(rng.standard_normal((CLS.n_max, 4096)), jnp.float32)
    obs.profile_launch("decode_matmul", jax.jit(lambda a, b: a @ b), G, shards)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fast", action="store_true", help="smaller run (CI)")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "benchmarks", "results"))
    args = ap.parse_args()

    obs.set_enabled(True)
    obs.reset_trace()
    obs.reset_profiles()

    serve_snap, serve_tl, serve_flight = serve_rounds(
        rounds=2 if args.fast else 4, steps=2 if args.fast else 4)
    taskq_snap, taskq_tl, flight_log = taskq_grid(
        count=128 if args.fast else 512)
    profile_kernels(count=128 if args.fast else 1024)

    spec = obs.SLOSpec(target_s=0.25, percentile=0.99, window=4)
    events = obs.EventLog("obs_demo")
    exemplars = flight_log.exemplars(3)
    report = obs.slo_report(serve_tl, spec, label="obs_demo", events=events,
                            exemplars=exemplars)
    profile = obs.profile_snapshot()

    print("== serving metrics ==")
    print(obs.to_prometheus(serve_snap, prefix="repro",
                            labels={"run": "obs_demo", "plane": "serve"}))
    print("== taskq metrics ==")
    print(obs.to_prometheus(taskq_snap, prefix="repro",
                            labels={"run": "obs_demo", "plane": "taskq"}))

    print("== compile accounting ==")
    for label, row in obs.compile_snapshot().items():
        print(f"  {label}: traces={row['traces']} launches={row['launches']}")

    print("\n== dashboard ==")
    print(obs.ascii_dashboard({"serve": serve_tl, "taskq": taskq_tl},
                              slo=report, profile=profile,
                              exemplars=exemplars))

    print("== span table ==")
    print(obs.get_tracer().format_table())

    out_dir = os.path.abspath(args.out)
    os.makedirs(out_dir, exist_ok=True)
    trace_path = obs.write_trace(os.path.join(out_dir, "obs_trace.json"))
    snap_path = os.path.join(out_dir, "obs_metrics.json")
    with open(snap_path, "w") as f:
        json.dump({"meta": obs.run_meta(), "serve": serve_snap,
                   "taskq": taskq_snap,
                   "slo": {k: v for k, v in report.items() if k != "events"},
                   "serve_flight": serve_flight,
                   "profile": profile,
                   "compile": obs.compile_snapshot()}, f, indent=1)
    dash_path = obs.html_report(
        os.path.join(out_dir, "obs_dashboard.html"),
        {"serve": serve_tl, "taskq": taskq_tl}, slo=report, profile=profile,
        exemplars=exemplars, meta={"run": "obs_demo", "fast": bool(args.fast)})
    events_path = events.write(os.path.join(out_dir, "obs_events.ndjson"))
    flight_trace = flight_log.write_trace(
        os.path.join(out_dir, "flight_trace.json"))
    flight_recs = flight_log.write_ndjson(
        os.path.join(out_dir, "flight_records.ndjson"))
    print(f"\nwrote {trace_path}")
    print(f"wrote {snap_path}")
    print(f"wrote {dash_path}")
    print(f"wrote {events_path}")
    print(f"wrote {flight_trace}")
    print(f"wrote {flight_recs}")


if __name__ == "__main__":
    main()
