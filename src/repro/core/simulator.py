"""Discrete-event simulator of the proxy queueing system (Fig.2).

Faithful to §II-A semantics:
  * FIFO request queue; FIFO task queue; L threads.
  * The head-of-line request is admitted only when at least one thread is
    idle AND the task queue is empty; its n tasks are then injected.
  * Tasks start on idle threads in FIFO order; per-batch task delays are
    pre-sampled jointly (preserving Shared-Key cross-thread correlation;
    "the i-th thread downloads the i-th coded chunk", §III-B).
  * When k tasks of a request have completed, the request departs and its
    remaining tasks are preemptively cancelled: queued ones are removed,
    in-service ones release their thread immediately (§II-A, footnote 1).
  * Work conserving: freed threads immediately pull queued tasks, and
    admission re-runs whenever a thread frees or the task queue drains.

Delay bookkeeping matches §II-C: D_q = T_1 − T_A (first task start minus
arrival), D_s = X_(k) − T_1, total = D_q + D_s.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
from collections import deque

import numpy as np

from repro.core.controller import Policy


@dataclasses.dataclass
class RequestStats:
    arrival: float
    cls_id: int
    n: int
    k: int
    t_first_start: float = np.nan
    t_done: float = np.nan
    completed_tasks: int = 0
    arrival_index: int = -1  # global arrival order (shared-pool sampler hook)

    @property
    def d_q(self) -> float:
        return self.t_first_start - self.arrival

    @property
    def d_s(self) -> float:
        return self.t_done - self.t_first_start

    @property
    def total(self) -> float:
        return self.t_done - self.arrival


@dataclasses.dataclass
class SimResult:
    stats: list[RequestStats]
    horizon: float

    def totals(self) -> np.ndarray:
        return np.array([s.total for s in self.stats])

    def service(self) -> np.ndarray:
        return np.array([s.d_s for s in self.stats])

    def queueing(self) -> np.ndarray:
        return np.array([s.d_q for s in self.stats])

    def ks(self) -> np.ndarray:
        return np.array([s.k for s in self.stats])

    def ns(self) -> np.ndarray:
        return np.array([s.n for s in self.stats])

    def throughput(self) -> float:
        return len(self.stats) / self.horizon if self.horizon > 0 else 0.0

    def k_composition(self, k_max: int) -> np.ndarray:
        """Fraction of requests served at each k = 1..k_max (Fig.8)."""
        ks = self.ks()
        return np.array([(ks == k).mean() for k in range(1, k_max + 1)])

    def summary(self) -> dict:
        t = self.totals()
        if len(t) == 0:
            return {"count": 0}
        return {
            "count": len(t),
            "mean": float(t.mean()),
            "median": float(np.median(t)),
            "p90": float(np.percentile(t, 90)),
            "p99": float(np.percentile(t, 99)),
            "std": float(t.std()),
            "mean_k": float(self.ks().mean()),
            "mean_n": float(self.ns().mean()),
            "throughput": float(self.throughput()),
        }


class _Task:
    __slots__ = ("req", "delay", "cancelled", "started", "done", "t_start",
                 "t_end")

    def __init__(self, req, delay: float):
        self.req = req
        self.delay = delay
        self.cancelled = False
        self.started = False
        self.done = False
        self.t_start = np.nan
        self.t_end = np.nan


class _Request:
    __slots__ = ("stats", "tasks")

    def __init__(self, stats: RequestStats):
        self.stats = stats
        self.tasks: list[_Task] = []


def simulate(
    policy: Policy,
    arrivals: np.ndarray,
    sampler,
    *,
    L: int = 16,
    cls_ids: np.ndarray | None = None,
    samplers: list | None = None,
    seed: int = 0,
    warmup_frac: float = 0.05,
    event_log: list | None = None,
) -> SimResult:
    """Run the event simulation over the given arrival times.

    ``sampler``: object with .sample(rng, k, n) → (n,) task delays (used for
    cls 0); ``samplers`` optionally overrides per class.

    ``event_log``: optional list the oracle appends one per-task record to
    at every request departure — ``(arrival_index, lane, kind, start, end,
    depart)`` with kind 0 = won, 1 = cancelled in queue, 2 = cancelled in
    service (start/end are NaN where the task never started) — the
    row-for-row host twin of the device engine's flight records
    (:class:`repro.obs.flight.FlightLog`).

    Thin front-end over :func:`simulate_shared_pool` with the FIFO
    discipline and one shared policy instance (which observes the true
    ``cls_id``): a single FIFO queue admitted in arrival order IS the
    shared-pool engine with per-class queues popped earliest-arrival-first,
    event for event and draw for draw.
    """
    if cls_ids is None:
        cls_ids = np.zeros(len(arrivals), dtype=np.int64)
    return simulate_shared_pool(
        policy, arrivals, cls_ids, samplers or [sampler],
        L=L, discipline="fifo", seed=seed, warmup_frac=warmup_frac,
        event_log=event_log,
    )


def simulate_shared_pool(
    policies: list[Policy] | Policy,
    arrivals: np.ndarray,
    cls_ids: np.ndarray,
    samplers: list,
    *,
    L: int = 16,
    discipline: str = "fifo",
    prio: tuple | None = None,
    weights: tuple | None = None,
    drr_quantum: float = 8.0,
    seed: int = 0,
    warmup_frac: float = 0.05,
    event_log: list | None = None,
) -> SimResult:
    """Multi-class shared-pool oracle: C classes contending for ONE L-thread
    pool under a pluggable admission discipline (§IV's shared-resource view).

    Unlike :func:`simulate` (single FIFO request queue), requests queue per
    class and the discipline decides whose head-of-line request is admitted
    when threads free up:

    * ``"fifo"``     — earliest arrival across all class queues.
    * ``"priority"`` — head of the non-empty class with the lowest ``prio``
      rank (strict; ties broken by class index).
    * ``"wfq"``      — deficit round-robin over class queues: each visit adds
      ``drr_quantum``·(w_c/min w) to the class's deficit counter; a request
      costs its task count n. Classic DRR — empty classes forfeit deficit.

    ``policies`` holds ONE policy instance per class (independent adaptation
    state); each sees a discipline-shaped queue-length observation: total
    queued (fifo), queued at its own or higher priority (priority), or its
    own queue scaled by the inverse of its weight share (wfq) — mirroring
    the waiting-work terms of :func:`repro.sched.scan.multiclass_scan_core`,
    which this function cross-validates. Passing a single :class:`Policy`
    instead shares it across classes (it then observes the true ``cls_id``
    per arrival) — the :func:`simulate` front-end.
    """
    rng = np.random.default_rng(seed)
    arrivals = np.asarray(arrivals, dtype=np.float64)
    cls_ids = np.asarray(cls_ids, dtype=np.int64)
    shared_policy = isinstance(policies, Policy)
    if shared_policy:
        C = int(max(int(cls_ids.max(initial=0)) + 1, len(samplers), 1))
    else:
        C = len(policies)
    if discipline not in ("fifo", "priority", "wfq"):
        raise ValueError(f"unknown discipline {discipline!r}")
    prio = tuple(prio) if prio is not None else tuple(range(C))
    weights = tuple(weights) if weights is not None else (1.0,) * C
    if len(prio) != C or sorted(prio) != list(range(C)):
        raise ValueError("prio must be a permutation of range(C)")
    if len(weights) != C or any(wt <= 0 for wt in weights):
        raise ValueError("weights must be C positive values")
    for pol in ([policies] if shared_policy else policies):
        pol.reset()

    seq = itertools.count()
    events: list = []
    for t, c in zip(arrivals, cls_ids):
        heapq.heappush(events, (float(t), next(seq), 0, int(c)))

    queues: list[deque[_Request]] = [deque() for _ in range(C)]
    task_queue: deque[_Task] = deque()
    idle = L
    now = 0.0
    done_stats: list[RequestStats] = []
    deficit = [0.0] * C
    drr_ptr = 0
    # Quantum scaled so the LIGHTEST class earns drr_quantum per visit:
    # identical service proportions, but admission needs O(n/quantum) visits
    # instead of O(w_max/w_min) — extreme weight skews can't spin pop_next.
    w_min = min(weights)

    def start_tasks():
        nonlocal idle
        while idle > 0 and task_queue:
            task = task_queue.popleft()
            if task.cancelled:
                continue
            idle -= 1
            task.started = True
            task.t_start = now
            req = task.req
            if np.isnan(req.stats.t_first_start):
                req.stats.t_first_start = now
            heapq.heappush(events, (now + task.delay, next(seq), 1, task))

    def pop_next() -> _Request | None:
        nonlocal drr_ptr
        nonempty = [c for c in range(C) if queues[c]]
        if not nonempty:
            return None
        if discipline == "fifo":
            c = min(nonempty, key=lambda c: queues[c][0].stats.arrival)
        elif discipline == "priority":
            c = min(nonempty, key=lambda c: prio[c])
        else:  # deficit round-robin
            while True:
                c = drr_ptr % C
                drr_ptr += 1
                if not queues[c]:
                    deficit[c] = 0.0  # classic DRR: empty class forfeits
                    continue
                deficit[c] += drr_quantum * weights[c] / w_min
                if deficit[c] >= queues[c][0].stats.n:
                    deficit[c] -= queues[c][0].stats.n
                    break
        return queues[c].popleft()

    def admit():
        while idle > 0 and not task_queue:
            req = pop_next()
            if req is None:
                return
            st = req.stats
            s = samplers[st.cls_id] if st.cls_id < len(samplers) else samplers[0]
            # Shared-pool hook: samplers exporting ``sample_indexed`` (e.g.
            # repro.core.traces.PoolSampler) are addressed by the request's
            # arrival index instead of RNG call order, so the oracle reads
            # the same pre-sampled pool rows as the device task engine.
            if hasattr(s, "sample_indexed"):
                delays = np.asarray(
                    s.sample_indexed(st.arrival_index, st.k, st.n), dtype=np.float64
                )
            else:
                delays = np.asarray(s.sample(rng, st.k, st.n), dtype=np.float64)
            req.tasks = [_Task(req, float(d)) for d in delays]
            task_queue.extend(req.tasks)
            start_tasks()

    def observed_q(c: int) -> float:
        if discipline == "fifo":
            return float(sum(len(q) for q in queues))
        if discipline == "priority":
            return float(sum(len(queues[c2]) for c2 in range(C) if prio[c2] <= prio[c]))
        act = [c2 for c2 in range(C) if queues[c2] or c2 == c]
        return len(queues[c]) * sum(weights[c2] for c2 in act) / weights[c]

    while events:
        now, seq_i, kind, payload = heapq.heappop(events)
        if kind == 0:  # arrival
            cls_id = payload
            # A shared policy keeps one state and sees the true class; a
            # per-class policy owns its state and always observes class 0.
            pol = policies if shared_policy else policies[cls_id]
            n, k = pol.select(
                q=observed_q(cls_id), idle=idle,
                cls_id=cls_id if shared_policy else 0, now=now,
            )
            # Arrivals are heap-pushed first with seq 0..T-1 in arrival
            # order, so seq_i IS the global arrival index.
            st = RequestStats(
                arrival=now, cls_id=cls_id, n=int(n), k=int(k), arrival_index=seq_i
            )
            queues[cls_id].append(_Request(st))
            admit()
        else:  # task completion
            task: _Task = payload
            if task.cancelled or task.done:
                continue
            task.done = True
            task.t_end = now
            idle += 1
            req = task.req
            req.stats.completed_tasks += 1
            if req.stats.completed_tasks == req.stats.k:
                req.stats.t_done = now
                done_stats.append(req.stats)
                for t2 in req.tasks:
                    if not t2.done and not t2.cancelled:
                        t2.cancelled = True
                        if t2.started:
                            t2.t_end = now
                            idle += 1
                if event_log is not None:
                    # One row per task lane, finalized at departure: won
                    # tasks keep their completion end, in-service
                    # cancellations end at the departure instant, queued
                    # cancellations never start (NaN start/end).
                    for lane, t2 in enumerate(req.tasks):
                        kind = 0 if t2.done else (2 if t2.started else 1)
                        event_log.append((
                            req.stats.arrival_index, lane, kind,
                            t2.t_start, t2.t_end, now,
                        ))
            start_tasks()
            admit()

    horizon = float(arrivals[-1] - arrivals[0]) if len(arrivals) > 1 else 0.0
    done_stats.sort(key=lambda s: s.arrival)
    n_warm = int(len(done_stats) * warmup_frac)
    return SimResult(stats=done_stats[n_warm:], horizon=horizon)


def poisson_arrivals(rng: np.random.Generator, lam: float, count: int) -> np.ndarray:
    return np.cumsum(rng.exponential(1.0 / lam, size=count))


def piecewise_poisson_arrivals(
    rng: np.random.Generator, rates: list[tuple[float, float]]
) -> np.ndarray:
    """Arrivals for consecutive (duration_s, rate) segments (Fig.10 setup).

    .. deprecated:: use :class:`repro.fleet.workloads.PiecewiseWorkload`
       directly — this is now a thin wrapper kept for source compatibility
       (draw-for-draw identical RNG consumption). The fleet workload family
       also yields device-ready interarrival arrays from the same spec.
    """
    from repro.fleet.workloads import PiecewiseWorkload

    return PiecewiseWorkload(tuple(rates)).arrival_times(rng)
