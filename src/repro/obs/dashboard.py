"""Dashboards over the time-resolved telemetry plane.

Two renderers over :meth:`TimelineBuf.snapshot` dicts + an
:func:`repro.obs.slo.slo_report` + :func:`repro.obs.profile.
profile_snapshot`:

* :func:`ascii_dashboard` — a terminal live view: one unicode sparkline
  per series (λ, backlog, pick, served, windowed p99), the SLO burn line,
  convergence stats and the profiler table.
* :func:`html_report` — a single self-contained HTML file (inline SVG, no
  external assets): small-multiple line charts (one series per chart, so
  identity never leans on color), the windowed percentile chart with the
  SLO target as a labeled reference hairline, breach/convergence stat
  tiles, and the roofline table.  Hover shows a crosshair + tooltip; every
  chart ships a ``<details>`` table view; dark mode is its own selected
  set of steps via CSS custom properties, not an automatic flip.

Colors are the reference data-viz palette (categorical slot 1 blue
``#2a78d6``/``#3987e5``, status colors reserved for the breach badge),
validated for both surfaces as a set; values/labels wear text tokens,
never the series color.
"""
from __future__ import annotations

import html
import json
import os

import numpy as np

from repro.obs.timeline import rolling_percentile

_SPARK = "▁▂▃▄▅▆▇█"


def _series_1d(v) -> np.ndarray:
    """Timeline series to one display row: per-case (G, S) arrays average
    across the case axis for the overview (per-case views stay in the
    snapshot)."""
    a = np.asarray(v, np.float64)
    if a.ndim == 2:
        a = a.mean(axis=0)
    return a


def _hist_rows(v) -> np.ndarray:
    """(S, B) delta rows; per-case (G, S, B) stacks sum across cases (the
    overview tail is the whole population's)."""
    a = np.asarray(v, np.float64)
    if a.ndim == 3:
        a = a.sum(axis=0)
    return a


def sparkline(values, width: int = 48) -> str:
    """Unicode sparkline; NaN renders as a gap."""
    a = _series_1d(values)
    if len(a) > width:  # bucket-mean downsample to the display width
        edge = np.linspace(0, len(a), width + 1).astype(int)

        def bucket_mean(lo, hi):
            sl = a[lo:hi]
            sl = sl[np.isfinite(sl)]
            return sl.mean() if len(sl) else np.nan

        a = np.array([bucket_mean(lo, hi)
                      for lo, hi in zip(edge[:-1], edge[1:])])
    finite = a[np.isfinite(a)]
    if not len(finite):
        return " " * len(a)
    lo, hi = float(finite.min()), float(finite.max())
    span = (hi - lo) or 1.0
    out = []
    for v in a:
        if not np.isfinite(v):
            out.append(" ")
        else:
            out.append(_SPARK[int((v - lo) / span * (len(_SPARK) - 1))])
    return "".join(out)


def _fmt(v) -> str:
    if v is None or not np.isfinite(v):
        return "-"
    return f"{v:.4g}"


def ascii_dashboard(timelines: dict, slo: dict | None = None,
                    profile: dict | None = None,
                    exemplars: list | None = None) -> str:
    """Terminal view: sparkline per series + SLO + profiler sections, plus
    the p99 exemplar task-race anatomy when flight exemplars are passed
    (:meth:`repro.obs.flight.FlightLog.exemplars`)."""
    lines = []
    for name, snap in timelines.items():
        lines.append(f"== timeline: {name} "
                     f"(window={snap.get('window', 1)} arrivals/slot) ==")
        rows = []
        for sname, vals in snap.get("series", {}).items():
            a = _series_1d(vals)
            rows.append((sname, sparkline(a),
                         _fmt(a[-1] if len(a) else np.nan),
                         _fmt(np.nanmax(a) if len(a) else np.nan)))
        for hname, hv in snap.get("hists", {}).items():
            p99 = rolling_percentile(_hist_rows(hv), 0.99, 8)
            rows.append((f"{hname}_p99_s", sparkline(p99),
                         _fmt(p99[-1] if len(p99) else np.nan),
                         _fmt(np.nanmax(p99) if len(p99) else np.nan)))
        w = max((len(r[0]) for r in rows), default=0)
        for sname, spark, last, peak in rows:
            lines.append(f"  {sname.ljust(w)}  {spark}  last={last} max={peak}")
    if slo:
        conv = slo.get("convergence", {})
        lines.append("== slo ==")
        lines.append(
            f"  p{slo['spec']['percentile'] * 100:g} target "
            f"{slo['spec']['target_s']}s  burn "
            f"{sparkline(slo['burn_rate'])}  max={_fmt(slo['max_burn_rate'])} "
            f"breach_slots={slo['breach_slots']}")
        lines.append(
            f"  pick settled at slot {conv.get('settle_slot')} on "
            f"{conv.get('final_code')} "
            f"(dwell {_fmt(conv.get('dwell_final'))})")
    if exemplars:
        from repro.obs.flight import exemplar_panel

        lines.append("== p99 exemplars (task-race anatomy) ==")
        lines.extend("  " + ln for ln in exemplar_panel(exemplars).splitlines())
    if profile:
        from repro.obs.profile import format_profile

        lines.append("== launch profile ==")
        lines.extend("  " + ln for ln in format_profile(profile).splitlines())
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# HTML report
# ---------------------------------------------------------------------------

_CSS = """
.viz-root {
  color-scheme: light;
  --surface-1: #fcfcfb; --page: #f9f9f7;
  --text-primary: #0b0b0b; --text-secondary: #52514e; --muted: #898781;
  --grid: #e1e0d9; --baseline: #c3c2b7;
  --series-1: #2a78d6; --critical: #d03b3b; --good: #0ca30c;
  --ring: rgba(11,11,11,0.10);
}
@media (prefers-color-scheme: dark) {
  :root:where(:not([data-theme="light"])) .viz-root {
    color-scheme: dark;
    --surface-1: #1a1a19; --page: #0d0d0d;
    --text-primary: #ffffff; --text-secondary: #c3c2b7; --muted: #898781;
    --grid: #2c2c2a; --baseline: #383835;
    --series-1: #3987e5; --critical: #d03b3b; --good: #0ca30c;
    --ring: rgba(255,255,255,0.10);
  }
}
:root[data-theme="dark"] .viz-root {
  color-scheme: dark;
  --surface-1: #1a1a19; --page: #0d0d0d;
  --text-primary: #ffffff; --text-secondary: #c3c2b7; --muted: #898781;
  --grid: #2c2c2a; --baseline: #383835;
  --series-1: #3987e5; --critical: #d03b3b; --good: #0ca30c;
  --ring: rgba(255,255,255,0.10);
}
.viz-root { background: var(--page); color: var(--text-primary);
  font-family: system-ui, -apple-system, "Segoe UI", sans-serif;
  margin: 0; padding: 24px; }
.viz-root h1 { font-size: 20px; margin: 0 0 4px; }
.viz-root h2 { font-size: 15px; margin: 24px 0 8px; }
.viz-root .meta { color: var(--text-secondary); font-size: 12px; }
.tiles { display: flex; gap: 12px; flex-wrap: wrap; margin: 16px 0; }
.tile { background: var(--surface-1); border: 1px solid var(--ring);
  border-radius: 8px; padding: 10px 14px; min-width: 120px; }
.tile .v { font-size: 22px; }
.tile .l { font-size: 11px; color: var(--text-secondary); }
.tile .badge { font-size: 12px; }
.badge.bad { color: var(--critical); }
.badge.ok { color: var(--good); }
.charts { display: grid; gap: 12px;
  grid-template-columns: repeat(auto-fill, minmax(320px, 1fr)); }
.chart { background: var(--surface-1); border: 1px solid var(--ring);
  border-radius: 8px; padding: 10px 12px; position: relative; }
.chart .t { font-size: 12px; color: var(--text-secondary);
  margin-bottom: 4px; }
.chart svg { display: block; width: 100%; height: auto; }
.chart .tip { position: absolute; display: none; pointer-events: none;
  background: var(--surface-1); border: 1px solid var(--ring);
  border-radius: 4px; padding: 2px 6px; font-size: 11px;
  color: var(--text-primary); white-space: nowrap; z-index: 2; }
.chart details { font-size: 11px; color: var(--text-secondary);
  margin-top: 4px; }
.chart table, .prof table { border-collapse: collapse; font-size: 11px; }
.chart td, .chart th, .prof td, .prof th { padding: 1px 8px 1px 0;
  text-align: right; font-variant-numeric: tabular-nums; }
.prof th { color: var(--text-secondary); font-weight: 600; }
.prof { background: var(--surface-1); border: 1px solid var(--ring);
  border-radius: 8px; padding: 10px 14px; overflow-x: auto; }
.axis { fill: var(--muted); font-size: 9px;
  font-variant-numeric: tabular-nums; }
.refline-label { fill: var(--text-secondary); font-size: 9px; }
"""

_JS = """
document.querySelectorAll('.chart[data-v]').forEach(function (c) {
  var vals = JSON.parse(c.dataset.v), svg = c.querySelector('svg'),
      cross = c.querySelector('.cross'), dot = c.querySelector('.dot'),
      tip = c.querySelector('.tip'),
      x0 = +c.dataset.x0, x1 = +c.dataset.x1,
      y0 = +c.dataset.y0, y1 = +c.dataset.y1,
      lo = +c.dataset.lo, hi = +c.dataset.hi;
  svg.addEventListener('mousemove', function (e) {
    var r = svg.getBoundingClientRect(),
        fx = (e.clientX - r.left) / r.width * 560;
    var i = Math.round((fx - x0) / (x1 - x0) * (vals.length - 1));
    i = Math.max(0, Math.min(vals.length - 1, i));
    var v = vals[i];
    if (v === null) { cross.style.display = dot.style.display =
        tip.style.display = 'none'; return; }
    var px = x0 + (x1 - x0) * (vals.length > 1 ? i / (vals.length - 1) : 0),
        py = y1 - (y1 - y0) * ((v - lo) / ((hi - lo) || 1));
    cross.setAttribute('x1', px); cross.setAttribute('x2', px);
    cross.style.display = 'block';
    dot.setAttribute('cx', px); dot.setAttribute('cy', py);
    dot.style.display = 'block';
    tip.textContent = 'slot ' + i + ' \\u00b7 ' + (+v.toPrecision(4));
    tip.style.display = 'block';
    tip.style.left = (e.clientX - r.left + 12) + 'px';
    tip.style.top = (e.clientY - r.top - 10) + 'px';
  });
  svg.addEventListener('mouseleave', function () {
    cross.style.display = dot.style.display = tip.style.display = 'none';
  });
});
"""

_W, _H = 560, 120
_PAD_L, _PAD_R, _PAD_T, _PAD_B = 44, 8, 8, 16


def _svg_chart(title: str, values, *, target: float | None = None,
               target_label: str = "SLO target") -> str:
    a = _series_1d(values)
    finite = a[np.isfinite(a)]
    lo = float(finite.min()) if len(finite) else 0.0
    hi = float(finite.max()) if len(finite) else 1.0
    if target is not None:
        lo, hi = min(lo, target), max(hi, target)
    if hi == lo:
        hi = lo + 1.0
    x0, x1 = _PAD_L, _W - _PAD_R
    y0, y1 = _PAD_T, _H - _PAD_B

    def px(i):
        return x0 + (x1 - x0) * (i / (len(a) - 1) if len(a) > 1 else 0.0)

    def py(v):
        return y1 - (y1 - y0) * (v - lo) / (hi - lo)

    # NaN-aware polyline segments (gaps where a window had no data).
    segs, cur = [], []
    for i, v in enumerate(a):
        if np.isfinite(v):
            cur.append(f"{px(i):.1f},{py(v):.1f}")
        elif cur:
            segs.append(cur)
            cur = []
    if cur:
        segs.append(cur)
    grid = "".join(
        f'<line x1="{x0}" y1="{py(lo + f * (hi - lo)):.1f}" x2="{x1}" '
        f'y2="{py(lo + f * (hi - lo)):.1f}" stroke="var(--grid)" '
        f'stroke-width="1"/>' for f in (0.5,)
    )
    ref = ""
    if target is not None:
        ty = py(target)
        ref = (
            f'<line x1="{x0}" y1="{ty:.1f}" x2="{x1}" y2="{ty:.1f}" '
            f'stroke="var(--baseline)" stroke-width="1" '
            f'stroke-dasharray="4 3"/>'
            f'<text class="refline-label" x="{x1}" y="{ty - 3:.1f}" '
            f'text-anchor="end">{html.escape(target_label)} '
            f'{target:g}s</text>'
        )
    lines = "".join(
        f'<polyline fill="none" stroke="var(--series-1)" stroke-width="2" '
        f'stroke-linejoin="round" stroke-linecap="round" '
        f'points="{" ".join(seg)}"/>' for seg in segs if len(seg) > 1
    )
    dots = "".join(
        f'<circle cx="{seg[0].split(",")[0]}" cy="{seg[0].split(",")[1]}" '
        f'r="2" fill="var(--series-1)"/>'
        for seg in segs if len(seg) == 1
    )
    last = f"{finite[-1]:.4g}" if len(finite) else "-"
    tablerows = "".join(
        f"<tr><td>{i}</td><td>{_fmt(v)}</td></tr>" for i, v in enumerate(a)
    )
    data = json.dumps([None if not np.isfinite(v) else float(v) for v in a])
    return (
        f'<div class="chart" data-v=\'{data}\' data-x0="{x0}" data-x1="{x1}" '
        f'data-y0="{y0}" data-y1="{y1}" data-lo="{lo}" data-hi="{hi}">'
        f'<div class="t">{html.escape(title)} · last {last}</div>'
        f'<svg viewBox="0 0 {_W} {_H}" role="img" '
        f'aria-label="{html.escape(title)}">'
        f'<line x1="{x0}" y1="{y1}" x2="{x1}" y2="{y1}" '
        f'stroke="var(--baseline)" stroke-width="1"/>'
        f"{grid}{ref}{lines}{dots}"
        f'<text class="axis" x="{x0 - 4}" y="{y1}" '
        f'text-anchor="end">{lo:.3g}</text>'
        f'<text class="axis" x="{x0 - 4}" y="{y0 + 8}" '
        f'text-anchor="end">{hi:.3g}</text>'
        f'<line class="cross" x1="0" y1="{y0}" x2="0" y2="{y1}" '
        f'stroke="var(--muted)" stroke-width="1" style="display:none"/>'
        f'<circle class="dot" r="4" fill="var(--series-1)" '
        f'stroke="var(--surface-1)" stroke-width="2" style="display:none"/>'
        f"</svg>"
        f'<div class="tip"></div>'
        f"<details><summary>data</summary><table>"
        f"<tr><th>slot</th><th>value</th></tr>{tablerows}</table></details>"
        f"</div>"
    )


def _tiles(slo: dict) -> str:
    conv = slo.get("convergence", {})
    breach = slo.get("breach_slots", 0)
    badge = (
        '<div class="badge bad">&#9650; breach</div>' if breach
        else '<div class="badge ok">&#10003; within budget</div>'
    )
    code = conv.get("final_code")
    tiles = [
        (f"{_fmt(slo.get('percentile_last_s'))}s",
         f"p{slo['spec']['percentile'] * 100:g} (windowed)", ""),
        (_fmt(slo.get("max_burn_rate")), "max burn rate", badge),
        (str(conv.get("settle_slot", "-")), "pick settle slot", ""),
        (f"({code[0]},{code[1]})" if code else "-",
         f"final code · dwell {_fmt(conv.get('dwell_final'))}", ""),
    ]
    return '<div class="tiles">' + "".join(
        f'<div class="tile"><div class="v">{v}</div>'
        f'<div class="l">{html.escape(l)}</div>{b}</div>'
        for v, l, b in tiles
    ) + "</div>"


_EX_H = 18  # px per task row in the exemplar anatomy SVG


def _exemplar_html(exemplars: list) -> str:
    """Per-request task-race anatomy charts: one horizontal bar per task
    lane on the request's [arrival, depart] axis — winners in the series
    color, cancellations-in-service truncated in the critical color, queue
    wait as a muted leader line, lanes cancelled in queue as hollow
    markers.  Labels wear text tokens, never the series color."""
    blocks = []
    for ex in exemplars:
        t0, t1 = ex["arrival"], ex["depart"]
        span = max(t1 - t0, 1e-12)
        x0, x1 = _PAD_L, _W - _PAD_R

        def px(t):
            return x0 + (x1 - x0) * (t - t0) / span

        h = _PAD_T + _EX_H * len(ex["tasks"]) + _PAD_B
        rows = []
        for r, task in enumerate(ex["tasks"]):
            y = _PAD_T + _EX_H * r + _EX_H / 2
            thr = (f"t{task['lane']:02d}·thr{task['thread']:02d}"
                   if task["thread"] >= 0 else f"t{task['lane']:02d}·queued")
            rows.append(
                f'<text class="axis" x="{x0 - 4}" y="{y + 3:.1f}" '
                f'text-anchor="end">{html.escape(thr)}</text>')
            if task["start"] is None:
                rows.append(
                    f'<circle cx="{x1:.1f}" cy="{y:.1f}" r="3" fill="none" '
                    f'stroke="var(--muted)" stroke-width="1.5"/>')
                continue
            cancelled = task["kind"] == "cancel_service"
            color = "var(--critical)" if cancelled else "var(--series-1)"
            rows.append(
                f'<line x1="{px(t0):.1f}" y1="{y:.1f}" '
                f'x2="{px(task["start"]):.1f}" y2="{y:.1f}" '
                f'stroke="var(--muted)" stroke-width="1" '
                f'stroke-dasharray="2 3"/>')
            rows.append(
                f'<rect x="{px(task["start"]):.1f}" y="{y - 5:.1f}" '
                f'width="{max(px(task["end"]) - px(task["start"]), 1):.1f}" '
                f'height="10" rx="2" fill="{color}"/>')
        # Departure hairline: where the k-th completion cut the race.
        rows.append(
            f'<line x1="{x1:.1f}" y1="{_PAD_T}" x2="{x1:.1f}" '
            f'y2="{h - _PAD_B}" stroke="var(--baseline)" stroke-width="1" '
            f'stroke-dasharray="4 3"/>')
        title = (f"req {ex['req']} · total {ex['total_s']:.4g}s "
                 f"(queue {ex['queue_s']:.4g}s) · code "
                 f"({ex['n']},{ex['k']})")
        blocks.append(
            f'<div class="chart"><div class="t">{html.escape(title)}</div>'
            f'<svg viewBox="0 0 {_W} {h}" role="img" '
            f'aria-label="{html.escape(title)}">{"".join(rows)}'
            f'<text class="axis" x="{x0}" y="{h - 4}">0s</text>'
            f'<text class="axis" x="{x1}" y="{h - 4}" '
            f'text-anchor="end">{span:.4g}s</text></svg></div>')
    return '<div class="charts">' + "".join(blocks) + "</div>"


def _profile_table(profile: dict) -> str:
    head = ("fn", "flops", "bytes", "wall ms", "gflop/s", "gb/s", "bound",
            "peak %")
    rows = []
    for label, r in sorted(profile.items()):
        rows.append((
            html.escape(label), f"{r['flops']:.3g}", f"{r['bytes']:.3g}",
            f"{r['wall_s'] * 1e3:.3f}", f"{r['gflops']:.2f}",
            f"{r['gbps']:.2f}", r["bound"], f"{r['frac_peak'] * 100:.2f}",
        ))
    body = "".join(
        "<tr>" + "".join(f"<td>{c}</td>" for c in row) + "</tr>"
        for row in rows
    )
    return (
        '<div class="prof"><table><tr>'
        + "".join(f"<th>{h}</th>" for h in head)
        + f"</tr>{body}</table></div>"
    )


def html_report(path: str, timelines: dict, *, slo: dict | None = None,
                profile: dict | None = None, meta: dict | None = None,
                exemplars: list | None = None,
                title: str = "repro.obs — time-resolved telemetry") -> str:
    """Write the self-contained HTML dashboard; returns the path.

    ``exemplars`` (optional flight-recorder anatomies,
    :meth:`repro.obs.flight.FlightLog.exemplars`) adds the per-request
    task-race breakdown section."""
    parts = [
        "<!doctype html><html><head><meta charset='utf-8'>",
        f"<title>{html.escape(title)}</title>",
        f"<style>{_CSS}</style></head>",
        "<body class='viz-root'>",
        f"<h1>{html.escape(title)}</h1>",
    ]
    if meta:
        parts.append(
            f'<div class="meta">{html.escape(json.dumps(meta))}</div>')
    if slo:
        parts.append(_tiles(slo))
    for name, snap in timelines.items():
        parts.append(
            f"<h2>{html.escape(name)} "
            f'<span class="meta">window={snap.get("window", 1)} '
            f"arrivals/slot</span></h2>")
        parts.append('<div class="charts">')
        for sname, vals in snap.get("series", {}).items():
            parts.append(_svg_chart(sname, vals))
        for hname, hv in snap.get("hists", {}).items():
            spec = (slo or {}).get("spec", {})
            p = spec.get("percentile", 0.99)
            win = spec.get("window", 8)
            p99 = rolling_percentile(_hist_rows(hv), p, win)
            parts.append(_svg_chart(
                f"{hname} p{p * 100:g} (windowed, s)", p99,
                target=spec.get("target_s")))
        parts.append("</div>")
    if exemplars:
        parts.append("<h2>p99 exemplars "
                     '<span class="meta">task-race anatomy, simulated '
                     "time</span></h2>")
        parts.append(_exemplar_html(exemplars))
    if profile:
        parts.append("<h2>launch profile</h2>")
        parts.append(_profile_table(profile))
    parts.append(f"<script>{_JS}</script></body></html>")
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as fh:
        fh.write("".join(parts))
    return path
