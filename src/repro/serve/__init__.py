from repro.serve.engine import ServeResult, ServingEngine
