from repro.serve.engine import (
    ClosedLoopResult,
    ClosedLoopServer,
    FusedServingStep,
    ServePolicy,
    ServeResult,
    ServeTables,
    ServingEngine,
    serve_policy_step,
    tokens_from_strips,
)
