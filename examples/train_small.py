"""End-to-end driver: train a ~100M-param dense LM for a few hundred steps
with erasure-coded async checkpointing, then kill-and-restore mid-run.

Run:  PYTHONPATH=src python examples/train_small.py [--steps 200] [--fast]
"""

import argparse
import dataclasses

from repro.configs.qwen1_5_0_5b import CONFIG as QWEN
from repro.core import PAPER_READ_3MB, RequestClass, TOFECPolicy
from repro.models.config import ShapeSpec
from repro.models.registry import Arch, _FAMILY_MODULES
from repro.storage import FaultyStore, MemoryStore
from repro.train import AdamWConfig, Trainer, TrainerConfig

# ~100M params: 12L, d=768, 12H, d_ff=2048, 32k vocab (llama-ish family).
CONFIG_100M = dataclasses.replace(
    QWEN, name="dense-100m", n_layers=12, d_model=768, n_heads=12,
    n_kv_heads=12, d_ff=2048, vocab=32000, qkv_bias=False,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--fast", action="store_true", help="tiny shapes (CI)")
    args = ap.parse_args()

    arch = Arch(cfg=CONFIG_100M if not args.fast else dataclasses.replace(
        CONFIG_100M, n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256, vocab=1024),
        module=_FAMILY_MODULES["dense"])
    shape = ShapeSpec("train_small", "train", seq=256 if not args.fast else 64,
                      batch=8 if not args.fast else 2)
    steps = args.steps if not args.fast else 8

    print(f"params ≈ {arch.cfg.param_count_dense() / 1e6:.0f}M; "
          f"{shape.batch}×{shape.seq} tokens/step; {steps} steps")

    store = FaultyStore(MemoryStore(), p_fail=0.0)
    ckpt_cls = RequestClass("ckpt", 3.0, PAPER_READ_3MB, k_max=4, r_max=2.0, n_max=8)
    policy = TOFECPolicy.for_classes([ckpt_cls], L=16)
    tcfg = TrainerConfig(
        total_steps=steps, ckpt_every=max(steps // 4, 1), log_every=max(steps // 10, 1),
        opt=AdamWConfig(lr=3e-4),
    )

    trainer = Trainer(arch, shape, store, cfg=tcfg, ckpt_prefix="run100m", ckpt_policy=policy)
    log = trainer.run(steps=steps // 2)
    print(f"[phase 1] step {log[-1]['step']}: loss {log[-1]['loss']:.3f}")

    # Simulated failure: lose one checkpoint strip per leaf, then restart
    # from storage alone — the (n, k) code reconstructs every leaf.
    lost = 0
    for key in list(store.keys()):
        if key.endswith("/strip0") and lost < 50:
            store.lose_object(key)
            lost += 1
    print(f"[failure] lost {lost} checkpoint strip objects; restarting…")

    trainer2 = Trainer(arch, shape, store, cfg=tcfg, ckpt_prefix="run100m", ckpt_policy=policy)
    print(f"[restore] resumed at step {trainer2.start_step}")
    log2 = trainer2.run()
    print(f"[phase 2] step {log2[-1]['step']}: loss {log2[-1]['loss']:.3f}")
    first = log[0]["loss"]
    print(f"loss {first:.3f} → {log2[-1]['loss']:.3f} "
          f"({'improved' if log2[-1]['loss'] < first else 'no improvement'})")


if __name__ == "__main__":
    main()
