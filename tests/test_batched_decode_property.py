"""Property: batched decode with random per-item erasure patterns matches
per-object decode bit-exactly on all three codec backends (ISSUE 2).

Hypothesis drives (n, k), batch, strip width and per-item erasure patterns;
the plain fixed-case test keeps the same invariant exercised in bare
environments where hypothesis is absent (see tests/hypothesis_compat.py).
"""

import numpy as np
from hypothesis_compat import given, settings, st

from repro.coding import rs
from repro.coding.codec import Codec

# Shared instances so the bucketed jit caches amortize across examples.
CODECS = {name: Codec(name) for name in ("numpy", "jnp", "pallas")}


def _roundtrip_case(rng: np.random.Generator, n: int, k: int, batch: int, B: int):
    data = rng.integers(0, 256, size=(batch, k, B), dtype=np.uint8)
    coded = np.stack([rs.encode(data[i], n, k) for i in range(batch)])
    # Unsorted patterns: row order must follow ``present``, not strip order.
    present = np.stack([rng.permutation(n)[:k] for _ in range(batch)])
    strips = np.stack([coded[i][present[i]] for i in range(batch)])
    for name, codec in CODECS.items():
        batched = np.asarray(codec.decode(strips, present, n, k))
        per_object = np.stack(
            [
                np.asarray(codec.decode(strips[i], tuple(present[i]), n, k))
                for i in range(batch)
            ]
        )
        np.testing.assert_array_equal(batched, per_object, err_msg=name)
        np.testing.assert_array_equal(batched, data, err_msg=name)


@given(
    k=st.integers(1, 6),
    extra=st.integers(0, 6),
    batch=st.integers(1, 4),
    B=st.integers(1, 96),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=12, deadline=None)
def test_batched_decode_matches_per_object_decode(k, extra, batch, B, seed):
    n = k + extra
    _roundtrip_case(np.random.default_rng(seed), n, k, batch, B)


def test_batched_decode_fixed_case_all_backends():
    """Non-property twin: runs even without hypothesis installed."""
    rng = np.random.default_rng(1234)
    for n, k, batch, B in [(12, 6, 4, 64), (5, 3, 3, 17), (4, 1, 2, 40), (6, 6, 2, 9)]:
        _roundtrip_case(rng, n, k, batch, B)
