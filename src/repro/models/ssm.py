"""Recurrent sequence blocks: mLSTM / sLSTM (xLSTM) and Mamba2 (SSD).

All parallel-form blocks share one primitive — a chunked linear recurrence
(scalar per-(head, t) decay, rank-1 state updates):

    S_t = a_t · S_{t-1} + i_t · k_t v_tᵀ          (state: (dk, dv))
    n_t = a_t · n_{t-1} + i_t · k_t               (optional normalizer)
    y_t = qₜᵀ S_t   [ / max(|qₜᵀ n_t|, 1) ]

computed chunk-parallel: intra-chunk via a (c × c) decay-masked attention
matrix, inter-chunk via a lax.scan carrying (S, n). Decays are kept in log
space and clamped ≤ 0, so every exp() is ≤ 1 — numerically safe without the
xLSTM max-stabilizer (documented simplification vs. the paper's exact
formulation; equivalent to Gated Linear Attention form).

Decode-time forms are the exact O(1) recurrences.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dt, init_dense, use_weight
from repro.models.sharding import constrain


# ---------------------------------------------------------------------------
# Chunked linear recurrence primitive
# ---------------------------------------------------------------------------


def chunk_linear_recurrence(
    q: jax.Array,  # (B, S, H, dk)
    k: jax.Array,  # (B, S, H, dk)
    v: jax.Array,  # (B, S, H, dv)
    log_a: jax.Array,  # (B, S, H) decay, ≤ 0
    gate_i: jax.Array,  # (B, S, H) input gate, ≥ 0
    *,
    chunk: int,
    init_state: tuple[jax.Array, jax.Array] | None = None,
    normalize: bool = False,
    unroll: bool = False,
) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    """Returns (y: (B,S,H,dv), final (S_state: (B,H,dk,dv), n: (B,H,dk)))."""
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    c = min(chunk, S)
    S_real = S
    if S % c != 0:
        # Pad to a chunk multiple: decay 1 (log_a = 0) and gate 0 make the
        # padded steps exact no-ops on the state; outputs are trimmed.
        pad = c - S % c
        padt = lambda a, val=0.0: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2),
                                          constant_values=val)
        q, k, v = padt(q), padt(k), padt(v)
        log_a, gate_i = padt(log_a), padt(gate_i)
        S = S + pad
    nc = S // c

    def resh(x):
        return x.reshape(B, nc, c, *x.shape[2:]).swapaxes(0, 1)  # (nc, B, c, ...)

    qs, ks, vs = resh(q), resh(k), resh(v)
    las, gis = resh(log_a), resh(gate_i)

    if init_state is None:
        S0 = jnp.zeros((B, H, dk, dv), jnp.float32)
        n0 = jnp.zeros((B, H, dk), jnp.float32)
    else:
        S0, n0 = init_state

    def per_chunk(carry, inp):
        S_prev, n_prev = carry
        qc_, kc_, vc_, la, gi = inp  # (B, c, H, ·)
        cum = jnp.cumsum(la, axis=1)  # (B, c, H) inclusive log-decay products
        # Intra-chunk decay mask D[t, s] = exp(cum_t − cum_s − la_s·0) i_s, s ≤ t.
        # Using inclusive cumsum: decay from s to t (applying a_{s+1..t}) is
        # exp(cum_t − cum_s).
        d_ts = cum[:, :, None, :] - cum[:, None, :, :]  # (B, t, s, H)
        tri = jnp.tril(jnp.ones((c, c), bool))
        D = jnp.where(tri[None, :, :, None], jnp.exp(d_ts), 0.0) * gi[:, None, :, :]
        scores = jnp.einsum("bthd,bshd->btsh", qc_.astype(jnp.float32), kc_.astype(jnp.float32))
        w = scores * D  # (B, t, s, H)
        y_intra = jnp.einsum("btsh,bshv->bthv", w, vc_.astype(jnp.float32))
        carry_decay = jnp.exp(cum)  # (B, c, H): decay from chunk start to t
        y_inter = jnp.einsum(
            "bthd,bhdv->bthv", (qc_.astype(jnp.float32) * carry_decay[..., None]), S_prev
        )
        y = y_intra + y_inter
        if normalize:
            n_intra = jnp.einsum("btsh,bshd->bthd", D, kc_.astype(jnp.float32))
            n_t = n_intra + carry_decay[..., None] * n_prev[:, None]
            denom = jnp.abs(jnp.einsum("bthd,bthd->bth", qc_.astype(jnp.float32), n_t))
            y = y / jnp.maximum(denom, 1.0)[..., None]
        else:
            n_t = jnp.broadcast_to(n_prev[:, None], (B, c, H, dk))
        # State update to chunk end.
        total = cum[:, -1:, :]  # (B, 1, H)
        rem = jnp.exp(total - cum) * gi  # (B, s, H): decay from s to chunk end
        S_new = jnp.exp(total[:, 0])[..., None, None] * S_prev + jnp.einsum(
            "bshd,bshv->bhdv", (kc_.astype(jnp.float32) * rem[..., None]), vc_.astype(jnp.float32)
        )
        n_new = jnp.exp(total[:, 0])[..., None] * n_prev + jnp.einsum(
            "bshd,bsh->bhd", kc_.astype(jnp.float32), rem
        )
        return (S_new, n_new), y

    (Sf, nf), ys = jax.lax.scan(
        per_chunk, (S0, n0), (qs, ks, vs, las, gis), unroll=unroll
    )
    y = ys.swapaxes(0, 1).reshape(B, S, H, dv)[:, :S_real]
    return y, (Sf, nf)


def linear_recurrence_step(
    q, k, v, log_a, gate_i, state, n_state, *, normalize: bool = False
):
    """Exact single-step decode. q/k: (B,H,dk), v: (B,H,dv), gates: (B,H)."""
    a = jnp.exp(log_a.astype(jnp.float32))[..., None]
    state = a[..., None] * state + (gate_i.astype(jnp.float32)[..., None, None]) * (
        k.astype(jnp.float32)[..., :, None] * v.astype(jnp.float32)[..., None, :]
    )
    n_state = a * n_state + gate_i.astype(jnp.float32)[..., None] * k.astype(jnp.float32)
    y = jnp.einsum("bhd,bhdv->bhv", q.astype(jnp.float32), state)
    if normalize:
        denom = jnp.abs(jnp.einsum("bhd,bhd->bh", q.astype(jnp.float32), n_state))
        y = y / jnp.maximum(denom, 1.0)[..., None]
    return y, state, n_state


# ---------------------------------------------------------------------------
# mLSTM block (xLSTM)
# ---------------------------------------------------------------------------


def init_mlstm(rng, cfg: ModelConfig):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    ks = jax.random.split(rng, 8)
    return {
        "w_up": init_dense(ks[0], d, di, dt(cfg)),
        "w_qkv": init_dense(ks[1], di, 3 * di, dt(cfg)),
        "w_if": init_dense(ks[2], di, 2 * cfg.n_heads, dt(cfg)),
        "b_if": jnp.concatenate(
            [jnp.zeros((cfg.n_heads,)), 3.0 * jnp.ones((cfg.n_heads,))]
        ).astype(dt(cfg)),
        "w_og": init_dense(ks[3], d, di, dt(cfg)),
        "w_down": init_dense(ks[4], di, d, dt(cfg)),
    }


def mlstm_logical_axes(cfg: ModelConfig):
    return {
        "w_up": ("embed", "ff"),
        "w_qkv": ("ff", None),
        "w_if": ("ff", None),
        "b_if": (None,),
        "w_og": ("embed", "ff"),
        "w_down": ("ff", "embed"),
    }


def _mlstm_gates(params, cfg, h):
    H = cfg.n_heads
    gf = h @ params["w_if"] + params["b_if"]
    i_t = jax.nn.sigmoid(gf[..., :H].astype(jnp.float32))
    log_f = jax.nn.log_sigmoid(gf[..., H:].astype(jnp.float32))
    return i_t, log_f


def mlstm_block(params, cfg: ModelConfig, x, state=None):
    """x: (B, S, d). Returns (y, new_state)."""
    B, S, d = x.shape
    H = cfg.n_heads
    di = cfg.ssm_expand * d
    hd = di // H
    h = x @ use_weight(cfg, params["w_up"], None, "ff")
    qkv = h @ use_weight(cfg, params["w_qkv"], "ff", None)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(B, S, H, hd) / math.sqrt(hd)
    k = k.reshape(B, S, H, hd)
    v = v.reshape(B, S, H, hd)
    i_t, log_f = _mlstm_gates(params, cfg, h)
    y, new_state = chunk_linear_recurrence(
        q, k, v, log_f, i_t, chunk=cfg.ssm_chunk,
        init_state=state, normalize=True, unroll=cfg.scan_unroll,
    )
    og = jax.nn.sigmoid((x @ use_weight(cfg, params["w_og"], None, "ff")).astype(jnp.float32))
    out = (y.reshape(B, S, di) * og).astype(x.dtype)
    return out @ use_weight(cfg, params["w_down"], "ff", None), new_state


def mlstm_decode_step(params, cfg: ModelConfig, x, state):
    """x: (B, 1, d); state: (S_state, n_state)."""
    B, _, d = x.shape
    H = cfg.n_heads
    di = cfg.ssm_expand * d
    hd = di // H
    h = (x @ params["w_up"])[:, 0]
    qkv = h @ params["w_qkv"]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(B, H, hd) / math.sqrt(hd)
    k = k.reshape(B, H, hd)
    v = v.reshape(B, H, hd)
    i_t, log_f = _mlstm_gates(params, cfg, h)
    S_state, n_state = state
    y, S_state, n_state = linear_recurrence_step(
        q, k, v, log_f, i_t, S_state, n_state, normalize=True
    )
    og = jax.nn.sigmoid((x[:, 0] @ params["w_og"]).astype(jnp.float32))
    out = (y.reshape(B, di) * og).astype(x.dtype) @ params["w_down"]
    return out[:, None], (S_state, n_state)


def mlstm_state_init(cfg: ModelConfig, B: int):
    H = cfg.n_heads
    hd = cfg.ssm_expand * cfg.d_model // H
    return (
        jnp.zeros((B, H, hd, hd), jnp.float32),
        jnp.zeros((B, H, hd), jnp.float32),
    )


# ---------------------------------------------------------------------------
# sLSTM block (xLSTM) — sequential scalar-memory recurrence
# ---------------------------------------------------------------------------


def init_slstm(rng, cfg: ModelConfig):
    d = cfg.d_model
    ks = jax.random.split(rng, 3)
    return {
        "w_x": init_dense(ks[0], d, 4 * d, dt(cfg)),  # z, i, f, o pre-acts
        "r_h": init_dense(ks[1], d, 4 * d, dt(cfg), scale=1.0 / math.sqrt(d) * 0.5),
        "b": jnp.zeros((4 * d,), dt(cfg)),
        "w_down": init_dense(ks[2], d, d, dt(cfg)),
    }


def slstm_logical_axes(cfg: ModelConfig):
    return {
        "w_x": ("embed", None),
        "r_h": ("embed", None),
        "b": (None,),
        "w_down": ("embed", None),
    }


def _slstm_cell(params, cfg, xw_t, st):
    """One stabilized sLSTM step. xw_t: (B, 4d) precomputed x-projection."""
    h, c, n, m = st
    d = cfg.d_model
    pre = xw_t + h @ params["r_h"] + params["b"]
    z, it, ft, ot = jnp.split(pre.astype(jnp.float32), 4, axis=-1)
    z = jnp.tanh(z)
    o = jax.nn.sigmoid(ot)
    log_f = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(log_f + m, it)
    i_p = jnp.exp(it - m_new)
    f_p = jnp.exp(log_f + m - m_new)
    c = f_p * c + i_p * z
    n = f_p * n + i_p
    h_new = o * c / jnp.maximum(n, 1.0)
    return (h_new.astype(xw_t.dtype), c, n, m_new), h_new


def slstm_block(params, cfg: ModelConfig, x, state=None):
    B, S, d = x.shape
    xw = x @ params["w_x"]  # (B, S, 4d)
    st = state if state is not None else slstm_state_init(cfg, B)

    def step(carry, xw_t):
        return _slstm_cell(params, cfg, xw_t, carry)

    st, hs = jax.lax.scan(step, st, xw.swapaxes(0, 1))
    y = hs.swapaxes(0, 1).astype(x.dtype)  # (B, S, d)
    return y @ params["w_down"], st


def slstm_decode_step(params, cfg: ModelConfig, x, state):
    xw = (x @ params["w_x"])[:, 0]
    st, h = _slstm_cell(params, cfg, xw, state)
    return (h.astype(x.dtype) @ params["w_down"])[:, None], st


def slstm_state_init(cfg: ModelConfig, B: int):
    d = cfg.d_model
    z = jnp.zeros((B, d), jnp.float32)
    return (z.astype(jnp.dtype(cfg.dtype)), z, z, z - 30.0)


# ---------------------------------------------------------------------------
# Mamba2 (SSD) block
# ---------------------------------------------------------------------------


def init_mamba2(rng, cfg: ModelConfig):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    N = cfg.ssm_state
    H = cfg.n_heads
    ks = jax.random.split(rng, 6)
    return {
        # joint projection: [x (di), z (di), B (H·N), C (H·N), dt (H)]
        "w_in": init_dense(ks[0], d, 2 * di + 2 * H * N + H, dt(cfg)),
        "conv": (jax.random.normal(ks[1], (cfg.ssm_conv, di + 2 * H * N), jnp.float32) * 0.1).astype(dt(cfg)),
        "A_log": jnp.zeros((H,), jnp.float32),  # A = −exp(A_log) ≤ −1
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "w_out": init_dense(ks[2], di, d, dt(cfg)),
    }


def mamba2_logical_axes(cfg: ModelConfig):
    return {
        "w_in": ("embed", "ff"),
        "conv": (None, "ff"),
        "A_log": (None,),
        "D": (None,),
        "dt_bias": (None,),
        "w_out": ("ff", "embed"),
    }


def _mamba2_split(cfg: ModelConfig, proj):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    N, H = cfg.ssm_state, cfg.n_heads
    x_in = proj[..., :di]
    z = proj[..., di : 2 * di]
    Bv = proj[..., 2 * di : 2 * di + H * N]
    Cv = proj[..., 2 * di + H * N : 2 * di + 2 * H * N]
    dt_ = proj[..., 2 * di + 2 * H * N :]
    return x_in, z, Bv, Cv, dt_


def mamba2_block(params, cfg: ModelConfig, x, state=None):
    """x: (B, S, d). state: (conv_buf (B, conv−1, dconv), S_state, n_dummy)."""
    B, S, d = x.shape
    di = cfg.ssm_expand * d
    N, H = cfg.ssm_state, cfg.n_heads
    P = di // H
    proj = x @ use_weight(cfg, params["w_in"], None, "ff")
    x_in, z, Bv, Cv, dt_ = _mamba2_split(cfg, proj)
    # Causal depthwise conv over the (x, B, C) streams jointly.
    xbc = jnp.concatenate([x_in, Bv, Cv], axis=-1)  # (B, S, dconv)
    K = cfg.ssm_conv
    if state is not None:
        conv_buf = state[0]
        xbc_pad = jnp.concatenate([conv_buf, xbc], axis=1)
    else:
        xbc_pad = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
    conv_w = params["conv"]
    xbc_conv = sum(
        xbc_pad[:, i : i + S, :] * conv_w[i][None, None, :] for i in range(K)
    )
    xbc_conv = jax.nn.silu(xbc_conv.astype(jnp.float32)).astype(x.dtype)
    x_c = xbc_conv[..., :di]
    B_c = xbc_conv[..., di : di + H * N].reshape(B, S, H, N)
    C_c = xbc_conv[..., di + H * N :].reshape(B, S, H, N)

    dt_v = jax.nn.softplus(dt_.astype(jnp.float32) + params["dt_bias"])  # (B,S,H)
    A = -jnp.exp(params["A_log"])  # (H,)
    log_a = dt_v * A[None, None, :]  # ≤ 0
    v = x_c.reshape(B, S, H, P)
    y, (S_new, n_new) = chunk_linear_recurrence(
        C_c, B_c, v, log_a, dt_v, chunk=cfg.ssm_chunk,
        init_state=None if state is None else (state[1], state[2]),
        normalize=False, unroll=cfg.scan_unroll,
    )
    y = y + v.astype(jnp.float32) * params["D"][None, None, :, None]
    y = (y.reshape(B, S, di) * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    new_conv_buf = xbc[:, S - (K - 1) :, :] if S >= K - 1 else None
    return y @ use_weight(cfg, params["w_out"], "ff", None), (new_conv_buf, S_new, n_new)


def mamba2_decode_step(params, cfg: ModelConfig, x, state):
    B = x.shape[0]
    d = cfg.d_model
    di = cfg.ssm_expand * d
    N, H = cfg.ssm_state, cfg.n_heads
    P = di // H
    K = cfg.ssm_conv
    conv_buf, S_state, n_state = state
    proj = (x @ params["w_in"])[:, 0]
    x_in, z, Bv, Cv, dt_ = _mamba2_split(cfg, proj)
    xbc = jnp.concatenate([x_in, Bv, Cv], axis=-1)[:, None, :]  # (B,1,dconv)
    window = jnp.concatenate([conv_buf, xbc], axis=1)  # (B, K, dconv)
    conv_w = params["conv"]
    xbc_conv = jnp.einsum("bkc,kc->bc", window, conv_w)
    xbc_conv = jax.nn.silu(xbc_conv.astype(jnp.float32)).astype(x.dtype)
    x_c = xbc_conv[..., :di]
    B_c = xbc_conv[..., di : di + H * N].reshape(B, H, N)
    C_c = xbc_conv[..., di + H * N :].reshape(B, H, N)
    dt_v = jax.nn.softplus(dt_.astype(jnp.float32) + params["dt_bias"])  # (B,H)
    A = -jnp.exp(params["A_log"])
    log_a = dt_v * A[None, :]
    v = x_c.reshape(B, H, P)
    y, S_state, n_state = linear_recurrence_step(
        C_c, B_c, v, log_a, dt_v, S_state, n_state, normalize=False
    )
    y = y + v.astype(jnp.float32) * params["D"][None, :, None]
    y = (y.reshape(B, di) * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return (y @ params["w_out"])[:, None], (window[:, 1:], S_state, n_state)


def mamba2_state_init(cfg: ModelConfig, B: int):
    di = cfg.ssm_expand * cfg.d_model
    N, H = cfg.ssm_state, cfg.n_heads
    P = di // H
    dconv = di + 2 * H * N
    return (
        jnp.zeros((B, cfg.ssm_conv - 1, dconv), jnp.dtype(cfg.dtype)),
        jnp.zeros((B, H, N, P), jnp.float32),
        jnp.zeros((B, H, N), jnp.float32),
    )
