"""Kernel micro-benchmarks: GF(2) bit-matrix RS encode (Pallas, interpret)
vs the table-based GF(256) jnp oracle, plus the unified codec engine's
batched-throughput sweep (backend × batch × (n, k)).

On CPU the Pallas kernel runs in interpret mode, so wall-clock here measures
the *reference environment*, not TPU perf — the TPU story is the §Roofline
arithmetic-intensity argument (bit-matrix matmul is MXU-shaped; table
lookups are not). We report both wall time and derived arithmetic intensity.

The codec sweep is the measurement behind the TOFEC amortization claim
(coding overhead Ψ caps throughput under load, FAST CLOUD §IV): one batched
``Codec.encode`` over b queued objects vs b per-object calls. Rows report
MB/s for each and the batched/looped speedup.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BenchTimer
from repro import obs as _obs
from repro.coding import rs
from repro.coding.codec import Codec
from repro.core import PAPER_READ_3MB, RequestClass, TOFECPolicy
from repro.kernels.gf2mm import gf2mm, ops, ref
from repro.serve import FusedServingStep


def bench_gf2mm(n: int = 12, k: int = 6, B: int = 16384) -> list[str]:
    rng = np.random.default_rng(0)
    data = rng.integers(0, 256, size=(k, B), dtype=np.uint8)
    jdata = jnp.asarray(data)

    # jit the wrapper so both timed paths measure pure device dispatch
    enc = jax.jit(lambda d: ops.rs_encode(d, n=n, k=k, interpret=True))
    enc(jdata).block_until_ready()
    with BenchTimer("kernel_rs_encode_pallas", calls=3) as t1:
        for _ in range(3):
            enc(jdata).block_until_ready()

    par = jnp.asarray(rs.cauchy_parity_matrix(n, k))
    ref_fn = jax.jit(lambda d: ref.gf256_matmul_ref(par, d))
    ref_fn(jdata).block_until_ready()
    with BenchTimer("kernel_rs_encode_tableref", calls=3) as t2:
        for _ in range(3):
            ref_fn(jdata).block_until_ready()

    # Derived: GF(2) matmul arithmetic intensity on TPU for this shape.
    M, K = 8 * (n - k), 8 * k
    flops = 2 * M * K * B  # MXU MACs on bit-planes
    bytes_ = (M * K + K * B + M * B)  # bf16→1B-ish planes; order of magnitude
    return [
        t1.row(f"payload={k * B / 2 ** 20:.1f}MB"),
        t2.row(f"bitmm_arith_intensity={flops / bytes_:.1f}flop/B"),
    ]


def bench_codec_sweep(B: int = 4096) -> list[str]:
    """Backend × batch × (n, k): batched encode vs the per-object loop.

    The acceptance bar for the unified engine: batched throughput ≥ the
    per-object loop at batch ≥ 8 on the jnp or pallas-interpret backend
    (per-launch/trace overhead amortized across the admission round).
    """
    rng = np.random.default_rng(7)
    rows: list[str] = []
    for backend in ("numpy", "jnp", "pallas"):
        codec = Codec(backend)
        for n, k in ((8, 4), (12, 6)):
            for batch in (1, 8, 32):
                data = rng.integers(0, 256, size=(batch, k, B), dtype=np.uint8)
                # warm both paths (jit compile outside the timed region)
                codec.encode(data, n, k)
                codec.encode(data[0], n, k)
                mb = batch * k * B / 2**20

                t0 = time.monotonic()
                codec.encode(data, n, k)
                dt_batched = time.monotonic() - t0

                t0 = time.monotonic()
                for i in range(batch):
                    codec.encode(data[i], n, k)
                dt_looped = time.monotonic() - t0

                speedup = dt_looped / max(dt_batched, 1e-9)
                timer = BenchTimer(f"codec_encode_{backend}_n{n}k{k}_b{batch}", calls=1)
                timer.elapsed = dt_batched
                rows.append(
                    timer.row(
                        f"batched={mb / dt_batched:.1f}MB/s"
                        f"|looped={mb / dt_looped:.1f}MB/s"
                        f"|speedup={speedup:.2f}x"
                    )
                )
    return rows


def bench_fused_serve(B: int = 4096, reps: int = 5) -> list[str]:
    """Fused vs unfused TOFEC serving step across batch sizes and backends.

    Fused: ONE jitted launch runs the admission update (tofec_step_jax) and
    the batched decode of the whole round. Unfused: the pre-fused serving
    path — a host policy update plus one ``codec.decode`` launch per object.
    The acceptance bar (ISSUE 2): fused ≥ 1.5x unfused at batch ≥ 8 on the
    jnp backend. Pallas runs in interpret mode on CPU, so its wall-clock is
    the reference environment, not TPU perf.
    """
    cls = RequestClass("bench", 1.0, PAPER_READ_3MB, k_max=6, r_max=2.0, n_max=12)
    n, k = 12, 6
    rng = np.random.default_rng(11)
    rows_out: list[str] = []
    for backend in ("jnp", "pallas"):
        codec = Codec(backend)
        step = FusedServingStep.for_class(cls, L=16, codec=codec)
        policy = TOFECPolicy.for_classes([cls], L=16)
        for batch in (1, 8, 32):
            data = rng.integers(0, 256, size=(batch, k, B), dtype=np.uint8)
            coded = np.stack([rs.encode(data[i], n, k) for i in range(batch)])
            present = np.stack([np.sort(rng.choice(n, size=k, replace=False))
                                for _ in range(batch)])
            strips = np.stack([coded[i][present[i]] for i in range(batch)])

            def fused_once():
                out, _ = step.decode_batch(strips, present, n=n, k=k, q=batch)
                return out

            def unfused_once():
                outs = []
                for i in range(batch):
                    policy.select(q=batch, idle=0)
                    outs.append(np.asarray(
                        codec.decode(strips[i], tuple(present[i]), n, k)))
                return np.stack(outs)

            # warm both paths (compilation outside the timed region)
            np.testing.assert_array_equal(fused_once(), data)
            np.testing.assert_array_equal(unfused_once(), data)

            t0 = time.monotonic()
            for _ in range(reps):
                fused_once()
            dt_fused = (time.monotonic() - t0) / reps

            t0 = time.monotonic()
            for _ in range(reps):
                unfused_once()
            dt_unfused = (time.monotonic() - t0) / reps

            mb = batch * k * B / 2**20
            speedup = dt_unfused / max(dt_fused, 1e-9)
            # dt_fused is already a per-call average, so calls=1 here.
            timer = BenchTimer(f"fused_serve_{backend}_n{n}k{k}_b{batch}", calls=1)
            timer.elapsed = dt_fused
            rows_out.append(
                timer.row(
                    f"fused={mb / dt_fused:.1f}MB/s"
                    f"|unfused={mb / dt_unfused:.1f}MB/s"
                    f"|speedup={speedup:.2f}x"
                )
            )
    return rows_out



def bench_serve_closed_loop(batches: tuple = (8, 32), rounds: int = 8,
                            steps: int = 2) -> list[str]:
    """Sustained closed-loop serving throughput (req/s), fused vs unfused.

    Fused: :class:`ClosedLoopServer` — ONE jitted launch per round covers
    admission update + batched MDS decode + bytes→tokens + LM prefill, and
    the controller's pick feeds the proxy write policy. Unfused: the engine's
    pre-fused path — proxy-side host decode, then a separate prefill launch.
    Same store, same prompts, same generation steps; the delta is the serving
    control loop itself. The acceptance bar (ISSUE 7): fused ≥ unfused at
    batch 8 and 32. Writes BENCH_serve.json for the CI serve smoke leg.

    The fused step gets an explicit jnp codec so the numpy codec-backend CI
    leg can still run this benchmark (the step refuses host-only backends).
    """
    import json as _json
    import os as _os
    from benchmarks.common import RESULTS_DIR
    from repro.coding.layout import SharedKeyLayout
    from repro.core import FeedbackPolicy, StaticPolicy
    from repro.models import get
    from repro.serve import ClosedLoopServer, ServePolicy, ServingEngine
    from repro.storage import MemoryStore, Proxy

    arch = get("qwen1.5-0.5b", smoke=True)
    params = arch.init(jax.random.key(0))
    eng = ServingEngine(arch, params, max_seq=96)
    # 16 KB coded objects (prompt tokens in the head, as the serving tower
    # stores them): big enough that the storage decode path is real work —
    # the fused step's in-launch batched decode vs the proxy's per-object
    # host decode — small enough that a CI smoke run stays fast.
    prompt_len = 64
    layout = SharedKeyLayout(K=4, r=2, strip_bytes=4096)
    cls = RequestClass("serve", layout.file_bytes / 2**20, PAPER_READ_3MB,
                       k_max=4, r_max=2.0, n_max=8)
    rng = np.random.default_rng(13)

    rows_out: list[str] = []
    records = []
    for batch in batches:
        store = MemoryStore()
        keys = []
        for i in range(batch):
            toks = rng.integers(0, arch.cfg.vocab, size=(prompt_len,)).astype(np.int32)
            ServingEngine.store_prompt(store, f"p{batch}/{i}", layout, toks)
            keys.append(f"p{batch}/{i}")

        proxy_f = Proxy(store, StaticPolicy(8, 4), L=16,
                        write_policy=FeedbackPolicy(8, 4))
        step = FusedServingStep.for_policy(ServePolicy.tofec(), cls, 16,
                                           codec=Codec("jnp"))
        srv = ClosedLoopServer(eng, proxy_f, layout, step, prompt_len=prompt_len)
        proxy_u = Proxy(store, StaticPolicy(8, 4), L=16)
        fused_once = lambda: srv.serve_round(keys, steps=steps)
        unfused_once = lambda: eng.serve(proxy_u, layout, keys,
                                         prompt_len=prompt_len, steps=steps)
        try:
            # Warm both paths (compilation + codec caches), then INTERLEAVE
            # the timed rounds: host-load drift between two separate timing
            # windows would otherwise swamp the fused-vs-unfused delta.
            fused_once()
            unfused_once()
            dt_fused = dt_unfused = 0.0
            for _ in range(rounds):
                t0 = time.monotonic()
                fused_once()
                dt_fused += time.monotonic() - t0
                t0 = time.monotonic()
                unfused_once()
                dt_unfused += time.monotonic() - t0
            dt_fused /= rounds
            dt_unfused /= rounds
        finally:
            proxy_f.close()
            proxy_u.close()

        fused_rps = batch / dt_fused
        unfused_rps = batch / dt_unfused
        records.append({
            "batch": batch,
            "fused_req_per_s": fused_rps,
            "unfused_req_per_s": unfused_rps,
            "speedup": fused_rps / unfused_rps,
        })
        timer = BenchTimer(f"serve_closed_loop_b{batch}", calls=1)
        timer.elapsed = dt_fused
        rows_out.append(timer.row(
            f"fused={fused_rps:.1f}req/s|unfused={unfused_rps:.1f}req/s"
            f"|speedup={fused_rps / unfused_rps:.2f}x"))

    # -- collected pass (untimed): re-serve with observability ON so the
    # per-round timeline, the SLO/convergence monitor and the live dashboard
    # exercise the exact fused path the timed rounds ran. The collect=True
    # variant is a separate expected compilation and never overlaps the
    # timed windows above; the timeline rides the launch, so the only extra
    # host sync is the one snapshot at the end.
    slo_batch = batches[0]
    store = MemoryStore()
    keys = []
    for i in range(slo_batch):
        toks = rng.integers(0, arch.cfg.vocab, size=(prompt_len,)).astype(np.int32)
        ServingEngine.store_prompt(store, f"slo/{i}", layout, toks)
        keys.append(f"slo/{i}")
    proxy = Proxy(store, StaticPolicy(8, 4), L=16,
                  write_policy=FeedbackPolicy(8, 4))
    step = FusedServingStep.for_policy(ServePolicy.tofec(), cls, 16,
                                       codec=Codec("jnp"))
    srv = ClosedLoopServer(eng, proxy, layout, step, prompt_len=prompt_len)
    _obs.set_enabled(True)
    try:
        for _ in range(rounds):
            srv.serve_round(keys, steps=steps)
        snap = srv.timeline.snapshot()
    finally:
        _obs.set_enabled(None)
        proxy.close()

    spec = _obs.SLOSpec(target_s=0.5, percentile=0.99, window=4)
    events = _obs.EventLog("serve_bench")
    report = _obs.slo_report(snap, spec, label="serve_bench", events=events)
    conv = report["convergence"]
    slo_block = {
        "settle_round": conv["settle_slot"],
        "dwell_final": conv["dwell_final"],
        "final_code": conv["final_code"],
        "max_burn_rate": report["max_burn_rate"],
        "breach_slots": report["breach_slots"],
        "p99_last": report["percentile_last_s"],
    }
    rows_out.append(
        f"serve_slo: settle_round={slo_block['settle_round']}"
        f"|code={conv['final_code']}|dwell={conv['dwell_final']:.2f}"
        f"|max_burn={report['max_burn_rate']:.2f}")

    _os.makedirs(RESULTS_DIR, exist_ok=True)
    artifact = {
        "schema": "repro.serve/BENCH_serve/v1",
        "meta": _obs.run_meta(),
        "rounds": rounds, "steps": steps, "prompt_len": prompt_len,
        "layout": {"K": layout.K, "N": layout.N,
                   "strip_bytes": layout.strip_bytes},
        "results": records,
        "slo": slo_block,
        "slo_report": {k: v for k, v in report.items() if k != "events"},
    }
    with open(_os.path.join(RESULTS_DIR, "BENCH_serve.json"), "w") as f:
        _json.dump(artifact, f, indent=1)
    events.write(_os.path.join(RESULTS_DIR, "serve_events.ndjson"))
    _obs.html_report(
        _os.path.join(RESULTS_DIR, "serve_dashboard.html"),
        {"serve": snap}, slo=report,
        meta={"bench": "serve_closed_loop", "batch": slo_batch,
              "rounds": rounds, "steps": steps})
    return rows_out


def bench_fleet_sweep(count: int = 1024, grids: tuple = (8, 64, 256)) -> list[str]:
    """Vmapped fleet sweep vs the serial host loop at grid sizes {8, 64, 256}.

    The serial baseline dispatches one jitted ``simulate_tofec_scan`` per
    grid point (the pre-fleet λ-sweep shape); the fleet runs the same grid
    as chunked vmapped launches. At grid 8 the discrete-event simulator is
    also timed for scale (the original Fig.1/7 inner loop — why the fleet
    subsystem exists).
    """
    from repro.core.controller import TofecTables
    from repro.core.jax_sim import JaxSimParams, simulate_tofec_scan
    from repro.core.simulator import poisson_arrivals, simulate
    from repro.core.static_optimizer import build_class_plan
    from repro.core.traces import TraceSampler
    from repro.fleet import FleetSweep, PolicySpec, grid_cases

    cls = RequestClass("read3mb", 3.0, PAPER_READ_3MB, k_max=6, r_max=2.0, n_max=12)
    L = 16
    tables = TofecTables.from_plan(build_class_plan(cls, L))
    p = JaxSimParams.from_class(cls, L)
    sampler = TraceSampler(PAPER_READ_3MB, cls.file_mb)
    sweep = FleetSweep(chunk=64)
    rows: list[str] = []
    for grid in grids:
        lams = np.linspace(5.0, 65.0, max(grid // 8, 1))
        seeds = range(-(-grid // len(lams)))  # pad seeds so len(cases) >= grid
        cases = grid_cases(lams, [PolicySpec.tofec()], seeds, cls, L)[:grid]

        sweep.run(cases, count)  # warm the shape bucket (compile + workloads)
        t0 = time.monotonic()
        res = sweep.run(cases, count)
        jax.block_until_ready(res.out)  # async dispatch: sync before stopping
        dt_fleet = time.monotonic() - t0

        # Serial host loop: one jitted scan dispatch per point, same draws.
        simulate_tofec_scan(p, tables, *map(jnp.asarray, _point_arrays(cases[0], count)))
        t0 = time.monotonic()
        for case in cases:
            inter, exps = _point_arrays(case, count)
            simulate_tofec_scan(p, tables, jnp.asarray(inter), jnp.asarray(exps))[
                "total"
            ].block_until_ready()
        dt_serial = time.monotonic() - t0

        derived = (f"serial_scan={1e3 * dt_serial:.1f}ms"
                   f"|speedup={dt_serial / max(dt_fleet, 1e-9):.2f}x"
                   f"|launches={res.launches}|compiles={res.compiles}")
        if grid <= 8:
            t0 = time.monotonic()
            for case in cases:
                rng = np.random.default_rng(case.seed)
                arr = poisson_arrivals(rng, case.lam, count)
                simulate(TOFECPolicy.for_classes([cls], L), arr, sampler, L=L,
                         seed=case.seed)
            dt_event = time.monotonic() - t0
            derived += (f"|event_sim={1e3 * dt_event:.1f}ms"
                        f"|vs_event={dt_event / max(dt_fleet, 1e-9):.1f}x")
        timer = BenchTimer(f"fleet_sweep_g{grid}_t{count}", calls=1)
        timer.elapsed = dt_fleet
        rows.append(timer.row(derived))
    return rows


def _point_arrays(case, count: int):
    rng = np.random.default_rng(case.seed)
    return case.resolved_workload().device_arrays(rng, count, case.cls.n_max)


def bench_multiclass_sweep(count: int = 1024, grids: tuple = (6, 24, 96)) -> list[str]:
    """Joint shared-pool sweep vs per-class split scans vs the event oracle.

    The joint path (:class:`repro.sched.SchedSweep`) runs each grid point as
    ONE multi-class scan over the merged stream; the split baseline runs the
    same grids through the fleet's Poisson-splitting ``tenant_cases`` path
    (2 fluid scans per point — cheaper per point but blind to interference);
    at the smallest grid the discrete-event shared-pool oracle
    (:func:`repro.core.simulator.simulate_shared_pool`) is timed for scale.
    """
    from repro.core import TOFECPolicy, build_class_plan
    from repro.core.simulator import simulate_shared_pool
    from repro.core.traces import TraceSampler
    from repro.fleet import FleetSweep, PolicySpec, TenantMix, tenant_cases
    from repro.sched import DisciplineSpec, SchedSweep, sched_cases

    hi = RequestClass("read3mb", 3.0, PAPER_READ_3MB, k_max=6, r_max=2.0, n_max=12)
    lo = RequestClass("read1mb", 1.0, PAPER_READ_3MB, k_max=4, r_max=2.0, n_max=8)
    L = 16
    disciplines = [DisciplineSpec.fifo(), DisciplineSpec.priority(0, 1),
                   DisciplineSpec.wfq(2.0, 1.0)]
    rows: list[str] = []
    for grid in grids:
        n_mix = max(grid // (len(disciplines) * 2), 1)
        mixes = [TenantMix(float(lam), (hi, lo), (0.5, 0.5))
                 for lam in np.linspace(10.0, 55.0, n_mix)]
        seeds = range(-(-grid // (n_mix * len(disciplines))))
        cases = sched_cases(mixes, disciplines, seeds, L=L)[:grid]

        joint = SchedSweep(chunk=32)
        joint.run(cases, count)  # warm the shape bucket
        t0 = time.monotonic()
        res = joint.run(cases, count)
        jax.block_until_ready(res.out)
        dt_joint = time.monotonic() - t0

        split_cases = [
            c for case in cases
            for c in tenant_cases(case.mix, [PolicySpec.tofec()], [case.seed], L,
                                  quiet=True)
        ]
        fleet = FleetSweep(chunk=64)
        fleet.run(split_cases, count)  # warm
        t0 = time.monotonic()
        sres = fleet.run(split_cases, count)
        jax.block_until_ready(sres.out)
        dt_split = time.monotonic() - t0

        derived = (f"split_fleet={1e3 * dt_split:.1f}ms"
                   f"|joint_vs_split={dt_split / max(dt_joint, 1e-9):.2f}x"
                   f"|launches={res.launches}|compiles={res.compiles}")
        if grid <= 8:
            pols = [TOFECPolicy([build_class_plan(c, L)]) for c in (hi, lo)]
            samp = [TraceSampler(c.params, c.file_mb) for c in (hi, lo)]
            t0 = time.monotonic()
            for case in cases:
                rng = np.random.default_rng(case.seed)
                arr = np.cumsum(case.mix.interarrivals(rng, count).astype(np.float64))
                ids = case.mix.cls_ids(rng, count)
                kw = {}
                if case.discipline.kind == "priority":
                    kw["prio"] = case.discipline.prio
                if case.discipline.kind == "wfq":
                    kw["weights"] = case.discipline.weights
                simulate_shared_pool(pols, arr, ids, samp, L=L,
                                     discipline=case.discipline.kind, **kw)
            dt_event = time.monotonic() - t0
            derived += (f"|event_sim={1e3 * dt_event:.1f}ms"
                        f"|vs_event={dt_event / max(dt_joint, 1e-9):.1f}x")
        timer = BenchTimer(f"multiclass_sweep_g{grid}_t{count}", calls=1)
        timer.elapsed = dt_joint
        rows.append(timer.row(derived))
    return rows


def bench_taskq_engine(count: int = 1024, grids: tuple = (8, 64)) -> list[str]:
    """Exact task-level engine: vmapped sweep vs serial scan vs event oracle.

    The vmapped path runs the whole grid through :class:`repro.taskq.
    TaskqSweep` (chunked launches, pools broadcast); the serial baseline
    dispatches one jitted :func:`repro.taskq.engine.taskq_scan` per point on
    the same draws; at grid 8 the discrete-event oracle
    (:func:`repro.core.simulator.simulate`) is timed on the same shared
    pools — the loop the exact engine replaces.
    """
    from repro.core.traces import TraceStore
    from repro.core.simulator import simulate
    from repro.fleet import PolicySpec, grid_cases
    from repro.taskq import TaskqSweep, taskq_scan, taskq_streams

    cls = RequestClass("read3mb", 3.0, PAPER_READ_3MB, k_max=6, r_max=2.0, n_max=12)
    L = 16
    store = TraceStore.generate(
        PAPER_READ_3MB, [cls.file_mb / k for k in range(1, cls.k_max + 1)],
        threads=cls.n_max, samples=4096, correlation=0.14, seed=0,
    )
    dp = store.device_pools(n_max=cls.n_max)
    pools_j, sizes_j = jnp.asarray(dp.pools), jnp.asarray(dp.sizes_mb)
    sweep = TaskqSweep(chunk=64)
    rows: list[str] = []
    for grid in grids:
        lams = np.linspace(5.0, 60.0, max(grid // 8, 1))
        seeds = range(-(-grid // len(lams)))
        cases = grid_cases(lams, [PolicySpec.tofec()], seeds, cls, L)[:grid]

        sweep.run(cases, count, dp)  # warm the shape bucket
        t0 = time.monotonic()
        res = sweep.run(cases, count, dp)
        jax.block_until_ready(res.out)
        dt_vmap = time.monotonic() - t0

        # Serial baseline: one jitted single-point scan per grid point.
        def one(case):
            inter, idx = taskq_streams(case, count, dp.n_rows)
            cfg = {name: jnp.asarray(res.cfg[name][cases.index(case)])
                   for name in res.cfg}
            return taskq_scan(cfg, jnp.asarray(inter), jnp.asarray(idx),
                              pools_j, sizes_j, L=L, q_cap=sweep.q_cap)

        one(cases[0])["total"].block_until_ready()  # warm
        t0 = time.monotonic()
        for case in cases:
            one(case)["total"].block_until_ready()
        dt_serial = time.monotonic() - t0

        derived = (f"serial_scan={1e3 * dt_serial:.1f}ms"
                   f"|speedup={dt_serial / max(dt_vmap, 1e-9):.2f}x"
                   f"|launches={res.launches}|compiles={res.compiles}")
        if grid <= 8:
            from repro.core import TOFECPolicy, build_class_plan

            t0 = time.monotonic()
            for case in cases:
                inter, idx = taskq_streams(case, count, dp.n_rows)
                arr = np.cumsum(inter.astype(np.float64))
                simulate(TOFECPolicy([build_class_plan(cls, L)]), arr,
                         dp.host_sampler(cls.file_mb, idx), L=L)
            dt_event = time.monotonic() - t0
            derived += (f"|event_sim={1e3 * dt_event:.1f}ms"
                        f"|vs_event={dt_event / max(dt_vmap, 1e-9):.1f}x")
        timer = BenchTimer(f"taskq_engine_g{grid}_t{count}", calls=1)
        timer.elapsed = dt_vmap
        rows.append(timer.row(derived))
    return rows


def bench_shard_scaling(count: int = 1024, grid: int = 1024,
                        big_grid: int = 100_000, big_count: int = 512,
                        devices: tuple = (1, 2, 4, 8)) -> list[str]:
    """Mesh-sharded streaming fleet sweep: device scaling + memory bound.

    For each device count (host virtual devices when launched under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``; counts beyond
    the available devices are skipped): time the sharded **streamed** sweep
    on a mixed-policy grid and assert its frontier is a bit-exact equal of
    the single-device **materialized** baseline. Then a ``big_grid``-point
    streamed run demonstrates the O(chunk × devices) memory bound — no
    (G, T) block ever materializes. Writes ``BENCH_shard.json``.

    Speedup is physical: with fewer host cores than virtual devices (CI
    runners), sharding only adds collective overhead — the artifact records
    ``host_cores`` so readers can tell scaling rows from placebo rows, and
    the >1.8x @ 4-device bar is only asserted when 4 real cores exist.
    """
    import json as _json
    import os as _os

    from repro.fleet import FleetSweep, PolicySpec, frontier_points, grid_cases
    from benchmarks.common import RESULTS_DIR

    cls = RequestClass("read3mb", 3.0, PAPER_READ_3MB, k_max=6, r_max=2.0, n_max=12)
    L = 16
    pols = [PolicySpec.tofec(), PolicySpec.static(6, 3), PolicySpec.fixedk(4)]

    def mixed_grid(g: int) -> list:
        lams = np.linspace(5.0, 65.0, max(-(-g // (len(pols) * 4)), 1))
        return grid_cases(lams, pols, range(4), cls, L)[:g]

    cases = mixed_grid(grid)
    n_dev = len(jax.devices())
    rows: list[str] = []

    # Single-device materialized baseline: the pre-shard path, timed AND the
    # bit-exactness reference for every sharded-streaming run.
    base = FleetSweep(chunk=128)
    base.run(cases[: min(256, grid)], count)  # warm the shape bucket
    t0 = time.monotonic()
    ref = base.run(cases, count)
    jax.block_until_ready(ref.out)
    dt_base = time.monotonic() - t0
    ref_pts = [p.to_dict() for p in frontier_points(ref)]
    timer = BenchTimer(f"shard_baseline_g{grid}_t{count}", calls=1)
    timer.elapsed = dt_base
    rows.append(timer.row(f"materialized|devices=1|launches={ref.launches}"))

    scaling, dt_one = [], None
    for d in devices:
        if d > n_dev:
            continue
        sweep = FleetSweep(chunk=128, mesh=d)
        sweep.run(cases[: min(256, grid)], count, stream=True)
        t0 = time.monotonic()
        res = sweep.run(cases, count, stream=True)
        dt = time.monotonic() - t0
        assert res.out == {}  # streamed: no (G, T) block
        pts = [p.to_dict() for p in frontier_points(res)]
        assert _json.dumps(pts) == _json.dumps(ref_pts), \
            f"sharded-streaming frontier diverged at d={d}"
        dt_one = dt if d == 1 else dt_one
        speedup = (dt_one or dt) / max(dt, 1e-9)
        scaling.append({"devices": d, "ms": 1e3 * dt, "speedup_vs_1dev": speedup,
                        "bit_exact": True})
        timer = BenchTimer(f"shard_stream_d{d}_g{grid}_t{count}", calls=1)
        timer.elapsed = dt
        rows.append(timer.row(f"speedup={speedup:.2f}x|bit_exact=True"
                              f"|launches={res.launches}"))

    cores = _os.cpu_count() or 1
    if cores >= 4 and n_dev >= 4 and grid >= 1024:
        at4 = next(s["speedup_vs_1dev"] for s in scaling if s["devices"] == 4)
        assert at4 > 1.8, f"4-device speedup {at4:.2f}x <= 1.8x with {cores} cores"

    # Streamed-memory bound: a big grid whose materialized block would be
    # G × T × 20 B never exists — peak device residency is chunk-sized.
    big = mixed_grid(big_grid)
    d_big = max(d for d in devices if d <= n_dev)
    sweep = FleetSweep(chunk=128, mesh=None if d_big == 1 else d_big)
    sweep.run(big[: min(256, big_grid)], big_count, stream=True)  # warm
    t0 = time.monotonic()
    res = sweep.run(big, big_count, stream=True)
    dt_big = time.monotonic() - t0
    assert res.out == {} and len(frontier_points(res)) == big_grid
    mat_mb = big_grid * big_count * 20 / 2**20  # 3×f32 + 2×i32 per request
    str_mb = (128 * d_big * big_count * 20 + big_grid * 15 * 4) / 2**20
    timer = BenchTimer(f"shard_stream_big_g{big_grid}_t{big_count}", calls=1)
    timer.elapsed = dt_big
    rows.append(timer.row(
        f"devices={d_big}|req_per_s={big_grid * big_count / dt_big:.0f}"
        f"|materialized_would_be={mat_mb:.0f}MB"
        f"|streamed_peak~{str_mb:.0f}MB"))

    _os.makedirs(RESULTS_DIR, exist_ok=True)
    artifact = {
        "schema": "repro.fleet/BENCH_shard/v1",
        "meta": _obs.run_meta(mesh_shape=(d_big,)),
        "grid": grid, "count": count,
        "big_grid": big_grid, "big_count": big_count,
        "host_devices": n_dev, "host_cores": cores,
        "baseline_materialized_ms": 1e3 * dt_base,
        "scaling": scaling,
        "big_grid_ms": 1e3 * dt_big,
        "big_grid_devices": d_big,
        "materialized_would_be_mb": mat_mb,
        "streamed_peak_mb": str_mb,
    }
    with open(_os.path.join(RESULTS_DIR, "BENCH_shard.json"), "w") as f:
        _json.dump(artifact, f, indent=1)
    return rows


def bench_ckpt_encode(leaf_mb: int = 1) -> list[str]:
    rng = np.random.default_rng(1)
    payload = rng.integers(0, 256, size=leaf_mb * 2**20, dtype=np.uint8)
    with BenchTimer("ckpt_encode_blob", calls=1) as t:
        strips = ops.encode_blob(payload, n=8, k=4)
    present = (1, 3, 5, 7)
    with BenchTimer("ckpt_decode_blob", calls=1) as t2:
        out = ops.decode_blob(strips[list(present)], present, n=8, k=4,
                              payload_len=payload.size)
    assert np.array_equal(out, payload)
    mbps = leaf_mb / t.elapsed
    return [t.row(f"encode_{leaf_mb}MB@{mbps:.1f}MB/s"), t2.row("decode_ok")]


ALL_KERNEL = [
    bench_gf2mm,
    bench_codec_sweep,
    bench_fused_serve,
    bench_serve_closed_loop,
    bench_fleet_sweep,
    bench_multiclass_sweep,
    bench_taskq_engine,
    bench_shard_scaling,
    bench_ckpt_encode,
]
