import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT lower + compile every (arch × shape × mesh) cell.

MUST be run as its own process (``python -m repro.launch.dryrun``): the two
lines above execute before any jax import so the 512 placeholder host
devices exist before jax locks the device count. Smoke tests and benches
never import this module.

Per cell it prints/records:
  * compiled.memory_analysis()  — per-device bytes (proves it fits),
  * compiled.cost_analysis()    — FLOPs / bytes for §Roofline,
  * collective bytes parsed from optimized HLO,
  * the three roofline terms + dominant bottleneck.

Results accumulate in benchmarks/results/dryrun/<cell>.json so the roofline
table in EXPERIMENTS.md regenerates from artifacts.
"""

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.roofline import analyze, model_flops  # noqa: E402
from repro.launch.specs import dryrun_target, flops_pass_cfg, slstm_flops_correction  # noqa: E402
from repro.models.config import SHAPES, cell_is_runnable  # noqa: E402
from repro.models.registry import arch_names, get  # noqa: E402
from repro.models.sharding import axis_rules  # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "../../../benchmarks/results/dryrun")

# Cache: global FLOPs per (arch, shape) — mesh-independent, computed once.
_FLOPS_CACHE: dict[tuple[str, str], float] = {}


def cost_dict(ca) -> dict:
    """Normalize {lowered, compiled}.cost_analysis() across JAX versions.

    Older JAX returns a one-element list of dicts from compiled artifacts;
    newer versions return the dict directly (and lowered.cost_analysis()
    already does). Accept both.
    """
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def global_flops(arch_name: str, shape_name: str) -> float:
    """True executed FLOPs: unsharded lowering with scans unrolled.

    XLA's cost analysis counts while-loop bodies ONCE (validated in
    tests/test_dryrun_small.py), so the sharded/scanned compile pass
    undercounts by the trip counts. This pass unrolls every scan (except
    the sLSTM per-token scan — corrected analytically) and reads
    lowered.cost_analysis() without compiling.
    """
    key = (arch_name, shape_name)
    if key in _FLOPS_CACHE:
        return _FLOPS_CACHE[key]
    cfg = get(arch_name).cfg
    shape = SHAPES[shape_name]
    fcfg = flops_pass_cfg(cfg, shape)
    jfn, args = dryrun_target(arch_name, shape_name, None, cfg_override=fcfg)
    lowered = jfn.lower(*args)
    ca = cost_dict(lowered.cost_analysis())
    flops = float(ca.get("flops", 0.0)) + slstm_flops_correction(cfg, shape)
    _FLOPS_CACHE[key] = flops
    return flops


def run_cell(arch_name: str, shape_name: str, multi_pod: bool, *, save: bool = True,
             optimized: bool = False) -> dict:
    import dataclasses as _dc

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    shape = SHAPES[shape_name]
    cfg = get(arch_name).cfg
    cfg_override = None
    if optimized:
        # §Perf beyond-paper levers (EXPERIMENTS.md §Perf): weight gathering,
        # 256-way decode-cache sharding, and pure-DP for sub-1B models.
        accum = 8 if cfg.param_count_dense() > 1e11 else 1
        cfg_override = _dc.replace(
            cfg, weight_gather=True, decode_cache_seq_shard=True,
            grad_accum=accum,
        )
        cfg = cfg_override
    ok, reason = cell_is_runnable(cfg, shape)
    tag = f"{arch_name}×{shape_name}×{'multi' if multi_pod else 'single'}{'×opt' if optimized else ''}"
    rec = {
        "arch": arch_name,
        "shape": shape_name,
        "mesh": ("2x16x16" if multi_pod else "16x16") + ("-opt" if optimized else ""),
        "chips": chips,
        "kind": shape.kind,
        "optimized": optimized,
    }
    if not ok:
        rec.update(status="skipped", reason=reason)
        print(f"[dryrun] {tag}: SKIP ({reason})")
        _save(rec, save)
        return rec

    t0 = time.monotonic()
    try:
        with mesh:
            with axis_rules(mesh):
                jfn, args = dryrun_target(arch_name, shape_name, mesh, cfg_override=cfg_override)
                lowered = jfn.lower(*args)
                t_lower = time.monotonic() - t0
                compiled = lowered.compile()
                t_compile = time.monotonic() - t0 - t_lower
                mem = compiled.memory_analysis()
                print(f"[dryrun] {tag}: memory_analysis:")
                print(f"    {mem}")
                ca = cost_dict(compiled.cost_analysis())
                print(f"[dryrun] {tag}: cost_analysis(per-device, loops-once): "
                      f"flops={ca.get('flops', 0):.3e} "
                      f"bytes={ca.get('bytes accessed', 0):.3e}")
                roof = analyze(compiled, chips)
        # True executed FLOPs from the unrolled unsharded lowering.
        roof.flops = global_flops(arch_name, shape_name)
        # HBM traffic: per-device bytes from the compiled artifact undercount
        # loop bodies the same way; scale by the flops correction ratio.
        ca_flops = float(ca.get("flops", 0.0)) * chips
        scale = max((roof.flops / ca_flops) if ca_flops > 0 else 1.0, 1.0)
        roof.hbm_bytes *= chips * scale
        # Scale ONLY loop-resident collectives by the trip-count correction;
        # entry-level ones (grad all-reduce, FSDP epilogues) run once.
        in_loop = roof.coll_breakdown.get("in_loop", 0)
        in_entry = roof.coll_breakdown.get("in_entry", 0)
        roof.coll_bytes = float(in_loop) * scale + float(in_entry)
        mf = model_flops(cfg, shape, shape.kind)
        rec.update(
            status="ok",
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            roofline=roof.as_dict(),
            model_flops=mf,
            useful_flops_ratio=(mf / roof.flops) if roof.flops else None,
            memory={
                "argument_size_b": getattr(mem, "argument_size_in_bytes", None),
                "output_size_b": getattr(mem, "output_size_in_bytes", None),
                "temp_size_b": getattr(mem, "temp_size_in_bytes", None),
                "peak_b": getattr(mem, "peak_memory_in_bytes", None),
            },
        )
        print(
            f"[dryrun] {tag}: OK  t_comp={roof.t_compute:.4f}s "
            f"t_mem={roof.t_memory:.4f}s t_coll={roof.t_collective:.4f}s "
            f"dominant={roof.dominant} (lower {t_lower:.0f}s compile {t_compile:.0f}s)"
        )
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}")
        print(f"[dryrun] {tag}: ERROR {type(e).__name__}: {e}")
        traceback.print_exc()
    _save(rec, save)
    return rec


def _save(rec: dict, save: bool):
    if not save:
        return
    os.makedirs(RESULTS_DIR, exist_ok=True)
    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh'].replace('x', '_')}.json"
    with open(os.path.join(RESULTS_DIR, name), "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", help="shape name or 'all'")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--no-save", action="store_true")
    ap.add_argument("--opt", action="store_true",
                    help="apply §Perf levers (weight_gather, decode cache sharding)")
    args = ap.parse_args()

    archs = arch_names() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    n_ok = n_skip = n_err = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape, mp, save=not args.no_save, optimized=args.opt)
                n_ok += rec["status"] == "ok"
                n_skip += rec["status"] == "skipped"
                n_err += rec["status"] == "error"
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
