"""The front-end proxy of Fig.2, executing real I/O against an ObjectStore.

A :class:`Proxy` owns L connection threads, a FIFO request queue, and a FIFO
task queue, and serves high-level read/write requests with (n, k) MDS codes
chosen per request by a :class:`repro.core.controller.Policy` — the
real-I/O twin of :mod:`repro.core.simulator` (which is the statistics
oracle).

Reads use the Shared-Key layout: the coded object (N·b bytes) lives under
one key; each task is a ranged read of one chunk; the request completes when
k chunks arrive and the remaining tasks are cancelled (best-effort: queued
tasks are dropped; in-flight ones are abandoned — their results discarded —
matching a proxy that closes the connection).

Writes encode k chunks into n, upload each as a part, and complete when any
k parts are durable (the paper's write model; remaining uploads become
background tasks, footnote 1). All n parts target the same multipart object.

Write encoding goes through the unified batched codec engine: each admission
round drains every queued write and encodes all same-layout payloads with
ONE batched :meth:`SharedKeyLayout.encode_files` call, amortizing kernel
launch + trace cost across the backlog (the coding-overhead Ψ cap of FAST
CLOUD §IV). The admission *rule* (inject the next request's tasks only when
the task queue is drained and a thread idles) is unchanged — batching moves
encode off the per-request critical path, not the paper's queueing model.
"""

from __future__ import annotations

import dataclasses
import queue as _queue
import threading
import time
from collections import deque

import numpy as np

from repro.coding import codec as codec_mod
from repro.coding.layout import SharedKeyLayout
from repro.core.controller import Policy
from repro.storage.backend import ObjectStore, StorageError


@dataclasses.dataclass
class RequestResult:
    key: str
    op: str
    n: int
    k: int
    ok: bool
    data: bytes | None
    t_arrival: float
    t_first_start: float
    t_done: float
    failures: int = 0

    @property
    def total_s(self) -> float:
        return self.t_done - self.t_arrival

    @property
    def queueing_s(self) -> float:
        return self.t_first_start - self.t_arrival

    @property
    def service_s(self) -> float:
        return self.t_done - self.t_first_start


class _Request:
    def __init__(self, op, key, layout, payload, payload_len, n, k, cls_id):
        self.op = op
        self.key = key
        self.layout: SharedKeyLayout = layout
        self.payload = payload
        self.payload_len = payload_len
        self.n = n
        self.k = k
        self.cls_id = cls_id
        self.t_arrival = time.monotonic()
        self.t_first_start = None
        self.done = threading.Event()
        self.lock = threading.Lock()
        self.completed: dict[int, bytes] = {}
        self.failures = 0
        self.cancelled = False
        self.result: RequestResult | None = None
        self.coded: bytes | None = None  # write path: batch-encoded object


class Proxy:
    """L-threaded proxy with TOFEC admission control."""

    def __init__(self, store: ObjectStore, policy: Policy, *, L: int = 16,
                 codec: codec_mod.Codec | None = None):
        self.store = store
        self.policy = policy
        self.L = L
        self.codec = codec or codec_mod.get_codec()
        self._task_q: _queue.Queue = _queue.Queue()
        self._request_q: _queue.Queue = _queue.Queue()
        self._idle = L
        # Requests the admit loop has drained but not yet injected: still
        # queued from the policy's point of view (TOFEC's q signal).
        self._admit_backlog = 0
        self._state_lock = threading.Lock()
        self._shutdown = False
        self.results: list[RequestResult] = []
        self._threads = [
            threading.Thread(target=self._worker, daemon=True, name=f"proxy-{i}")
            for i in range(L)
        ]
        self._admitter = threading.Thread(target=self._admit_loop, daemon=True)
        for t in self._threads:
            t.start()
        self._admitter.start()

    # -- public API ---------------------------------------------------------

    def read(self, key: str, layout: SharedKeyLayout, payload_len: int | None = None,
             cls_id: int = 0, timeout: float = 60.0) -> RequestResult:
        req = self._submit("read", key, layout, None, payload_len, cls_id)
        req.done.wait(timeout)
        if req.result is None:
            raise TimeoutError(f"read {key} timed out")
        return req.result

    def write(self, key: str, layout: SharedKeyLayout, payload: bytes,
              cls_id: int = 0, timeout: float = 60.0) -> RequestResult:
        req = self._submit("write", key, layout, payload, len(payload), cls_id)
        req.done.wait(timeout)
        if req.result is None:
            raise TimeoutError(f"write {key} timed out")
        return req.result

    def close(self):
        self._shutdown = True
        self._request_q.put(None)
        for _ in self._threads:
            self._task_q.put(None)

    # -- internals ----------------------------------------------------------

    def _submit(self, op, key, layout, payload, payload_len, cls_id) -> _Request:
        with self._state_lock:
            q_len = self._request_q.qsize() + self._admit_backlog
            idle = self._idle
        n, k = self.policy.select(q=q_len, idle=idle, cls_id=cls_id)
        # Clamp to what the layout supports: k | K, n ≤ N/m.
        k = max(kk for kk in layout.supported_k() if kk <= k)
        n_max, _, _ = layout.code_for_k(k)
        n = max(k, min(n, n_max))
        req = _Request(op, key, layout, payload, payload_len, n, k, cls_id)
        self._request_q.put(req)
        return req

    def _admit_loop(self):
        pending: deque[_Request] = deque()
        while not self._shutdown:
            if not pending:
                req = self._request_q.get()
                if req is None:
                    return
                pending.append(req)
            # Drain everything else that already arrived, then batch-encode
            # all queued writes in one codec call per layout class.
            while True:
                try:
                    req = self._request_q.get_nowait()
                except _queue.Empty:
                    break
                if req is None:
                    return
                pending.append(req)
            with self._state_lock:
                self._admit_backlog = len(pending)
            self._encode_pending_writes(pending)
            req = pending.popleft()
            with self._state_lock:
                self._admit_backlog = len(pending)
            # Paper's admission rule: wait until the task queue is drained
            # and a thread is idle before injecting the next batch.
            while not self._shutdown:
                with self._state_lock:
                    ready = self._idle > 0 and self._task_q.empty()
                if ready:
                    break
                time.sleep(1e-4)
            self._inject(req)

    def _encode_pending_writes(self, pending: "deque[_Request]") -> None:
        """One batched encode per (layout-class) group of queued writes."""
        todo = [r for r in pending if r.op == "write" and r.coded is None]
        groups: dict[SharedKeyLayout, list[_Request]] = {}
        for r in todo:
            groups.setdefault(r.layout, []).append(r)
        for lay, reqs in groups.items():
            coded = lay.encode_files([r.payload for r in reqs], codec=self.codec)
            for r, c in zip(reqs, coded):
                r.coded = c

    def _inject(self, req: _Request):
        if req.op == "read":
            n_max, _, _ = req.layout.code_for_k(req.k)
            # Prefer spread of chunk indices across the object (diversity).
            order = list(np.random.default_rng(hash(req.key) & 0xFFFF).permutation(n_max))
            for ci in order[: req.n]:
                self._task_q.put((req, int(ci), None))
        else:
            coded = req.coded
            if coded is None:  # direct _inject callers outside the admit loop
                coded = req.layout.encode_file(req.payload, codec=self.codec)
            _, _, m = req.layout.code_for_k(req.k)
            for ci in range(req.n):
                off, ln = req.layout.chunk_range(req.k, ci)
                self._task_q.put((req, int(ci), coded[off : off + ln]))

    def _worker(self):
        while True:
            item = self._task_q.get()
            if item is None:
                return
            req, ci, blob = item
            if req.cancelled:
                continue
            with self._state_lock:
                self._idle -= 1
            if req.t_first_start is None:
                req.t_first_start = time.monotonic()
            try:
                if req.op == "read":
                    off, ln = req.layout.chunk_range(req.k, ci)
                    data = self.store.get_range(req.key, off, ln)
                else:
                    self.store.upload_part(req.key, ci, blob)
                    data = blob
                ok = True
            except StorageError:
                ok = False
            finally:
                with self._state_lock:
                    self._idle += 1
            self._on_task_done(req, ci, data if ok else None, ok)

    def _on_task_done(self, req: _Request, ci: int, data, ok: bool):
        with req.lock:
            if req.cancelled:
                return
            if ok:
                req.completed[ci] = data
            else:
                req.failures += 1
            if len(req.completed) >= req.k:
                req.cancelled = True  # preemptive cancellation of the rest
                self._finish(req, True)
            elif req.failures > req.n - req.k:
                req.cancelled = True
                self._finish(req, False)

    def _finish(self, req: _Request, ok: bool):
        data = None
        if ok and req.op == "read":
            data = req.layout.reconstruct(req.k, req.completed, req.payload_len,
                                          codec=self.codec)
        elif ok and req.op == "write":
            # k parts durable → request complete (footnote 1: the rest could
            # continue in background; here they are cancelled).
            pass
        req.result = RequestResult(
            key=req.key,
            op=req.op,
            n=req.n,
            k=req.k,
            ok=ok,
            data=data,
            t_arrival=req.t_arrival,
            t_first_start=req.t_first_start or time.monotonic(),
            t_done=time.monotonic(),
            failures=req.failures,
        )
        self.results.append(req.result)
        req.done.set()


def store_coded_object(store: ObjectStore, key: str, layout: SharedKeyLayout, payload: bytes):
    """Pre-code and store a file for later proxy reads (paper: files are
    pre-coded with the (n_max, k) code and stored on the cloud)."""
    store.put(key, layout.encode_file(payload))
