"""Host span tracing.

Monotonic-clock spans with nested parents (thread-local stack), tagged with
compile-cache bucket keys and mesh shape by the call sites.  Spans are
recorded as Chrome ``trace_event`` complete events ("X", ts/dur in
microseconds) so :func:`write_trace` output loads directly in
``chrome://tracing`` / Perfetto; :func:`aggregate` gives per-span-name
count/total/mean/max tables for quick terminal triage.

When telemetry is disabled (see :mod:`repro.obs.state`) entering a span is
two attribute reads and a truth test — safe to leave on hot paths.  Spans
opened inside a jax trace measure *trace* time, which is exactly what the
retrace accounting wants.
"""
from __future__ import annotations

import functools
import json
import os
import threading
import time
import warnings

from repro.obs.state import enabled


def write_trace_doc(path: str, events: list) -> str:
    """Serialize a Chrome ``trace_event`` list as a loadable trace document.

    The shared writer behind :meth:`Tracer.write_trace` (wallclock spans)
    and :meth:`repro.obs.flight.FlightLog.write_trace` (simulated-clock
    task records): both produce the same ``{"traceEvents": [...]}`` JSON
    envelope Perfetto / ``chrome://tracing`` load directly — only the
    meaning of ``ts`` (monotonic µs vs simulated-seconds × 1e6) differs.
    Returns the path."""
    doc = {"traceEvents": list(events), "displayTimeUnit": "ms"}
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as fh:
        json.dump(doc, fh)
    return path


class Tracer:
    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._events: list = []
        self._tls = threading.local()
        self._epoch = time.monotonic()
        #: Spans entered but not yet exited; write_trace() auto-closes them.
        self._open: dict = {}
        self._warned_incomplete = False

    # ---- recording --------------------------------------------------------
    def _stack(self) -> list:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def span(self, name: str, **tags) -> "_Span":
        """Context manager for one span; tags must be JSON-serializable."""
        return _Span(self, name, tags)

    def traced(self, name: str | None = None, **tags):
        """Decorator form of :meth:`span`."""

        def deco(fn):
            label = name or fn.__qualname__

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                with self.span(label, **tags):
                    return fn(*args, **kwargs)

            return wrapper

        return deco

    def instant(self, name: str, **tags) -> None:
        """Record a zero-duration instant mark (Chrome "i" phase event) —
        used for SLO breach / convergence events so they line up with the
        compile/launch spans on the same timeline.  No-op when disabled."""
        if not enabled():
            return
        ev = {
            "name": name,
            "ph": "i",
            "cat": "repro",
            "s": "t",
            "ts": round((time.monotonic() - self._epoch) * 1e6, 3),
            "pid": os.getpid(),
            "tid": threading.get_ident() % 2**31,
            "args": tags,
        }
        with self._lock:
            self._events.append(ev)

    def _record(self, name, t0, t1, depth, parent, tags) -> None:
        ev = {
            "name": name,
            "ph": "X",
            "cat": "repro",
            "ts": round((t0 - self._epoch) * 1e6, 3),
            "dur": round((t1 - t0) * 1e6, 3),
            "pid": os.getpid(),
            "tid": threading.get_ident() % 2**31,
            "args": {"depth": depth, "parent": parent, **tags},
        }
        with self._lock:
            self._events.append(ev)

    # ---- export -----------------------------------------------------------
    def events(self) -> list:
        with self._lock:
            return list(self._events)

    def aggregate(self) -> dict:
        """Per-span-name {count, total_us, mean_us, max_us}, by total desc.

        Instant marks (:meth:`instant`) carry no duration and are skipped."""
        agg: dict = {}
        for ev in self.events():
            if "dur" not in ev:
                continue
            a = agg.setdefault(ev["name"], {"count": 0, "total_us": 0.0, "max_us": 0.0})
            a["count"] += 1
            a["total_us"] += ev["dur"]
            a["max_us"] = max(a["max_us"], ev["dur"])
        for a in agg.values():
            a["mean_us"] = a["total_us"] / a["count"]
        return dict(sorted(agg.items(), key=lambda kv: -kv[1]["total_us"]))

    def format_table(self) -> str:
        rows = [("span", "count", "total_ms", "mean_us", "max_us")]
        for name, a in self.aggregate().items():
            rows.append(
                (
                    name,
                    str(a["count"]),
                    f"{a['total_us'] / 1e3:.2f}",
                    f"{a['mean_us']:.1f}",
                    f"{a['max_us']:.1f}",
                )
            )
        widths = [max(len(r[i]) for r in rows) for i in range(len(rows[0]))]
        return "\n".join(
            "  ".join(c.ljust(w) for c, w in zip(r, widths)).rstrip() for r in rows
        )

    def _close_incomplete(self) -> None:
        """Auto-close spans still open (entered, never exited) as complete
        events tagged ``incomplete: true``, warning once.  The span's later
        real ``__exit__`` (if any) still pops the thread stack but won't
        record a second event."""
        with self._lock:
            stuck = list(self._open.values())
            self._open.clear()
        if not stuck:
            return
        if not self._warned_incomplete:
            self._warned_incomplete = True
            warnings.warn(
                f"{len(stuck)} span(s) left unclosed at write_trace(); "
                "auto-closing with incomplete=true "
                f"({', '.join(sorted({s.name for s in stuck}))})",
                RuntimeWarning,
                stacklevel=3,
            )
        t1 = time.monotonic()
        for sp in stuck:
            self._record(sp.name, sp._t0, t1, sp._depth, sp._parent,
                         {**sp.tags, "incomplete": True})

    def write_trace(self, path: str) -> str:
        """Write Chrome trace_event JSON; returns the path."""
        self._close_incomplete()
        return write_trace_doc(path, self.events())

    def reset(self) -> None:
        with self._lock:
            self._events.clear()
            self._open.clear()
            self._warned_incomplete = False


class _Span:
    __slots__ = ("_tracer", "name", "tags", "_t0", "_depth", "_parent", "_on")

    def __init__(self, tracer: Tracer, name: str, tags: dict) -> None:
        self._tracer = tracer
        self.name = name
        self.tags = tags

    def __enter__(self) -> "_Span":
        self._on = enabled()
        if not self._on:
            return self
        st = self._tracer._stack()
        self._parent = st[-1] if st else None
        self._depth = len(st)
        st.append(self.name)
        self._t0 = time.monotonic()
        with self._tracer._lock:
            self._tracer._open[id(self)] = self
        return self

    def __exit__(self, *exc) -> bool:
        if self._on:
            t1 = time.monotonic()
            self._tracer._stack().pop()
            with self._tracer._lock:
                live = self._tracer._open.pop(id(self), None) is not None
            if live:  # not already auto-closed by write_trace()
                self._tracer._record(
                    self.name, self._t0, t1, self._depth, self._parent, self.tags
                )
        return False


_TRACER = Tracer()


def get_tracer() -> Tracer:
    return _TRACER


def span(name: str, **tags) -> _Span:
    return _TRACER.span(name, **tags)


def instant(name: str, **tags) -> None:
    return _TRACER.instant(name, **tags)


def traced(name: str | None = None, **tags):
    return _TRACER.traced(name, **tags)


def write_trace(path: str) -> str:
    return _TRACER.write_trace(path)


def aggregate() -> dict:
    return _TRACER.aggregate()


def reset_trace() -> None:
    return _TRACER.reset()
