"""Per-request flight recorder: event-level oracle parity at the pinned
parity grid points, flight-off bit-identity, exemplar-miner determinism
under padding, one-case replay equality with the sweep cell, the serving
FlightRing, and the satellite window/no-data regression guards."""

import json

import numpy as np
import pytest

from repro import obs
from repro.core import PAPER_READ_3MB, RequestClass, StaticPolicy
from repro.core.simulator import simulate
from repro.core.traces import TraceStore
from repro.fleet import PolicySpec, grid_cases
from repro.obs.flight import (
    FLIGHT_SCHEMA,
    KINDS,
    FlightLog,
    FlightRing,
    exemplar_panel,
    oracle_task_rows,
)
from repro.taskq import TaskqSweep, taskq_scan, taskq_streams

CLS = RequestClass("read3mb", 3.0, PAPER_READ_3MB, k_max=6, r_max=2.0, n_max=12)
L = 16
SIZES = tuple(CLS.file_mb / k for k in range(1, CLS.k_max + 1))


def make_pools(correlation: float, seed: int = 3, samples: int = 2048):
    store = TraceStore.generate(
        PAPER_READ_3MB, SIZES, threads=CLS.n_max, samples=samples,
        correlation=correlation, seed=seed,
    )
    return store, store.device_pools(n_max=CLS.n_max)


@pytest.fixture(scope="module")
def small():
    """One static-code grid point swept and flight-replayed once."""
    _, dp = make_pools(correlation=0.14)
    sweep = TaskqSweep(chunk=4)
    case = grid_cases([30.0], [PolicySpec.static(6, 3)], [7], CLS, L)[0]
    res = sweep.run([case], 300, dp)
    log = sweep.replay_flight(res, dp, 0)
    return dp, sweep, case, res, log


def _cfg_row(res, i=0):
    return {name: np.asarray(v[i]) for name, v in res.cfg.items()
            if name != "obs_count"}


# ---------------------------------------------------------------------------
# Event-level oracle parity (the tentpole pin)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "n,k,lam,correlation",
    [
        (1, 1, 8.0, 0.0),
        (6, 3, 30.0, 0.0),
        (12, 6, 20.0, 0.14),
        (4, 2, 45.0, 0.14),
    ],
)
def test_flight_records_match_oracle_event_log(n, k, lam, correlation):
    """Device flight records equal the discrete-event oracle's task event
    log ROW FOR ROW at the same grid points the aggregate parity tests pin:
    identical (req, lane, kind) triples, start/end/depart within float32
    tolerance (NaN-equal for tasks cancelled in queue)."""
    _, dp = make_pools(correlation)
    count = 1200
    case = grid_cases([lam], [PolicySpec.static(n, k)], [7], CLS, L)[0]
    sweep = TaskqSweep(chunk=4)
    res = sweep.run([case], count, dp)
    log = sweep.replay_flight(res, dp, 0)

    inter, idx = taskq_streams(case, count, dp.n_rows)
    arrivals = np.cumsum(inter.astype(np.float64))
    ev: list = []
    simulate(StaticPolicy(n, k), arrivals, dp.host_sampler(CLS.file_mb, idx),
             L=L, warmup_frac=0.0, event_log=ev)

    dev = log.task_rows()
    orc = oracle_task_rows(ev)
    assert len(dev) == count * n and len(orc) == len(dev)
    assert [r[:3] for r in dev] == [r[:3] for r in orc]
    d = np.array([r[3:] for r in dev], np.float64)
    o = np.array([r[3:] for r in orc], np.float64)
    np.testing.assert_allclose(d, o, rtol=1e-3, atol=2e-3)


def test_oracle_event_log_off_by_default():
    """The hook must not change the oracle's default behavior."""
    _, dp = make_pools(correlation=0.0)
    case = grid_cases([10.0], [PolicySpec.static(2, 1)], [1], CLS, L)[0]
    inter, idx = taskq_streams(case, 64, dp.n_rows)
    arrivals = np.cumsum(inter.astype(np.float64))
    sampler = dp.host_sampler(CLS.file_mb, idx)
    a = simulate(StaticPolicy(2, 1), arrivals, sampler, L=L, warmup_frac=0.0)
    ev: list = []
    b = simulate(StaticPolicy(2, 1), arrivals, sampler, L=L, warmup_frac=0.0,
                 event_log=ev)
    np.testing.assert_array_equal(a.totals(), b.totals())
    assert len(ev) == 64 * 2


# ---------------------------------------------------------------------------
# Flight off: bit-identical outputs, untouched sweep compile cache
# ---------------------------------------------------------------------------


def test_flight_off_outputs_bit_identical(small):
    dp, sweep, case, res, _ = small
    cfg = _cfg_row(res)
    inter, idx = taskq_streams(case, 300, dp.n_rows)
    inter = np.asarray(inter, np.float32)
    idx = np.asarray(idx, np.int32)
    kw = dict(L=case.L, q_cap=sweep.q_cap, collect=False)
    off = taskq_scan(cfg, inter, idx, dp.pools, dp.sizes_mb, **kw)
    on = taskq_scan(cfg, inter, idx, dp.pools, dp.sizes_mb, flight=True, **kw)
    assert "flight" not in off and "flight" in on
    for name in ("total", "queueing", "service", "n", "k"):
        np.testing.assert_array_equal(
            np.asarray(off[name]), np.asarray(on[name]))


def test_replay_does_not_touch_sweep_compile_cache(small):
    """The zoom replay runs through ``taskq_scan``'s own jit entry — the
    sweep's pinned compile counters must not move."""
    dp, sweep, _, res, _ = small
    traces = sweep.stats.traces
    sweep.replay_flight(res, dp, 0)
    assert sweep.stats.traces == traces


def test_replay_flight_validates_case_index(small):
    dp, sweep, _, res, _ = small
    with pytest.raises(ValueError):
        sweep.replay_flight(res, dp, 1)
    with pytest.raises(ValueError):
        sweep.replay_flight(res, dp, -1)


# ---------------------------------------------------------------------------
# Replay equality + exemplar determinism
# ---------------------------------------------------------------------------


def test_replay_flight_matches_sweep_cell_delays(small):
    """The one-case replay consumes the stored cfg row and regenerated
    seed streams, so its per-request delays equal the sweep cell's."""
    _, _, _, res, log = small
    out = res.to_numpy()
    np.testing.assert_allclose(log.total, out["total"][0],
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(log.queueing, out["queueing"][0],
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(log.n, out["n"][0])
    np.testing.assert_array_equal(log.k, out["k"][0])


def test_exemplar_miner_deterministic_under_padding(small):
    """Bucket-padded replays of the same case (extra masked arrivals after
    the real horizon) mine exactly the same exemplar anatomies: the scan is
    causal and the miner ranks valid arrivals only."""
    dp, sweep, case, res, _ = small
    cfg = _cfg_row(res)
    inter, idx = taskq_streams(case, 360, dp.n_rows)
    inter = np.asarray(inter, np.float32)
    idx = np.asarray(idx, np.int32)
    kw = dict(L=case.L, q_cap=sweep.q_cap, collect=False, flight=True)
    out = taskq_scan(cfg, inter[:300], idx[:300], dp.pools, dp.sizes_mb, **kw)
    out_pad = taskq_scan(cfg, inter, idx, dp.pools, dp.sizes_mb, **kw)
    plain = FlightLog(out)
    padded = FlightLog(out_pad, valid=np.arange(360) < 300)
    assert len(plain) == len(padded) == 300
    assert plain.exemplars(5) == padded.exemplars(5)
    # Padding never exports: no record or task row past the real horizon.
    assert max(r[0] for r in padded.task_rows()) < 300
    assert max(r["req"] for r in padded.records()) < 300


def test_flight_log_validates_mask_shape(small):
    dp, sweep, case, res, log = small
    out = taskq_scan(_cfg_row(res),
                     *map(np.asarray, taskq_streams(case, 300, dp.n_rows)),
                     dp.pools, dp.sizes_mb, L=case.L, q_cap=sweep.q_cap,
                     collect=False, flight=True)
    with pytest.raises(ValueError, match="valid mask"):
        FlightLog(out, valid=np.ones(7, bool))


# ---------------------------------------------------------------------------
# Exports: NDJSON stream, Perfetto trace, dashboards
# ---------------------------------------------------------------------------


def test_flight_ndjson_records_schema(small, tmp_path):
    _, _, _, _, log = small
    path = log.write_ndjson(str(tmp_path / "flight_records.ndjson"))
    with open(path) as fh:
        recs = [json.loads(line) for line in fh]
    assert len(recs) == len(log.records()) > 0
    for rec in recs:
        assert rec["schema"] == FLIGHT_SCHEMA
        assert rec["kind"] in KINDS
        if rec["kind"] == "cancel_queue":
            assert rec["start"] is None and rec["thread"] == -1
        else:
            assert rec["thread"] >= 0
            assert rec["end"] <= rec["depart"] + 1e-9
        if rec["kind"] == "cancel_service":
            assert rec["end"] == pytest.approx(rec["depart"])
    # Each request carries exactly n lanes and at least k winners' worth
    # of completed work is impossible to lose: >= 1 winner per request.
    by_req: dict = {}
    for rec in recs:
        by_req.setdefault(rec["req"], []).append(rec)
    for req, rows in by_req.items():
        assert len(rows) == rows[0]["n"]
        assert sum(r["kind"] == "won" for r in rows) >= rows[0]["k"]


def test_flight_trace_loads_and_tracks_per_thread(small, tmp_path):
    _, _, _, _, log = small
    path = log.write_trace(str(tmp_path / "flight_trace.json"))
    with open(path) as fh:
        doc = json.load(fh)
    events = doc["traceEvents"]
    assert isinstance(events, list) and all("ph" in e for e in events)
    names = [e for e in events if e["ph"] == "M" and e["name"] == "thread_name"]
    tids = {e["tid"] for e in names}
    # One named track per pool thread that ever held a task + arrivals.
    held = {int(t) for t in np.unique(log.thread) if t >= 0}
    assert tids == held | {999}
    slices = [e for e in events if e["ph"] == "X"]
    assert slices and all(e["dur"] >= 0 and e["tid"] in held for e in slices)
    # Flow arrows pair up and reference winning requests.
    starts = [e for e in events if e["ph"] == "s"]
    finishes = [e for e in events if e["ph"] == "f"]
    assert len(starts) == len(finishes) == len(log)


def test_exemplar_panel_and_dashboards_render(small, tmp_path):
    _, _, _, _, log = small
    ex = log.exemplars(3)
    assert len(ex) == 3
    assert ex[0]["total_s"] >= ex[1]["total_s"] >= ex[2]["total_s"]
    panel = exemplar_panel(ex)
    assert f"req {ex[0]['req']}" in panel and "#" in panel
    assert exemplar_panel([]) == "(no exemplars)"

    text = obs.ascii_dashboard({}, exemplars=ex)
    assert "p99 exemplars" in text and f"req {ex[0]['req']}" in text
    html_path = obs.html_report(str(tmp_path / "dash.html"), {},
                                exemplars=ex)
    html = open(html_path).read()
    assert "p99 exemplars" in html and f"req {ex[0]['req']}" in html
    assert "<svg" in html


def test_slo_report_links_exemplars(small):
    _, _, _, _, log = small
    ex = log.exemplars(2)
    rows = np.zeros((4, obs.DELAY_BINS))
    rows[1, -1] = 8  # every observation far past target: breach
    snap = {"hists": {"delay": rows}, "window": 1,
            "series": {"pick_n": [6.0] * 4, "pick_k": [3.0] * 4}}
    spec = obs.SLOSpec(target_s=0.05, percentile=0.99, window=1)
    report = obs.slo_report(snap, spec, exemplars=ex)
    assert [e["req"] for e in report["exemplars"]] == [e["req"] for e in ex]
    breach = [e for e in report["events"].events if e["kind"] == "slo_breach"]
    assert breach and breach[0]["exemplar_reqs"] == [e["req"] for e in ex]


# ---------------------------------------------------------------------------
# Serving FlightRing
# ---------------------------------------------------------------------------


def test_flight_ring_compacted_clock_and_eviction(tmp_path):
    ring = FlightRing(capacity=2, label="serve")
    ring.record([("admit", 0.1), ("decode", 0.2)],
                requested=4, served=4, code=(8, 4))
    ring.record([("admit", 0.3)], requested=4, served=3, code=(8, 4))
    ring.record([("admit", 0.5)], requested=4, served=4, code=(12, 6))
    assert len(ring) == 2  # oldest round fell off the front
    r1, r2 = ring.rounds()
    assert (r1.round, r2.round) == (1, 2)
    # Compacted simulated clock: rounds butt against each other and the
    # clock keeps counting past evicted rounds.
    assert r1.t0 == pytest.approx(0.3) and r2.t0 == pytest.approx(0.6)
    assert r1.total_s == pytest.approx(0.3)

    recs = ring.records()
    assert all(r["schema"] == FLIGHT_SCHEMA for r in recs)
    assert recs[-1]["code"] == [12, 6] and recs[-1]["phases"] == {"admit": 0.5}

    path = ring.write_trace(str(tmp_path / "serve_flight.json"))
    doc = json.load(open(path))
    slices = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert {e["name"] for e in slices} == {"round1", "round2", "admit"}

    with pytest.raises(ValueError):
        FlightRing(capacity=0)


# ---------------------------------------------------------------------------
# Satellites: window guards + no-data NaN contracts
# ---------------------------------------------------------------------------


def test_taskq_scan_window_zero_raises(small):
    dp, sweep, case, res, _ = small
    inter, idx = taskq_streams(case, 300, dp.n_rows)
    with pytest.raises(ValueError, match="window"):
        taskq_scan(_cfg_row(res), np.asarray(inter, np.float32),
                   np.asarray(idx, np.int32), dp.pools, dp.sizes_mb,
                   L=case.L, q_cap=sweep.q_cap, window=0)


def test_sweep_timeline_window_zero_raises():
    from repro.obs.timeline import sweep_timeline
    with pytest.raises(ValueError, match="window"):
        sweep_timeline({"total": np.zeros(8)}, np.ones(8), window=0)


def test_rolling_percentile_window_zero_raises():
    with pytest.raises(ValueError, match="window"):
        obs.rolling_percentile(np.zeros((4, obs.DELAY_BINS)), 0.99, 0)


def test_hist_percentile_all_zero_is_nan():
    rows = np.zeros((3, obs.DELAY_BINS))
    rows[1, 5] = 4
    p = obs.hist_percentile(rows, 0.99)
    assert np.isnan(p[0]) and np.isfinite(p[1]) and np.isnan(p[2])
    # And the windowed series inherits the gap, never a clamped edge.
    series = obs.rolling_percentile(rows, 0.99, 1)
    assert np.isnan(series[0]) and np.isfinite(series[1])


def test_burn_rate_no_data_is_nan_not_breach():
    spec = obs.SLOSpec(target_s=0.05, percentile=0.99, window=1)
    rows = np.zeros((5, obs.DELAY_BINS))
    rows[1, -1] = 10  # all slow: breach
    burn = obs.burn_rate(rows, spec)
    assert np.isnan(burn[0]) and burn[1] >= 1.0
    assert np.all(np.isnan(burn[2:]))

    snap = {"hists": {"delay": rows}, "window": 1,
            "series": {"pick_n": [1.0] * 5, "pick_k": [1.0] * 5}}
    report = obs.slo_report(snap, spec)
    kinds = [e["kind"] for e in report["events"].events]
    # One breach at slot 1; the trailing no-data windows hold the state —
    # idle stretches are neither a breach nor a recovery.
    assert kinds.count("slo_breach") == 1
    assert kinds.count("slo_recovered") == 0
    assert report["breach_slots"] == 1
    assert np.isfinite(report["max_burn_rate"])
