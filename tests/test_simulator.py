"""Event-simulator validation: against Eq.2/Eq.4 analytics, trace machinery,
and the JAX scan approximation."""

import numpy as np
import pytest

from repro.core import (
    PAPER_READ_3MB,
    GreedyPolicy,
    RequestClass,
    StaticPolicy,
    TofecTables,
    TOFECPolicy,
    build_class_plan,
)
from repro.core import queueing
from repro.core.jax_sim import run_tofec_scan
from repro.core.simulator import piecewise_poisson_arrivals, poisson_arrivals, simulate
from repro.core.traces import StoreSampler, TraceSampler, TraceStore

CLS = RequestClass("read3mb", 3.0, PAPER_READ_3MB, k_max=6, r_max=2.0, n_max=12)
L = 16
SAMPLER = TraceSampler(PAPER_READ_3MB, CLS.file_mb)


def _run(policy, lam, count=6000, seed=1):
    rng = np.random.default_rng(seed)
    arr = poisson_arrivals(rng, lam, count)
    return simulate(policy, arr, SAMPLER, L=L, seed=seed + 1)


def test_static_light_load_matches_eq2():
    """At light load, total ≈ service delay ≈ Eq.2 exact form."""
    for n, k in [(1, 1), (2, 1), (6, 3), (12, 6)]:
        res = _run(StaticPolicy(n, k), lam=1.0, count=3000)
        want = queueing.service_delay_exact(PAPER_READ_3MB, 3.0, k, n)
        got = res.totals().mean()
        assert got == pytest.approx(want, rel=0.08), (n, k, got, want)


def test_static_moderate_load_queueing_positive_and_bounded():
    """At 60% load, simulated total ≈ D_s + D_q(M/M/1) within coarse bounds
    (the paper itself calls Eq.4 'quite coarse')."""
    n, k = 1, 1
    U = queueing.usage(PAPER_READ_3MB, 3.0, k, n / k)
    lam = 0.6 * L / U
    res = _run(StaticPolicy(n, k), lam, count=12000)
    d_s = queueing.service_delay_exact(PAPER_READ_3MB, 3.0, k, n)
    d_q = queueing.queueing_delay(lam, U, L)
    got = res.totals().mean()
    # The paper's Eq.4 treats L threads as one fluid server; the real M/G/L
    # queues less at (1,1), so the sim can sit slightly below d_s + d_q.
    assert d_s * 0.93 < got < d_s + 4 * d_q + 0.05
    assert res.queueing().mean() >= 0


def test_overload_queue_grows():
    """Past capacity the backlog dominates (mean total >> service delay)."""
    U = queueing.usage(PAPER_READ_3MB, 3.0, 3, 2.0)
    lam = 1.4 * L / U
    res = _run(StaticPolicy(6, 3), lam, count=4000)
    d_s = queueing.service_delay_exact(PAPER_READ_3MB, 3.0, 3, 6)
    assert res.totals().mean() > 5 * d_s


def test_more_redundancy_cuts_light_load_delay():
    means = []
    for n in [3, 4, 5, 6]:
        res = _run(StaticPolicy(n, 3), lam=1.0, count=3000)
        means.append(res.totals().mean())
    assert np.all(np.diff(means) < 0)  # Fig.5: extra coded chunks help


def test_tofec_tracks_light_and_heavy(capsys):
    pol = TOFECPolicy.for_classes([CLS], L)
    light = _run(pol, lam=2.0, count=4000)
    assert light.ks().mean() > 4.0  # high chunking at light load
    basic = _run(StaticPolicy(1, 1), lam=2.0, count=4000)
    assert light.totals().mean() < 0.55 * basic.totals().mean()  # ≥ ~2x better

    pol2 = TOFECPolicy.for_classes([CLS], L)
    U11 = queueing.usage(PAPER_READ_3MB, 3.0, 1, 1.0)
    lam_heavy = 0.9 * L / U11
    heavy = _run(pol2, lam_heavy, count=12000)
    assert heavy.ks().mean() < 2.5  # converges toward (1,1)
    # Retains capacity: mean delay stays finite-ish, not runaway backlog.
    assert heavy.totals().mean() < 3.0


def test_greedy_vs_tofec_std(capsys):
    """Fig.9: Greedy's all-or-nothing behavior → higher delay std at mid load."""
    lam = 30.0
    tofec = _run(TOFECPolicy.for_classes([CLS], L), lam, count=9000)
    greedy = _run(GreedyPolicy(CLS.k_max, CLS.r_max), lam, count=9000, seed=7)
    assert greedy.totals().std() > 1.2 * tofec.totals().std()


def test_greedy_composition_bimodal():
    """Fig.8: Greedy round-robins k; k=1 and k=6 dominate at mid load."""
    res = _run(GreedyPolicy(CLS.k_max, CLS.r_max), lam=30.0, count=9000)
    comp = res.k_composition(CLS.k_max)
    assert comp[0] + comp[5] > 0.5


def test_piecewise_arrivals_shape():
    rng = np.random.default_rng(0)
    arr = piecewise_poisson_arrivals(rng, [(200.0, 10.0), (200.0, 70.0), (200.0, 10.0)])
    assert arr[0] > 0 and arr[-1] < 600.0
    mid = np.sum((arr > 200) & (arr < 400))
    assert mid > 10_000  # ~70/s for 200 s
    assert np.all(np.diff(arr) > 0)


def test_trace_store_fit_and_correlation():
    store = TraceStore.generate(
        PAPER_READ_3MB, [0.5, 1.0, 1.5, 3.0], samples=20_000, correlation=0.14, seed=3
    )
    rho = store.cross_correlation(1.0)
    assert 0.08 < rho < 0.25  # paper §III-B(2): 0.11-0.17 for Shared Key
    store_uk = TraceStore.generate(
        PAPER_READ_3MB, [1.0], samples=20_000, correlation=0.0, seed=4
    )
    assert abs(store_uk.cross_correlation(1.0)) < 0.05  # Unique Key


def test_store_sampler_drives_simulation():
    store = TraceStore.generate(PAPER_READ_3MB, [0.5, 0.6, 0.75, 1.0, 1.5, 3.0], samples=5000)
    s = StoreSampler(store, CLS.file_mb)
    rng = np.random.default_rng(0)
    arr = poisson_arrivals(rng, 2.0, 1500)
    res = simulate(StaticPolicy(6, 3), arr, s, L=L)
    want = queueing.service_delay_exact(PAPER_READ_3MB, 3.0, 3, 6)
    assert res.totals().mean() == pytest.approx(want, rel=0.15)


def test_jax_scan_sim_close_to_event_sim():
    plan = build_class_plan(CLS, L)
    tables = TofecTables.from_plan(plan)
    out = run_tofec_scan(CLS, tables, lam=5.0, count=4000, L=L)
    event = _run(TOFECPolicy([plan]), lam=5.0, count=4000)
    # Same operating regime: high chunking, light-load service delay.
    assert out["k"].mean() > 4.0
    assert out["total"].mean() == pytest.approx(event.totals().mean(), rel=0.3)
