"""Fleet sweep demo: a Fig.7-style throughput-delay frontier in ONE launch.

Builds a (λ × policy × seed) grid — TOFEC, basic (1,1), replication (2,1),
the latency-optimal high-chunk static (12,6) and fixed-k(6) — over mixed
workload generators (homogeneous Poisson plus an MMPP bursty variant),
evaluates the whole grid with the vmapped fleet simulator, and renders the
mean-delay-vs-λ frontier as an ASCII plot plus a BENCH_fleet.json artifact.

Run:  PYTHONPATH=src python examples/fleet_sweep_demo.py [--fast]
"""

import argparse
import os
import time

import jax
import numpy as np

from repro.core import PAPER_READ_3MB, RequestClass
from repro.core import queueing
from repro.fleet import (
    FleetSweep,
    MMPPWorkload,
    PolicySpec,
    frontier,
    frontier_points,
    grid_cases,
    write_fleet_artifact,
)

CLS = RequestClass("read3mb", 3.0, PAPER_READ_3MB, k_max=6, r_max=2.0, n_max=12)
L = 16


def ascii_frontier(by, width: int = 64, height: int = 16) -> str:
    """λ on x, mean total delay on y (log-ish via clipping), one glyph per
    policy — the Fig.7 shape without a plotting dependency."""
    glyphs = {}
    pts_all = [p for pts in by.values() for p in pts]
    y_min = min(p.mean for p in pts_all)
    y_max = max(p.mean for p in pts_all)
    x_min = min(p.lam for p in pts_all)
    x_max = max(p.lam for p in pts_all)
    span = np.log(y_max / y_min) + 1e-9
    grid = [[" "] * width for _ in range(height)]
    for name, pts in sorted(by.items()):
        g = name[0] if name[0] not in glyphs.values() else name[-2]
        glyphs[name] = g
        for p in pts:
            x = int((p.lam - x_min) / (x_max - x_min + 1e-9) * (width - 1))
            y = int(np.log(p.mean / y_min) / span * (height - 1))
            grid[height - 1 - y][x] = g
    lines = [f"mean delay, log scale ({y_min:.3f}s .. {y_max:.3f}s)"]
    lines += ["|" + "".join(row) for row in grid]
    lines.append("+" + "-" * width + f"> lambda {x_min:.0f}..{x_max:.0f} req/s")
    lines.append("legend: " + "  ".join(f"{g}={n}" for n, g in sorted(glyphs.items())))
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="smaller grid/horizon")
    args = ap.parse_args()

    cap = queueing.capacity(PAPER_READ_3MB, CLS.file_mb, 1, 1.0, L)
    n_rates = 6 if args.fast else 12
    count = 1500 if args.fast else 4000
    rates = np.linspace(0.08 * cap, 0.92 * cap, n_rates)
    policies = [
        PolicySpec.tofec(),
        PolicySpec.static(1, 1),   # throughput-optimal basic
        PolicySpec.static(2, 1),   # simple replication
        PolicySpec.static(12, 6),  # latency-optimal high-chunk code
        PolicySpec.fixedk(6),
    ]
    # Half the seeds ride a bursty MMPP with the same mean rate — scenario
    # diversity from the same grid (dwell ~8s low / ~2s at 3x).
    cases = grid_cases(rates, policies, [0], CLS, L)
    cases += grid_cases(
        rates, policies, [1], CLS, L,
        workload_for=lambda lam: MMPPWorkload(
            rates=(0.6 * lam, 2.2 * lam), dwell=(8.0, 2.0)),
    )
    print(f"grid: {len(cases)} points ({n_rates} rates x {len(policies)} policies "
          f"x 2 workloads), {count} arrivals each")

    sweep = FleetSweep(chunk=64)
    t0 = time.monotonic()
    res = sweep.run(cases, count)
    jax.block_until_ready(res.out)  # async dispatch: sync before stopping
    dt = time.monotonic() - t0
    print(f"swept {len(cases)} x {count} arrivals in {dt:.2f}s "
          f"({res.launches} launches, {res.compiles} compiles)\n")

    pts = frontier_points(res)
    poisson = [p for p, c in zip(pts, res.cases) if c.workload is None]
    print("=== Poisson frontier (Fig.7) ===")
    print(ascii_frontier(frontier(poisson)))

    path = os.path.join(os.path.dirname(__file__), "..", "benchmarks", "results",
                        "BENCH_fleet.json")
    art = write_fleet_artifact(os.path.normpath(path), res, points=pts,
                               extra={"source": "fleet_sweep_demo"})
    h = art["headline"]
    print("\n=== headline (paper: ~2.5x delay, ~3x capacity) ===")
    print(f"light-load delay gain vs basic (1,1): {h['delay_gain_vs_basic']:.2f}x")
    print(f"capacity gain vs {h['latency_optimal_static']}: "
          f"{h['capacity_gain_vs_latency_optimal']:.2f}x")
    print(f"\nartifact: {os.path.normpath(path)}")


if __name__ == "__main__":
    main()
