"""AdamW, hand-rolled (no optax dependency), FSDP-friendly.

Params live in the model dtype (bf16); first/second moments are fp32 and
shard exactly like their parameters (the spec tree reuses the param logical
axes), giving ZeRO-style optimizer-state sharding for free under pjit.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def init_opt_state(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.int32(0),
    }


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(params, grads, state, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / (1 - cfg.b1 ** step.astype(jnp.float32))
        vh = v / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - cfg.lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {"grad_norm": gnorm}
