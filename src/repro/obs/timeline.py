"""Time-resolved telemetry: fixed-capacity on-device timelines.

:class:`TimelineBuf` is the windowed/ring twin of :class:`repro.obs.metrics.
MetricsBuf`: a registered-dataclass pytree of float32 per-slot series and
int32 per-slot histogram *deltas*, built from plain ``jnp`` ops so it
threads through ``jit`` / ``vmap`` / ``lax.scan`` without host syncs. Two
modes share one type:

* **windowed** (the sweep engines): :func:`sweep_timeline` folds a scan's
  (T,) per-request outputs into S = T/window slots — arrival rate, backlog,
  mean pick (n, k), served count, and a fixed-bucket delay histogram delta
  per window.  The window is ``timeline_window(T_bucket)``, derived from
  the pow2 time bucket so it rides the jit cache key without ever splitting
  a bucket (two runs sharing a time bucket share a window — and a trace).
* **ring** (the serving loop): :meth:`TimelineBuf.append` writes one slot
  per round at ``pos % capacity``, overwriting the oldest round once the
  ring wraps; :meth:`TimelineBuf.snapshot` restores oldest-first order.

Delay histograms use fixed log-spaced buckets (:data:`DELAY_BINS` bins,
:data:`DELAY_SUB` per octave from 2**:data:`DELAY_MIN_EXP` seconds, ~9%
width), so windowed percentiles are recoverable from the deltas at bucket
resolution (:func:`hist_percentile` / :func:`rolling_percentile`) — the
windowed-tail observable the SLO monitor (:mod:`repro.obs.slo`) consumes.

Chunk folds differ from MetricsBuf deliberately: timelines stay PER CASE,
so :meth:`reduce_rows` only cuts the tail padding and chunks concatenate
(:meth:`concat`) along the case axis instead of summing.  Per-case slots
are leading-batch invariant, which is what keeps streamed and mesh-sharded
timelines bit-exact against the materialized single-device path.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

#: Slot budget for sweep timelines: a pow2 time bucket T yields
#: T / timeline_window(T) <= TIMELINE_SLOTS windows.
TIMELINE_SLOTS = 64

#: Fixed log-spaced delay buckets: DELAY_SUB buckets per octave starting at
#: 2**DELAY_MIN_EXP seconds; the first/last buckets absorb the clipped
#: tails. 96 bins cover ~15.6 ms .. ~59 s at ~9% resolution.
DELAY_BINS = 96
DELAY_SUB = 8
DELAY_MIN_EXP = -6


def timeline_window(t_bucket: int) -> int:
    """Window size (arrivals per slot) for a pow2 time bucket.

    Derived deterministically from the bucket, so appending it to a sweep's
    jit cache key is explicit without ever creating a new compilation."""
    return max(int(t_bucket) // TIMELINE_SLOTS, 1)


def delay_bucket(value):
    """Traceable value -> bucket index under the fixed log-spaced buckets."""
    v = jnp.maximum(jnp.asarray(value, jnp.float32), 2.0 ** DELAY_MIN_EXP)
    idx = jnp.floor(jnp.log2(v) * DELAY_SUB).astype(jnp.int32)
    return jnp.clip(idx - DELAY_MIN_EXP * DELAY_SUB, 0, DELAY_BINS - 1)


def bucket_edges() -> np.ndarray:
    """(DELAY_BINS,) upper edges in seconds; bucket i spans (E[i-1], E[i]]."""
    i = np.arange(DELAY_BINS, dtype=np.float64)
    return 2.0 ** (DELAY_MIN_EXP + (i + 1) / DELAY_SUB)


def hist_percentile(hist, p: float) -> np.ndarray:
    """Recover a percentile from bucket counts (host side).

    ``hist``: (..., DELAY_BINS) counts.  Returns the upper edge of the
    bucket holding the p-quantile observation (<= ~9% conservative).  An
    all-zero row (a window that saw no observations) is explicitly NaN —
    never a clamped bucket edge — so downstream consumers
    (:func:`rolling_percentile` series, the SLO burn rate, the dashboards'
    gap-aware sparklines/polylines) can tell "no data" from "fast"."""
    h = np.asarray(hist, np.float64)
    tot = h.sum(axis=-1)
    cum = h.cumsum(axis=-1)
    target = p * tot
    idx = np.minimum((cum < target[..., None]).sum(axis=-1), DELAY_BINS - 1)
    out = bucket_edges()[idx]
    return np.where(tot > 0, out, np.nan)


def rolling_percentile(hist_rows, p: float, window: int) -> np.ndarray:
    """Percentile series over a trailing window of histogram delta rows.

    ``hist_rows``: (S, DELAY_BINS) per-slot deltas; row i's value is the
    p-quantile of slots max(0, i-window+1)..i combined — the windowed-tail
    series the SLO burn rate is judged on.  Windows whose combined rows are
    all zero report NaN (inherited from :func:`hist_percentile`)."""
    if int(window) < 1:
        raise ValueError(f"window must be >= 1, got {window}")
    h = np.asarray(hist_rows, np.float64)
    c = h.cumsum(axis=0)
    lo = np.concatenate([np.zeros_like(c[:window]), c[:-window]], axis=0) \
        if window < len(c) else np.zeros_like(c)
    return hist_percentile(c - lo, p)


def _map(d: dict, fn) -> dict:
    return {name: fn(v) for name, v in d.items()}


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TimelineBuf:
    """Per-slot series + histogram deltas as device arrays.

    pos:    () int32 slots appended (ring mode; ``capacity`` in windowed
            mode).  Gains leading axes under vmap / :meth:`concat`.
    series: name -> (S,) float32 per-slot values
    hists:  name -> (S, B) int32 per-slot histogram deltas
    ``capacity`` (S) and ``window`` (samples per slot; 1 = per-round ring)
    are static pytree fields — part of the tracing structure, like the
    metric names."""

    pos: jax.Array
    series: dict
    hists: dict
    capacity: int = dataclasses.field(metadata=dict(static=True))
    window: int = dataclasses.field(metadata=dict(static=True))

    @classmethod
    def zeros(cls, capacity: int, series=(), hists=None,
              window: int = 1) -> "TimelineBuf":
        return cls(
            pos=jnp.int32(0),
            series={n: jnp.zeros((int(capacity),), jnp.float32) for n in series},
            hists={n: jnp.zeros((int(capacity), int(b)), jnp.int32)
                   for n, b in dict(hists or {}).items()},
            capacity=int(capacity),
            window=int(window),
        )

    # ---- in-trace updates -------------------------------------------------
    def append(self, values: dict, hist_obs: dict | None = None) -> "TimelineBuf":
        """Write one slot at ``pos % capacity`` (ring semantics).

        ``values``: name -> scalar for the series slots.  ``hist_obs``:
        name -> (bucket_idx, weight) vectors scattered into that slot's
        delta row (pass a 0/1 weight mask to drop padded entries)."""
        i = jnp.mod(self.pos, self.capacity)
        series = dict(self.series)
        for name, v in values.items():
            series[name] = series[name].at[i].set(jnp.asarray(v, jnp.float32))
        hists = dict(self.hists)
        for name, (idx, w) in (hist_obs or {}).items():
            bins = hists[name].shape[-1]
            row = jnp.zeros((bins,), jnp.int32).at[
                jnp.clip(jnp.asarray(idx, jnp.int32), 0, bins - 1)
            ].add(jnp.asarray(w, jnp.int32))
            hists[name] = hists[name].at[i].set(row)
        return dataclasses.replace(self, pos=self.pos + 1, series=series,
                                   hists=hists)

    # ---- folds ------------------------------------------------------------
    def reduce_rows(self, rows: int | None = None) -> "TimelineBuf":
        """Cut the tail padding a chunk launch adds by repeating its last
        real row.  Unlike MetricsBuf this does NOT reduce across cases —
        timelines stay per case; chunks then :meth:`concat`."""

        def cut(a):
            return a[:rows] if rows is not None else a

        return dataclasses.replace(
            self, pos=cut(self.pos), series=_map(self.series, cut),
            hists=_map(self.hists, cut),
        )

    def concat(self, other: "TimelineBuf") -> "TimelineBuf":
        """Stack two per-case timelines along the leading case axis."""
        if (self.capacity, self.window) != (other.capacity, other.window):
            raise ValueError(
                f"cannot concat timelines with different slotting: "
                f"{(self.capacity, self.window)} vs "
                f"{(other.capacity, other.window)}"
            )
        cat = lambda a, b: jnp.concatenate([a, b], axis=0)
        return dataclasses.replace(
            self,
            pos=cat(jnp.atleast_1d(self.pos), jnp.atleast_1d(other.pos)),
            series={n: cat(v, other.series[n]) for n, v in self.series.items()},
            hists={n: cat(v, other.hists[n]) for n, v in self.hists.items()},
        )

    # ---- export -----------------------------------------------------------
    def snapshot(self) -> dict:
        """The one host sync: device arrays -> numpy, ring order restored.

        Ring mode (scalar ``pos``): slots come back oldest-first and cut to
        the appended count.  Windowed/stacked mode (vmapped ``pos``): the
        per-case arrays pass through as-is."""
        pos = np.asarray(self.pos)
        series = {n: np.asarray(v) for n, v in self.series.items()}
        hists = {n: np.asarray(v) for n, v in self.hists.items()}
        if pos.ndim == 0:
            m = int(pos)
            if m <= self.capacity:
                order = np.arange(m)
            else:  # wrapped: oldest slot sits at pos % capacity
                order = (np.arange(self.capacity) + m) % self.capacity
            series = {n: v[order] for n, v in series.items()}
            hists = {n: v[order] for n, v in hists.items()}
            slots = len(order)
        else:
            slots = self.capacity
        return {
            "window": self.window,
            "capacity": self.capacity,
            "slots": slots,
            "pos": pos.tolist(),
            "series": series,
            "hists": hists,
        }


def sweep_timeline(out: dict, interarrivals, *, window: int, valid=None,
                   backlog=None) -> TimelineBuf:
    """Windowed timeline from a scan-core output dict, inside the vmapped
    ``one`` — traced alongside the primary outputs; the launcher cuts the
    tail padding and concatenates per chunk.

    Per window of ``window`` arrivals: ``lam`` (valid arrivals / elapsed
    seconds), ``served`` (valid count), mean ``pick_n``/``pick_k``, the
    optional ``backlog`` series mean, and a ``delay`` histogram delta of
    the total delays under the fixed log buckets.  ``valid`` is the (T,)
    real-arrival mask (bucket padding must not count); all reductions are
    per-slot and leading-batch invariant, so streamed / sharded runs carry
    the identical timeline."""
    if int(window) < 1:
        raise ValueError(f"window must be >= 1, got {window}")
    total = out["total"]
    T = total.shape[-1]
    if T % window:
        raise ValueError(f"horizon {T} not divisible by window {window}")
    S = T // window
    mask = jnp.ones(T, bool) if valid is None else valid
    w = mask.astype(jnp.float32)
    wi = mask.astype(jnp.int32)
    cnt = w.reshape(S, window).sum(axis=1)
    denom = jnp.maximum(cnt, 1.0)

    def wmean(x):
        return (jnp.asarray(x, jnp.float32) * w).reshape(S, window).sum(axis=1) / denom

    span = (jnp.asarray(interarrivals, jnp.float32) * w).reshape(S, window).sum(axis=1)
    lam = jnp.where(span > 0, cnt / jnp.maximum(span, 1e-12), 0.0)
    series = {
        "lam": lam,
        "served": cnt,
        "pick_n": wmean(out["n"]),
        "pick_k": wmean(out["k"]),
    }
    if backlog is not None:
        series["backlog"] = wmean(backlog)
    win_idx = jnp.arange(T) // window
    hist = jnp.zeros((S, DELAY_BINS), jnp.int32).at[
        win_idx, delay_bucket(total)
    ].add(wi)
    return TimelineBuf(pos=jnp.int32(S), series=series,
                       hists={"delay": hist}, capacity=S, window=window)
