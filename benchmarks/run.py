"""Benchmark entrypoint: ``PYTHONPATH=src python -m benchmarks.run``.

One function per paper table/figure (Fig.1, 4-10) plus kernel micro-
benchmarks. Prints ``name,us_per_call,derived`` CSV lines; per-figure data
artifacts land in benchmarks/results/*.csv. The dry-run/roofline tables are
separate (python -m repro.launch.dryrun; see EXPERIMENTS.md).
"""

from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="substring filter on bench names")
    ap.add_argument("--fast", action="store_true", help="reduced request counts")
    args = ap.parse_args()

    from benchmarks import kernel_bench, paper_figures

    benches = list(paper_figures.ALL_FIGS) + list(kernel_bench.ALL_KERNEL)
    print("name,us_per_call,derived")
    failures = 0
    for fn in benches:
        if args.only and args.only not in fn.__name__:
            continue
        try:
            kwargs = {}
            if args.fast and "count" in fn.__code__.co_varnames:
                kwargs["count"] = 1200
            for line in fn(**kwargs):
                print(line)
                sys.stdout.flush()
        except Exception as e:
            failures += 1
            print(f"{fn.__name__},ERROR,{type(e).__name__}:{e}")
            traceback.print_exc()
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
