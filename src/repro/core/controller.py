"""Code-selection policies (§IV-C, §V-A).

Every policy answers one question at request-arrival time: which (n, k) MDS
code serves this request. Inputs available to a policy (mirroring what the
paper's proxy can observe locally): the instantaneous request-queue length
``q`` and the number of idle threads ``idle``.

Policies:
  * StaticPolicy(n, k)           — the paper's static strategies (incl. basic
                                   (1,1) and simple replication (2,1)).
  * TOFECPolicy                  — the paper's adaptive algorithm: EWMA of q
                                   against the H^N / H^K threshold tables.
  * GreedyPolicy                 — §V-A heuristic from idle-thread count.
  * FixedKAdaptivePolicy         — the strategy of [3]: k fixed, n adapted
                                   (backlog-driven via the same machinery).

A jit-friendly functional form of the TOFEC update is provided in
:func:`tofec_step_jax` so the serving engine can run the controller inside a
compiled step.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.delay_model import RequestClass
from repro.core.static_optimizer import ClassPlan, build_class_plan


class Policy:
    """Interface: observe arrival, emit (n, k)."""

    name: str = "policy"

    def select(self, *, q: int, idle: int, cls_id: int = 0, now: float | None = None) -> tuple[int, int]:
        raise NotImplementedError

    def reset(self) -> None:  # pragma: no cover - default no state
        pass


@dataclasses.dataclass
class StaticPolicy(Policy):
    n: int
    k: int

    def __post_init__(self):
        if self.n < self.k or self.k < 1:
            raise ValueError(f"invalid static code ({self.n},{self.k})")
        self.name = f"static({self.n},{self.k})"

    def select(self, *, q: int, idle: int, cls_id: int = 0, now: float | None = None) -> tuple[int, int]:
        return self.n, self.k


class TOFECPolicy(Policy):
    """The paper's algorithm (§IV-C pseudocode), per-class thresholds.

    q̄ ← αq + (1−α)q̄ on each arrival; k and n from threshold lookup;
    n ← min(r_max·k, n); guard n ≥ k.
    """

    def __init__(self, plans: list[ClassPlan], alpha: float = 0.99):
        if not 0.0 < alpha <= 1.0:
            raise ValueError("memory factor must be in (0, 1]")
        self.plans = plans
        self.alpha = alpha
        self.name = f"tofec(alpha={alpha})"
        self.reset()

    @classmethod
    def for_classes(
        cls, classes: list[RequestClass], L: int, alpha: float = 0.99, eq7_factor: float = 2.0
    ) -> "TOFECPolicy":
        return cls([build_class_plan(c, L, eq7_factor=eq7_factor) for c in classes], alpha)

    def reset(self) -> None:
        self.q_ewma = 0.0

    def select(self, *, q: int, idle: int, cls_id: int = 0, now: float | None = None) -> tuple[int, int]:
        self.q_ewma = self.alpha * q + (1.0 - self.alpha) * self.q_ewma
        return self.plans[cls_id].pick_code(self.q_ewma)


@dataclasses.dataclass
class GreedyPolicy(Policy):
    """§V-A Greedy: chunk as much as idle threads allow, then add redundancy.

    Paper's printed formula sets n = min(k_max, l) which would force n = k;
    the prose ("then increase the redundancy ratio as long as there are idle
    threads remain") implies n = min(r_max·k, l). We implement the prose and
    note the discrepancy.
    """

    k_max: int
    r_max: float

    def __post_init__(self):
        self.name = "greedy"

    def select(self, *, q: int, idle: int, cls_id: int = 0, now: float | None = None) -> tuple[int, int]:
        if idle <= 0:
            return 1, 1
        k = min(self.k_max, idle)
        n = min(int(self.r_max * k), max(idle, 1))
        return max(n, k), k


class FixedKAdaptivePolicy(Policy):
    """The adaptive strategy of [3]: fixed code dimension k, n adapted to
    backlog. Uses the Eq.7-analogue at fixed k: r(r−1) =
    f·L(Ψ̄k + Ψ̃J) / (k(Δ̄k + Δ̃J)((L/(L−λ̄))² − 1)), n = k·r, thresholded
    the same way as TOFEC.
    """

    def __init__(
        self,
        cls_: RequestClass,
        L: int,
        k: int,
        alpha: float = 0.99,
        eq7_factor: float = 2.0,
    ):
        self.cls = cls_
        self.k = k
        self.alpha = alpha
        self.name = f"fixedk(k={k})"
        p, J = cls_.params, cls_.file_mb
        c = (
            eq7_factor
            * L
            * (p.psi_bar * k + p.psi_tilde * J)
            / (k * (p.delta_bar * k + p.delta_tilde * J))
        )

        # Q at which n is optimal (n = k..n_max): from r = n/k,
        # (L/(L−λ̄))² − 1 = c / (r(r−1)) → λ̄ → Q.
        def q_for_n(n: int) -> float:
            r = n / k
            if r <= 1.0:
                return math.inf  # n = k only optimal at overload (Q → ∞)
            pi = c / (r * (r - 1.0))
            lam_bar = L * (1.0 - 1.0 / math.sqrt(1.0 + pi))
            return lam_bar**2 / (L * (L - lam_bar))

        n_values = list(range(k, cls_.n_max + 1))
        q_tab = np.array([q_for_n(n) for n in n_values])
        h = np.empty(len(n_values) + 1)
        h[0] = math.inf
        for j in range(1, len(n_values)):
            h[j] = 0.5 * (q_tab[j] + q_tab[j - 1])
        h[-1] = 0.0
        self.n_values = n_values
        self.h_n = h
        self.reset()

    def reset(self) -> None:
        self.q_ewma = 0.0

    def select(self, *, q: int, idle: int, cls_id: int = 0, now: float | None = None) -> tuple[int, int]:
        self.q_ewma = self.alpha * q + (1.0 - self.alpha) * self.q_ewma
        j = int(np.searchsorted(-self.h_n[1:], -self.q_ewma, side="left"))
        n = self.n_values[min(j, len(self.n_values) - 1)]
        return n, self.k


# ---------------------------------------------------------------------------
# JAX functional form (used inside jitted serving steps)
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TofecTables:
    """Static threshold tables as device arrays (one class)."""

    h_k: jax.Array  # (k_max + 1,) descending, h_k[0] = +inf
    h_n: jax.Array  # (n_max + 1,)
    r_max: float = dataclasses.field(metadata=dict(static=True))

    @classmethod
    def from_plan(cls, plan: ClassPlan) -> "TofecTables":
        big = jnp.float32(jnp.finfo(jnp.float32).max)
        h_k = jnp.asarray(plan.h_k, jnp.float32)
        h_n = jnp.asarray(plan.h_n, jnp.float32)
        h_k = jnp.where(jnp.isinf(h_k), big, h_k)
        h_n = jnp.where(jnp.isinf(h_n), big, h_n)
        return cls(h_k=h_k, h_n=h_n, r_max=plan.cls.r_max)


def tofec_threshold_step(
    q_ewma: jax.Array,
    q: jax.Array,
    h_k: jax.Array,
    h_n: jax.Array,
    r_max,
    alpha,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Table-free form of the TOFEC update: every argument may be a tracer.

    Unlike :func:`tofec_step_jax` this takes the threshold tables and the
    redundancy cap as plain (possibly traced) arrays, so the fleet sweep can
    ``vmap`` it across a stacked policy axis where ``r_max`` varies per grid
    point. Trailing zero entries in ``h_k``/``h_n`` are inert (0 > q̄ never
    holds for q̄ ≥ 0), which is what makes cross-class table padding safe.
    """
    q_new = alpha * q + (1.0 - alpha) * q_ewma
    k = 1 + jnp.sum(h_k[1:] > q_new).astype(jnp.int32)
    n = 1 + jnp.sum(h_n[1:] > q_new).astype(jnp.int32)
    n = jnp.minimum((r_max * k).astype(jnp.int32), n)
    n = jnp.maximum(n, k)
    return q_new, n, k


def tofec_step_jax(
    q_ewma: jax.Array, q: jax.Array, tables: TofecTables, alpha: float
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One arrival update, fully traceable: returns (q̄', n, k).

    Same semantics as :class:`TOFECPolicy.select` (threshold search =
    1 + #{h > q̄} over the descending tables).
    """
    return tofec_threshold_step(q_ewma, q, tables.h_k, tables.h_n, tables.r_max, alpha)


class MPCPolicy(Policy):
    """Beyond-paper controller: discrete model-predictive code selection.

    Instead of inverting the continuous relaxation into thresholds (§IV-C),
    estimate the arrival rate online (interarrival EWMA) and pick the
    discrete (n, k) minimizing the paper's own cost model

        D̂(n, k) = D_q^{M/M/1}(λ̂, U(n, k)) + D_s^{exact}(n, k)

    over the feasible code set, rejecting codes with λ̂·U ≥ util_cap·L.
    Falls back to max chunking until a rate estimate exists. Motivation and
    measured gains vs the threshold controller: EXPERIMENTS.md §Perf
    (controller hillclimb).
    """

    def __init__(
        self,
        cls_: RequestClass,
        L: int,
        *,
        alpha_rate: float = 0.05,
        util_cap: float = 0.9,
        q_guard: float = 4.0,
    ):
        from repro.core import queueing as _q

        self.cls = cls_
        self.L = L
        self.alpha_rate = alpha_rate
        self.util_cap = util_cap
        self.q_guard = q_guard
        self.name = "mpc"
        p, J = cls_.params, cls_.file_mb
        self.codes = []
        for k in range(1, cls_.k_max + 1):
            for n in range(k, min(int(cls_.r_max * k), cls_.n_max) + 1):
                u = _q.usage(p, J, k, n / k)
                ds = _q.service_delay_exact(p, J, k, n)
                self.codes.append((n, k, u, ds))
        self.reset()

    def reset(self) -> None:
        self.mean_ia = None
        self.last_arrival = None
        self.q_ewma = 0.0

    def select(self, *, q: int, idle: int, cls_id: int = 0, now: float | None = None) -> tuple[int, int]:
        self.q_ewma = 0.1 * q + 0.9 * self.q_ewma
        if now is not None:
            if self.last_arrival is not None:
                ia = max(now - self.last_arrival, 1e-9)
                self.mean_ia = (
                    ia if self.mean_ia is None
                    else (1 - self.alpha_rate) * self.mean_ia + self.alpha_rate * ia
                )
            self.last_arrival = now
        if self.mean_ia is None:
            best = max(self.codes, key=lambda c: (c[1], c[0]))
            return best[0], best[1]
        lam = 1.0 / self.mean_ia
        best, best_cost = (1, 1), float("inf")
        for n, k, u, ds in self.codes:
            lam_bar = lam * u
            if lam_bar >= self.util_cap * self.L:
                continue
            dq = lam_bar * u / (self.L * (self.L - lam_bar))
            # backlog guard: sustained queue penalizes expensive codes.
            dq *= 1.0 + self.q_ewma / self.q_guard
            cost = dq + ds
            if cost < best_cost:
                best_cost, best = cost, (n, k)
        return best
