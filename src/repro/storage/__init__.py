from repro.storage.backend import (
    FaultyStore,
    FileStore,
    LatencyStore,
    MemoryStore,
    ObjectStore,
    StorageError,
)
from repro.storage.proxy import Proxy, RequestResult, store_coded_object

__all__ = [
    "ObjectStore",
    "MemoryStore",
    "FileStore",
    "LatencyStore",
    "FaultyStore",
    "StorageError",
    "Proxy",
    "RequestResult",
    "store_coded_object",
]
