"""Theorem-1 solver (§IV-B): optimal static codes and their Q-mapping.

Eq.6 (per class, workload independent — links k and r along the optimal
curve):

    k(Ψ̄k + Ψ̃J) / (Δ̄k + Δ̃J)
        = J·r(r−1)·(Δ̃ + Ψ̃·ln(r/(r−1))) / (Δ̄r + Ψ̄)

Eq.7 (workload coupling; the paper's printed form):

    (L/(L−λ̄))² − 1 = 2L(Ψ̄k + Ψ̃J) / (k·r(r−1)·(Δ̄k + Δ̃J))

NOTE on the factor 2: differentiating D_q = λŪ²/(L(L−λŪ)) by hand gives a
factor L (not 2L) on the right-hand side. We default to the paper's printed
2L (``eq7_factor=2.0``) for faithfulness; the factor only shifts the
Q ↔ (k, r) calibration slightly and preserves every monotonicity property
(Corollary 1) either way. ``eq7_factor=1.0`` selects our derivation.

From these we build, per class:
  * r_opt(k): bisection on the strictly-increasing RHS of Eq.6,
  * λ̄(k), Q(k) via Eq.7 + Eq.5,
  * the inverses K(Q), R(Q), N(Q) (Corollary 1: strictly decreasing), and
  * the threshold tables H^N, H^K of §IV-C.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core import queueing
from repro.core.delay_model import DelayParams, RequestClass


def _eq6_lhs(p: DelayParams, J: float, k: float) -> float:
    return k * (p.psi_bar * k + p.psi_tilde * J) / (p.delta_bar * k + p.delta_tilde * J)


def _eq6_rhs(p: DelayParams, J: float, r: float) -> float:
    if r <= 1.0:
        return 0.0
    lg = math.log(r / (r - 1.0))
    return (
        J
        * r
        * (r - 1.0)
        * (p.delta_tilde + p.psi_tilde * lg)
        / (p.delta_bar * r + p.psi_bar)
    )


def solve_r_for_k(p: DelayParams, J: float, k: float, *, r_hi: float = 1e6) -> float:
    """Solve Eq.6 for r given (continuous) k > 0. RHS is strictly increasing
    in r on (1, ∞), from 0 to ∞, so bisection is exact."""
    target = _eq6_lhs(p, J, k)
    lo, hi = 1.0 + 1e-12, 2.0
    while _eq6_rhs(p, J, hi) < target:
        hi *= 2.0
        if hi > r_hi:
            return r_hi
    for _ in range(200):
        mid = 0.5 * (lo + hi)
        if _eq6_rhs(p, J, mid) < target:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)


def _eq7_rhs(p: DelayParams, J: float, k: float, r: float, L: int, factor: float) -> float:
    """π_i(k) with r = r_opt(k) substituted (paper appendix): RHS of Eq.7."""
    return (
        factor
        * L
        * (p.psi_bar * k + p.psi_tilde * J)
        / (k * r * (r - 1.0) * (p.delta_bar * k + p.delta_tilde * J))
    )


def lambda_bar_for_k(
    p: DelayParams, J: float, k: float, L: int, *, eq7_factor: float = 2.0
) -> float:
    """Close Eq.7 for λ̄ given k (and r = r_opt(k)):

    (L/(L−λ̄))² = 1 + π(k)  ⇒  λ̄ = L(1 − 1/√(1 + π(k))).
    """
    r = solve_r_for_k(p, J, k)
    pi = _eq7_rhs(p, J, k, r, L, eq7_factor)
    return L * (1.0 - 1.0 / math.sqrt(1.0 + pi))


def q_for_k(p: DelayParams, J: float, k: float, L: int, *, eq7_factor: float = 2.0) -> float:
    """Q at which (continuous) dimension k is optimal: Eq.5 at λ̄(k)."""
    lam_bar = lambda_bar_for_k(p, J, k, L, eq7_factor=eq7_factor)
    if lam_bar >= L:
        return math.inf
    return lam_bar**2 / (L * (L - lam_bar))


def _bisect_decreasing(fn, target: float, lo: float, hi: float, iters: int = 200) -> float:
    """Find x with fn(x) = target for strictly decreasing fn on [lo, hi]."""
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        if fn(mid) > target:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)


@dataclasses.dataclass
class ClassPlan:
    """Per-class solution tables: Q-grid ↔ (k, r, n) plus §IV-C thresholds."""

    cls: RequestClass
    L: int
    eq7_factor: float
    # Descending-Q tables, indexed by integer code parameter value:
    q_at_k: np.ndarray  # q_at_k[k-1] = K^{-1}(k) = Q at which dim k optimal
    q_at_n: np.ndarray  # q_at_n[n-1] = N^{-1}(n)
    h_k: np.ndarray  # thresholds H^K[1..k_max+1]; h_k[0] = inf, h_k[k_max] = 0
    h_n: np.ndarray  # thresholds H^N[1..n_max+1]

    def pick_k(self, q_ewma: float) -> int:
        """k s.t. q̄ ∈ [H_{k+1}, H_k), i.e. 1 + #{thresholds > q̄}."""
        k = int(np.searchsorted(-self.h_k[1:], -q_ewma, side="left")) + 1
        return min(k, self.cls.k_max)

    def pick_n(self, q_ewma: float) -> int:
        n = int(np.searchsorted(-self.h_n[1:], -q_ewma, side="left")) + 1
        return min(n, self.cls.n_max)

    def pick_code(self, q_ewma: float) -> tuple[int, int]:
        """TOFEC steps 4-6: (n, k) with the r_max cap applied."""
        k = self.pick_k(q_ewma)
        n = self.pick_n(q_ewma)
        n = min(int(self.cls.r_max * k), n)
        return max(n, k), k


def build_class_plan(
    cls: RequestClass, L: int, *, eq7_factor: float = 2.0
) -> ClassPlan:
    """Compute Q^K, Q^N and the threshold tables of §IV-C for one class."""
    p, J = cls.params, cls.file_mb

    q_at_k = np.array(
        [q_for_k(p, J, float(k), L, eq7_factor=eq7_factor) for k in range(1, cls.k_max + 1)]
    )

    # N(Q): n(k) = k · r_opt(k) is strictly increasing in k, so invert by
    # bisection on k for each integer n, then map through Q(k).
    def n_of_k(k: float) -> float:
        return k * solve_r_for_k(p, J, k)

    q_at_n = np.empty(cls.n_max)
    for n in range(1, cls.n_max + 1):
        if n_of_k(1e-9) >= n:  # n below the n(k) range: treat as k→0 (Q→∞)
            q_at_n[n - 1] = math.inf
            continue
        hi = float(max(cls.k_max * 4, 8))
        while n_of_k(hi) < n:
            hi *= 2.0
        k_sol = _bisect_decreasing(lambda k: -n_of_k(k), -float(n), 1e-9, hi)
        q_at_n[n - 1] = q_for_k(p, J, k_sol, L, eq7_factor=eq7_factor)

    def thresholds(q_tab: np.ndarray) -> np.ndarray:
        """H[0]=∞ (i.e. H_1), H[j] = (Q_{j+1} + Q_j)/2, last = 0 (§IV-C)."""
        m = len(q_tab)
        h = np.empty(m + 1)
        h[0] = math.inf
        for j in range(1, m):
            h[j] = 0.5 * (q_tab[j] + q_tab[j - 1])
        h[m] = 0.0
        return h

    return ClassPlan(
        cls=cls,
        L=L,
        eq7_factor=eq7_factor,
        q_at_k=q_at_k,
        q_at_n=q_at_n,
        h_k=thresholds(q_at_k),
        h_n=thresholds(q_at_n),
    )


def optimal_static_code(
    cls: RequestClass, L: int, lam: float, *, eq7_factor: float = 2.0
) -> tuple[float, float, float]:
    """Solve (*) for a single class at arrival rate λ: returns (k*, r*, Q*).

    Uses the fixed-point structure: Q ↦ (k, r) via Eq.6/7, then Eq.5
    consistency g(Q) = Q_implied − Q is strictly decreasing → bisection.
    """
    p, J = cls.params, cls.file_mb

    def k_for_q(Q: float) -> float:
        # q_for_k is strictly decreasing in k (Corollary 1).
        lo, hi = 1e-9, 1.0
        while q_for_k(p, J, hi, L, eq7_factor=eq7_factor) > Q and hi < 1e6:
            hi *= 2.0
        return _bisect_decreasing(
            lambda k: q_for_k(p, J, k, L, eq7_factor=eq7_factor), Q, lo, hi
        )

    def implied_q(Q: float) -> float:
        k = k_for_q(Q)
        r = solve_r_for_k(p, J, k)
        U = queueing.usage(p, J, k, r)
        return queueing.queue_length(lam, U, L)

    lo, hi = 1e-9, 1.0
    while implied_q(hi) > hi:
        hi *= 2.0
        if hi > 1e9:
            break
    Q = _bisect_decreasing(lambda q: implied_q(q) - q, 0.0, lo, hi)
    k = k_for_q(Q)
    r = solve_r_for_k(p, J, k)
    return k, r, Q
