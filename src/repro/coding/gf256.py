"""GF(2^8) arithmetic.

Field: GF(2)[x] / (x^8 + x^4 + x^3 + x^2 + 1)  (0x11d, the Rijndael-adjacent
polynomial used by most Reed-Solomon deployments, e.g. ISA-L, par2).

Two representations are provided:

* **Table form** — log/antilog tables for scalar and vectorized numpy/jnp
  arithmetic. This is the oracle used by ``kernels/gf2mm/ref.py`` and the
  host-side matrix inversion in decode.
* **Bit-matrix form** — every constant c in GF(256) acts on the field (an
  8-dim GF(2) vector space) as a linear map; ``bitmatrix(c)`` returns the
  8x8 0/1 matrix of that map. Expanding an RS generator matrix entrywise
  into bit matrices turns GF(256) encode into a GF(2) matmul, which is the
  MXU-native formulation used by the Pallas kernel.
"""

from __future__ import annotations

import functools

import numpy as np

POLY = 0x11D  # x^8 + x^4 + x^3 + x^2 + 1
ORDER = 256
GENERATOR = 2  # primitive element for 0x11d


@functools.cache
def _tables() -> tuple[np.ndarray, np.ndarray]:
    """(exp, log) tables. exp has length 512 so exp[a+b] avoids a mod."""
    exp = np.zeros(512, dtype=np.uint8)
    log = np.zeros(256, dtype=np.int32)
    x = 1
    for i in range(255):
        exp[i] = x
        log[x] = i
        x <<= 1
        if x & 0x100:
            x ^= POLY
    exp[255:510] = exp[:255]
    log[0] = 0  # by convention; mul() special-cases zero
    return exp, log


def exp_table() -> np.ndarray:
    return _tables()[0]


def log_table() -> np.ndarray:
    return _tables()[1]


def add(a, b):
    """Addition in GF(2^8) is XOR (works elementwise on arrays)."""
    return np.bitwise_xor(a, b)


def mul(a, b):
    """Elementwise GF(256) multiply of uint8 arrays (broadcasting)."""
    exp, log = _tables()
    a = np.asarray(a, dtype=np.uint8)
    b = np.asarray(b, dtype=np.uint8)
    out = exp[log[a.astype(np.int32)] + log[b.astype(np.int32)]]
    return np.where((a == 0) | (b == 0), np.uint8(0), out)


def inv(a):
    """Elementwise multiplicative inverse. inv(0) is an error."""
    exp, log = _tables()
    a = np.asarray(a, dtype=np.uint8)
    if np.any(a == 0):
        raise ZeroDivisionError("inverse of 0 in GF(256)")
    return exp[255 - log[a.astype(np.int32)]]


def div(a, b):
    return mul(a, inv(b))


def pow_(a: int, e: int) -> int:
    exp, log = _tables()
    if a == 0:
        return 0
    return int(exp[(int(log[a]) * e) % 255])


def matmul(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """GF(256) matrix multiply, (m,k) @ (k,n) -> (m,n), uint8.

    Straightforward O(mkn) via table lookups; fine for the small generator /
    decode matrices handled on host. Bulk data encode goes through the
    bit-matrix kernel instead.
    """
    a = np.asarray(a, dtype=np.uint8)
    b = np.asarray(b, dtype=np.uint8)
    assert a.ndim == 2 and b.ndim == 2 and a.shape[1] == b.shape[0]
    exp, log = _tables()
    # products[i, t, j] = a[i, t] * b[t, j], then XOR-reduce over t.
    prod = mul(a[:, :, None], b[None, :, :])
    return np.bitwise_xor.reduce(prod, axis=1)


def mat_inv(m: np.ndarray) -> np.ndarray:
    """Invert a square GF(256) matrix by Gauss-Jordan. Raises if singular."""
    m = np.asarray(m, dtype=np.uint8)
    n = m.shape[0]
    assert m.shape == (n, n)
    aug = np.concatenate([m.copy(), np.eye(n, dtype=np.uint8)], axis=1)
    for col in range(n):
        # Find pivot.
        piv = None
        for row in range(col, n):
            if aug[row, col] != 0:
                piv = row
                break
        if piv is None:
            raise np.linalg.LinAlgError("singular GF(256) matrix")
        if piv != col:
            aug[[col, piv]] = aug[[piv, col]]
        # Normalize pivot row.
        aug[col] = mul(aug[col], inv(aug[col, col]))
        # Eliminate all other rows.
        for row in range(n):
            if row != col and aug[row, col] != 0:
                aug[row] = add(aug[row], mul(aug[row, col], aug[col]))
    return aug[:, n:].astype(np.uint8)


# ---------------------------------------------------------------------------
# Bit-matrix (GF(2)) representation
# ---------------------------------------------------------------------------


@functools.cache
def _bitmatrix_cache() -> np.ndarray:
    """(256, 8, 8) uint8 array: bitmatrix(c)[i, j] = bit i of c * x^j.

    Column j of M(c) is the bit-vector of ``c * 2^j`` in GF(256), so that for
    a byte v with bits v_j (LSB-first), ``M(c) @ bits(v) mod 2 == bits(c*v)``.
    """
    out = np.zeros((256, 8, 8), dtype=np.uint8)
    for c in range(256):
        for j in range(8):
            col = int(mul(np.uint8(c), np.uint8(1 << j)))
            for i in range(8):
                out[c, i, j] = (col >> i) & 1
    return out


def bitmatrix(c: int) -> np.ndarray:
    """8x8 GF(2) matrix of multiplication by c (LSB-first bit order)."""
    return _bitmatrix_cache()[c].copy()


def expand_bitmatrix(m: np.ndarray) -> np.ndarray:
    """Expand an (r, c) GF(256) matrix to an (8r, 8c) GF(2) 0/1 matrix."""
    m = np.asarray(m, dtype=np.uint8)
    r, c = m.shape
    cache = _bitmatrix_cache()
    # (r, c, 8, 8) -> (r, 8, c, 8) -> (8r, 8c)
    blocks = cache[m]  # fancy index: (r, c, 8, 8)
    return blocks.transpose(0, 2, 1, 3).reshape(8 * r, 8 * c)


def expand_bitmatrix_batched(mats: np.ndarray) -> np.ndarray:
    """Expand (batch, r, c) GF(256) matrices to (batch, 8r, 8c) in one
    vectorized fancy-index — no per-item Python loop on the hot path."""
    mats = np.asarray(mats, dtype=np.uint8)
    b, r, c = mats.shape
    blocks = _bitmatrix_cache()[mats]  # (b, r, c, 8, 8)
    return blocks.transpose(0, 1, 3, 2, 4).reshape(b, 8 * r, 8 * c)


def bytes_to_bitplanes(data: np.ndarray) -> np.ndarray:
    """(k, B) uint8 -> (8k, B) 0/1 uint8, LSB-first within each row block.

    Row 8*i + b of the output is bit b of data row i. This matches the
    LSB-first convention of :func:`bitmatrix`.
    """
    data = np.asarray(data, dtype=np.uint8)
    k, B = data.shape
    shifts = np.arange(8, dtype=np.uint8)
    planes = (data[:, None, :] >> shifts[None, :, None]) & 1
    return planes.reshape(8 * k, B)


def bitplanes_to_bytes(planes: np.ndarray) -> np.ndarray:
    """(8n, B) 0/1 -> (n, B) uint8, inverse of :func:`bytes_to_bitplanes`."""
    planes = np.asarray(planes, dtype=np.uint8)
    n8, B = planes.shape
    assert n8 % 8 == 0
    n = n8 // 8
    shifts = np.arange(8, dtype=np.uint8)
    grouped = planes.reshape(n, 8, B)
    return np.bitwise_or.reduce(grouped << shifts[None, :, None], axis=1).astype(np.uint8)
