"""Enablement switch for the telemetry layer.

One flag drives both the device-resident metrics and the host span tracer:
``REPRO_OBS=1`` in the environment, or :func:`set_enabled` for programmatic
control (tests).  The flag is read at *call* time, never baked into module
state, so flipping it mid-process works — engines that jit-cache on it put
the flag into their cache key, which keeps compile-count pins exact: a
constant flag yields exactly the same bucket counts as before this layer
existed.
"""
from __future__ import annotations

import os

_OVERRIDE: list = [None]


def set_enabled(value: bool | None) -> None:
    """Force telemetry on/off; ``None`` restores env (``REPRO_OBS``) control."""
    _OVERRIDE[0] = None if value is None else bool(value)


def enabled() -> bool:
    if _OVERRIDE[0] is not None:
        return _OVERRIDE[0]
    return os.environ.get("REPRO_OBS", "0") not in ("", "0")
