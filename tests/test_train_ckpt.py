"""Training loop + erasure-coded checkpointing + fault tolerance tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import latest_step, restore_checkpoint, save_checkpoint
from repro.core import PAPER_READ_3MB, RequestClass, TOFECPolicy
from repro.data import SyntheticTokens
from repro.models import get
from repro.models.config import ShapeSpec
from repro.storage import FaultyStore, MemoryStore, StorageError
from repro.train import Trainer, TrainerConfig
from repro.train.optimizer import AdamWConfig

SHAPE = ShapeSpec("tiny_train", "train", seq=32, batch=2)


def test_synthetic_data_deterministic_and_sharded():
    cfg = get("qwen1.5-0.5b", smoke=True).cfg
    a = SyntheticTokens(cfg, SHAPE, seed=7).batch_at(3)
    b = SyntheticTokens(cfg, SHAPE, seed=7).batch_at(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])
    s0 = SyntheticTokens(cfg, ShapeSpec("t", "train", 32, 4), seed=7, shard_id=0, n_shards=2)
    s1 = SyntheticTokens(cfg, ShapeSpec("t", "train", 32, 4), seed=7, shard_id=1, n_shards=2)
    assert not np.array_equal(s0.batch_at(0)["tokens"], s1.batch_at(0)["tokens"])


def test_checkpoint_roundtrip_and_erasure_recovery():
    store = MemoryStore()
    rng = np.random.default_rng(0)
    tree = {
        "w": rng.normal(size=(33, 17)).astype(np.float32),
        "nested": {"b": rng.integers(-5, 5, size=(9,)).astype(np.int32)},
    }
    save_checkpoint(store, "ck", 5, tree, n_max=6, k_max=3)
    assert latest_step(store, "ck") == 5

    # Drop strips up to n - k per leaf: restore must still succeed.
    faulty = FaultyStore(store)
    for key in store.keys():
        if key.endswith("strip0") or key.endswith("strip2"):
            faulty.lose_object(key)
    got = restore_checkpoint(faulty, "ck", 5, tree)
    np.testing.assert_array_equal(got["w"], tree["w"])
    np.testing.assert_array_equal(got["nested"]["b"], tree["nested"]["b"])


def test_checkpoint_unrecoverable_raises():
    store = MemoryStore()
    tree = {"w": np.ones((4, 4), np.float32)}
    save_checkpoint(store, "ck2", 1, tree, n_max=4, k_max=2)
    faulty = FaultyStore(store)
    lost = 0
    for key in store.keys():
        if "strip" in key and lost < 3:
            faulty.lose_object(key)
            lost += 1
    with pytest.raises(StorageError):
        restore_checkpoint(faulty, "ck2", 1, tree)


def test_tofec_policy_drives_checkpoint_chunking():
    """Backlogged writer → k drops toward 1 (throughput mode)."""
    store = MemoryStore()
    cls = RequestClass("ckpt", 3.0, PAPER_READ_3MB, k_max=4, r_max=2.0, n_max=8)
    pol = TOFECPolicy.for_classes([cls], L=16)
    tree = {f"w{i}": np.ones((64,), np.float32) for i in range(4)}
    m_idle = save_checkpoint(store, "cki", 1, tree, policy=pol, n_max=8, k_max=4)
    pol2 = TOFECPolicy.for_classes([cls], L=16)
    m_busy = save_checkpoint(
        store, "ckb", 1, tree, policy=pol2, n_max=8, k_max=4, pending_hint=500
    )
    k_idle = [v["k"] for v in m_idle["leaves"].values()]
    k_busy = [v["k"] for v in m_busy["leaves"].values()]
    assert max(k_idle) > max(k_busy)
    assert max(k_busy) == 1


def test_trainer_restart_resumes_identically():
    """Train 6 steps straight vs 3 + restart + 3: identical final loss."""
    arch = get("qwen1.5-0.5b", smoke=True)
    tc = TrainerConfig(total_steps=6, ckpt_every=3, log_every=1,
                       opt=AdamWConfig(lr=1e-3))

    store_a = MemoryStore()
    t_a = Trainer(arch, SHAPE, store_a, cfg=tc, ckpt_prefix="a")
    log_a = t_a.run()

    store_b = MemoryStore()
    t_b = Trainer(arch, SHAPE, store_b, cfg=tc, ckpt_prefix="b")
    t_b.run(steps=3)
    assert latest_step(store_b, "b") == 3
    # Simulate crash: rebuild the trainer from storage only.
    t_b2 = Trainer(arch, SHAPE, store_b, cfg=tc, ckpt_prefix="b")
    assert t_b2.start_step == 3
    log_b = t_b2.run(steps=3)

    final_a = log_a[-1]["loss"]
    final_b = log_b[-1]["loss"]
    assert final_a == pytest.approx(final_b, rel=1e-4)


def test_trainer_loss_decreases():
    arch = get("qwen1.5-0.5b", smoke=True)
    tc = TrainerConfig(total_steps=30, ckpt_every=30, log_every=1,
                       opt=AdamWConfig(lr=3e-3, weight_decay=0.0))
    # Overfit a single repeated batch (seeded pipeline with 1 distinct step).
    store = MemoryStore()
    t = Trainer(arch, SHAPE, store, cfg=tc, ckpt_prefix="c")
    t.data.batch_at = lambda step: SyntheticTokens(arch.cfg, SHAPE, seed=1).batch_at(0)
    log = t.run()
    assert log[-1]["loss"] < log[0]["loss"] * 0.8
