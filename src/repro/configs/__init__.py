"""Exact per-architecture configs (one module per assigned architecture).

Import side-effect free; each module exports ``CONFIG`` plus a
``smoke_config()`` returning a reduced same-family config for CPU tests.
"""

from repro.configs import (
    gemma2_2b,
    grok_1_314b,
    mistral_nemo_12b,
    mixtral_8x7b,
    pixtral_12b,
    qwen1_5_0_5b,
    whisper_base,
    xlstm_350m,
    yi_6b,
    zamba2_2_7b,
)

ALL_CONFIGS = {
    m.CONFIG.name: m.CONFIG
    for m in [
        whisper_base,
        xlstm_350m,
        gemma2_2b,
        mistral_nemo_12b,
        yi_6b,
        qwen1_5_0_5b,
        pixtral_12b,
        grok_1_314b,
        mixtral_8x7b,
        zamba2_2_7b,
    ]
}

SMOKE_CONFIGS = {
    m.CONFIG.name: m.smoke_config()
    for m in [
        whisper_base,
        xlstm_350m,
        gemma2_2b,
        mistral_nemo_12b,
        yi_6b,
        qwen1_5_0_5b,
        pixtral_12b,
        grok_1_314b,
        mixtral_8x7b,
        zamba2_2_7b,
    ]
}
