"""Tests for the TOFEC core math: Eq.2-7, Corollary 1, thresholds, policies."""

import math

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import (
    PAPER_READ_3MB,
    DelayParams,
    FixedKAdaptivePolicy,
    GreedyPolicy,
    RequestClass,
    StaticPolicy,
    TofecTables,
    TOFECPolicy,
    build_class_plan,
    fit_delay_params,
    optimal_static_code,
    q_for_k,
    solve_r_for_k,
)
from repro.core import controller as ctrl
from repro.core import queueing
from repro.core.static_optimizer import _eq6_lhs, _eq6_rhs

CLS = RequestClass("read3mb", 3.0, PAPER_READ_3MB, k_max=6, r_max=2.0, n_max=12)
L = 16


# ---------------------------------------------------------------------------
# Eq.2 / Eq.3 / Eq.4-5
# ---------------------------------------------------------------------------


def test_service_delay_log_approx_close_to_exact():
    for k, r in [(2, 2.0), (3, 2.0), (6, 2.0), (4, 1.5)]:
        n = k * r
        exact = queueing.service_delay_exact(PAPER_READ_3MB, 3.0, k, n)
        approx = queueing.service_delay(PAPER_READ_3MB, 3.0, k, r)
        assert approx == pytest.approx(exact, rel=0.15)


def test_usage_eq3_matches_manual():
    p, J, k, r = PAPER_READ_3MB, 3.0, 3.0, 2.0
    want = p.delta_bar * k * r + p.delta_tilde * J * r + p.psi_bar * k + p.psi_tilde * J
    assert queueing.usage(p, J, k, r) == pytest.approx(want)


def test_queueing_delay_blows_up_at_capacity():
    U = queueing.usage(PAPER_READ_3MB, 3.0, 1.0, 1.0)
    cap = L / U
    assert math.isinf(queueing.queueing_delay(cap * 1.01, U, L))
    assert queueing.queueing_delay(cap * 0.5, U, L) < 0.1


def test_lambda_bar_queue_roundtrip():
    for lam_bar in [0.5, 4.0, 12.0, 15.9]:
        Q = lam_bar**2 / (L * (L - lam_bar))
        assert queueing.lambda_bar_from_queue(Q, L) == pytest.approx(lam_bar, rel=1e-9)


def test_paper_calibration_headline_numbers():
    """Light-load means should land near the paper's Fig.7 numbers."""
    p, J = PAPER_READ_3MB, 3.0
    basic = queueing.service_delay_exact(p, J, 1, 1)
    repl = queueing.service_delay_exact(p, J, 1, 2)
    best = queueing.service_delay_exact(p, J, 6, 12)
    assert 0.18 < basic < 0.23  # paper: ~205 ms
    assert 0.13 < repl < 0.17  # paper: ~151 ms
    assert 0.06 < best < 0.10  # paper: ~84 ms
    # capacity loss of delay-optimal static code (paper: ~30%)
    cap_11 = queueing.capacity(p, J, 1, 1.0, L)
    cap_63 = queueing.capacity(p, J, 3, 2.0, L)
    assert 0.25 < cap_63 / cap_11 < 0.45


# ---------------------------------------------------------------------------
# Theorem 1 / Corollary 1
# ---------------------------------------------------------------------------


def test_eq6_rhs_strictly_increasing_in_r():
    rs = np.linspace(1.01, 50, 300)
    vals = [_eq6_rhs(PAPER_READ_3MB, 3.0, r) for r in rs]
    assert np.all(np.diff(vals) > 0)


def test_eq6_lhs_strictly_increasing_in_k():
    ks = np.linspace(0.1, 50, 300)
    vals = [_eq6_lhs(PAPER_READ_3MB, 3.0, k) for k in ks]
    assert np.all(np.diff(vals) > 0)


@given(st.floats(0.2, 20.0))
@settings(max_examples=25, deadline=None)
def test_solve_r_satisfies_eq6(k):
    r = solve_r_for_k(PAPER_READ_3MB, 3.0, k)
    assert _eq6_rhs(PAPER_READ_3MB, 3.0, r) == pytest.approx(
        _eq6_lhs(PAPER_READ_3MB, 3.0, k), rel=1e-6
    )


def test_r_increasing_in_k():
    ks = np.linspace(0.3, 12, 60)
    rs = [solve_r_for_k(PAPER_READ_3MB, 3.0, k) for k in ks]
    assert np.all(np.diff(rs) > 0)


def test_corollary1_q_strictly_decreasing_in_k():
    ks = np.linspace(0.3, 12, 60)
    qs = [q_for_k(PAPER_READ_3MB, 3.0, k, L) for k in ks]
    assert np.all(np.diff(qs) < 0)


def test_class_plan_threshold_interleaving():
    """Paper §IV-C: H_1 > Q_1 > H_2 > Q_2 > ... > H_{m} > Q_m > H_{m+1} = 0."""
    plan = build_class_plan(CLS, L)
    for q_tab, h in [(plan.q_at_k, plan.h_k), (plan.q_at_n, plan.h_n)]:
        assert np.all(np.diff(q_tab) < 0)
        assert h[0] == math.inf and h[-1] == 0.0
        for j in range(len(q_tab) - 1):
            assert h[j] > q_tab[j] > h[j + 1]


def test_plan_pick_monotone_in_q():
    plan = build_class_plan(CLS, L)
    qs = np.linspace(0.0, 30.0, 400)
    ks = [plan.pick_k(q) for q in qs]
    ns = [plan.pick_n(q) for q in qs]
    assert np.all(np.diff(ks) <= 0) and np.all(np.diff(ns) <= 0)
    assert ks[0] == CLS.k_max  # empty queue → max chunking
    assert ks[-1] == 1  # huge backlog → no chunking
    n0, k0 = plan.pick_code(0.0)
    assert k0 == CLS.k_max and n0 <= CLS.r_max * k0


def test_optimal_static_code_light_vs_heavy():
    k_light, r_light, _ = optimal_static_code(CLS, L, lam=5.0)
    k_heavy, r_heavy, _ = optimal_static_code(CLS, L, lam=60.0)
    assert k_light > k_heavy
    assert r_light > r_heavy


# ---------------------------------------------------------------------------
# Fitting (§V-A)
# ---------------------------------------------------------------------------


def test_fit_recovers_params_from_samples():
    rng = np.random.default_rng(0)
    p = PAPER_READ_3MB
    sizes = np.array([0.5, 1.0, 1.5, 3.0])
    delays = [p.sample(rng, B, size=60_000) for B in sizes]
    got = fit_delay_params(sizes, delays, drop_worst_frac=0.0)
    assert got.delta_bar == pytest.approx(p.delta_bar, rel=0.15)
    assert got.delta_tilde == pytest.approx(p.delta_tilde, rel=0.15)
    assert got.psi_bar == pytest.approx(p.psi_bar, rel=0.2)
    assert got.psi_tilde == pytest.approx(p.psi_tilde, rel=0.15)


# ---------------------------------------------------------------------------
# Policies
# ---------------------------------------------------------------------------


def test_static_policy():
    pol = StaticPolicy(6, 3)
    assert pol.select(q=0, idle=16) == (6, 3)
    with pytest.raises(ValueError):
        StaticPolicy(2, 3)


def test_tofec_policy_adapts_with_backlog():
    pol = TOFECPolicy.for_classes([CLS], L)
    n0, k0 = pol.select(q=0, idle=16)
    assert k0 == CLS.k_max
    pol.reset()
    for _ in range(50):
        n1, k1 = pol.select(q=500, idle=0)
    assert k1 == 1 and n1 == 1


def test_greedy_policy_matches_paper_rules():
    pol = GreedyPolicy(k_max=6, r_max=2.0)
    assert pol.select(q=3, idle=0) == (1, 1)
    assert pol.select(q=0, idle=3) == (3, 3)
    assert pol.select(q=0, idle=16) == (12, 6)
    assert pol.select(q=0, idle=8) == (8, 6)


def test_fixedk_policy_n_decreasing_in_backlog():
    pol = FixedKAdaptivePolicy(CLS, L, k=6)
    pol.reset()
    n_light, k_light = pol.select(q=0, idle=16)
    pol.reset()
    for _ in range(50):
        n_heavy, k_heavy = pol.select(q=500, idle=0)
    assert k_light == k_heavy == 6
    assert n_light > n_heavy >= 6


def test_jax_controller_matches_numpy():
    plan = build_class_plan(CLS, L)
    tables = TofecTables.from_plan(plan)
    pol = TOFECPolicy([plan], alpha=0.7)
    import jax.numpy as jnp

    # -1.0 = the device cold-start sentinel: like the freshly-reset host
    # policy, the first observed q seeds the EWMA rather than decaying from 0.
    q_ewma = jnp.float32(-1.0)
    pol.reset()
    rng = np.random.default_rng(5)
    for q in rng.integers(0, 40, size=60):
        n_np, k_np = pol.select(q=int(q), idle=3)
        q_ewma, n_j, k_j = ctrl.tofec_step_jax(q_ewma, jnp.float32(q), tables, 0.7)
        assert (int(n_j), int(k_j)) == (n_np, k_np)
        assert float(q_ewma) == pytest.approx(pol.q_ewma, rel=1e-5)
