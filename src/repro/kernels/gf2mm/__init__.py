from repro.kernels.gf2mm.gf2mm import gf2_matmul, gf2_rs_matmul_bytes, tpu_compiler_params
from repro.kernels.gf2mm.ops import decode_blob, encode_blob, rs_decode, rs_encode

__all__ = [
    "gf2_matmul",
    "gf2_rs_matmul_bytes",
    "tpu_compiler_params",
    "rs_encode",
    "rs_decode",
    "encode_blob",
    "decode_blob",
]
