"""Production mesh builders.

Single-pod: (16, 16) → ("data", "model") — 256 chips (one v5e pod).
Multi-pod:  (2, 16, 16) → ("pod", "data", "model") — 512 chips.

Functions, not module constants: importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before any jax import)."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist, as a 1-D 'data' mesh (tests/examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))


def make_grid_mesh(n: int | None = None):
    """First ``n`` devices (default: all) as a 1-D ``'grid'`` mesh.

    The sweep engines (:mod:`repro.fleet.shard`) partition their stacked
    grid-case axis over this mesh with ``shard_map``; a submesh over a
    device subset lets one process bench 1/2/4/... device scaling from the
    same pool of (possibly ``--xla_force_host_platform_device_count``
    virtual) devices.
    """
    import numpy as np

    devices = jax.devices()
    n = len(devices) if n is None else int(n)
    if not 1 <= n <= len(devices):
        raise ValueError(f"need 1 <= n <= {len(devices)} devices, got {n}")
    return jax.sharding.Mesh(np.asarray(devices[:n]), ("grid",))
