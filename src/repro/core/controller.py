"""Code-selection policies (§IV-C, §V-A).

Every policy answers one question at request-arrival time: which (n, k) MDS
code serves this request. Inputs available to a policy (mirroring what the
paper's proxy can observe locally): the instantaneous request-queue length
``q`` and the number of idle threads ``idle``.

Policies:
  * StaticPolicy(n, k)           — the paper's static strategies (incl. basic
                                   (1,1) and simple replication (2,1)).
  * TOFECPolicy                  — the paper's adaptive algorithm: EWMA of q
                                   against the H^N / H^K threshold tables.
  * GreedyPolicy                 — §V-A heuristic from idle-thread count.
  * FixedKAdaptivePolicy         — the strategy of [3]: k fixed, n adapted
                                   (backlog-driven via the same machinery).

A jit-friendly functional form of the TOFEC update is provided in
:func:`tofec_step_jax` so the serving engine can run the controller inside a
compiled step.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.delay_model import RequestClass
from repro.core.static_optimizer import ClassPlan, build_class_plan


class Policy:
    """Interface: observe arrival, emit (n, k)."""

    name: str = "policy"

    def select(self, *, q: int, idle: int, cls_id: int = 0, now: float | None = None) -> tuple[int, int]:
        raise NotImplementedError

    def reset(self) -> None:  # pragma: no cover - default no state
        pass


@dataclasses.dataclass
class StaticPolicy(Policy):
    n: int
    k: int

    def __post_init__(self):
        if self.n < self.k or self.k < 1:
            raise ValueError(f"invalid static code ({self.n},{self.k})")
        self.name = f"static({self.n},{self.k})"

    def select(self, *, q: int, idle: int, cls_id: int = 0, now: float | None = None) -> tuple[int, int]:
        return self.n, self.k


class TOFECPolicy(Policy):
    """The paper's algorithm (§IV-C pseudocode), per-class thresholds.

    q̄ ← αq + (1−α)q̄ on each arrival; k and n from threshold lookup;
    n ← min(r_max·k, n); guard n ≥ k.
    """

    def __init__(self, plans: list[ClassPlan], alpha: float = 0.99):
        if not 0.0 < alpha <= 1.0:
            raise ValueError("memory factor must be in (0, 1]")
        self.plans = plans
        self.alpha = alpha
        self.name = f"tofec(alpha={alpha})"
        self.reset()

    @classmethod
    def for_classes(
        cls, classes: list[RequestClass], L: int, alpha: float = 0.99, eq7_factor: float = 2.0
    ) -> "TOFECPolicy":
        return cls([build_class_plan(c, L, eq7_factor=eq7_factor) for c in classes], alpha)

    def reset(self) -> None:
        # None = cold start: the first observation seeds the EWMA directly
        # (an EWMA initialized from 0 would bias early picks toward low q̄,
        # hence toward under-chunked codes). Device scans use a -1.0 carry
        # sentinel for the same rule — see tofec_threshold_step.
        self.q_ewma = None

    def select(self, *, q: int, idle: int, cls_id: int = 0, now: float | None = None) -> tuple[int, int]:
        if self.q_ewma is None:
            self.q_ewma = float(q)
        else:
            self.q_ewma = self.alpha * q + (1.0 - self.alpha) * self.q_ewma
        return self.plans[cls_id].pick_code(self.q_ewma)


@dataclasses.dataclass
class GreedyPolicy(Policy):
    """§V-A Greedy: chunk as much as idle threads allow, then add redundancy.

    Paper's printed formula sets n = min(k_max, l) which would force n = k;
    the prose ("then increase the redundancy ratio as long as there are idle
    threads remain") implies n = min(r_max·k, l). We implement the prose and
    note the discrepancy.
    """

    k_max: int
    r_max: float

    def __post_init__(self):
        self.name = "greedy"

    def select(self, *, q: int, idle: int, cls_id: int = 0, now: float | None = None) -> tuple[int, int]:
        if idle <= 0:
            return 1, 1
        k = min(self.k_max, idle)
        n = min(int(self.r_max * k), max(idle, 1))
        return max(n, k), k


class FixedKAdaptivePolicy(Policy):
    """The adaptive strategy of [3]: fixed code dimension k, n adapted to
    backlog. Uses the Eq.7-analogue at fixed k: r(r−1) =
    f·L(Ψ̄k + Ψ̃J) / (k(Δ̄k + Δ̃J)((L/(L−λ̄))² − 1)), n = k·r, thresholded
    the same way as TOFEC.
    """

    def __init__(
        self,
        cls_: RequestClass,
        L: int,
        k: int,
        alpha: float = 0.99,
        eq7_factor: float = 2.0,
    ):
        self.cls = cls_
        self.k = k
        self.alpha = alpha
        self.name = f"fixedk(k={k})"
        p, J = cls_.params, cls_.file_mb
        c = (
            eq7_factor
            * L
            * (p.psi_bar * k + p.psi_tilde * J)
            / (k * (p.delta_bar * k + p.delta_tilde * J))
        )

        # Q at which n is optimal (n = k..n_max): from r = n/k,
        # (L/(L−λ̄))² − 1 = c / (r(r−1)) → λ̄ → Q.
        def q_for_n(n: int) -> float:
            r = n / k
            if r <= 1.0:
                return math.inf  # n = k only optimal at overload (Q → ∞)
            pi = c / (r * (r - 1.0))
            lam_bar = L * (1.0 - 1.0 / math.sqrt(1.0 + pi))
            return lam_bar**2 / (L * (L - lam_bar))

        n_values = list(range(k, cls_.n_max + 1))
        q_tab = np.array([q_for_n(n) for n in n_values])
        h = np.empty(len(n_values) + 1)
        h[0] = math.inf
        for j in range(1, len(n_values)):
            h[j] = 0.5 * (q_tab[j] + q_tab[j - 1])
        h[-1] = 0.0
        self.n_values = n_values
        self.h_n = h
        self.reset()

    def reset(self) -> None:
        self.q_ewma = None  # cold-start sentinel, see TOFECPolicy.reset

    def select(self, *, q: int, idle: int, cls_id: int = 0, now: float | None = None) -> tuple[int, int]:
        if self.q_ewma is None:
            self.q_ewma = float(q)
        else:
            self.q_ewma = self.alpha * q + (1.0 - self.alpha) * self.q_ewma
        j = int(np.searchsorted(-self.h_n[1:], -self.q_ewma, side="left"))
        n = self.n_values[min(j, len(self.n_values) - 1)]
        return n, self.k


# ---------------------------------------------------------------------------
# JAX functional form (used inside jitted serving steps)
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TofecTables:
    """Static threshold tables as device arrays (one class)."""

    h_k: jax.Array  # (k_max + 1,) descending, h_k[0] = +inf
    h_n: jax.Array  # (n_max + 1,)
    r_max: float = dataclasses.field(metadata=dict(static=True))

    @classmethod
    def from_plan(cls, plan: ClassPlan) -> "TofecTables":
        big = jnp.float32(jnp.finfo(jnp.float32).max)
        h_k = jnp.asarray(plan.h_k, jnp.float32)
        h_n = jnp.asarray(plan.h_n, jnp.float32)
        h_k = jnp.where(jnp.isinf(h_k), big, h_k)
        h_n = jnp.where(jnp.isinf(h_n), big, h_n)
        return cls(h_k=h_k, h_n=h_n, r_max=plan.cls.r_max)


def tofec_threshold_step(
    q_ewma: jax.Array,
    q: jax.Array,
    h_k: jax.Array,
    h_n: jax.Array,
    r_max,
    alpha,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Table-free form of the TOFEC update: every argument may be a tracer.

    Unlike :func:`tofec_step_jax` this takes the threshold tables and the
    redundancy cap as plain (possibly traced) arrays, so the fleet sweep can
    ``vmap`` it across a stacked policy axis where ``r_max`` varies per grid
    point. Trailing zero entries in ``h_k``/``h_n`` are inert (0 > q̄ never
    holds for q̄ ≥ 0), which is what makes cross-class table padding safe.

    ``q_ewma < 0`` is the cold-start sentinel (carries initialize to -1.0):
    the first observation seeds the EWMA instead of averaging against a bogus
    0, matching the host policies' ``q_ewma = None`` rule.
    """
    q_new = jnp.where(q_ewma < 0.0, q, alpha * q + (1.0 - alpha) * q_ewma)
    k = 1 + jnp.sum(h_k[1:] > q_new).astype(jnp.int32)
    n = 1 + jnp.sum(h_n[1:] > q_new).astype(jnp.int32)
    n = jnp.minimum((r_max * k).astype(jnp.int32), n)
    n = jnp.maximum(n, k)
    return q_new, n, k


def tofec_step_jax(
    q_ewma: jax.Array, q: jax.Array, tables: TofecTables, alpha: float
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One arrival update, fully traceable: returns (q̄', n, k).

    Same semantics as :class:`TOFECPolicy.select` (threshold search =
    1 + #{h > q̄} over the descending tables).
    """
    return tofec_threshold_step(q_ewma, q, tables.h_k, tables.h_n, tables.r_max, alpha)


class MPCPolicy(Policy):
    """Beyond-paper controller: discrete model-predictive code selection.

    Instead of inverting the continuous relaxation into thresholds (§IV-C),
    estimate the arrival rate online (interarrival EWMA) and pick the
    discrete (n, k) minimizing the paper's own cost model

        D̂(n, k) = D_q^{M/M/1}(λ̂, U(n, k)) + D_s^{exact}(n, k)

    over the feasible code set, rejecting codes with λ̂·U ≥ util_cap·L.
    Falls back to max chunking until a rate estimate exists. Motivation and
    measured gains vs the threshold controller: EXPERIMENTS.md §Perf
    (controller hillclimb).

    The whole select is vectorized float32 over the k-major code enumeration
    (k ascending outer, n ascending inner) so it is the bit-level oracle for
    :func:`mpc_step_jax`; see that function for the tie-break contract.
    """

    def __init__(
        self,
        cls_: RequestClass,
        L: int,
        *,
        alpha_rate: float = 0.05,
        util_cap: float = 0.9,
        q_guard: float = 4.0,
        alpha_q: float = 0.1,
    ):
        from repro.core import queueing as _q

        self.cls = cls_
        self.L = L
        self.alpha_rate = alpha_rate
        self.util_cap = util_cap
        self.q_guard = q_guard
        self.alpha_q = alpha_q
        self.name = "mpc"
        p, J = cls_.params, cls_.file_mb
        self.codes = []
        for k in range(1, cls_.k_max + 1):
            for n in range(k, min(int(cls_.r_max * k), cls_.n_max) + 1):
                u = _q.usage(p, J, k, n / k)
                ds = _q.service_delay_exact(p, J, k, n)
                self.codes.append((n, k, u, ds))
        self._n = np.asarray([c[0] for c in self.codes], np.int32)
        self._k = np.asarray([c[1] for c in self.codes], np.int32)
        self._u = np.asarray([c[2] for c in self.codes], np.float32)
        self._ds = np.asarray([c[3] for c in self.codes], np.float32)
        self.reset()

    def reset(self) -> None:
        self.mean_ia = None
        self.last_arrival = None
        self.q_ewma = None  # cold-start sentinel, see TOFECPolicy.reset

    def select(self, *, q: int, idle: int, cls_id: int = 0, now: float | None = None) -> tuple[int, int]:
        one = np.float32(1.0)
        a_q = np.float32(self.alpha_q)
        if self.q_ewma is None:
            self.q_ewma = np.float32(q)
        else:
            self.q_ewma = a_q * np.float32(q) + (one - a_q) * np.float32(self.q_ewma)
        if now is not None:
            if self.last_arrival is not None:
                ia = np.float32(max(now - self.last_arrival, 1e-9))
                a_r = np.float32(self.alpha_rate)
                self.mean_ia = (
                    ia if self.mean_ia is None
                    else (one - a_r) * np.float32(self.mean_ia) + a_r * ia
                )
            self.last_arrival = now
        if self.mean_ia is None:
            # Cold: max chunking = the LAST entry of the k-major enumeration
            # (largest k, then largest n).
            i = len(self.codes) - 1
        else:
            L = np.float32(self.L)
            lam_bar = (one / np.float32(self.mean_ia)) * self._u
            feasible = lam_bar < np.float32(self.util_cap) * L
            with np.errstate(divide="ignore", invalid="ignore", over="ignore"):
                dq = lam_bar * self._u / (L * (L - lam_bar))
                # backlog guard: sustained queue penalizes expensive codes.
                dq = dq * (one + np.float32(self.q_ewma) / np.float32(self.q_guard))
                cost = np.where(feasible, dq + self._ds, np.float32(np.inf))
            # First minimum = lowest k-major index; all-infeasible → index 0
            # = (1, 1). Same rule as jnp.argmin in mpc_step_jax.
            i = int(np.argmin(cost))
        return int(self._n[i]), int(self._k[i])


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class MPCTables:
    """MPC cost model as device arrays (one class) — all fields runtime data.

    The code enumeration is k-major (k ascending outer, n ascending inner),
    identical to ``MPCPolicy.codes``; ``n``/``k``/``u``/``ds`` are parallel
    (C,) arrays and the scalars ride along as 0-d arrays so swapping the
    model never retraces.
    """

    n: jax.Array  # (C,) int32
    k: jax.Array  # (C,) int32
    u: jax.Array  # (C,) float32 thread-seconds per request
    ds: jax.Array  # (C,) float32 exact service delay
    L: jax.Array  # () float32 pool size
    util_cap: jax.Array  # () float32
    q_guard: jax.Array  # () float32
    alpha_q: jax.Array  # () float32 backlog-EWMA gain (MPC default 0.1)
    alpha_rate: jax.Array  # () float32 interarrival-EWMA gain

    @classmethod
    def from_policy(cls, pol: MPCPolicy) -> "MPCTables":
        return cls(
            n=jnp.asarray(pol._n),
            k=jnp.asarray(pol._k),
            u=jnp.asarray(pol._u),
            ds=jnp.asarray(pol._ds),
            L=jnp.float32(pol.L),
            util_cap=jnp.float32(pol.util_cap),
            q_guard=jnp.float32(pol.q_guard),
            alpha_q=jnp.float32(pol.alpha_q),
            alpha_rate=jnp.float32(pol.alpha_rate),
        )

    @classmethod
    def trivial(cls) -> "MPCTables":
        """Inert single-code table for steps that never select the MPC lane."""
        return cls(
            n=jnp.ones(1, jnp.int32),
            k=jnp.ones(1, jnp.int32),
            u=jnp.ones(1, jnp.float32),
            ds=jnp.zeros(1, jnp.float32),
            L=jnp.float32(1.0),
            util_cap=jnp.float32(1.0),
            q_guard=jnp.float32(1.0),
            alpha_q=jnp.float32(0.1),
            alpha_rate=jnp.float32(0.05),
        )


def mpc_tables(
    cls_: RequestClass,
    L: int,
    *,
    alpha_rate: float = 0.05,
    util_cap: float = 0.9,
    q_guard: float = 4.0,
    alpha_q: float = 0.1,
) -> MPCTables:
    """Build :class:`MPCTables` through the host policy so the enumeration
    and float32 casts are shared with the oracle by construction."""
    pol = MPCPolicy(
        cls_, L, alpha_rate=alpha_rate, util_cap=util_cap, q_guard=q_guard, alpha_q=alpha_q
    )
    return MPCTables.from_policy(pol)


def mpc_step_jax(
    carry: tuple[jax.Array, jax.Array, jax.Array],
    q: jax.Array,
    dt: jax.Array,
    tables: MPCTables,
) -> tuple[tuple[jax.Array, jax.Array, jax.Array], jax.Array, jax.Array]:
    """One MPC arrival update, fully traceable: ((q̄', ia', has_rate'), n, k).

    Carry = (q_ewma, mean_ia, has_rate), all float32 scalars; initialize to
    (-1.0, 0.0, 0.0). ``q_ewma < 0`` is the cold-start sentinel (first
    observation seeds the backlog EWMA); ``dt < 0`` means "no previous
    arrival timestamp" — the rate EWMA only updates on ``dt ≥ 0``, mirroring
    the host's ``now``/``last_arrival`` bookkeeping.

    Tie-break contract (pinned by tests/test_fused_serve.py): costs are
    evaluated over the k-major enumeration of :class:`MPCTables` and the
    winner is the FIRST minimum — ``jnp.argmin`` here, ``np.argmin`` on the
    host (which replaced the original strict-``<`` scalar loop precisely so
    float cost ties resolve identically on both sides). Cold start
    (has_rate == 0) picks index C-1, the max-(k, n) code; an all-infeasible
    round degenerates to argmin over all-inf costs = index 0 = (1, 1).
    """
    q_ewma, mean_ia, has_rate = carry
    q = jnp.float32(q)
    dt = jnp.float32(dt)
    t = tables
    one = jnp.float32(1.0)
    q_new = jnp.where(q_ewma < 0.0, q, t.alpha_q * q + (one - t.alpha_q) * q_ewma)
    ia = jnp.maximum(dt, jnp.float32(1e-9))
    seen = dt >= 0.0
    ia_new = jnp.where(has_rate > 0.0, (one - t.alpha_rate) * mean_ia + t.alpha_rate * ia, ia)
    mean_ia = jnp.where(seen, ia_new, mean_ia)
    has_rate = jnp.where(seen, one, has_rate)
    lam_bar = (one / jnp.maximum(mean_ia, jnp.float32(1e-30))) * t.u
    feasible = lam_bar < t.util_cap * t.L
    dq = lam_bar * t.u / (t.L * (t.L - lam_bar))
    dq = dq * (one + q_new / t.q_guard)
    cost = jnp.where(feasible, dq + t.ds, jnp.float32(jnp.inf))
    idx = jnp.argmin(cost).astype(jnp.int32)
    idx = jnp.where(has_rate > 0.0, idx, jnp.int32(t.n.shape[0] - 1))
    return (q_new, mean_ia, has_rate), t.n[idx], t.k[idx]


class FeedbackPolicy(Policy):
    """Externally-driven write policy: closes the §III control loop.

    The serving tower's fused controller picks (n, k) on device each round
    and :meth:`push`\\ es it here; the proxy's write path then encodes every
    queued write under the adapted code. ``select`` just replays the last
    pushed code — no internal state beyond it.
    """

    def __init__(self, n: int, k: int):
        self.name = "feedback"
        self.push(n, k)

    def push(self, n: int, k: int) -> None:
        n, k = int(n), int(k)
        if n < k or k < 1:
            raise ValueError(f"invalid pushed code ({n},{k})")
        self.code = (n, k)

    def select(self, *, q: int, idle: int, cls_id: int = 0, now: float | None = None) -> tuple[int, int]:
        return self.code
