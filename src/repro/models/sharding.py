"""Logical-axis sharding: MaxText-style rules mapping logical tensor axes to
mesh axes, with divisibility-aware fallback.

Logical axes used across the models:
  batch     — data-parallel batch            → ("pod", "data")
  seq_sp    — sequence-parallel residual     → "model"   (Megatron-SP)
  heads     — attention heads                → "model"
  kv_heads  — KV heads                       → "model" (if divisible)
  ff        — MLP hidden                     → "model"
  vocab     — vocabulary                     → "model"
  embed     — d_model on weights             → ("pod", "data")  (FSDP/ZeRO)
  experts   — MoE experts                    → (unsharded; d_ff TP instead)
  kv_seq    — KV-cache sequence              → "model" (long-context decode)

``with axis_rules(mesh, rules): ...`` activates constraint emission; without
an active context (CPU unit tests) every constraint is a no-op.
"""

from __future__ import annotations

import contextlib
import threading

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()


def default_rules(mesh: Mesh) -> dict[str, tuple[str, ...]]:
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    model = ("model",) if "model" in mesh.axis_names else ()
    return {
        "batch": data_axes,
        "seq_sp": model,
        "heads": model,
        "kv_heads": model,
        "ff": model,
        "vocab": model,
        "embed": data_axes,
        "experts": (),
        "kv_seq": model,
        "state": (),
    }


def pure_dp_rules(mesh: Mesh) -> dict[str, tuple[str, ...]]:
    """§Perf profile for small models: no tensor parallelism at all — batch
    over (data, model), params fully replicated, grads all-reduced once.
    Removes every per-layer activation collective (see EXPERIMENTS.md §Perf,
    qwen hillclimb)."""
    axes = tuple(a for a in ("data", "model") if a in mesh.axis_names)
    return {
        "batch": axes,
        "seq_sp": (), "heads": (), "kv_heads": (), "ff": (),
        "vocab": (), "embed": (), "experts": (), "kv_seq": (), "state": (),
    }


@contextlib.contextmanager
def axis_rules(mesh: Mesh | None, rules: dict[str, tuple[str, ...]] | None = None):
    prev = getattr(_STATE, "ctx", None)
    if mesh is None:
        _STATE.ctx = None
    else:
        _STATE.ctx = (mesh, rules or default_rules(mesh))
    try:
        yield
    finally:
        _STATE.ctx = prev


def active_mesh() -> Mesh | None:
    ctx = getattr(_STATE, "ctx", None)
    return ctx[0] if ctx else None


def _axes_for(logical: str | None) -> tuple[str, ...]:
    ctx = getattr(_STATE, "ctx", None)
    if ctx is None or logical is None:
        return ()
    return ctx[1].get(logical, ())


def spec_for(shape: tuple[int, ...], logical_axes: tuple[str | None, ...]) -> P:
    """PartitionSpec for a shape, dropping axes that don't divide evenly."""
    ctx = getattr(_STATE, "ctx", None)
    if ctx is None:
        return P()
    mesh = ctx[0]
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for dim, logical in zip(shape, logical_axes):
        axes = _axes_for(logical)
        prod = int(np.prod([sizes[a] for a in axes])) if axes else 1
        if axes and dim % prod == 0 and prod > 1:
            out.append(axes if len(axes) > 1 else axes[0])
        else:
            out.append(None)
    return P(*out)


def constrain(x: jax.Array, *logical_axes: str | None) -> jax.Array:
    """with_sharding_constraint against the active rules (no-op if none)."""
    ctx = getattr(_STATE, "ctx", None)
    if ctx is None:
        return x
    mesh = ctx[0]
    spec = spec_for(x.shape, logical_axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(shape: tuple[int, ...], logical_axes: tuple[str | None, ...]) -> NamedSharding | None:
    ctx = getattr(_STATE, "ctx", None)
    if ctx is None:
        return None
    return NamedSharding(ctx[0], spec_for(shape, logical_axes))


def tree_specs(tree_shapes, tree_logical) -> object:
    """Map matching pytrees of shapes & logical-axis tuples to PartitionSpecs."""
    return jax.tree.map(
        lambda sh, lg: spec_for(tuple(sh), tuple(lg)),
        tree_shapes,
        tree_logical,
        is_leaf=lambda x: isinstance(x, (tuple, list)) and (not x or not isinstance(x[0], (tuple, list, dict))),
    )
