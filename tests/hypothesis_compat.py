"""Guarded ``hypothesis`` import so the suite collects without it.

``hypothesis`` is a dev-only dependency (see requirements-dev.txt). When it
is installed, this module re-exports the real ``given`` / ``settings`` /
``strategies``. When it is missing, property tests are collected but
individually skipped (via a stub decorator), and plain tests in the same
module keep running — so a bare environment still exercises everything
non-property-based.

Usage in a test module::

    from hypothesis_compat import given, settings, st
"""

from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised in bare containers
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for any ``st.*`` strategy expression at collect time."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    class _StrategiesStub:
        def __getattr__(self, name):
            return _AnyStrategy()

    st = _StrategiesStub()

    def given(*_args, **_kwargs):
        def deco(fn):
            # A fresh zero-arg function (not a wrapper) so pytest collects
            # it without trying to fixture-resolve the strategy args.
            def skipped_property_test():
                pytest.skip("hypothesis not installed")

            skipped_property_test.__name__ = fn.__name__
            skipped_property_test.__doc__ = fn.__doc__
            return skipped_property_test

        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco
