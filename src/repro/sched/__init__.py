"""repro.sched — shared-pool multi-class scheduler simulation.

§IV of the paper analyses multiple (type, size) request classes contending
for ONE pool of L parallel connections. The fleet's ``tenant_cases`` path
approximates that with Poisson splitting — independent per-class fluid
queues that each think they own the pool — which erases cross-class
interference. This package simulates the shared pool jointly:

* :mod:`repro.sched.scan` — ``multiclass_scan_core``: a single ``lax.scan``
  over the merged arrival stream carrying per-class backlog and TOFEC state,
  with FIFO / strict-priority / weighted-fair admission disciplines as
  traceable select logic.
* :mod:`repro.sched.sweep` — ``SchedSweep``: (mix × discipline × seed)
  grids vmapped through the scan with the fleet's pow2-bucketed jit caching
  and chunked launches; heterogeneous-discipline grids compile once.
* :mod:`repro.sched.frontier` — per-class delay percentiles, Jain fairness
  index, interference headlines and the ``BENCH_multiclass.json`` artifact.

The discrete-event oracle is :func:`repro.core.simulator.
simulate_shared_pool`; cross-validation lives in ``tests/test_sched.py``.
"""

from repro.sched.frontier import (
    MulticlassPoint,
    by_discipline,
    interference_summary,
    jain_index,
    multiclass_points,
    write_multiclass_artifact,
)
from repro.sched.scan import (
    DISC_FIFO,
    DISC_NAMES,
    DISC_PRIORITY,
    DISC_WFQ,
    multiclass_scan_core,
)
from repro.sched.sweep import (
    DisciplineSpec,
    SchedCase,
    SchedResult,
    SchedSweep,
    sched_cases,
)

__all__ = [
    "DISC_FIFO",
    "DISC_PRIORITY",
    "DISC_WFQ",
    "DISC_NAMES",
    "multiclass_scan_core",
    "DisciplineSpec",
    "SchedCase",
    "SchedResult",
    "SchedSweep",
    "sched_cases",
    "MulticlassPoint",
    "multiclass_points",
    "by_discipline",
    "interference_summary",
    "jain_index",
    "write_multiclass_artifact",
]
