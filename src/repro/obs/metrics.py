"""Device-resident metrics.

:class:`MetricsBuf` is a registered-dataclass pytree of int32 counters,
fixed-bucket int32 histograms, and float32 high-water marks.  Every update
is a pure functional op (frozen dataclass -> new instance) made of plain
``jnp`` arithmetic, so a buf threads straight through ``jit`` / ``vmap`` /
``lax.scan`` carries without adding host syncs.  Metric *names* live in the
dict keys, which are pytree structure: two bufs with the same field names
are the same pytree type under tracing, and adding a metric to an existing
buf changes the cache key (-> one new compile), never silently retraces.

Collection sites fold per chunk exactly like the PR 6 streaming frontier
reductions: the vmapped engine returns a per-case buf, the launcher slices
off tail padding, row-reduces on device, and union-merges across chunks.
The only host sync is :meth:`MetricsBuf.snapshot`, on demand.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

# Shared bucket count for picked-(n, k) histograms across the sweep engines.
# Codes in the repro use n well below 32; the last bucket absorbs the clip.
PICK_BINS = 33


def _union(a: dict, b: dict, op) -> dict:
    out = dict(a)
    for k, v in b.items():
        out[k] = op(out[k], v) if k in out else v
    return out


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class MetricsBuf:
    """Counters + fixed-bucket histograms + high-water marks as device arrays.

    counters: name -> () int32 running sum
    hists:    name -> (B,) int32; a value v lands in bucket clip(int(v), 0, B-1)
    highs:    name -> () float32 running max (non-negative quantities; zeros init)
    """

    counters: dict
    hists: dict
    highs: dict

    @classmethod
    def zeros(cls, counters=(), hists=None, highs=()) -> "MetricsBuf":
        return cls(
            counters={n: jnp.zeros((), jnp.int32) for n in counters},
            hists={n: jnp.zeros((int(b),), jnp.int32) for n, b in dict(hists or {}).items()},
            highs={n: jnp.zeros((), jnp.float32) for n in highs},
        )

    # ---- in-trace updates -------------------------------------------------
    def count(self, name: str, by=1) -> "MetricsBuf":
        c = dict(self.counters)
        c[name] = c[name] + jnp.asarray(by, jnp.int32)
        return dataclasses.replace(self, counters=c)

    def observe(self, name: str, value, weight=None) -> "MetricsBuf":
        """Bucket scalar or vector values; repeated indices scatter-add.
        ``weight`` (same shape, int) scales each observation — pass a 0/1
        validity mask to drop padded entries without a dynamic shape."""
        h = dict(self.hists)
        idx = jnp.clip(jnp.asarray(value).astype(jnp.int32), 0, h[name].shape[-1] - 1)
        w = 1 if weight is None else jnp.asarray(weight, jnp.int32)
        h[name] = h[name].at[idx].add(w)
        return dataclasses.replace(self, hists=h)

    def high(self, name: str, value) -> "MetricsBuf":
        hi = dict(self.highs)
        v = jnp.asarray(value, jnp.float32)
        if v.ndim:
            v = v.max()
        hi[name] = jnp.maximum(hi[name], v)
        return dataclasses.replace(self, highs=hi)

    # ---- folds ------------------------------------------------------------
    def reduce_rows(self, rows: int | None = None) -> "MetricsBuf":
        """Fold a vmapped buf (leading batch axis on every leaf) to scalars:
        sum counters/hists, max highs.  ``rows`` drops the tail padding a
        chunk launch adds by repeating its last real row."""

        def cut(a):
            return a[:rows] if rows is not None else a

        return MetricsBuf(
            counters={n: cut(v).sum(axis=0) for n, v in self.counters.items()},
            hists={n: cut(v).sum(axis=0) for n, v in self.hists.items()},
            highs={n: cut(v).max(axis=0) for n, v in self.highs.items()},
        )

    def merge(self, other: "MetricsBuf") -> "MetricsBuf":
        """Union-merge: add counters/hists, max highs; disjoint names pass through."""
        return MetricsBuf(
            counters=_union(self.counters, other.counters, lambda a, b: a + b),
            hists=_union(self.hists, other.hists, lambda a, b: a + b),
            highs=_union(self.highs, other.highs, jnp.maximum),
        )

    # ---- export -----------------------------------------------------------
    def snapshot(self) -> dict:
        """The one host sync: device arrays -> plain python dicts."""
        return {
            "counters": {n: int(np.asarray(v)) for n, v in self.counters.items()},
            "hists": {n: np.asarray(v).astype(int).tolist() for n, v in self.hists.items()},
            "highs": {n: float(np.asarray(v)) for n, v in self.highs.items()},
        }

    def to_prometheus(self, prefix: str = "repro",
                      labels: dict | None = None) -> str:
        return to_prometheus(self.snapshot(), prefix=prefix, labels=labels)


def _escape_label_value(v) -> str:
    """Prometheus exposition-format label-value escaping (backslash first)."""
    return (
        str(v).replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")
    )


def _label_str(labels: dict | None, extra: str = "") -> str:
    parts = [f'{k}="{_escape_label_value(v)}"'
             for k, v in sorted((labels or {}).items())]
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


def to_prometheus(snap: dict, prefix: str = "repro",
                  labels: dict | None = None) -> str:
    """Prometheus-style text exposition of a :meth:`MetricsBuf.snapshot`.

    Each metric family carries its ``# HELP`` / ``# TYPE`` header lines.
    ``labels`` (e.g. ``{"engine": "fleet"}``) are attached to every sample
    with exposition-format value escaping.  Histogram buckets are
    unit-width (`le="i"` covers values <= i); the last bucket is `+Inf`
    (clipped tail), so cumulative counts are monotone.
    """
    lines = []
    base = _label_str(labels)
    for n, v in sorted(snap.get("counters", {}).items()):
        name = f"{prefix}_{n}_total"
        lines.append(f"# HELP {name} Running count of '{n}'.")
        lines.append(f"# TYPE {name} counter")
        lines.append(f"{name}{base} {v}")
    for n, buckets in sorted(snap.get("hists", {}).items()):
        name = f"{prefix}_{n}"
        lines.append(f"# HELP {name} Fixed-bucket histogram of '{n}'.")
        lines.append(f"# TYPE {name} histogram")
        cum = 0
        for i, c in enumerate(buckets):
            cum += int(c)
            le = "+Inf" if i == len(buckets) - 1 else str(i)
            le_labels = _label_str(labels, 'le="%s"' % le)
            lines.append(f"{name}_bucket{le_labels} {cum}")
        lines.append(f"{name}_count{base} {cum}")
    for n, v in sorted(snap.get("highs", {}).items()):
        name = f"{prefix}_{n}"
        lines.append(f"# HELP {name} High-water mark of '{n}'.")
        lines.append(f"# TYPE {name} gauge")
        lines.append(f"{name}{base} {v}")
    return "\n".join(lines) + "\n"


def sweep_point_metrics(out: dict, prefix: str, valid=None) -> "MetricsBuf":
    """Per-case metrics derived from a scan-core output dict inside the
    vmapped ``one`` — requests served, tasks issued, picked-(n, k)
    histograms, and the worst per-request delay.  Traced alongside the
    primary outputs; the launcher folds it per chunk.

    ``valid`` is a (T,) boolean mask marking real arrivals: chunked
    launches pad the time axis to the pow2 bucket (`obs_count` config
    rows carry the true count), and padded steps must not be counted."""
    n = out["n"]
    k = out["k"]
    if valid is None:
        valid = jnp.ones(n.shape[-1], bool)
    w = valid.astype(jnp.int32)
    buf = MetricsBuf.zeros(
        counters=(f"{prefix}_requests", f"{prefix}_tasks"),
        hists={f"{prefix}_pick_n": PICK_BINS, f"{prefix}_pick_k": PICK_BINS},
        highs=(f"{prefix}_delay_hi",),
    )
    buf = buf.count(f"{prefix}_requests", w.sum())
    buf = buf.count(f"{prefix}_tasks", (n.astype(jnp.int32) * w).sum())
    buf = buf.observe(f"{prefix}_pick_n", n, weight=w)
    buf = buf.observe(f"{prefix}_pick_k", k, weight=w)
    buf = buf.high(f"{prefix}_delay_hi", jnp.where(valid, out["total"], 0.0))
    return buf


def valid_mask(cfg: dict, horizon: int):
    """(T,) mask of real arrivals from the per-case ``obs_count`` row the
    sweeps add when collection is on (None when absent)."""
    cnt = cfg.get("obs_count")
    if cnt is None:
        return None
    return jnp.arange(horizon) < cnt
