"""Shared on-device reduction helpers for the sweep frontiers.

:func:`masked_percentiles` is the single implementation of the
sort-and-gather percentile reduction that used to live twice — inline in
``repro.fleet.frontier`` (unmasked ``jnp.percentile``) and in
``repro.sched.frontier`` (class-masked sort + gather). All three frontier
modules (fleet, sched, taskq) now route through this one:

* values outside ``mask`` are pushed to ``BIG`` before the sort, so they
  sort past every real sample and never enter a gather;
* the gather index is ``floor(q/100 · (count−1))`` — lower-interpolation
  percentiles, exact order statistics of the masked sample (no
  interpolation between neighbors, so the result is always a value that
  actually occurred);
* rows whose mask is empty report 0.0, matching their masked means.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

#: Finite stand-in for +inf in float32 sorts (sorts past any real delay).
BIG = float(np.finfo(np.float32).max)


def masked_percentiles(x, qs, mask=None):
    """(G, T) values → (G, len(qs)) lower-interpolation percentiles.

    ``mask`` (G, T) bool restricts each row to a subsample (e.g. one class
    of a multi-class stream); ``None`` reduces over whole rows. Traceable —
    safe inside jitted reductions.
    """
    qs = jnp.asarray(qs, jnp.float32)
    T = x.shape[1]
    if mask is None:
        cnt = jnp.full((x.shape[0],), T, jnp.int32)
        srt = jnp.sort(x, axis=1)
    else:
        cnt = jnp.sum(mask, axis=1).astype(jnp.int32)
        srt = jnp.sort(jnp.where(mask, x, BIG), axis=1)
    idx = jnp.clip(
        (qs[:, None] / 100.0 * (cnt[None, :] - 1)).astype(jnp.int32), 0, T - 1
    )  # (len(qs), G)
    # An empty subsample would gather the BIG sentinel; report 0.0 instead
    # (matching the corresponding masked mean).
    return jnp.where(
        cnt[:, None] > 0, jnp.take_along_axis(srt, idx.T, axis=1), 0.0
    )  # (G, len(qs))
