"""Proxy batched read path: admission-round decode, raw reads, and the
paper's heavy-load adaptation (backlog pressure → fewer/larger chunks)."""

import threading

import numpy as np

from repro.coding.layout import SharedKeyLayout
from repro.core import (
    PAPER_READ_3MB,
    FeedbackPolicy,
    RequestClass,
    StaticPolicy,
    TOFECPolicy,
)
from repro.storage import (
    FaultyStore,
    MemoryStore,
    Proxy,
    StorageError,
    store_coded_object,
)

LAYOUT = SharedKeyLayout(K=6, r=2, strip_bytes=128)


class _GatedStore(MemoryStore):
    """Deterministic fake store: ranged reads block until the gate opens,
    with a controllable post-gate delay. Lets a test pile up a backlog of
    known size before ANY task completes."""

    def __init__(self):
        super().__init__()
        self.gate = threading.Event()
        self.range_calls = 0
        self._count_lock = threading.Lock()

    def get_range(self, key, offset, length):
        self.gate.wait()
        with self._count_lock:
            self.range_calls += 1
        return super().get_range(key, offset, length)


def _payloads(rng, count, nbytes):
    return [rng.integers(0, 256, size=nbytes, dtype=np.uint8).tobytes() for _ in range(count)]


def test_read_many_batch_decodes_heterogeneous_erasures():
    """One round of concurrent reads with random per-item failures: every
    item reconstructs despite each surviving a different erasure pattern
    (the admission round's single batched decode path)."""
    rng = np.random.default_rng(0)
    inner = MemoryStore()
    payloads = _payloads(rng, 8, LAYOUT.file_bytes - 11)
    keys = []
    for i, p in enumerate(payloads):
        store_coded_object(inner, f"obj/{i}", LAYOUT, p)
        keys.append(f"obj/{i}")
    store = FaultyStore(inner, p_fail=0.15, seed=1)
    proxy = Proxy(store, StaticPolicy(12, 6), L=8)
    try:
        results = proxy.read_many(keys, LAYOUT, payload_len=len(payloads[0]))
        assert all(r.ok for r in results)
        for r, p in zip(results, payloads):
            assert r.data == p
    finally:
        proxy.close()


def test_raw_read_returns_chunks_for_external_decode():
    """raw=True skips proxy decode; the chunks round-trip through the
    layout's own reconstruct (what the fused serving step does in-jit)."""
    rng = np.random.default_rng(2)
    store = MemoryStore()
    payload = _payloads(rng, 1, LAYOUT.file_bytes)[0]
    store_coded_object(store, "raw/0", LAYOUT, payload)
    proxy = Proxy(store, StaticPolicy(6, 3), L=4)
    try:
        res = proxy.read("raw/0", LAYOUT, payload_len=len(payload), raw=True)
        assert res.ok and res.data is None
        assert res.chunks is not None and len(res.chunks) >= res.k
        got = LAYOUT.reconstruct(res.k, res.chunks, payload_len=len(payload))
        assert got == payload
    finally:
        proxy.close()


def test_mixed_chunk_levels_share_one_admission_round():
    """Reads admitted at different k levels all reconstruct correctly via
    the per-item present masks of the shared (N, K) strip code."""
    rng = np.random.default_rng(3)
    inner = MemoryStore()
    payloads = _payloads(rng, 6, LAYOUT.file_bytes)
    keys = []
    for i, p in enumerate(payloads):
        store_coded_object(inner, f"mix/{i}", LAYOUT, p)
        keys.append(f"mix/{i}")

    class _CyclePolicy(StaticPolicy):
        """Cycles the chunk level so one round mixes k = 6, 3, 2, 1."""

        def __init__(self):
            super().__init__(12, 6)
            self._cycle = [(12, 6), (6, 3), (4, 2), (2, 1), (3, 3), (2, 2)]
            self._i = 0

        def select(self, *, q, idle, cls_id=0, now=None):
            out = self._cycle[self._i % len(self._cycle)]
            self._i += 1
            return out

    proxy = Proxy(inner, _CyclePolicy(), L=8)
    try:
        results = proxy.read_many(keys, LAYOUT, payload_len=LAYOUT.file_bytes)
        assert all(r.ok for r in results)
        assert sorted({r.k for r in results}) == [1, 2, 3, 6]
        for r, p in zip(results, payloads):
            assert r.data == p
    finally:
        proxy.close()


def test_backlog_pressure_shifts_code_toward_fewer_chunks():
    """The paper's heavy-load behavior on the real-I/O proxy: as the gated
    backlog builds, TOFEC picks fewer/larger chunks (k drops from k_max
    toward 1), deterministically — selection happens at submission time
    while the store blocks every task."""
    rng = np.random.default_rng(4)
    store = _GatedStore()
    count = 24
    payloads = _payloads(rng, count, LAYOUT.file_bytes)
    keys = []
    for i, p in enumerate(payloads):
        store_coded_object(store, f"load/{i}", LAYOUT, p)
        keys.append(f"load/{i}")

    cls = RequestClass("gated", LAYOUT.file_bytes / 2**20, PAPER_READ_3MB,
                       k_max=6, r_max=2.0, n_max=12)
    proxy = Proxy(store, TOFECPolicy.for_classes([cls], L=8), L=8)
    try:
        # Submit the whole backlog while the store admits nothing.
        reqs = [proxy.read_async(k, LAYOUT, payload_len=LAYOUT.file_bytes) for k in keys]
        store.gate.set()
        results = [proxy.wait(r, timeout=60.0) for r in reqs]
        assert all(r.ok for r in results)
        for r, p in zip(results, payloads):
            assert r.data == p
        ks = [r.k for r in results]
        assert ks[0] == 6  # empty queue → max chunking (light-load optimum)
        assert ks[-1] == 1  # deep backlog → no chunking (heavy-load optimum)
        # Monotone non-increasing in submission order: the EWMA only grows
        # while the gate is closed (modulo the one-in-flight admission slot).
        assert all(b <= a + 1 for a, b in zip(ks, ks[1:]))
        assert {1, 6} <= set(ks)
    finally:
        proxy.close()


class _OffsetFailStore(MemoryStore):
    """Fails ranged reads for one key past a byte offset — a deterministic
    'this object lost most of its strips' fault."""

    def __init__(self, bad_key, max_offset):
        super().__init__()
        self.bad_key = bad_key
        self.max_offset = max_offset

    def get_range(self, key, offset, length):
        if key == self.bad_key and offset >= self.max_offset:
            raise StorageError(f"simulated loss: {key}@{offset}")
        return super().get_range(key, offset, length)


def test_raw_batch_surfaces_per_item_error_mask():
    """A partially-failed item in a raw batch reports ok=False with its
    surviving chunks, while the rest of the batch completes normally —
    per-item error mask, not an all-or-nothing batch failure."""
    rng = np.random.default_rng(7)
    payloads = _payloads(rng, 4, LAYOUT.file_bytes)
    # chunks 0-3 of the k=6 level survive; 4-11 are gone → < k readable
    store = _OffsetFailStore("part/1", 4 * LAYOUT.strip_bytes)
    keys = []
    for i, p in enumerate(payloads):
        store_coded_object(store, f"part/{i}", LAYOUT, p)
        keys.append(f"part/{i}")
    proxy = Proxy(store, StaticPolicy(12, 6), L=8)
    try:
        results = proxy.read_many(keys, LAYOUT, payload_len=LAYOUT.file_bytes,
                                  raw=True)
        assert [r.ok for r in results] == [True, False, True, True]
        bad = results[1]
        assert bad.chunks is not None and 0 < len(bad.chunks) < bad.k
        for ci, blob in bad.chunks.items():  # what arrived is still intact
            off, ln = LAYOUT.chunk_range(bad.k, ci)
            assert blob == payloads[1][0:0] + store.get("part/1")[off:off + ln]
        for r, p in zip(results, payloads):
            if r.ok:
                got = LAYOUT.reconstruct(r.k, r.chunks, payload_len=len(p))
                assert got == p
    finally:
        proxy.close()


def test_closed_write_path_recodes_after_midrun_switch():
    """Tentpole round-trip: the controller's fed-back (n, k) governs how the
    NEXT queued write is encoded, while objects written under the old code
    stay readable. Exercises write → flush → registry-guided read."""
    rng = np.random.default_rng(8)
    store = MemoryStore()
    wp = FeedbackPolicy(12, 6)
    proxy = Proxy(store, StaticPolicy(12, 6), L=8, write_policy=wp)
    pa = _payloads(rng, 1, LAYOUT.file_bytes)[0]
    pb = _payloads(rng, 1, LAYOUT.file_bytes)[0]
    try:
        ra = proxy.write("w/a", LAYOUT, pa)
        assert ra.ok and (ra.n, ra.k) == (12, 6)
        wp.push(2, 2)  # controller adapts: heavy load → fewer, larger chunks
        rb = proxy.write("w/b", LAYOUT, pb)
        assert rb.ok and (rb.n, rb.k) == (2, 2)
        proxy.flush_writes()
        # the two stored objects really are different codes of the shared
        # strip space: full (12,6) codeword vs the 2-chunk (k=2, m=3) prefix
        assert len(store.get("w/a")) == 12 * LAYOUT.strip_bytes
        assert len(store.get("w/b")) == 2 * 3 * LAYOUT.strip_bytes
        for key, p in [("w/a", pa), ("w/b", pb)]:
            res = proxy.read(key, LAYOUT, payload_len=len(p))
            assert res.ok and res.data == p
    finally:
        proxy.close()
