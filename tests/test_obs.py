"""repro.obs: collection invariance (bit-identical primary outputs and
pinned compile counts with telemetry on), device-folded histogram
correctness against host recounts, exact taskq cancellation accounting,
span-tree nesting + Chrome-trace JSON validity, the Prometheus formatter,
the shared CompileStats registry, and the perf-gate comparison rules."""

import json

import numpy as np
import pytest

import jax.numpy as jnp

from repro import obs
from repro.core import PAPER_READ_3MB, RequestClass
from repro.core.traces import TraceStore
from repro.fleet import FleetSweep, PolicySpec, grid_cases
from repro.taskq import TaskqSweep

CLS = RequestClass("read3mb", 3.0, PAPER_READ_3MB, k_max=6, r_max=2.0, n_max=12)
L = 16
SIZES = tuple(CLS.file_mb / k for k in range(1, CLS.k_max + 1))


@pytest.fixture
def obs_on():
    obs.set_enabled(True)
    obs.reset_trace()
    yield
    obs.set_enabled(None)
    obs.reset_trace()


@pytest.fixture
def obs_off():
    obs.set_enabled(False)
    yield
    obs.set_enabled(None)


def _pools(seed=3, samples=512):
    store = TraceStore.generate(
        PAPER_READ_3MB, SIZES, threads=CLS.n_max, samples=samples,
        correlation=0.0, seed=seed,
    )
    return store.device_pools(n_max=CLS.n_max)


def _grid(n_seeds=2):
    return grid_cases(
        [10.0, 25.0], [PolicySpec.tofec(), PolicySpec.static(12, 6)],
        list(range(n_seeds)), CLS, L,
    )


# ---------------------------------------------------------------------------
# MetricsBuf: host-visible semantics of the device folds
# ---------------------------------------------------------------------------


def test_metricsbuf_count_observe_high_snapshot():
    buf = obs.MetricsBuf.zeros(counters=("c",), hists={"h": 4}, highs=("hi",))
    buf = buf.count("c", 3).count("c")
    buf = buf.observe("h", jnp.array([0, 1, 1, 9]))  # 9 clips to last bucket
    buf = buf.observe("h", jnp.array([2, 2]), weight=jnp.array([1, 0]))
    buf = buf.high("hi", jnp.array([1.5, 7.25, 0.0])).high("hi", 2.0)
    snap = buf.snapshot()
    assert snap["counters"]["c"] == 4
    assert snap["hists"]["h"] == [1, 2, 1, 1]
    assert snap["highs"]["hi"] == 7.25


def test_metricsbuf_reduce_rows_drops_tail_padding():
    buf = obs.MetricsBuf(
        counters={"c": jnp.array([1, 2, 99], jnp.int32)},
        hists={"h": jnp.array([[1, 0], [0, 1], [5, 5]], jnp.int32)},
        highs={"hi": jnp.array([1.0, 3.0, 9.0], jnp.float32)},
    )
    snap = buf.reduce_rows(2).snapshot()
    assert snap["counters"]["c"] == 3
    assert snap["hists"]["h"] == [1, 1]
    assert snap["highs"]["hi"] == 3.0


def test_metricsbuf_merge_unions_disjoint_and_adds_shared():
    a = obs.MetricsBuf.zeros(counters=("x",), highs=("hi",)).count("x", 2)
    b = obs.MetricsBuf.zeros(counters=("x", "y"), highs=("hi",))
    b = b.count("x", 5).count("y", 1).high("hi", 4.0)
    snap = a.merge(b).snapshot()
    assert snap["counters"] == {"x": 7, "y": 1}
    assert snap["highs"]["hi"] == 4.0


def test_prometheus_exposition_shape():
    buf = obs.MetricsBuf.zeros(counters=("reqs",), hists={"q": 3}, highs=("q_hi",))
    buf = buf.count("reqs", 2).observe("q", jnp.array([0, 2, 2])).high("q_hi", 2.0)
    text = buf.to_prometheus(prefix="t")
    assert "# TYPE t_reqs_total counter" in text
    assert "t_reqs_total 2" in text
    # cumulative buckets, +Inf tail, count line
    assert 't_q_bucket{le="0"} 1' in text
    assert 't_q_bucket{le="+Inf"} 3' in text
    assert "t_q_count 3" in text
    assert "t_q_hi 2.0" in text


# ---------------------------------------------------------------------------
# Sweep collection: invariance, padding masks, host recounts
# ---------------------------------------------------------------------------


def test_fleet_collection_invariant_and_histograms_match_host_recount():
    cases, count = _grid(), 300  # pads to a larger pow2 time bucket
    try:
        obs.set_enabled(False)
        base = FleetSweep(chunk=4).run(cases, count)
        obs.set_enabled(True)
        res = FleetSweep(chunk=4).run(cases, count)
    finally:
        obs.set_enabled(None)
    # Primary outputs are bit-identical with collection on.
    for name in base.out:
        np.testing.assert_array_equal(
            np.asarray(base.out[name]), np.asarray(res.out[name]))
    # Collection costs no extra compiles (the collect flag is in the key).
    assert res.compiles == base.compiles
    assert base.metrics is None and res.metrics is not None
    snap = res.metrics.snapshot()
    G = len(cases)
    # Padded steps masked out: exact request/task tallies.
    assert snap["counters"]["fleet_requests"] == G * count
    ks = np.asarray(res.out["k"])[:, :count].astype(int)
    ns = np.asarray(res.out["n"])[:, :count].astype(int)
    assert snap["counters"]["fleet_tasks"] == int(ns.sum())
    np.testing.assert_array_equal(
        snap["hists"]["fleet_pick_k"],
        np.bincount(ks.ravel(), minlength=obs.PICK_BINS))
    np.testing.assert_array_equal(
        snap["hists"]["fleet_pick_n"],
        np.bincount(ns.ravel(), minlength=obs.PICK_BINS))
    assert snap["highs"]["fleet_delay_hi"] == pytest.approx(
        float(np.asarray(res.out["total"])[:, :count].max()), rel=1e-6)


def test_taskq_collection_invariant_with_exact_cancellations(obs_on):
    cases, count = _grid(n_seeds=1), 200
    dp = _pools()
    obs.set_enabled(False)
    base = TaskqSweep(chunk=4).run(cases, count, dp)
    obs.set_enabled(True)
    res = TaskqSweep(chunk=4).run(cases, count, dp)
    for name in base.out:
        np.testing.assert_array_equal(
            np.asarray(base.out[name]), np.asarray(res.out[name]))
    assert res.compiles == base.compiles == 1
    snap = res.metrics.snapshot()
    G = len(cases)
    assert snap["counters"]["taskq_requests"] == G * count
    ns = np.asarray(res.out["n"])[:, :count].astype(int)
    ks = np.asarray(res.out["k"])[:, :count].astype(int)
    c = snap["counters"]
    # Cancel RPCs split exactly into queued vs in-service; ties C == D
    # complete with the request, so the total can undershoot Σ(n−k).
    assert c["taskq_cancelled"] == c["taskq_cancel_queue"] + c["taskq_cancel_service"]
    assert 0 < c["taskq_cancelled"] <= int((ns - ks).sum())
    # Idle-thread histogram counts every real arrival once.
    assert sum(snap["hists"]["taskq_idle"]) == G * count
    assert len(snap["hists"]["taskq_idle"]) == L + 1
    assert snap["highs"]["taskq_q_hi"] >= 0.0


def test_taskq_scan_entry_point_collect_arg(obs_off):
    from repro.taskq.engine import taskq_scan
    from repro.taskq.policies import encode_policy

    case = _grid(n_seeds=1)[0]
    dp = _pools()
    enc = encode_policy(PolicySpec.static(12, 6), CLS, L, CLS.k_max + 1,
                        CLS.n_max + 1, None)
    cfg = {"J": CLS.file_mb, "alpha": enc.alpha, "r_max": enc.r_max,
           "pol": enc.pol, "gk_max": enc.gk_max, "h_k": enc.h_k,
           "h_n": enc.h_n}
    from repro.taskq import taskq_streams
    inter, idx = taskq_streams(case, 64, dp.n_rows)
    off = taskq_scan(cfg, inter, idx, dp.pools, dp.sizes_mb, L=L)
    on = taskq_scan(cfg, inter, idx, dp.pools, dp.sizes_mb, L=L, collect=True)
    assert "obs" not in off and "obs" in on
    for name in off:
        np.testing.assert_array_equal(np.asarray(off[name]), np.asarray(on[name]))


# ---------------------------------------------------------------------------
# Closed-loop serving: device metrics ride the fused step
# ---------------------------------------------------------------------------


def _serve_tokens(rounds=2, steps=2):
    import jax

    from repro.coding.codec import Codec
    from repro.coding.layout import SharedKeyLayout
    from repro.core import FeedbackPolicy, StaticPolicy
    from repro.models import get
    from repro.serve import ClosedLoopServer, FusedServingStep, ServePolicy, ServingEngine
    from repro.storage import MemoryStore, Proxy

    arch = get("qwen1.5-0.5b", smoke=True)
    params = arch.init(jax.random.key(2))
    eng = ServingEngine(arch, params, max_seq=64)
    prompt_len = 16
    layout = SharedKeyLayout(K=4, r=2, strip_bytes=prompt_len)
    store = MemoryStore()
    rng = np.random.default_rng(6)
    keys = []
    for i in range(3):
        toks = rng.integers(0, arch.cfg.vocab, size=(prompt_len,)).astype(np.int32)
        ServingEngine.store_prompt(store, f"p/{i}", layout, toks)
        keys.append(f"p/{i}")
    proxy = Proxy(store, StaticPolicy(8, 4), L=8,
                  write_policy=FeedbackPolicy(layout.N, layout.K))
    step = FusedServingStep.for_policy(ServePolicy.tofec(), CLS, L,
                                       codec=Codec("jnp"))
    server = ClosedLoopServer(eng, proxy, layout, step, prompt_len=prompt_len)
    try:
        results = [server.serve_round(keys, steps=steps) for _ in range(rounds)]
        return [np.asarray(r.tokens) for r in results], server
    finally:
        proxy.close()


def test_closed_loop_metrics_invariant_and_exact(tmp_path):
    obs.set_enabled(False)
    try:
        toks_off, server_off = _serve_tokens()
    finally:
        obs.set_enabled(None)
    obs.set_enabled(True)
    obs.reset_trace()
    try:
        toks_on, server_on = _serve_tokens()
        # Generated tokens bit-identical with collection on; still one trace.
        for a, b in zip(toks_off, toks_on):
            np.testing.assert_array_equal(a, b)
        assert server_on.traces == server_off.traces == 1
        assert server_off.metrics is None
        snap = server_on.metrics.snapshot()
        c = snap["counters"]
        assert c["serve_rounds"] == 2
        assert c["serve_requested"] == 2 * 3
        assert c["serve_served"] == 2 * 3
        assert c["serve_decode_errors"] == 0
        assert sum(snap["hists"]["serve_batch"]) == 2
        assert sum(snap["hists"]["serve_pick_n"]) == 2
        assert snap["highs"]["serve_q_hi"] >= 0.0
        # The round's host spans export as a loadable Chrome trace.
        names = {ev["name"] for ev in obs.get_tracer().events()}
        assert {"serve.round", "serve.fetch", "serve.launch"} <= names
        path = obs.write_trace(str(tmp_path / "serve_trace.json"))
        doc = json.load(open(path))
        assert any(ev["name"] == "serve.round" for ev in doc["traceEvents"])
        # Prometheus exposition of the same snapshot is well-formed.
        assert "repro_serve_rounds_total 2" in obs.to_prometheus(snap)
    finally:
        obs.set_enabled(None)
        obs.reset_trace()


# ---------------------------------------------------------------------------
# Span tracing
# ---------------------------------------------------------------------------


def test_span_nesting_and_chrome_trace_json(obs_on, tmp_path):
    tr = obs.get_tracer()
    with obs.span("outer", mesh=[1]):
        with obs.span("inner", bucket="(4, 64)"):
            pass
        with obs.span("inner"):
            pass
    by_name: dict = {}
    for ev in tr.events():  # spans record at exit: inner events come first
        by_name.setdefault(ev["name"], []).append(ev)
    (outer,), inners = by_name["outer"], by_name["inner"]
    assert outer["args"]["depth"] == 0
    assert outer["args"]["parent"] is None
    assert all(ev["args"]["depth"] == 1 for ev in inners)
    assert all(ev["args"]["parent"] == "outer" for ev in inners)
    assert inners[0]["args"]["bucket"] == "(4, 64)"
    # Chrome trace_event document: loads back, complete events, µs fields.
    path = obs.write_trace(str(tmp_path / "trace.json"))
    doc = json.load(open(path))
    assert doc["displayTimeUnit"] == "ms"
    assert len(doc["traceEvents"]) == 3
    for ev in doc["traceEvents"]:
        assert ev["ph"] == "X" and ev["dur"] >= 0 and "pid" in ev and "tid" in ev
    agg = obs.aggregate()
    assert agg["inner"]["count"] == 2
    assert agg["outer"]["total_us"] >= agg["outer"]["max_us"]
    assert "outer" in tr.format_table()


def test_spans_disabled_record_nothing():
    obs.set_enabled(False)
    obs.reset_trace()
    try:
        with obs.span("never"):
            pass
        assert obs.get_tracer().events() == []
    finally:
        obs.set_enabled(None)


def test_traced_decorator(obs_on):
    calls = []

    @obs.traced("deco.fn", tag=1)
    def fn(x):
        calls.append(x)
        return x + 1

    assert fn(1) == 2 and calls == [1]
    ev = [e for e in obs.get_tracer().events() if e["name"] == "deco.fn"]
    assert len(ev) == 1 and ev[0]["args"]["tag"] == 1


def test_sweep_run_emits_spans(obs_on):
    FleetSweep(chunk=4).run(_grid(n_seeds=1), 64)
    names = {ev["name"] for ev in obs.get_tracer().events()}
    assert {"sweep.chunk", "sweep.launch", "sweep.trace"} <= names


# ---------------------------------------------------------------------------
# Shared compile accounting + run metadata
# ---------------------------------------------------------------------------


def test_compile_stats_registry_and_aliases():
    s = obs.CompileStats(label="test.engine")
    s.traces += 2
    s.launches += 5
    snap = obs.compile_snapshot()
    assert snap["test.engine"]["traces"] == 2
    assert snap["test.engine"]["launches"] == 5
    # Back-compat aliases still resolve to the shared class.
    from repro.coding.codec import CodecStats
    from repro.fleet.sweep import SweepStats
    assert SweepStats is obs.CompileStats and CodecStats is obs.CompileStats


def test_run_meta_fields():
    meta = obs.run_meta(mesh_shape=(2, 4))
    assert meta["schema_version"] == obs.SCHEMA_VERSION
    assert meta["host_cores"] >= 1 and meta["host_devices"] >= 1
    assert meta["mesh_shape"] == [2, 4]
    rev = meta["git_rev"]
    assert rev is None or (isinstance(rev, str) and len(rev) >= 7)


# ---------------------------------------------------------------------------
# Perf gate: comparison rules
# ---------------------------------------------------------------------------


def test_gate_rules(tmp_path):
    from benchmarks import gate

    art = {
        "schema": "repro.fleet/BENCH_fleet/v1",
        "grid_size": 8, "count": 256, "compiles": 1, "launches": 2,
        "capacity_req_s": {"tofec": 30.0},
        "headline": {"delay_gain_vs_basic": 2.5},
    }
    res_dir, base_dir = tmp_path / "res", tmp_path / "base"
    res_dir.mkdir()
    (res_dir / "BENCH_fleet.json").write_text(json.dumps(art))
    # No baseline: passes with a note.
    assert gate.check(str(res_dir), str(base_dir)) == 0
    gate.update(str(res_dir), str(base_dir))
    assert gate.check(str(res_dir), str(base_dir)) == 0
    # Count drift fails exactly; stat drift fails past the tolerance.
    bad = dict(art, compiles=2,
               headline={"delay_gain_vs_basic": 2.5 * 1.2})
    (res_dir / "BENCH_fleet.json").write_text(json.dumps(bad))
    assert gate.check(str(res_dir), str(base_dir)) == 1
    fails, warns, notes = gate.check_file(
        str(res_dir / "BENCH_fleet.json"),
        str(base_dir / "BENCH_fleet.json"))
    assert len(fails) == 2 and not warns
    # Within-tolerance stat drift passes.
    ok = dict(art, headline={"delay_gain_vs_basic": 2.5 * 1.05})
    (res_dir / "BENCH_fleet.json").write_text(json.dumps(ok))
    assert gate.check(str(res_dir), str(base_dir)) == 0


# ---------------------------------------------------------------------------
# TimelineBuf: ring semantics, windows, percentile recovery
# ---------------------------------------------------------------------------


def test_timeline_window_rule():
    # max(T_bucket // TIMELINE_SLOTS, 1): derived from the pow2 time bucket.
    assert obs.timeline_window(64) == 1
    assert obs.timeline_window(8) == 1
    assert obs.timeline_window(512) == 8
    assert obs.timeline_window(1024) == 16


def test_timelinebuf_ring_wrap_restores_order():
    buf = obs.TimelineBuf.zeros(4, series=("x",), hists={"h": 3})
    for i in range(6):
        buf = buf.append({"x": float(i)},
                         {"h": (jnp.array([i % 3]), jnp.array([1]))})
    snap = buf.snapshot()
    # Wrapped ring: the last 4 appends survive, oldest first.
    assert snap["slots"] == 4 and snap["pos"] == 6
    np.testing.assert_array_equal(snap["series"]["x"], [2.0, 3.0, 4.0, 5.0])
    np.testing.assert_array_equal(snap["hists"]["h"].sum(axis=1), [1, 1, 1, 1])
    np.testing.assert_array_equal(
        np.argmax(snap["hists"]["h"], axis=1), [2, 0, 1, 2])


def test_timelinebuf_concat_validates_slotting():
    a = obs.TimelineBuf.zeros(4, series=("x",), window=2)
    b = obs.TimelineBuf.zeros(8, series=("x",), window=2)
    with pytest.raises(ValueError, match="slotting"):
        a.concat(b)


def test_hist_percentile_and_rolling():
    from repro.obs.timeline import bucket_edges

    edges = bucket_edges()
    h = np.zeros((2, obs.DELAY_BINS))
    h[0, 10] = 99
    h[0, 50] = 1
    # p50 of row 0 sits in bucket 10; p999 reaches the lone tail observation.
    p = obs.hist_percentile(h, 0.5)
    assert p[0] == edges[10]
    assert obs.hist_percentile(h, 0.999)[0] == edges[50]
    assert np.isnan(p[1])  # empty row -> NaN, not garbage
    # Rolling window 2: row 1 sees row 0's mass.
    r = obs.rolling_percentile(h, 0.5, window=2)
    assert r[1] == edges[10]


# ---------------------------------------------------------------------------
# Sweep timelines: host recounts, stream/mesh invariance
# ---------------------------------------------------------------------------


def test_fleet_timeline_matches_host_recount(obs_on):
    cases, count = _grid(n_seeds=1), 300  # pads into the pow2 bucket
    res = FleetSweep(chunk=4).run(cases, count)
    assert res.timeline is not None
    snap = res.timeline.snapshot()
    G = len(cases)
    window, S = snap["window"], snap["capacity"]
    T_b = window * S
    assert T_b >= count
    assert snap["series"]["pick_n"].shape == (G, S)
    # Padding is masked: per-case served sums to the real arrival count.
    np.testing.assert_array_equal(snap["series"]["served"].sum(axis=1),
                                  np.full(G, count))
    # Host recount of the windowed pick mean and the delay-histogram deltas.
    # The stacked outputs come back cut to `count`; re-pad to the bucket
    # (padded entries carry zero weight, so the pad value is inert).
    w = (np.arange(T_b) < count).astype(np.float32)
    cnt = w.reshape(S, window).sum(axis=1)
    ns = np.zeros((G, T_b), np.float32)
    ns[:, :count] = np.asarray(res.out["n"], np.float32)
    num = (ns * w).reshape(G, S, window).sum(axis=2)
    expect = np.where(cnt > 0, num / np.maximum(cnt, 1.0), 0.0)
    np.testing.assert_allclose(snap["series"]["pick_n"], expect, rtol=1e-5)
    tot = np.ones((G, T_b), np.float32)
    tot[:, :count] = np.asarray(res.out["total"], np.float32)
    idx = np.asarray(obs.delay_bucket(jnp.asarray(tot)))
    win_idx = np.arange(T_b) // window
    for g in range(G):
        h = np.zeros((S, obs.DELAY_BINS), np.int64)
        np.add.at(h, (win_idx, idx[g]), w.astype(np.int64))
        np.testing.assert_array_equal(snap["hists"]["delay"][g], h)


def test_fleet_streamed_timeline_bit_exact(obs_on):
    cases, count = _grid(n_seeds=1), 256
    mat = FleetSweep(chunk=2).run(cases, count)
    st = FleetSweep(chunk=2).run(cases, count, stream=True)
    a, b = mat.timeline.snapshot(), st.timeline.snapshot()
    assert set(a["series"]) == set(b["series"])
    for name in a["series"]:
        np.testing.assert_array_equal(a["series"][name], b["series"][name])
    np.testing.assert_array_equal(a["hists"]["delay"], b["hists"]["delay"])


def test_taskq_timeline_backlog_series(obs_on):
    cases, count = _grid(n_seeds=1), 200
    res = TaskqSweep(chunk=4).run(cases, count, _pools())
    snap = res.timeline.snapshot()
    G = len(cases)
    assert "backlog" in snap["series"]  # the scan's exact per-arrival queue
    np.testing.assert_array_equal(snap["series"]["served"].sum(axis=1),
                                  np.full(G, count))
    assert (snap["series"]["backlog"] >= 0).all()
    assert snap["hists"]["delay"].sum() == G * count


def test_sweep_timeline_rejects_bad_window():
    out = {"total": jnp.ones(10), "n": jnp.ones(10), "k": jnp.ones(10)}
    with pytest.raises(ValueError, match="not divisible"):
        obs.sweep_timeline(out, jnp.ones(10), window=3)


# ---------------------------------------------------------------------------
# Serve timeline + SLO/convergence monitor
# ---------------------------------------------------------------------------


def test_serve_timeline_and_slo_report():
    obs.set_enabled(True)
    obs.reset_trace()
    try:
        toks, server = _serve_tokens(rounds=3)
        assert server.traces == 1  # the collect variant still compiles once
        snap = server.timeline.snapshot()
        assert snap["window"] == 1 and snap["slots"] == 3
        np.testing.assert_array_equal(snap["series"]["served"], [3, 3, 3])
        np.testing.assert_array_equal(snap["hists"]["delay"].sum(axis=1),
                                      [3, 3, 3])
        assert (snap["series"]["pick_n"] >= snap["series"]["pick_k"]).all()
        spec = obs.SLOSpec(target_s=60.0, percentile=0.99, window=2)
        report = obs.slo_report(snap, spec, label="t")
        conv = report["convergence"]
        assert conv["settled"] and 0 <= conv["settle_slot"] < 3
        assert conv["dwell_final"] > 0
        assert report["max_burn_rate"] == 0.0  # nothing violates a 60 s target
        assert report["percentile_last_s"] > 0
        kinds = [e["kind"] for e in report["events"].events]
        assert "controller_converged" in kinds and "slo_breach" not in kinds
    finally:
        obs.set_enabled(None)
        obs.reset_trace()


def test_serve_timeline_absent_when_disabled(obs_off):
    _, server = _serve_tokens(rounds=1)
    assert server.timeline is None


def test_slo_burn_rate_and_breach_events(obs_on, tmp_path):
    S = 8
    hist = np.zeros((S, obs.DELAY_BINS), int)
    hist[:4, 0] = 100                      # fast slots
    hist[4:, obs.DELAY_BINS - 1] = 100     # every request blows the target
    snap = {"window": 1, "capacity": S, "slots": S, "pos": S,
            "series": {"pick_n": np.full(S, 8.0), "pick_k": np.full(S, 4.0)},
            "hists": {"delay": hist}}
    spec = obs.SLOSpec(target_s=1.0, percentile=0.99, window=2)
    events = obs.EventLog("synthetic")
    report = obs.slo_report(snap, spec, label="synthetic", events=events)
    burn = np.asarray(report["burn_rate"])
    assert (burn[:4] == 0).all() and (burn[4:] >= 1.0).all()
    assert report["breach_slots"] == 4
    kinds = [e["kind"] for e in events.events]
    assert kinds.count("slo_breach") == 1  # one edge event, not 4
    conv = report["convergence"]
    assert conv == {"settle_slot": 0, "settled": True, "final_code": [8, 4],
                    "dwell": {"8/4": 1.0}, "dwell_final": 1.0}
    # NDJSON export: one schema-tagged object per line.
    path = events.write(str(tmp_path / "events.ndjson"))
    lines = [json.loads(ln) for ln in open(path)]
    assert all(ev["schema"] == "repro.obs/event/v1" for ev in lines)
    assert {ev["kind"] for ev in lines} == {"slo_breach", "controller_converged"}
    # Breach events mirror into the span trace as instant marks.
    marks = [e for e in obs.get_tracer().events() if e.get("ph") == "i"]
    assert any(e["name"] == "obs.slo_breach" for e in marks)


def test_slo_recovery_edge():
    hist = np.zeros((6, obs.DELAY_BINS), int)
    hist[1, obs.DELAY_BINS - 1] = 100  # breach slot 1, recover when it ages out
    hist[2:, 0] = 100
    snap = {"window": 1, "capacity": 6, "slots": 6, "pos": 6,
            "series": {"pick_n": np.full(6, 4.0), "pick_k": np.full(6, 2.0)},
            "hists": {"delay": hist}}
    report = obs.slo_report(snap, obs.SLOSpec(target_s=1.0, window=1),
                            label="edge")
    kinds = [e["kind"] for e in report["events"].events]
    assert kinds.count("slo_breach") == 1 and kinds.count("slo_recovered") == 1


# ---------------------------------------------------------------------------
# Prometheus exposition hygiene
# ---------------------------------------------------------------------------


def test_prometheus_help_type_and_label_escaping():
    buf = obs.MetricsBuf.zeros(counters=("reqs",), hists={"q": 2}, highs=("hi",))
    buf = buf.count("reqs", 1).observe("q", jnp.array([0])).high("hi", 1.0)
    text = buf.to_prometheus(prefix="t", labels={"run": 'a"b\\c\nd'})
    assert "# HELP t_reqs_total Running count of 'reqs'." in text
    assert "# TYPE t_reqs_total counter" in text
    assert "# TYPE t_q histogram" in text
    assert "# TYPE t_hi gauge" in text
    esc = 'run="a\\"b\\\\c\\nd"'
    assert "t_reqs_total{" + esc + "} 1" in text
    assert "t_q_bucket{" + esc + ',le="0"} 1' in text
    assert "t_q_count{" + esc + "} 1" in text
    # No labels: bare sample names, headers still present.
    bare = buf.to_prometheus(prefix="t")
    assert "t_reqs_total 1" in bare and "# TYPE t_q histogram" in bare


# ---------------------------------------------------------------------------
# Trace hygiene: unclosed spans, instant marks
# ---------------------------------------------------------------------------


def test_unclosed_spans_autoclose_and_warn_once(obs_on, tmp_path):
    import warnings

    sp1 = obs.span("dangling.outer", tag=1)
    sp1.__enter__()
    sp2 = obs.span("dangling.inner")
    sp2.__enter__()
    with pytest.warns(RuntimeWarning, match="dangling"):
        path = obs.write_trace(str(tmp_path / "t.json"))
    doc = json.load(open(path))
    bad = {e["name"]: e for e in doc["traceEvents"]
           if e["args"].get("incomplete")}
    assert set(bad) == {"dangling.outer", "dangling.inner"}
    assert bad["dangling.outer"]["args"]["tag"] == 1
    # The late real exits are no-ops; a second export neither warns again
    # nor duplicates the auto-closed records.
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        sp2.__exit__(None, None, None)
        sp1.__exit__(None, None, None)
        sp3 = obs.span("dangling.late")
        sp3.__enter__()
        path2 = obs.write_trace(str(tmp_path / "t2.json"))
    doc2 = json.load(open(path2))
    names = [e["name"] for e in doc2["traceEvents"]]
    assert names.count("dangling.outer") == 1
    assert "dangling.late" in names


def test_instant_marks_export_and_skip_aggregate(obs_on, tmp_path):
    obs.instant("mark.one", detail="x")
    with obs.span("real"):
        pass
    doc = json.load(open(obs.write_trace(str(tmp_path / "t.json"))))
    marks = [e for e in doc["traceEvents"] if e["ph"] == "i"]
    assert len(marks) == 1 and marks[0]["args"]["detail"] == "x"
    agg = obs.aggregate()  # duration table ignores the durationless marks
    assert "real" in agg and "mark.one" not in agg


# ---------------------------------------------------------------------------
# Launch profiler
# ---------------------------------------------------------------------------


def test_profile_launch_records_and_registers(obs_on):
    import jax

    obs.reset_profiles()
    try:
        fn = jax.jit(lambda a, b: a @ b)
        a = jnp.ones((64, 64), jnp.float32)
        rec = obs.profile_launch("mm", fn, a, a, warmup=1, iters=2)
        assert rec["flops"] > 0 and rec["wall_s"] > 0
        assert rec["bound"] in ("compute", "memory")
        assert rec["gflops"] > 0 and rec["intensity"] > 0
        snap = obs.profile_snapshot()
        assert snap["mm"]["traces"] == 1
        assert snap["mm"]["launches"] == 3  # warmup + iters
        # First-class citizen of the shared compile registry.
        assert obs.compile_snapshot()["profile.mm"]["launches"] == 3
        table = obs.format_profile()
        assert "mm" in table and "bound" in table
        # Repeat at the same label: counts accumulate, record refreshes.
        obs.profile_launch("mm", fn, a, a, warmup=0, iters=1)
        assert obs.profile_snapshot()["mm"]["launches"] == 4
    finally:
        obs.reset_profiles()


# ---------------------------------------------------------------------------
# Dashboard rendering
# ---------------------------------------------------------------------------


def _ring_snap(rounds=6):
    buf = obs.TimelineBuf.zeros(8, series=("lam", "pick_n", "pick_k", "served"),
                                hists={"delay": obs.DELAY_BINS})
    for i in range(rounds):
        buf = buf.append(
            {"lam": 1.0 + i, "pick_n": 8.0, "pick_k": 4.0, "served": 3.0},
            {"delay": (jnp.array([5, 20, 40]), jnp.array([1, 1, 1]))})
    return buf.snapshot()


def test_ascii_dashboard_renders(obs_on):
    snap = _ring_snap()
    report = obs.slo_report(snap, obs.SLOSpec(target_s=10.0, window=2))
    text = obs.ascii_dashboard({"serve": snap}, slo=report)
    assert "timeline: serve" in text and "lam" in text
    assert "delay_p99_s" in text and "slo" in text


def test_sparkline_shapes():
    assert len(obs.sparkline([1.0, 2.0, 3.0])) == 3
    assert len(obs.sparkline(np.arange(200.0))) == 48
    assert obs.sparkline([np.nan, 1.0])[0] == " "


def test_html_report_self_contained(obs_on, tmp_path):
    snap = _ring_snap()
    report = obs.slo_report(snap, obs.SLOSpec(target_s=10.0, window=2))
    path = obs.html_report(str(tmp_path / "dash.html"), {"serve": snap},
                           slo=report, meta={"run": "test"})
    html = open(path).read()
    assert "<svg" in html and "serve" in html
    assert "prefers-color-scheme: dark" in html  # dual-mode palette
    assert "<script" in html
    # Self-contained: no external fetches.
    assert "https://" not in html and "http://" not in html


# ---------------------------------------------------------------------------
# Perf gate: serve SLO fields
# ---------------------------------------------------------------------------


def test_gate_serve_slo_fields(tmp_path):
    from benchmarks import gate

    art = {
        "schema": "repro.serve/BENCH_serve/v1",
        "rounds": 2, "steps": 2, "prompt_len": 16,
        "results": [{"batch": 4, "fused_req_per_s": 100.0, "speedup": 1.1}],
        "slo": {"settle_round": 1, "dwell_final": 0.5,
                "max_burn_rate": 0.0, "p99_last": 0.02},
    }
    m = gate.normalize(art)
    # Settle round is structurally deterministic -> count class; dwell is a
    # simulation statistic -> stat class (±10%).
    assert m["slo/settle_round"]["kind"] == "count"
    assert m["slo/dwell_final"]["kind"] == "stat"
    res_dir, base_dir = tmp_path / "res", tmp_path / "base"
    res_dir.mkdir()
    (res_dir / "BENCH_serve.json").write_text(json.dumps(art))
    gate.update(str(res_dir), str(base_dir))
    assert gate.check(str(res_dir), str(base_dir)) == 0
    # Settle-round drift fails exactly; dwell within tolerance passes.
    drift = dict(art, slo=dict(art["slo"], settle_round=2, dwell_final=0.52))
    (res_dir / "BENCH_serve.json").write_text(json.dumps(drift))
    fails, warns, notes = gate.check_file(
        str(res_dir / "BENCH_serve.json"), str(base_dir / "BENCH_serve.json"))
    assert len(fails) == 1 and "settle_round" in fails[0]
