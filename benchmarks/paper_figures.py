"""One benchmark per paper figure (Fig.1, 4, 5, 6, 7, 8, 9, 10).

Each ``fig*`` function runs the trace-driven simulation, writes a CSV
artifact under benchmarks/results/, and returns `name,us_per_call,derived`
summary lines for benchmarks.run.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import (
    CAPACITY_BASIC,
    CLS,
    L,
    SAMPLER,
    BenchTimer,
    all_static_codes,
    fresh_fixedk,
    fresh_greedy,
    fresh_tofec,
    rate_grid,
    run_policy,
    write_csv,
)
from repro.core import PAPER_READ_3MB, StaticPolicy, fit_delay_params
from repro.core import queueing
from repro.core.simulator import piecewise_poisson_arrivals, simulate
from repro.core.traces import TraceSampler, TraceStore


def fig1_static_tradeoff(count: int = 3000) -> list[str]:
    """Fig.1: total delay vs arrival rate for every static MDS code."""
    rows = []
    rates = rate_grid(8, 0.1, 0.95)
    with BenchTimer("fig1_static_tradeoff", calls=len(rates) * len(all_static_codes())) as t:
        for (n, k) in all_static_codes():
            for lam in rates:
                res = run_policy(StaticPolicy(n, k), lam, count)
                s = res.summary()
                rows.append([n, k, f"{lam:.2f}", f"{s['mean']:.4f}", f"{s['median']:.4f}",
                             f"{s['throughput']:.2f}"])
    write_csv("fig1_static_tradeoff.csv", ["n", "k", "lambda", "mean_s", "median_s", "tput"], rows)
    # Derived check: capacity loss of (6,3) vs (1,1) ≈ 30-40% (paper: ~30%).
    cap_63 = queueing.capacity(PAPER_READ_3MB, CLS.file_mb, 3, 2.0, L)
    return [t.row(f"cap63/cap11={cap_63 / CAPACITY_BASIC:.2f}")]


def fig4_task_ccdf() -> list[str]:
    """Fig.4: per-thread task-delay CCDF, Unique vs Shared Key (1MB chunks)."""
    rows = []
    with BenchTimer("fig4_task_ccdf") as t:
        for mode, corr in [("unique", 0.0), ("shared", 0.14)]:
            store = TraceStore.generate(
                PAPER_READ_3MB, [1.0], threads=6, samples=30_000,
                correlation=corr, seed=11,
            )
            delays = store.flat_delays(1.0)
            qs = np.quantile(delays, 1 - np.logspace(0, -4, 30))
            for q, v in zip(np.logspace(0, -4, 30), qs):
                rows.append([mode, f"{v:.4f}", f"{q:.6f}"])
            rho = store.cross_correlation(1.0)
            rows.append([f"{mode}_xcorr", f"{rho:.4f}", ""])
    write_csv("fig4_task_ccdf.csv", ["mode", "delay_s", "ccdf"], rows)
    return [t.row("unique_xcorr<0.05,shared~0.14")]


def fig5_service_ccdf(count: int = 20_000) -> list[str]:
    """Fig.5: service-delay CCDF for (n, 3) codes, n = 3..6, batch start."""
    rows = []
    rng = np.random.default_rng(5)
    p99_by_n = {}
    with BenchTimer("fig5_service_ccdf") as t:
        for n in range(3, 7):
            batch = SAMPLER.sample_batch(rng, k=3, n=n, size=count)
            d_s = np.sort(batch, axis=1)[:, 2]  # 3rd order statistic
            p99_by_n[n] = float(np.percentile(d_s, 99))
            for q in np.logspace(0, -4, 30):
                rows.append([n, f"{np.quantile(d_s, 1 - q):.4f}", f"{q:.6f}"])
    write_csv("fig5_service_ccdf.csv", ["n", "delay_s", "ccdf"], rows)
    # Paper: +1/+2/+3 chunks cut p99 by ~50/65/80%.
    red = 1 - p99_by_n[6] / p99_by_n[3]
    return [t.row(f"p99cut_n6_vs_n3={red:.2f}(paper~0.8)")]


def fig6_linear_fit() -> list[str]:
    """Fig.6: mean/std of task delay vs chunk size + least-squares lines,
    closing the loop: re-fitting traces recovers the generating params."""
    sizes = [0.5, 0.75, 1.0, 1.5, 2.0, 3.0]
    rows = []
    with BenchTimer("fig6_linear_fit") as t:
        store = TraceStore.generate(PAPER_READ_3MB, sizes, samples=30_000, seed=6)
        delays = [store.flat_delays(B) for B in sizes]
        for B, d in zip(sizes, delays):
            rows.append([f"{B:.2f}", f"{d.mean():.4f}", f"{d.std():.4f}"])
        fit = fit_delay_params(np.array(sizes), delays, drop_worst_frac=0.10)
    write_csv("fig6_linear_fit.csv", ["chunk_mb", "mean_s", "std_s"], rows)
    err = abs(fit.delta_tilde - PAPER_READ_3MB.delta_tilde) / PAPER_READ_3MB.delta_tilde
    return [t.row(f"refit_delta_tilde_relerr={err:.3f}")]


def fig7_adaptive_tradeoff(count: int = 3500) -> list[str]:
    """Fig.7: mean/median/p90/p99 vs λ — TOFEC, Greedy, FixedK(6), basic,
    replication, and the brute-force best static per rate."""
    rates = rate_grid(8, 0.1, 0.92)
    rows = []
    lines = []
    with BenchTimer("fig7_adaptive_tradeoff", calls=len(rates)) as t:
        for lam in rates:
            from repro.core.controller import MPCPolicy

            entries = {
                "tofec": run_policy(fresh_tofec(), lam, count),
                "mpc": run_policy(MPCPolicy(CLS, L), lam, count),  # beyond-paper
                "greedy": run_policy(fresh_greedy(), lam, count),
                "fixedk6": run_policy(fresh_fixedk(6), lam, count),
                "basic": run_policy(StaticPolicy(1, 1), lam, count),
                "repl21": run_policy(StaticPolicy(2, 1), lam, count),
            }
            best = {"mean": np.inf, "median": np.inf, "p90": np.inf, "p99": np.inf}
            for (n, k) in all_static_codes():
                s = run_policy(StaticPolicy(n, k), lam, count // 2, seed=3).summary()
                for key in best:
                    best[key] = min(best[key], s[key])
            for name, res in entries.items():
                s = res.summary()
                rows.append([name, f"{lam:.2f}", f"{s['mean']:.4f}", f"{s['median']:.4f}",
                             f"{s['p90']:.4f}", f"{s['p99']:.4f}", f"{s['mean_k']:.2f}"])
            rows.append(["best_static", f"{lam:.2f}", f"{best['mean']:.4f}",
                         f"{best['median']:.4f}", f"{best['p90']:.4f}", f"{best['p99']:.4f}", ""])
    write_csv(
        "fig7_adaptive_tradeoff.csv",
        ["policy", "lambda", "mean_s", "median_s", "p90_s", "p99_s", "mean_k"], rows,
    )
    # Headline claims at light load.
    light = rates[0]
    tof = run_policy(fresh_tofec(), light, count).summary()
    bas = run_policy(StaticPolicy(1, 1), light, count).summary()
    gain = bas["mean"] / tof["mean"]
    lines.append(t.row(f"light_load_mean_gain_vs_basic={gain:.2f}x(paper~2.5x)"))
    return lines


def fig8_composition(count: int = 3500) -> list[str]:
    """Fig.8: fraction of requests served at each k, TOFEC vs Greedy."""
    rates = rate_grid(6, 0.15, 0.9)
    rows = []
    with BenchTimer("fig8_composition", calls=len(rates)) as t:
        mono_ok = True
        prev_mean_k = np.inf
        for lam in rates:
            for name, pol in [("tofec", fresh_tofec()), ("greedy", fresh_greedy())]:
                res = run_policy(pol, lam, count)
                comp = res.k_composition(CLS.k_max)
                rows.append([name, f"{lam:.2f}"] + [f"{c:.3f}" for c in comp])
                if name == "tofec":
                    mk = res.ks().mean()
                    mono_ok &= mk <= prev_mean_k + 0.35
                    prev_mean_k = mk
    write_csv("fig8_composition.csv",
              ["policy", "lambda"] + [f"k{k}" for k in range(1, CLS.k_max + 1)], rows)
    return [t.row(f"tofec_k_monotone_decreasing={mono_ok}")]


def fig9_std(count: int = 3500) -> list[str]:
    """Fig.9: delay standard deviation — TOFEC vs Greedy (QoS claim)."""
    rates = rate_grid(6, 0.15, 0.9)
    rows = []
    ratios = []
    with BenchTimer("fig9_std", calls=len(rates)) as t:
        for lam in rates:
            s_t = run_policy(fresh_tofec(), lam, count).totals().std()
            s_g = run_policy(fresh_greedy(), lam, count).totals().std()
            rows.append([f"{lam:.2f}", f"{s_t:.4f}", f"{s_g:.4f}"])
            ratios.append(s_g / s_t)
    write_csv("fig9_std.csv", ["lambda", "tofec_std_s", "greedy_std_s"], rows)
    return [t.row(f"greedy/tofec_std_mid={np.median(ratios):.2f}x(paper:2-3x)")]


def fig10_transient() -> list[str]:
    """Fig.10: 600s run at 10 → 70 → 10 req/s; per-request total delay and
    backlog recovery for TOFEC / Greedy / static(3,2)."""
    rows = []
    with BenchTimer("fig10_transient", calls=3) as t:
        recover = {}
        for name, pol in [
            ("tofec", fresh_tofec()),
            ("greedy", fresh_greedy()),
            ("static32", StaticPolicy(3, 2)),
        ]:
            rng = np.random.default_rng(10)
            arr = piecewise_poisson_arrivals(
                rng, [(200.0, 10.0), (200.0, 70.0), (200.0, 10.0)]
            )
            res = simulate(pol, arr, SAMPLER, L=L, seed=23, warmup_frac=0.0)
            for st in res.stats[:: max(1, len(res.stats) // 600)]:
                rows.append([name, f"{st.arrival:.1f}", f"{st.total:.4f}"])
            # recovery = first time after t=400 when the delay stays down
            # (rolling median of the next 20 requests < 2× light-load mean).
            late = [(st.arrival, st.total) for st in res.stats if st.arrival > 400.0]
            light_mean = np.mean([st.total for st in res.stats if st.arrival < 180.0])
            rec = 600.0
            for i in range(len(late) - 20):
                window = np.median([d for _, d in late[i : i + 20]])
                if window < 2 * light_mean:
                    rec = late[i][0]
                    break
            recover[name] = rec - 400.0
    write_csv("fig10_transient.csv", ["policy", "arrival_s", "total_delay_s"], rows)
    return [t.row(
        f"recovery_s tofec={recover['tofec']:.0f} greedy={recover['greedy']:.0f} "
        f"static32={recover['static32']:.0f}(paper:>100s)"
    )]


ALL_FIGS = [
    fig1_static_tradeoff,
    fig4_task_ccdf,
    fig5_service_ccdf,
    fig6_linear_fit,
    fig7_adaptive_tradeoff,
    fig8_composition,
    fig9_std,
    fig10_transient,
]
