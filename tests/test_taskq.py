"""repro.taskq: the exact task-level engine's draw-for-draw parity with the
discrete-event oracle over shared trace pools, device/host pool-read and
Greedy-selection parity, the bounded-compile claim for heterogeneous
(threshold + greedy) grids, and the BENCH_taskq.json artifact."""

import json

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import PAPER_READ_3MB, RequestClass, StaticPolicy, TOFECPolicy, build_class_plan
from repro.core.controller import GreedyPolicy
from repro.core.simulator import simulate
from repro.core.traces import TraceStore
from repro.fleet import PolicySpec, grid_cases, policy_tables
from repro.fleet.stats import masked_percentiles
from repro.taskq import (
    TaskqSweep,
    greedy_select,
    taskq_streams,
    write_taskq_artifact,
)

CLS = RequestClass("read3mb", 3.0, PAPER_READ_3MB, k_max=6, r_max=2.0, n_max=12)
L = 16
SIZES = tuple(CLS.file_mb / k for k in range(1, CLS.k_max + 1))


def make_pools(correlation: float, seed: int = 3, samples: int = 2048):
    store = TraceStore.generate(
        PAPER_READ_3MB, SIZES, threads=CLS.n_max, samples=samples,
        correlation=correlation, seed=seed,
    )
    return store, store.device_pools(n_max=CLS.n_max)


def run_host(case, count, dp, policy):
    """The event oracle on the same draws a TaskqSweep point consumes."""
    inter, idx = taskq_streams(case, count, dp.n_rows)
    arrivals = np.cumsum(inter.astype(np.float64))
    return simulate(
        policy, arrivals, dp.host_sampler(CLS.file_mb, idx), L=L, warmup_frac=0.0
    )


# ---------------------------------------------------------------------------
# Shared trace pools: device and host read identical values
# ---------------------------------------------------------------------------


def test_device_pools_and_host_sampler_read_identical_values():
    store, dp = make_pools(correlation=0.14)
    assert dp.pools.shape == (len(SIZES), 2048, CLS.n_max)
    assert dp.pools.dtype == np.float32 and dp.sizes_mb.dtype == np.float32
    rng = np.random.default_rng(0)
    indices = rng.integers(dp.n_rows, size=64)
    sampler = dp.host_sampler(CLS.file_mb, indices)
    for i in [0, 7, 31, 63]:
        for k, n in [(1, 2), (3, 6), (6, 12)]:
            host = sampler.sample_indexed(i, k, n)
            s = dp.pool_index(CLS.file_mb, k)
            dev = np.asarray(jnp.asarray(dp.pools)[s, indices[i], :n])
            np.testing.assert_array_equal(host.astype(np.float32), dev)
            # And both equal the originating store pool row.
            np.testing.assert_array_equal(
                dev, store.pools[s][indices[i], :n].astype(np.float32)
            )


def test_device_pools_validates_width_and_rows():
    store, _ = make_pools(correlation=0.0, samples=128)
    with pytest.raises(ValueError):
        store.device_pools(n_max=CLS.n_max + 1)
    with pytest.raises(ValueError):
        store.device_pools(n_max=CLS.n_max, size=256)
    small = store.device_pools(n_max=4, size=32)
    assert small.pools.shape == (len(SIZES), 32, 4)


def test_shared_key_correlation_survives_export():
    _, dp = make_pools(correlation=0.14)
    pool = dp.pools[0]  # (P, W) at the largest chunk size
    c = np.corrcoef(pool.T)
    off = c[~np.eye(c.shape[0], dtype=bool)]
    assert off.mean() > 0.05, off.mean()


# ---------------------------------------------------------------------------
# Greedy parity: device select vs host GreedyPolicy
# ---------------------------------------------------------------------------


def test_greedy_select_matches_host_policy_on_randomized_states():
    rng = np.random.default_rng(42)
    checked = 0
    for _ in range(200):
        k_max = int(rng.integers(1, 9))
        r_max = float(rng.choice([1.5, 2.0, 2.5, 3.0]))
        idle = int(rng.integers(-2, 2 * L + 1))
        q = int(rng.integers(0, 50))
        host = GreedyPolicy(k_max, r_max).select(q=q, idle=idle)
        n_d, k_d = greedy_select(
            jnp.float32(q), jnp.int32(idle), jnp.int32(k_max), jnp.float32(r_max)
        )
        assert (int(n_d), int(k_d)) == host, (q, idle, k_max, r_max)
        checked += 1
    assert checked == 200


# ---------------------------------------------------------------------------
# Exactness: engine vs event oracle on shared pools
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "n,k,lam,correlation",
    [
        (1, 1, 8.0, 0.0),    # basic code, unique-key placement
        (6, 3, 30.0, 0.0),   # mid code under load, unique-key
        (12, 6, 20.0, 0.14),  # latency-optimal code, shared-key copula
        (4, 2, 45.0, 0.14),  # heavy load, shared-key
    ],
)
def test_engine_matches_event_oracle_draw_for_draw(n, k, lam, correlation):
    """With shared pre-sampled pools, per-request (total, queueing, service)
    delays equal the discrete-event oracle within float32 tolerance — the
    exact k-of-n + cancellation dynamics, not the fluid approximation."""
    _, dp = make_pools(correlation)
    count = 1200
    case = grid_cases([lam], [PolicySpec.static(n, k)], [7], CLS, L)[0]
    res = TaskqSweep(chunk=4).run([case], count, dp)
    host = run_host(case, count, dp, StaticPolicy(n, k))
    assert len(host.stats) == count
    out = res.to_numpy()
    np.testing.assert_allclose(out["total"][0], host.totals(), rtol=1e-3, atol=2e-3)
    np.testing.assert_allclose(out["queueing"][0], host.queueing(), rtol=1e-3, atol=2e-3)
    np.testing.assert_allclose(out["service"][0], host.service(), rtol=1e-3, atol=2e-3)
    assert (out["n"][0] == n).all() and (out["k"][0] == k).all()


def test_engine_exact_when_n_exceeds_thread_count():
    """n > L: the excess tasks queue for threads freed by their own
    siblings' completions (and are cancelled with the rest at the k-th
    completion) — the pass-1 feedback makes this exact too."""
    _, dp = make_pools(correlation=0.14)
    count = 800
    L_small = 8
    case = grid_cases([15.0], [PolicySpec.static(12, 6)], [9], CLS, L_small)[0]
    res = TaskqSweep(chunk=4).run([case], count, dp)
    inter, idx = taskq_streams(case, count, dp.n_rows)
    arrivals = np.cumsum(inter.astype(np.float64))
    host = simulate(StaticPolicy(12, 6), arrivals,
                    dp.host_sampler(CLS.file_mb, idx), L=L_small, warmup_frac=0.0)
    out = res.to_numpy()
    np.testing.assert_allclose(out["total"][0], host.totals(), rtol=1e-3, atol=2e-3)
    np.testing.assert_allclose(out["queueing"][0], host.queueing(), rtol=1e-3, atol=2e-3)


def test_engine_tracks_adaptive_trajectories_of_the_oracle():
    """Beyond static codes: the exact backlog/idle observables let TOFEC and
    Greedy reproduce the oracle's per-request (n, k) decision sequence
    almost everywhere (fp boundary ties at threshold crossings excepted)."""
    _, dp = make_pools(correlation=0.0)
    count = 1200
    # TOFEC thresholds on the true queue length.
    case = grid_cases([35.0], [PolicySpec.tofec()], [5], CLS, L)[0]
    res = TaskqSweep(chunk=4).run([case], count, dp)
    host = run_host(case, count, dp, TOFECPolicy([build_class_plan(CLS, L)]))
    out = res.to_numpy()
    assert (out["n"][0] == host.ns()).mean() > 0.99
    assert (out["k"][0] == host.ks()).mean() > 0.99
    np.testing.assert_allclose(
        out["total"][0].mean(), host.totals().mean(), rtol=1e-2
    )
    # Greedy on the true idle-thread count — the policy the fluid sweeps
    # could never run.
    case = grid_cases([40.0], [PolicySpec.greedy()], [11], CLS, L)[0]
    res = TaskqSweep(chunk=4).run([case], count, dp)
    host = run_host(case, count, dp, GreedyPolicy(CLS.k_max, CLS.r_max))
    out = res.to_numpy()
    assert (out["n"][0] == host.ns()).mean() > 0.99
    assert (out["k"][0] == host.ks()).mean() > 0.99


def test_chunk_padding_keeps_results_exact():
    """Different chunkings of the same grid are bit-identical (the fleet's
    tail-padding guarantee holds for the broadcast-pool launch path too)."""
    _, dp = make_pools(correlation=0.14)
    cases = grid_cases([10.0, 30.0, 50.0], [PolicySpec.tofec()], [0, 1], CLS, L)
    a = TaskqSweep(chunk=4).run(cases, 600, dp).to_numpy()  # 6 = 4 + 2(pad)
    b = TaskqSweep(chunk=8).run(cases, 600, dp).to_numpy()  # one launch
    for name in ("total", "queueing", "service", "n", "k"):
        np.testing.assert_array_equal(a[name], b[name])


# ---------------------------------------------------------------------------
# Shape buckets / compile counts
# ---------------------------------------------------------------------------


def test_heterogeneous_policy_sweep_compiles_once_per_bucket():
    """A ≥32-case grid mixing threshold policies AND greedy runs in ONE
    compilation; same-bucket re-runs are compile-free; a new time bucket
    compiles once more — TaskqSweep.stats pins it."""
    _, dp = make_pools(correlation=0.0)
    sweep = TaskqSweep(chunk=16, t_floor=512)
    lams = np.linspace(6.0, 48.0, 4)
    policies = [PolicySpec.tofec(), PolicySpec.static(1, 1),
                PolicySpec.static(12, 6), PolicySpec.greedy()]
    cases = grid_cases(lams, policies, [0, 1], CLS, L)
    assert len(cases) == 32

    res = sweep.run(cases, count=400, pools=dp)
    assert res.compiles == 1, res.compiles
    assert res.launches == 2  # 32 points / chunk 16

    res2 = sweep.run(cases[:12], count=500, pools=dp)  # same 512 bucket
    assert res2.compiles == 0
    res3 = sweep.run(cases[:4], count=600, pools=dp)  # new time bucket
    assert res3.compiles == 1
    assert sweep.stats.traces == 2 and sweep.stats.cases == 32 + 12 + 4


def test_greedy_rejected_by_fleet_tables():
    with pytest.raises(ValueError, match="taskq"):
        policy_tables(PolicySpec.greedy(), CLS, L)


def test_mixed_L_rejected():
    _, dp = make_pools(correlation=0.0)
    cases = grid_cases([10.0], [PolicySpec.tofec()], [0], CLS, L)
    cases += grid_cases([10.0], [PolicySpec.tofec()], [0], CLS, L=8)
    with pytest.raises(ValueError, match="share L"):
        TaskqSweep().run(cases, 256, dp)


# ---------------------------------------------------------------------------
# Frontier reuse + artifact
# ---------------------------------------------------------------------------


def test_taskq_artifact_orders_policies_like_the_paper(tmp_path):
    """The exact engine's frontier reproduces the TOFEC-vs-static story and
    lands in BENCH_taskq.json via the fleet's reductions."""
    _, dp = make_pools(correlation=0.0)
    lams = np.linspace(6.0, 48.0, 4)
    policies = [PolicySpec.tofec(), PolicySpec.static(1, 1),
                PolicySpec.static(12, 6), PolicySpec.greedy()]
    res = TaskqSweep(chunk=16).run(grid_cases(lams, policies, [1], CLS, L),
                                   1500, dp)
    path = tmp_path / "BENCH_taskq.json"
    art = write_taskq_artifact(str(path), res)
    on_disk = json.loads(path.read_text())
    assert on_disk["schema"] == "repro.taskq/BENCH_taskq/v1"
    assert on_disk["grid_size"] == 16 and len(on_disk["points"]) == 16

    from repro.fleet import frontier, frontier_points

    by = frontier(frontier_points(res))
    assert set(by) == {"tofec", "static(1,1)", "static(12,6)", "greedy"}
    # Light load: high-chunk codes (static(12,6), TOFEC, greedy) all beat
    # the basic code's mean delay.
    light = {name: pts[0].mean for name, pts in by.items()}
    assert light["static(12,6)"] < light["static(1,1)"]
    assert light["tofec"] < light["static(1,1)"]
    assert light["greedy"] < light["static(1,1)"]
    for p in frontier_points(res):
        assert p.p50 <= p.p90 <= p.p95 <= p.p99


def test_masked_percentiles_shared_helper_matches_numpy():
    """The hoisted fleet/sched/taskq percentile helper is the lower-method
    order statistic, masked and unmasked."""
    rng = np.random.default_rng(1)
    x = rng.exponential(1.0, size=(3, 257)).astype(np.float32)
    qs = [50.0, 90.0, 95.0, 99.0]
    got = np.asarray(masked_percentiles(jnp.asarray(x), qs))
    want = np.percentile(x, qs, axis=1, method="lower").T
    np.testing.assert_allclose(got, want, rtol=1e-6)
    mask = x < 1.5
    got_m = np.asarray(masked_percentiles(jnp.asarray(x), qs, jnp.asarray(mask)))
    for g in range(3):
        want_m = np.percentile(x[g][mask[g]], qs, method="lower")
        np.testing.assert_allclose(got_m[g], want_m, rtol=1e-6)
    empty = np.zeros_like(mask)
    # An all-false mask has no order statistic: NaN, not a clamped gather
    # (the edge-case contract pinned in tests/test_shard.py).
    assert np.all(np.isnan(np.asarray(
        masked_percentiles(jnp.asarray(x), qs, jnp.asarray(empty)))))
