"""Quickstart: the paper in 60 seconds.

Builds the TOFEC threshold tables from the calibrated S3 delay model,
simulates light vs heavy workloads, and prints the throughput-delay story
of the paper (adaptive code selection keeps light-load latency AND full
capacity). Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (
    PAPER_READ_3MB,
    RequestClass,
    StaticPolicy,
    TOFECPolicy,
    build_class_plan,
)
from repro.core import queueing
from repro.core.simulator import poisson_arrivals, simulate
from repro.core.traces import TraceSampler

CLS = RequestClass("read-3MB", 3.0, PAPER_READ_3MB, k_max=6, r_max=2.0, n_max=12)
L = 16


def main():
    plan = build_class_plan(CLS, L)
    print("=== TOFEC threshold tables (paper §IV-C) ===")
    print(f"Q at which k=1..6 is optimal: {np.round(plan.q_at_k, 3)}")
    print(f"Q at which n=1..12 is optimal: {np.round(plan.q_at_n, 3)}")

    cap = queueing.capacity(PAPER_READ_3MB, CLS.file_mb, 1, 1.0, L)
    print(f"\nBasic (1,1) capacity ≈ {cap:.1f} req/s; "
          f"(6,3) static capacity ≈ {queueing.capacity(PAPER_READ_3MB, 3.0, 3, 2.0, L):.1f} req/s")

    sampler = TraceSampler(PAPER_READ_3MB, CLS.file_mb, correlation=0.14)
    rng = np.random.default_rng(0)
    for lam, label in [(0.15 * cap, "light"), (0.85 * cap, "heavy")]:
        arr = poisson_arrivals(rng, lam, 4000)
        tofec = simulate(TOFECPolicy.for_classes([CLS], L), arr, sampler, L=L)
        basic = simulate(StaticPolicy(1, 1), arr, sampler, L=L)
        st, sb = tofec.summary(), basic.summary()
        print(f"\n--- {label} load ({lam:.0f} req/s) ---")
        print(f"TOFEC : mean {st['mean'] * 1e3:6.1f} ms  p99 {st['p99'] * 1e3:7.1f} ms  "
              f"mean k {st['mean_k']:.2f}")
        print(f"basic : mean {sb['mean'] * 1e3:6.1f} ms  p99 {sb['p99'] * 1e3:7.1f} ms")
        print(f"TOFEC gain: {sb['mean'] / st['mean']:.2f}x mean")


if __name__ == "__main__":
    main()
