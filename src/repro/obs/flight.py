"""Per-request flight recorder over the exact task-level engine.

Everything else in :mod:`repro.obs` is an aggregate; this plane keeps the
*individual* request anatomies the paper's §II-A dynamics are made of.  The
device side is :func:`repro.taskq.engine.taskq_scan_core`'s static
``flight=True`` flag (riding jit cache keys like ``collect``), which emits
per-lane start / tentative-completion times, the pass-2 assigned-thread id
and the departure instant for every request.  This module is the host side:

* :class:`FlightLog` — reconstructs the raw arrays into per-task records
  (cancel kind won / cancelled-in-queue / cancelled-in-service derived from
  the same ``C ≤ D`` / ``S < D`` classification the engine's counters use),
  exports them as an NDJSON stream (:data:`FLIGHT_SCHEMA`) and as Chrome
  ``trace_event`` JSON on a **simulated** clock — one Perfetto track per
  pool thread, cancellations as slices truncated at the departure hairline,
  flow arrows tying each request's first task to its winning k-th one.
* :meth:`FlightLog.task_rows` / :func:`oracle_task_rows` — the two sides of
  the event-level parity pin: device flight rows vs the
  :func:`repro.core.simulator.simulate` ``event_log`` hook, row for row.
* :meth:`FlightLog.exemplars` + :func:`exemplar_panel` — the top-K slowest
  valid requests and their task-race anatomy as an ASCII breakdown (the
  HTML twin renders in :func:`repro.obs.dashboard.html_report`).
* :class:`FlightRing` — the serving loop's per-round flight recorder:
  admit → decode → generate phase durations on a compacted simulated round
  clock (rounds butt against each other, no inter-round idle), so serve
  dashboards show where breached rounds spent their budget.

Clock convention: :class:`repro.obs.trace.Tracer` spans are **wallclock**
(monotonic µs since tracer epoch); flight traces are **simulated seconds**
scaled to µs (``ts = sim_s * 1e6``).  Both serialize through the shared
:func:`repro.obs.trace.write_trace_doc` writer, so either file loads in
Perfetto — they are different timelines, not different formats.

NDJSON record schema (one JSON object per line)::

    {"schema": "repro.obs/flight/v1", "label": <run label>,
     "req": <arrival index>, "lane": <task lane>, "thread": <pool thread
     id, -1 if never started>, "kind": "won" | "cancel_queue" |
     "cancel_service", "arrival": <s>, "start": <s | null>, "end": <s |
     null>, "depart": <s>, "n": ..., "k": ..., "queue_s": ...,
     "total_s": ...}

The rule of thumb the sweep engines follow: **aggregate engines stream,
flight replays one case** — a grid run keeps its streamed reductions, and
an anomalous cell is zoomed into via
:meth:`repro.taskq.sweep.TaskqSweep.replay_flight`, which re-runs that one
point with ``flight=True`` and returns a :class:`FlightLog`.
"""
from __future__ import annotations

import dataclasses
import json
import os
from collections import deque

import numpy as np

from repro.obs.trace import write_trace_doc

FLIGHT_SCHEMA = "repro.obs/flight/v1"

#: kind ids shared with the oracle's event_log rows: index = device/oracle
#: integer kind, value = the NDJSON kind string.
KINDS = ("won", "cancel_queue", "cancel_service")

#: Synthetic Perfetto track (tid) for the per-request arrival instants.
ARRIVAL_TID = 999


def _f(v) -> float | None:
    """float for JSON: NaN/inf → null."""
    v = float(v)
    return v if np.isfinite(v) else None


class FlightLog:
    """Host-side reconstruction of one ``flight=True`` scan output.

    ``out`` is the :func:`repro.taskq.engine.taskq_scan` result dict (must
    carry the ``"flight"`` block); ``valid`` optionally masks padded
    arrivals (bucket-padded launches replay real + pad lanes — padding must
    never mine as an exemplar or export as a record)."""

    def __init__(self, out: dict, *, valid=None, label: str = "taskq"):
        fl = out["flight"]
        self.label = label
        self.arrival = np.asarray(fl["arrival"], np.float64)
        self.depart = np.asarray(fl["depart"], np.float64)
        self.start = np.asarray(fl["start"], np.float64)
        self.tent = np.asarray(fl["tent"], np.float64)
        self.thread = np.asarray(fl["thread"], np.int64)
        self.n = np.asarray(out["n"], np.int64)
        self.k = np.asarray(out["k"], np.int64)
        self.total = np.asarray(out["total"], np.float64)
        self.queueing = np.asarray(out["queueing"], np.float64)
        T = self.arrival.shape[0]
        self.valid = (
            np.ones(T, bool) if valid is None else np.asarray(valid, bool)
        )
        if self.valid.shape != (T,):
            raise ValueError(
                f"valid mask shape {self.valid.shape} != ({T},)")

    def __len__(self) -> int:
        return int(self.valid.sum())

    # ---- per-task rows ----------------------------------------------------
    def _task(self, i: int, m: int) -> tuple[int, float, float]:
        """(kind_id, start, end) for request i's lane m (NaN = no event)."""
        started = self.thread[i, m] >= 0
        if not started:
            return 1, np.nan, np.nan
        if self.tent[i, m] <= self.depart[i]:  # winner: completed at C
            return 0, float(self.start[i, m]), float(self.tent[i, m])
        return 2, float(self.start[i, m]), float(self.depart[i])

    def task_rows(self) -> list[tuple]:
        """Valid per-task rows ``(req, lane, kind, start, end, depart)``
        sorted by (req, lane) — the exact layout of the oracle's
        ``event_log`` hook after :func:`oracle_task_rows`, the two sides of
        the event-level parity pin."""
        rows = []
        for i in np.nonzero(self.valid)[0]:
            for m in range(int(self.n[i])):
                kind, s, e = self._task(i, m)
                rows.append((int(i), m, kind, s, e, float(self.depart[i])))
        return rows

    def records(self) -> list[dict]:
        """One :data:`FLIGHT_SCHEMA` dict per valid (request, lane)."""
        recs = []
        for i in np.nonzero(self.valid)[0]:
            i = int(i)
            for m in range(int(self.n[i])):
                kind, s, e = self._task(i, m)
                recs.append({
                    "schema": FLIGHT_SCHEMA,
                    "label": self.label,
                    "req": i,
                    "lane": m,
                    "thread": int(self.thread[i, m]),
                    "kind": KINDS[kind],
                    "arrival": float(self.arrival[i]),
                    "start": _f(s),
                    "end": _f(e),
                    "depart": float(self.depart[i]),
                    "n": int(self.n[i]),
                    "k": int(self.k[i]),
                    "queue_s": float(self.queueing[i]),
                    "total_s": float(self.total[i]),
                })
        return recs

    def write_ndjson(self, path: str) -> str:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as fh:
            for rec in self.records():
                fh.write(json.dumps(rec) + "\n")
        return path

    # ---- simulated-clock Chrome trace ------------------------------------
    def to_trace_events(self) -> list:
        """Chrome ``trace_event`` list on the simulated clock (sim seconds
        × 1e6 as µs): one track per pool thread carrying task-occupancy
        slices (cancelled-in-service slices truncate at the departure
        instant), an ``arrivals`` instant track, and one flow arrow per
        request from its first started task to the winning k-th one."""
        pid = 0
        events: list = [{
            "ph": "M", "name": "process_name", "pid": pid,
            "args": {"name": f"flight:{self.label} (simulated time)"},
        }, {
            "ph": "M", "name": "thread_name", "pid": pid,
            "tid": ARRIVAL_TID, "args": {"name": "arrivals"},
        }]
        threads = sorted(int(t) for t in np.unique(self.thread) if t >= 0)
        for j in threads:
            events.append({
                "ph": "M", "name": "thread_name", "pid": pid, "tid": j,
                "args": {"name": f"pool-thread-{j:02d}"},
            })
        for i in np.nonzero(self.valid)[0]:
            i = int(i)
            events.append({
                "name": f"req{i} arrive", "ph": "i", "cat": "flight",
                "s": "t", "ts": self.arrival[i] * 1e6,
                "pid": pid, "tid": ARRIVAL_TID,
                "args": {"req": i, "n": int(self.n[i]), "k": int(self.k[i])},
            })
            first = None  # (start, thread) of the earliest started task
            winner = None  # (start, thread) of the task completing at D
            for m in range(int(self.n[i])):
                kind, s, e = self._task(i, m)
                if kind == 1:
                    continue  # cancelled in queue: never held a thread
                tid = int(self.thread[i, m])
                events.append({
                    "name": f"req{i}/t{m}", "ph": "X", "cat": "flight",
                    "ts": s * 1e6, "dur": max(e - s, 0.0) * 1e6,
                    "pid": pid, "tid": tid,
                    "args": {"req": i, "lane": m, "kind": KINDS[kind],
                             "n": int(self.n[i]), "k": int(self.k[i])},
                })
                if first is None or s < first[0]:
                    first = (s, tid)
                if kind == 0 and e == float(self.depart[i]):
                    winner = (e, tid)
            if first is not None and winner is not None:
                # Flow binding: s/f events must sit inside slices on their
                # thread; nudge the finish arrow just before the slice end.
                events.append({
                    "name": f"req{i}", "ph": "s", "cat": "req", "id": i,
                    "ts": first[0] * 1e6, "pid": pid, "tid": first[1],
                })
                events.append({
                    "name": f"req{i}", "ph": "f", "bp": "e", "cat": "req",
                    "id": i, "ts": winner[0] * 1e6, "pid": pid,
                    "tid": winner[1],
                })
        return events

    def write_trace(self, path: str) -> str:
        """Write the simulated-clock Perfetto trace; returns the path."""
        return write_trace_doc(path, self.to_trace_events())

    # ---- exemplar mining --------------------------------------------------
    def anatomy(self, i: int) -> dict:
        """One request's task-race anatomy as a plain dict."""
        i = int(i)
        tasks = []
        for m in range(int(self.n[i])):
            kind, s, e = self._task(i, m)
            tasks.append({"lane": m, "thread": int(self.thread[i, m]),
                          "kind": KINDS[kind], "start": _f(s), "end": _f(e)})
        return {
            "req": i,
            "arrival": float(self.arrival[i]),
            "depart": float(self.depart[i]),
            "total_s": float(self.total[i]),
            "queue_s": float(self.queueing[i]),
            "n": int(self.n[i]),
            "k": int(self.k[i]),
            "tasks": tasks,
        }

    def exemplars(self, top_k: int = 3) -> list[dict]:
        """The ``top_k`` slowest VALID requests' anatomies, slowest first.

        Deterministic under padding and reordering: candidates are the
        valid arrivals only, ranked by (total delay desc, arrival index
        asc) — so bucket-padded replays of the same case mine identical
        exemplars."""
        idx = np.nonzero(self.valid)[0]
        order = sorted(idx, key=lambda i: (-self.total[i], int(i)))
        return [self.anatomy(i) for i in order[: int(top_k)]]


def oracle_task_rows(event_log: list) -> list[tuple]:
    """Normalize a :func:`repro.core.simulator.simulate` ``event_log`` into
    the :meth:`FlightLog.task_rows` layout: tuples ``(req, lane, kind,
    start, end, depart)`` sorted by (req, lane).  The oracle appends rows
    in departure order (which under load differs from arrival order); the
    device log is arrival-ordered — sorting makes them row-for-row
    comparable."""
    rows = [(int(r), int(m), int(kd), float(s), float(e), float(d))
            for r, m, kd, s, e, d in event_log]
    rows.sort(key=lambda row: (row[0], row[1]))
    return rows


def exemplar_panel(exemplars: list[dict], width: int = 44) -> str:
    """ASCII task-race anatomy for mined exemplars (dashboard section).

    One block per request: a header line with the delay split and code,
    then one bar per task lane spanning [arrival, depart] — ``#`` while the
    task holds a thread, ``x`` marking a cancellation-in-service's
    truncation, ``.`` for queue wait before its start, blank for lanes
    cancelled in queue."""
    if not exemplars:
        return "(no exemplars)"
    lines = []
    for ex in exemplars:
        lines.append(
            f"req {ex['req']}  total={ex['total_s']:.4g}s "
            f"(queue {ex['queue_s']:.4g}s)  code=({ex['n']},{ex['k']})")
        t0, t1 = ex["arrival"], ex["depart"]
        span = max(t1 - t0, 1e-12)

        def col(t):
            return int(round((t - t0) / span * (width - 1)))

        for task in ex["tasks"]:
            row = [" "] * width
            if task["start"] is not None:
                lo, hi = col(task["start"]), col(task["end"])
                for c in range(0, lo):
                    row[c] = "."
                for c in range(lo, max(hi, lo) + 1):
                    row[c] = "#"
                if task["kind"] == "cancel_service":
                    row[min(hi, width - 1)] = "x"
            thr = (f"thr{task['thread']:02d}" if task["thread"] >= 0
                   else "  -  ")
            lines.append(
                f"  t{task['lane']:02d} {thr} |{''.join(row)}| "
                f"{task['kind']}")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# Serving-loop flight ring
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RoundFlight:
    """One serving round's phase breakdown on the compacted round clock.

    ``t0`` is the round's start in simulated seconds (the cumulative sum of
    all prior rounds' phase durations — rounds butt against each other, so
    the trace shows budget *composition*, not host idle time).  ``phases``
    is the ordered (name, seconds) list: admit (proxy fetch), decode (the
    fused admission+decode+prefill launch) and generate (the token loop)."""

    round: int
    t0: float
    phases: tuple
    requested: int
    served: int
    code: tuple

    @property
    def total_s(self) -> float:
        return float(sum(d for _, d in self.phases))


class FlightRing:
    """Fixed-capacity host-side ring of :class:`RoundFlight` records.

    The serving twin of the taskq flight plane: the closed-loop server
    appends one record per collected round (obs-gated, like its timeline
    ring) and the last ``capacity`` rounds stay resident; older rounds fall
    off the front."""

    def __init__(self, capacity: int = 256, label: str = "serve"):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = int(capacity)
        self.label = label
        self._rounds: deque[RoundFlight] = deque(maxlen=self.capacity)
        self._clock = 0.0
        self._count = 0

    def __len__(self) -> int:
        return len(self._rounds)

    def record(self, phases, *, requested: int, served: int,
               code: tuple) -> RoundFlight:
        """Append one round; ``phases`` is an ordered (name, seconds) list."""
        rf = RoundFlight(
            round=self._count, t0=self._clock,
            phases=tuple((str(n), float(d)) for n, d in phases),
            requested=int(requested), served=int(served), code=tuple(code),
        )
        self._rounds.append(rf)
        self._clock += rf.total_s
        self._count += 1
        return rf

    def rounds(self) -> list[RoundFlight]:
        return list(self._rounds)

    def records(self) -> list[dict]:
        """NDJSON-ready dicts, one per retained round (oldest first)."""
        return [{
            "schema": FLIGHT_SCHEMA,
            "label": self.label,
            "round": rf.round,
            "t0": rf.t0,
            "total_s": rf.total_s,
            "phases": {n: d for n, d in rf.phases},
            "requested": rf.requested,
            "served": rf.served,
            "code": list(rf.code),
        } for rf in self._rounds]

    def to_trace_events(self) -> list:
        """Round slices with nested phase slices on one simulated track."""
        pid = 0
        events: list = [{
            "ph": "M", "name": "process_name", "pid": pid,
            "args": {"name": f"flight:{self.label} (simulated round time)"},
        }, {
            "ph": "M", "name": "thread_name", "pid": pid, "tid": 0,
            "args": {"name": "serve rounds"},
        }]
        for rf in self._rounds:
            events.append({
                "name": f"round{rf.round}", "ph": "X", "cat": "flight",
                "ts": rf.t0 * 1e6, "dur": rf.total_s * 1e6,
                "pid": pid, "tid": 0,
                "args": {"requested": rf.requested, "served": rf.served,
                         "code": list(rf.code)},
            })
            t = rf.t0
            for name, dur in rf.phases:
                events.append({
                    "name": name, "ph": "X", "cat": "flight",
                    "ts": t * 1e6, "dur": dur * 1e6, "pid": pid, "tid": 0,
                    "args": {"round": rf.round},
                })
                t += dur
        return events

    def write_trace(self, path: str) -> str:
        return write_trace_doc(path, self.to_trace_events())
