"""SLO and controller-convergence monitoring over timeline snapshots.

The paper's headline claim is temporal — "the adaptation mechanism
converges to an appropriate code" — so this module turns the time-resolved
plane (:mod:`repro.obs.timeline`) into first-class measurements:

* :class:`SLOSpec` — a declarative delay objective: percentile target plus
  an error budget (the fraction of requests allowed past the target).
* :func:`burn_rate` — the windowed violation fraction over the timeline's
  delay-histogram deltas, divided by the budget: burn >= 1 means the
  window is eating budget faster than allowed (the breach condition).
* :func:`convergence` — pick-settling slot (first slot after which the
  rounded (n, k) pick never changes again) and per-code dwell fractions —
  the paper's Fig.-style convergence story as numbers.
* :func:`slo_report` — one dict tying it together, emitting breach /
  converge events both as instant marks into the span trace
  (:meth:`repro.obs.trace.Tracer.instant`) and as structured NDJSON lines
  through :class:`EventLog`.

Everything here is host-side numpy over :meth:`TimelineBuf.snapshot`
output — the device work already happened in the timeline fold.

Event-log schema (one JSON object per line)::

    {"schema": "repro.obs/event/v1", "ts": <unix seconds>,
     "kind": "slo_breach" | "slo_recovered" | "controller_converged",
     "label": <run label>, ...kind-specific fields}
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

import numpy as np

from repro.obs import trace as _trace
from repro.obs.timeline import bucket_edges, rolling_percentile

EVENT_SCHEMA = "repro.obs/event/v1"
REPORT_SCHEMA = "repro.obs/slo_report/v1"


@dataclasses.dataclass(frozen=True)
class SLOSpec:
    """Declarative delay objective for one timeline.

    ``percentile`` of delays must stay under ``target_s``; equivalently at
    most ``error_budget`` = 1 - percentile of requests may exceed it.  An
    explicit ``error_budget`` decouples the budget from the reported
    percentile (e.g. watch p99 against a 5% budget).  ``window`` is the
    trailing slot count burn rate / percentiles are judged over."""

    target_s: float
    percentile: float = 0.99
    error_budget: float | None = None
    window: int = 8

    @property
    def budget(self) -> float:
        if self.error_budget is not None:
            return float(self.error_budget)
        return max(1.0 - float(self.percentile), 1e-9)

    def to_dict(self) -> dict:
        return {
            "target_s": self.target_s,
            "percentile": self.percentile,
            "error_budget": self.budget,
            "window": self.window,
        }


class EventLog:
    """Structured NDJSON event sink (breach / converge / custom marks)."""

    def __init__(self, label: str = "run"):
        self.label = label
        self.events: list[dict] = []

    def emit(self, kind: str, **fields) -> dict:
        ev = {"schema": EVENT_SCHEMA, "ts": time.time(), "kind": kind,
              "label": self.label, **fields}
        self.events.append(ev)
        # Mirror into the span trace as an instant mark so breaches line up
        # with the compile/launch spans on the Perfetto timeline.
        _trace.get_tracer().instant(f"obs.{kind}", label=self.label, **fields)
        return ev

    def write(self, path: str) -> str:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as fh:
            for ev in self.events:
                fh.write(json.dumps(ev) + "\n")
        return path


def violation_fraction(hist_rows, target_s: float) -> np.ndarray:
    """Per-row fraction of observations strictly past ``target_s``.

    Only buckets whose LOWER edge clears the target count, so the estimate
    is conservative by at most one bucket (~9%); rows with no observations
    report 0 (no traffic burns no budget)."""
    h = np.asarray(hist_rows, np.float64)
    edges = bucket_edges()
    cut = int(np.searchsorted(edges, target_s, side="left")) + 1
    tot = h.sum(axis=-1)
    bad = h[..., cut:].sum(axis=-1)
    return np.where(tot > 0, bad / np.maximum(tot, 1.0), 0.0)


def burn_rate(hist_rows, spec: SLOSpec) -> np.ndarray:
    """Windowed budget burn: violation fraction over the trailing
    ``spec.window`` slots divided by the error budget (>= 1 = breach).

    Windows with zero observations are NaN — "no data", never a breach or
    a recovery: an idle stretch must not trip the monitor either way, and
    NaN propagates as a gap through the dashboards' NaN-aware renderers
    (same convention as :func:`repro.obs.timeline.hist_percentile`)."""
    h = np.asarray(hist_rows, np.float64)
    c = h.cumsum(axis=0)
    if spec.window < len(c):
        lo = np.concatenate([np.zeros_like(c[: spec.window]),
                             c[: -spec.window]], axis=0)
    else:
        lo = np.zeros_like(c)
    win = c - lo
    rate = violation_fraction(win, spec.target_s) / spec.budget
    return np.where(win.sum(axis=-1) > 0, rate, np.nan)


def convergence(pick_n, pick_k) -> dict:
    """Pick-settling slot + per-code dwell fractions from pick series.

    Picks are rounded to integer codes (sweep timelines carry per-window
    means).  ``settle_slot`` is the first slot from which the code never
    changes again (0 = settled immediately); ``dwell`` maps ``"n/k"`` to
    the fraction of slots spent at that code."""
    n = np.rint(np.asarray(pick_n, np.float64)).astype(int)
    k = np.rint(np.asarray(pick_k, np.float64)).astype(int)
    S = len(n)
    if S == 0:
        return {"settle_slot": 0, "settled": False, "final_code": None,
                "dwell": {}, "dwell_final": 0.0}
    same = (n == n[-1]) & (k == k[-1])
    # First index of the trailing all-final run.
    settle = S - 1
    while settle > 0 and same[settle - 1]:
        settle -= 1
    codes, counts = np.unique(
        np.stack([n, k], axis=1), axis=0, return_counts=True)
    dwell = {f"{int(cn)}/{int(ck)}": float(c) / S
             for (cn, ck), c in zip(codes, counts)}
    final = f"{int(n[-1])}/{int(k[-1])}"
    return {
        "settle_slot": int(settle),
        "settled": True,
        "final_code": [int(n[-1]), int(k[-1])],
        "dwell": dwell,
        "dwell_final": dwell[final],
    }


def slo_report(snap: dict, spec: SLOSpec, *, label: str = "serve",
               hist: str = "delay", events: EventLog | None = None,
               exemplars: list | None = None) -> dict:
    """The SLO/convergence report for one timeline snapshot.

    Emits ``slo_breach`` / ``slo_recovered`` edges (burn rate crossing 1)
    and one ``controller_converged`` event into ``events`` (a fresh
    :class:`EventLog` when None — returned under ``"events"`` either way).
    NaN burn slots (no-data windows) are skipped: they neither open nor
    close a breach.

    ``exemplars``: optional anatomies from
    :meth:`repro.obs.flight.FlightLog.exemplars` — breach events then carry
    the offending exemplar request ids (``exemplar_reqs``) so a breach line
    links straight to the per-request flight records, and the report
    summarizes them under ``"exemplars"``."""
    if events is None:
        events = EventLog(label)
    ex_reqs = [int(ex["req"]) for ex in (exemplars or [])]
    rows = np.asarray(snap["hists"][hist])
    burn = burn_rate(rows, spec)
    p_series = rolling_percentile(rows, spec.percentile, spec.window)
    conv = convergence(snap["series"]["pick_n"], snap["series"]["pick_k"])

    breached = False
    for slot, b in enumerate(burn):
        if not np.isfinite(b):
            continue  # no data: hold the current breach state
        if b >= 1.0 and not breached:
            breached = True
            events.emit("slo_breach", slot=slot, burn_rate=float(b),
                        target_s=spec.target_s, percentile=spec.percentile,
                        exemplar_reqs=ex_reqs)
        elif b < 1.0 and breached:
            breached = False
            events.emit("slo_recovered", slot=slot, burn_rate=float(b))
    if conv["settled"] and conv["final_code"] is not None:
        events.emit("controller_converged", slot=conv["settle_slot"],
                    code=conv["final_code"],
                    dwell_final=conv["dwell_final"])

    finite = p_series[np.isfinite(p_series)]
    finite_burn = burn[np.isfinite(burn)]
    report_exemplars = [
        {"req": int(ex["req"]), "total_s": float(ex["total_s"]),
         "queue_s": float(ex["queue_s"]), "n": int(ex["n"]),
         "k": int(ex["k"])}
        for ex in (exemplars or [])
    ]
    return {
        "schema": REPORT_SCHEMA,
        "label": label,
        "spec": spec.to_dict(),
        "slots": int(len(burn)),
        "window_arrivals": int(snap.get("window", 1)),
        "burn_rate": [float(b) for b in burn],
        "max_burn_rate": (
            float(finite_burn.max()) if len(finite_burn) else 0.0),
        "breach_slots": int((finite_burn >= 1.0).sum()),
        "exemplars": report_exemplars,
        "percentile_series_s": [float(p) for p in p_series],
        "percentile_last_s": float(finite[-1]) if len(finite) else None,
        "convergence": conv,
        "events": events,
    }
