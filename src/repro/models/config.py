"""Unified model configuration covering all 10 assigned architectures.

One frozen dataclass parameterizes every family (dense / moe / ssm / hybrid /
encdec / vlm); family-specific behavior keys off these fields inside the
model implementations. Exact per-arch instantiations live in
``repro/configs/<id>.py`` and are registered in
:mod:`repro.models.registry`.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None
    # MLP
    mlp_act: str = "silu"  # silu | gelu
    glu: bool = True
    # attention details
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int | None = None  # all layers SWA (mixtral)
    local_global_period: int | None = None  # gemma2: every other layer local
    local_window: int = 4096
    attn_softcap: float | None = None
    logit_softcap: float | None = None
    # MoE
    n_experts: int = 0
    top_k: int = 2
    capacity_factor: float = 1.25
    # SSM / recurrent
    ssm_state: int = 64
    ssm_conv: int = 4
    ssm_expand: int = 2
    slstm_every: int = 0  # xlstm: every j-th layer is an sLSTM block
    attn_every: int = 0  # zamba2: shared attention block every j layers
    # encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 1500  # whisper-base 30 s → 1500 frames (stub frontend)
    # vlm (pixtral)
    vision_patches: int = 1024  # stub ViT output length
    # numerics
    dtype: str = "bfloat16"
    # training-time knobs (hillclimbing levers; see EXPERIMENTS.md §Perf)
    remat_policy: str = "nothing"  # nothing | dots | full
    seq_shard_activations: bool = True  # Megatron-SP style residual sharding
    attn_q_chunk: int = 1024
    attn_kv_chunk: int = 1024
    ssm_chunk: int = 256
    # dry-run FLOPs pass: unroll scans so HLO cost_analysis counts every
    # loop iteration (XLA counts while-loop bodies once).
    scan_unroll: bool = False
    # §Perf lever: gather FSDP-sharded weights at the use site instead of
    # letting GSPMD all-reduce contraction outputs (MaxText-style).
    weight_gather: bool = False
    # §Perf lever: shard decode KV-cache sequence over "model" (256-way
    # caches) and update caches in-place through the layer-scan carry.
    decode_cache_seq_shard: bool = False
    # §Perf lever: "default" or "pure_dp" (replicate params, batch-only
    # sharding — right call for sub-1B models on 256 chips).
    sharding_profile: str = "default"
    # §Perf lever: gradient-accumulation microbatches per step (memory).
    grad_accum: int = 1

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def is_recurrent(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode at 512k: SSM/hybrid state or bounded SWA."""
        if self.is_recurrent:
            return True
        return self.sliding_window is not None and self.local_global_period is None

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs have an autoregressive decoder

    def param_count_dense(self) -> int:
        """Analytic parameter estimate (used for MODEL_FLOPS = 6·N·D)."""
        d, hd = self.d_model, self.hd
        emb = self.vocab * d * 2  # embed + untied head
        attn = d * (self.n_heads * hd) * 2 + d * (self.n_kv_heads * hd) * 2
        if self.n_experts:
            ff_unit = self.n_experts * (3 if self.glu else 2) * d * self.d_ff
            router = d * self.n_experts
            mlp = ff_unit + router
        elif self.d_ff:
            mlp = (3 if self.glu else 2) * d * self.d_ff
        else:
            mlp = 0
        if self.family == "ssm":
            # mLSTM-ish block: in/out proj at expansion + gates
            di = self.ssm_expand * d
            mlp = 0
            attn = 2 * d * di * 2 + 3 * di  # up/gate + down, cheap gates
        per_layer = attn + mlp
        if self.family == "hybrid":
            # Mamba2 backbone layers + ONE shared attn+mlp block (weights
            # applied at multiple depths but stored once).
            di = self.ssm_expand * d
            dconv = di + 2 * self.n_heads * self.ssm_state
            mamba = (
                d * (2 * di + 2 * self.n_heads * self.ssm_state + self.n_heads)
                + self.ssm_conv * dconv
                + di * d
            )
            return int(emb + self.n_layers * mamba + per_layer)
        total = emb + self.n_layers * per_layer
        if self.encoder_layers:
            total += self.encoder_layers * per_layer
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if not self.n_experts:
            return self.param_count_dense()
        full = self.param_count_dense()
        d = self.d_model
        ff_unit = (3 if self.glu else 2) * d * self.d_ff
        moe_total = self.n_layers * self.n_experts * ff_unit
        moe_active = self.n_layers * self.top_k * ff_unit
        return int(full - moe_total + moe_active)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""

    name: str
    kind: str  # train | prefill | decode
    seq: int
    batch: int


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def cell_is_runnable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(runnable, reason). long_500k needs sub-quadratic attention."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "full attention at 512k ctx is quadratic — skipped per task spec"
    return True, ""
