"""Unified batched MDS codec engine — one API over numpy / jnp / Pallas.

TOFEC's proxy re-picks the (n, k) MDS code on *every* arrival (§IV-C), so
the coding hot path sees a stream of heterogeneous codes. A naive jit-per-
(n, k) design retraces on each code change and encodes object-by-object;
this engine instead exposes one batched API

    encode(data: (batch, k, B)) -> (batch, n, B)      # systematic
    decode(rows: (batch, k, B), present)  -> (batch, k, B)

with **shape-bucketed jit caching**: compiled kernels are keyed on
(kind, k, bucket(n - k), bucket(B), bucket(batch)) and the actual GF(256)
coding matrices travel as *traced array inputs* (tiny, built host-side from
the cached Cauchy generator), so any (n, k) stream from ``TOFECPolicy``
reuses a small set of compilations instead of retracing per code. ``decode``
accepts a per-item ``present`` matrix, so one batched call reconstructs many
objects that each survived a *different* erasure pattern.

Backends (registry-selected):

* ``numpy``  — the table oracle (vectorized log/exp gathers on host). No
  compilation; the reference all others are tested against.
* ``jnp``    — pure ``jax.numpy`` log/exp-table backend (gather + XOR fold),
  vmap-free batched formulation, jit-cached per bucket.
* ``pallas`` — the GF(2) bit-matrix MXU kernel
  (:func:`repro.kernels.gf2mm.gf2mm.gf2_rs_matmul_bytes`), batched over the
  grid with bitplane pack/unpack fused into the kernel.

Selection: ``get_codec("jnp")`` explicitly, or ``get_codec()`` which reads
``REPRO_CODEC_BACKEND`` (default ``numpy``). ``REPRO_PALLAS_INTERPRET=1``
(the default in CPU containers) runs the Pallas backend in interpret mode;
set it to 0 on real TPUs.

Consumers: :mod:`repro.coding.layout` (file encode/reconstruct),
:mod:`repro.storage.proxy` (batched write-queue encode per admission round),
:mod:`repro.ckpt.checkpoint` (leaf sharding), and the codec throughput sweep
in ``benchmarks/kernel_bench.py``.
"""

from __future__ import annotations

import os
import threading

import numpy as np

from repro import obs
from repro.coding import gf256, rs

__all__ = [
    "Codec",
    "CodecStats",
    "get_codec",
    "default_backend",
    "register_backend",
    "available_backends",
    "pow2_bucket",
]


def pow2_bucket(x: int, floor: int = 1) -> int:
    """Smallest power of two ≥ max(x, floor)."""
    b = max(floor, 1)
    while b < x:
        b <<= 1
    return b


def default_pallas_interpret() -> bool:
    """Resolve REPRO_PALLAS_INTERPRET (default on: CPU containers). The one
    place this env var is parsed — backend, instance cache and the gf2mm ops
    module all share it."""
    return os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


def _is_traced(x) -> bool:
    """True when x is a JAX tracer (call made inside jit/vmap/grad)."""
    try:
        from jax.core import Tracer
    except ImportError:  # pragma: no cover
        return False
    return isinstance(x, Tracer)


#: Back-compat alias — codec counters (calls/items/traces) now live on the
#: shared :class:`repro.obs.CompileStats` so retrace accounting is uniform.
CodecStats = obs.CompileStats


class _Backend:
    """One coding backend: batched GF(256) matmul + optional jit bucketing.

    The single primitive every backend implements is

        matmul(mats: (batch, m, k) GF(256), data: (batch, k, B) bytes)
            -> (batch, m, B) bytes

    — parity rows for encode, inverted-generator rows for decode. ``mats``
    is always a *runtime* array so code changes never retrace.
    """

    name = "base"
    jitted = False

    def __init__(self, stats: CodecStats):
        self.stats = stats
        self._fns: dict[tuple, object] = {}
        self._lock = threading.Lock()  # guards _fns mutation only

    def matmul(self, mats, data):  # pragma: no cover - interface
        raise NotImplementedError

    def prep_mats(self, mats):
        """Host-side prep of (already padded) coding matrices into the form
        :meth:`matmul_traced` consumes — identity for the table backends,
        GF(2) bit-expansion for pallas. Runs once per admission round on
        tiny arrays; the result is a valid runtime input to a jitted step."""
        return mats

    def matmul_traced(self, mats, data):
        """Trace-safe matmul for use INSIDE an outer ``jax.jit`` (the fused
        serving step): both operands may be tracers, ``mats`` having been
        through :meth:`prep_mats`. Host-only backends raise."""
        raise TypeError(
            f"codec backend {self.name!r} is host-only; use the jnp or "
            "pallas backend inside jit-traced code"
        )

    def _fn_for(self, key: tuple, build):
        """Shared-cache lookup; only the dict mutation is locked, so
        concurrent encodes on different (or same) buckets run in parallel."""
        with self._lock:
            fn = self._fns.get(key)
            if fn is None:
                with obs.span("codec.build", backend=self.name,
                              bucket=str(key)):
                    fn = self._fns[key] = build()
        return fn

    def to_host(self, arr) -> np.ndarray:
        return np.asarray(arr)


class NumpyBackend(_Backend):
    """Vectorized table oracle; no compilation, runs anywhere."""

    name = "numpy"

    def matmul(self, mats, data):
        mats = np.asarray(mats, np.uint8)
        data = np.asarray(data, np.uint8)
        batch, m, k = mats.shape
        B = data.shape[2]
        out = np.zeros((batch, m, B), np.uint8)
        for t in range(k):  # k ≤ 256 and static; avoids a (b, m, k, B) temp
            prod = gf256.mul(mats[:, :, t : t + 1], data[:, t : t + 1, :])
            np.bitwise_xor(out, prod, out=out)
        return out


class JnpBackend(_Backend):
    """Pure jax.numpy log/exp-table backend, jit-cached per shape bucket."""

    name = "jnp"
    jitted = True

    def _build(self, k: int):
        import jax
        import jax.numpy as jnp

        # Keep the tables as host numpy in the closure: _build may run while
        # an OUTER jit (the fused serving step) is tracing, and any device
        # array created here would be a tracer leaking into the cached fn.
        exp_np = gf256.exp_table()
        log_np = gf256.log_table()

        def fn(mats, data):
            self.stats.traces += 1  # runs at trace time only
            exp = jnp.asarray(exp_np, jnp.int32)
            log = jnp.asarray(log_np, jnp.int32)
            a = mats.astype(jnp.int32)  # (batch, m, k)
            d = data.astype(jnp.int32)  # (batch, k, B)
            la, ld = log[a], log[d]
            out = jnp.zeros((a.shape[0], a.shape[1], d.shape[2]), jnp.int32)
            for t in range(k):  # static fold over the contraction dim
                prod = exp[la[:, :, t, None] + ld[:, None, t, :]]
                prod = jnp.where(
                    (a[:, :, t, None] == 0) | (d[:, None, t, :] == 0), 0, prod
                )
                out = jnp.bitwise_xor(out, prod)
            return out.astype(jnp.uint8)

        return jax.jit(fn)

    def matmul(self, mats, data):
        import jax.numpy as jnp

        k = mats.shape[2]
        key = (k, mats.shape[0], mats.shape[1], data.shape[2])
        fn = self._fn_for(key, lambda: self._build(k))
        return fn(jnp.asarray(mats), jnp.asarray(data))

    # The log/exp-table formulation is already trace-safe: shapes come from
    # the (possibly traced) operands and the inner jit inlines under an
    # outer jit, so the fused serving step reuses the same kernel.
    matmul_traced = matmul


class PallasBackend(_Backend):
    """GF(2) bit-matrix MXU kernel; fused bytes→bitplanes→bytes path."""

    name = "pallas"
    jitted = True

    def __init__(self, stats: CodecStats, interpret: bool | None = None):
        super().__init__(stats)
        if interpret is None:
            interpret = default_pallas_interpret()
        self.interpret = interpret

    def _build(self, k: int):
        import jax

        from repro.kernels.gf2mm.gf2mm import gf2_rs_matmul_bytes

        def fn(bitmats, data):
            self.stats.traces += 1  # runs at trace time only
            return gf2_rs_matmul_bytes(bitmats, data, interpret=self.interpret)

        return jax.jit(fn)

    def prep_mats(self, mats):
        """GF(2) bit-expansion (batch, m, k) → (batch, 8m, 8k); host-side."""
        return gf256.expand_bitmatrix_batched(np.asarray(mats, np.uint8))

    def matmul_traced(self, bitmats, data):
        """Kernel dispatch on pre-expanded bit-matrices; safe under jit."""
        import jax.numpy as jnp

        k = bitmats.shape[2] // 8
        key = (k, bitmats.shape[0], bitmats.shape[1] // 8, data.shape[2])
        fn = self._fn_for(key, lambda: self._build(k))
        return fn(jnp.asarray(bitmats), jnp.asarray(data))

    def matmul(self, mats, data):
        return self.matmul_traced(self.prep_mats(mats), data)


class Codec:
    """Batched systematic Cauchy-RS codec over a pluggable backend.

    All entry points accept and return host ``np.ndarray``; jitted backends
    move data through the device internally. Shape bucketing (powers of two
    on batch, parity count and strip width, zero-padded, sliced on exit)
    keeps the compiled-kernel set small under heterogeneous (n, k) streams.
    """

    #: floor for the strip-width bucket — keeps tile shapes lane-aligned.
    B_FLOOR = 128

    def __init__(self, backend: str | None = None, *, interpret: bool | None = None):
        name = backend or default_backend()
        if name not in _REGISTRY:
            raise ValueError(f"unknown codec backend {name!r}; have {sorted(_REGISTRY)}")
        self.stats = CodecStats(label=f"codec.{name}")
        if name == "pallas":
            self.backend: _Backend = _REGISTRY[name](self.stats, interpret=interpret)
        else:
            self.backend = _REGISTRY[name](self.stats)
        self.name = name

    # -- bucketing ----------------------------------------------------------

    def bucket_key(self, kind: str, n: int, k: int, B: int, batch: int) -> tuple:
        """The compilation-cache key a call with these params lands in."""
        if not self.backend.jitted:
            return (self.name,)
        m = k if kind == "dec" else n - k
        return (kind, k, pow2_bucket(m), pow2_bucket(B, self.B_FLOOR), pow2_bucket(batch))

    def _pad(self, arr, batch_b: int, B_b: int):
        batch, rows, B = arr.shape
        if batch == batch_b and B == B_b:
            return arr
        if isinstance(arr, np.ndarray):
            out = np.zeros((batch_b, rows, B_b), np.uint8)
            out[:batch, :, :B] = arr
            return out
        import jax.numpy as jnp  # traced / device input

        return jnp.zeros((batch_b, rows, B_b), jnp.uint8).at[:batch, :, :B].set(arr)

    def _as_bytes(self, arr):
        """(uint8 view, use_jnp flag) for the input.

        numpy inputs stay on host and come back as numpy. On the jitted
        backends, jax inputs — tracers (calls made under ``jax.jit``) and
        concrete device arrays alike — stay in jax-land end to end, so the
        codec composes with compiled steps and skips host round-trips.
        """
        if _is_traced(arr):
            if not self.backend.jitted:
                raise TypeError(
                    f"codec backend {self.name!r} is host-only; use the jnp or "
                    "pallas backend inside jit-traced code"
                )
            import jax.numpy as jnp

            return jnp.asarray(arr, jnp.uint8), True
        if self.backend.jitted:
            import jax

            if isinstance(arr, jax.Array):
                import jax.numpy as jnp

                return jnp.asarray(arr, jnp.uint8), True
        return np.asarray(arr, np.uint8), False

    # -- batched API --------------------------------------------------------

    def encode(self, data, n: int, k: int, *, n_out: int | None = None):
        """Systematic encode: (batch, k, B) → (batch, n, B). Also accepts a
        single codeword (k, B) and returns (n, B).

        ``n_out`` (k ≤ n_out ≤ n) produces only the FIRST n_out codeword rows
        — the write path's partial encode for an adapted (smaller) code.
        Cauchy parity rows depend on n − k, so this slices the full (n, k)
        parity matrix rather than building an (n_out, k) code: the emitted
        strips are bit-identical to a prefix of the full codeword and stay
        compatible with every chunking level of the same layout.

        numpy inputs return host numpy; on the jitted backends jax inputs
        (traced or concrete) return jax arrays, so the codec composes with
        compiled serving/checkpoint steps without host round-trips.
        """
        data, use_jnp = self._as_bytes(data)
        single = data.ndim == 2
        if single:
            data = data[None]
        if data.ndim != 3 or data.shape[1] != k:
            raise ValueError(f"data must be (batch, k={k}, B), got {data.shape}")
        if not 0 < k <= n:
            raise ValueError(f"need 0 < k <= n, got ({n=}, {k=})")
        if n_out is None:
            n_out = n
        elif not k <= n_out <= n:
            raise ValueError(f"need k <= n_out <= n, got ({n=}, {k=}, {n_out=})")
        batch, _, B = data.shape
        self.stats.calls += 1
        self.stats.items += batch
        if n_out == k:
            out = data
        else:
            # Prefix of the cached full parity matrix (see n_out docstring).
            par = rs.cauchy_parity_matrix(n, k)[: n_out - k]
            parity = self._matmul_bucketed("enc", par[None].repeat(batch, 0), data, n, k,
                                           use_jnp=use_jnp)
            if use_jnp:
                import jax.numpy as jnp

                out = jnp.concatenate([data, parity], axis=1)
            else:
                out = np.concatenate([data, parity], axis=1)
        return out[0] if single else out

    def decode(self, rows, present, n: int, k: int) -> np.ndarray:
        """Reconstruct data from any k surviving strips per item.

        rows: (batch, k, B) (or (k, B)); ``present`` is the strip ids of
        those rows — either one shared (k,) tuple or a per-item (batch, k)
        array, enabling one batched call across heterogeneous erasure
        patterns. Row order must match ``present`` (which must be concrete —
        it selects the host-side decode matrices — even when ``rows`` is
        traced).
        """
        rows, use_jnp = self._as_bytes(rows)
        single = rows.ndim == 2
        if single:
            rows = rows[None]
        if rows.ndim != 3 or rows.shape[1] != k:
            raise ValueError(f"rows must be (batch, k={k}, B), got {rows.shape}")
        batch, _, B = rows.shape
        present = np.asarray(present, np.int64)
        if present.ndim == 1:
            present = np.broadcast_to(present, (batch, k))
        if present.shape != (batch, k):
            raise ValueError(f"present must be (k,) or (batch, k), got {present.shape}")
        self.stats.calls += 1
        self.stats.items += batch
        out = self._matmul_bucketed("dec", self.decode_mats(present, n, k), rows, n, k,
                                    use_jnp=use_jnp)
        return out[0] if single else out

    def decode_mats(self, present, n: int, k: int) -> np.ndarray:
        """(batch, k, k) host decode matrices for per-item ``present``
        patterns — tiny inversions, cached per (n, k, pattern). This is the
        runtime-matrix input of the fused serving step: built host-side each
        round, fed to the jitted step as a traced array so erasure-pattern
        changes never retrace."""
        present = np.asarray(present, np.int64)
        if present.ndim == 1:
            present = present[None]
        return np.stack(
            [rs.decode_matrix(n, k, tuple(int(i) for i in p)) for p in present]
        )

    def pad_to_bucket(self, kind: str, mats: np.ndarray, data, n: int, k: int):
        """Zero-pad (mats, data) to the shape bucket this call lands in.

        Returns (mats_p, data_p, key) with key = :meth:`bucket_key`'s tuple.
        The ONE source of truth for bucket padding, shared by the unfused
        matmul path and the fused serving step (which feeds mats_p through
        ``backend.prep_mats`` into its own jitted launch); callers slice
        ``[:batch, :m, :B]`` off the result themselves."""
        batch, m, _ = mats.shape
        key = self.bucket_key(kind, n, k, data.shape[2], batch)
        if not self.backend.jitted:
            return mats, data, key
        _, _, m_b, B_b, batch_b = key
        mats_p = np.zeros((batch_b, m_b, k), np.uint8)
        mats_p[:batch, :m] = mats
        return mats_p, self._pad(data, batch_b, B_b), key

    def _matmul_bucketed(self, kind, mats, data, n, k, *, use_jnp=False):
        batch, m, _ = mats.shape
        B = data.shape[2]
        if not self.backend.jitted:
            return self.backend.matmul(mats, data)
        mats_p, data_p, _ = self.pad_to_bucket(kind, mats, data, n, k)
        out = self.backend.matmul(mats_p, data_p)
        if use_jnp:  # stay in jax-land (traced or device) for the caller
            return out[:batch, :m, :B]
        return self.backend.to_host(out)[:batch, :m, :B]

    # -- blob helpers (1-D payload convenience) -----------------------------

    @staticmethod
    def strip_bytes(payload_len: int, k: int) -> int:
        return -(-max(payload_len, 1) // k)

    def encode_blob(self, payload, *, n: int, k: int) -> np.ndarray:
        """1-D uint8 payload → (n, ceil(len/k)) coded strips."""
        return self.encode_blobs([payload], n=n, k=k)[0]

    def encode_blobs(self, payloads, *, n: int, k: int) -> list[np.ndarray]:
        """Batch-encode same-class payloads in ONE kernel launch.

        Payloads are packed to a common strip width (the max over the batch);
        each result is sliced back to its own ceil(len/k) strip width, which
        is lossless because coded columns depend only on same-index data
        columns (zero columns encode to zero).
        """
        bufs = [np.asarray(p, np.uint8).reshape(-1) for p in payloads]
        strips = [self.strip_bytes(b.size, k) for b in bufs]
        B = max(strips)
        data = np.zeros((len(bufs), k, B), np.uint8)
        for i, (b, s) in enumerate(zip(bufs, strips)):
            # Each blob keeps ITS OWN (k, strip_i) row layout, left-aligned
            # into the batch-max width; coded columns are column-independent,
            # so coded[i][:, :strip_i] equals the individually-encoded blob.
            row = np.zeros(k * s, np.uint8)
            row[: b.size] = b
            data[i, :, :s] = row.reshape(k, s)
        coded = self.encode(data, n, k)
        return [coded[i][:, : strips[i]] for i in range(len(bufs))]

    def decode_blob(self, strips, present, *, n: int, k: int, payload_len: int) -> np.ndarray:
        """Any k strips (k, strip) + their ids → payload bytes."""
        out = self.decode(np.asarray(strips, np.uint8), present, n, k)
        return out.reshape(-1)[:payload_len]


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, type] = {}
_INSTANCES: dict[tuple, Codec] = {}
_INSTANCES_LOCK = threading.Lock()


def register_backend(name: str, cls: type) -> None:
    _REGISTRY[name] = cls


def available_backends() -> list[str]:
    return sorted(_REGISTRY)


def default_backend() -> str:
    return os.environ.get("REPRO_CODEC_BACKEND", "numpy")


def get_codec(backend: str | None = None, *, interpret: bool | None = None) -> Codec:
    """Process-wide codec instance per (backend, resolved interpret) pair.

    ``interpret`` only applies to the pallas backend; ``None`` resolves to
    the ``REPRO_PALLAS_INTERPRET`` env default, so explicit and defaulted
    callers share one instance (and its jit caches).
    """
    name = backend or default_backend()
    if name == "pallas":
        if interpret is None:
            interpret = default_pallas_interpret()
    else:
        interpret = None
    key = (name, interpret)
    with _INSTANCES_LOCK:
        if key not in _INSTANCES:
            _INSTANCES[key] = Codec(name, interpret=interpret)
        return _INSTANCES[key]


register_backend("numpy", NumpyBackend)
register_backend("jnp", JnpBackend)
register_backend("pallas", PallasBackend)
