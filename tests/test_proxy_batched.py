"""Proxy batched read path: admission-round decode, raw reads, and the
paper's heavy-load adaptation (backlog pressure → fewer/larger chunks)."""

import threading

import numpy as np

from repro.coding.layout import SharedKeyLayout
from repro.core import PAPER_READ_3MB, RequestClass, StaticPolicy, TOFECPolicy
from repro.storage import FaultyStore, MemoryStore, Proxy, store_coded_object

LAYOUT = SharedKeyLayout(K=6, r=2, strip_bytes=128)


class _GatedStore(MemoryStore):
    """Deterministic fake store: ranged reads block until the gate opens,
    with a controllable post-gate delay. Lets a test pile up a backlog of
    known size before ANY task completes."""

    def __init__(self):
        super().__init__()
        self.gate = threading.Event()
        self.range_calls = 0
        self._count_lock = threading.Lock()

    def get_range(self, key, offset, length):
        self.gate.wait()
        with self._count_lock:
            self.range_calls += 1
        return super().get_range(key, offset, length)


def _payloads(rng, count, nbytes):
    return [rng.integers(0, 256, size=nbytes, dtype=np.uint8).tobytes() for _ in range(count)]


def test_read_many_batch_decodes_heterogeneous_erasures():
    """One round of concurrent reads with random per-item failures: every
    item reconstructs despite each surviving a different erasure pattern
    (the admission round's single batched decode path)."""
    rng = np.random.default_rng(0)
    inner = MemoryStore()
    payloads = _payloads(rng, 8, LAYOUT.file_bytes - 11)
    keys = []
    for i, p in enumerate(payloads):
        store_coded_object(inner, f"obj/{i}", LAYOUT, p)
        keys.append(f"obj/{i}")
    store = FaultyStore(inner, p_fail=0.15, seed=1)
    proxy = Proxy(store, StaticPolicy(12, 6), L=8)
    try:
        results = proxy.read_many(keys, LAYOUT, payload_len=len(payloads[0]))
        assert all(r.ok for r in results)
        for r, p in zip(results, payloads):
            assert r.data == p
    finally:
        proxy.close()


def test_raw_read_returns_chunks_for_external_decode():
    """raw=True skips proxy decode; the chunks round-trip through the
    layout's own reconstruct (what the fused serving step does in-jit)."""
    rng = np.random.default_rng(2)
    store = MemoryStore()
    payload = _payloads(rng, 1, LAYOUT.file_bytes)[0]
    store_coded_object(store, "raw/0", LAYOUT, payload)
    proxy = Proxy(store, StaticPolicy(6, 3), L=4)
    try:
        res = proxy.read("raw/0", LAYOUT, payload_len=len(payload), raw=True)
        assert res.ok and res.data is None
        assert res.chunks is not None and len(res.chunks) >= res.k
        got = LAYOUT.reconstruct(res.k, res.chunks, payload_len=len(payload))
        assert got == payload
    finally:
        proxy.close()


def test_mixed_chunk_levels_share_one_admission_round():
    """Reads admitted at different k levels all reconstruct correctly via
    the per-item present masks of the shared (N, K) strip code."""
    rng = np.random.default_rng(3)
    inner = MemoryStore()
    payloads = _payloads(rng, 6, LAYOUT.file_bytes)
    keys = []
    for i, p in enumerate(payloads):
        store_coded_object(inner, f"mix/{i}", LAYOUT, p)
        keys.append(f"mix/{i}")

    class _CyclePolicy(StaticPolicy):
        """Cycles the chunk level so one round mixes k = 6, 3, 2, 1."""

        def __init__(self):
            super().__init__(12, 6)
            self._cycle = [(12, 6), (6, 3), (4, 2), (2, 1), (3, 3), (2, 2)]
            self._i = 0

        def select(self, *, q, idle, cls_id=0, now=None):
            out = self._cycle[self._i % len(self._cycle)]
            self._i += 1
            return out

    proxy = Proxy(inner, _CyclePolicy(), L=8)
    try:
        results = proxy.read_many(keys, LAYOUT, payload_len=LAYOUT.file_bytes)
        assert all(r.ok for r in results)
        assert sorted({r.k for r in results}) == [1, 2, 3, 6]
        for r, p in zip(results, payloads):
            assert r.data == p
    finally:
        proxy.close()


def test_backlog_pressure_shifts_code_toward_fewer_chunks():
    """The paper's heavy-load behavior on the real-I/O proxy: as the gated
    backlog builds, TOFEC picks fewer/larger chunks (k drops from k_max
    toward 1), deterministically — selection happens at submission time
    while the store blocks every task."""
    rng = np.random.default_rng(4)
    store = _GatedStore()
    count = 24
    payloads = _payloads(rng, count, LAYOUT.file_bytes)
    keys = []
    for i, p in enumerate(payloads):
        store_coded_object(store, f"load/{i}", LAYOUT, p)
        keys.append(f"load/{i}")

    cls = RequestClass("gated", LAYOUT.file_bytes / 2**20, PAPER_READ_3MB,
                       k_max=6, r_max=2.0, n_max=12)
    proxy = Proxy(store, TOFECPolicy.for_classes([cls], L=8), L=8)
    try:
        # Submit the whole backlog while the store admits nothing.
        reqs = [proxy.read_async(k, LAYOUT, payload_len=LAYOUT.file_bytes) for k in keys]
        store.gate.set()
        results = [proxy.wait(r, timeout=60.0) for r in reqs]
        assert all(r.ok for r in results)
        for r, p in zip(results, payloads):
            assert r.data == p
        ks = [r.k for r in results]
        assert ks[0] == 6  # empty queue → max chunking (light-load optimum)
        assert ks[-1] == 1  # deep backlog → no chunking (heavy-load optimum)
        # Monotone non-increasing in submission order: the EWMA only grows
        # while the gate is closed (modulo the one-in-flight admission slot).
        assert all(b <= a + 1 for a, b in zip(ks, ks[1:]))
        assert {1, 6} <= set(ks)
    finally:
        proxy.close()
