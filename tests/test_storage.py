"""Storage backends + real-I/O proxy tests."""

import os

import numpy as np
import pytest

from repro.coding.layout import SharedKeyLayout
from repro.core import PAPER_READ_3MB, GreedyPolicy, StaticPolicy
from repro.storage import (
    FaultyStore,
    FileStore,
    LatencyStore,
    MemoryStore,
    Proxy,
    StorageError,
    store_coded_object,
)

LAYOUT = SharedKeyLayout(K=6, r=2, strip_bytes=256)


@pytest.mark.parametrize("make", [MemoryStore, lambda: FileStore("/tmp/repro_store_test")])
def test_store_basic_and_range(make):
    s = make()
    s.put("a", b"hello world")
    assert s.get("a") == b"hello world"
    assert s.get_range("a", 6, 5) == b"world"
    assert s.exists("a")
    s.delete("a")
    assert not s.exists("a")
    with pytest.raises(StorageError):
        s.get("a")


@pytest.mark.parametrize("make", [MemoryStore, lambda: FileStore("/tmp/repro_store_test2")])
def test_store_multipart(make):
    s = make()
    s.upload_part("obj", 0, b"AA")
    s.upload_part("obj", 2, b"CC")
    s.upload_part("obj", 1, b"BB")
    s.complete_multipart("obj", [0, 1, 2])
    assert s.get("obj") == b"AABBCC"


def test_latency_store_accumulates_emulated_time():
    s = LatencyStore(MemoryStore(), PAPER_READ_3MB, time_scale=0.0, seed=1)
    s.put("x", b"z" * 1024)
    s.get("x")
    assert s.emulated_busy_s > 2 * PAPER_READ_3MB.delta_bar  # one write + one read


def test_faulty_store_lost_object():
    s = FaultyStore(MemoryStore())
    s.put("x", b"data")
    s.lose_object("x")
    with pytest.raises(StorageError):
        s.get("x")
    assert not s.exists("x")


def _mk_payload(rng, nbytes):
    return rng.integers(0, 256, size=nbytes, dtype=np.uint8).tobytes()


def test_proxy_read_roundtrip_static_code():
    rng = np.random.default_rng(0)
    store = MemoryStore()
    payload = _mk_payload(rng, LAYOUT.file_bytes - 100)
    store_coded_object(store, "f1", LAYOUT, payload)
    proxy = Proxy(store, StaticPolicy(6, 3), L=8)
    try:
        res = proxy.read("f1", LAYOUT, payload_len=len(payload))
        assert res.ok and res.data == payload
        assert (res.n, res.k) == (6, 3)
    finally:
        proxy.close()


def test_proxy_read_survives_chunk_failures():
    rng = np.random.default_rng(1)
    inner = MemoryStore()
    payload = _mk_payload(rng, LAYOUT.file_bytes)
    store_coded_object(inner, "f2", LAYOUT, payload)
    store = FaultyStore(inner, p_fail=0.3, seed=2)
    proxy = Proxy(store, StaticPolicy(6, 3), L=8)
    try:
        ok = 0
        for _ in range(10):
            res = proxy.read("f2", LAYOUT, payload_len=len(payload))
            if res.ok:
                assert res.data == payload
                ok += 1
        assert ok >= 7  # (6,3) tolerates up to 3 failed tasks per request
    finally:
        proxy.close()


def test_proxy_write_then_read():
    rng = np.random.default_rng(3)
    store = MemoryStore()
    proxy = Proxy(store, GreedyPolicy(k_max=6, r_max=2.0), L=16)
    payload = _mk_payload(rng, LAYOUT.file_bytes - 7)
    try:
        wres = proxy.write("f3", LAYOUT, payload)
        assert wres.ok
        # Writer stored >= k parts; assemble the full coded object from the
        # durable parts for subsequent reads (background completion).
        coded = LAYOUT.encode_file(payload)
        store.put("f3", coded)
        res = proxy.read("f3", LAYOUT, payload_len=len(payload))
        assert res.ok and res.data == payload
    finally:
        proxy.close()


@pytest.mark.skipif(
    os.environ.get("CI") == "true",
    reason="wall-clock median comparison across real proxy threads; flaky "
    "under shared-runner scheduler contention (flakes at seed HEAD too)",
)
def test_proxy_latency_tail_beats_basic():
    """Redundant ranged reads cut tail latency vs (1,1) — the paper's point,
    on the real-I/O path with emulated S3 latencies. Tail-heavy parameters
    make the erasure-coding gain dominate thread overhead at small scale."""
    from repro.core import DelayParams

    tail_heavy = DelayParams(delta_bar=0.01, delta_tilde=0.001, psi_bar=0.25, psi_tilde=0.01)
    rng = np.random.default_rng(4)
    payload = _mk_payload(rng, LAYOUT.file_bytes)
    lat_a = LatencyStore(MemoryStore(), tail_heavy, time_scale=3e-2, seed=5)
    lat_b = LatencyStore(MemoryStore(), tail_heavy, time_scale=3e-2, seed=5)
    store_coded_object(lat_a.inner, "f", LAYOUT, payload)
    store_coded_object(lat_b.inner, "f", LAYOUT, payload)

    def run(store, policy, n_req=30):
        proxy = Proxy(store, policy, L=8)
        try:
            ts = []
            for _ in range(n_req):
                r = proxy.read("f", LAYOUT, payload_len=len(payload))
                assert r.ok
                ts.append(r.total_s)
            return np.array(ts)
        finally:
            proxy.close()

    # Medians are robust to scheduler-noise outliers, but the comparison is
    # still wall-clock across real threads: retry a few times so one noisy
    # scheduling window on a contended box doesn't fail the suite. The
    # emulated-latency gap (6-2 code ≈ 3× tail cut) dominates overhead.
    for attempt in range(4):
        t_coded = run(lat_a, StaticPolicy(6, 2))  # 2-of-6: heavy tail trim
        t_basic = run(lat_b, StaticPolicy(1, 1))
        if np.median(t_coded) < np.median(t_basic):
            break
    assert np.median(t_coded) < np.median(t_basic)
