from repro.serve.engine import FusedServingStep, ServeResult, ServingEngine
