"""Traceable code-selection policies for the task-level engine.

The engine observes the *exact* proxy state at each arrival — the FIFO
backlog length ``q`` and the idle-thread count ``idle`` — so policies here
see what :class:`repro.core.controller.Policy` implementations see on the
host, not the fluid waiting-work proxy of :mod:`repro.core.jax_sim`. Two
policy families ride every grid point as runtime data and are selected with
``jnp.where`` on a per-point id (the fleet's policies-as-data trick), so a
heterogeneous mix of threshold and greedy points compiles once:

* ``POL_TABLE`` — the threshold form ``1 + #{h > q̄}`` shared with the fleet
  (:func:`repro.core.controller.tofec_threshold_step`), covering TOFEC,
  static codes and fixed-k via :func:`repro.fleet.sweep.policy_tables`.
* ``POL_GREEDY`` — §V-A's Greedy heuristic, previously exiled to the host
  event simulator because it needs the instantaneous idle-thread count the
  fluid scan cannot provide. :func:`greedy_select` is its traceable form,
  pinned select-for-select against :class:`repro.core.controller.
  GreedyPolicy` in ``tests/test_taskq.py``.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.fleet.sweep import PolicySpec, policy_tables

#: Per-grid-point policy ids (runtime data, never a static arg).
POL_TABLE = 0
POL_GREEDY = 1


def greedy_select(q, idle, k_max, r_max) -> tuple[jax.Array, jax.Array]:
    """Traceable §V-A Greedy: (n, k) from the idle-thread count.

    Chunk as much as idle threads allow (k = min(k_max, idle)), then add
    redundancy as long as idle threads remain (n = min(⌊r_max·k⌋, idle)) —
    the closed-form argmin of expected completion time over the feasible
    codes when every chosen task can start immediately: more chunks shrink
    each task linearly while redundancy only trims the order-statistic tail,
    so filling idle threads with chunks first is optimal under the paper's
    Δ(B), 1/μ(B) model. Falls back to the basic (1, 1) code when no thread
    is idle. ``q`` is accepted (and ignored) to mirror the host
    :meth:`Policy.select` observation signature; every argument may be a
    tracer. Matches :class:`repro.core.controller.GreedyPolicy` decision for
    decision, including the float-truncation of ``int(r_max · k)``.
    """
    del q  # greedy keys on idle threads only (host parity)
    idle = jnp.asarray(idle, jnp.int32)
    k = jnp.minimum(jnp.asarray(k_max, jnp.int32), idle)
    n = jnp.minimum(
        (jnp.asarray(r_max, jnp.float32) * k.astype(jnp.float32)).astype(jnp.int32),
        jnp.maximum(idle, 1),
    )
    n = jnp.maximum(n, k)
    one = jnp.int32(1)
    return jnp.where(idle > 0, n, one), jnp.where(idle > 0, k, one)


@dataclasses.dataclass(frozen=True)
class EncodedPolicy:
    """One grid point's policy as runtime arrays (tables zeroed for greedy —
    trailing-zero thresholds are inert, the fleet padding convention)."""

    pol: int          # POL_TABLE | POL_GREEDY
    h_k: np.ndarray   # (hk_len,) float32
    h_n: np.ndarray   # (hn_len,) float32
    r_max: float
    alpha: float
    gk_max: int       # greedy k_max (1 for table policies; inert)


def encode_policy(spec: PolicySpec, cls, L: int, hk_len: int, hn_len: int,
                  plan=None) -> EncodedPolicy:
    """Resolve a :class:`repro.fleet.sweep.PolicySpec` for the task engine."""
    h_k = np.zeros(hk_len, np.float32)
    h_n = np.zeros(hn_len, np.float32)
    if spec.kind == "greedy":
        return EncodedPolicy(
            pol=POL_GREEDY, h_k=h_k, h_n=h_n, r_max=float(cls.r_max),
            alpha=spec.alpha, gk_max=int(cls.k_max),
        )
    hk, hn, r_max = policy_tables(spec, cls, L, plan)
    h_k[: len(hk)] = hk
    h_n[: len(hn)] = hn
    return EncodedPolicy(
        pol=POL_TABLE, h_k=h_k, h_n=h_n, r_max=float(r_max),
        alpha=spec.alpha, gk_max=1,
    )
