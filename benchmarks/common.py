"""Shared benchmark scaffolding: the paper's evaluation setup (§V-A).

One class (read, 3 MB), L = 16 threads, k_max = 6, r_max = 2, synthetic
North-California-calibrated traces; arrival-rate grids as fractions of the
basic (1,1) capacity. CSV artifacts land in benchmarks/results/.
"""

from __future__ import annotations

import csv
import os
import time

import numpy as np

from repro.core import (
    PAPER_READ_3MB,
    FixedKAdaptivePolicy,
    GreedyPolicy,
    RequestClass,
    StaticPolicy,
    TOFECPolicy,
)
from repro.core import queueing
from repro.core.simulator import poisson_arrivals, simulate
from repro.core.traces import TraceSampler

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")

CLS = RequestClass("read3mb", 3.0, PAPER_READ_3MB, k_max=6, r_max=2.0, n_max=12)
L = 16
CAPACITY_BASIC = queueing.capacity(PAPER_READ_3MB, CLS.file_mb, 1, 1.0, L)  # ≈ 76.5 req/s

SAMPLER = TraceSampler(PAPER_READ_3MB, CLS.file_mb, correlation=0.14)  # Shared Key


def all_static_codes(k_max: int = 6, r_max: float = 2.0, n_max: int = 12):
    """Every (n, k) with k ≤ k_max, k ≤ n ≤ min(r_max·k, n_max)."""
    out = []
    for k in range(1, k_max + 1):
        for n in range(k, min(int(r_max * k), n_max) + 1):
            out.append((n, k))
    return out


def run_policy(policy, lam: float, count: int = 4000, seed: int = 1):
    rng = np.random.default_rng(seed)
    arr = poisson_arrivals(rng, lam, count)
    return simulate(policy, arr, SAMPLER, L=L, seed=seed + 17)


def rate_grid(n: int = 8, lo_frac: float = 0.1, hi_frac: float = 0.92):
    return np.linspace(lo_frac * CAPACITY_BASIC, hi_frac * CAPACITY_BASIC, n)


_FLEET_SWEEP = None


def fleet_sweep():
    """Process-wide :class:`repro.fleet.FleetSweep` so every figure's
    λ-sweep shares one compilation cache (lazy: keeps jax out of the
    import path of the event-sim-only benches)."""
    global _FLEET_SWEEP
    if _FLEET_SWEEP is None:
        from repro.fleet import FleetSweep

        _FLEET_SWEEP = FleetSweep(chunk=64)
    return _FLEET_SWEEP


_TASKQ = None


def taskq_sweep():
    """Process-wide (:class:`repro.taskq.TaskqSweep`, shared-key
    :class:`repro.core.traces.DevicePools`) pair — the exact task-level
    engine behind the figures' Greedy rows (and any other point that needs
    per-request exactness). Pools mirror ``SAMPLER``'s shared-key setup."""
    global _TASKQ
    if _TASKQ is None:
        from repro.core.traces import TraceStore
        from repro.taskq import TaskqSweep

        store = TraceStore.generate(
            PAPER_READ_3MB, [CLS.file_mb / k for k in range(1, CLS.k_max + 1)],
            threads=CLS.n_max, samples=8192, correlation=0.14, seed=5,
        )
        _TASKQ = (TaskqSweep(chunk=64), store.device_pools(n_max=CLS.n_max))
    return _TASKQ


def fresh_tofec(alpha: float = 0.99) -> TOFECPolicy:
    return TOFECPolicy.for_classes([CLS], L, alpha=alpha)


def fresh_greedy() -> GreedyPolicy:
    return GreedyPolicy(CLS.k_max, CLS.r_max)


def fresh_fixedk(k: int = 6) -> FixedKAdaptivePolicy:
    return FixedKAdaptivePolicy(CLS, L, k=k)


def write_csv(name: str, header: list[str], rows: list[list]):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name)
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        w.writerows(rows)
    return path


class BenchTimer:
    """Context helper: report `name,us_per_call,derived` lines."""

    def __init__(self, name: str, calls: int = 1):
        self.name = name
        self.calls = calls

    def __enter__(self):
        self.t0 = time.monotonic()
        return self

    def __exit__(self, *exc):
        self.elapsed = time.monotonic() - self.t0

    def row(self, derived: str) -> str:
        us = 1e6 * self.elapsed / max(self.calls, 1)
        return f"{self.name},{us:.1f},{derived}"
