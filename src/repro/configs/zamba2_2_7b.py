"""zamba2-2.7b [hybrid]: Mamba2 backbone + shared attention block.

54L Mamba2 (d_model=2560, ssm_state=64) with one SHARED attention+MLP block
(32H MHA, d_ff=10240) applied every 6 backbone layers. [arXiv:2411.15242]
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab=32000,
    ssm_state=64,
    ssm_conv=4,
    ssm_expand=2,
    attn_every=6,
    local_window=4096,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=512, ssm_state=16, attn_every=2, ssm_chunk=8, local_window=8,
    )
