from repro.models.config import SHAPES, ModelConfig, ShapeSpec, cell_is_runnable
from repro.models.registry import Arch, arch_names, get, make_batch, runnable_cells

__all__ = [
    "ModelConfig",
    "ShapeSpec",
    "SHAPES",
    "cell_is_runnable",
    "Arch",
    "get",
    "arch_names",
    "make_batch",
    "runnable_cells",
]
