"""repro.obs — unified telemetry across the serving tower and sweep engines.

Three layers (see ROADMAP "Conventions"):

* device-resident metrics — :class:`MetricsBuf` pytrees threaded through
  the jitted hot paths and folded per chunk (no host syncs);
* host span tracing — :func:`span` / :func:`traced` around compile /
  launch / upload / finalize boundaries, exported as Chrome trace JSON via
  :func:`write_trace` and aggregate tables via :func:`aggregate`;
* shared compile accounting — :class:`CompileStats` behind every engine's
  ``stats`` object, queryable in one shot via :func:`compile_snapshot`.

Everything is gated on ``REPRO_OBS=1`` (or :func:`set_enabled`); disabled,
the layer costs one branch per site and changes no compiled graph.
"""
from repro.obs.state import enabled, set_enabled
from repro.obs.compile import CompileStats, compile_snapshot, register_stats
from repro.obs.metrics import (
    PICK_BINS,
    MetricsBuf,
    sweep_point_metrics,
    to_prometheus,
    valid_mask,
)
from repro.obs.trace import (
    Tracer,
    aggregate,
    get_tracer,
    reset_trace,
    span,
    traced,
    write_trace,
)
from repro.obs.meta import SCHEMA_VERSION, git_rev, run_meta

__all__ = [
    "enabled",
    "set_enabled",
    "CompileStats",
    "compile_snapshot",
    "register_stats",
    "MetricsBuf",
    "PICK_BINS",
    "sweep_point_metrics",
    "valid_mask",
    "to_prometheus",
    "Tracer",
    "span",
    "traced",
    "get_tracer",
    "write_trace",
    "aggregate",
    "reset_trace",
    "SCHEMA_VERSION",
    "git_rev",
    "run_meta",
]
