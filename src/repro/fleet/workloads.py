"""Workload-generator family: one spec, two consumers.

Every workload answers the same two questions from one spec and one RNG
stream:

* ``arrival_times(rng)`` — absolute arrival instants for the discrete-event
  oracle (:func:`repro.core.simulator.simulate`), and
* ``interarrivals(rng, count)`` — a fixed-length device-ready float32 array
  for the jitted scan (:func:`repro.core.jax_sim.tofec_scan_core`) and the
  fleet sweep.

Generators (the scenario diversity of the journal version arXiv:1403.5007
and FAST CLOUD arXiv:1301.1294):

* :class:`PoissonWorkload`    — homogeneous Poisson(λ).
* :class:`MMPPWorkload`       — Markov-modulated Poisson: exponential dwell
                                in each state, per-state rate (bursty).
* :class:`DiurnalWorkload`    — sinusoidal rate λ(t) = base·(1 + a·sin(·)).
* :class:`FlashCrowdWorkload` — step to a peak rate on [t_on, t_off).
* :class:`PiecewiseWorkload`  — piecewise-constant trace replay; absorbs
                                ``repro.core.simulator.piecewise_poisson_
                                arrivals`` (now a thin wrapper over this).
* :class:`TenantMix`          — multi-class tenant mixes over
                                :class:`repro.core.delay_model.RequestClass`
                                (per-class arrival splits + event-sim
                                class-id streams).

Time-varying rates use exact methods where the rate is piecewise constant
(per-segment/per-dwell exponentials) and Lewis-Shedler thinning for the
continuous diurnal profile.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.delay_model import RequestClass


def _as_float32(times: np.ndarray, count: int | None) -> np.ndarray:
    inter = np.diff(times, prepend=0.0).astype(np.float32)
    if count is not None:
        inter = inter[:count]
    return inter


class Workload:
    """Interface: a stochastic arrival process with a well-defined mean rate."""

    def mean_rate(self) -> float:
        raise NotImplementedError

    def arrival_times(self, rng: np.random.Generator, horizon: float | None = None) -> np.ndarray:
        """Absolute arrival times on [0, horizon); default horizon covers
        ~``DEFAULT_COUNT`` arrivals at the mean rate."""
        raise NotImplementedError

    DEFAULT_COUNT = 4096

    def interarrivals(self, rng: np.random.Generator, count: int) -> np.ndarray:
        """(count,) float32 interarrival gaps — the device-ready form.

        Generic implementation: draw arrival times over a horizon sized for
        ``count`` arrivals at the mean rate (retrying with a larger horizon
        on shortfall), then difference.
        """
        horizon = 1.25 * count / self.mean_rate()
        for _ in range(16):
            times = self.arrival_times(rng, horizon)
            if len(times) >= count:
                return _as_float32(times, count)
            horizon *= 2.0
        raise RuntimeError(f"workload {self!r} could not produce {count} arrivals")

    def device_arrays(
        self, rng: np.random.Generator, count: int, n_max: int
    ) -> tuple[np.ndarray, np.ndarray]:
        """(interarrivals (count,), Exp(1) draws (count, n_max)) — everything
        one fleet grid point feeds the scan."""
        inter = self.interarrivals(rng, count)
        exps = rng.exponential(1.0, size=(count, n_max)).astype(np.float32)
        return inter, exps


@dataclasses.dataclass(frozen=True)
class PoissonWorkload(Workload):
    """Homogeneous Poisson arrivals at rate ``lam``."""

    lam: float

    def mean_rate(self) -> float:
        return self.lam

    def arrival_times(self, rng, horizon=None):
        horizon = horizon or self.DEFAULT_COUNT / self.lam
        # Draw in blocks of the expected count (+5σ) until past the horizon.
        n_exp = max(int(self.lam * horizon + 5.0 * np.sqrt(self.lam * horizon)), 16)
        times = np.cumsum(rng.exponential(1.0 / self.lam, size=n_exp))
        while times[-1] < horizon:
            times = np.concatenate(
                [times, times[-1] + np.cumsum(rng.exponential(1.0 / self.lam, size=n_exp))]
            )
        return times[times < horizon]

    def interarrivals(self, rng, count):
        return rng.exponential(1.0 / self.lam, size=count).astype(np.float32)


@dataclasses.dataclass(frozen=True)
class MMPPWorkload(Workload):
    """Markov-modulated Poisson process: exponential dwells, per-state rates.

    ``rates[i]`` is the Poisson rate in state i; ``dwell[i]`` the mean dwell
    time. The classic 2-state on/off burst model is ``rates=(lo, hi)``;
    states cycle (i → i+1 mod S), which for S = 2 is exactly the alternating
    renewal burst process.
    """

    rates: tuple[float, ...]
    dwell: tuple[float, ...]

    def __post_init__(self):
        if len(self.rates) != len(self.dwell) or not self.rates:
            raise ValueError("rates and dwell must be equal-length, non-empty")

    def mean_rate(self) -> float:
        d = np.asarray(self.dwell)
        return float(np.dot(self.rates, d) / d.sum())

    def arrival_times(self, rng, horizon=None):
        horizon = horizon or self.DEFAULT_COUNT / self.mean_rate()
        out, t, state = [], 0.0, 0
        while t < horizon:
            stay = rng.exponential(self.dwell[state])
            end = min(t + stay, horizon)
            lam = self.rates[state]
            if lam > 0.0:
                tt = t
                while True:
                    tt += rng.exponential(1.0 / lam)
                    if tt >= end:
                        break
                    out.append(tt)
            t += stay
            state = (state + 1) % len(self.rates)
        return np.asarray(out)


@dataclasses.dataclass(frozen=True)
class DiurnalWorkload(Workload):
    """Sinusoidal rate λ(t) = base·(1 + amplitude·sin(2πt/period))."""

    base: float
    amplitude: float
    period: float

    def __post_init__(self):
        if not 0.0 <= self.amplitude < 1.0:
            raise ValueError("amplitude must be in [0, 1) so the rate stays positive")

    def mean_rate(self) -> float:
        return self.base

    def rate(self, t: np.ndarray) -> np.ndarray:
        return self.base * (1.0 + self.amplitude * np.sin(2.0 * np.pi * t / self.period))

    def arrival_times(self, rng, horizon=None):
        horizon = horizon or self.DEFAULT_COUNT / self.base
        # Lewis-Shedler thinning against the envelope rate, in blocks.
        lam_max = self.base * (1.0 + self.amplitude)
        out, t = [], 0.0
        block = max(int(lam_max * horizon / 4), 64)
        while t < horizon:
            gaps = rng.exponential(1.0 / lam_max, size=block)
            cand = t + np.cumsum(gaps)
            keep = rng.uniform(size=block) * lam_max < self.rate(cand)
            out.append(cand[keep])
            t = cand[-1]
        times = np.concatenate(out)
        return times[times < horizon]


@dataclasses.dataclass(frozen=True)
class FlashCrowdWorkload(Workload):
    """Step workload: ``base`` rate, jumping to ``peak`` on [t_on, t_off)."""

    base: float
    peak: float
    t_on: float
    t_off: float

    def __post_init__(self):
        if not 0.0 <= self.t_on < self.t_off:
            raise ValueError("need 0 <= t_on < t_off")

    def mean_rate(self) -> float:
        # Rate averaged over one "episode" [0, t_off + t_on) — used only to
        # size horizons, so the pre/post-flash base split is fine.
        span = self.t_off + self.t_on
        burst = self.t_off - self.t_on
        return (self.base * (span - burst) + self.peak * burst) / span

    def _segments(self, horizon: float) -> list[tuple[float, float]]:
        segs = [(min(self.t_on, horizon), self.base)]
        if horizon > self.t_on:
            segs.append((min(self.t_off, horizon) - self.t_on, self.peak))
        if horizon > self.t_off:
            segs.append((horizon - self.t_off, self.base))
        return [(d, r) for d, r in segs if d > 0.0]

    def arrival_times(self, rng, horizon=None):
        horizon = horizon or self.DEFAULT_COUNT / self.mean_rate()
        return PiecewiseWorkload(tuple(self._segments(horizon))).arrival_times(rng, horizon)


@dataclasses.dataclass(frozen=True)
class PiecewiseWorkload(Workload):
    """Piecewise-constant trace replay: consecutive (duration_s, rate)
    segments, cycled if more arrivals are requested than one pass provides
    (the paper's Fig.10 transient setup is one pass of three segments)."""

    segments: tuple[tuple[float, float], ...]

    def __post_init__(self):
        if not self.segments or any(d <= 0 or r < 0 for d, r in self.segments):
            raise ValueError("segments must be non-empty (duration>0, rate>=0) pairs")

    def total_duration(self) -> float:
        return float(sum(d for d, _ in self.segments))

    def mean_rate(self) -> float:
        return float(sum(d * r for d, r in self.segments) / self.total_duration())

    def arrival_times(self, rng, horizon=None):
        """One pass over the segments (clipped/cycled to ``horizon``).

        Draw-for-draw identical to the historical
        ``repro.core.simulator.piecewise_poisson_arrivals`` for the default
        horizon: per segment, exponential gaps are accumulated until one
        crosses the segment boundary (that crossing draw is discarded, as a
        fresh exponential restarts each segment — memorylessness makes this
        exact).
        """
        horizon = horizon if horizon is not None else self.total_duration()
        out: list[float] = []
        t0 = 0.0
        while t0 < horizon:
            for dur, lam in self.segments:
                end = min(t0 + dur, horizon)
                if lam > 0.0:
                    t = t0
                    while True:
                        t += rng.exponential(1.0 / lam)
                        if t >= end:
                            break
                        out.append(t)
                t0 += dur
                if t0 >= horizon:
                    break
        return np.asarray(out)


@dataclasses.dataclass(frozen=True)
class TenantMix(Workload):
    """Multi-class tenant mix: total rate ``lam`` split across request
    classes by ``weights`` (§IV's multiple (type, size) classes).

    For the host event sim this is one merged Poisson stream plus a
    categorical ``cls_ids`` stream (``simulate(..., cls_ids=..., samplers=
    ...)``). For the device sweep, :meth:`split` expands the mix into
    per-class sub-workloads (independent Poisson splitting), each of which
    becomes its own grid point with its own class tables.
    """

    lam: float
    classes: tuple[RequestClass, ...]
    weights: tuple[float, ...]

    def __post_init__(self):
        if len(self.classes) != len(self.weights) or not self.classes:
            raise ValueError("classes and weights must be equal-length, non-empty")
        if abs(sum(self.weights) - 1.0) > 1e-6 or any(w < 0 for w in self.weights):
            raise ValueError("weights must be non-negative and sum to 1")

    def mean_rate(self) -> float:
        return self.lam

    def arrival_times(self, rng, horizon=None):
        return PoissonWorkload(self.lam).arrival_times(rng, horizon)

    def interarrivals(self, rng, count):
        return PoissonWorkload(self.lam).interarrivals(rng, count)

    def cls_ids(self, rng: np.random.Generator, count: int) -> np.ndarray:
        """Per-arrival class ids for the event sim's ``cls_ids`` argument."""
        return rng.choice(len(self.classes), size=count, p=np.asarray(self.weights))

    def multiclass_device_arrays(
        self, rng: np.random.Generator, count: int, n_max: int
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(interarrivals (count,), Exp(1) draws (count, n_max), class ids
        (count,)) — everything one joint shared-pool grid point feeds
        :func:`repro.sched.scan.multiclass_scan_core`.

        RNG plumbing matches :meth:`Workload.device_arrays` draw for draw:
        interarrivals then exponentials from the same stream, and a
        single-class mix consumes NO extra draws for the ids (they are all
        zero) — the degenerate-equivalence guarantee that a one-class mix
        through the joint scan reproduces ``tofec_scan_core`` exactly.
        """
        inter = self.interarrivals(rng, count)
        exps = rng.exponential(1.0, size=(count, n_max)).astype(np.float32)
        if len(self.classes) == 1:
            ids = np.zeros(count, np.int32)
        else:
            ids = self.cls_ids(rng, count).astype(np.int32)
        return inter, exps, ids

    def split(self) -> list[tuple[RequestClass, "PoissonWorkload"]]:
        """Per-class (class, Poisson(w·λ)) sub-workloads (Poisson splitting)."""
        return [
            (c, PoissonWorkload(self.lam * w))
            for c, w in zip(self.classes, self.weights)
            if w > 0.0
        ]
