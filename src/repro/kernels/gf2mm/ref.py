"""Pure-jnp oracles for the GF(2)/GF(256) encode path.

``gf2_matmul_ref`` is the direct oracle for the Pallas kernel.
``gf256_matmul_ref`` is the table-based GF(256) matmul — the "mechanical
port" of CPU RS encode (gather-heavy; kept as oracle + benchmark baseline).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.coding import gf256


def gf2_matmul_ref(a, b):
    """(A @ B) mod 2 in int32; exact for 0/1 inputs."""
    a = jnp.asarray(a, jnp.int32)
    b = jnp.asarray(b, jnp.int32)
    return (a @ b) % 2


def _jnp_tables():
    exp = jnp.asarray(gf256.exp_table(), jnp.int32)
    log = jnp.asarray(gf256.log_table(), jnp.int32)
    return exp, log


def gf256_mul_ref(a, b):
    """Elementwise GF(256) multiply via log/exp gathers (jnp)."""
    exp, log = _jnp_tables()
    a = jnp.asarray(a, jnp.int32)
    b = jnp.asarray(b, jnp.int32)
    out = exp[log[a] + log[b]]
    return jnp.where((a == 0) | (b == 0), 0, out).astype(jnp.uint8)


def gf256_matmul_ref(g, d):
    """GF(256) matmul (n, k) @ (k, B) -> (n, B) via gathers + XOR reduce."""
    g = jnp.asarray(g, jnp.int32)
    d = jnp.asarray(d, jnp.int32)
    prod = gf256_mul_ref(g[:, :, None], d[None, :, :]).astype(jnp.int32)
    # XOR-reduce over the contraction axis, bit by bit is unnecessary:
    # jnp has no bitwise_xor.reduce; fold with a loop over k (small).
    out = jnp.zeros((g.shape[0], d.shape[1]), jnp.int32)
    for t in range(g.shape[1]):  # k is small & static (<= 256)
        out = jnp.bitwise_xor(out, prod[:, t, :])
    return out.astype(jnp.uint8)


def bytes_to_bitplanes_ref(data):
    """(k, B) uint8 -> (8k, B) 0/1 uint8, LSB-first (jnp)."""
    data = jnp.asarray(data, jnp.uint8)
    k, B = data.shape
    shifts = jnp.arange(8, dtype=jnp.uint8)
    planes = (data[:, None, :] >> shifts[None, :, None]) & 1
    return planes.reshape(8 * k, B)


def bitplanes_to_bytes_ref(planes):
    """(8n, B) 0/1 -> (n, B) uint8 (jnp)."""
    planes = jnp.asarray(planes, jnp.uint8)
    n8, B = planes.shape
    n = n8 // 8
    shifts = jnp.arange(8, dtype=jnp.uint8)
    grouped = planes.reshape(n, 8, B)
    vals = grouped << shifts[None, :, None]
    out = jnp.zeros((n, B), jnp.uint8)
    for b in range(8):
        out = jnp.bitwise_or(out, vals[:, b, :])
    return out


def rs_parity_ref(parity_gf256: np.ndarray, data):
    """Oracle for the full encode path: parity rows = P ·_{GF256} data."""
    return gf256_matmul_ref(jnp.asarray(parity_gf256), data)
