"""Unified batched codec engine: cross-backend equivalence, batched-vs-
looped parity, per-item erasure patterns, blob helpers, and the bucketed-jit
retrace guarantee (≤ #buckets compilations for a mixed (n, k) stream)."""

import numpy as np
import pytest

from repro.coding import rs
from repro.coding.codec import Codec, available_backends, get_codec

BACKENDS = ["numpy", "jnp", "pallas"]

# (n, k) grid including the degenerate corners: n = k (no parity) and k = 1
# (replication-style codes).
NK_GRID = [(1, 1), (2, 1), (4, 1), (3, 3), (4, 3), (6, 3), (12, 6), (5, 4), (8, 4)]


def _rng(seed=0):
    return np.random.default_rng(seed)


def test_registry_lists_all_backends():
    assert set(BACKENDS) <= set(available_backends())
    with pytest.raises(ValueError):
        Codec("no-such-backend")


@pytest.mark.parametrize("backend", BACKENDS)
def test_encode_matches_numpy_oracle_over_grid(backend):
    rng = _rng(1)
    c = Codec(backend)
    for n, k in NK_GRID:
        B = int(rng.integers(1, 150))
        batch = int(rng.integers(1, 5))
        data = rng.integers(0, 256, size=(batch, k, B), dtype=np.uint8)
        got = np.asarray(c.encode(data, n, k))
        want = np.stack([rs.encode(data[i], n, k) for i in range(batch)])
        np.testing.assert_array_equal(got, want)
        # systematic prefix
        np.testing.assert_array_equal(got[:, :k], data)


@pytest.mark.parametrize("backend", BACKENDS)
def test_decode_any_k_of_n_per_item_present(backend):
    """One batched decode call across items with different erasure patterns."""
    rng = _rng(2)
    c = Codec(backend)
    for n, k in NK_GRID:
        B = int(rng.integers(1, 100))
        batch = 3
        data = rng.integers(0, 256, size=(batch, k, B), dtype=np.uint8)
        coded = np.stack([rs.encode(data[i], n, k) for i in range(batch)])
        present = np.stack(
            [np.sort(rng.choice(n, size=k, replace=False)) for _ in range(batch)]
        )
        rows = np.stack([coded[i][present[i]] for i in range(batch)])
        got = np.asarray(c.decode(rows, present, n, k))
        np.testing.assert_array_equal(got, data)


@pytest.mark.parametrize("backend", BACKENDS)
def test_batched_equals_looped(backend):
    rng = _rng(3)
    c = Codec(backend)
    n, k, B, batch = 9, 4, 123, 8
    data = rng.integers(0, 256, size=(batch, k, B), dtype=np.uint8)
    batched = np.asarray(c.encode(data, n, k))
    looped = np.stack([np.asarray(c.encode(data[i], n, k)) for i in range(batch)])
    np.testing.assert_array_equal(batched, looped)


@pytest.mark.parametrize("backend", BACKENDS)
def test_single_codeword_rank2_api(backend):
    rng = _rng(4)
    c = Codec(backend)
    data = rng.integers(0, 256, size=(3, 50), dtype=np.uint8)
    coded = np.asarray(c.encode(data, 6, 3))
    assert coded.shape == (6, 50)
    present = (1, 4, 5)
    got = np.asarray(c.decode(coded[list(present)], present, 6, 3))
    np.testing.assert_array_equal(got, data)


@pytest.mark.parametrize("backend", BACKENDS)
def test_blob_helpers_roundtrip_mixed_sizes(backend):
    rng = _rng(5)
    c = Codec(backend)
    n, k = 7, 3
    payloads = [
        rng.integers(0, 256, size=sz, dtype=np.uint8)
        for sz in (1, 17, 1000, 257, 3 * 64)
    ]
    all_strips = c.encode_blobs(payloads, n=n, k=k)
    # batched blob encode must equal the one-at-a-time path byte for byte
    for p, strips in zip(payloads, all_strips):
        np.testing.assert_array_equal(strips, c.encode_blob(p, n=n, k=k))
        assert strips.shape == (n, Codec.strip_bytes(p.size, k))
        present = tuple(np.sort(rng.choice(n, size=k, replace=False)))
        got = c.decode_blob(strips[list(present)], present, n=n, k=k, payload_len=p.size)
        np.testing.assert_array_equal(got, p)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_bucketed_jit_bounds_retraces(backend):
    """A heterogeneous (n, k) stream compiles ≤ once per shape bucket."""
    rng = _rng(6)
    c = Codec(backend)  # fresh instance: clean trace counter + jit cache
    stream = [(n, k) for k in (2, 4) for n in (k, k + 1, k + 2, 2 * k)]
    buckets = set()
    for n, k in stream * 2:  # revisit every code: second pass must be free
        B = int(rng.integers(60, 128))
        data = rng.integers(0, 256, size=(2, k, B), dtype=np.uint8)
        coded = np.asarray(c.encode(data, n, k))
        if n > k:
            buckets.add(c.bucket_key("enc", n, k, B, 2))
        present = tuple(range(n - k, n))
        got = np.asarray(c.decode(coded[:, list(present)], present, n, k))
        np.testing.assert_array_equal(got, data)
        buckets.add(c.bucket_key("dec", n, k, B, 2))
    assert c.stats.traces <= len(buckets), (
        f"{c.stats.traces} compilations for {len(buckets)} shape buckets"
    )
    # sanity: far fewer compilations than calls
    assert c.stats.calls > 2 * len(buckets)


def test_stats_and_numpy_never_compiles():
    c = Codec("numpy")
    data = _rng(7).integers(0, 256, size=(4, 3, 40), dtype=np.uint8)
    c.encode(data, 6, 3)
    assert c.stats.traces == 0
    assert c.stats.calls == 1
    assert c.stats.items == 4


def test_get_codec_is_cached_per_backend():
    a = get_codec("numpy")
    b = get_codec("numpy")
    assert a is b
    assert get_codec("jnp") is not a


def test_encode_rejects_bad_shapes():
    c = Codec("numpy")
    with pytest.raises(ValueError):
        c.encode(np.zeros((2, 4, 8), np.uint8), n=6, k=3)  # k mismatch
    with pytest.raises(ValueError):
        c.encode(np.zeros((3, 8), np.uint8), n=2, k=3)  # n < k
    with pytest.raises(ValueError):
        c.decode(np.zeros((3, 8), np.uint8), (0, 1), n=6, k=3)  # short present
