"""On-device reductions: sweep outputs → throughput-delay frontiers.

Consumes a :class:`repro.fleet.sweep.SweepResult` (stacked device arrays)
and produces the paper's evaluation quantities without a per-point host
loop: mean and p50/p95/p99 total delay, mean chosen (n, k), mean thread
usage U(n, k) and the capacity estimate L/Ū it implies, per-policy
throughput-delay frontiers, adaptation-convergence statistics, the
TOFEC-vs-static headline ratios (Fig.7/8: ~2.5× lower light-load delay than
the throughput-optimal basic code, ~3× the capacity of the latency-optimal
static code), and the ``BENCH_fleet.json`` artifact feeding the bench
trajectory.
"""

from __future__ import annotations

import dataclasses
import json
import os

import numpy as np

from repro import obs
from repro.fleet import stats


@dataclasses.dataclass
class FrontierPoint:
    """Reduced statistics for one grid point."""

    policy: str
    lam: float
    seed: int
    cls_name: str
    mean: float
    p50: float
    p90: float
    p95: float
    p99: float
    std: float
    mean_queueing: float
    mean_k: float
    mean_n: float
    mean_usage: float
    util: float          # offered utilization λ·Ū/L of the chosen code mix
    capacity_est: float  # L/Ū: the rate at which this code mix saturates

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


#: The whole-block reduction is the shared kernel in :mod:`repro.fleet.stats`
#: — one implementation serves the materialized path here and the per-chunk
#: streaming fold (:func:`repro.fleet.sweep.frontier_fold`), which is what
#: makes streamed statistics bit-exact equals of materialized ones.
_reduce_block = stats.frontier_block_reduce


def _reduced(result, warmup_frac: float):
    streamed = getattr(result, "streamed", None)
    if streamed is not None:
        return streamed.require(warmup_frac)
    cfg = result.cfg
    red = _reduce_block(
        result.out, cfg["delta_bar"], cfg["delta_tilde"], cfg["psi_bar"],
        cfg["psi_tilde"], cfg["J"], w=int(result.count * warmup_frac),
    )
    return {k: np.asarray(v) for k, v in red.items()}


def frontier_points(result, warmup_frac: float = 0.05) -> list[FrontierPoint]:
    """Per-grid-point statistics, reduced on device in one jitted call."""
    red = _reduced(result, warmup_frac)
    points = []
    for i, case in enumerate(result.cases):
        usage = float(red["mean_usage"][i])
        points.append(FrontierPoint(
            policy=case.policy.name,
            lam=case.lam,
            seed=case.seed,
            cls_name=case.cls.name,
            mean=float(red["mean"][i]),
            p50=float(red["p50"][i]),
            p90=float(red["p90"][i]),
            p95=float(red["p95"][i]),
            p99=float(red["p99"][i]),
            std=float(red["std"][i]),
            mean_queueing=float(red["mean_queueing"][i]),
            mean_k=float(red["mean_k"][i]),
            mean_n=float(red["mean_n"][i]),
            mean_usage=usage,
            util=case.lam * usage / case.L,
            capacity_est=case.L / usage,
        ))
    return points


def frontier(points: list[FrontierPoint]) -> dict[str, list[FrontierPoint]]:
    """Group by policy, λ-sorted: the Fig.1/Fig.7 delay-vs-rate curves."""
    by: dict[str, list[FrontierPoint]] = {}
    for pt in points:
        by.setdefault(pt.policy, []).append(pt)
    for pts in by.values():
        pts.sort(key=lambda p: (p.lam, p.seed))
    return by


def capacity_estimates(points: list[FrontierPoint], *, util_cap: float = 0.98) -> dict[str, float]:
    """Per-policy supportable-rate estimate.

    For each policy, take the highest-λ grid point still stable
    (util < util_cap) and report the L/Ū its chosen code mix implies —
    static codes give their constant L/U, adaptive policies the capacity of
    the codes they degrade to under load (basic-like, per Corollary 1).
    Falls back to the minimum L/Ū over the grid when no point is stable.
    """
    caps: dict[str, float] = {}
    for name, pts in frontier(points).items():
        stable = [p for p in pts if p.util < util_cap]
        caps[name] = stable[-1].capacity_est if stable else min(p.capacity_est for p in pts)
    return caps


def convergence_stats(result, warmup_frac: float = 0.05) -> list[dict]:
    """Adaptation convergence per grid point: how fast k settles.

    ``settle_frac``: fraction of the (post-warmup) horizon after which the
    chosen k never leaves ±1 of its final mode; ``modal_frac``: fraction of
    requests served exactly at the modal k. Static policies settle at 0.

    Streamed results read the convergence integers the per-chunk fold
    accumulated (:func:`repro.fleet.stats.convergence_reduce`) and finish
    the exact fractions here — identical values, no (G, T) block.
    """
    w = int(result.count * warmup_frac)
    horizon = max(result.count - w, 1)
    streamed = getattr(result, "streamed", None)
    if streamed is not None:
        red = streamed.require(warmup_frac)
        return [
            {
                "policy": case.policy.name,
                "lam": case.lam,
                "seed": case.seed,
                "modal_k": int(red["modal_k"][i]),
                "modal_frac": int(red["modal_count"][i]) / horizon,
                "settle_frac": int(red["settle_idx"][i]) / horizon,
            }
            for i, case in enumerate(result.cases)
        ]
    ks = np.asarray(result.out["k"])
    out = []
    for i, case in enumerate(result.cases):
        k_i = ks[i, w:]
        modal = int(np.bincount(k_i).argmax())
        off = np.abs(k_i.astype(np.int64) - modal) > 1
        settle_idx = int(np.max(np.nonzero(off)[0])) + 1 if off.any() else 0
        out.append({
            "policy": case.policy.name,
            "lam": case.lam,
            "seed": case.seed,
            "modal_k": modal,
            "modal_frac": float((k_i == modal).mean()),
            "settle_frac": settle_idx / max(len(k_i), 1),
        })
    return out


def headline_ratios(points: list[FrontierPoint]) -> dict:
    """The paper's two headline comparisons, computed from the frontier.

    * ``delay_gain_vs_basic`` — mean-delay ratio of the throughput-optimal
      static code (basic (1,1)) over TOFEC at the lightest common λ
      (paper: ~2.5×).
    * ``capacity_gain_vs_latency_optimal`` — TOFEC's capacity estimate over
      that of the latency-optimal static code (the static policy with the
      lowest light-load mean delay; paper: ~3×).
    """
    by = frontier(points)
    out: dict = {}
    caps = capacity_estimates(points)
    tofec = by.get("tofec")
    basic = by.get("static(1,1)")
    if tofec and basic:
        lam0 = min(p.lam for p in tofec)
        t0 = next(p for p in tofec if p.lam == lam0)
        b0 = min((p for p in basic), key=lambda p: abs(p.lam - lam0))
        out["light_lam"] = lam0
        out["tofec_light_mean"] = t0.mean
        out["basic_light_mean"] = b0.mean
        out["delay_gain_vs_basic"] = b0.mean / t0.mean
    statics = {n: pts for n, pts in by.items() if n.startswith("static(") and n != "static(1,1)"}
    if tofec and statics:
        # Latency-optimal static: best mean at the lightest λ.
        lam0 = min(p.lam for p in tofec)
        best_name = min(
            statics,
            key=lambda n: min(p.mean for p in statics[n] if p.lam <= lam0 * 1.5 + 1e-9),
        )
        out["latency_optimal_static"] = best_name
        out["capacity_tofec"] = caps.get("tofec")
        out["capacity_latency_optimal"] = caps.get(best_name)
        if caps.get(best_name):
            out["capacity_gain_vs_latency_optimal"] = caps["tofec"] / caps[best_name]
    return out


def write_fleet_artifact(
    path: str,
    result,
    *,
    warmup_frac: float = 0.05,
    extra: dict | None = None,
    points: list[FrontierPoint] | None = None,
) -> dict:
    """Reduce a sweep and write the ``BENCH_fleet.json`` artifact.

    Returns the artifact dict (also written to ``path``): grid metadata,
    per-point frontier stats, per-policy capacities, convergence stats and
    the headline TOFEC-vs-static ratios. Pass ``points`` to reuse an
    already-computed :func:`frontier_points` reduction.
    """
    if points is None:
        points = frontier_points(result, warmup_frac)
    artifact = {
        "schema": "repro.fleet/BENCH_fleet/v1",
        "meta": obs.run_meta(mesh_shape=getattr(result, "mesh_shape", ())),
        "grid_size": len(result.cases),
        "count": result.count,
        "compiles": result.compiles,
        "launches": result.launches,
        "points": [p.to_dict() for p in points],
        "capacity_req_s": capacity_estimates(points),
        "convergence": convergence_stats(result, warmup_frac),
        "headline": headline_ratios(points),
    }
    if extra:
        artifact.update(extra)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(artifact, f, indent=1)
    return artifact
