"""Vmapped fleet sweep: a whole (λ × policy × seed) grid per jitted launch.

One grid point = one :func:`repro.core.jax_sim.tofec_scan_core` run. The
sweep stacks every per-point quantity — delay-model params, threshold
tables, redundancy cap, arrival/exponential draws — along a leading grid
axis and ``vmap``s the scan core over it, so a 256-point λ-sweep costs a
handful of launches instead of 256 serial ones.

Uniformity across the grid is manufactured, not assumed:

* **Policies as tables.** The scan's controller is the threshold form
  ``1 + #{h > q̄}``; :func:`static_tables` and :func:`fixedk_tables` encode
  static (n, k) codes and the fixed-k adaptive strategy of [3] into the
  same (h_k, h_n, r_max) triple (sentinel-``BIG``/0 thresholds pin the
  choice), so heterogeneous policy mixes ride one vmapped launch.
* **Shape-bucketed jit caching.** Following the ``Codec.pad_to_bucket``
  convention, compiled sweeps are keyed on (chunk, pow2-bucketed T, n_max,
  table lengths); trailing-zero threshold padding and zero-gap arrival
  padding are semantically inert (outputs are sliced back), so
  heterogeneous grids compile once per bucket — asserted in
  ``tests/test_fleet.py``.
* **Memory-bounded chunked batching.** The grid axis is split into
  ``chunk``-sized launches (the last chunk padded by repetition), bounding
  per-launch device footprint at chunk × T × (n_max + 2) float32s.
"""

from __future__ import annotations

import dataclasses
import types

import numpy as np

from repro import obs
from repro.coding.codec import pow2_bucket
from repro.core.delay_model import RequestClass
from repro.core.static_optimizer import ClassPlan, build_class_plan
from repro.fleet.workloads import PoissonWorkload, TenantMix, Workload

#: Finite stand-in for +inf thresholds (float32 max, like TofecTables).
BIG = float(np.finfo(np.float32).max)


# ---------------------------------------------------------------------------
# Policies as threshold tables
# ---------------------------------------------------------------------------


def static_tables(n: int, k: int, k_max: int, n_max: int):
    """(h_k, h_n, r_max) pinning the controller to the static code (n, k).

    With the threshold rule ``k = 1 + #{h[1:] > q̄}``, k-1 leading ``BIG``
    entries and trailing zeros select k for every q̄ ≥ 0; same for n. The
    half-chunk slack in r_max keeps the float cap ``int(r_max·k)`` == n.
    """
    if not 1 <= k <= n <= n_max or k > k_max:
        raise ValueError(f"invalid static code ({n},{k}) for k_max={k_max}, n_max={n_max}")
    h_k = np.zeros(k_max + 1, np.float32)
    h_k[:k] = BIG
    h_n = np.zeros(n_max + 1, np.float32)
    h_n[:n] = BIG
    return h_k, h_n, (n + 0.5) / k


def fixedk_tables(cls: RequestClass, L: int, k: int, *, eq7_factor: float = 2.0):
    """(h_k, h_n, r_max) for the fixed-k, adaptive-n strategy of [3].

    Reuses :class:`repro.core.controller.FixedKAdaptivePolicy`'s Q→n table,
    re-indexed into the scan's 1-based threshold form: k-1 ``BIG`` entries
    shift the count so ``1 + #{h_n > q̄}`` lands on n ∈ [k, n_max].
    """
    from repro.core.controller import FixedKAdaptivePolicy

    pol = FixedKAdaptivePolicy(cls, L, k=k, eq7_factor=eq7_factor)
    h_k = np.zeros(cls.k_max + 1, np.float32)
    h_k[:k] = BIG
    h_n = np.concatenate([[BIG] * k, pol.h_n[1:]]).astype(np.float32)
    h_n = np.where(np.isinf(h_n), BIG, h_n)
    assert h_n.shape == (cls.n_max + 1,)
    return h_k, h_n, (cls.n_max + 0.5) / k


@dataclasses.dataclass(frozen=True)
class PolicySpec:
    """Declarative policy for a grid point: tofec | static | fixedk | greedy.

    ``greedy`` (§V-A idle-thread heuristic) is NOT table-expressible — it
    observes the instantaneous idle-thread count, which the fluid scan does
    not model. Greedy grid points only run on the exact task-level engine
    (:class:`repro.taskq.TaskqSweep`); :func:`policy_tables` raises for them.
    """

    kind: str
    n: int = 0
    k: int = 0
    alpha: float = 0.99
    eq7_factor: float = 2.0

    @classmethod
    def tofec(cls, alpha: float = 0.99, eq7_factor: float = 2.0) -> "PolicySpec":
        return cls("tofec", alpha=alpha, eq7_factor=eq7_factor)

    @classmethod
    def static(cls, n: int, k: int) -> "PolicySpec":
        return cls("static", n=n, k=k)

    @classmethod
    def fixedk(cls, k: int, eq7_factor: float = 2.0) -> "PolicySpec":
        return cls("fixedk", k=k, eq7_factor=eq7_factor)

    @classmethod
    def greedy(cls) -> "PolicySpec":
        return cls("greedy")

    @property
    def name(self) -> str:
        if self.kind == "static":
            return f"static({self.n},{self.k})"
        if self.kind == "fixedk":
            return f"fixedk(k={self.k})"
        if self.kind == "greedy":
            return "greedy"
        return "tofec"


def policy_tables(spec: PolicySpec, cls: RequestClass, L: int, plan: ClassPlan | None = None):
    """Resolve a :class:`PolicySpec` to (h_k, h_n, r_max) numpy tables."""
    if spec.kind == "static":
        return static_tables(spec.n, spec.k, cls.k_max, cls.n_max)
    if spec.kind == "fixedk":
        return fixedk_tables(cls, L, spec.k, eq7_factor=spec.eq7_factor)
    if spec.kind == "tofec":
        plan = plan or build_class_plan(cls, L, eq7_factor=spec.eq7_factor)
        h_k = np.where(np.isinf(plan.h_k), BIG, plan.h_k).astype(np.float32)
        h_n = np.where(np.isinf(plan.h_n), BIG, plan.h_n).astype(np.float32)
        return h_k, h_n, float(cls.r_max)
    if spec.kind == "greedy":
        raise ValueError(
            "greedy is not table-expressible (it observes idle threads, not "
            "backlog); run it on the exact task engine: repro.taskq.TaskqSweep"
        )
    raise ValueError(f"unknown policy kind {spec.kind!r}")


# ---------------------------------------------------------------------------
# Grid construction
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SweepCase:
    """One grid point: arrival process × policy × seed (× class, L)."""

    lam: float
    policy: PolicySpec
    seed: int
    cls: RequestClass
    L: int = 16
    workload: Workload | None = None  # default: Poisson(lam)

    def resolved_workload(self) -> Workload:
        return self.workload if self.workload is not None else PoissonWorkload(self.lam)


def grid_cases(
    lams,
    policies,
    seeds,
    cls: RequestClass,
    L: int = 16,
    workload_for=None,
) -> list[SweepCase]:
    """Cartesian λ × policy × seed grid; ``workload_for(lam)`` optionally
    maps each rate to a non-Poisson workload spec."""
    return [
        SweepCase(
            lam=float(lam), policy=pol, seed=int(seed), cls=cls, L=L,
            workload=workload_for(float(lam)) if workload_for else None,
        )
        for lam in lams
        for pol in policies
        for seed in seeds
    ]


def tenant_cases(
    mix: TenantMix, policies, seeds, L: int = 16, *, quiet: bool = False
) -> list[SweepCase]:
    """Expand a multi-tenant mix into per-class grid points (Poisson
    splitting): each class rides the sweep with its own tables and its
    split rate w·λ.

    .. note:: This is the documented **approximation path**: splitting gives
       every class an independent fluid queue that believes it owns all L
       threads, so cross-class interference — §IV's shared-resource story —
       is invisible (a starved low-priority class, FIFO head-of-line
       coupling, weighted shares). Use :class:`repro.sched.SchedSweep` with
       a :class:`repro.sched.DisciplineSpec` for the joint shared-pool
       simulation; pass ``quiet=True`` here when the fluid split is wanted
       deliberately (e.g. as the no-interference baseline in benchmarks).
    """
    if not quiet:
        import warnings

        warnings.warn(
            "tenant_cases() Poisson-splits the mix into independent per-class "
            "fluid queues and cannot show cross-class interference; use "
            "repro.sched (SchedSweep + DisciplineSpec) for the joint "
            "shared-pool simulation, or pass quiet=True to keep the fluid "
            "split deliberately.",
            UserWarning,
            stacklevel=2,
        )
    return [
        SweepCase(lam=sub.lam, policy=pol, seed=int(seed), cls=c, L=L, workload=sub)
        for c, sub in mix.split()
        for pol in policies
        for seed in seeds
    ]


# ---------------------------------------------------------------------------
# The vmapped sweep engine
# ---------------------------------------------------------------------------


#: Back-compat alias — the per-engine counter dataclass now lives in
#: :mod:`repro.obs` so retrace accounting is uniform across engines.
SweepStats = obs.CompileStats


class ChunkedVmapSweep:
    """Shared engine for chunked, shape-bucketed vmapped case sweeps.

    Owns what :class:`FleetSweep` and :class:`repro.sched.sweep.SchedSweep`
    have in common: the compile cache keyed by shape bucket, the
    trace-counting jit+vmap wrapper, the per-(class, L) plan cache, and the
    chunked launch loop (tail chunk padded by repetition, outputs sliced
    back and restacked). Subclasses define the bucket key, the per-case
    config stacking and the single-case scan body.

    ``chunk`` bounds the grid points per launch (memory bound); ``t_floor``
    floors the pow2 time-axis bucket so nearby horizon lengths share a
    compilation, mirroring ``Codec.B_FLOOR``.

    ``mesh`` (None | int device count | 1-D jax Mesh) shards every launch's
    chunk axis across a device mesh via :func:`repro.fleet.shard.
    shard_grid`: axis-0 operands split along the grid axis, ``in_axes=None``
    broadcast operands replicate. Compilations are keyed additionally on
    the mesh shape, and the effective chunk is rounded up to a mesh-size
    multiple so every device owns an equal slice.
    """

    T_FLOOR = 512

    def __init__(self, *, chunk: int = 64, t_floor: int | None = None,
                 mesh=None):
        if chunk < 1:
            raise ValueError("chunk must be >= 1")
        from repro.fleet.shard import resolve_grid_mesh

        self.chunk = chunk
        self.t_floor = t_floor or self.T_FLOOR
        self.mesh = resolve_grid_mesh(mesh)
        self.stats = obs.CompileStats(label=f"sweep.{type(self).__name__}")
        self._fns: dict[tuple, object] = {}
        self._plans: dict[tuple, ClassPlan] = {}
        self._last_metrics = None  # MetricsBuf of the most recent run, if collected
        self._last_timeline = None  # per-case TimelineBuf of the most recent run

    @property
    def mesh_shape(self) -> tuple:
        """Device-mesh shape key: () single-device, (D,) for a grid mesh."""
        return () if self.mesh is None else tuple(self.mesh.devices.shape)

    @property
    def mesh_size(self) -> int:
        return 1 if self.mesh is None else int(self.mesh.devices.size)

    def _chunk_bucket(self, n_cases: int) -> int:
        """Effective per-launch chunk: pow2-bucketed grid size capped at
        ``chunk``, then rounded up to a mesh-size multiple so ``shard_map``
        can split the chunk axis evenly across devices."""
        c = min(pow2_bucket(n_cases), self.chunk)
        d = self.mesh_size
        return -(-c // d) * d

    def _vmapped(self, one, in_axes: tuple):
        """jit(vmap(one, in_axes)) with a trace-time counter feeding
        ``stats``. ``in_axes`` entries of ``None`` mark grid-shared broadcast
        arguments (e.g. the taskq engine's trace pools) that every grid row
        reads without a per-row copy; on a mesh they are the replicated
        operands while axis-0 entries shard along the grid axis.

        Per-chunk operands (the axis-0 args) are donated: each chunk uploads
        fresh config/stream buffers that nothing re-reads after the launch,
        so XLA may reuse their device memory for the outputs. Broadcast
        operands live across launches and are never donated.
        """
        import jax

        def fn(*args):
            self.stats.traces += 1  # runs at trace time only
            key = self.mesh_shape
            self.stats.by_mesh[key] = self.stats.by_mesh.get(key, 0) + 1
            with obs.span("sweep.trace", engine=type(self).__name__,
                          mesh=str(key)):
                return jax.vmap(one, in_axes=in_axes)(*args)

        donate = tuple(i for i, ax in enumerate(in_axes) if ax == 0)
        if self.mesh is not None:
            from repro.fleet.shard import shard_grid

            fn = shard_grid(fn, self.mesh, in_axes)
        return jax.jit(fn, donate_argnums=donate)

    def _build(self, key: tuple, collect: bool = False):
        raise NotImplementedError

    def _fn_for(self, key: tuple, collect: bool = False):
        """``collect`` (metrics on/off) is part of the cache key: a constant
        ``REPRO_OBS`` setting yields exactly the pinned compile counts, and
        flipping it mid-process recompiles instead of mis-tracing."""
        fn = self._fns.get((key, collect))
        if fn is None:
            fn = self._fns[(key, collect)] = self._build(key, collect)
        return fn

    def _plan_for(self, cls: RequestClass, L: int, eq7_factor: float) -> ClassPlan:
        key = (cls, L, eq7_factor)
        plan = self._plans.get(key)
        if plan is None:
            plan = self._plans[key] = build_class_plan(cls, L, eq7_factor=eq7_factor)
        return plan

    def _launch_chunks(self, fn, cfg, streams, G: int, chunk: int, count: int,
                       broadcast: tuple = (), fold=None):
        """ceil(G / chunk) launches over (cfg, *streams, *broadcast); returns
        the stacked (G, count) output dict. Tail-chunk rows are repetitions
        of row ``lo`` and sliced off before stacking, so padding never leaks.
        ``broadcast`` arguments are passed whole to every launch (no grid
        axis) — they must line up with ``None`` entries of the builder's
        ``in_axes``.

        ``streams`` is a callable ``(idx) -> tuple of (chunk, ...) blocks``
        generating one chunk's host-side streams on demand from the padded
        case-index array — host memory never holds more than one chunk of
        workload draws, which is what lets a 1e5-point grid run at all.
        (A tuple of full (G, ...) arrays is still accepted and gathered
        per chunk.)

        The chunk gather rides one preallocated index buffer (no per-chunk
        concatenate), and the per-chunk device uploads are donated to the
        launch (see :meth:`_vmapped`), so peak memory stays at one chunk's
        working set on both host and device.

        ``fold`` streams: called per launch as ``fold(out, cfg_np, streams_np)``
        with the chunk's outputs sliced to ``[:, :count]`` and the chunk's
        host-side config/stream rows, it returns fixed-size per-row
        statistics which are stacked *instead of* the raw (chunk, T) block —
        the block itself is dropped before the next launch, so a streamed
        sweep never materializes O(G × T).
        """
        import warnings

        import jax.numpy as jnp

        outs = []
        mbuf = None
        tlbuf = None
        engine = type(self).__name__
        mesh_tag = str(self.mesh_shape)
        bcast = tuple(jnp.asarray(b) for b in broadcast)
        idx = np.empty(chunk, np.intp)  # preallocated chunk-gather indices
        for lo in range(0, G, chunk):
            hi = min(lo + chunk, G)
            with obs.span("sweep.chunk", engine=engine, mesh=mesh_tag,
                          rows=hi - lo):
                idx[: hi - lo] = np.arange(lo, hi)
                idx[hi - lo:] = lo  # pad the tail chunk by repetition
                with obs.span("sweep.hostgen", engine=engine):
                    cfg_np = {name: v[idx] for name, v in cfg.items()}
                    streams_np = (
                        streams(idx) if callable(streams)
                        else tuple(s[idx] for s in streams)
                    )
                with warnings.catch_warnings():
                    # Donated operands with no same-sized output (e.g. the
                    # (chunk, T, n_max) Exp draws) cannot be aliased; XLA warns
                    # about that expected partial usability on every compile.
                    warnings.filterwarnings(
                        "ignore", message="Some donated buffers were not usable"
                    )
                    with obs.span("sweep.launch", engine=engine, mesh=mesh_tag):
                        out = fn(
                            {name: jnp.asarray(v) for name, v in cfg_np.items()},
                            *(jnp.asarray(s) for s in streams_np), *bcast)
                self.stats.launches += 1
                # The per-case metrics fold rides the same launch: slice off
                # the tail padding, row-reduce on device, merge across chunks
                # (mirrors the streamed frontier folds — no host syncs).
                out = dict(out)
                mb = out.pop("obs", None)
                if mb is not None:
                    mb = mb.reduce_rows(hi - lo)
                    mbuf = mb if mbuf is None else mbuf.merge(mb)
                # Timelines stay per case: cut the tail padding, then
                # concatenate chunks along the case axis (leading-batch
                # invariant, so streamed/sharded runs carry them bit-exactly).
                tl = out.pop("timeline", None)
                if tl is not None:
                    tl = tl.reduce_rows(hi - lo)
                    tlbuf = tl if tlbuf is None else tlbuf.concat(tl)
                if fold is None:
                    outs.append(
                        {name: v[: hi - lo, :count] for name, v in out.items()})
                else:
                    with obs.span("sweep.fold", engine=engine):
                        red = fold({name: v[:, :count] for name, v in out.items()},
                                   cfg_np, streams_np)
                    outs.append({name: v[: hi - lo] for name, v in red.items()})
        self.stats.cases += G
        self._last_metrics = mbuf
        self._last_timeline = tlbuf
        return {
            name: jnp.concatenate([o[name] for o in outs], axis=0)
            for name in outs[0]
        }


def frontier_fold(w: int, bins: int):
    """Per-chunk streaming fold for fleet-style (single-class) sweeps.

    Runs the SAME jitted reduction kernels the materialized frontier uses
    (:func:`repro.fleet.stats.frontier_block_reduce` for the delay/usage
    statistics, :func:`repro.fleet.stats.convergence_reduce` for the
    adaptation integers) on one (chunk, count) block at a time — per-row
    reductions are leading-batch invariant, so the streamed statistics are
    bit-exact equals of the materialized ones. ``w`` is the warmup cut,
    ``bins`` any bound exceeding every chosen k (table length works).
    """
    import jax.numpy as jnp

    from repro.fleet.stats import convergence_reduce, frontier_block_reduce

    def fold(out, cfg_np, streams_np):
        red = dict(frontier_block_reduce(
            out, jnp.asarray(cfg_np["delta_bar"]),
            jnp.asarray(cfg_np["delta_tilde"]), jnp.asarray(cfg_np["psi_bar"]),
            jnp.asarray(cfg_np["psi_tilde"]), jnp.asarray(cfg_np["J"]), w=w,
        ))
        red.update(convergence_reduce(out["k"], w=w, bins=bins))
        return red

    return fold


@dataclasses.dataclass
class SweepResult:
    """Stacked per-request outputs for every grid point.

    ``out`` holds device arrays of shape (G, count): ``total``/``queueing``/
    ``service`` delays (float32) and the chosen ``n``/``k`` (int32) — kept
    on device so :mod:`repro.fleet.frontier` reduces them without a host
    round-trip. ``cfg`` is the stacked per-case config (params + tables).

    A **streamed** run (``run(..., stream=...)``) never materializes the
    (G, count) block: ``out`` is empty and ``streamed`` carries the running
    frontier reduction (:class:`repro.fleet.shard.StreamedStats`) that the
    frontier consumers read instead.
    """

    cases: list[SweepCase]
    out: dict
    cfg: dict[str, np.ndarray]
    count: int
    compiles: int
    launches: int
    streamed: object = None  # StreamedStats for streamed runs
    metrics: object = None  # MetricsBuf folded across chunks (REPRO_OBS=1)
    timeline: object = None  # per-case TimelineBuf, (G, S) slots (REPRO_OBS=1)
    mesh_shape: tuple = ()  # device-mesh shape the run launched on

    def to_numpy(self) -> dict[str, np.ndarray]:
        return {k: np.asarray(v) for k, v in self.out.items()}


class FleetSweep(ChunkedVmapSweep):
    """Chunked, shape-bucketed vmapped sweep over :class:`SweepCase` grids."""

    # -- compilation cache --------------------------------------------------

    def bucket_key(self, n_cases: int, count: int, n_max: int, hk_len: int, hn_len: int):
        """The compilation-cache key a run with these shapes lands in.

        The trailing timeline window is derived from the pow2 time bucket
        (see :func:`repro.obs.timeline_window`), so listing it explicitly
        never splits a bucket — it documents the slotting each compilation
        traces with."""
        t_b = pow2_bucket(count, self.t_floor)
        return (
            self._chunk_bucket(n_cases),
            t_b,
            n_max,
            hk_len,
            hn_len,
            self.mesh_shape,
            obs.timeline_window(t_b),
        )

    def _build(self, key: tuple, collect: bool = False):
        n_max = key[2]
        window = key[-1]

        def one(cfg, inter, exps):
            from repro.core.jax_sim import backlog_proxy, tofec_scan_core

            p = types.SimpleNamespace(
                delta_bar=cfg["delta_bar"], delta_tilde=cfg["delta_tilde"],
                psi_bar=cfg["psi_bar"], psi_tilde=cfg["psi_tilde"],
                J=cfg["J"], L=cfg["L"], alpha=cfg["alpha"],
            )
            out = tofec_scan_core(
                p, cfg["h_k"], cfg["h_n"], cfg["r_max"], inter, exps, n_max=n_max
            )
            if collect:
                out = dict(out)
                valid = obs.valid_mask(cfg, inter.shape[-1])
                out["obs"] = obs.sweep_point_metrics(out, "fleet", valid=valid)
                out["timeline"] = obs.sweep_timeline(
                    out, inter, window=window, valid=valid,
                    backlog=backlog_proxy(p, out["queueing"]))
            return out

        return self._vmapped(one, in_axes=(0, 0, 0))

    # -- the sweep ----------------------------------------------------------

    def _stack_cfg(self, cases: list[SweepCase], hk_len: int, hn_len: int):
        G = len(cases)
        cfg = {
            name: np.empty(G, np.float32)
            for name in ("delta_bar", "delta_tilde", "psi_bar", "psi_tilde",
                         "J", "L", "alpha", "r_max")
        }
        cfg["h_k"] = np.zeros((G, hk_len), np.float32)
        cfg["h_n"] = np.zeros((G, hn_len), np.float32)
        for i, case in enumerate(cases):
            plan = (
                self._plan_for(case.cls, case.L, case.policy.eq7_factor)
                if case.policy.kind == "tofec" else None
            )
            h_k, h_n, r_max = policy_tables(case.policy, case.cls, case.L, plan)
            pr = case.cls.params
            cfg["delta_bar"][i] = pr.delta_bar
            cfg["delta_tilde"][i] = pr.delta_tilde
            cfg["psi_bar"][i] = pr.psi_bar
            cfg["psi_tilde"][i] = pr.psi_tilde
            cfg["J"][i] = case.cls.file_mb
            cfg["L"][i] = case.L
            cfg["alpha"][i] = case.policy.alpha
            cfg["r_max"][i] = r_max
            # Trailing zeros are inert thresholds (0 > q̄ never holds), so
            # shorter per-class tables pad into the shared bucket for free.
            cfg["h_k"][i, : len(h_k)] = h_k
            cfg["h_n"][i, : len(h_n)] = h_n
        return cfg

    def run(self, cases: list[SweepCase], count: int, *,
            stream=None) -> SweepResult:
        """Evaluate every grid point over ``count`` arrivals.

        Host side: per-case RNG streams generate the workload arrays.
        Device side: ceil(G / chunk) vmapped launches, each hitting the
        shape-bucket cache.

        ``stream`` (True or a :class:`repro.fleet.shard.StreamSpec`) folds
        each chunk into running frontier statistics instead of stacking the
        raw (G, count) block — see :mod:`repro.fleet.shard`.
        """
        if not cases:
            raise ValueError("empty case grid")
        from repro.fleet.shard import StreamedStats, resolve_stream

        spec = resolve_stream(stream)
        traces0, launches0 = self.stats.traces, self.stats.launches
        n_max = max(c.cls.n_max for c in cases)
        hk_len = max(c.cls.k_max for c in cases) + 1
        hn_len = n_max + 1
        key = self.bucket_key(len(cases), count, n_max, hk_len, hn_len)
        chunk, T_b = key[0], key[1]

        cfg = self._stack_cfg(cases, hk_len, hn_len)
        G = len(cases)
        collect = obs.enabled()
        if collect:
            # Runtime row, not a cache-key entry: runs sharing a pow2 time
            # bucket keep sharing one compilation.
            cfg["obs_count"] = np.full(G, count, np.int32)

        def chunk_streams(idx):
            inter = np.zeros((len(idx), T_b), np.float32)
            exps = np.zeros((len(idx), T_b, n_max), np.float32)
            for j, i in enumerate(idx):
                if j and i == idx[0]:  # tail pad: repeat the chunk's row 0
                    inter[j], exps[j] = inter[0], exps[0]
                    continue
                case = cases[i]
                rng = np.random.default_rng(case.seed)
                it, ex = case.resolved_workload().device_arrays(
                    rng, count, case.cls.n_max)
                inter[j, :count] = it
                # Classes with smaller n_max leave trailing Exp columns at
                # zero; the scan masks draws at j >= k, so padding never
                # enters.
                exps[j, :count, : case.cls.n_max] = ex
            return inter, exps

        fn = self._fn_for(key, collect)
        fold = (
            frontier_fold(int(count * spec.warmup_frac), hn_len)
            if spec else None
        )
        stacked = self._launch_chunks(fn, cfg, chunk_streams, G, chunk, count,
                                      fold=fold)
        return SweepResult(
            cases=list(cases),
            out={} if spec else stacked,
            cfg=cfg,
            count=count,
            compiles=self.stats.traces - traces0,
            launches=self.stats.launches - launches0,
            streamed=(
                StreamedStats(spec.warmup_frac, count, stacked) if spec else None
            ),
            metrics=self._last_metrics,
            timeline=self._last_timeline,
            mesh_shape=self.mesh_shape,
        )
