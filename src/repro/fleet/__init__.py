"""repro.fleet — on-device fleet simulator for TOFEC experiment grids.

The paper's evaluation story (Fig.1/7/8, and the wide λ-grids of the
journal version arXiv:1403.5007) is a sweep over (arrival rate × policy ×
seed). This package evaluates entire such grids in a handful of jitted
launches:

* :mod:`repro.fleet.workloads` — a workload-generator family (Poisson,
  MMPP bursty, diurnal, flash-crowd, piecewise trace replay, multi-tenant
  class mixes) producing device-ready arrival arrays AND host event-sim
  arrival times from the same spec.
* :mod:`repro.fleet.sweep` — ``vmap``ped :func:`repro.core.jax_sim.
  tofec_scan_core` across a stacked config axis with memory-bounded
  chunked batching and shape-bucketed jit caching.
* :mod:`repro.fleet.frontier` — on-device reductions to throughput-delay
  frontiers, delay percentiles, capacity estimates, adaptation-convergence
  stats, and the ``BENCH_fleet.json`` artifact writer.
* :mod:`repro.fleet.shard` — ``shard_map`` scale-out of the grid axis
  across a device mesh plus streaming per-chunk frontier reductions
  (``run(..., stream=...)``), shared with :mod:`repro.sched` and
  :mod:`repro.taskq` through the common chunked-sweep base.
"""

from repro.fleet.frontier import (
    FrontierPoint,
    capacity_estimates,
    convergence_stats,
    frontier,
    frontier_points,
    headline_ratios,
    write_fleet_artifact,
)
from repro.fleet.shard import (
    StreamedStats,
    StreamSpec,
    resolve_grid_mesh,
)
from repro.fleet.sweep import (
    FleetSweep,
    PolicySpec,
    SweepCase,
    SweepResult,
    fixedk_tables,
    grid_cases,
    policy_tables,
    static_tables,
    tenant_cases,
)
from repro.fleet.workloads import (
    DiurnalWorkload,
    FlashCrowdWorkload,
    MMPPWorkload,
    PiecewiseWorkload,
    PoissonWorkload,
    TenantMix,
    Workload,
)

__all__ = [
    "Workload",
    "PoissonWorkload",
    "MMPPWorkload",
    "DiurnalWorkload",
    "FlashCrowdWorkload",
    "PiecewiseWorkload",
    "TenantMix",
    "FleetSweep",
    "SweepCase",
    "SweepResult",
    "PolicySpec",
    "grid_cases",
    "tenant_cases",
    "policy_tables",
    "static_tables",
    "fixedk_tables",
    "FrontierPoint",
    "frontier",
    "frontier_points",
    "capacity_estimates",
    "convergence_stats",
    "headline_ratios",
    "write_fleet_artifact",
    "StreamSpec",
    "StreamedStats",
    "resolve_grid_mesh",
]
