"""Sharded, streaming scale-out for the chunked sweep engines.

Two orthogonal capabilities, shared by :class:`repro.fleet.FleetSweep`,
:class:`repro.sched.SchedSweep` and :class:`repro.taskq.TaskqSweep` through
their common :class:`repro.fleet.sweep.ChunkedVmapSweep` base:

**Grid sharding** (:func:`resolve_grid_mesh` + :func:`shard_grid`): the
stacked grid-case axis of each chunked launch is partitioned across a 1-D
device mesh with ``shard_map`` — per-case config arrays and RNG streams are
sharded on the grid axis, while grid-shared broadcast operands (the taskq
trace pools, threshold tables passed via ``in_axes=None``) are replicated
to every device. Each device runs the same vmapped scan over its slice of
the chunk, so a D-device mesh cuts per-launch wall clock ~D× without
changing a single drawn value: grid rows are independent, which makes the
sharded result bit-exact against the single-device path (asserted in
``tests/test_shard.py``). The compile cache stays pow2-bucketed and is
keyed additionally on the mesh shape.

**Streaming frontier reductions** (:class:`StreamSpec` + :class:`StreamedStats`):
instead of materializing the whole (G, T) per-request output block and
reducing it afterwards, a streamed run folds every chunk's scan outputs
into fixed-size per-row frontier statistics on device — the fused reduction
kernels in :mod:`repro.fleet.stats` — and drops the (chunk, T) block before
the next launch. Peak memory becomes O(chunk × T) per launch plus O(G) for
the carried statistics, instead of O(G × T) for the stacked result, which
is what lets ~1e5-point grids run at all. Because the streamed fold runs
the *same* jitted reduction the materialized frontier uses (and per-row
reductions are invariant to the leading batch size), the streamed
statistics are bit-exact equals of the materialized ones;
``frontier_points`` / ``convergence_stats`` / ``multiclass_points`` and the
artifact writers consume a streamed result through the same API.

The observability side-channels ride both capabilities unchanged: per-case
:class:`repro.obs.MetricsBuf` rows fold per chunk (cut → row-reduce →
merge) while per-case :class:`repro.obs.TimelineBuf` timelines keep their
case axis (cut → concat).  Both are per-slot/per-case reductions —
invariant to the leading batch size and to where the grid axis is split —
so streamed and mesh-sharded runs carry metrics AND timelines bit-exactly
equal to the materialized single-device path (asserted in
``tests/test_obs.py`` / ``tests/test_shard.py``).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro import obs


def resolve_grid_mesh(mesh):
    """Normalize a sweep's ``mesh`` argument to a 1-D jax Mesh (or None).

    Accepts ``None`` (single-device path, never touches jax device state),
    an int device count (first n devices via :func:`repro.launch.mesh.
    make_grid_mesh`), or an existing 1-D Mesh of any axis name.
    """
    if mesh is None:
        return None
    if isinstance(mesh, int):
        from repro.launch.mesh import make_grid_mesh

        return make_grid_mesh(mesh)
    if len(mesh.axis_names) != 1:
        raise ValueError(
            f"sweep meshes are 1-D (the grid axis); got axes {mesh.axis_names}"
        )
    return mesh


def shard_grid(fn, mesh, in_axes: tuple):
    """Wrap a whole-chunk vmapped launch body in ``shard_map`` over ``mesh``.

    ``in_axes`` is the launch's vmap spec: axis-0 entries (per-case config
    pytrees, RNG streams) shard along the mesh's grid axis; ``None`` entries
    (grid-shared broadcast operands, e.g. trace pools) replicate whole to
    every device — mirroring the taskq ``in_axes=None`` convention. Outputs
    come back sharded on the grid axis. The wrapped body must consume
    positional args matching ``in_axes`` one-for-one.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    axis = mesh.axis_names[0]
    in_specs = tuple(P(axis) if ax == 0 else P() for ax in in_axes)
    return shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=P(axis))


@dataclasses.dataclass(frozen=True)
class StreamSpec:
    """Ask a sweep run to stream: fold each chunk into frontier statistics.

    The warmup cut must be fixed before the first chunk is folded, so it is
    part of the run request rather than a reduction-time argument; the
    frontier consumers validate that their ``warmup_frac`` lands on the same
    cut (:meth:`StreamedStats.require`).
    """

    warmup_frac: float = 0.05


class StreamedStats:
    """Running frontier-reduction state carried by a streamed sweep result.

    Holds the per-row statistics (name → (G,) / (G, C) numpy arrays) that
    the per-chunk folds accumulated, plus the warmup cut they were folded
    at. ``repro.fleet.frontier`` / ``repro.sched.frontier`` consume this in
    place of the (G, T) output block — same API surface, no materialized
    grid.
    """

    def __init__(self, warmup_frac: float, count: int, red: dict):
        self.warmup_frac = float(warmup_frac)
        self.count = int(count)
        # The streamed path's one device→host download of the folded stats.
        with obs.span("sweep.stream_finalize", stats=len(red)):
            self.red = {name: np.asarray(v) for name, v in red.items()}

    @property
    def warmup(self) -> int:
        return int(self.count * self.warmup_frac)

    def require(self, warmup_frac: float) -> dict:
        """The streamed statistics, checked against a requested warmup cut.

        Streaming fixes the cut at launch time; asking the frontier for a
        different one afterwards cannot be served from the carry.
        """
        if int(self.count * warmup_frac) != self.warmup:
            raise ValueError(
                f"result was streamed at warmup_frac={self.warmup_frac} "
                f"(cut {self.warmup}); re-run the sweep with "
                f"StreamSpec(warmup_frac={warmup_frac}) to reduce at a "
                "different cut"
            )
        return self.red


def resolve_stream(stream) -> StreamSpec | None:
    """Normalize a run's ``stream`` argument: None/False | True | StreamSpec."""
    if not stream:
        return None
    return stream if isinstance(stream, StreamSpec) else StreamSpec()
