"""pixtral-12b [vlm]: pixtral-ViT frontend STUB + mistral-nemo backbone.

Backbone: 40L, d_model=5120, 32H (GQA kv=8), d_ff=14336, vocab=131072.
The ViT is a stub: ``input_specs`` provides precomputed patch embeddings
(1024 patches at d_model). [hf:mistralai/Pixtral-12B-2409]
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=131072,
    rope_theta=1_000_000.0,
    vision_patches=1024,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512, vision_patches=8,
    )
