"""Shared on-device reduction helpers for the sweep frontiers.

:func:`masked_percentiles` is the single implementation of the
sort-and-gather percentile reduction that used to live twice — inline in
``repro.fleet.frontier`` (unmasked ``jnp.percentile``) and in
``repro.sched.frontier`` (class-masked sort + gather). All three frontier
modules (fleet, sched, taskq) now route through this one:

* values outside ``mask`` are pushed to ``BIG`` before the sort, so they
  sort past every real sample and never enter a gather;
* the gather index is ``floor(q/100 · (count−1))`` — lower-interpolation
  percentiles, exact order statistics of the masked sample (no
  interpolation between neighbors, so the result is always a value that
  actually occurred);
* rows whose mask is empty report NaN (there is no sample to take an order
  statistic of); a single-survivor mask reports that survivor for every q.

:func:`frontier_block_reduce` and :func:`convergence_reduce` are the fused
per-block reduction kernels behind BOTH frontier paths: the materialized
reduction (``repro.fleet.frontier`` over a whole (G, T) result block) and
the streaming per-chunk fold (``repro.fleet.shard``, one (chunk, T) block
at a time). Because the two paths run the *same* jitted functions on the
same per-row data — and per-row reductions are invariant to the leading
batch size — streamed statistics are bit-exact equals of the materialized
ones (asserted in ``tests/test_shard.py``).
"""

from __future__ import annotations

import functools
import types

import jax
import jax.numpy as jnp
import numpy as np

#: Finite stand-in for +inf in float32 sorts (sorts past any real delay).
BIG = float(np.finfo(np.float32).max)


def masked_percentiles(x, qs, mask=None):
    """(G, T) values → (G, len(qs)) lower-interpolation percentiles.

    ``mask`` (G, T) bool restricts each row to a subsample (e.g. one class
    of a multi-class stream); ``None`` reduces over whole rows. Rows with an
    empty mask report NaN. Traceable — safe inside jitted reductions.
    """
    qs = jnp.asarray(qs, jnp.float32)
    T = x.shape[1]
    if mask is None:
        cnt = jnp.full((x.shape[0],), T, jnp.int32)
        srt = jnp.sort(x, axis=1)
    else:
        cnt = jnp.sum(mask, axis=1).astype(jnp.int32)
        srt = jnp.sort(jnp.where(mask, x, BIG), axis=1)
    idx = jnp.clip(
        (qs[:, None] / 100.0 * (cnt[None, :] - 1)).astype(jnp.int32), 0, T - 1
    )  # (len(qs), G)
    # An empty subsample has no order statistics: its gather would land on
    # the BIG sentinel (via the idx clamp) — report NaN instead, and let the
    # frontiers propagate it. A single survivor (cnt == 1) needs no special
    # case: every q indexes floor(q/100 · 0) = 0, the survivor itself.
    return jnp.where(
        cnt[:, None] > 0, jnp.take_along_axis(srt, idx.T, axis=1), jnp.nan
    )  # (G, len(qs))


@functools.partial(jax.jit, static_argnames=("w",))
def frontier_block_reduce(out, delta_bar, delta_tilde, psi_bar, psi_tilde,
                          J, *, w: int):
    """One jitted per-row frontier reduction over a (rows, T) result block.

    The single implementation behind the fleet/taskq frontier statistics:
    the materialized path calls it once on the whole (G, T) block, the
    streaming path once per (chunk, T) launch block. Module-level (with the
    warmup cut static) so repeated reductions of same-shaped blocks hit the
    compile cache.
    """
    from repro.core import queueing

    tot = out["total"][:, w:]
    nf = out["n"][:, w:].astype(jnp.float32)
    kf = out["k"][:, w:].astype(jnp.float32)
    r = nf / kf
    params = types.SimpleNamespace(
        delta_bar=delta_bar[:, None], delta_tilde=delta_tilde[:, None],
        psi_bar=psi_bar[:, None], psi_tilde=psi_tilde[:, None],
    )
    usage = queueing.usage(params, J[:, None], kf, r)  # Eq.3, broadcast
    pct = masked_percentiles(tot, [50.0, 90.0, 95.0, 99.0])
    return {
        "mean": jnp.mean(tot, axis=1),
        "std": jnp.std(tot, axis=1),
        "p50": pct[:, 0], "p90": pct[:, 1], "p95": pct[:, 2], "p99": pct[:, 3],
        "mean_queueing": jnp.mean(out["queueing"][:, w:], axis=1),
        "mean_k": jnp.mean(kf, axis=1),
        "mean_n": jnp.mean(nf, axis=1),
        "mean_usage": jnp.mean(usage, axis=1),
    }


@functools.partial(jax.jit, static_argnames=("w", "bins"))
def convergence_reduce(k, *, w: int, bins: int):
    """Per-row adaptation-convergence integers for a (rows, T) k block.

    The device mirror of the host loop in :func:`repro.fleet.frontier.
    convergence_stats`, returning exact integers so the streamed path can
    finish the fractions on host in float64, bit-for-bit equal to the
    numpy originals:

    * ``modal_k`` — first-argmax of the k histogram (``np.bincount(...).
      argmax()`` tie-breaking);
    * ``modal_count`` — occurrences of the modal k;
    * ``settle_idx`` — 1 + the last position where k leaves ±1 of the modal
      value (0 if it never does).

    ``bins`` must exceed every k the block can contain (any table length
    bound works — extra bins hold zero counts and never win the argmax).
    """
    ks = k[:, w:].astype(jnp.int32)
    counts = jnp.sum(ks[:, :, None] == jnp.arange(bins)[None, None, :], axis=1)
    modal = jnp.argmax(counts, axis=1).astype(jnp.int32)  # first max, as bincount
    off = jnp.abs(ks - modal[:, None]) > 1
    pos = jnp.arange(1, ks.shape[1] + 1, dtype=jnp.int32)
    return {
        "modal_k": modal,
        "modal_count": jnp.take_along_axis(counts, modal[:, None], axis=1)[:, 0],
        "settle_idx": jnp.max(jnp.where(off, pos[None, :], 0), axis=1),
    }
