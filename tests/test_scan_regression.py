"""Regression pin: the jitted TOFEC scan cannot silently diverge from the
reference adaptation dynamics (ISSUE 2).

Two anchors on fixed-seed traces:

* step-for-step against :func:`simulate_tofec_reference`, the host numpy
  mirror of the scan (same Lindley recursion + threshold controller, float32
  arithmetic) — catches semantic drift in the fused/jitted step;
* statistically against the discrete-event oracle
  :mod:`repro.core.simulator` — catches divergence of the *adaptation*
  behavior (which codes the controller actually picks under load).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    PAPER_READ_3MB,
    RequestClass,
    TofecTables,
    TOFECPolicy,
    build_class_plan,
)
from repro.core.jax_sim import (
    JaxSimParams,
    simulate_tofec_reference,
    simulate_tofec_scan,
)
from repro.core.simulator import poisson_arrivals, simulate
from repro.core.traces import TraceSampler

CLS = RequestClass("read3mb", 3.0, PAPER_READ_3MB, k_max=6, r_max=2.0, n_max=12)
L = 16
PLAN = build_class_plan(CLS, L)
TABLES = TofecTables.from_plan(PLAN)
P = JaxSimParams.from_class(CLS, L)


def _fixed_trace(lam: float, count: int, seed: int = 42):
    rng = np.random.default_rng(seed)
    inter = rng.exponential(1.0 / lam, size=count).astype(np.float32)
    exps = rng.exponential(1.0, size=(count, CLS.n_max)).astype(np.float32)
    return inter, exps


@pytest.mark.parametrize("lam", [5.0, 40.0])
def test_scan_matches_host_reference_step_for_step(lam):
    inter, exps = _fixed_trace(lam, count=2000)
    out = simulate_tofec_scan(P, TABLES, jnp.asarray(inter), jnp.asarray(exps))
    out = {k: np.asarray(v) for k, v in out.items()}
    ref = simulate_tofec_reference(P, TABLES, inter, exps)
    # Code choices are integer decisions: tolerate at most a stray flip from
    # device FMA contraction at a threshold boundary, nothing systematic.
    assert (out["n"] == ref["n"]).mean() >= 0.999
    assert (out["k"] == ref["k"]).mean() >= 0.999
    for field in ("total", "queueing", "service"):
        np.testing.assert_allclose(out[field], ref[field], rtol=1e-4, atol=1e-6)


def test_scan_pinned_golden_head():
    """Fixed-seed golden pin: the first decisions of the light-load trace.

    These values changing means the controller-in-the-scan changed behavior
    (not just noise) — update them only with a deliberate semantic change.
    """
    inter, exps = _fixed_trace(5.0, count=64)
    out = simulate_tofec_scan(P, TABLES, jnp.asarray(inter), jnp.asarray(exps))
    np.testing.assert_array_equal(np.asarray(out["k"])[:8], [6, 6, 6, 6, 2, 6, 6, 6])
    np.testing.assert_array_equal(np.asarray(out["n"])[:8], [12, 12, 12, 12, 3, 12, 12, 12])


@pytest.mark.parametrize(
    "lam,k_lo,k_hi",
    [(2.0, 4.0, 6.0), (50.0, 1.0, 2.8)],
)
def test_scan_adaptation_tracks_event_sim(lam, k_lo, k_hi):
    """The scan and the event oracle agree on WHICH codes load selects."""
    inter, exps = _fixed_trace(lam, count=4000)
    out = simulate_tofec_scan(P, TABLES, jnp.asarray(inter), jnp.asarray(exps))
    scan_k = float(np.asarray(out["k"]).mean())
    rng = np.random.default_rng(7)
    arr = poisson_arrivals(rng, lam, 4000)
    event = simulate(
        TOFECPolicy([PLAN]), arr, TraceSampler(PAPER_READ_3MB, CLS.file_mb), L=L, seed=8
    )
    event_k = float(event.ks().mean())
    assert k_lo <= scan_k <= k_hi, (scan_k, event_k)
    assert k_lo <= event_k <= k_hi, (scan_k, event_k)
    assert abs(scan_k - event_k) < 1.2


def test_ewma_warmup_seeds_from_first_observation():
    """Cold-start pin (EWMA bias bugfix): the first admission round's backlog
    observation SEEDS q̄ — it is not averaged against a bogus 0 — identically
    on the host policy and the device step (-1.0 carry sentinel).

    The q̄ trajectory below starts at exactly 30.0 (the first observation);
    the pre-fix behavior started at alpha*30 = 15.0 and biased every early
    (n, k) pick low. Update these pins only with a deliberate semantic
    change to the controller.
    """
    from repro.core import tofec_step_jax

    qs = [30, 30, 5, 0, 0, 0]
    pol = TOFECPolicy([PLAN], alpha=0.5)
    host_codes, host_qbar = [], []
    for q in qs:
        host_codes.append(pol.select(q=q, idle=0))
        host_qbar.append(float(pol.q_ewma))
    q_ewma = jnp.float32(-1.0)  # device cold-start sentinel
    dev_codes, dev_qbar = [], []
    for q in qs:
        q_ewma, n, k = tofec_step_jax(q_ewma, jnp.float32(q), TABLES, 0.5)
        dev_codes.append((int(n), int(k)))
        dev_qbar.append(float(q_ewma))
    assert host_codes == dev_codes == [(1, 1), (1, 1), (1, 1), (1, 1), (2, 1), (3, 2)]
    np.testing.assert_allclose(
        host_qbar, [30.0, 30.0, 17.5, 8.75, 4.375, 2.1875], rtol=1e-6)
    np.testing.assert_allclose(dev_qbar, host_qbar, rtol=1e-6)
