"""Vmapped shared-pool sweep: (mix × discipline × seed) grids, jointly.

Mirrors :class:`repro.fleet.sweep.FleetSweep` — pow2-bucketed jit caching,
chunked memory-bounded launches, policies as threshold tables — but each
grid point is a whole multi-class system: one merged arrival stream, one
L-thread pool, per-class TOFEC state, and a per-point admission discipline
(:mod:`repro.sched.scan`). Disciplines travel as runtime data (id + rank +
weight arrays), so a grid mixing FIFO, strict priority and weighted-fair
points compiles ONCE per shape bucket — asserted in ``tests/test_sched.py``.

Shared-bucket rule: within one :meth:`SchedSweep.run`, every case is padded
to the run's widest class count C (dummy classes get zero tables, zero
weight and the lowest priority; their ids never occur in ``cls_ids``, so
they are semantically inert), and the compilation key is (chunk, pow2(T),
C, n_max, table lengths) — the fleet's ``Codec.pad_to_bucket`` convention
with a class axis.
"""

from __future__ import annotations

import dataclasses
import types

import numpy as np

from repro import obs
from repro.coding.codec import pow2_bucket
from repro.fleet.sweep import ChunkedVmapSweep, PolicySpec, policy_tables
from repro.fleet.workloads import TenantMix
from repro.sched.scan import DISC_FIFO, DISC_PRIORITY, DISC_WFQ


@dataclasses.dataclass(frozen=True)
class DisciplineSpec:
    """Declarative admission discipline for one grid point.

    ``prio`` (priority only): per-class ranks, a permutation of range(C),
    lower = served first. ``weights`` (wfq only): positive per-class shares.
    """

    kind: str
    prio: tuple = ()
    weights: tuple = ()

    @classmethod
    def fifo(cls) -> "DisciplineSpec":
        return cls("fifo")

    @classmethod
    def priority(cls, *prio: int) -> "DisciplineSpec":
        return cls("priority", prio=tuple(int(r) for r in prio))

    @classmethod
    def wfq(cls, *weights: float) -> "DisciplineSpec":
        return cls("wfq", weights=tuple(float(w) for w in weights))

    @property
    def name(self) -> str:
        if self.kind == "priority":
            return f"priority({','.join(map(str, self.prio))})"
        if self.kind == "wfq":
            return f"wfq({':'.join(f'{w:g}' for w in self.weights)})"
        return "fifo"

    def validate(self, C: int) -> None:
        if self.kind == "priority":
            if sorted(self.prio) != list(range(C)):
                raise ValueError(f"priority ranks {self.prio} must permute range({C})")
        elif self.kind == "wfq":
            if len(self.weights) != C or any(w <= 0 for w in self.weights):
                raise ValueError(f"wfq weights {self.weights} must be {C} positives")
        elif self.kind != "fifo":
            raise ValueError(f"unknown discipline kind {self.kind!r}")

    def encode(self, C: int, C_pad: int):
        """(disc_id, prio (C_pad,), weights (C_pad,)) runtime arrays.

        Padded classes rank below every real one and carry zero weight —
        they never arrive, never backlog, never receive pool share.
        """
        self.validate(C)
        disc = {"fifo": DISC_FIFO, "priority": DISC_PRIORITY, "wfq": DISC_WFQ}[self.kind]
        prio = np.arange(C_pad, dtype=np.float32)
        if self.kind == "priority":
            prio[:C] = np.asarray(self.prio, np.float32)
            prio[C:] = C + np.arange(C_pad - C)
        weights = np.zeros(C_pad, np.float32)
        weights[:C] = np.asarray(self.weights, np.float32) if self.kind == "wfq" else 1.0
        return disc, prio, weights


@dataclasses.dataclass(frozen=True)
class SchedCase:
    """One grid point: a tenant mix × discipline × per-class policies × seed."""

    mix: TenantMix
    discipline: DisciplineSpec
    policy: object = None  # PolicySpec (shared) | tuple[PolicySpec, ...] | None→tofec
    seed: int = 0
    L: int = 16

    @property
    def lam(self) -> float:
        return self.mix.lam

    def policies(self) -> tuple[PolicySpec, ...]:
        C = len(self.mix.classes)
        pol = self.policy if self.policy is not None else PolicySpec.tofec()
        if isinstance(pol, PolicySpec):
            return (pol,) * C
        pol = tuple(pol)
        if len(pol) != C:
            raise ValueError(f"need {C} per-class policies, got {len(pol)}")
        return pol


def sched_cases(mixes, disciplines, seeds, *, policy=None, L: int = 16) -> list[SchedCase]:
    """Cartesian mix × discipline × seed grid of :class:`SchedCase`."""
    return [
        SchedCase(mix=mix, discipline=disc, policy=policy, seed=int(seed), L=L)
        for mix in mixes
        for disc in disciplines
        for seed in seeds
    ]


def multiclass_fold(w: int, C: int, count: int):
    """Per-chunk streaming fold for joint multi-class sweeps.

    Runs the SAME jitted per-class reduction the materialized path uses
    (:func:`repro.sched.frontier._reduce_multiclass`) on one (chunk, count)
    block at a time, rebuilding the chunk's ``cls_ids`` from the host-side
    class-id stream (the second stream operand). Per-row reductions are
    leading-batch invariant, so the streamed per-class statistics are
    bit-exact equals of the materialized ones.
    """
    import jax.numpy as jnp

    from repro.sched.frontier import _reduce_multiclass

    def fold(out, cfg_np, streams_np):
        ids_c = streams_np[1][:, :count]  # (chunk, count) class-id rows
        return dict(_reduce_multiclass(
            {**out, "cls_ids": jnp.asarray(ids_c)}, C=C, w=w,
        ))

    return fold


@dataclasses.dataclass
class SchedResult:
    """Stacked per-request outputs for every joint grid point.

    ``out`` holds (G, count) device arrays (``total``/``queueing``/
    ``service`` float32, ``n``/``k`` int32) plus ``cls_ids`` (G, count)
    int32 — kept on device so :mod:`repro.sched.frontier` masks per-class
    reductions without a host round-trip. A **streamed** run leaves ``out``
    empty and carries the running per-class reduction in ``streamed``
    (:class:`repro.fleet.shard.StreamedStats`) instead.
    """

    cases: list[SchedCase]
    out: dict
    cfg: dict[str, np.ndarray]
    count: int
    compiles: int
    launches: int
    streamed: object = None  # StreamedStats for streamed runs
    metrics: object = None  # MetricsBuf folded across chunks (REPRO_OBS=1)
    timeline: object = None  # per-case TimelineBuf, (G, S) slots (REPRO_OBS=1)
    mesh_shape: tuple = ()  # device-mesh shape the run launched on

    def to_numpy(self) -> dict[str, np.ndarray]:
        return {k: np.asarray(v) for k, v in self.out.items()}


class SchedSweep(ChunkedVmapSweep):
    """Chunked, shape-bucketed vmapped sweep over :class:`SchedCase` grids.

    Shares the compile cache, trace counting and chunked launch loop with
    :class:`repro.fleet.sweep.FleetSweep` via :class:`repro.fleet.sweep.
    ChunkedVmapSweep`; differs in the bucket key (a class axis C), the
    per-case config (per-class vectors + discipline encoding) and the scan
    body (the joint multi-class core).
    """

    # -- compilation cache --------------------------------------------------

    def bucket_key(self, n_cases: int, count: int, C: int, n_max: int,
                   hk_len: int, hn_len: int):
        """The compilation-cache key a run with these shapes lands in.

        The trailing timeline window derives from the pow2 time bucket
        (:func:`repro.obs.timeline_window`), so listing it never splits a
        bucket."""
        t_b = pow2_bucket(count, self.t_floor)
        return (
            self._chunk_bucket(n_cases),
            t_b,
            C,
            n_max,
            hk_len,
            hn_len,
            self.mesh_shape,
            obs.timeline_window(t_b),
        )

    def _build(self, key: tuple, collect: bool = False):
        n_max = key[3]
        window = key[-1]

        def one(cfg, inter, cls_ids, exps):
            from repro import obs
            from repro.sched.scan import multiclass_scan_core

            p = types.SimpleNamespace(
                delta_bar=cfg["delta_bar"], delta_tilde=cfg["delta_tilde"],
                psi_bar=cfg["psi_bar"], psi_tilde=cfg["psi_tilde"],
                J=cfg["J"], L=cfg["L"], alpha=cfg["alpha"], r_max=cfg["r_max"],
            )
            out = multiclass_scan_core(
                p, cfg["h_k"], cfg["h_n"], cfg["disc"], cfg["prio"], cfg["wfq_w"],
                inter, cls_ids, exps, n_max=n_max,
            )
            if collect:
                out = dict(out)
                valid = obs.valid_mask(cfg, inter.shape[-1])
                out["obs"] = obs.sweep_point_metrics(out, "sched", valid=valid)
                # The joint scan does not expose a single-queue backlog (the
                # pool is shared across classes), so the sched timeline
                # carries rate/pick/delay series only.
                out["timeline"] = obs.sweep_timeline(
                    out, inter, window=window, valid=valid)
            return out

        return self._vmapped(one, in_axes=(0, 0, 0, 0))

    # -- the sweep ----------------------------------------------------------

    def _stack_cfg(self, cases: list[SchedCase], C: int, hk_len: int, hn_len: int):
        G = len(cases)
        cfg = {
            name: np.zeros((G, C), np.float32)
            for name in ("delta_bar", "delta_tilde", "psi_bar", "psi_tilde",
                         "J", "alpha", "r_max")
        }
        cfg["L"] = np.empty(G, np.float32)
        cfg["disc"] = np.empty(G, np.int32)
        cfg["prio"] = np.zeros((G, C), np.float32)
        cfg["wfq_w"] = np.zeros((G, C), np.float32)
        cfg["h_k"] = np.zeros((G, C, hk_len), np.float32)
        cfg["h_n"] = np.zeros((G, C, hn_len), np.float32)
        for i, case in enumerate(cases):
            disc, prio, wfq_w = case.discipline.encode(len(case.mix.classes), C)
            cfg["L"][i] = case.L
            cfg["disc"][i] = disc
            cfg["prio"][i] = prio
            cfg["wfq_w"][i] = wfq_w
            for c, (cls, spec) in enumerate(zip(case.mix.classes, case.policies())):
                plan = (
                    self._plan_for(cls, case.L, spec.eq7_factor)
                    if spec.kind == "tofec" else None
                )
                h_k, h_n, r_max = policy_tables(spec, cls, case.L, plan)
                pr = cls.params
                cfg["delta_bar"][i, c] = pr.delta_bar
                cfg["delta_tilde"][i, c] = pr.delta_tilde
                cfg["psi_bar"][i, c] = pr.psi_bar
                cfg["psi_tilde"][i, c] = pr.psi_tilde
                cfg["J"][i, c] = cls.file_mb
                cfg["alpha"][i, c] = spec.alpha
                cfg["r_max"][i, c] = r_max
                cfg["h_k"][i, c, : len(h_k)] = h_k
                cfg["h_n"][i, c, : len(h_n)] = h_n
        return cfg

    def run(self, cases: list[SchedCase], count: int, *,
            stream=None) -> SchedResult:
        """Evaluate every joint grid point over ``count`` merged arrivals.

        Host side: per-case RNG streams generate merged interarrivals,
        exponential draws and class-id streams (same plumbing as the fleet:
        one ``default_rng(seed)`` per case). Device side: ceil(G / chunk)
        vmapped launches hitting the shape-bucket cache.

        ``stream`` (True or a :class:`repro.fleet.shard.StreamSpec`) folds
        each chunk into the per-class frontier statistics on device instead
        of stacking the (G, count) block — see :mod:`repro.fleet.shard`.
        """
        if not cases:
            raise ValueError("empty case grid")
        import jax.numpy as jnp

        from repro.fleet.shard import StreamedStats, resolve_stream

        spec = resolve_stream(stream)
        traces0, launches0 = self.stats.traces, self.stats.launches
        C = max(len(case.mix.classes) for case in cases)
        n_max = max(c.n_max for case in cases for c in case.mix.classes)
        hk_len = max(c.k_max for case in cases for c in case.mix.classes) + 1
        hn_len = n_max + 1
        key = self.bucket_key(len(cases), count, C, n_max, hk_len, hn_len)
        chunk, T_b = key[0], key[1]

        cfg = self._stack_cfg(cases, C, hk_len, hn_len)
        G = len(cases)
        collect = obs.enabled()
        if collect:
            cfg["obs_count"] = np.full(G, count, np.int32)
        # Materialized runs keep the class-id streams for the per-class
        # reductions; streamed runs fold them per chunk and never stack them.
        ids_full = None if spec else np.zeros((G, count), np.int32)

        def chunk_streams(idx):
            inter = np.zeros((len(idx), T_b), np.float32)
            ids = np.zeros((len(idx), T_b), np.int32)
            exps = np.zeros((len(idx), T_b, n_max), np.float32)
            for j, i in enumerate(idx):
                if j and i == idx[0]:  # tail pad: repeat the chunk's row 0
                    inter[j], ids[j], exps[j] = inter[0], ids[0], exps[0]
                    continue
                case = cases[i]
                rng = np.random.default_rng(case.seed)
                case_n_max = max(c.n_max for c in case.mix.classes)
                it, ex, ci = case.mix.multiclass_device_arrays(
                    rng, count, case_n_max)
                inter[j, :count] = it
                ids[j, :count] = ci
                # Narrower classes leave trailing Exp columns at zero; the
                # scan masks draws at j >= k, so the padding never enters.
                exps[j, :count, :case_n_max] = ex
                if ids_full is not None:
                    ids_full[i] = ci
            return inter, ids, exps

        fn = self._fn_for(key, collect)
        fold = (
            multiclass_fold(int(count * spec.warmup_frac), C, count)
            if spec else None
        )
        stacked = self._launch_chunks(fn, cfg, chunk_streams, G, chunk, count,
                                      fold=fold)
        if not spec:
            stacked["cls_ids"] = jnp.asarray(ids_full)
        return SchedResult(
            cases=list(cases),
            out={} if spec else stacked,
            cfg=cfg,
            count=count,
            compiles=self.stats.traces - traces0,
            launches=self.stats.launches - launches0,
            streamed=(
                StreamedStats(spec.warmup_frac, count, stacked) if spec else None
            ),
            metrics=self._last_metrics,
            timeline=self._last_timeline,
            mesh_shape=self.mesh_shape,
        )
