"""The paper's throughput-delay trade-off on the checkpoint path.

Writes the same pytree under (a) an idle writer and (b) a backlogged writer
with a TOFEC policy choosing the chunking level k per leaf, then restores
after losing strips. Shows k adapting (high k when idle → low write latency;
k→1 under backlog → max throughput), i.e. Corollary 1 on checkpoints.

Run:  PYTHONPATH=src python examples/adaptive_checkpointing.py
"""

import collections

import numpy as np

from repro.ckpt import restore_checkpoint, save_checkpoint
from repro.core import PAPER_READ_3MB, RequestClass, TOFECPolicy
from repro.storage import FaultyStore, MemoryStore


def main():
    rng = np.random.default_rng(0)
    tree = {f"layer{i:02d}/w": rng.normal(size=(256, 256)).astype(np.float32)
            for i in range(12)}

    cls = RequestClass("ckpt", 3.0, PAPER_READ_3MB, k_max=4, r_max=2.0, n_max=8)
    store = MemoryStore()

    for label, pending in [("idle writer", 0), ("backlogged writer", 400)]:
        policy = TOFECPolicy.for_classes([cls], L=16)
        manifest = save_checkpoint(
            store, f"ck_{pending}", 1, tree, policy=policy,
            n_max=8, k_max=4, pending_hint=pending,
        )
        ks = collections.Counter(v["k"] for v in manifest["leaves"].values())
        ns = collections.Counter(v["n"] for v in manifest["leaves"].values())
        print(f"{label:>18}: k histogram {dict(ks)}  n histogram {dict(ns)}")

    # Failure drill: lose 2 strips of every leaf written with n-k >= 2.
    faulty = FaultyStore(store)
    lost = 0
    for key in store.keys():
        if key.startswith("ck_0/") and (key.endswith("strip0") or key.endswith("strip1")):
            faulty.lose_object(key)
            lost += 1
    got = restore_checkpoint(faulty, "ck_0", 1, tree)
    ok = all(np.array_equal(got[k], tree[k]) for k in tree)
    print(f"\nlost {lost} strip objects; restore bit-exact: {ok}")


if __name__ == "__main__":
    main()
