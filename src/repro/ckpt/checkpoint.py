"""Erasure-coded distributed checkpointing (TOFEC-integrated).

Every checkpoint leaf (one array of the params/opt-state pytree) is:
  1. serialized (raw bytes + dtype/shape manifest entry, crc32 checksum),
  2. RS-encoded into n strips of size ⌈bytes/k⌉ through the unified batched
     codec engine (:mod:`repro.coding.codec` — numpy / jnp / Pallas backend
     per ``REPRO_CODEC_BACKEND``); leaves sharing an (n, k) plan are encoded
     in ONE batched kernel call,
  3. written as n independent objects ``{prefix}/step{s}/{leaf}/strip{i}``.

Restore fetches any k surviving strips per leaf and batch-decodes all leaves
that share (n, k, strip size) in one codec call — the engine accepts a
per-item ``present`` matrix, so heterogeneous erasure patterns across
leaves still form a single batch. Node/object loss up to n−k per leaf is
invisible. The chunking level k is chosen per-write by the TOFEC controller
from the writer backlog: an idle writer uses high k (many small parallel
strips → low write latency), a backlogged writer drops to k=1 (one big
strip + parity → max throughput), which is exactly the paper's
throughput-delay trade-off transplanted to checkpoints.

``AsyncCheckpointer`` overlaps encode+write with training steps.
"""

from __future__ import annotations

import dataclasses
import json
import queue as _queue
import threading
import zlib

import jax
import numpy as np

from repro.coding import codec as codec_mod
from repro.core.controller import Policy, StaticPolicy
from repro.storage.backend import ObjectStore, StorageError


@dataclasses.dataclass
class CodingPlan:
    n: int
    k: int


def _leaf_paths(tree) -> list[tuple[str, np.ndarray]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(str(p.key) if hasattr(p, "key") else str(p.idx) for p in path)
        out.append((name, np.asarray(leaf)))
    return out


def save_checkpoint(
    store: ObjectStore,
    prefix: str,
    step: int,
    tree,
    *,
    policy: Policy | None = None,
    n_max: int = 8,
    k_max: int = 4,
    pending_hint: int = 0,
    codec: codec_mod.Codec | None = None,
) -> dict:
    """Write one erasure-coded checkpoint; returns the manifest."""
    policy = policy or StaticPolicy(n_max, k_max)
    codec = codec or codec_mod.get_codec()
    leaves = _leaf_paths(tree)
    manifest = {"step": step, "leaves": {}, "format": 1}

    # Pick a plan per leaf, then group by (n, k) so each group shards
    # through ONE batched encode call.
    plans: list[tuple[str, np.ndarray, int, int]] = []
    for name, arr in leaves:
        # Backlog signal = externally pending checkpoint snapshots (the
        # async writer's queue depth) — the TOFEC queue-length analogue.
        # An idle writer chunks finely (low latency); a backlogged one
        # degrades toward k=1 (max throughput), Corollary 1 verbatim.
        q = pending_hint
        n, k = policy.select(q=q, idle=max(0, n_max - 1), cls_id=0)
        n = min(n, n_max)
        k = min(k, k_max, max(1, n))
        plans.append((name, arr, n, k))

    # Group by (n, k, pow2-bucketed strip width): batching pads members to
    # the group max, so bucketing bounds zero-padding waste at 2× per leaf
    # (a lone giant embedding never drags 100 small leaves up to its width)
    # and matches the codec's own internal shape buckets.
    groups: dict[tuple[int, int, int], list[tuple[str, np.ndarray]]] = {}
    for name, arr, n, k in plans:
        strip = codec_mod.Codec.strip_bytes(arr.nbytes, k)
        groups.setdefault((n, k, codec_mod.pow2_bucket(strip, 128)), []).append((name, arr))

    for (n, k, _bucket), members in groups.items():
        payloads = [arr.tobytes() for _, arr in members]
        all_strips = codec.encode_blobs(
            [np.frombuffer(p, np.uint8) for p in payloads], n=n, k=k
        )
        for (name, arr), payload, strips in zip(members, payloads, all_strips):
            strip = strips.shape[1]  # this leaf's own ⌈bytes/k⌉ width
            for si in range(n):
                store.put(f"{prefix}/step{step}/{name}/strip{si}", strips[si].tobytes())
            manifest["leaves"][name] = {
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "n": int(n),
                "k": int(k),
                "bytes": len(payload),
                "strip_bytes": int(strip),
                "crc": zlib.crc32(payload) & 0xFFFFFFFF,
            }
    store.put(f"{prefix}/step{step}/MANIFEST", json.dumps(manifest).encode())
    store.put(f"{prefix}/LATEST", str(step).encode())
    return manifest


def latest_step(store: ObjectStore, prefix: str) -> int | None:
    try:
        return int(store.get(f"{prefix}/LATEST").decode())
    except StorageError:
        return None


def restore_checkpoint(
    store: ObjectStore,
    prefix: str,
    step: int,
    tree_like,
    *,
    codec: codec_mod.Codec | None = None,
) -> object:
    """Rebuild a pytree matching ``tree_like`` from any-k-of-n strips."""
    codec = codec or codec_mod.get_codec()
    manifest = json.loads(store.get(f"{prefix}/step{step}/MANIFEST").decode())
    leaves = _leaf_paths(tree_like)

    # Fetch any k surviving strips per leaf, then batch-decode all leaves
    # sharing (n, k, strip_bytes) in one codec call (per-item present).
    fetched: dict[str, tuple[np.ndarray, tuple[int, ...]]] = {}
    groups: dict[tuple[int, int, int], list[str]] = {}
    for name, _ in leaves:
        meta = manifest["leaves"][name]
        n, k = meta["n"], meta["k"]
        got: dict[int, bytes] = {}
        for si in range(n):
            if len(got) >= k:
                break
            try:
                got[si] = store.get(f"{prefix}/step{step}/{name}/strip{si}")
            except StorageError:
                continue
        if len(got) < k:
            raise StorageError(
                f"{name}: only {len(got)}/{k} strips survive — unrecoverable"
            )
        present = tuple(sorted(got))[:k]
        strips = np.stack([np.frombuffer(got[si], np.uint8) for si in present])
        fetched[name] = (strips, present)
        groups.setdefault((n, k, meta["strip_bytes"]), []).append(name)

    payloads: dict[str, np.ndarray] = {}
    for (n, k, _strip), names in groups.items():
        rows = np.stack([fetched[nm][0] for nm in names])
        present = np.stack([fetched[nm][1] for nm in names])
        decoded = np.asarray(codec.decode(rows, present, n, k))
        for i, nm in enumerate(names):
            nbytes = manifest["leaves"][nm]["bytes"]
            payloads[nm] = decoded[i].reshape(-1)[:nbytes]

    out_leaves = []
    for name, like in leaves:
        meta = manifest["leaves"][name]
        payload = payloads[name]
        if (zlib.crc32(payload.tobytes()) & 0xFFFFFFFF) != meta["crc"]:
            raise StorageError(f"{name}: checksum mismatch after decode")
        arr = np.frombuffer(payload.tobytes(), dtype=meta["dtype"]).reshape(meta["shape"])
        out_leaves.append(arr)
    treedef = jax.tree_util.tree_structure(tree_like)
    return jax.tree_util.tree_unflatten(treedef, out_leaves)


class AsyncCheckpointer:
    """Background checkpoint writer: snapshot on submit, write off-thread.

    ``submit`` copies device arrays to host (blocking only on transfer),
    then a worker thread encodes + writes. ``wait()`` drains the queue.
    """

    def __init__(self, store: ObjectStore, prefix: str, *, policy: Policy | None = None):
        self.store = store
        self.prefix = prefix
        self.policy = policy
        self._q: _queue.Queue = _queue.Queue()
        self._err: Exception | None = None
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    def submit(self, step: int, tree) -> None:
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        self._q.put((step, host_tree))

    def _loop(self):
        while True:
            item = self._q.get()
            try:
                if item is None:
                    return
                step, tree = item
                save_checkpoint(
                    self.store, self.prefix, step, tree,
                    policy=self.policy, pending_hint=self._q.qsize(),
                )
            except Exception as e:  # pragma: no cover
                self._err = e
            finally:
                self._q.task_done()

    def wait(self):
        """Block until all submitted checkpoints are durable."""
        self._q.join()
        if self._err:
            raise self._err

    def close(self):
        self._q.put(None)
        self._thread.join()
        if self._err:
            raise self._err
