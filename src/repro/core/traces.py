"""Synthetic S3-like delay traces (stand-in for the paper's measured traces).

No network access in this container, so the trace-driven evaluation draws
from the paper's own fitted model family (§III-C): shifted exponential with
Δ(B), 1/μ(B) linear in chunk size. Two placement modes:

  * ``unique_key``  — i.i.d. task delays (measured cross-corr < 0.05),
  * ``shared_key``  — correlated tails via a Gaussian copula targeting the
                      measured cross-correlation coefficient (0.11–0.17).

A :class:`TraceStore` pre-generates per-chunk-size delay pools — the moral
equivalent of the paper's 24h measurement runs — from which the simulator
resamples, and from which :func:`repro.core.delay_model.fit_delay_params`
re-estimates {Δ̄, Δ̃, Ψ̄, Ψ̃} exactly the way §V-A does.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

try:  # dev-only dependency (requirements-dev.txt); the erf fallback below
    from scipy import stats as _scipy_stats  # keeps minimal containers working
except ImportError:  # pragma: no cover - exercised on minimal containers
    _scipy_stats = None

from repro.core.delay_model import DelayParams

_SQRT2 = math.sqrt(2.0)
_vec_erf = np.vectorize(math.erf, otypes=[np.float64])


def _norm_cdf(z: np.ndarray) -> np.ndarray:
    """Standard-normal CDF; scipy when available, math.erf otherwise.

    Φ(z) = (1 + erf(z/√2))/2 — exact, just slower elementwise on the
    fallback path, which only runs where scipy isn't installed.
    """
    if _scipy_stats is not None:
        return _scipy_stats.norm.cdf(z)
    return 0.5 * (1.0 + _vec_erf(np.asarray(z) / _SQRT2))


def _corr_exponentials(
    rng: np.random.Generator, mean: float, n: int, rho: float, size: int
) -> np.ndarray:
    """(size, n) exponentials, pairwise Gaussian-copula correlation ~rho."""
    if rho <= 0.0 or n == 1:
        return rng.exponential(mean, size=(size, n))
    cov = np.full((n, n), rho)
    np.fill_diagonal(cov, 1.0)
    z = rng.multivariate_normal(np.zeros(n), cov, size=size, method="cholesky")
    u = _norm_cdf(z)
    u = np.clip(u, 1e-12, 1.0 - 1e-12)
    return -mean * np.log1p(-u)


@dataclasses.dataclass
class TraceSampler:
    """Draws per-task delays for a request served with an (n, k) code."""

    params: DelayParams
    file_mb: float
    correlation: float = 0.0  # 0 → Unique Key; ~0.14 → Shared Key

    def sample(self, rng: np.random.Generator, k: int, n: int) -> np.ndarray:
        B = self.file_mb / k
        tails = _corr_exponentials(rng, self.params.tail_mean(B), n, self.correlation, 1)[0]
        return self.params.delta(B) + tails

    def sample_batch(self, rng: np.random.Generator, k: int, n: int, size: int) -> np.ndarray:
        B = self.file_mb / k
        tails = _corr_exponentials(rng, self.params.tail_mean(B), n, self.correlation, size)
        return self.params.delta(B) + tails


@dataclasses.dataclass
class TraceStore:
    """Pre-generated delay pools per chunk size (the 'collected traces')."""

    chunk_sizes_mb: np.ndarray
    pools: list[np.ndarray]  # pools[i]: (samples, threads) delays for size i

    @classmethod
    def generate(
        cls,
        params: DelayParams,
        chunk_sizes_mb,
        *,
        threads: int = 12,
        samples: int = 20_000,
        correlation: float = 0.0,
        seed: int = 0,
    ) -> "TraceStore":
        rng = np.random.default_rng(seed)
        sizes = np.asarray(chunk_sizes_mb, dtype=np.float64)
        pools = []
        for B in sizes:
            tails = _corr_exponentials(rng, params.tail_mean(B), threads, correlation, samples)
            pools.append(params.delta(B) + tails)
        return cls(chunk_sizes_mb=sizes, pools=pools)

    def pool_for(self, B: float) -> np.ndarray:
        i = int(np.argmin(np.abs(self.chunk_sizes_mb - B)))
        return self.pools[i]

    def thread_delays(self, B: float) -> list[np.ndarray]:
        """Per-thread delay series at chunk size B (for CCDF / corr plots)."""
        pool = self.pool_for(B)
        return [pool[:, t] for t in range(pool.shape[1])]

    def flat_delays(self, B: float) -> np.ndarray:
        return self.pool_for(B).reshape(-1)

    def cross_correlation(self, B: float) -> float:
        """Mean pairwise cross-correlation coefficient between threads."""
        pool = self.pool_for(B)
        c = np.corrcoef(pool.T)
        n = c.shape[0]
        off = c[~np.eye(n, dtype=bool)]
        return float(off.mean())


@dataclasses.dataclass
class StoreSampler:
    """Trace-driven sampler: resamples rows of a TraceStore pool.

    Sampling a row (all threads at one 'time') preserves the cross-thread
    correlation structure of the trace, like replaying measured batches.
    """

    store: TraceStore
    file_mb: float

    def sample(self, rng: np.random.Generator, k: int, n: int) -> np.ndarray:
        B = self.file_mb / k
        pool = self.store.pool_for(B)
        row = pool[rng.integers(pool.shape[0])]
        if n <= row.shape[0]:
            return row[:n].copy()
        extra = pool[rng.integers(pool.shape[0])][: n - row.shape[0]]
        return np.concatenate([row, extra])
