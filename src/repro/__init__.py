"""repro: TOFEC (Liang & Kozat 2013) as the storage/IO layer of a multi-pod
JAX LM training/serving framework. See DESIGN.md."""
