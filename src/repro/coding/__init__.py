from repro.coding import gf256, layout, rs
from repro.coding.layout import SharedKeyLayout, layout_for_file
from repro.coding.rs import MDSCode

__all__ = [
    "gf256",
    "rs",
    "layout",
    "MDSCode",
    "SharedKeyLayout",
    "layout_for_file",
]
