"""Architecture registry: uniform API over all model families.

Every entry exposes:
  init(rng, cfg), train_loss(params, cfg, batch),
  prefill(params, cfg, batch, max_seq), decode_step(params, cfg, token, cache),
  init_cache(cfg, B, max_seq), logical_axes(cfg)
plus batch builders for tests/examples and ShapeDtypeStruct specs for the
dry-run (see repro.launch.specs).
"""

from __future__ import annotations

import dataclasses
import types

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import encdec, hybrid, lm, xlstm
from repro.models.config import SHAPES, ModelConfig, ShapeSpec, cell_is_runnable

_FAMILY_MODULES = {
    "dense": lm,
    "moe": lm,
    "vlm": lm,
    "encdec": encdec,
    "ssm": xlstm,
    "hybrid": hybrid,
}


@dataclasses.dataclass(frozen=True)
class Arch:
    cfg: ModelConfig
    module: types.ModuleType

    @property
    def name(self) -> str:
        return self.cfg.name

    def init(self, rng):
        return self.module.init(rng, self.cfg)

    def train_loss(self, params, batch):
        return self.module.train_loss(params, self.cfg, batch)

    def prefill(self, params, batch, max_seq=None):
        return self.module.prefill(params, self.cfg, batch, max_seq)

    def decode_step(self, params, token, cache):
        return self.module.decode_step(params, self.cfg, token, cache)

    def init_cache(self, B, max_seq):
        return self.module.init_cache(self.cfg, B, max_seq)

    def prefill_tokens(self, params, tokens, max_seq=None):
        """Tokens-only prefill (fused-serving contract): (B, S) int32 in,
        (logits, cache) out, fully traceable. Families whose module defines
        ``prefill_tokens`` use it; otherwise the batch dict is built in-trace
        with zero non-token extras (encdec frames, vlm patches)."""
        fn = getattr(self.module, "prefill_tokens", None)
        if fn is not None:
            return fn(params, self.cfg, tokens, max_seq)
        import jax.numpy as jnp

        batch = {"tokens": tokens}
        if self.cfg.family == "vlm":
            batch["patches"] = jnp.zeros(
                (tokens.shape[0], self.cfg.vision_patches, self.cfg.d_model),
                jnp.float32,
            )
        if self.cfg.family == "encdec":
            batch["frames"] = jnp.zeros(
                (tokens.shape[0], self.cfg.encoder_seq, self.cfg.d_model),
                jnp.float32,
            )
        return self.module.prefill(params, self.cfg, batch, max_seq)

    def logical_axes(self):
        return self.module.logical_axes(self.cfg)


def _configs(smoke: bool):
    # Imported lazily: repro.configs modules import repro.models.config,
    # which would otherwise make this a circular import.
    from repro.configs import ALL_CONFIGS, SMOKE_CONFIGS

    return SMOKE_CONFIGS if smoke else ALL_CONFIGS


def get(name: str, smoke: bool = False) -> Arch:
    cfgs = _configs(smoke)
    if name not in cfgs:
        raise KeyError(f"unknown arch {name!r}; have {sorted(cfgs)}")
    cfg = cfgs[name]
    return Arch(cfg=cfg, module=_FAMILY_MODULES[cfg.family])


def arch_names() -> list[str]:
    return list(_configs(False))


def make_batch(cfg: ModelConfig, shape: ShapeSpec, rng: np.random.Generator | None = None):
    """Concrete batch (numpy → jnp) for train/prefill; tokens/labels/extras."""
    rng = rng or np.random.default_rng(0)
    B, S = shape.batch, shape.seq
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, size=(B, S)), jnp.int32),
    }
    if shape.kind == "train":
        batch["labels"] = jnp.asarray(rng.integers(0, cfg.vocab, size=(B, S)), jnp.int32)
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq, cfg.d_model)), jnp.float32
        )
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(B, cfg.vision_patches, cfg.d_model)), jnp.float32
        )
    return batch


def runnable_cells(arch: str) -> list[tuple[str, bool, str]]:
    """[(shape_name, runnable, reason)] for the given architecture."""
    cfg = _configs(False)[arch]
    return [(s.name, *cell_is_runnable(cfg, s)) for s in SHAPES.values()]
